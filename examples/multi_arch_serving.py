"""AGFT across the assigned architecture zoo: the same tuner binary drives
serving engines for architectures with very different compute/memory
balances (dense / MoE / MLA / SSM / hybrid) and learns a different optimal
frequency for each — the workload-conditional behaviour the paper's
fingerprint is designed to expose.

  PYTHONPATH=src python examples/multi_arch_serving.py
"""
import numpy as np

from repro.configs import get_config
from repro.energy import A6000
from repro.policies import get_policy
from repro.serving import EngineConfig, InferenceEngine
from repro.workloads import PROTOTYPES, generate_requests

ARCHS = ["tinyllama-1.1b", "llama3-3b", "deepseek-v2-lite-16b",
         "mamba2-1.3b", "recurrentgemma-9b"]


def main():
    print(f"{'arch':24s} {'f* (MHz)':>9s} {'energy':>8s} {'tpot':>8s} "
          f"{'EDP':>8s}")
    for arch in ARCHS:
        results = {}
        for with_tuner in (False, True):
            eng = InferenceEngine(get_config(arch), EngineConfig(),
                                  hardware=A6000,
                                  initial_frequency=A6000.f_max)
            eng.submit(generate_requests(PROTOTYPES["normal"], 600,
                                         base_rate=3.0, seed=5))
            tuner = get_policy("agft") if with_tuner else None
            eng.drain(policy=tuner)
            fin = eng.finished
            tpot = float(np.mean([r.tpot for r in fin
                                  if r.tpot is not None]))
            results[with_tuner] = (eng.metrics.c.energy_joules_total, tpot,
                                   tuner)
        (eb, tb, _), (ea, ta, tuner) = results[False], results[True]
        post = [h["freq"] for h in tuner.history if h["converged"]]
        fstar = np.mean(post) if post else float("nan")
        print(f"{arch:24s} {fstar:9.0f} {100*(1-ea/eb):+7.1f}% "
              f"{100*(ta/tb-1):+7.1f}% "
              f"{100*(1-(ea*ta)/(eb*tb)):+7.1f}%")


if __name__ == "__main__":
    main()
