"""Quickstart: the AGFT closed loop in ~40 lines.

Builds the continuous-batching engine for the paper's Llama-3-3B serving
setup (simulated A6000 DVFS backend), runs the 'normal' workload prototype
with and without AGFT, and prints the energy/latency/EDP comparison.
Any registered power policy drops in the same way — try
``get_policy("ondemand")`` or ``get_policy("static", frequency_mhz=1200)``.

  PYTHONPATH=src python examples/quickstart.py
"""
import numpy as np

from repro.configs import get_config
from repro.energy import A6000
from repro.policies import get_policy
from repro.serving import EngineConfig, InferenceEngine
from repro.workloads import PROTOTYPES, generate_requests


def serve(policy=None, n=800, seed=7):
    engine = InferenceEngine(get_config("llama3-3b"), EngineConfig(),
                             hardware=A6000,
                             initial_frequency=A6000.f_max)
    engine.submit(generate_requests(PROTOTYPES["normal"], n,
                                    base_rate=3.0, seed=seed))
    engine.drain(policy=policy)
    fin = engine.finished
    tpot = float(np.mean([r.tpot for r in fin if r.tpot is not None]))
    return {
        "energy_j": engine.metrics.c.energy_joules_total,
        "ttft_s": float(np.mean([r.ttft for r in fin])),
        "tpot_s": tpot,
        "edp": engine.metrics.c.energy_joules_total * tpot,
    }


def main():
    print("baseline (unlocked frequency)...")
    base = serve()
    print("AGFT (online contextual bandit)...")
    tuner = get_policy("agft")
    agft = serve(policy=tuner)

    print(f"\n{'metric':10s} {'baseline':>12s} {'AGFT':>12s} {'diff':>8s}")
    for k in ("energy_j", "ttft_s", "tpot_s", "edp"):
        d = 100 * (agft[k] / base[k] - 1)
        print(f"{k:10s} {base[k]:12.4f} {agft[k]:12.4f} {d:+7.1f}%")
    print(f"\nconverged after {tuner.first_converged_round} decision rounds; "
          f"{len(tuner.pruner.permanently_pruned)} frequencies pruned; "
          f"{len(tuner.refiner.log)} action-space refinements")


if __name__ == "__main__":
    main()
