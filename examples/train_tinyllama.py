"""Train a ~120M-parameter llama-family model for a few hundred steps on
the synthetic LM pipeline (CPU-friendly), demonstrating the training
substrate (AdamW, remat+scan train step, checkpointing).

  PYTHONPATH=src python examples/train_tinyllama.py --steps 300
"""
import argparse

import jax

from repro.configs import get_config
from repro.data import synthetic_token_batches
from repro.models import build_model
from repro.training import AdamWConfig, save_checkpoint, train


def config_120m():
    return get_config("tinyllama-1.1b").replace(
        name="tinyllama-120m",
        num_layers=12, d_model=768, num_heads=12, num_kv_heads=4,
        head_dim=64, d_ff=2048, vocab_size=32000,
        dtype="float32", param_dtype="float32")


def main():
    ap = argparse.ArgumentParser()
    ap.add_argument("--steps", type=int, default=300)
    ap.add_argument("--batch", type=int, default=4)
    ap.add_argument("--seq", type=int, default=64)
    ap.add_argument("--checkpoint", default="")
    args = ap.parse_args()

    cfg = config_120m()
    model = build_model(cfg)
    params = model.init(jax.random.PRNGKey(0))
    n = sum(p.size for p in jax.tree.leaves(params))
    print(f"{cfg.name}: {n/1e6:.1f}M params, "
          f"{args.steps} steps @ batch={args.batch} seq={args.seq}")

    data = synthetic_token_batches(cfg.vocab_size, args.batch, args.seq,
                                   seed=0)

    def log(i, m):
        print(f"step {i:4d}  loss {m['loss']:.4f}  "
              f"gnorm {m['grad_norm']:.2f}  {m['wall_s']:.0f}s")

    params, _, hist = train(model, params, data, steps=args.steps,
                            opt_cfg=AdamWConfig(lr=6e-4, warmup_steps=50),
                            log_every=20, callback=log)
    if args.checkpoint:
        save_checkpoint(args.checkpoint, params)
    print(f"loss: {hist[0]['loss']:.4f} -> {hist[-1]['loss']:.4f}")
    assert hist[-1]["loss"] < hist[0]["loss"], "training must reduce loss"


if __name__ == "__main__":
    main()
