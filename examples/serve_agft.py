"""End-to-end serving driver (the paper's scenario): a long non-stationary
Azure-style request stream served with continuous batching while AGFT tunes
the frequency online. Prints a rolling report of regime shifts, frequency
decisions and cumulative savings, then a final comparison vs baseline.

  PYTHONPATH=src python examples/serve_agft.py --duration 1800
"""
import argparse

import numpy as np

from repro.configs import get_config
from repro.energy import A6000
from repro.policies import get_policy
from repro.serving import EngineConfig, InferenceEngine
from repro.workloads import generate_azure_trace


def run(duration, rate, seed, with_tuner, report_every=300.0):
    eng = InferenceEngine(get_config("llama3-3b"), EngineConfig(),
                          hardware=A6000, initial_frequency=A6000.f_max)
    eng.submit(generate_azure_trace(duration, base_rate=rate, seed=seed))
    tuner = get_policy("agft") if with_tuner else None
    next_report = report_every
    while eng.has_work:
        eng.run_until(next_report, policy=tuner)
        if with_tuner and eng.has_work:
            c = eng.metrics.c
            print(f"  t={eng.clock:7.0f}s f={eng.frequency:6.0f}MHz "
                  f"P={c.current_power_watts:5.1f}W "
                  f"E={c.energy_joules_total/1e3:8.1f}kJ "
                  f"run={c.requests_running:3d} wait={c.requests_waiting:4d} "
                  f"{'EXPLOIT' if tuner.converged else 'explore'}")
        next_report = eng.clock + report_every
    return eng, tuner


def main():
    ap = argparse.ArgumentParser()
    ap.add_argument("--duration", type=float, default=1800.0)
    ap.add_argument("--rate", type=float, default=3.0)
    ap.add_argument("--seed", type=int, default=11)
    args = ap.parse_args()

    print(f"=== AGFT on a {args.duration:.0f}s Azure-style trace ===")
    eng, tuner = run(args.duration, args.rate, args.seed, True)
    print("=== baseline (same trace, unlocked frequency) ===")
    base, _ = run(args.duration, args.rate, args.seed, False)

    def stats(e):
        fin = e.finished
        tpot = float(np.mean([r.tpot for r in fin if r.tpot is not None]))
        return (e.metrics.c.energy_joules_total, tpot,
                float(np.mean([r.ttft for r in fin])))

    ea, ta, fa = stats(eng)
    eb, tb, fb = stats(base)
    print(f"\nenergy  : {ea/1e3:9.1f} kJ vs {eb/1e3:9.1f} kJ "
          f"({100*(1-ea/eb):+.1f}% saving)")
    print(f"TPOT    : {ta*1e3:9.2f} ms vs {tb*1e3:9.2f} ms "
          f"({100*(ta/tb-1):+.1f}%)")
    print(f"TTFT    : {fa*1e3:9.2f} ms vs {fb*1e3:9.2f} ms "
          f"({100*(fa/fb-1):+.1f}%)")
    print(f"EDP     : {ea*ta:9.1f} vs {eb*tb:9.1f} "
          f"({100*(1-(ea*ta)/(eb*tb)):+.1f}% improvement)")
    print(f"adaptive: reopened exploration {tuner.convergence.reopened}x "
          f"across workload regime shifts")


if __name__ == "__main__":
    main()
