"""Fleet-level AGFT (beyond-paper): a 4-node cluster with per-node power
policies and a length-segregating router — nodes specialize and learn
different frequencies for their traffic class. Also shows a heterogeneous
per-node policy mix (AGFT on the long-context half, an SLO controller and
the ondemand governor on the chat half) and the fleet-global controller
(one frequency for every node, learned from aggregated telemetry) through
the same discrete-event driver.

  PYTHONPATH=src python examples/cluster_serving.py
"""
import numpy as np

from repro.configs import get_config
from repro.serving.cluster import ServingCluster, route_by_length
from repro.workloads import PROTOTYPES, generate_requests


def trace(n=800, seed=13):
    return (generate_requests(PROTOTYPES["long_context"], n // 2,
                              base_rate=3.0, seed=seed)
            + generate_requests(PROTOTYPES["normal"], n // 2,
                                base_rate=3.0, seed=seed + 1))


def main():
    cfg = get_config("llama3-3b")
    base = ServingCluster(cfg, n_nodes=4, with_tuners=False,
                          router=route_by_length)
    base.submit(trace())
    base.drain()
    tuned = ServingCluster(cfg, n_nodes=4, with_tuners=True,
                           router=route_by_length)
    tuned.submit(trace())
    tuned.drain()

    b, t = base.summary(), tuned.summary()
    print(f"fleet energy : {t.energy_j/1e3:9.1f} kJ vs {b.energy_j/1e3:9.1f}"
          f" kJ ({100*(1-t.energy_j/b.energy_j):+.1f}%)")
    print(f"fleet EDP    : {t.edp:9.1f} vs {b.edp:9.1f} "
          f"({100*(1-t.edp/b.edp):+.1f}%)")
    for i, tun in enumerate(tuned.policies):
        post = [h["freq"] for h in tun.history if h["converged"]]
        kind = "long-context" if i < 2 else "chat"
        f = np.mean(post) if post else float("nan")
        print(f"node {i} ({kind:12s}): learned f* = {f:6.0f} MHz "
              f"({len(post)} exploit windows)")

    # heterogeneous per-node mix through the same driver: AGFT where the
    # traffic is hard, cheaper controllers where it is predictable
    mixed = ServingCluster(cfg, n_nodes=4, router=route_by_length,
                           policies=["agft", "agft", "slo", "ondemand"])
    mixed.submit(trace())
    mixed.drain()
    m = mixed.summary()
    print(f"mixed fleet  : {m.energy_j/1e3:9.1f} kJ "
          f"({100*(1-m.energy_j/b.energy_j):+.1f}% vs baseline), "
          f"node policies = "
          f"{[type(p).__name__ for p in mixed.policies]}")

    # cross-node coordination baseline: ONE controller, one frequency for
    # the whole fleet, driven by summed telemetry — what per-node loops
    # are measured against (benchmarks.tab_fleet does this exhaustively)
    glob = ServingCluster(cfg, n_nodes=4, router=route_by_length,
                          fleet_policy="global")
    glob.submit(trace())
    glob.drain()
    g = glob.summary()
    print(f"global fleet : {g.energy_j/1e3:9.1f} kJ "
          f"({100*(1-g.energy_j/b.energy_j):+.1f}% vs baseline), "
          f"single f* = {g.node_frequencies[0]:.0f} MHz "
          f"({len(glob.fleet_policy.history)} fleet ticks)")


if __name__ == "__main__":
    main()
