"""Fleet-level AGFT (beyond-paper): a 4-node cluster with per-node tuners
and a length-segregating router — nodes specialize and learn different
frequencies for their traffic class.

  PYTHONPATH=src python examples/cluster_serving.py
"""
import numpy as np

from repro.configs import get_config
from repro.serving.cluster import ServingCluster, route_by_length
from repro.workloads import PROTOTYPES, generate_requests


def trace(n=800, seed=13):
    return (generate_requests(PROTOTYPES["long_context"], n // 2,
                              base_rate=3.0, seed=seed)
            + generate_requests(PROTOTYPES["normal"], n // 2,
                                base_rate=3.0, seed=seed + 1))


def main():
    cfg = get_config("llama3-3b")
    base = ServingCluster(cfg, n_nodes=4, with_tuners=False,
                          router=route_by_length)
    base.submit(trace())
    base.drain()
    tuned = ServingCluster(cfg, n_nodes=4, with_tuners=True,
                           router=route_by_length)
    tuned.submit(trace())
    tuned.drain()

    b, t = base.summary(), tuned.summary()
    print(f"fleet energy : {t.energy_j/1e3:9.1f} kJ vs {b.energy_j/1e3:9.1f}"
          f" kJ ({100*(1-t.energy_j/b.energy_j):+.1f}%)")
    print(f"fleet EDP    : {t.edp:9.1f} vs {b.edp:9.1f} "
          f"({100*(1-t.edp/b.edp):+.1f}%)")
    for i, tun in enumerate(tuned.tuners):
        post = [h["freq"] for h in tun.history if h["converged"]]
        kind = "long-context" if i < 2 else "chat"
        f = np.mean(post) if post else float("nan")
        print(f"node {i} ({kind:12s}): learned f* = {f:6.0f} MHz "
              f"({len(post)} exploit windows)")


if __name__ == "__main__":
    main()
