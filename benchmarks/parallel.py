"""Process-parallel, order-preserving map for benchmark grids.

Every benchmark grid in this repo is an embarrassingly-parallel list of
fully-seeded simulation cells (one trace run per frequency / workload /
policy), so the only orchestration needed is: fan the cells out over a
``ProcessPoolExecutor``, keep the result order identical to the input order
(deterministic merge — results never depend on completion order), and never
nest pools (a worker that fans out again would oversubscribe the host).

Workers are marked via an environment variable inherited by (or injected
into) child processes; ``pmap`` inside a marked worker degrades to a serial
loop. Each cell also reseeds numpy's *global* RNG from (base_seed, index)
before running, so any stray ``np.random`` use stays deterministic
per-cell regardless of scheduling.

Pools use the ``spawn`` start method: the benchmark mains transitively
import JAX (multithreaded), and forking a multithreaded parent can
deadlock. Spawned workers re-import their modules — a one-time ~seconds
cost per pool, irrelevant for the long-lived top-level pools used here
(unit fns and args are picklable by construction).
"""
from __future__ import annotations

import multiprocessing
import os
from concurrent.futures import ProcessPoolExecutor
from typing import Callable, List, Optional, Sequence, TypeVar

T = TypeVar("T")
R = TypeVar("R")

_WORKER_ENV = "REPRO_BENCH_WORKER"


def default_jobs() -> int:
    return max(1, os.cpu_count() or 1)


def in_worker() -> bool:
    return os.environ.get(_WORKER_ENV) == "1"


def _mark_worker() -> None:
    os.environ[_WORKER_ENV] = "1"


def _seeded_call(fn: Callable[[T], R], item: T, seed: Optional[int],
                 idx: int) -> R:
    if seed is not None:
        import numpy as np
        np.random.seed((seed + idx) % (2 ** 32))
    return fn(item)


def pmap(fn: Callable[[T], R], items: Sequence[T], *,
         jobs: Optional[int] = None, seed: Optional[int] = 0) -> List[R]:
    """Map ``fn`` over ``items`` with process parallelism.

    Results are returned in input order (deterministic merge). Falls back
    to a serial loop when ``jobs <= 1``, when there is at most one item, or
    when already inside a pmap worker (no nested pools). ``fn`` and the
    items must be picklable — module-level functions with plain-data
    arguments; strip engine/policy objects from returned rows.
    """
    items = list(items)
    jobs = default_jobs() if jobs is None else jobs
    if jobs <= 1 or len(items) <= 1 or in_worker():
        return [_seeded_call(fn, it, seed, i) for i, it in enumerate(items)]
    with ProcessPoolExecutor(max_workers=min(jobs, len(items)),
                             mp_context=multiprocessing.get_context("spawn"),
                             initializer=_mark_worker) as ex:
        futs = [ex.submit(_seeded_call, fn, it, seed, i)
                for i, it in enumerate(items)]
        return [f.result() for f in futs]
