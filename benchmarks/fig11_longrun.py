"""Paper Figs. 11/12 + headline claims: long-horizon Azure-trace serving,
AGFT vs default-frequency baseline — cumulative energy and cumulative EDP.
(The paper's 12 h is compressed: our synthetic Azure regime shifts every
600 sim-seconds, so a 3600 s run spans ~6 regimes.)"""
from __future__ import annotations

from benchmarks.common import _mean, make_engine, save_json
from benchmarks.parallel import pmap
from repro.policies import get_policy
from repro.workloads import generate_azure_trace


def _run(duration: float, rate: float, seed: int, with_tuner: bool):
    eng = make_engine()
    eng.submit(generate_azure_trace(duration, base_rate=rate, seed=seed))
    tuner = get_policy("agft") if with_tuner else None
    # sample cumulative series every 30 sim-seconds
    series = []
    next_t = 30.0
    while eng.has_work:
        eng.run_until(next_t, policy=tuner)
        c = eng.metrics.c
        gen = max(c.generation_tokens_total, 1)
        series.append({
            "t": eng.clock,
            "energy_j": c.energy_joules_total,
            "cum_tpot": c.busy_seconds_total / gen,
            "freq": eng.frequency,
            "power_w": c.current_power_watts,
        })
        next_t = eng.clock + 30.0
    fin = eng.finished
    tpot = _mean([r.tpot for r in fin if r.tpot is not None])
    ttft = _mean([r.ttft for r in fin])
    return {
        "series": series,
        "energy_j": eng.metrics.c.energy_joules_total,
        "tpot_s": tpot,
        "ttft_s": ttft,
        "edp": eng.metrics.c.energy_joules_total * tpot,
        "finished": len(fin),
        "tuner": None if tuner is None else {
            "converged_round": tuner.converged_round,
            "reopened": tuner.convergence.reopened,
            "rounds": tuner.round,
        },
    }


def _cell(args):
    return _run(*args)


def unit_args(duration: float, rate: float = 3.0, seed: int = 3):
    return [(duration, rate, seed, False), (duration, rate, seed, True)]


def _assemble(base, agft, quiet: bool = False):
    out = {
        "baseline": base,
        "agft": agft,
        "energy_saving_pct": 100 * (1 - agft["energy_j"] / base["energy_j"]),
        "edp_reduction_pct": 100 * (1 - agft["edp"] / base["edp"]),
        "ttft_overhead_pct": 100 * (agft["ttft_s"] / base["ttft_s"] - 1),
        "tpot_overhead_pct": 100 * (agft["tpot_s"] / base["tpot_s"] - 1),
        "paper": {"energy_saving_pct": 30.9, "edp_reduction_pct": 26.1,
                  "note": "paper Fig11/12 cumulative 12h numbers"},
    }
    save_json("fig11_longrun.json", out)
    if not quiet:
        print(f"energy saving {out['energy_saving_pct']:.1f}% "
              f"(paper 30.9%) | EDP {out['edp_reduction_pct']:.1f}% "
              f"(paper 26.1%) | TTFT +{out['ttft_overhead_pct']:.1f}% "
              f"TPOT +{out['tpot_overhead_pct']:.1f}% | "
              f"reopened {agft['tuner']['reopened']}x")
    return out


def run(duration: float = 3600.0, rate: float = 3.0, seed: int = 3,
        quiet: bool = False):
    # baseline and AGFT traces are independent: one process each
    base, agft = pmap(_cell, unit_args(duration, rate, seed), seed=seed)
    return _assemble(base, agft, quiet=quiet)


def main():
    import argparse
    ap = argparse.ArgumentParser(description=__doc__)
    ap.add_argument("--quick", action="store_true",
                    help="short run (900 sim-seconds) for CI smoke")
    ap.add_argument("--duration", type=float, default=0.0,
                    help="explicit trace duration in sim-seconds")
    ap.add_argument("--rate", type=float, default=3.0)
    ap.add_argument("--seed", type=int, default=3)
    args = ap.parse_args()
    duration = args.duration or (900.0 if args.quick else 3600.0)
    run(duration=duration, rate=args.rate, seed=args.seed)


if __name__ == "__main__":
    main()
