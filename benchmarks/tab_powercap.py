"""Power-cap budget sweep (beyond paper; ROADMAP hierarchical fleet
control): the same segregated trace served under a cluster power budget by

  ``pernode``    uncoordinated per-node AGFT — the paper's loop, blind to
                 the budget (metered by an observe-only fleet policy so
                 cap violations are accounted under exactly the same
                 meter)
  ``uniform``    the capped single-frequency controller — one fleet-wide
                 frequency meeting the budget, no node differentiation
                 (``hierarchy-uniform``)
  ``hierarchy``  the two-level coordinator — load-weighted water-filling
                 of the budget into per-node frequency bands on
                 FLEET_TICK, per-node AGFT fine-tuning inside them
                 (``repro.policies.hierarchy``)

Per budget cell we report energy, EDP, latency and the budget accounting
(cap-violation seconds, mean/peak fleet watts). The acceptance shape: the
hierarchy meets budgets the uncoordinated loop violates, at lower EDP
than the uniform single-frequency controller (which must throttle its
whole fleet to what the budget divided by n allows, while the hierarchy
routes the scarce watts to the loaded nodes). An uncapped per-node AGFT
row anchors the sweep; its decisions are bit-identical with the
coordinator attached-but-unconfigured (``power_cap_w=None`` produces no
bands — the golden-trajectory guarantee).
"""
from __future__ import annotations

import argparse
from typing import Dict, List, Optional

from benchmarks.common import PAPER_MODEL, save_json
from repro.configs import get_config
from repro.policies import get_policy
from repro.serving.cluster import ServingCluster, route_by_length
from repro.workloads import PROTOTYPES, generate_requests

#: budgets (watts) for the default 4-node A6000 fleet: ~f_min floor is
#: ~461 W fully busy, uncoordinated AGFT peaks near 500 W on this trace
BUDGETS_W = [300.0, 400.0, 500.0]
N_NODES = 4


def _trace(n: int, seed: int, rate: float = 4.0):
    """Length-segregated long-context + chat mix (the split where
    load-weighted bands can differentiate nodes)."""
    return (generate_requests(PROTOTYPES["long_context"], n // 2,
                              base_rate=rate, seed=seed)
            + generate_requests(PROTOTYPES["normal"], n - n // 2,
                                base_rate=rate, seed=seed + 1))


def _serve(scheme: str, cap: Optional[float], n_requests: int,
           seed: int, n_nodes: int = N_NODES) -> Dict:
    if scheme == "pernode":
        fleet = get_policy("fleet-meter", power_cap_w=cap)
        policies = ["agft"] * n_nodes
    elif scheme == "uniform":
        fleet = get_policy("hierarchy-uniform", power_cap_w=cap)
        policies = None
    elif scheme == "hierarchy":
        fleet = get_policy("hierarchy", power_cap_w=cap)
        policies = ["agft"] * n_nodes
    else:
        raise ValueError(scheme)
    cl = ServingCluster(get_config(PAPER_MODEL), n_nodes=n_nodes,
                        with_tuners=False, policies=policies,
                        fleet_policy=fleet, router=route_by_length)
    cl.submit(_trace(n_requests, seed))
    steps = cl.drain()
    s = cl.summary()
    return {
        "scheme": scheme,
        "power_cap_w": cap,
        "finished": s.finished,
        "energy_j": s.energy_j,
        "ttft_s": s.mean_ttft_s,
        "tpot_s": s.mean_tpot_s,
        "edp": s.edp,
        "cap_violation_s": s.cap_violation_s,
        "metered_s": s.metered_s,
        "mean_fleet_power_w": s.mean_fleet_power_w,
        "peak_fleet_power_w": s.peak_fleet_power_w,
        "node_frequencies": s.node_frequencies,
        "engine_steps": steps,
    }


def unit_args(n_requests: int, budgets: Optional[List[float]] = None,
              seed: int = 11) -> List[tuple]:
    """One unit per (budget, scheme) cell, plus the uncapped anchor."""
    budgets = BUDGETS_W if budgets is None else budgets
    args = [("pernode", None, n_requests, seed)]        # uncapped anchor
    for cap in budgets:
        for scheme in ("pernode", "uniform", "hierarchy"):
            args.append((scheme, cap, n_requests, seed))
    return args


def _cell(args: tuple) -> Dict:
    return _serve(*args)


def _assemble(rows: List[Dict], quiet: bool = False) -> Dict:
    anchor = rows[0]
    by_cap: Dict[str, Dict] = {}
    for r in rows[1:]:
        cell = by_cap.setdefault(f"{r['power_cap_w']:.0f}W", {})
        cell[r["scheme"]] = r
    out = {"uncapped_pernode": anchor, "budgets": by_cap, "headline": {}}
    # headline: tightest budget where per-node AGFT violates — there the
    # hierarchy must hold the cap AND beat the uniform controller's EDP
    for cap_key in sorted(by_cap, key=lambda k: float(k[:-1])):
        cell = by_cap[cap_key]
        if cell["pernode"]["cap_violation_s"] > 0:
            hier, uni = cell["hierarchy"], cell["uniform"]
            out["headline"] = {
                "budget": cap_key,
                "pernode_violation_s": cell["pernode"]["cap_violation_s"],
                "hierarchy_violation_s": hier["cap_violation_s"],
                "hierarchy_meets_cap": hier["cap_violation_s"] == 0.0,
                "hierarchy_edp": hier["edp"],
                "uniform_edp": uni["edp"],
                "edp_vs_uniform_pct":
                    100.0 * (hier["edp"] / uni["edp"] - 1.0),
            }
            break
    save_json("tab_powercap.json", out)
    if not quiet:
        print(f"{'budget':>8s} {'scheme':>10s} {'energy':>9s} {'edp':>9s} "
              f"{'tpot':>8s} {'viol':>7s} {'meanP':>7s} {'peakP':>7s}")
        for cap_key in sorted(by_cap, key=lambda k: float(k[:-1])):
            for scheme in ("pernode", "uniform", "hierarchy"):
                r = by_cap[cap_key][scheme]
                print(f"{cap_key:>8s} {scheme:>10s} "
                      f"{r['energy_j'] / 1e3:8.1f}k {r['edp']:9.1f} "
                      f"{r['tpot_s'] * 1e3:6.1f}ms "
                      f"{r['cap_violation_s']:6.1f}s "
                      f"{r['mean_fleet_power_w']:7.1f} "
                      f"{r['peak_fleet_power_w']:7.1f}")
        h = out["headline"]
        if h:
            print(f"headline @{h['budget']}: pernode violates "
                  f"{h['pernode_violation_s']:.1f}s, hierarchy "
                  f"{h['hierarchy_violation_s']:.1f}s, hierarchy EDP "
                  f"{h['edp_vs_uniform_pct']:+.1f}% vs uniform")
    return out


def run(n_requests: int = 400, budgets: Optional[List[float]] = None,
        seed: int = 11, quiet: bool = False) -> Dict:
    rows = [_cell(a) for a in unit_args(n_requests, budgets, seed)]
    return _assemble(rows, quiet=quiet)


def main() -> None:
    ap = argparse.ArgumentParser()
    ap.add_argument("--quick", action="store_true",
                    help="smaller trace (CI perf-smoke cell)")
    ap.add_argument("--requests", type=int, default=0)
    args = ap.parse_args()
    n = args.requests or (200 if args.quick else 400)
    run(n_requests=n)


if __name__ == "__main__":
    main()
