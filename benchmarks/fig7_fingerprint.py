"""Paper Fig. 7: the 7-dimensional workload fingerprints — per-prototype
mean feature vectors (normalized), pairwise separability, and a 1-NN
identification accuracy check over held-out windows."""
from __future__ import annotations

import numpy as np

from benchmarks.common import make_engine, save_json
from benchmarks.fig5_workloads import WORKLOADS
from repro.core import FEATURE_NAMES, FeatureExtractor
from repro.energy.edp import diff_snapshots
from repro.workloads import PROTOTYPES, generate_requests


def collect_windows(workload: str, *, n_requests: int = 300,
                    rate: float = 3.0, period: float = 0.8,
                    seed: int = 1) -> np.ndarray:
    eng = make_engine()
    eng.submit(generate_requests(PROTOTYPES[workload], n_requests,
                                 base_rate=rate, seed=seed))
    fx = FeatureExtractor()
    xs = []
    prev = eng.metrics.snapshot()
    prev_t = eng.clock
    next_t = period
    while eng.has_work:
        eng.step()
        if eng.clock >= next_t:
            snap = eng.metrics.snapshot()
            w = diff_snapshots(prev, snap, max(eng.clock - prev_t, 1e-9))
            if w.iterations > 0:
                xs.append(fx(w))
            prev, prev_t = snap, eng.clock
            next_t = eng.clock + period
    return np.array(xs)


def run(n_requests: int = 250, quiet: bool = False):
    data = {w: collect_windows(w, n_requests=n_requests) for w in WORKLOADS}
    # normalized mean fingerprints (per-dimension max across prototypes = 1)
    means = {w: x.mean(axis=0) for w, x in data.items()}
    M = np.array([means[w] for w in WORKLOADS])
    denom = np.maximum(M.max(axis=0), 1e-9)
    fingerprints = {w: (means[w] / denom).round(3).tolist()
                    for w in WORKLOADS}
    # separability: pairwise L2 on normalized means
    dists = {}
    for i, a in enumerate(WORKLOADS):
        for b in WORKLOADS[i + 1:]:
            dists[f"{a}|{b}"] = float(np.linalg.norm(
                (means[a] - means[b]) / denom))
    # 1-NN identification on held-out windows (seed=2)
    test = {w: collect_windows(w, n_requests=120, seed=2) for w in WORKLOADS}
    correct = total = 0
    centroids = {w: means[w] / denom for w in WORKLOADS}
    for w, xs in test.items():
        for x in xs:
            xn = x / denom
            pred = min(centroids, key=lambda c: np.linalg.norm(
                xn - centroids[c]))
            correct += int(pred == w)
            total += 1
    acc = correct / max(total, 1)
    out = {"feature_names": list(FEATURE_NAMES),
           "fingerprints": fingerprints,
           "pairwise_distance": dists,
           "min_pairwise_distance": min(dists.values()),
           "nn_identification_accuracy": acc}
    save_json("fig7_fingerprint.json", out)
    if not quiet:
        print("fingerprints (normalized):")
        hdr = " ".join(f"{n[:9]:>10s}" for n in FEATURE_NAMES)
        print(f"{'workload':18s} {hdr}")
        for w in WORKLOADS:
            row = " ".join(f"{v:10.2f}" for v in fingerprints[w])
            print(f"{w:18s} {row}")
        print(f"1-NN window identification accuracy: {acc:.2%}")
    return out


if __name__ == "__main__":
    run()
