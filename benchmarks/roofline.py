"""Roofline analysis (deliverable g): per (arch x shape) on the single-pod
mesh, derive the three roofline terms from the compiled dry-run artifact:

    compute    = HLO_FLOPs / (chips x peak_FLOP/s)
    memory     = HLO_bytes / (chips x HBM_bw)
    collective = collective_bytes / (chips x link_bw)

HLO_FLOPs/bytes use the depth-extrapolated per-device costs (XLA costs a
scan body once; see dryrun --cost-extrapolate). cost_analysis is already
per-partition (per-device), so `chips` divides only the collective term,
whose bytes are whole-program.

Hardware: TPU v5e — 197 TFLOP/s bf16, 819 GB/s HBM, ~50 GB/s/link ICI.
"""
from __future__ import annotations

import json
import os
from typing import Dict, List

from benchmarks.common import save_json
from repro.configs import config_for_shape, get_shape
from repro.energy import active_param_count

PEAK_FLOPS = 197e12
HBM_BW = 819e9
ICI_BW = 50e9

DRYRUN_JSON = os.path.join(os.path.dirname(__file__), "..", "results",
                           "dryrun_full.json")


def model_flops(arch: str, shape_name: str) -> float:
    """Analytical MODEL_FLOPS: 6*N_active*D for train (fwd+bwd), 2*N_active*D
    for prefill, 2*N_active*B for one decode step (2mnk convention)."""
    cfg = config_for_shape(arch, shape_name)
    shape = get_shape(shape_name)
    n = active_param_count(cfg)
    tokens = shape.global_batch * shape.seq_len
    if shape.kind == "train":
        return 6.0 * n * tokens
    if shape.kind == "prefill":
        return 2.0 * n * tokens
    return 2.0 * n * shape.global_batch        # decode: one token per seq


def analyse(row: Dict) -> Dict:
    chips = row["devices"]
    ex = row.get("extrapolated") or {}
    flops = ex.get("flops", row["flops"])            # per-device
    mem_bytes = ex.get("bytes_accessed", row["bytes_accessed"])
    coll = ex.get("collective_bytes", row["collective_bytes"])["total"]

    t_compute = flops / PEAK_FLOPS
    t_memory = mem_bytes / HBM_BW
    t_collective = coll / (chips * ICI_BW)
    terms = {"compute_s": t_compute, "memory_s": t_memory,
             "collective_s": t_collective}
    dominant = max(terms, key=terms.get)

    mf = model_flops(row["arch"], row["shape"])
    useful = mf / (flops * chips) if flops else 0.0
    bound = max(terms.values())
    return {
        "arch": row["arch"], "shape": row["shape"], "mesh": row["mesh"],
        **{k: float(v) for k, v in terms.items()},
        "dominant": dominant.replace("_s", ""),
        "model_flops": mf,
        "useful_flops_ratio": useful,
        "roofline_bound_s": bound,
        "compute_fraction_of_bound": t_compute / bound if bound else 0.0,
    }


def run(quiet: bool = False) -> List[Dict]:
    with open(DRYRUN_JSON) as f:
        data = json.load(f)
    rows = [r for r in data["results"] if r["mesh"] == "16x16"]
    out = [analyse(r) for r in rows]
    save_json("roofline.json", out)
    if not quiet:
        print(f"{'arch':24s} {'shape':12s} {'compute':>10s} {'memory':>10s} "
              f"{'collect':>10s} {'dominant':>10s} {'useful':>7s}")
        for r in out:
            print(f"{r['arch']:24s} {r['shape']:12s} "
                  f"{r['compute_s']:10.3e} {r['memory_s']:10.3e} "
                  f"{r['collective_s']:10.3e} {r['dominant']:>10s} "
                  f"{r['useful_flops_ratio']:7.2f}")
    return out


if __name__ == "__main__":
    run()
