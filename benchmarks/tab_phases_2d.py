"""Phase-disaggregated DVFS ablation (1-D vs 2-D action space).

Serves the Azure production trace (fig11's workload) under five
controllers on one node and compares the phase-disaggregated tuner
against the best single-frequency one:

  ``agft-1d``           the paper's tuner (LinUCB), one clock per node
  ``agft-1d-thompson``  the Thompson-sampling 1-D variant
  ``agft-2d``           AGFT over ``(f_prefill, f_decode)`` pairs seeded
                        around the analytic per-phase EDP optima
                        (``repro.core.tuner2d``)
  ``greenllm-rule``     static per-phase clocks from the same sweep —
                        right clocks, no adaptation
  ``static-fmax``       locked clocks at f_max (the un-tuned anchor)

The physics says 2-D has real headroom: on the A6000/llama3-3b pair the
prefill optimum sits ~1395 MHz (compute-bound — the roofline rewards
fast clocks) and the decode optimum ~1170 MHz (bandwidth-bound — fast
clocks wait on HBM at higher power), so any single clock is a ~225 MHz
compromise against one phase or the other. The headline summary metric,
``agft2d_vs_best1d_edp_pct``, is the EDP delta of the 2-D tuner against
the BEST 1-D AGFT variant (negative = 2-D wins); the ``tab4_5_ablation``
table carries the matching ``phase2d`` ablation row.
"""
from __future__ import annotations

import argparse
from typing import Dict, List, Optional, Tuple

from benchmarks.common import BASE_RATE, run_workload, save_json, \
    strip_engine

#: (variant, registry policy name, policy kwargs); None policy = fixed
#: clocks at f_max
VARIANTS: List[Tuple[str, Optional[str], Dict]] = [
    ("agft-1d", "agft", {}),
    ("agft-1d-thompson", "agft", {"strategy": "thompson"}),
    ("agft-2d", "agft-2d", {}),
    ("greenllm-rule", "greenllm-rule", {}),
    ("static-fmax", None, {}),
]
ONE_D_AGFT = ("agft-1d", "agft-1d-thompson")
FULL_DURATION_S = 1200.0
QUICK_DURATION_S = 240.0


def _cell(args: tuple) -> Dict:
    variant, policy, kwargs, duration, rate, seed = args
    r = run_workload("azure", azure_duration=duration, rate=rate,
                     seed=seed, policy=policy,
                     policy_kwargs=kwargs or None)
    pol = r["policy_obj"]
    row = strip_engine(r)
    row["variant"] = variant
    if pol is not None and hasattr(pol, "bank"):
        row["n_arms"] = len(pol.bank.arms)
        row["converged"] = bool(pol.converged)
        row["switches"] = pol.switch_count
        row["final_action"] = pol.prev_action
    if getattr(pol, "seed_pair", None) is not None:
        row["seed_pair"] = list(pol.seed_pair)
    return row


def unit_args(duration: float, rate: float = BASE_RATE,
              seed: int = 11) -> List[tuple]:
    """One unit per controller variant, all over the same seeded trace."""
    return [(v, p, kw, duration, rate, seed) for v, p, kw in VARIANTS]


def _assemble(rows: List[Dict], quiet: bool = False) -> Dict:
    grid = {r["variant"]: r for r in rows}

    summary: Dict[str, object] = {}
    best_1d = min((v for v in ONE_D_AGFT if v in grid),
                  key=lambda v: grid[v]["edp"], default=None)
    two_d = grid.get("agft-2d")
    if best_1d and two_d:
        ref = grid[best_1d]
        summary["best_1d_variant"] = best_1d
        summary["agft2d_vs_best1d_edp_pct"] = 100.0 * (
            two_d["edp"] / ref["edp"] - 1.0)
        summary["agft2d_vs_best1d_energy_pct"] = 100.0 * (
            two_d["energy_j"] / ref["energy_j"] - 1.0)
    rule = grid.get("greenllm-rule")
    if rule and two_d:
        summary["agft2d_vs_rule_edp_pct"] = 100.0 * (
            two_d["edp"] / rule["edp"] - 1.0)
    static = grid.get("static-fmax")
    if static and two_d:
        summary["agft2d_vs_static_edp_pct"] = 100.0 * (
            two_d["edp"] / static["edp"] - 1.0)
        summary["agft2d_vs_static_energy_pct"] = 100.0 * (
            two_d["energy_j"] / static["energy_j"] - 1.0)

    out = {"grid": grid, "summary": summary}
    save_json("tab_phases_2d.json", out)
    if not quiet:
        print(f"{'variant':>18s} {'finished':>8s} {'energy':>9s} "
              f"{'tpot':>8s} {'edp':>9s} {'transitions':>11s}")
        for v, _, _ in VARIANTS:
            r = grid.get(v)
            if r is None:
                continue
            print(f"{v:>18s} {r['finished']:8d} "
                  f"{r['energy_j'] / 1e3:8.1f}k {r['tpot_s'] * 1e3:6.2f}ms "
                  f"{r['edp']:9.1f} {r['freq_transitions']:11d}")
        d = summary.get("agft2d_vs_best1d_edp_pct")
        if d is not None:
            print(f"agft-2d vs best 1-D ({summary['best_1d_variant']}): "
                  f"edp{d:+.1f}%")
    return out


def run(duration: float = FULL_DURATION_S, rate: float = BASE_RATE,
        seed: int = 11, quiet: bool = False) -> Dict:
    rows = [_cell(a) for a in unit_args(duration, rate, seed)]
    return _assemble(rows, quiet=quiet)


def main() -> None:
    ap = argparse.ArgumentParser()
    ap.add_argument("--quick", action="store_true",
                    help="240s trace instead of 1200s (CI smoke cell)")
    ap.add_argument("--duration", type=float, default=0.0)
    ap.add_argument("--check", action="store_true",
                    help="fail unless agft-2d beats the best 1-D AGFT "
                         "variant on EDP (the PR's acceptance claim)")
    args = ap.parse_args()
    dur = args.duration or (QUICK_DURATION_S if args.quick
                            else FULL_DURATION_S)
    out = run(duration=dur)
    if args.check:
        delta = out["summary"].get("agft2d_vs_best1d_edp_pct")
        if delta is None or delta >= 0.0:
            raise SystemExit(
                f"CHECK FAILED: agft-2d does not beat the best 1-D AGFT "
                f"on EDP (delta {delta})")
        print(f"check passed: 2-D beats best 1-D on EDP ({delta:+.1f}%)")


if __name__ == "__main__":
    main()
