"""Benchmark harness entry point: one function per paper table/figure.

Prints ``name,us_per_call,derived`` CSV (us_per_call = host wall-time per
simulated engine iteration or per benchmark call; derived = the benchmark's
headline metric vs the paper's claim).

  PYTHONPATH=src python -m benchmarks.run            # full suite
  PYTHONPATH=src python -m benchmarks.run --quick    # reduced sizes
"""
from __future__ import annotations

import argparse
import time


def _fig5(quick):
    from benchmarks.fig5_workloads import run
    rows = run(n_requests=120 if quick else 300, quiet=True)
    hc = next(r for r in rows if r["workload"] == "high_concurrency")
    us = sum(r["host_us_per_iteration"] for r in rows) / len(rows)
    return us, f"high_conc_power={hc['avg_power_w']:.0f}W"


def _fig6(quick):
    from benchmarks.fig6_freq_sweep import run
    out = run(n_requests=60 if quick else 120, quiet=True)
    interior = all(v["interior_optimum"] for v in out.values())
    spread = (max(v["optimal_freq"] for v in out.values())
              - min(v["optimal_freq"] for v in out.values()))
    return 0.0, f"interior_optima={interior};spread={spread:.0f}MHz"


def _fig7(quick):
    from benchmarks.fig7_fingerprint import run
    out = run(n_requests=120 if quick else 250, quiet=True)
    return 0.0, f"nn_acc={out['nn_identification_accuracy']:.2f}"


def _fig11(quick):
    from benchmarks.fig11_longrun import run
    out = run(duration=900.0 if quick else 3600.0, quiet=True)
    return 0.0, (f"energy-{out['energy_saving_pct']:.1f}%;"
                 f"edp-{out['edp_reduction_pct']:.1f}%")


def _tab23(quick):
    from benchmarks.tab2_3_phases import run
    out = run(n_requests=800 if quick else 2500, quiet=True)
    st = out["stable_phase"]["diff_pct"] if out["stable_phase"] else {}
    return 0.0, (f"stable_energy{st.get('energy', 0):+.1f}%;"
                 f"stable_edp{st.get('edp', 0):+.1f}%")


def _tab45(quick):
    from benchmarks.tab4_5_ablation import run
    out = run(n_requests=600 if quick else 1500, quiet=True)
    t4 = out["tab4_no_grain_vs_full"]["edp"]
    t5 = out["tab5_no_pruning_vs_full"]["edp"]
    return 0.0, (f"nograin_edp{t4['mean_diff_pct']:+.1f}%;"
                 f"nopruning_edp_cv{t5['cv_diff_pct']:+.0f}%")


def _tab6(quick):
    from benchmarks.tab6_optimal_freq import run
    out = run(n_requests=600 if quick else 1500, quiet=True)
    return 0.0, f"max_abs_dev={out['max_abs_deviation_pct']:.1f}%"


def _tab_fleet(quick):
    from benchmarks.tab_fleet import run
    out = run(n_requests=300 if quick else 600, quiet=True)
    d = out["per_node_vs_global_pct"]
    g = out["global_vs_base_pct"]
    return 0.0, (f"global_energy{g['energy_j']:+.1f}%;"
                 f"pernode_vs_global_edp{d['edp']:+.1f}%")


def _roofline(quick):
    from benchmarks.roofline import run
    try:
        rows = run(quiet=True)
    except FileNotFoundError:
        return 0.0, "SKIPPED(run launch.dryrun --all first)"
    dom = {}
    for r in rows:
        dom[r["dominant"]] = dom.get(r["dominant"], 0) + 1
    return 0.0, ";".join(f"{k}={v}" for k, v in sorted(dom.items()))


BENCHMARKS = [
    ("fig5_workload_profiles", _fig5),
    ("fig6_freq_sweep_optima", _fig6),
    ("fig7_fingerprints", _fig7),
    ("fig11_12_longrun_azure", _fig11),
    ("tab2_3_phase_metrics", _tab23),
    ("tab4_5_ablations", _tab45),
    ("tab6_online_vs_offline", _tab6),
    ("tab_fleet_global_vs_pernode", _tab_fleet),
    ("roofline_terms", _roofline),
]


def main() -> None:
    ap = argparse.ArgumentParser()
    ap.add_argument("--quick", action="store_true")
    ap.add_argument("--only", default="")
    args = ap.parse_args()

    print("name,us_per_call,derived")
    for name, fn in BENCHMARKS:
        if args.only and args.only not in name:
            continue
        t0 = time.perf_counter()
        try:
            us, derived = fn(args.quick)
        except Exception as e:  # noqa: BLE001
            us, derived = 0.0, f"ERROR({str(e)[:80]})"
        wall = time.perf_counter() - t0
        if not us:
            us = 1e6 * wall
        print(f"{name},{us:.1f},{derived}")


if __name__ == "__main__":
    main()
