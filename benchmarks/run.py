"""Benchmark harness entry point: one function per paper table/figure.

Prints ``name,us_per_call,derived`` CSV (us_per_call = host wall-time per
simulated engine iteration or per benchmark call; derived = the benchmark's
headline metric vs the paper's claim).

  PYTHONPATH=src python -m benchmarks.run            # full suite
  PYTHONPATH=src python -m benchmarks.run --quick    # reduced sizes
  PYTHONPATH=src python -m benchmarks.run --jobs 1   # serial (stable timing)

The grid is a DAG of independent, fully-seeded simulation *units* (one
trace run per workload / policy / frequency cell) fanned out over a
``ProcessPoolExecutor``: decomposable benchmarks (fig6, fig11, tab2/3,
tab6) contribute one unit per grid cell, monolithic ones contribute a
single unit. Units are merged back by (benchmark, index) — deterministic
regardless of completion order — and each benchmark's ``reduce`` assembles
its artifact in the main process. The one inter-benchmark dependency
(tab6 consumes fig6's per-workload optima) is expressed as a DAG edge and
handed over by value, not via a filesystem rendezvous. Inside a worker,
nested grids degrade to serial loops — no pool-in-pool.

Profiling & perf budget
-----------------------
Every run writes ``results/perf_baseline.json``: per-benchmark host
wall-time (``wall_s`` = summed unit wall-times, i.e. host CPU cost), the
per-call/per-iteration cost the CSV shows (``us_per_call``), the headline
metric (``derived``), and the end-to-end suite makespan (``total_wall_s``).
Read it as the repo's perf trajectory:

* ``benchmarks["fig5_workload_profiles"].us_per_call`` is the purest
  signal — host microseconds per simulated engine iteration, no policy
  attached, measured in a single process. This is the number the
  physics/decision hot paths are optimized against (PR 3: ~87 -> ~22
  us/iter uncontended).
* ``total_wall_s`` tracks harness throughput (vectorization x process
  parallelism); it is scheduling-sensitive, so compare like-for-like
  ``--jobs`` values. ``comparison`` (when present) records the measured
  before/after wall-times this PR's acceptance was checked against.
* ``--check`` compares a fresh run against the committed
  ``results/perf_baseline.json`` and exits nonzero if any benchmark ERRORs
  or the host-us-per-iteration metric regressed more than 2x (CI
  perf-smoke runs this with ``--jobs 1`` so numbers aren't polluted by
  core contention; raw cell wall-times are recorded but not gated — they
  flake with co-tenancy).
"""
from __future__ import annotations

import argparse
import sys
import time
import zlib
from concurrent.futures import FIRST_COMPLETED, ProcessPoolExecutor, wait
from typing import Callable, Dict, List, Optional, Tuple

from benchmarks import (fig6_freq_sweep, fig11_longrun, tab2_3_phases,
                        tab6_optimal_freq)
from benchmarks.parallel import _mark_worker, default_jobs, in_worker


# ---------------------------------------------------------------------------
# Monolithic benchmark cells (single unit each)
# ---------------------------------------------------------------------------

def _fig5(quick):
    from benchmarks.fig5_workloads import run
    rows = run(n_requests=120 if quick else 300, quiet=True)
    hc = next(r for r in rows if r["workload"] == "high_concurrency")
    us = sum(r["host_us_per_iteration"] for r in rows) / len(rows)
    return us, f"high_conc_power={hc['avg_power_w']:.0f}W"


def _fig7(quick):
    from benchmarks.fig7_fingerprint import run
    out = run(n_requests=120 if quick else 250, quiet=True)
    return 0.0, f"nn_acc={out['nn_identification_accuracy']:.2f}"


def _tab45(quick):
    from benchmarks.tab4_5_ablation import run
    out = run(n_requests=600 if quick else 1500, quiet=True)
    t4 = out["tab4_no_grain_vs_full"]["edp"]
    t5 = out["tab5_no_pruning_vs_full"]["edp"]
    return 0.0, (f"nograin_edp{t4['mean_diff_pct']:+.1f}%;"
                 f"nopruning_edp_cv{t5['cv_diff_pct']:+.0f}%")


def _tab_fleet(quick):
    from benchmarks.tab_fleet import run
    out = run(n_requests=300 if quick else 600, quiet=True)
    d = out["per_node_vs_global_pct"]
    g = out["global_vs_base_pct"]
    m = out["policy_mix"]["tiered_vs_agft_all_by_length_pct"]
    return 0.0, (f"global_energy{g['energy_j']:+.1f}%;"
                 f"pernode_vs_global_edp{d['edp']:+.1f}%;"
                 f"tiered_mix_ttft{m['ttft_s']:+.1f}%")


def _megafleet(quick):
    from benchmarks.tab_megafleet import measure_batched
    out = measure_batched(60 if quick else 250,
                          600.0 if quick else 1800.0, 0.1, 0)
    # us_per_step is a host-us-per-simulated-iteration metric, so the
    # --check 2x gate covers the batched fleet core automatically
    return (out["us_per_step"],
            f"node_iters_per_s={out['node_iterations_per_sec']:.0f}")


def _roofline(quick):
    from benchmarks.roofline import run
    try:
        rows = run(quiet=True)
    except FileNotFoundError:
        return 0.0, "SKIPPED(run launch.dryrun --all first)"
    dom = {}
    for r in rows:
        dom[r["dominant"]] = dom.get(r["dominant"], 0) + 1
    return 0.0, ";".join(f"{k}={v}" for k, v in sorted(dom.items()))


def _mono(fn: Callable) -> Dict:
    return {
        "units": lambda quick, deps: [(fn, (quick,))],
        "reduce": lambda results, quick: (*results[0], None),
    }


# ---------------------------------------------------------------------------
# Decomposed benchmarks: one unit per grid cell + a main-process reduce
# ---------------------------------------------------------------------------

def _fig6_units(quick, deps):
    return [(fig6_freq_sweep._cell, (a,))
            for a in fig6_freq_sweep.unit_args(60 if quick else 120)]


def _fig6_reduce(results, quick):
    out = fig6_freq_sweep._assemble(results, quiet=True)
    interior = all(v["interior_optimum"] for v in out.values())
    spread = (max(v["optimal_freq"] for v in out.values())
              - min(v["optimal_freq"] for v in out.values()))
    return 0.0, f"interior_optima={interior};spread={spread:.0f}MHz", out


def _fig11_units(quick, deps):
    return [(fig11_longrun._cell, (a,))
            for a in fig11_longrun.unit_args(900.0 if quick else 3600.0)]


def _fig11_reduce(results, quick):
    out = fig11_longrun._assemble(results[0], results[1], quiet=True)
    return 0.0, (f"energy-{out['energy_saving_pct']:.1f}%;"
                 f"edp-{out['edp_reduction_pct']:.1f}%"), out


def _tab23_units(quick, deps):
    return [(tab2_3_phases._serve_unit, (a,))
            for a in tab2_3_phases.unit_args(800 if quick else 2500)]


def _tab23_reduce(results, quick):
    out = tab2_3_phases._assemble(results, quiet=True)
    st = out["stable_phase"]["diff_pct"] if out["stable_phase"] else {}
    return 0.0, (f"stable_energy{st.get('energy', 0):+.1f}%;"
                 f"stable_edp{st.get('edp', 0):+.1f}%"), out


def _tab6_units(quick, deps):
    sweep = deps.get("fig6_freq_sweep_optima")
    if sweep is None:                   # standalone --only run: use the file
        from benchmarks.common import load_json
        try:
            sweep = load_json("fig6_freq_sweep.json")
        except FileNotFoundError:       # fresh checkout: compute it
            sweep = fig6_freq_sweep.run(n_requests=60 if quick else 120,
                                        quiet=True)
    return [(tab6_optimal_freq._cell, (a,))
            for a in tab6_optimal_freq.unit_args(600 if quick else 1500,
                                                 sweep)]


def _tab6_reduce(results, quick):
    out = tab6_optimal_freq._assemble(results, quiet=True)
    return 0.0, f"max_abs_dev={out['max_abs_deviation_pct']:.1f}%", out


def _network_units(quick, deps):
    from benchmarks import tab_network
    return [(tab_network._cell, (a,))
            for a in tab_network.unit_args(
                150 if quick else 400,
                tab_network.QUICK_DELAYS_MS if quick else None)]


def _network_reduce(results, quick):
    from benchmarks import tab_network
    out = tab_network._assemble(results, quiet=True)
    s = out["summary"]
    tv = s.get("tick_vs_iteration_at_zero_delay_pct", {})
    impact = s["delay_impact_pct"].get("iteration", {})
    worst = impact[max(impact, key=lambda k: float(k[:-2]))] if impact \
        else {}
    return 0.0, (f"tick_vs_iter_edp{tv.get('edp', 0):+.1f}%;"
                 f"maxdelay_ttft{worst.get('ttft_s', 0):+.1f}%;"
                 f"maxdelay_edp{worst.get('edp', 0):+.1f}%"), out


def _faults_units(quick, deps):
    from benchmarks import tab_faults
    return [(tab_faults._cell, (a,))
            for a in tab_faults.unit_args(
                120 if quick else 300,
                tab_faults.QUICK_PRESETS if quick else None)]


def _faults_reduce(results, quick):
    from benchmarks import tab_faults
    out = tab_faults._assemble(results, quiet=True)
    churn = out["summary"].get("churn", {})
    return 0.0, (f"churn_resilient_compl"
                 f"{churn.get('resilient_completion_rate', 0):.3f};"
                 f"churn_naive_lost"
                 f"{churn.get('naive_lost_requests', 0)}"), out


def _phases2d_units(quick, deps):
    from benchmarks import tab_phases_2d
    return [(tab_phases_2d._cell, (a,))
            for a in tab_phases_2d.unit_args(
                tab_phases_2d.QUICK_DURATION_S if quick
                else tab_phases_2d.FULL_DURATION_S)]


def _phases2d_reduce(results, quick):
    from benchmarks import tab_phases_2d
    out = tab_phases_2d._assemble(results, quiet=True)
    s = out["summary"]
    return 0.0, (f"2d_vs_best1d_edp"
                 f"{s.get('agft2d_vs_best1d_edp_pct', 0):+.1f}%;"
                 f"2d_vs_rule_edp"
                 f"{s.get('agft2d_vs_rule_edp_pct', 0):+.1f}%"), out


def _hetero_units(quick, deps):
    from benchmarks import tab_hetero
    return [(tab_hetero._cell, (a,))
            for a in tab_hetero.unit_args(
                tab_hetero.QUICK_REQUESTS if quick
                else tab_hetero.FULL_REQUESTS)]


def _hetero_reduce(results, quick):
    from benchmarks import tab_hetero
    out = tab_hetero._assemble(results, quiet=True)
    s = out["summary"]
    wins = s["wins"]
    derived = f"energy_wins:{len(wins)}/{len(tab_hetero.MIXED)}"
    first = next((c for c in tab_hetero.MIXED if c in s), None)
    if first is not None:
        derived += (f";{first}_edp_vs_ll"
                    f"{s[first]['edp_vs_least-loaded_pct']:+.1f}%")
    return 0.0, derived, out


def _powercap_units(quick, deps):
    from benchmarks import tab_powercap
    return [(tab_powercap._cell, (a,))
            for a in tab_powercap.unit_args(200 if quick else 400)]


def _powercap_reduce(results, quick):
    from benchmarks import tab_powercap
    out = tab_powercap._assemble(results, quiet=True)
    h = out["headline"]
    if not h:
        return 0.0, "no_binding_budget", out
    derived = (f"@{h['budget']}:"
               f"pernode_viol{h['pernode_violation_s']:.0f}s;"
               f"hier_viol{h['hierarchy_violation_s']:.0f}s;"
               f"hier_vs_uniform_edp{h['edp_vs_uniform_pct']:+.1f}%")
    return 0.0, derived, out


GRID = [
    ("fig5_workload_profiles", _mono(_fig5)),
    ("fig6_freq_sweep_optima", {"units": _fig6_units,
                                "reduce": _fig6_reduce}),
    ("fig7_fingerprints", _mono(_fig7)),
    ("fig11_12_longrun_azure", {"units": _fig11_units,
                                "reduce": _fig11_reduce}),
    ("tab2_3_phase_metrics", {"units": _tab23_units,
                              "reduce": _tab23_reduce}),
    ("tab4_5_ablations", _mono(_tab45)),
    ("tab6_online_vs_offline", {"units": _tab6_units,
                                "reduce": _tab6_reduce,
                                "deps": ("fig6_freq_sweep_optima",)}),
    ("tab_fleet_global_vs_pernode", _mono(_tab_fleet)),
    ("tab_powercap_hierarchy", {"units": _powercap_units,
                                "reduce": _powercap_reduce}),
    ("tab_network_delay_grid", {"units": _network_units,
                                "reduce": _network_reduce}),
    ("tab_faults_robustness", {"units": _faults_units,
                               "reduce": _faults_reduce}),
    ("tab_phases_2d", {"units": _phases2d_units,
                       "reduce": _phases2d_reduce}),
    ("tab_hetero_routing", {"units": _hetero_units,
                            "reduce": _hetero_reduce}),
    ("tab_megafleet_batched", _mono(_megafleet)),
    ("roofline_terms", _mono(_roofline)),
]

PERF_BASELINE = "perf_baseline.json"
# ignore sub-50ms benchmarks in --check: pure noise on shared CI runners
CHECK_MIN_WALL_S = 0.05
CHECK_MAX_REGRESSION = 2.0


def _unit_seed(name: str, idx: int) -> int:
    """Stable per-cell seed for any stray global-RNG use in a unit."""
    return zlib.crc32(f"{name}:{idx}".encode()) % (2 ** 32)


def _run_unit(fn: Callable, args: tuple, seed: int) -> Dict:
    """Worker entry: seed, star-call, time, never raise."""
    import numpy as np
    np.random.seed(seed)
    t0 = time.perf_counter()
    try:
        result = fn(*args)
    except Exception as e:  # noqa: BLE001
        return {"wall_s": time.perf_counter() - t0, "error": str(e)}
    return {"wall_s": time.perf_counter() - t0, "result": result}


def _submit_args(units: List[Tuple[Callable, tuple]], name: str):
    """Attach the stable per-unit seed to every (fn, argtuple) pair."""
    return [(fn, args, _unit_seed(name, i))
            for i, (fn, args) in enumerate(units)]


class _BenchRun:
    """Mutable per-benchmark scheduling state."""

    def __init__(self, name: str, spec: Dict):
        self.name = name
        self.spec = spec
        self.results: List[Optional[Dict]] = []
        self.launched = False

    @property
    def complete(self) -> bool:
        return self.launched and all(r is not None for r in self.results)


def _finalize(run: _BenchRun, quick: bool, rows: Dict, outputs: Dict) -> None:
    wall = sum(r["wall_s"] for r in run.results)
    errors = [r["error"] for r in run.results if "error" in r]
    if errors:
        us, derived, out = 0.0, f"ERROR({errors[0][:80]})", None
    else:
        try:
            us, derived, out = run.spec["reduce"](
                [r["result"] for r in run.results], quick)
        except Exception as e:  # noqa: BLE001
            us, derived, out = 0.0, f"ERROR({str(e)[:80]})", None
    kind = "per_iteration" if us else "wall"
    if not us:
        us = 1e6 * wall
    rows[run.name] = {"wall_s": wall, "us_per_call": us, "us_kind": kind,
                      "derived": derived}
    outputs[run.name] = out


def _profile_units(run: "_BenchRun", units: List) -> List[Dict]:
    """Run a benchmark's DAG units under one cProfile session and dump
    the aggregated stats to ``results/profile_<benchmark>.txt``."""
    import cProfile
    import pstats

    from benchmarks.common import results_path
    pr = cProfile.Profile()
    pr.enable()
    try:
        results = [_run_unit(fn, args, seed) for fn, args, seed in units]
    finally:
        pr.disable()
    path = results_path(f"profile_{run.name}.txt")
    with open(path, "w") as f:
        f.write(f"# cProfile of {len(units)} unit(s) of {run.name}\n")
        st = pstats.Stats(pr, stream=f)
        st.sort_stats("cumulative").print_stats(80)
        st.sort_stats("tottime").print_stats(40)
    print(f"# wrote {path}", file=sys.stderr)
    return results


def run_suite(quick: bool = False, only: str = "",
              jobs: Optional[int] = None, profile: str = "") -> Dict:
    """Run the benchmark DAG; returns the perf_baseline.json payload.

    ``profile`` is a benchmark-name substring: matching benchmarks have
    their units wrapped in cProfile (serial path only — ``main`` forces
    ``--jobs 1`` so the profiler sees the work)."""
    jobs = default_jobs() if jobs is None else jobs
    selected = {n: s for n, s in GRID if not only or only in n}
    runs = {n: _BenchRun(n, s) for n, s in selected.items()}
    rows: Dict[str, Dict] = {}
    outputs: Dict[str, object] = {}
    t0 = time.perf_counter()

    def make_units(run: _BenchRun):
        deps = {d: outputs.get(d) for d in run.spec.get("deps", ())}
        return _submit_args(run.spec["units"](quick, deps), run.name)

    def ready(run: _BenchRun) -> bool:
        return not run.launched and all(
            d not in runs or runs[d].complete
            for d in run.spec.get("deps", ()))

    if jobs <= 1 or in_worker():
        import os

        from benchmarks.parallel import _WORKER_ENV
        prev_mark = os.environ.get(_WORKER_ENV)
        _mark_worker()      # nested grids must not fan out: 1 means serial
        try:
            remaining = list(runs.values())
            while remaining:
                progressed = False
                for run in list(remaining):
                    if not ready(run):
                        continue
                    progressed = True
                    run.launched = True
                    try:
                        units = make_units(run)
                    except Exception as e:  # noqa: BLE001
                        run.results = [{"wall_s": 0.0, "error": str(e)}]
                    else:
                        if profile and profile in run.name:
                            run.results = _profile_units(run, units)
                        else:
                            run.results = [_run_unit(fn, args, seed)
                                           for fn, args, seed in units]
                    _finalize(run, quick, rows, outputs)
                    remaining.remove(run)
                if not progressed:   # unsatisfiable deps (shouldn't happen)
                    for run in remaining:
                        rows[run.name] = {
                            "wall_s": 0.0, "us_per_call": 0.0,
                            "derived": "ERROR(unmet dependency)"}
                    break
        finally:            # don't leave the caller's process marked serial
            if prev_mark is None:
                os.environ.pop(_WORKER_ENV, None)
            else:
                os.environ[_WORKER_ENV] = prev_mark
    else:
        import multiprocessing
        with ProcessPoolExecutor(
                max_workers=jobs,
                mp_context=multiprocessing.get_context("spawn"),
                initializer=_mark_worker) as ex:
            futs = {}

            def launch_ready():
                for run in runs.values():
                    if not ready(run):
                        continue
                    run.launched = True
                    try:
                        units = make_units(run)
                    except Exception as e:  # noqa: BLE001
                        run.results = [{"wall_s": 0.0, "error": str(e)}]
                        _finalize(run, quick, rows, outputs)
                        continue
                    run.results = [None] * len(units)
                    for i, (fn, args, seed) in enumerate(units):
                        futs[ex.submit(_run_unit, fn, args, seed)] = (run, i)

            launch_ready()
            while futs:
                done, _ = wait(list(futs), return_when=FIRST_COMPLETED)
                for f in done:
                    run, i = futs.pop(f)
                    try:
                        run.results[i] = f.result()
                    except Exception as e:  # noqa: BLE001
                        run.results[i] = {"wall_s": 0.0, "error": str(e)}
                    if run.complete:
                        _finalize(run, quick, rows, outputs)
                launch_ready()

    total = time.perf_counter() - t0
    return {
        "quick": quick,
        "jobs": jobs,
        "total_wall_s": total,
        "benchmarks": {n: rows[n] for n in selected if n in rows},
    }


def check_against_baseline(payload: Dict, baseline: Dict) -> list:
    """Perf-regression gate: list of failure strings (empty = pass).

    Any ERROR row fails. The >2x timing gate applies only to rows whose
    ``us_per_call`` is a host-us-per-simulated-iteration metric (fig5):
    raw cell wall-times swing with scheduling/co-tenancy far more than the
    per-iteration cost does, so gating on them would flake; they are still
    recorded in the artifact for trend review."""
    failures = []
    for name, row in payload["benchmarks"].items():
        if row["derived"].startswith("ERROR("):
            failures.append(f"{name}: {row['derived']}")
            continue
        ref = baseline.get("benchmarks", {}).get(name)
        if ref is None or ref["derived"].startswith(("ERROR(", "SKIPPED")):
            continue
        if (row.get("us_kind") != "per_iteration"
                or ref.get("us_kind") != "per_iteration"):
            continue
        if min(row["wall_s"], ref["wall_s"]) < CHECK_MIN_WALL_S:
            continue
        if row["us_per_call"] > CHECK_MAX_REGRESSION * ref["us_per_call"]:
            failures.append(
                f"{name}: us/iteration {row['us_per_call']:.1f} > "
                f"{CHECK_MAX_REGRESSION}x baseline {ref['us_per_call']:.1f}")
    return failures


def main() -> None:
    from benchmarks.common import load_json, save_json

    ap = argparse.ArgumentParser()
    ap.add_argument("--quick", action="store_true")
    ap.add_argument("--only", default="")
    ap.add_argument("--jobs", type=int, default=None,
                    help="process-pool width (default: all cores; 1=serial)")
    ap.add_argument("--check", action="store_true",
                    help="fail if us_per_call regressed >2x vs the "
                         "committed results/perf_baseline.json")
    ap.add_argument("--profile", default="",
                    help="benchmark-name substring: wrap matching DAG "
                         "units in cProfile and write "
                         "results/profile_<benchmark>.txt (forces "
                         "--jobs 1; timings are skewed, so the baseline "
                         "file is not rewritten)")
    args = ap.parse_args()
    if args.profile:
        args.jobs = 1

    baseline = None
    if args.check:
        try:
            baseline = load_json(PERF_BASELINE)
        except (FileNotFoundError, ValueError):
            print("no committed perf baseline; writing a fresh one",
                  file=sys.stderr)

    payload = run_suite(quick=args.quick, only=args.only, jobs=args.jobs,
                        profile=args.profile)
    print("name,us_per_call,derived")
    for name, row in payload["benchmarks"].items():
        print(f"{name},{row['us_per_call']:.1f},{row['derived']}")
    print(f"# total_wall_s={payload['total_wall_s']:.1f} "
          f"jobs={payload['jobs']}")

    if baseline is not None:
        payload["reference"] = {
            "total_wall_s": baseline["total_wall_s"],
            "jobs": baseline.get("jobs"),
        }
        if "comparison" in baseline:
            payload["comparison"] = baseline["comparison"]
    if not args.only and not args.profile:
        # a filtered or profiled run must not gut the committed baseline
        save_json(PERF_BASELINE, payload)

    if args.check and baseline is not None:
        failures = check_against_baseline(payload, baseline)
        if failures:
            print("PERF CHECK FAILED:\n  " + "\n  ".join(failures),
                  file=sys.stderr)
            sys.exit(1)
        print("perf check passed vs committed baseline", file=sys.stderr)


if __name__ == "__main__":
    main()
