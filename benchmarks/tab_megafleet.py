"""Mega-fleet replay: batched SoA fleet stepping vs the per-event loop.

Replays an Azure-trace day across a 1000-node fleet with
``ServingCluster(step_mode="batched")`` and reports throughput as
**node-iterations/sec** — simulated engine iterations summed over all
nodes, per host wall-second. The per-event baseline is measured on a
short prefix slice of the same workload and extrapolated by its
host-us-per-iteration cost times the replay's total iteration count.
That extrapolation is exact in iteration count (the two backends execute
bit-identical trajectories — see ``tests/test_fleet_step.py`` — so the
slice's per-iteration cost is priced against the very same step stream)
and conservative in per-step cost: the slice is the cold-cache start of
the day, where the event loop spends *less* time per iteration than in
the KV-pressured steady state.

Both backends run the same throughput-oriented engine configuration
(``ENGINE_CFG``): identical KV token capacity to the default config, but
coarser 128-token blocks (8x fewer Python-level block walks per request
in the prefix-cache paths) and single-chunk prefill for typical Azure
prompts. Request placement is O(1) round-robin over arrival order so
router cost does not pollute either backend's drain timing.

  PYTHONPATH=src python -m benchmarks.tab_megafleet            # day replay
  PYTHONPATH=src python -m benchmarks.tab_megafleet --quick    # CI smoke
  PYTHONPATH=src python -m benchmarks.tab_megafleet --quick --check
  PYTHONPATH=src python -m benchmarks.tab_megafleet --train-cap sweep

``--check`` compares the run's node-iterations/sec against the committed
``results/tab_megafleet.json`` for the same mode and fails on a >2x
regression (the CI perf-smoke gate). ``--train-cap`` overrides the
batched backend's decode-train cap, or sweeps 8/16/64/256 — the sweep on
a 1h day slice measured 64 (the committed default) fastest, ~20% over
cap 8 and ~16% over cap 256.
"""
from __future__ import annotations

import argparse
import gc
import sys
import time
from typing import Dict, List

from benchmarks.common import PAPER_MODEL, load_json, save_json
from repro.configs import get_config
from repro.serving.cluster import ServingCluster
from repro.serving.engine import EngineConfig
from repro.workloads import generate_azure_trace

ARTIFACT = "tab_megafleet.json"
DAY_S = 86400.0
CHECK_MAX_REGRESSION = 2.0

# Same 65536-token KV capacity as the default EngineConfig (4096 x 16),
# restated in 128-token blocks; prefill_chunk matches max_batched_tokens
# so a typical Azure prompt prefills in one iteration on both backends.
ENGINE_CFG = EngineConfig(num_kv_blocks=512, kv_block_size=128,
                          prefill_chunk=2048)


# ---------------------------------------------------------------------------
def build_fleet(n_nodes: int, duration_s: float, rate_per_node: float,
                seed: int, step_mode: str = "batched",
                train_cap: int = None) -> ServingCluster:
    """Fleet + submitted trace. Round-robin placement over arrival order:
    O(1) per request, identical assignment for both backends."""
    cl = ServingCluster(get_config(PAPER_MODEL), n_nodes=n_nodes,
                        engine_cfg=ENGINE_CFG, step_mode=step_mode,
                        batched_record_history=False,
                        batched_train_cap=train_cap)
    reqs = generate_azure_trace(duration_s,
                                base_rate=rate_per_node * n_nodes,
                                seed=seed)
    reqs.sort(key=lambda r: r.arrival_time)
    for i, r in enumerate(reqs):
        cl.nodes[i % n_nodes].engine.submit([r])
    cl._n_submitted = len(reqs)
    return cl


MAX_ITERS = 2_000_000_000   # a day replay runs ~270M iterations; the
                            # default drain budget (10M) would truncate it


def _drain_timed(cl: ServingCluster) -> Dict:
    """Drain with GC parked (both backends get the same treatment: a
    multi-million-object fleet makes collector sweeps the top cost of
    whichever backend runs second)."""
    was_enabled = gc.isenabled()
    gc.collect()
    gc.disable()
    try:
        t0 = time.perf_counter()
        steps = cl.drain(max_iters=MAX_ITERS)
        wall = time.perf_counter() - t0
    finally:
        if was_enabled:
            gc.enable()
    return {"steps": int(steps), "wall_s": wall,
            "us_per_step": 1e6 * wall / max(steps, 1),
            "node_iterations_per_sec": steps / wall if wall > 0 else 0.0}


def measure_batched(n_nodes: int, duration_s: float, rate_per_node: float,
                    seed: int, train_cap: int = None) -> Dict:
    cl = build_fleet(n_nodes, duration_s, rate_per_node, seed, "batched",
                     train_cap=train_cap)
    out = _drain_timed(cl)
    out["requests"] = cl._n_submitted
    loop = cl._loop
    out["train_cap"] = loop.train_cap
    out["classb_fast_steps"] = int(loop.classb_fast_steps)
    out["classb_engine_steps"] = int(loop.classb_engine_steps)
    out["admitted_requests"] = int(loop.admitted_requests)
    out["engine_steps_per_admitted"] = (
        loop.classb_engine_steps / loop.admitted_requests
        if loop.admitted_requests else 0.0)
    return out


def measure_event_slice(n_nodes: int, slice_s: float, rate_per_node: float,
                        seed: int) -> Dict:
    """Event-loop cost on the day's first ``slice_s`` seconds of arrivals
    (same trace generator, same seed, same placement), drained to empty."""
    cl = build_fleet(n_nodes, slice_s, rate_per_node, seed, "event")
    out = _drain_timed(cl)
    out["sim_s"] = slice_s
    out["requests"] = cl._n_submitted
    return out


# ---------------------------------------------------------------------------
def run(n_nodes: int = 1000, duration_s: float = DAY_S,
        rate_per_node: float = 0.05, event_slice_s: float = 600.0,
        seed: int = 0, quiet: bool = False,
        train_cap: int = None) -> Dict:
    log = (lambda *a: None) if quiet else print
    log(f"[megafleet] event-loop slice: {n_nodes} nodes x "
        f"{event_slice_s:.0f}s @ {rate_per_node}/node/s")
    ev = measure_event_slice(n_nodes, event_slice_s, rate_per_node, seed)
    log(f"[megafleet]   {ev['steps']} iterations in {ev['wall_s']:.1f}s "
        f"({ev['us_per_step']:.2f} us/iter)")
    log(f"[megafleet] batched replay: {n_nodes} nodes x {duration_s:.0f}s")
    bt = measure_batched(n_nodes, duration_s, rate_per_node, seed,
                         train_cap=train_cap)
    log(f"[megafleet]   {bt['steps']} iterations in {bt['wall_s']:.1f}s "
        f"({bt['us_per_step']:.2f} us/iter, "
        f"{bt['node_iterations_per_sec']:.0f} node-iters/s)")
    extrap = ev["us_per_step"] * bt["steps"] * 1e-6
    speedup = extrap / bt["wall_s"] if bt["wall_s"] > 0 else float("inf")
    log(f"[megafleet] extrapolated event-loop replay: {extrap:.0f}s "
        f"-> speedup {speedup:.1f}x")
    return {
        "n_nodes": n_nodes,
        "duration_s": duration_s,
        "rate_per_node": rate_per_node,
        "requests": bt.pop("requests"),
        "engine_cfg": {"num_kv_blocks": ENGINE_CFG.num_kv_blocks,
                       "kv_block_size": ENGINE_CFG.kv_block_size,
                       "prefill_chunk": ENGINE_CFG.prefill_chunk},
        "batched": bt,
        "event_slice": ev,
        "extrapolated_event_wall_s": extrap,
        "speedup_vs_event": speedup,
    }


# ---------------------------------------------------------------------------
SWEEP_CAPS = (8, 16, 64, 256)


def sweep(n_nodes: int, duration_s: float, rate_per_node: float,
          seed: int = 0) -> List[Dict]:
    """Time the batched replay at each train cap in ``SWEEP_CAPS`` —
    the measurement behind the committed ``TRAIN_CAP`` default (the
    trajectories are cap-invariant, so this is a pure wall-clock
    comparison)."""
    out = []
    print(f"[megafleet] train-cap sweep: {n_nodes} nodes x "
          f"{duration_s:.0f}s @ {rate_per_node}/node/s")
    for cap in SWEEP_CAPS:
        bt = measure_batched(n_nodes, duration_s, rate_per_node, seed,
                             train_cap=cap)
        print(f"[megafleet]   cap={cap:>4}: {bt['wall_s']:6.1f}s  "
              f"{bt['node_iterations_per_sec']:>10,.0f} node-iters/s")
        out.append(bt)
    return out


def _check(payload: Dict, mode: str) -> List[str]:
    """>2x node-iterations/sec regression vs the committed artifact."""
    try:
        ref = load_json(ARTIFACT).get(mode)
    except (FileNotFoundError, ValueError):
        return []
    if not ref:
        return []
    cur = payload["batched"]["node_iterations_per_sec"]
    base = ref["batched"]["node_iterations_per_sec"]
    if cur * CHECK_MAX_REGRESSION < base:
        return [f"megafleet[{mode}]: {cur:.0f} node-iters/s < "
                f"1/{CHECK_MAX_REGRESSION}x baseline {base:.0f}"]
    return []


def main() -> None:
    ap = argparse.ArgumentParser()
    ap.add_argument("--quick", action="store_true",
                    help="CI smoke: 100 nodes, 900s slice of the day")
    ap.add_argument("--nodes", type=int, default=None)
    ap.add_argument("--duration", type=float, default=None)
    ap.add_argument("--rate", type=float, default=None,
                    help="requests per node per second")
    ap.add_argument("--event-slice", type=float, default=None,
                    help="seconds of the workload timed under the "
                         "per-event loop for the extrapolation")
    ap.add_argument("--check", action="store_true",
                    help="fail on >2x node-iterations/sec regression vs "
                         "committed results/tab_megafleet.json")
    ap.add_argument("--train-cap", default=None,
                    help="decode-train length cap for the batched backend "
                         "(int), or 'sweep' to time caps "
                         f"{'/'.join(str(c) for c in SWEEP_CAPS)} on the "
                         "batched replay and exit (no artifact write)")
    args = ap.parse_args()

    if args.quick:
        defaults = dict(n_nodes=100, duration_s=900.0, rate_per_node=0.1,
                        event_slice_s=120.0)
    else:
        defaults = dict(n_nodes=1000, duration_s=DAY_S, rate_per_node=0.05,
                        event_slice_s=600.0)
    if args.nodes is not None:
        defaults["n_nodes"] = args.nodes
    if args.duration is not None:
        defaults["duration_s"] = args.duration
    if args.rate is not None:
        defaults["rate_per_node"] = args.rate
    if args.event_slice is not None:
        defaults["event_slice_s"] = args.event_slice

    if args.train_cap == "sweep":
        defaults.pop("event_slice_s")
        sweep(**defaults)
        return
    if args.train_cap is not None:
        defaults["train_cap"] = int(args.train_cap)

    payload = run(**defaults)
    mode = "quick" if args.quick else "day"

    # merge into the committed artifact: a quick run must not clobber the
    # day-replay numbers (and vice versa)
    try:
        artifact = load_json(ARTIFACT)
    except (FileNotFoundError, ValueError):
        artifact = {}
    artifact[mode] = payload
    save_json(ARTIFACT, artifact)

    if args.check:
        failures = _check(payload, mode)
        if failures:
            print("PERF CHECK FAILED:\n  " + "\n  ".join(failures),
                  file=sys.stderr)
            sys.exit(1)
        print("megafleet perf check passed vs committed artifact",
              file=sys.stderr)


if __name__ == "__main__":
    main()
