"""Paper Fig. 6: U-shaped EDP-vs-frequency curves and per-prototype optimal
frequencies (offline 'theoretical optimum' sweep, two-stage at 15 MHz)."""
from __future__ import annotations

from benchmarks.common import save_json, two_stage_optimal
from benchmarks.fig5_workloads import WORKLOADS
from benchmarks.parallel import pmap

# paper Fig. 6 reported optima (MHz) for qualitative comparison
PAPER_OPTIMA = {"normal": 1230, "long_context": 1395,
                "long_generation": 1260, "high_concurrency": 1365,
                "high_cache_hit": 1200}


def _cell(args):
    """Two-stage sweep for one workload prototype (one pmap cell; the
    inner frequency grid runs serially when nested in a worker)."""
    w, n_requests = args
    best, rows = two_stage_optimal(w, n_requests=n_requests)
    # U-shape check: optimum strictly interior
    freqs = [r["frequency"] for r in rows]
    interior = (min(freqs) < best["frequency"] < max(freqs))
    return {
        "optimal_freq": best["frequency"],
        "optimal_edp": best["edp_sweep"],
        "interior_optimum": bool(interior),
        "paper_optimum": PAPER_OPTIMA[w],
        "curve": [{"f": r["frequency"], "edp": r["edp_sweep"],
                   "energy_j": r["energy_j"], "delay_s": r["delay_s"]}
                  for r in rows],
    }


def unit_args(n_requests: int):
    return [(w, n_requests) for w in WORKLOADS]


def _assemble(cells, quiet: bool = False):
    out = dict(zip(WORKLOADS, cells))
    for w in WORKLOADS:
        if not quiet:
            print(f"{w:18s} f*={out[w]['optimal_freq']:6.0f} MHz "
                  f"(paper {PAPER_OPTIMA[w]}) "
                  f"interior={out[w]['interior_optimum']}")
    save_json("fig6_freq_sweep.json", out)
    return out


def run(n_requests: int = 120, quiet: bool = False):
    return _assemble(pmap(_cell, unit_args(n_requests), seed=1),
                     quiet=quiet)


if __name__ == "__main__":
    run()
