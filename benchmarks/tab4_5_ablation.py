"""Paper Tables 4/5: ablations — disable fine-grained frequency control
("No-grain") and disable intelligent pruning ("No pruning"); compare means
and coefficients of variation (CV) of the window metrics. Extended with
the switching-cost-aware variant (``agft-switchcost``, ROADMAP /
arXiv:2410.11855): DVFS transitions are priced into the reward, so the row
quantifies how much actuation churn the penalty removes and what it costs
in EDP. A second extension row (``phase2d``) runs the phase-disaggregated
``agft-2d`` tuner on the same trace, treating the whole 1-D action space
as the ablated configuration — the Azure-trace headline comparison lives
in ``tab_phases_2d.py``."""
from __future__ import annotations


import numpy as np

from benchmarks.common import make_engine, save_json
from repro.core import AGFTConfig
from repro.core.pruning import PruningConfig
from repro.energy import A6000
from repro.policies import get_policy
from repro.workloads import PROTOTYPES, generate_requests


def _run(tcfg: AGFTConfig, n_requests: int, rate: float, seed: int,
         policy: str = "agft"):
    eng = make_engine()
    eng.submit(generate_requests(PROTOTYPES["normal"], n_requests,
                                 base_rate=rate, seed=seed))
    # any registered windowed policy works here; only agft takes a cfg
    tuner = get_policy(policy, hardware=A6000,
                       **({"cfg": tcfg}
                          if policy in ("agft", "agft-switchcost",
                                        "agft-2d") else {}))
    eng.drain(policy=tuner)
    ws = [h for h in tuner.history
          if h["energy_j"] is not None and h["tpot"] is not None]
    energy = np.array([h["energy_j"] for h in ws])
    edp = np.array([h["edp"] for h in ws])
    tpot = np.array([h["tpot"] for h in ws])
    fin = eng.finished
    ttft = np.array([r.ttft for r in fin])
    e2e = np.array([r.e2e for r in fin])

    def stats(x):
        m = float(np.mean(x))
        return {"mean": m, "cv": float(np.std(x) / m) if m else 0.0}

    pruner = getattr(tuner, "pruner", None)
    return {"energy": stats(energy), "edp": stats(edp),
            "tpot": stats(tpot), "ttft": stats(ttft), "e2e": stats(e2e),
            "pruned": len(pruner.permanently_pruned) if pruner else 0,
            "switches": eng.metrics.c.freq_transitions_total,
            "n_windows": len(ws)}


def run(n_requests: int = 1500, rate: float = 3.0, seed: int = 2,
        policy: str = "agft", quiet: bool = False):
    full = _run(AGFTConfig(), n_requests, rate, seed, policy=policy)
    nograin = _run(AGFTConfig(fine_grained=False), n_requests, rate, seed)
    nopruning = _run(
        AGFTConfig(pruning=PruningConfig(enabled=False)),
        n_requests, rate, seed)
    switchcost = _run(AGFTConfig(), n_requests, rate, seed,
                      policy="agft-switchcost")
    phase2d = _run(AGFTConfig(), n_requests, rate, seed, policy="agft-2d")

    def diff(a, b, key, field):
        return 100 * (b[key][field] / a[key][field] - 1) \
            if a[key][field] else 0.0

    out = {
        "full": full, "no_grain": nograin, "no_pruning": nopruning,
        "switchcost": switchcost, "phase2d": phase2d,
        "tab4_no_grain_vs_full": {
            k: {"mean_diff_pct": diff(full, nograin, k, "mean"),
                "cv_diff_pct": diff(full, nograin, k, "cv")}
            for k in ("energy", "edp", "ttft", "tpot", "e2e")},
        "tab5_no_pruning_vs_full": {
            k: {"cv_diff_pct": diff(full, nopruning, k, "cv")}
            for k in ("energy", "edp", "ttft", "tpot", "e2e")},
        "tab_switchcost_vs_full": {
            "switches_full": full["switches"],
            "switches_switchcost": switchcost["switches"],
            "switch_reduction_pct": 100 * (
                1 - switchcost["switches"] / max(full["switches"], 1)),
            **{k: {"mean_diff_pct": diff(full, switchcost, k, "mean")}
               for k in ("energy", "edp", "ttft", "tpot", "e2e")},
        },
        "tab_2d_vs_full": {
            "switches_full": full["switches"],
            "switches_2d": phase2d["switches"],
            **{k: {"mean_diff_pct": diff(full, phase2d, k, "mean")}
               for k in ("energy", "edp", "ttft", "tpot", "e2e")},
        },
        "paper": {
            "tab4": {"edp_mean": +9.24, "energy_cv": +151, "edp_cv": +34},
            "tab5": {"edp_cv": +33.1, "tpot_cv": +31.5},
        },
    }
    save_json("tab4_5_ablation.json", out)
    if not quiet:
        print("no-grain vs full:   " + " ".join(
            f"{k}:mean{v['mean_diff_pct']:+.1f}%/cv{v['cv_diff_pct']:+.0f}%"
            for k, v in out["tab4_no_grain_vs_full"].items()))
        print("no-pruning vs full: " + " ".join(
            f"{k}:cv{v['cv_diff_pct']:+.0f}%"
            for k, v in out["tab5_no_pruning_vs_full"].items()))
        sc = out["tab_switchcost_vs_full"]
        print(f"switchcost vs full: switches {sc['switches_full']} -> "
              f"{sc['switches_switchcost']} "
              f"({sc['switch_reduction_pct']:+.0f}% fewer), "
              f"edp {sc['edp']['mean_diff_pct']:+.1f}%")
        p2 = out["tab_2d_vs_full"]
        print(f"phase-2d vs full:   "
              f"energy {p2['energy']['mean_diff_pct']:+.1f}%, "
              f"edp {p2['edp']['mean_diff_pct']:+.1f}%, "
              f"switches {p2['switches_full']} -> {p2['switches_2d']}")
    return out


if __name__ == "__main__":
    run()
