"""Fault-preset x policy-resilience grid (robustness; ROADMAP fault
items): what seeded fault injection costs each controller, and what the
graceful-degradation paths buy back.

The same fixed-seed trace is served by a 3-node cluster under every
combination of

  fault preset   ``none`` (healthy anchor), ``flaky-dvfs`` (stuck
                 actuations), ``node-churn`` (crash/repair with retry
                 re-routing), ``thermal`` (throttle windows),
                 ``lossy-telemetry`` (blank monitor windows)
  configuration  ``resilient``  per-node AGFT with fault-aware freezes
                               + the preset's full retry budget
                 ``naive``      agft-naive (learns from corrupted
                               windows, never re-issues stuck
                               actuations) + a zero retry budget
                 ``static``     fixed f_max, no tuner, full retry
                               budget — isolates the serving-path
                               resilience from the learning story

Per cell we report completion rate (finished / non-dropped submitted),
drop counts, SLO attainment (fraction of finished requests with TTFT
under the threshold), energy/EDP, and the fault counters. The summary
pulls the acceptance comparisons: resilient completes 100% of
non-dropped requests under churn while the naive no-retry baseline
provably loses requests, and the resilient tuner's EDP under corrupted
telemetry vs the naive learner's.
"""
from __future__ import annotations

import argparse
from typing import Dict, List, Optional

from benchmarks.common import PAPER_MODEL, save_json
from repro.configs import get_config
from repro.serving.cluster import ServingCluster
from repro.workloads import PROTOTYPES, generate_requests

PRESETS = ["none", "flaky-dvfs", "node-churn", "thermal",
           "lossy-telemetry"]
QUICK_PRESETS = ["none", "node-churn", "lossy-telemetry"]
CONFIGS = ("resilient", "naive", "static")
N_NODES = 3
FAULT_SEED = 0
#: TTFT SLO threshold (seconds) for the attainment column
SLO_TTFT_S = 1.0


def _spec_and_policies(preset: str, config: str):
    """The (fault spec, per-node policies) a grid cell runs."""
    if config == "resilient":
        return preset, ["agft"] * N_NODES
    if config == "naive":
        spec = preset if preset == "none" else f"{preset};crash:retries=0"
        return spec, ["agft-naive"] * N_NODES
    return preset, [None] * N_NODES          # static f_max


def _trace(n: int, seed: int):
    return generate_requests(PROTOTYPES["normal"], n, base_rate=4.0,
                             seed=seed)


def _serve(preset: str, config: str, n_requests: int, seed: int) -> Dict:
    spec, policies = _spec_and_policies(preset, config)
    cl = ServingCluster(get_config(PAPER_MODEL), n_nodes=N_NODES,
                        with_tuners=False, policies=policies,
                        faults=spec, fault_seed=FAULT_SEED)
    cl.submit(_trace(n_requests, seed))
    steps = cl.drain()
    s = cl.summary()
    fin = [r for e in cl.engines for r in e.finished]
    slo = (sum(1 for r in fin if r.ttft is not None
               and r.ttft <= SLO_TTFT_S) / len(fin)) if fin else 0.0
    return {
        "preset": preset,
        "config": config,
        "submitted": s.submitted,
        "finished": s.finished,
        "dropped_total": s.dropped_total,
        "completion_rate": s.completion_rate,
        "slo_attainment": slo,
        "energy_j": s.energy_j,
        "ttft_s": s.mean_ttft_s,
        "tpot_s": s.mean_tpot_s,
        "edp": s.edp,
        "node_frequencies": s.node_frequencies,
        "fault_counters": s.fault_counters,
        "engine_steps": steps,
    }


def unit_args(n_requests: int, presets: Optional[List[str]] = None,
              seed: int = 23) -> List[tuple]:
    """One unit per (preset, configuration) cell."""
    presets = PRESETS if presets is None else presets
    return [(p, c, n_requests, seed) for p in presets for c in CONFIGS]


def _cell(args: tuple) -> Dict:
    return _serve(*args)


def _assemble(rows: List[Dict], quiet: bool = False) -> Dict:
    grid: Dict[str, Dict] = {}
    for r in rows:
        grid[f"{r['preset']}|{r['config']}"] = r

    summary: Dict[str, object] = {}
    churn_res = grid.get("node-churn|resilient")
    churn_naive = grid.get("node-churn|naive")
    if churn_res and churn_naive:
        summary["churn"] = {
            "resilient_completion_rate": churn_res["completion_rate"],
            "resilient_dropped": churn_res["dropped_total"],
            "naive_dropped": churn_naive["dropped_total"],
            "naive_lost_requests": (churn_naive["submitted"]
                                    - churn_naive["finished"]),
        }
    lossy_res = grid.get("lossy-telemetry|resilient")
    lossy_naive = grid.get("lossy-telemetry|naive")
    if lossy_res and lossy_naive and lossy_naive["edp"]:
        summary["lossy_telemetry_resilient_vs_naive_edp_pct"] = (
            100.0 * (lossy_res["edp"] / lossy_naive["edp"] - 1.0))
    anchor = grid.get("none|resilient")
    if anchor:
        summary["fault_cost_vs_healthy_pct"] = {
            p: {k: 100.0 * (grid[f"{p}|resilient"][k] / anchor[k] - 1.0)
                for k in ("energy_j", "edp", "ttft_s") if anchor[k]}
            for p in sorted({r["preset"] for r in rows})
            if p != "none" and f"{p}|resilient" in grid}
    out = {"grid": grid, "summary": summary}
    save_json("tab_faults.json", out)
    if not quiet:
        print(f"{'cell':>28s} {'compl':>6s} {'drop':>5s} {'slo':>6s} "
              f"{'energy':>9s} {'edp':>9s} {'ttft':>8s}")
        for key, r in grid.items():
            print(f"{key:>28s} {r['completion_rate']:6.3f} "
                  f"{r['dropped_total']:5d} {r['slo_attainment']:6.3f} "
                  f"{r['energy_j'] / 1e3:8.1f}k {r['edp']:9.1f} "
                  f"{r['ttft_s']:7.3f}s")
        churn = summary.get("churn")
        if churn:
            print(f"churn: resilient completes "
                  f"{churn['resilient_completion_rate']:.3f} of "
                  f"non-dropped; naive no-retry loses "
                  f"{churn['naive_lost_requests']} requests")
    return out


def run(n_requests: int = 300, presets: Optional[List[str]] = None,
        seed: int = 23, quiet: bool = False) -> Dict:
    rows = [_cell(a) for a in unit_args(n_requests, presets, seed)]
    return _assemble(rows, quiet=quiet)


def main() -> None:
    ap = argparse.ArgumentParser()
    ap.add_argument("--quick", action="store_true",
                    help="smaller trace + 3 presets (CI bench-smoke cell)")
    ap.add_argument("--requests", type=int, default=0)
    args = ap.parse_args()
    n = args.requests or (120 if args.quick else 300)
    run(n_requests=n, presets=QUICK_PRESETS if args.quick else None)


if __name__ == "__main__":
    main()
