"""Paper Tables 2/3: learning-phase vs stable-phase (post-convergence)
metrics, AGFT vs the default-frequency baseline on the same trace — plus a
per-policy comparison (registry-constructed: agft / static / ondemand /
...) so the paper's headline numbers sit next to the competing controllers
they are implicitly measured against.

The baseline engine carries an observe-only TelemetryRecorder policy, so
its per-window energy series is measured through the same monitor boundary
as every other policy (no more average-power estimates)."""
from __future__ import annotations

from typing import Sequence

import numpy as np

from benchmarks.common import (measured_oracle_frequency, run_workload,
                               save_json)

DEFAULT_POLICIES = ("agft", "static", "ondemand", "oracle")


def _phase(reqs, lo, hi):
    rs = [r for r in reqs if r.finish_time and lo <= r.finish_time < hi]
    if not rs:
        return None
    return {
        "ttft": float(np.mean([r.ttft for r in rs])),
        "tpot": float(np.mean([r.tpot for r in rs if r.tpot is not None])),
        "e2e": float(np.mean([r.e2e for r in rs])),
        "n": len(rs),
    }


def _window_energy(history, lo, hi):
    return sum(h["energy_j"] for h in history
               if h["energy_j"] and lo <= h["t"] < hi)


def _serve(policy_name, n_requests, rate, seed):
    """One policy on the shared trace via the common runner; returns
    (engine, policy, totals-dict keyed like the phase tables). The oracle
    row is pinned at the TRACE-MEASURED sweep optimum (two-stage offline
    procedure), not the analytic cost-model sweep."""
    kw = ({"frequency_mhz": measured_oracle_frequency("normal", rate=rate,
                                                      seed=seed)}
          if policy_name == "oracle" else None)
    row = run_workload("normal", n_requests=n_requests, rate=rate,
                       policy=policy_name, policy_kwargs=kw, seed=seed)
    totals = {"energy_j": row["energy_j"], "ttft": row["ttft_s"],
              "tpot": row["tpot_s"], "e2e": row["e2e_s"],
              "edp": row["edp"], "finished": row["finished"]}
    return row["engine"], row["policy_obj"], totals


def run(n_requests: int = 2500, rate: float = 3.0, seed: int = 2,
        policies: Sequence[str] = DEFAULT_POLICIES, quiet: bool = False):
    # baseline: fixed f_max, observed through the same telemetry boundary
    beng, brec, base_tot = _serve("observer", n_requests, rate, seed)

    runs = {name: _serve(name, n_requests, rate, seed) for name in policies}
    eng, tuner, _ = runs.get("agft") or _serve("agft", n_requests, rate,
                                               seed)

    post = [h for h in tuner.history if h["converged"]]
    t_conv = post[0]["t"] if post else eng.clock
    end = min(eng.clock, beng.clock)

    def table(lo, hi):
        a = _phase(eng.finished, lo, hi)
        b = _phase(beng.finished, lo, hi)
        # per-window energy over the span — measured on BOTH sides now
        ea = _window_energy(tuner.history, lo, hi)
        eb = _window_energy(brec.history, lo, hi)
        if a is None or b is None or eb <= 0:
            return None
        return {
            "agft": {"energy_j": ea, "edp": ea * a["tpot"], **a},
            "baseline": {"energy_j": eb, "edp": eb * b["tpot"], **b},
            "diff_pct": {
                "energy": 100 * (ea / eb - 1),
                "edp": 100 * (ea * a["tpot"] / (eb * b["tpot"]) - 1),
                "ttft": 100 * (a["ttft"] / b["ttft"] - 1),
                "tpot": 100 * (a["tpot"] / b["tpot"] - 1),
                "e2e": 100 * (a["e2e"] / b["e2e"] - 1),
            },
        }

    comparison = {}
    for name, (_, _, tot) in runs.items():
        comparison[name] = {
            **tot,
            "diff_pct": {k: 100 * (tot[k] / base_tot[k] - 1)
                         for k in ("energy_j", "edp", "ttft", "tpot", "e2e")},
        }

    out = {
        "convergence_time_s": t_conv,
        "convergence_round": tuner.converged_round,
        "learning_phase": table(0.0, t_conv),
        "stable_phase": table(t_conv, end),
        "baseline_totals": base_tot,
        "policy_comparison": comparison,
        "paper": {
            "learning": {"energy": -43.2, "edp": -22.4, "ttft": 57.4,
                         "tpot": 40.9},
            "stable": {"energy": -44.3, "edp": -40.3, "ttft": 9.3,
                       "tpot": 7.1},
        },
    }
    save_json("tab2_3_phases.json", out)
    if not quiet:
        for name in ("learning_phase", "stable_phase"):
            d = out[name]["diff_pct"] if out[name] else {}
            print(f"{name:15s}: " + " ".join(
                f"{k} {v:+.1f}%" for k, v in d.items()))
        for name, row in comparison.items():
            d = row["diff_pct"]
            print(f"policy {name:10s}: " + " ".join(
                f"{k} {v:+.1f}%" for k, v in d.items()))
    return out


if __name__ == "__main__":
    run()
