"""Paper Tables 2/3: learning-phase vs stable-phase (post-convergence)
metrics, AGFT vs the default-frequency baseline on the same trace."""
from __future__ import annotations

import numpy as np

from benchmarks.common import make_engine, save_json
from repro.core import AGFTTuner
from repro.energy import A6000
from repro.workloads import PROTOTYPES, generate_requests


def _phase(reqs, lo, hi):
    rs = [r for r in reqs if r.finish_time and lo <= r.finish_time < hi]
    if not rs:
        return None
    return {
        "ttft": float(np.mean([r.ttft for r in rs])),
        "tpot": float(np.mean([r.tpot for r in rs if r.tpot is not None])),
        "e2e": float(np.mean([r.e2e for r in rs])),
        "n": len(rs),
    }


def _window_energy(history, lo, hi):
    return sum(h["energy_j"] for h in history
               if h["energy_j"] and lo <= h["t"] < hi)


def run(n_requests: int = 2500, rate: float = 3.0, seed: int = 2,
        quiet: bool = False):
    beng = make_engine()
    beng.submit(generate_requests(PROTOTYPES["normal"], n_requests,
                                  base_rate=rate, seed=seed))
    beng.drain()

    eng = make_engine()
    eng.submit(generate_requests(PROTOTYPES["normal"], n_requests,
                                 base_rate=rate, seed=seed))
    tuner = AGFTTuner(A6000)
    eng.drain(tuner=tuner)

    post = [h for h in tuner.history if h["converged"]]
    t_conv = post[0]["t"] if post else eng.clock
    end = min(eng.clock, beng.clock)

    def table(lo, hi):
        a = _phase(eng.finished, lo, hi)
        b = _phase(beng.finished, lo, hi)
        # per-window energy over the span, normalized per 100 s
        ea = _window_energy(tuner.history, lo, hi)
        span = max(hi - lo, 1e-9)
        # baseline energy estimated from its average power over the span
        pb = beng.metrics.c.energy_joules_total / max(beng.clock, 1e-9)
        eb = pb * span
        if a is None or b is None:
            return None
        return {
            "agft": {"energy_j": ea, "edp": ea * a["tpot"], **a},
            "baseline": {"energy_j": eb, "edp": eb * b["tpot"], **b},
            "diff_pct": {
                "energy": 100 * (ea / eb - 1),
                "edp": 100 * (ea * a["tpot"] / (eb * b["tpot"]) - 1),
                "ttft": 100 * (a["ttft"] / b["ttft"] - 1),
                "tpot": 100 * (a["tpot"] / b["tpot"] - 1),
                "e2e": 100 * (a["e2e"] / b["e2e"] - 1),
            },
        }

    out = {
        "convergence_time_s": t_conv,
        "convergence_round": tuner.converged_round,
        "learning_phase": table(0.0, t_conv),
        "stable_phase": table(t_conv, end),
        "paper": {
            "learning": {"energy": -43.2, "edp": -22.4, "ttft": 57.4,
                         "tpot": 40.9},
            "stable": {"energy": -44.3, "edp": -40.3, "ttft": 9.3,
                       "tpot": 7.1},
        },
    }
    save_json("tab2_3_phases.json", out)
    if not quiet:
        for name in ("learning_phase", "stable_phase"):
            d = out[name]["diff_pct"] if out[name] else {}
            print(f"{name:15s}: " + " ".join(
                f"{k} {v:+.1f}%" for k, v in d.items()))
    return out


if __name__ == "__main__":
    run()
