"""Paper Tables 2/3: learning-phase vs stable-phase (post-convergence)
metrics, AGFT vs the default-frequency baseline on the same trace — plus a
per-policy comparison (registry-constructed: agft / static / ondemand /
...) so the paper's headline numbers sit next to the competing controllers
they are implicitly measured against.

The baseline engine carries an observe-only TelemetryRecorder policy, so
its per-window energy series is measured through the same monitor boundary
as every other policy (no more average-power estimates).

Each policy run is an independent fully-seeded simulation: ``_serve_unit``
is the process-pool cell (returns plain data — request timing tuples and
the policy's window history — so payloads pickle cheaply), and
``_assemble`` folds the cells into the phase tables deterministically."""
from __future__ import annotations

from typing import Dict, List, Sequence

from benchmarks.common import (_mean, measured_oracle_frequency,
                               run_workload, save_json)
from benchmarks.parallel import pmap

DEFAULT_POLICIES = ("agft", "static", "ondemand", "oracle")


def _phase(reqs: List[tuple], lo: float, hi: float):
    """reqs: (finish_time, ttft, tpot, e2e) tuples from ``_serve_unit``."""
    rs = [r for r in reqs if r[0] and lo <= r[0] < hi]
    if not rs:
        return None
    return {
        "ttft": _mean([r[1] for r in rs]),
        "tpot": _mean([r[2] for r in rs if r[2] is not None]),
        "e2e": _mean([r[3] for r in rs]),
        "n": len(rs),
    }


def _window_energy(history, lo, hi):
    return sum(h["energy_j"] for h in history
               if h["energy_j"] and lo <= h["t"] < hi)


def _serve_unit(args) -> Dict:
    """One policy on the shared trace; plain-data payload for the pool.
    The oracle row is pinned at the TRACE-MEASURED sweep optimum (two-stage
    offline procedure), not the analytic cost-model sweep."""
    policy_name, n_requests, rate, seed = args
    kw = ({"frequency_mhz": measured_oracle_frequency("normal", rate=rate,
                                                      seed=seed)}
          if policy_name == "oracle" else None)
    row = run_workload("normal", n_requests=n_requests, rate=rate,
                       policy=policy_name, policy_kwargs=kw, seed=seed)
    eng, pol = row["engine"], row["policy_obj"]
    return {
        "policy": policy_name,
        "totals": {"energy_j": row["energy_j"], "ttft": row["ttft_s"],
                   "tpot": row["tpot_s"], "e2e": row["e2e_s"],
                   "edp": row["edp"], "finished": row["finished"]},
        "clock": eng.clock,
        "history": list(getattr(pol, "history", [])),
        "converged_round": getattr(pol, "converged_round", None),
        "finished_reqs": [(r.finish_time, r.ttft, r.tpot, r.e2e)
                          for r in eng.finished],
    }


def unit_args(n_requests: int, rate: float = 3.0, seed: int = 2,
              policies: Sequence[str] = DEFAULT_POLICIES) -> List[tuple]:
    """Cells for the harness: the observer baseline first, then one cell
    per compared policy (order fixed — the merge relies on it)."""
    return [("observer", n_requests, rate, seed)] + \
        [(p, n_requests, rate, seed) for p in policies]


def _assemble(payloads: List[Dict], quiet: bool = False,
              policies: Sequence[str] = DEFAULT_POLICIES) -> Dict:
    base = payloads[0]
    runs = {p["policy"]: p for p in payloads[1:]}
    agft = runs["agft"]

    post = [h for h in agft["history"] if h["converged"]]
    t_conv = post[0]["t"] if post else agft["clock"]
    end = min(agft["clock"], base["clock"])

    def table(lo, hi):
        a = _phase(agft["finished_reqs"], lo, hi)
        b = _phase(base["finished_reqs"], lo, hi)
        # per-window energy over the span — measured on BOTH sides now
        ea = _window_energy(agft["history"], lo, hi)
        eb = _window_energy(base["history"], lo, hi)
        if a is None or b is None or eb <= 0:
            return None
        return {
            "agft": {"energy_j": ea, "edp": ea * a["tpot"], **a},
            "baseline": {"energy_j": eb, "edp": eb * b["tpot"], **b},
            "diff_pct": {
                "energy": 100 * (ea / eb - 1),
                "edp": 100 * (ea * a["tpot"] / (eb * b["tpot"]) - 1),
                "ttft": 100 * (a["ttft"] / b["ttft"] - 1),
                "tpot": 100 * (a["tpot"] / b["tpot"] - 1),
                "e2e": 100 * (a["e2e"] / b["e2e"] - 1),
            },
        }

    base_tot = base["totals"]
    comparison = {}
    for name in policies:
        tot = runs[name]["totals"]
        comparison[name] = {
            **tot,
            "diff_pct": {k: 100 * (tot[k] / base_tot[k] - 1)
                         for k in ("energy_j", "edp", "ttft", "tpot", "e2e")},
        }

    out = {
        "convergence_time_s": t_conv,
        "convergence_round": agft["converged_round"],
        "learning_phase": table(0.0, t_conv),
        "stable_phase": table(t_conv, end),
        "baseline_totals": base_tot,
        "policy_comparison": comparison,
        "paper": {
            "learning": {"energy": -43.2, "edp": -22.4, "ttft": 57.4,
                         "tpot": 40.9},
            "stable": {"energy": -44.3, "edp": -40.3, "ttft": 9.3,
                       "tpot": 7.1},
        },
    }
    save_json("tab2_3_phases.json", out)
    if not quiet:
        for name in ("learning_phase", "stable_phase"):
            d = out[name]["diff_pct"] if out[name] else {}
            print(f"{name:15s}: " + " ".join(
                f"{k} {v:+.1f}%" for k, v in d.items()))
        for name, row in comparison.items():
            d = row["diff_pct"]
            print(f"policy {name:10s}: " + " ".join(
                f"{k} {v:+.1f}%" for k, v in d.items()))
    return out


def run(n_requests: int = 2500, rate: float = 3.0, seed: int = 2,
        policies: Sequence[str] = DEFAULT_POLICIES, quiet: bool = False):
    args = unit_args(n_requests, rate, seed, policies)
    if "agft" not in policies:
        args.append(("agft", n_requests, rate, seed))
    payloads = pmap(_serve_unit, args, seed=seed)
    return _assemble(payloads, quiet=quiet, policies=policies)


if __name__ == "__main__":
    run()
