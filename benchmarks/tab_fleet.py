"""Fleet-scope comparison (beyond paper; ROADMAP cross-node baseline):
the same trace and router served by

  ``fmax``       fixed default clocks (fleet baseline)
  ``global``     ONE cluster-global controller — a single frequency for
                 all nodes, learned from fleet-aggregated telemetry
                 (``get_policy("global")``, inner AGFT)
  ``per-node``   the paper's closed loop per node (heterogeneous optima)

The gap between ``global`` and ``per-node`` is exactly what per-node
closed loops buy over cross-node coordination — the quantity the ROADMAP
asks for. A length-segregating router widens it (nodes see different
phase mixes and want different frequencies); the default least-loaded
router narrows it (homogeneous traffic -> one frequency is near-optimal).

The ``policy_mix`` grid (ROADMAP heterogeneity item) crosses the two
routers with per-node policy assignments: all-AGFT, all-SLO, and the
tiered mix — AGFT on the batch tier (the first half of the fleet, which
``route_by_length`` feeds long-context traffic) where EDP is the right
objective, the SLO latency controller on the latency tier (chat traffic)
where responsiveness is. Tiering only means something to the segregating
router; under least-loaded routing every node sees the same mix and the
assignment degenerates to a sanity check.
"""
from __future__ import annotations

from typing import Dict, List, Optional

import numpy as np

from benchmarks.common import PAPER_MODEL, save_json
from repro.configs import get_config
from repro.serving.cluster import (ServingCluster, route_by_length,
                                   route_least_loaded)
from repro.workloads import PROTOTYPES, generate_requests


def _trace(n: int, seed: int):
    """Mixed long-context + chat traffic (the split where per-node loops
    can specialize)."""
    return (generate_requests(PROTOTYPES["long_context"], n // 2,
                              base_rate=1.5, seed=seed)
            + generate_requests(PROTOTYPES["normal"], n - n // 2,
                                base_rate=1.5, seed=seed + 1))


def _serve(n_nodes, n_requests, seed, *, policies=None, fleet=None,
           router=route_by_length) -> Dict:
    cfg = get_config(PAPER_MODEL)
    cl = ServingCluster(cfg, n_nodes=n_nodes, with_tuners=False,
                        policies=policies, fleet_policy=fleet,
                        router=router)
    cl.submit(_trace(n_requests, seed))
    steps = cl.drain()
    s = cl.summary()
    return {
        "finished": s.finished,
        "energy_j": s.energy_j,
        "ttft_s": s.mean_ttft_s,
        "tpot_s": s.mean_tpot_s,
        "edp": s.edp,
        "node_frequencies": s.node_frequencies,
        "freq_spread_mhz": (max(s.node_frequencies)
                            - min(s.node_frequencies)),
        "engine_steps": steps,
    }


ROUTERS = {"least_loaded": route_least_loaded,
           "by_length": route_by_length}


def _mixes(n_nodes: int) -> Dict[str, List[Optional[str]]]:
    half = max(n_nodes // 2, 1)
    return {
        "agft-all": ["agft"] * n_nodes,
        "slo-all": ["slo"] * n_nodes,
        # batch tier (route_by_length's long-context half) optimizes EDP,
        # latency tier holds its TPOT budget
        "agft-batch/slo-latency": (["agft"] * half
                                   + ["slo"] * (n_nodes - half)),
    }


def run_policy_mix(n_requests: int = 600, n_nodes: int = 4, seed: int = 11,
                   quiet: bool = False,
                   precomputed: Optional[Dict[str, Dict]] = None) -> Dict:
    """Router x policy-mix grid (the ROADMAP's open heterogeneity item).

    ``precomputed`` maps grid keys to already-served rows (the simulation
    is deterministic, so ``run()`` hands in its per-node-AGFT cell instead
    of re-simulating it)."""
    grid: Dict[str, Dict] = {}
    for rname, router in ROUTERS.items():
        for mname, mix in _mixes(n_nodes).items():
            key = f"{rname}|{mname}"
            if precomputed and key in precomputed:
                row = dict(precomputed[key])
            else:
                row = _serve(n_nodes, n_requests, seed, policies=mix,
                             router=router)
            row["router"] = rname
            row["mix"] = mname
            grid[key] = row
    # what tiering buys where it should: segregated traffic, mixed policies
    tiered = grid["by_length|agft-batch/slo-latency"]
    agft_all = grid["by_length|agft-all"]
    summary = {
        k: 100 * (tiered[k] / agft_all[k] - 1)
        for k in ("energy_j", "edp", "ttft_s", "tpot_s")}
    out = {"grid": grid, "tiered_vs_agft_all_by_length_pct": summary}
    if not quiet:
        for key, r in grid.items():
            fr = np.array(r["node_frequencies"])
            print(f"{key:32s} energy {r['energy_j']/1e3:8.1f} kJ  "
                  f"edp {r['edp']:8.1f}  tpot {r['tpot_s']*1e3:6.2f} ms  "
                  f"ttft {r['ttft_s']:5.2f} s  "
                  f"f=[{fr.min():.0f}..{fr.max():.0f}] MHz")
        print(f"tiered vs agft-all (by_length): "
              f"edp {summary['edp']:+.1f}%  ttft {summary['ttft_s']:+.1f}%")
    return out


def run(n_requests: int = 600, n_nodes: int = 4, seed: int = 11,
        quiet: bool = False):
    base = _serve(n_nodes, n_requests, seed)
    glob = _serve(n_nodes, n_requests, seed, fleet="global")
    pern = _serve(n_nodes, n_requests, seed,
                  policies=["agft"] * n_nodes)

    def vs_base(row):
        return {k: 100 * (row[k] / base[k] - 1)
                for k in ("energy_j", "edp", "ttft_s", "tpot_s")}

    out = {
        "fmax": base, "global": glob, "per_node": pern,
        "global_vs_base_pct": vs_base(glob),
        "per_node_vs_base_pct": vs_base(pern),
        # what the per-node closed loops buy over one global setting
        "per_node_vs_global_pct": {
            k: 100 * (pern[k] / glob[k] - 1)
            for k in ("energy_j", "edp", "ttft_s", "tpot_s")},
    }
    out["policy_mix"] = run_policy_mix(
        n_requests, n_nodes, seed, quiet=quiet,
        precomputed={"by_length|agft-all": pern})
    save_json("tab_fleet.json", out)
    if not quiet:
        for name in ("fmax", "global", "per_node"):
            r = out[name]
            fr = np.array(r["node_frequencies"])
            print(f"{name:9s} energy {r['energy_j']/1e3:8.1f} kJ  "
                  f"edp {r['edp']:8.1f}  tpot {r['tpot_s']*1e3:6.2f} ms  "
                  f"f=[{fr.min():.0f}..{fr.max():.0f}] MHz")
        d = out["per_node_vs_global_pct"]
        print(f"per-node vs global: energy {d['energy_j']:+.1f}%  "
              f"edp {d['edp']:+.1f}%")
    return out


if __name__ == "__main__":
    run()
