"""Mixed-hardware fleet routing grid (router x fleet composition).

Serves the same seeded trace through a :class:`ServingCluster` built
from each fleet composition under three placement policies:

  ``energy``        marginal joules-per-token placement subject to the
                    request's TTFT tier (``EnergyAwareRouter``)
  ``least-loaded``  throughput-normalized queue depth (the default)
  ``round-robin``   hardware- and load-blind cyclic placement

Clocks are fixed at each node's ``f_max`` (``with_tuners=False``) so the
comparison isolates *placement*: every joule of difference comes from
where requests land, not from what a tuner learned. The headline claim
(gated by ``--check``, mirrored in CI) is that the energy-aware router
beats BOTH baselines on fleet EDP at equal-or-better SLO attainment
(fraction of finished requests with TTFT <= 2 s) on at least two mixed
compositions. The homogeneous A6000 control row isolates the router's
*consolidation* effect from its *hardware-selection* effect: with no
hardware signal the jpt ties all break to node 0, so traffic
concentrates on one node while it meets the tier — spread-out baselines
pay every node's static draw instead. The mixed-fleet wins are larger
than the control's win: that surplus is the hardware-aware part.
"""
from __future__ import annotations

import argparse
from typing import Dict, List

from benchmarks.common import BASE_RATE, save_json
from repro.configs import get_config
from repro.energy import parse_fleet_hardware
from repro.serving.cluster import ServingCluster
from repro.workloads import PROTOTYPES, generate_requests

#: (composition name, fleet spec string); the first two are the mixed
#: fleets the acceptance claim is measured on, the last is the
#: homogeneous control
COMPOSITIONS = [
    ("h100x2+l4x2", "h100:2,l4:2"),
    ("4tier", "a6000,h100,l4,edge-orin"),
    ("a6000+h100x2+l4", "a6000,h100:2,l4"),
    ("a6000x4", "a6000:4"),
]
MIXED = [c for c, spec in COMPOSITIONS
         if len(set(parse_fleet_hardware(spec))) > 1]
ROUTER_NAMES = ["energy", "least-loaded", "round-robin"]
TTFT_SLO_S = 2.0
FULL_REQUESTS = 400
QUICK_REQUESTS = 120


def _cell(args: tuple) -> Dict:
    comp, spec, router, n_requests, rate, seed = args
    hw_list = parse_fleet_hardware(spec)
    cl = ServingCluster(get_config("llama3-3b"), n_nodes=len(hw_list),
                        hardware=hw_list, router=router,
                        with_tuners=False, step_mode="batched")
    cl.submit(generate_requests(PROTOTYPES["normal"], n_requests,
                                base_rate=rate, seed=seed))
    cl.drain()
    s = cl.summary()
    fin = [r for node in cl.nodes for r in node.engine.finished]
    attained = sum(1 for r in fin if r.ttft <= TTFT_SLO_S)
    return {
        "composition": comp,
        "fleet": spec,
        "router": router,
        "finished": s.finished,
        "energy_j": s.energy_j,
        "ttft_s": s.mean_ttft_s,
        "tpot_s": s.mean_tpot_s,
        "edp": s.edp,
        "slo_attainment": attained / max(len(fin), 1),
        "node_hardware": s.node_hardware,
        "node_energy_j": s.node_energy_j,
        "energy_by_tier": s.energy_by_tier,
        "finished_by_tier": s.finished_by_tier,
    }


def unit_args(n_requests: int, rate: float = BASE_RATE,
              seed: int = 13) -> List[tuple]:
    """One unit per (composition, router), all over the same trace."""
    return [(comp, spec, router, n_requests, rate, seed)
            for comp, spec in COMPOSITIONS for router in ROUTER_NAMES]


def _assemble(rows: List[Dict], quiet: bool = False) -> Dict:
    grid = {f"{r['composition']}|{r['router']}": r for r in rows}

    summary: Dict[str, object] = {"wins": []}
    for comp, _ in COMPOSITIONS:
        en = grid.get(f"{comp}|energy")
        if en is None:
            continue
        deltas = {}
        win = comp in MIXED
        for base in ("least-loaded", "round-robin"):
            b = grid.get(f"{comp}|{base}")
            if b is None:
                win = False
                continue
            deltas[f"edp_vs_{base}_pct"] = 100.0 * (en["edp"] / b["edp"]
                                                    - 1.0)
            deltas[f"attainment_vs_{base}"] = (en["slo_attainment"]
                                               - b["slo_attainment"])
            if en["edp"] >= b["edp"] \
                    or en["slo_attainment"] < b["slo_attainment"]:
                win = False
        summary[comp] = deltas
        if win:
            summary["wins"].append(comp)
    summary["mixed_compositions"] = MIXED

    out = {"grid": grid, "summary": summary}
    save_json("tab_hetero.json", out)
    if not quiet:
        print(f"{'composition':>16s} {'router':>13s} {'finished':>8s} "
              f"{'energy':>9s} {'tpot':>8s} {'edp':>9s} {'slo':>6s}")
        for comp, _ in COMPOSITIONS:
            for router in ROUTER_NAMES:
                r = grid.get(f"{comp}|{router}")
                if r is None:
                    continue
                print(f"{comp:>16s} {router:>13s} {r['finished']:8d} "
                      f"{r['energy_j'] / 1e3:8.1f}k "
                      f"{r['tpot_s'] * 1e3:6.2f}ms {r['edp']:9.1f} "
                      f"{r['slo_attainment']:6.1%}")
        print(f"energy-router wins (edp down, attainment >=): "
              f"{summary['wins']}")
    return out


def run(n_requests: int = FULL_REQUESTS, rate: float = BASE_RATE,
        seed: int = 13, quiet: bool = False) -> Dict:
    rows = [_cell(a) for a in unit_args(n_requests, rate, seed)]
    return _assemble(rows, quiet=quiet)


def main() -> None:
    ap = argparse.ArgumentParser()
    ap.add_argument("--quick", action="store_true",
                    help=f"{QUICK_REQUESTS} requests instead of "
                         f"{FULL_REQUESTS} (CI smoke cell)")
    ap.add_argument("--requests", type=int, default=0)
    ap.add_argument("--check", action="store_true",
                    help="fail unless the energy-aware router beats both "
                         "baselines on fleet EDP at equal-or-better SLO "
                         "attainment on >= 2 mixed compositions (the "
                         "PR's acceptance claim)")
    args = ap.parse_args()
    n = args.requests or (QUICK_REQUESTS if args.quick else FULL_REQUESTS)
    out = run(n_requests=n)
    if args.check:
        wins = out["summary"]["wins"]
        if len(wins) < 2:
            raise SystemExit(
                f"CHECK FAILED: energy router wins on {wins} — need >= 2 "
                f"mixed compositions out of {MIXED}")
        print(f"check passed: energy router wins on {wins}")


if __name__ == "__main__":
    main()
