"""Paper Fig. 5: performance (TTFT/TPOT) and average power across the five
workload prototypes at the default (unlocked == f_max) frequency."""
from __future__ import annotations

from benchmarks.common import run_workload, save_json, strip_engine

WORKLOADS = ["normal", "long_context", "long_generation",
             "high_concurrency", "high_cache_hit"]


def run(n_requests: int = 300, quiet: bool = False):
    rows = []
    base = None
    for w in WORKLOADS:
        r = strip_engine(run_workload(w, n_requests=n_requests))
        if w == "normal":
            base = r
        rows.append(r)
    for r in rows:
        r["ttft_vs_normal_pct"] = 100 * (r["ttft_s"] / base["ttft_s"] - 1)
        r["tpot_vs_normal_pct"] = 100 * (r["tpot_s"] / base["tpot_s"] - 1)
        r["power_vs_normal_pct"] = (100 * (r["avg_power_w"]
                                           / base["avg_power_w"] - 1))
    save_json("fig5_workloads.json", rows)
    if not quiet:
        print(f"{'workload':18s} {'TTFT(s)':>9s} {'TPOT(s)':>9s} "
              f"{'power(W)':>9s} {'hit':>5s}")
        for r in rows:
            print(f"{r['workload']:18s} {r['ttft_s']:9.4f} "
                  f"{r['tpot_s']:9.5f} {r['avg_power_w']:9.1f} "
                  f"{r['prefix_hit_rate']:5.2f}")
    return rows


if __name__ == "__main__":
    run()
