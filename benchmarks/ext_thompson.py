"""Beyond-paper extension: linear Thompson sampling vs the paper's LinUCB
exploration, same engine/workloads/convergence machinery."""
from __future__ import annotations

import numpy as np

from benchmarks.common import make_engine, save_json
from repro.policies import get_policy
from repro.workloads import PROTOTYPES, generate_azure_trace, \
    generate_requests


def _run(strategy: str, workload: str, n=1200, rate=3.0, seed=6,
         azure_dur=0.0):
    eng = make_engine()
    if workload == "azure":
        eng.submit(generate_azure_trace(azure_dur or 1200.0,
                                        base_rate=rate, seed=seed))
    else:
        eng.submit(generate_requests(PROTOTYPES[workload], n,
                                     base_rate=rate, seed=seed))
    tuner = get_policy("agft", strategy=strategy)
    eng.drain(policy=tuner)
    fin = eng.finished
    tpot = float(np.mean([r.tpot for r in fin if r.tpot is not None]))
    rewards = [h["reward"] for h in tuner.history if h["reward"] is not None]
    return {
        "strategy": strategy,
        "energy_j": eng.metrics.c.energy_joules_total,
        "tpot_s": tpot,
        "edp": eng.metrics.c.energy_joules_total * tpot,
        "first_converged_round": tuner.first_converged_round,
        "mean_reward_last50": float(np.mean(rewards[-50:])) if rewards
        else None,
        "exploit_fraction": (sum(1 for h in tuner.history if h["converged"])
                             / max(len(tuner.history), 1)),
    }


def run(quiet: bool = False):
    out = {}
    for workload in ("normal", "azure"):
        rows = [_run(s, workload) for s in ("linucb", "thompson")]
        out[workload] = rows
        if not quiet:
            for r in rows:
                print(f"{workload:8s} {r['strategy']:9s} "
                      f"EDP={r['edp']:9.1f} conv@{r['first_converged_round']} "
                      f"exploit={r['exploit_fraction']:.2f}")
    save_json("ext_thompson.json", out)
    return out


if __name__ == "__main__":
    run()
