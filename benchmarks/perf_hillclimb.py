import os
os.environ["XLA_FLAGS"] = "--xla_force_host_platform_device_count=512"

"""§Perf hillclimbing harness (deliverable g + the perf-iteration log).

For a chosen (arch x shape) pair this measures the depth-extrapolated
roofline terms of the BASELINE lowering, then re-lowers each candidate
variant (config/sharding/donation change) and reports the per-term delta —
the hypothesis -> change -> measure -> validate loop, driven from the
compiled HLO because this container has no TPU clock.

  PYTHONPATH=src python -m benchmarks.perf_hillclimb \
      --pairs llama4-scout-17b-a16e:train_4k phi3-medium-14b:decode_32k
"""
import argparse      # noqa: E402
import json          # noqa: E402
import time          # noqa: E402

from repro.launch.dryrun import cost_extrapolated   # noqa: E402
from repro.launch.mesh import make_production_mesh  # noqa: E402

PEAK_FLOPS = 197e12
HBM_BW = 819e9
ICI_BW = 50e9
CHIPS = 256


def terms(costs: dict) -> dict:
    return {
        "compute_s": costs["flops"] / PEAK_FLOPS,
        "memory_s": costs["bytes_accessed"] / HBM_BW,
        "collective_s": costs["collective_bytes"]["total"] / (CHIPS * ICI_BW),
        "temp_gb": costs.get("u2_temp_bytes", 0) / 1e9,
    }


# ---------------------------------------------------------------------------
# candidate variants (name, hypothesis, cfg_transform, donate)
# ---------------------------------------------------------------------------

VARIANTS = {
    "scatter_kv": (
        "decode cache write via dynamic_update_slice instead of one-hot "
        "blend: removes one full cache read+write per step -> memory term "
        "down by ~cache_bytes/HBM_bw",
        lambda c: c.replace(kv_update="scatter"), False),
    "scatter_kv_donated": (
        "scatter + donated cache buffers: XLA aliases the cache in-place, "
        "eliminating the copy the undonated scatter must make",
        lambda c: c.replace(kv_update="scatter"), True),
    "no_remat": (
        "training without activation checkpointing: compute term down "
        "~25-30% (no recompute) at the cost of activation memory",
        lambda c: c.replace(remat=False), False),
    "donate_train_state": (
        "donate params+optimizer buffers in train step: removes the "
        "copy-on-write of the updated state -> memory term down",
        None, True),
    "top1_router": (
        "MoE top-1 instead of top-6 (deepseek): active-expert FLOPs and "
        "expert all-reduce traffic scale ~1/6 (quality trade-off, measures "
        "the routing-cost share)",
        lambda c: c.replace(top_k=1), False),
    "chunked_attention": (
        "flash-style chunked reference attention (lax.scan over KV blocks, "
        "streaming softmax): removes the O(S*T) score materialization -> "
        "memory term down by ~2*S*T*H*4B/HBM_bw; also what makes 32k "
        "prefill fit per-device HBM",
        lambda c: c.replace(ref_attention="chunked"), False),
    "capacity_moe": (
        "capacity-based scatter/gather MoE dispatch instead of all-experts "
        "dense einsum: FFN FLOPs scale with routed tokens -> compute term "
        "down ~E/(top_k*cap_factor)",
        lambda c: c.replace(moe_dispatch="capacity"), False),
    "capacity_moe_ep": (
        "capacity dispatch + explicit expert-parallel sharding constraint "
        "on the dispatch buffers (GSPMD cannot infer sharding through the "
        "data-dependent scatter; the constraint should restore the "
        "E/(top_k*cap) per-device FLOPs reduction)",
        lambda c: c.replace(moe_dispatch="capacity",
                            moe_ep_constraint=True), False),
    "capacity_moe_chunked_attn": (
        "both MoE capacity dispatch and chunked attention",
        lambda c: c.replace(moe_dispatch="capacity",
                            ref_attention="chunked"), False),
    "all_opts": (
        "chunked attention + capacity MoE + scatter KV + donation",
        lambda c: c.replace(moe_dispatch="capacity",
                            ref_attention="chunked",
                            kv_update="scatter"), True),
}


def run_pair(arch: str, shape: str, variant_names, multi_pod=False):
    mesh = make_production_mesh(multi_pod=multi_pod)
    out = {"arch": arch, "shape": shape, "iterations": []}
    with mesh:
        t0 = time.time()
        base = cost_extrapolated(arch, shape, mesh)
        bt = terms(base)
        out["baseline"] = {**bt, "dominant": max(bt, key=bt.get),
                           "compile_s": round(time.time() - t0, 1)}
        print(f"[perf] {arch} x {shape} baseline: " + " ".join(
            f"{k}={v:.3e}" for k, v in bt.items())
            + f" dominant={out['baseline']['dominant']}")
        for name in variant_names:
            hypo, transform, donate = VARIANTS[name]
            t0 = time.time()
            try:
                cost = cost_extrapolated(arch, shape, mesh,
                                         cfg_transform=transform,
                                         donate=donate)
                vt = terms(cost)
                deltas = {k: 100 * (vt[k] / bt[k] - 1) if bt[k] else 0.0
                          for k in vt}
                rec = {"variant": name, "hypothesis": hypo, **vt,
                       "delta_pct": deltas,
                       "compile_s": round(time.time() - t0, 1)}
                print(f"[perf]   {name}: " + " ".join(
                    f"{k.split('_')[0]}{d:+.1f}%"
                    for k, d in deltas.items()))
            except Exception as e:  # noqa: BLE001
                rec = {"variant": name, "hypothesis": hypo,
                       "error": str(e)[:300]}
                print(f"[perf]   {name}: FAILED {str(e)[:120]}")
            out["iterations"].append(rec)
    return out


def main():
    ap = argparse.ArgumentParser()
    ap.add_argument("--pairs", nargs="+", required=True,
                    help="arch:shape entries")
    ap.add_argument("--variants", nargs="+",
                    default=["scatter_kv", "scatter_kv_donated"])
    ap.add_argument("--out", default="results/perf_hillclimb.json")
    args = ap.parse_args()

    results = []
    for pair in args.pairs:
        arch, shape = pair.split(":")
        results.append(run_pair(arch, shape, args.variants))
    existing = []
    if os.path.exists(args.out):
        with open(args.out) as f:
            existing = json.load(f)
    with open(args.out, "w") as f:
        json.dump(existing + results, f, indent=1)


if __name__ == "__main__":
    main()
