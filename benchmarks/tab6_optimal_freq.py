"""Paper Table 6: offline theoretical-optimum frequencies vs the frequency
AGFT learns online, per workload prototype — plus the trace-measured
oracle row: the registry oracle pinned at the two-stage sweep optimum
(``measured_oracle_frequency``) and replayed on the workload, so the
"theoretical optimum" comparator is measured end-to-end rather than
derived from the analytic cost model."""
from __future__ import annotations

import numpy as np

from benchmarks.common import (load_json, make_engine,
                               measured_oracle_frequency, run_workload,
                               save_json, strip_engine)
from benchmarks.fig5_workloads import WORKLOADS
from benchmarks.parallel import pmap
from repro.policies import get_policy
from repro.workloads import PROTOTYPES, generate_requests

PAPER = {  # (offline MHz, online MHz, deviation %)
    "normal": (1230, 1230, 0.0),
    "long_context": (1395, 1410, 1.1),
    "long_generation": (1260, 1200, -4.8),
    "high_concurrency": (1365, 1320, -3.3),
    "high_cache_hit": (1200, 1290, 7.5),
}


def online_frequency(workload: str, *, n_requests: int = 1500,
                     rate: float = 3.0, seed: int = 4) -> float:
    """Run AGFT on the prototype long enough to converge; return the mean
    post-convergence (exploitation) frequency."""
    eng = make_engine()
    eng.submit(generate_requests(PROTOTYPES[workload], n_requests,
                                 base_rate=rate, seed=seed))
    tuner = get_policy("agft")
    eng.drain(policy=tuner)
    post = [h["freq"] for h in tuner.history if h["converged"]]
    if not post:   # fall back to the greedy choice distribution
        post = [h["freq"] for h in tuner.history[-50:]]
    return float(np.mean(post))


def _cell(args):
    """Per-workload column: online AGFT convergence + trace-measured oracle
    replay (independent across workloads — one pmap cell each)."""
    w, offline, n_requests = args
    online = online_frequency(w, n_requests=n_requests)
    dev = 100 * (online - offline) / offline
    # trace-measured oracle: two-stage sweep optimum, replayed through
    # the registry policy on the same prototype
    oracle_mhz = measured_oracle_frequency(w)
    orc = strip_engine(run_workload(w, n_requests=min(n_requests, 600),
                                    policy="oracle",
                                    policy_kwargs={
                                        "frequency_mhz": oracle_mhz},
                                    seed=4))
    return {"offline_mhz": offline, "online_mhz": round(online, 1),
            "deviation_pct": round(dev, 2),
            "oracle_measured_mhz": oracle_mhz,
            "oracle_energy_j": orc["energy_j"],
            "oracle_edp": orc["edp"],
            "paper": {"offline": PAPER[w][0], "online": PAPER[w][1],
                      "deviation_pct": PAPER[w][2]}}


def unit_args(n_requests: int, sweep: dict):
    """Cells from fig6's sweep output (``{workload: {"optimal_freq": ..}}``)
    — pass the reduced value, not the artifact path, so the harness can
    chain fig6 -> tab6 without a filesystem rendezvous."""
    return [(w, sweep[w]["optimal_freq"], n_requests) for w in WORKLOADS]


def _assemble(cells, quiet: bool = False):
    out = dict(zip(WORKLOADS, cells))
    for w in WORKLOADS:
        row = out[w]
        if not quiet:
            print(f"{w:18s} offline {row['offline_mhz']:6.0f}  "
                  f"online {row['online_mhz']:6.0f}  "
                  f"oracle(meas) {row['oracle_measured_mhz']:6.0f}  "
                  f"dev {row['deviation_pct']:+5.1f}% "
                  f"(paper {PAPER[w][2]:+.1f}%)")
    devs = [abs(v["deviation_pct"]) for v in out.values()
            if isinstance(v, dict)]
    out["max_abs_deviation_pct"] = max(devs)
    save_json("tab6_optimal_freq.json", out)
    return out


def run(n_requests: int = 1500, quiet: bool = False):
    try:
        sweep = load_json("fig6_freq_sweep.json")
    except FileNotFoundError:
        from benchmarks.fig6_freq_sweep import run as run_fig6
        sweep = run_fig6(quiet=True)
    return _assemble(pmap(_cell, unit_args(n_requests, sweep), seed=4),
                     quiet=quiet)


if __name__ == "__main__":
    run()
