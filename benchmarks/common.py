"""Shared benchmark helpers: engine runners, metric summaries, artifacts."""
from __future__ import annotations

import json
import os
import time
from typing import Dict, List, Optional, Sequence, Union

import numpy as np

from benchmarks.parallel import pmap
from repro.configs import get_config
from repro.energy import A6000, HardwareSpec, resolve_hardware
from repro.policies import PowerPolicy, get_policy
from repro.serving import EngineConfig, InferenceEngine
from repro.workloads import (PROTOTYPES, generate_azure_trace,
                             generate_requests)

RESULTS_DIR = os.path.join(os.path.dirname(__file__), "..", "results")
PAPER_MODEL = "llama3-3b"
BASE_RATE = 3.0


def results_path(name: str) -> str:
    os.makedirs(RESULTS_DIR, exist_ok=True)
    return os.path.join(RESULTS_DIR, name)


def save_json(name: str, obj) -> str:
    """Atomic write (tmp + rename) so parallel benchmark cells never leave
    a half-written artifact behind."""
    p = results_path(name)
    tmp = f"{p}.tmp.{os.getpid()}"
    with open(tmp, "w") as f:
        json.dump(obj, f, indent=1)
    os.replace(tmp, p)
    return p


def _mean(vals: Sequence[float]) -> float:
    """Mean that tolerates an empty list (a --quick run can finish zero
    requests) without numpy's RuntimeWarning — returns NaN instead."""
    vals = list(vals)
    return float(np.mean(vals)) if vals else float("nan")


def load_json(name: str):
    with open(results_path(name)) as f:
        return json.load(f)


def make_engine(frequency: Optional[float] = None,
                arch: str = PAPER_MODEL,
                hardware: Union[HardwareSpec, str] = A6000
                ) -> InferenceEngine:
    hw = resolve_hardware(hardware)
    eng = InferenceEngine(get_config(arch), EngineConfig(),
                          hardware=hw,
                          initial_frequency=frequency or hw.f_max)
    return eng


def resolve_policy(policy, policy_kwargs: Optional[Dict] = None,
                   hardware: Union[HardwareSpec, str] = A6000):
    """Registry name -> constructed policy; instances/None pass through."""
    if isinstance(policy, str):
        return get_policy(policy, hardware=resolve_hardware(hardware),
                          **(policy_kwargs or {}))
    return policy


def run_workload(workload: str, *, n_requests: int = 400,
                 rate: float = BASE_RATE, frequency: Optional[float] = None,
                 policy: Union[str, PowerPolicy, None] = None,
                 policy_kwargs: Optional[Dict] = None,
                 tuner=None, seed: int = 1,
                 azure_duration: float = 0.0,
                 hardware: Union[HardwareSpec, str] = A6000) -> Dict:
    """Run one workload trace; ``policy`` is a registry name (e.g.
    "agft"/"static"/"ondemand"), a PowerPolicy instance, or None for fixed
    clocks at ``frequency`` (default f_max). ``tuner=`` is the legacy
    alias for a ready instance. ``hardware`` picks the spec (instance or
    registry name); registry-name policies resolve against the same spec."""
    if policy is None:
        policy = tuner
    policy = resolve_policy(policy, policy_kwargs, hardware=hardware)
    eng = make_engine(frequency, hardware=hardware)
    if workload == "azure":
        eng.submit(generate_azure_trace(azure_duration or 1200.0,
                                        base_rate=rate, seed=seed))
    else:
        eng.submit(generate_requests(PROTOTYPES[workload], n_requests,
                                     base_rate=rate, seed=seed))
    t0 = time.perf_counter()
    eng.drain(policy=policy)
    wall = time.perf_counter() - t0
    fin = eng.finished
    c = eng.metrics.c
    tpot = _mean([r.tpot for r in fin if r.tpot is not None])
    return {
        "workload": workload,
        "frequency": frequency,
        "policy": type(policy).__name__ if policy is not None else None,
        "finished": len(fin),
        "energy_j": c.energy_joules_total,
        "sim_s": eng.clock,
        "busy_s": c.busy_seconds_total,
        "iterations": c.iterations_total,
        "ttft_s": _mean([r.ttft for r in fin]),
        "tpot_s": tpot,
        "e2e_s": _mean([r.e2e for r in fin]),
        "edp": c.energy_joules_total * tpot,
        "avg_power_w": c.energy_joules_total / max(eng.clock, 1e-9),
        "prefix_hit_rate": eng.kv.stats.hit_rate,
        "host_wall_s": wall,
        "host_us_per_iteration": 1e6 * wall / max(c.iterations_total, 1),
        "freq_transitions": c.freq_transitions_total,
        "engine": eng,
        "policy_obj": policy,
    }


def strip_engine(row: Dict) -> Dict:
    return {k: v for k, v in row.items()
            if k not in ("engine", "policy_obj")}


def _sweep_cell(args: tuple) -> Dict:
    """One fixed-frequency trace run — module-level so it pickles into
    ``pmap`` workers; strips the engine before crossing the process
    boundary."""
    workload, f, n_requests, rate, seed, ttft_weight, hardware = args
    r = strip_engine(run_workload(workload, n_requests=n_requests, rate=rate,
                                  frequency=float(f), seed=seed,
                                  hardware=hardware))
    r["delay_s"] = r["tpot_s"] + ttft_weight * r["ttft_s"]
    r["edp_sweep"] = r["energy_j"] * r["delay_s"]
    return r


def sweep_frequencies(workload: str, freqs: List[float], *,
                      n_requests: int = 150, rate: float = BASE_RATE,
                      seed: int = 1, ttft_weight: float = 0.1,
                      jobs: Optional[int] = None,
                      hardware: Union[HardwareSpec, str] = A6000
                      ) -> List[Dict]:
    """EDP(f) curve; delay = tpot + ttft_weight*ttft (paper's latency mix).

    Cells are independent fully-seeded runs, fanned out over a process pool
    and merged back in frequency order (deterministic regardless of
    completion order)."""
    hw = resolve_hardware(hardware)
    return pmap(_sweep_cell,
                [(workload, float(f), n_requests, rate, seed, ttft_weight,
                  hw) for f in freqs], jobs=jobs, seed=seed)


ORACLE_SWEEPS = "oracle_sweeps.json"


def _oracle_key(workload: str, n_requests: int, rate: float, seed: int,
                hw: HardwareSpec) -> str:
    return f"{workload}|n{n_requests}|r{rate}|s{seed}|{hw.name}"


def _migrate_oracle_cache(cache: Dict[str, float]) -> Dict[str, float]:
    """Rewrite legacy ``workload|n|rate|seed`` keys to the hardware-keyed
    form. Every pre-migration sweep ran on the A6000 calibration (the old
    code hardcoded it), so legacy entries are A6000 results by
    construction; without the spec name in the key, any non-A6000 caller
    would silently read A6000 optima back out."""
    out: Dict[str, float] = {}
    for k, v in cache.items():
        if k.count("|") == 3:
            k = f"{k}|{A6000.name}"
        out[k] = v
    return out


def measured_oracle_frequency(workload: str, *, n_requests: int = 150,
                              rate: float = BASE_RATE, seed: int = 1,
                              refresh: bool = False,
                              hardware: Union[HardwareSpec, str] = A6000
                              ) -> float:
    """Trace-measured best fixed frequency for ``workload``: the two-stage
    offline sweep's optimum, cached in ``results/oracle_sweeps.json`` so
    every benchmark table shares one sweep per (workload, trace, hardware)
    point. Feed it to the registry — ``get_policy("oracle",
    frequency_mhz=...)`` — to get the paper's "theoretical optimum" row
    measured on the trace rather than derived from the analytic cost
    model."""
    hw = resolve_hardware(hardware)
    key = _oracle_key(workload, n_requests, rate, seed, hw)
    cache: Dict[str, float] = {}
    try:
        cache = _migrate_oracle_cache(load_json(ORACLE_SWEEPS))
    except (FileNotFoundError, ValueError):
        pass
    if not refresh and key in cache:
        return float(cache[key])
    best, _ = two_stage_optimal(workload, n_requests=n_requests, rate=rate,
                                seed=seed, hardware=hw)
    # re-merge before saving: a concurrently-running benchmark cell may have
    # added other keys since we loaded (values are deterministic per key, so
    # last-writer-wins is safe; the merge just avoids dropping them)
    try:
        cache = {**_migrate_oracle_cache(load_json(ORACLE_SWEEPS)), **cache}
    except (FileNotFoundError, ValueError):
        pass
    cache[key] = float(best["frequency"])
    save_json(ORACLE_SWEEPS, cache)
    return float(best["frequency"])


def two_stage_optimal(workload: str, *, coarse_step: float = 90.0,
                      fine_step: float = 15.0, fine_half: float = 90.0,
                      n_requests: int = 150, rate: float = BASE_RATE,
                      seed: int = 1, jobs: Optional[int] = None,
                      hardware: Union[HardwareSpec, str] = A6000):
    """Coarse sweep over the full range, then 15 MHz resolution around the
    coarse optimum — the paper's offline 'theoretical optimum' procedure at
    tractable cost. Each stage fans its frequency cells out over the
    process pool (the fine stage depends on the coarse argmin, so the two
    stages themselves stay sequential). The sweep range, grid step, and
    engine all come from ``hardware`` (A6000 default)."""
    hw = resolve_hardware(hardware)
    coarse = list(np.arange(hw.f_min, hw.f_max + 1, coarse_step))
    rows = sweep_frequencies(workload, coarse, n_requests=n_requests,
                             rate=rate, seed=seed, jobs=jobs, hardware=hw)
    best = min(rows, key=lambda r: r["edp_sweep"])
    lo = max(hw.f_min, best["frequency"] - fine_half)
    hi = min(hw.f_max, best["frequency"] + fine_half)
    fine = [f for f in np.arange(lo, hi + 1, fine_step)
            if abs(f - best["frequency"]) > 1e-9]
    rows += sweep_frequencies(workload, fine, n_requests=n_requests,
                              rate=rate, seed=seed, jobs=jobs, hardware=hw)
    rows.sort(key=lambda r: r["frequency"])
    best = min(rows, key=lambda r: r["edp_sweep"])
    return best, rows
