"""Routing-delay x policy-tick-mode grid (beyond paper; ROADMAP event-core
items): how much decision quality and EDP shift once requests stop
teleporting to engines and tuners stop deciding exactly at iteration
boundaries.

The same fixed-seed trace is served by a 2-node per-node-AGFT cluster
under every combination of

  delay level   total mean routing delay (client->router->node hops +
                router FIFO service), 0-50 ms — 0 is the bit-identical
                anchor (zero-delay NetworkModel == direct submit)
  tick mode     ``iteration`` (windows gated on the engine clock at
                iteration boundaries; the golden-pinned paper mode) vs
                ``tick`` (pure POLICY_TICK events: wall-clock cadence,
                windows cut at tick time)

Per cell we report energy/EDP/latency, the measured mean delivery delay,
how many windows the tuners decided on, and DVFS transition counts. The
summary quantifies the two ROADMAP questions: what 0-50 ms of routing
delay does to EDP/TTFT (delay rows vs the 0 ms anchor, per mode) and
what pure-tick scheduling changes at zero delay (tick vs iteration
anchor cells).
"""
from __future__ import annotations

import argparse
from typing import Dict, List, Optional

from benchmarks.common import PAPER_MODEL, save_json
from repro.configs import get_config
from repro.serving import NetworkConfig, NetworkModel
from repro.serving.cluster import ServingCluster
from repro.workloads import PROTOTYPES, generate_requests

#: total mean routing delay levels (ms); 0 = the bit-identity anchor
DELAYS_MS = [0.0, 5.0, 20.0, 50.0]
QUICK_DELAYS_MS = [0.0, 20.0, 50.0]
TICK_MODES = ("iteration", "tick")
N_NODES = 2
ROUTER_SERVICE_S = 100e-6


def network_for(delay_ms: float, seed: int = 0) -> Optional[NetworkModel]:
    """A NetworkModel whose mean total delay (two hops + router service)
    is ``delay_ms``; None-delay cells use the zero model so the anchor
    row exercises the routed event path, not the direct one."""
    if delay_ms <= 0.0:
        return NetworkModel()
    hop = max((delay_ms * 1e-3 - ROUTER_SERVICE_S) / 2.0, 0.0)
    return NetworkModel(NetworkConfig(hop_latency_s=hop,
                                      router_service_s=ROUTER_SERVICE_S,
                                      distribution="lognormal",
                                      jitter=0.25), seed=seed)


def _trace(n: int, seed: int):
    return generate_requests(PROTOTYPES["normal"], n, base_rate=4.0,
                             seed=seed)


def _serve(delay_ms: float, tick_mode: str, n_requests: int,
           seed: int) -> Dict:
    cl = ServingCluster(get_config(PAPER_MODEL), n_nodes=N_NODES,
                        with_tuners=False, policies=["agft"] * N_NODES,
                        network=network_for(delay_ms, seed=seed),
                        policy_tick_mode=tick_mode)
    cl.submit(_trace(n_requests, seed))
    steps = cl.drain()
    s = cl.summary()
    decisions = sum(len(p.history) for p in cl.policies if p is not None)
    transitions = sum(e.metrics.c.freq_transitions_total
                     for e in cl.engines)
    return {
        "delay_ms": delay_ms,
        "tick_mode": tick_mode,
        "finished": s.finished,
        "energy_j": s.energy_j,
        "ttft_s": s.mean_ttft_s,
        "tpot_s": s.mean_tpot_s,
        "edp": s.edp,
        "mean_net_delay_s": s.mean_net_delay_s,
        "max_net_delay_s": s.max_net_delay_s,
        "node_frequencies": s.node_frequencies,
        "policy_decisions": decisions,
        "freq_transitions": transitions,
        "engine_steps": steps,
    }


def unit_args(n_requests: int, delays: Optional[List[float]] = None,
              seed: int = 17) -> List[tuple]:
    """One unit per (delay, tick-mode) cell."""
    delays = DELAYS_MS if delays is None else delays
    return [(d, mode, n_requests, seed)
            for mode in TICK_MODES for d in delays]


def _cell(args: tuple) -> Dict:
    return _serve(*args)


def _assemble(rows: List[Dict], quiet: bool = False) -> Dict:
    grid: Dict[str, Dict] = {}
    for r in rows:
        grid[f"{r['tick_mode']}|{r['delay_ms']:g}ms"] = r

    def rel(row, anchor, keys=("energy_j", "edp", "ttft_s", "tpot_s")):
        return {k: 100.0 * (row[k] / anchor[k] - 1.0) for k in keys}

    delays = sorted({r["delay_ms"] for r in rows})
    summary: Dict[str, Dict] = {"delay_impact_pct": {}}
    for mode in TICK_MODES:
        anchor = grid.get(f"{mode}|{delays[0]:g}ms")
        if anchor is None:
            continue
        summary["delay_impact_pct"][mode] = {
            f"{d:g}ms": rel(grid[f"{mode}|{d:g}ms"], anchor)
            for d in delays[1:] if f"{mode}|{d:g}ms" in grid}
    it0 = grid.get(f"iteration|{delays[0]:g}ms")
    tk0 = grid.get(f"tick|{delays[0]:g}ms")
    if it0 and tk0:
        summary["tick_vs_iteration_at_zero_delay_pct"] = rel(tk0, it0)
        summary["tick_vs_iteration_decisions"] = {
            "iteration": it0["policy_decisions"],
            "tick": tk0["policy_decisions"]}
    out = {"grid": grid, "summary": summary}
    save_json("tab_network.json", out)
    if not quiet:
        print(f"{'cell':>18s} {'energy':>9s} {'edp':>9s} {'ttft':>8s} "
              f"{'tpot':>8s} {'netdelay':>9s} {'decisions':>9s}")
        for key, r in grid.items():
            nd = r["mean_net_delay_s"]
            print(f"{key:>18s} {r['energy_j'] / 1e3:8.1f}k "
                  f"{r['edp']:9.1f} {r['ttft_s']:7.3f}s "
                  f"{r['tpot_s'] * 1e3:6.2f}ms "
                  f"{(nd or 0.0) * 1e3:7.1f}ms {r['policy_decisions']:9d}")
        tv = summary.get("tick_vs_iteration_at_zero_delay_pct")
        if tv:
            print(f"tick vs iteration @0ms: edp {tv['edp']:+.1f}%  "
                  f"ttft {tv['ttft_s']:+.1f}%")
        for mode, impact in summary["delay_impact_pct"].items():
            for lvl, d in impact.items():
                print(f"{mode} @{lvl} vs 0ms: edp {d['edp']:+.1f}%  "
                      f"ttft {d['ttft_s']:+.1f}%")
    return out


def run(n_requests: int = 400, delays: Optional[List[float]] = None,
        seed: int = 17, quiet: bool = False) -> Dict:
    rows = [_cell(a) for a in unit_args(n_requests, delays, seed)]
    return _assemble(rows, quiet=quiet)


def main() -> None:
    ap = argparse.ArgumentParser()
    ap.add_argument("--quick", action="store_true",
                    help="smaller trace + 3 delay levels (CI bench-smoke "
                         "cell)")
    ap.add_argument("--requests", type=int, default=0)
    args = ap.parse_args()
    n = args.requests or (150 if args.quick else 400)
    run(n_requests=n, delays=QUICK_DELAYS_MS if args.quick else None)


if __name__ == "__main__":
    main()
