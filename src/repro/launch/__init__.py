# NOTE: do not import dryrun here — it sets XLA_FLAGS at import time and is
# meant to be run as a standalone entry point.
