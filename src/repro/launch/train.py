"""Training driver: train a (reduced or full) model on the synthetic LM
pipeline. CPU-friendly at reduced scale; the full configs are exercised via
the dry-run.

  python -m repro.launch.train --arch tinyllama-1.1b --reduced \
      --steps 200 --batch 8 --seq 128
"""
from __future__ import annotations

import argparse
import json

import jax

from repro.configs import get_config
from repro.data import synthetic_token_batches
from repro.models import build_model
from repro.training import AdamWConfig, save_checkpoint, train


def main():
    ap = argparse.ArgumentParser()
    ap.add_argument("--arch", default="tinyllama-1.1b")
    ap.add_argument("--reduced", action="store_true",
                    help="use the smoke-test-sized variant")
    ap.add_argument("--steps", type=int, default=100)
    ap.add_argument("--batch", type=int, default=8)
    ap.add_argument("--seq", type=int, default=128)
    ap.add_argument("--lr", type=float, default=3e-4)
    ap.add_argument("--seed", type=int, default=0)
    ap.add_argument("--checkpoint", default="")
    ap.add_argument("--out", default="")
    args = ap.parse_args()

    cfg = get_config(args.arch)
    if args.reduced:
        cfg = cfg.reduced()
    model = build_model(cfg)
    params = model.init(jax.random.PRNGKey(args.seed))
    n_params = sum(p.size for p in jax.tree.leaves(params))
    print(f"[train] {cfg.name} ({'reduced' if args.reduced else 'full'}): "
          f"{n_params/1e6:.1f}M params")

    data = synthetic_token_batches(
        cfg.vocab_size, args.batch, args.seq, seed=args.seed,
        with_frames=cfg.is_encoder_decoder,
        frame_len=cfg.encoder_seq, d_model=cfg.d_model)

    def log(i, m):
        print(f"[train] step {i:5d} loss {m['loss']:.4f} "
              f"gnorm {m['grad_norm']:.3f} wall {m['wall_s']:.1f}s")

    params, opt_state, history = train(
        model, params, data, steps=args.steps,
        opt_cfg=AdamWConfig(lr=args.lr), callback=log)

    if args.checkpoint:
        save_checkpoint(args.checkpoint, params)
        print(f"[train] checkpoint -> {args.checkpoint}")
    if args.out:
        with open(args.out, "w") as f:
            json.dump(history, f, indent=1)
    first, last = history[0]["loss"], history[-1]["loss"]
    print(f"[train] loss {first:.4f} -> {last:.4f} "
          f"({'improved' if last < first else 'NOT improved'})")


if __name__ == "__main__":
    main()
