import os
os.environ["XLA_FLAGS"] = "--xla_force_host_platform_device_count=512"

"""Multi-pod dry-run: prove every (architecture x input-shape x mesh)
combination lowers, SPMD-partitions, and compiles.

For each combination this builds the jitted step (train_step / prefill /
serve_step) with explicit in/out shardings, lowers it against
ShapeDtypeStruct stand-ins (no device allocation), compiles, and reports
``memory_analysis()`` (proves it fits) + ``cost_analysis()`` (FLOPs/bytes
for the roofline) + collective-transfer bytes parsed from the HLO.

Usage:
  python -m repro.launch.dryrun --arch tinyllama-1.1b --shape train_4k
  python -m repro.launch.dryrun --all --mesh both --out results/dryrun
"""
import argparse      # noqa: E402
import functools     # noqa: E402
import json          # noqa: E402
import re            # noqa: E402
import time          # noqa: E402

import jax           # noqa: E402
import jax.numpy as jnp  # noqa: E402

from repro.configs import (ASSIGNED_ARCHS, config_for_shape,   # noqa: E402
                           get_shape)
from repro.distributed.sharding import (batch_pspec, cache_pspecs,  # noqa: E402
                                        logits_pspec, param_pspecs,
                                        with_sharding)
from repro.launch.mesh import make_debug_mesh, make_production_mesh  # noqa: E402
from repro.models import build_model                    # noqa: E402
from repro.training.optimizer import AdamWConfig, init_adamw  # noqa: E402
from repro.training.train_loop import make_train_step   # noqa: E402

from jax.sharding import NamedSharding, PartitionSpec as P  # noqa: E402

_DTYPE_BYTES = {"f64": 8, "f32": 4, "bf16": 2, "f16": 2, "s64": 8, "s32": 4,
                "u64": 8, "u32": 4, "s16": 2, "u16": 2, "s8": 1, "u8": 1,
                "pred": 1, "f8e4m3": 1, "f8e5m2": 1}

_COLL_RE = re.compile(
    r"(\w+[\d.\-]*)\s*=\s*([a-z0-9]+)\[([\d,]*)\][^=]*?"
    r"(all-reduce|all-gather|reduce-scatter|all-to-all|collective-permute)"
    r"[\s(]")


def collective_bytes(hlo_text: str) -> dict:
    """Sum result bytes of collective ops in the (SPMD-partitioned) HLO.
    Convention: all-reduce counted 2x (ring send+recv), others 1x."""
    out = {"all-reduce": 0, "all-gather": 0, "reduce-scatter": 0,
           "all-to-all": 0, "collective-permute": 0}
    for line in hlo_text.splitlines():
        s = line.strip()
        m = re.match(r"^%?[\w.\-]+ = ([a-z0-9]+)\[([\d,]*)\]", s)
        if not m:
            continue
        op = None
        for cand in out:
            if re.search(rf"\b{cand}(-start|-done)?\(", s):
                op = cand
                break
        if op is None:
            continue
        dt, dims = m.group(1), m.group(2)
        nb = _DTYPE_BYTES.get(dt, 4)
        n = 1
        for d in dims.split(","):
            if d:
                n *= int(d)
        out[op] += n * nb * (2 if op == "all-reduce" else 1)
    out["total"] = sum(v for k, v in out.items())
    return out


# ---------------------------------------------------------------------------
# input specs (ShapeDtypeStruct stand-ins; never allocates)
# ---------------------------------------------------------------------------

def input_specs(cfg, shape):
    """Model inputs for the given InputShape (tokens/labels/frames...)."""
    B, S = shape.global_batch, shape.seq_len
    sds = lambda shp, dt: jax.ShapeDtypeStruct(shp, dt)   # noqa: E731
    if shape.kind == "train":
        batch = {"tokens": sds((B, S), jnp.int32),
                 "labels": sds((B, S), jnp.int32)}
        if cfg.is_encoder_decoder:
            batch["frames"] = sds((B, cfg.encoder_seq, cfg.d_model),
                                  cfg.activation_dtype)
        return batch
    if shape.kind == "prefill":
        out = {"tokens": sds((B, S), jnp.int32)}
        if cfg.is_encoder_decoder:
            out["frames"] = sds((B, cfg.encoder_seq, cfg.d_model),
                                cfg.activation_dtype)
        return out
    # decode: one token against a seq_len-deep cache
    return {"token": sds((B, 1), jnp.int32),
            "pos": sds((B,), jnp.int32)}


def _shard_batch(tree, mesh, B):
    def one(path, leaf):
        extra = len(leaf.shape) - 1
        return with_sharding(
            leaf, batch_pspec(mesh, B, extra_dims=extra), mesh)
    return jax.tree_util.tree_map_with_path(
        lambda p, l: jax.ShapeDtypeStruct(
            l.shape, l.dtype,
            sharding=NamedSharding(mesh, batch_pspec(
                mesh, B, extra_dims=len(l.shape) - 1))), tree)


# ---------------------------------------------------------------------------
# build the lowerable function per shape kind
# ---------------------------------------------------------------------------

def build_lowering(arch: str, shape_name: str, mesh, *, seed: int = 0,
                   cfg_override=None, donate: bool = False):
    shape = get_shape(shape_name)
    cfg = cfg_override or config_for_shape(arch, shape_name)
    # dry-run uses the pure-jnp reference path (kernels are TPU-target)
    cfg = cfg.replace(use_pallas=False)
    model = build_model(cfg)
    B, S = shape.global_batch, shape.seq_len

    key = jax.random.PRNGKey(seed)
    params_sds = jax.eval_shape(model.init, key)
    p_specs = param_pspecs(params_sds, mesh)
    params_in = with_sharding(params_sds, p_specs, mesh)
    inputs = input_specs(cfg, shape)
    inputs_in = _shard_batch(inputs, mesh, B)
    repl = NamedSharding(mesh, P())

    if shape.kind == "train":
        opt_sds = jax.eval_shape(functools.partial(init_adamw), params_sds)
        o_specs = param_pspecs_like_opt(opt_sds, p_specs)
        opt_in = with_sharding(opt_sds, o_specs, mesh)
        step = make_train_step(model, AdamWConfig())
        fn = jax.jit(
            step,
            in_shardings=(jax.tree.map(lambda s: s.sharding, params_in),
                          jax.tree.map(lambda s: s.sharding, opt_in),
                          jax.tree.map(lambda s: s.sharding, inputs_in)),
            out_shardings=(
                jax.tree.map(lambda s: s.sharding, params_in),
                jax.tree.map(lambda s: s.sharding, opt_in),
                {"loss": repl, "grad_norm": repl, "step": repl}),
            donate_argnums=(0, 1) if donate else (),
        )
        return fn, (params_in, opt_in, inputs_in)

    if shape.kind == "prefill":
        def prefill_fn(params, batch):
            if cfg.is_encoder_decoder:
                return model.prefill(params, batch["tokens"],
                                     batch["frames"], max_len=S)
            return model.prefill(params, batch["tokens"], max_len=S)

        cache_sds = jax.eval_shape(
            lambda: _prefill_cache_shape(model, cfg, B, S))
        fn = jax.jit(
            prefill_fn,
            in_shardings=(jax.tree.map(lambda s: s.sharding, params_in),
                          jax.tree.map(lambda s: s.sharding, inputs_in)),
        )
        return fn, (params_in, inputs_in)

    # decode
    cache_sds = jax.eval_shape(lambda: model.init_cache(B, S))
    c_specs = cache_pspecs(cache_sds, mesh, B)
    cache_in = with_sharding(cache_sds, c_specs, mesh)

    def serve_step(params, token, cache, pos):
        return model.decode_step(params, token, cache, pos)

    fn = jax.jit(
        serve_step,
        in_shardings=(jax.tree.map(lambda s: s.sharding, params_in),
                      inputs_in["token"].sharding,
                      jax.tree.map(lambda s: s.sharding, cache_in),
                      inputs_in["pos"].sharding),
        out_shardings=(NamedSharding(mesh, logits_pspec(mesh, B, cfg.vocab_size)),
                       jax.tree.map(lambda s: s.sharding, cache_in)),
        donate_argnums=(2,) if donate else (),
    )
    return fn, (params_in, inputs_in["token"], cache_in, inputs_in["pos"])


def _prefill_cache_shape(model, cfg, B, S):
    return 0  # placeholder: prefill out_shardings left to GSPMD


def param_pspecs_like_opt(opt_sds, p_specs):
    """Optimizer state: step replicated; moments shard like params."""
    return type(opt_sds)(step=P(), m=p_specs, v=p_specs)


# ---------------------------------------------------------------------------
# cost extrapolation: XLA's cost_analysis counts a lax.scan body ONCE
# regardless of trip count. For exact roofline terms we compile two small
# UNROLLED variants (scan length u1, u2), fit the linear cost-in-depth
# model, and extrapolate to the real depth. The full-scan compile still
# provides the lowering proof + memory analysis.
# ---------------------------------------------------------------------------

def _scan_length(cfg) -> int:
    if cfg.arch_type == "hybrid":
        pat = len(cfg.block_pattern or ("rec", "rec", "attn"))
        return cfg.num_layers // pat
    prefix = cfg.first_k_dense if cfg.num_experts else 0
    return cfg.num_layers - prefix


def _cost_variant(cfg, u: int):
    if cfg.arch_type == "hybrid":
        pat = len(cfg.block_pattern or ("rec", "rec", "attn"))
        tail = cfg.num_layers % pat
        return cfg.replace(num_layers=pat * u + tail, unroll_layers=True)
    if cfg.is_encoder_decoder:
        return cfg.replace(num_layers=u, encoder_layers=u,
                           unroll_layers=True)
    prefix = cfg.first_k_dense if cfg.num_experts else 0
    return cfg.replace(num_layers=prefix + u, unroll_layers=True)


def _cost_dict(compiled) -> dict:
    """cost_analysis() returns a dict on jax >= 0.6 but a one-element list
    of dicts on older releases; normalize to a dict."""
    cost = compiled.cost_analysis() or {}
    if isinstance(cost, (list, tuple)):
        cost = cost[0] if cost else {}
    return cost


def _compile_cost(arch, shape_name, mesh, cfg, donate: bool = False):
    fn, args = build_lowering(arch, shape_name, mesh, cfg_override=cfg,
                              donate=donate)
    compiled = fn.lower(*args).compile()
    cost = _cost_dict(compiled)
    coll = collective_bytes(compiled.as_text())
    mem = compiled.memory_analysis()
    return {"flops": cost.get("flops", 0.0),
            "bytes": cost.get("bytes accessed", 0.0),
            "coll": coll,
            "temp_bytes": getattr(mem, "temp_size_in_bytes", 0),
            "arg_bytes": getattr(mem, "argument_size_in_bytes", 0)}


def cost_extrapolated(arch, shape_name, mesh, cfg_transform=None,
                      donate: bool = False) -> dict:
    cfg = config_for_shape(arch, shape_name).replace(use_pallas=False)
    if cfg_transform is not None:
        cfg = cfg_transform(cfg)
    U = _scan_length(cfg)
    u1, u2 = 1, 2
    c1 = _compile_cost(arch, shape_name, mesh, _cost_variant(cfg, u1),
                       donate=donate)
    c2 = _compile_cost(arch, shape_name, mesh, _cost_variant(cfg, u2),
                       donate=donate)

    def lin(a, b):
        slope = (b - a) / (u2 - u1)
        return max(a + slope * (U - u1), 0.0)

    coll = {k: lin(c1["coll"][k], c2["coll"][k]) for k in c1["coll"]}
    return {"flops": lin(c1["flops"], c2["flops"]),
            "bytes_accessed": lin(c1["bytes"], c2["bytes"]),
            "collective_bytes": coll,
            "scan_length": U,
            # u=2 variant's allocation footprint (NOT extrapolated; use for
            # relative comparisons e.g. donation / remat variants)
            "u2_temp_bytes": c2["temp_bytes"],
            "u2_arg_bytes": c2["arg_bytes"],
            "note": "linear-in-depth extrapolation from unrolled u=1,2"}


# ---------------------------------------------------------------------------
# runner
# ---------------------------------------------------------------------------

def run_one(arch: str, shape_name: str, *, multi_pod: bool = False,
            debug_mesh: bool = False, verbose: bool = True,
            extrapolate: bool = False) -> dict:
    t0 = time.time()
    if debug_mesh:
        mesh = make_debug_mesh(multi_pod=multi_pod)
    else:
        mesh = make_production_mesh(multi_pod=multi_pod)
    with mesh:
        fn, args = build_lowering(arch, shape_name, mesh)
        lowered = fn.lower(*args)
        compiled = lowered.compile()
        mem = compiled.memory_analysis()
        cost = _cost_dict(compiled)
        coll = collective_bytes(compiled.as_text())
        extra = cost_extrapolated(arch, shape_name, mesh) \
            if extrapolate else None
    n_dev = 1
    for v in mesh.shape.values():
        n_dev *= v
    result = {
        "arch": arch,
        "shape": shape_name,
        "mesh": "x".join(str(v) for v in mesh.shape.values()),
        "devices": n_dev,
        "flops": cost.get("flops", 0.0) if cost else 0.0,
        "bytes_accessed": cost.get("bytes accessed", 0.0) if cost else 0.0,
        "collective_bytes": coll,
        "memory": {
            "argument_size_bytes": getattr(mem, "argument_size_in_bytes", 0),
            "output_size_bytes": getattr(mem, "output_size_in_bytes", 0),
            "temp_size_bytes": getattr(mem, "temp_size_in_bytes", 0),
            "generated_code_size_bytes": getattr(
                mem, "generated_code_size_in_bytes", 0),
        },
        "compile_s": round(time.time() - t0, 2),
    }
    if extra is not None:
        result["extrapolated"] = extra
    if verbose:
        print(f"[dryrun] {arch} x {shape_name} x mesh={result['mesh']}: "
              f"OK ({result['compile_s']}s)")
        print(f"  memory_analysis: {result['memory']}")
        print(f"  cost_analysis: flops={result['flops']:.3e} "
              f"bytes={result['bytes_accessed']:.3e}")
        print(f"  collectives: { {k: f'{v:.2e}' for k, v in coll.items()} }")
    return result


def main():
    ap = argparse.ArgumentParser()
    ap.add_argument("--arch", default="tinyllama-1.1b",
                    help="architecture id or 'all'")
    ap.add_argument("--shape", default="train_4k",
                    help="input shape name or 'all'")
    ap.add_argument("--mesh", default="pod",
                    choices=["pod", "multipod", "both"])
    ap.add_argument("--all", action="store_true",
                    help="every (arch x shape)")
    ap.add_argument("--debug-mesh", action="store_true",
                    help="small 2x4 mesh (tests)")
    ap.add_argument("--out", default="",
                    help="write JSON results to this path")
    ap.add_argument("--cost-extrapolate", action="store_true",
                    help="add exact depth-extrapolated roofline costs")
    args = ap.parse_args()

    archs = ASSIGNED_ARCHS if (args.all or args.arch == "all") \
        else [args.arch]
    shapes = ["train_4k", "prefill_32k", "decode_32k", "long_500k"] \
        if (args.all or args.shape == "all") else [args.shape]
    meshes = {"pod": [False], "multipod": [True],
              "both": [False, True]}[args.mesh]

    results, failures = [], []
    for arch in archs:
        for shape in shapes:
            for mp in meshes:
                try:
                    results.append(run_one(
                        arch, shape, multi_pod=mp,
                        debug_mesh=args.debug_mesh,
                        extrapolate=args.cost_extrapolate))
                except Exception as e:  # noqa: BLE001
                    failures.append({"arch": arch, "shape": shape,
                                     "multi_pod": mp, "error": str(e)[:500]})
                    print(f"[dryrun] FAIL {arch} x {shape} x mp={mp}: "
                          f"{str(e)[:200]}")
    if args.out:
        os.makedirs(os.path.dirname(args.out) or ".", exist_ok=True)
        with open(args.out, "w") as f:
            json.dump({"results": results, "failures": failures}, f,
                      indent=1)
    print(f"\n[dryrun] {len(results)} ok, {len(failures)} failed")
    raise SystemExit(1 if failures else 0)


if __name__ == "__main__":
    main()
