"""Serving driver: run the continuous-batching engine — or an N-node
cluster — under a workload with any registered power policy (or none).

  python -m repro.launch.serve --arch llama3-3b --workload normal \
      --requests 2000 --policy agft
  python -m repro.launch.serve --arch llama3-3b --workload azure \
      --duration 3600 --policy slo
  python -m repro.launch.serve --workload normal --policy none \
      --frequency 1200
  python -m repro.launch.serve --nodes 4 --policy agft       # per-node loops
  python -m repro.launch.serve --nodes 4 --fleet-policy global   # one global
  # hierarchical power capping: the coordinator water-fills an 800 W
  # cluster budget into per-node frequency bands on FLEET_TICK while
  # per-node AGFT loops fine-tune inside them
  python -m repro.launch.serve --nodes 4 --fleet-policy hierarchy \
      --power-cap-w 800 --policy agft
  # realistic routing path (WAN-ish ~50 ms delivery delay) + per-node
  # policies deciding on wall-clock POLICY_TICK events instead of
  # iteration boundaries
  python -m repro.launch.serve --nodes 2 --policy agft \
      --network-model wan --policy-tick-mode tick
  # mixed-hardware fleet with energy-aware placement: requests land on
  # the node whose marginal joules-per-token is lowest among nodes that
  # can still meet the request's TTFT tier
  python -m repro.launch.serve --nodes 4 --hardware a6000,h100:2,l4 \
      --router energy --policy agft
"""
from __future__ import annotations

import argparse
import json

import numpy as np

from repro.configs import get_config
from repro.energy import HARDWARE, parse_fleet_hardware, resolve_hardware
from repro.policies import available_policies, get_policy
from repro.serving import (FAULT_PRESETS, NETWORK_PRESETS,
                           POLICY_TICK_MODES, EngineConfig,
                           InferenceEngine, NetworkModel)
from repro.serving.cluster import ROUTERS, ServingCluster
from repro.workloads import (PROTOTYPES, generate_azure_trace,
                             generate_requests)


def build_engine(arch: str, hardware_name: str = "a6000",
                 engine_cfg: EngineConfig = None) -> InferenceEngine:
    hw = resolve_hardware(hardware_name)
    return InferenceEngine(get_config(arch), engine_cfg or EngineConfig(),
                           hardware=hw, initial_frequency=hw.f_max)


def summarize(engine: InferenceEngine, tuner=None) -> dict:
    fin = engine.finished
    c = engine.metrics.c
    ttft = float(np.mean([r.ttft for r in fin])) if fin else 0.0
    tpot = float(np.mean([r.tpot for r in fin
                          if r.tpot is not None])) if fin else 0.0
    e2e = float(np.mean([r.e2e for r in fin])) if fin else 0.0
    out = {
        "finished": len(fin),
        "energy_j": c.energy_joules_total,
        "wall_s": engine.clock,
        "busy_s": c.busy_seconds_total,
        "ttft_s": ttft,
        "tpot_s": tpot,
        "e2e_s": e2e,
        "edp": c.energy_joules_total * tpot,
        "prefix_hit_rate": engine.kv.stats.hit_rate,
        "preemptions": engine.kv.stats.preemptions,
        "avg_power_w": (c.energy_joules_total / engine.clock
                        if engine.clock else 0.0),
    }
    if tuner is not None:
        out["policy"] = type(tuner).__name__
        if hasattr(tuner, "bank"):   # AGFT-specific learning state
            out["tuner"] = {
                "rounds": tuner.round,
                "converged_round": tuner.converged_round,
                "reopened": tuner.convergence.reopened,
                "pruned": len(tuner.pruner.permanently_pruned),
                "refinements": len(tuner.refiner.log),
                "arms": len(tuner.bank.arms),
            }
        elif getattr(tuner, "history", None):
            acted = [h for h in tuner.history if h.get("acted")]
            out["tuner"] = {"windows": len(tuner.history),
                            "actions": len(acted)}
    return out


def _generate(args):
    if args.workload == "azure":
        dur = args.duration or 3600.0
        return generate_azure_trace(dur, base_rate=args.rate,
                                    seed=args.seed)
    return generate_requests(PROTOTYPES[args.workload], args.requests,
                             base_rate=args.rate, seed=args.seed)


def _node_policies(args, hw_list):
    if args.policy == "none":
        return [None] * args.nodes
    kw = ({"frequency_mhz": args.frequency}
          if args.policy in ("static", "oracle") and args.frequency
          else {})
    return [get_policy(args.policy, hardware=hw, **kw)
            for hw in hw_list]


def _serve_cluster(args) -> dict:
    """N-node fleet: per-node copies of --policy (each resolved against
    its node's hardware spec), one --fleet-policy controller for the
    whole cluster, or BOTH for hierarchical control (a band coordinator
    on FLEET_TICK + node-local loops inside the bands)."""
    hw_list = parse_fleet_hardware(args.hardware, args.nodes)
    hetero = any(hw != hw_list[0] for hw in hw_list)
    fleet_hw = hw_list if hetero else hw_list[0]
    fleet = None
    if args.fleet_policy != "none":
        try:
            fleet = get_policy(args.fleet_policy, hardware=fleet_hw,
                               **({"power_cap_w": args.power_cap_w}
                                  if args.power_cap_w else {}))
        except TypeError:
            # controller without a cap parameter (e.g. "global"): attach
            # the cap as a metering-only attribute — the event loop still
            # accounts violations against it
            fleet = get_policy(args.fleet_policy, hardware=fleet_hw)
            fleet.power_cap_w = args.power_cap_w
    if fleet is None:
        policies = _node_policies(args, hw_list)
    elif getattr(fleet, "coordinates_bands", False):
        # hierarchical: node loops fine-tune inside the coordinator's
        # bands (default to the paper's per-node AGFT)
        if args.policy == "none":
            args.policy = "agft"
        policies = _node_policies(args, hw_list)
    elif getattr(fleet, "observe_only", False):
        # metering-only fleet policy: per-node --policy stays in charge
        policies = _node_policies(args, hw_list)
    else:
        policies = None     # single-frequency controllers actuate alone
    network = None
    if args.network_model != "none":
        network = NetworkModel.from_spec(args.network_model,
                                         seed=args.network_seed)
    cl = ServingCluster(get_config(args.arch), n_nodes=args.nodes,
                        hardware=hw_list, policies=policies,
                        fleet_policy=fleet, router=args.router,
                        network=network,
                        faults=(args.faults if args.faults != "none"
                                else None),
                        fault_seed=args.fault_seed,
                        policy_tick_mode=args.policy_tick_mode)
    if args.policy == "none" and args.frequency:
        for e in cl.engines:
            e.set_frequency(args.frequency)
    cl.submit(_generate(args))
    steps = cl.drain()
    s = cl.summary()
    out = {
        "nodes": args.nodes,
        "hardware": s.node_hardware,
        "router": args.router,
        "network_model": args.network_model,
        "policy_tick_mode": args.policy_tick_mode,
        "fleet_policy": args.fleet_policy,
        "policy": (args.policy if fleet is None
                   or getattr(fleet, "coordinates_bands", False)
                   or getattr(fleet, "observe_only", False) else None),
        "finished": s.finished,
        "energy_j": s.energy_j,
        "ttft_s": s.mean_ttft_s,
        "tpot_s": s.mean_tpot_s,
        "edp": s.edp,
        "node_frequencies": s.node_frequencies,
        "node_energy_j": s.node_energy_j,
        "engine_steps": steps,
    }
    if s.energy_by_tier and len(s.energy_by_tier) > 1:
        out["energy_by_tier"] = s.energy_by_tier
        out["finished_by_tier"] = s.finished_by_tier
    if s.power_cap_w is not None:
        out["power_cap_w"] = s.power_cap_w
        out["cap_violation_s"] = s.cap_violation_s
        out["metered_s"] = s.metered_s
        out["mean_fleet_power_w"] = s.mean_fleet_power_w
        out["peak_fleet_power_w"] = s.peak_fleet_power_w
    if s.mean_net_delay_s is not None:
        out["mean_net_delay_s"] = s.mean_net_delay_s
        out["max_net_delay_s"] = s.max_net_delay_s
    out["submitted"] = s.submitted
    out["dropped_total"] = s.dropped_total
    out["completion_rate"] = s.completion_rate
    if args.faults != "none":
        out["faults"] = args.faults
        out["fault_seed"] = args.fault_seed
        out["fault_counters"] = s.fault_counters
    return out


def main():
    ap = argparse.ArgumentParser()
    ap.add_argument("--arch", default="llama3-3b")
    ap.add_argument("--hardware", default="a6000",
                    help="hardware spec name "
                         f"({', '.join(sorted(HARDWARE))}) or, with "
                         "--nodes N, a mixed-fleet spec string like "
                         "'a6000,h100:2,l4' (name[:count] entries; counts "
                         "must sum to N; one bare name broadcasts)")
    ap.add_argument("--router", default="least-loaded",
                    choices=sorted(ROUTERS),
                    help="cluster request placement: 'least-loaded' "
                         "(throughput-normalized queue depth), 'energy' "
                         "(lowest marginal joules-per-token meeting the "
                         "request's TTFT tier), 'round-robin', 'length'")
    ap.add_argument("--workload", default="normal",
                    choices=list(PROTOTYPES) + ["azure"])
    ap.add_argument("--requests", type=int, default=2000)
    ap.add_argument("--duration", type=float, default=0.0,
                    help="azure trace duration (sim seconds)")
    ap.add_argument("--rate", type=float, default=3.0)
    ap.add_argument("--policy", "--tuner", dest="policy", default="agft",
                    choices=available_policies(scope="node") + ["none"])
    ap.add_argument("--frequency", type=float, default=0.0,
                    help="fixed frequency for --policy none/static "
                         "(0 = f_max / the static default)")
    ap.add_argument("--nodes", type=int, default=1,
                    help="serve through an N-node ServingCluster")
    ap.add_argument("--fleet-policy", default="none",
                    choices=available_policies(scope="fleet") + ["none"],
                    help="fleet-scope controller: 'global' (one frequency "
                         "for all nodes, overrides per-node --policy) or "
                         "'hierarchy' (per-node bands; --policy keeps "
                         "running inside them)")
    ap.add_argument("--power-cap-w", type=float, default=0.0,
                    help="cluster power budget in watts for --fleet-policy "
                         "hierarchy/hierarchy-uniform (0 = uncapped); with "
                         "other fleet policies it only meters violations")
    ap.add_argument("--network-model", default="none",
                    help="routing-path model for --nodes >= 2: 'none' "
                         "(instant placement), a preset "
                         f"({', '.join(sorted(NETWORK_PRESETS))}), or "
                         "fixed:<ms> for a constant total routing delay")
    ap.add_argument("--network-seed", type=int, default=0,
                    help="seed of the network model's hop-latency stream")
    ap.add_argument("--faults", default="none",
                    help="fault-injection preset "
                         f"({', '.join(sorted(FAULT_PRESETS))}) or clause "
                         "spec like 'crash:mttf=60,mttr=5;telemetry:"
                         "drop=0.3' (see repro.serving.faults)")
    ap.add_argument("--fault-seed", type=int, default=0,
                    help="seed of the per-node fault RNG streams")
    ap.add_argument("--policy-tick-mode", default="iteration",
                    choices=list(POLICY_TICK_MODES),
                    help="when per-node policies decide: 'iteration' "
                         "(engine-clock gating at iteration boundaries; "
                         "golden-pinned default) or 'tick' (wall-clock "
                         "POLICY_TICK events, windows cut at tick time)")
    ap.add_argument("--seed", type=int, default=0)
    ap.add_argument("--out", default="")
    args = ap.parse_args()

    if args.fleet_policy != "none" and args.nodes < 2:
        ap.error("--fleet-policy needs --nodes >= 2")
    # network routing, fault injection and pure policy ticks live in the
    # cluster/event-loop path; a single node becomes a 1-node cluster
    if (args.nodes > 1 or args.network_model != "none"
            or args.faults != "none"
            or args.policy_tick_mode != "iteration"):
        summary = _serve_cluster(args)
    else:
        eng = build_engine(args.arch, args.hardware)
        eng.submit(_generate(args))
        tuner = None
        if args.policy != "none":
            kw = ({"frequency_mhz": args.frequency}
                  if args.policy in ("static", "oracle") and args.frequency
                  else {})
            tuner = get_policy(args.policy,
                               hardware=resolve_hardware(args.hardware),
                               **kw)
        elif args.frequency:
            eng.set_frequency(args.frequency)
        eng.drain(policy=tuner)
        summary = summarize(eng, tuner)
    print(json.dumps(summary, indent=1))
    if args.out:
        with open(args.out, "w") as f:
            json.dump(summary, f, indent=1)


if __name__ == "__main__":
    main()
