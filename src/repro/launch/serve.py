"""Serving driver: run the continuous-batching engine under a workload with
any registered power policy (or none).

  python -m repro.launch.serve --arch llama3-3b --workload normal \
      --requests 2000 --policy agft
  python -m repro.launch.serve --arch llama3-3b --workload azure \
      --duration 3600 --policy slo
  python -m repro.launch.serve --workload normal --policy none \
      --frequency 1200
"""
from __future__ import annotations

import argparse
import json

import numpy as np

from repro.configs import get_config
from repro.energy import A6000, TPU_V5E
from repro.policies import available_policies, get_policy
from repro.serving import EngineConfig, InferenceEngine
from repro.workloads import (PROTOTYPES, generate_azure_trace,
                             generate_requests)

HARDWARE = {"a6000": A6000, "tpu-v5e": TPU_V5E}


def build_engine(arch: str, hardware_name: str = "a6000",
                 engine_cfg: EngineConfig = None) -> InferenceEngine:
    hw = HARDWARE[hardware_name]
    return InferenceEngine(get_config(arch), engine_cfg or EngineConfig(),
                           hardware=hw, initial_frequency=hw.f_max)


def summarize(engine: InferenceEngine, tuner=None) -> dict:
    fin = engine.finished
    c = engine.metrics.c
    ttft = float(np.mean([r.ttft for r in fin])) if fin else 0.0
    tpot = float(np.mean([r.tpot for r in fin
                          if r.tpot is not None])) if fin else 0.0
    e2e = float(np.mean([r.e2e for r in fin])) if fin else 0.0
    out = {
        "finished": len(fin),
        "energy_j": c.energy_joules_total,
        "wall_s": engine.clock,
        "busy_s": c.busy_seconds_total,
        "ttft_s": ttft,
        "tpot_s": tpot,
        "e2e_s": e2e,
        "edp": c.energy_joules_total * tpot,
        "prefix_hit_rate": engine.kv.stats.hit_rate,
        "preemptions": engine.kv.stats.preemptions,
        "avg_power_w": (c.energy_joules_total / engine.clock
                        if engine.clock else 0.0),
    }
    if tuner is not None:
        out["policy"] = type(tuner).__name__
        if hasattr(tuner, "bank"):   # AGFT-specific learning state
            out["tuner"] = {
                "rounds": tuner.round,
                "converged_round": tuner.converged_round,
                "reopened": tuner.convergence.reopened,
                "pruned": len(tuner.pruner.permanently_pruned),
                "refinements": len(tuner.refiner.log),
                "arms": len(tuner.bank.arms),
            }
        elif getattr(tuner, "history", None):
            acted = [h for h in tuner.history if h.get("acted")]
            out["tuner"] = {"windows": len(tuner.history),
                            "actions": len(acted)}
    return out


def main():
    ap = argparse.ArgumentParser()
    ap.add_argument("--arch", default="llama3-3b")
    ap.add_argument("--hardware", default="a6000",
                    choices=list(HARDWARE))
    ap.add_argument("--workload", default="normal",
                    choices=list(PROTOTYPES) + ["azure"])
    ap.add_argument("--requests", type=int, default=2000)
    ap.add_argument("--duration", type=float, default=0.0,
                    help="azure trace duration (sim seconds)")
    ap.add_argument("--rate", type=float, default=3.0)
    ap.add_argument("--policy", "--tuner", dest="policy", default="agft",
                    choices=available_policies() + ["none"])
    ap.add_argument("--frequency", type=float, default=0.0,
                    help="fixed frequency for --policy none/static "
                         "(0 = f_max / the static default)")
    ap.add_argument("--seed", type=int, default=0)
    ap.add_argument("--out", default="")
    args = ap.parse_args()

    eng = build_engine(args.arch, args.hardware)
    if args.workload == "azure":
        dur = args.duration or 3600.0
        eng.submit(generate_azure_trace(dur, base_rate=args.rate,
                                        seed=args.seed))
    else:
        eng.submit(generate_requests(PROTOTYPES[args.workload],
                                     args.requests, base_rate=args.rate,
                                     seed=args.seed))
    tuner = None
    if args.policy != "none":
        kw = ({"frequency_mhz": args.frequency}
              if args.policy in ("static", "oracle") and args.frequency
              else {})
        tuner = get_policy(args.policy, hardware=HARDWARE[args.hardware],
                           **kw)
    elif args.frequency:
        eng.set_frequency(args.frequency)
    eng.drain(policy=tuner)
    summary = summarize(eng, tuner)
    print(json.dumps(summary, indent=1))
    if args.out:
        with open(args.out, "w") as f:
            json.dump(summary, f, indent=1)


if __name__ == "__main__":
    main()
