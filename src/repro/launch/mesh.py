"""Production mesh builders.

A FUNCTION, not a module-level constant: importing this module never touches
jax device state (device count is locked at first jax init, and only
``dryrun.py`` forces the 512-placeholder configuration)."""
from __future__ import annotations

import jax

try:                                   # jax >= 0.5
    from jax.sharding import AxisType
except ImportError:                    # older jax: meshes are Auto-only
    AxisType = None


def _make_mesh(shape, axes):
    if AxisType is None:
        return jax.make_mesh(shape, axes)
    return jax.make_mesh(shape, axes,
                         axis_types=(AxisType.Auto,) * len(axes))


def make_production_mesh(*, multi_pod: bool = False):
    """16x16 = 256 chips/pod (TPU v5e); multi_pod adds a 2-pod outer axis."""
    shape = (2, 16, 16) if multi_pod else (16, 16)
    axes = ("pod", "data", "model") if multi_pod else ("data", "model")
    return _make_mesh(shape, axes)


def make_debug_mesh(n_data: int = 2, n_model: int = 4, *,
                    multi_pod: bool = False):
    """Small mesh for CI-scale dry-run tests (8-16 host devices)."""
    if multi_pod:
        return _make_mesh((2, n_data, n_model), ("pod", "data", "model"))
    return _make_mesh((n_data, n_model), ("data", "model"))
