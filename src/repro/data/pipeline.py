"""Token data pipeline for the training examples: a deterministic synthetic
LM stream (zipfian unigram mixture with induced bigram structure so the loss
actually decreases), shard-aware batching."""
from __future__ import annotations

from typing import Dict, Iterator

import numpy as np


def synthetic_token_batches(vocab_size: int, batch: int, seq_len: int,
                            *, seed: int = 0,
                            with_frames: bool = False,
                            frame_len: int = 0, d_model: int = 0
                            ) -> Iterator[Dict[str, np.ndarray]]:
    rng = np.random.default_rng(seed)
    # zipf-ish unigram with a deterministic successor table (learnable bigram)
    ranks = np.arange(1, vocab_size + 1)
    probs = 1.0 / ranks
    probs /= probs.sum()
    succ = rng.permutation(vocab_size)
    while True:
        base = rng.choice(vocab_size, size=(batch, seq_len + 1), p=probs)
        # 50% of positions follow the bigram successor rule
        follow = rng.random((batch, seq_len)) < 0.5
        for t in range(1, seq_len + 1):
            base[:, t] = np.where(follow[:, t - 1],
                                  succ[base[:, t - 1]], base[:, t])
        out = {"tokens": base[:, :-1].astype(np.int32),
               "labels": base[:, 1:].astype(np.int32)}
        if with_frames:
            out["frames"] = rng.normal(
                0, 1, (batch, frame_len, d_model)).astype(np.float32)
        yield out
