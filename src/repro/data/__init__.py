from repro.data.pipeline import synthetic_token_batches

__all__ = ["synthetic_token_batches"]
