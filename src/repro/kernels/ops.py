"""Jit'd public wrappers around the Pallas kernels.

These present the model-layer calling conventions ((B,S,H,D) attention
layouts etc.), handle layout shuffling into kernel-friendly shapes, and pick
interpret mode automatically off-TPU so the same call sites work on CPU
(tests / dry-runs) and TPU (deployment).
"""
from __future__ import annotations

import jax
import jax.numpy as jnp

from repro.kernels.decode_attention import decode_attention_grouped
from repro.kernels.flash_attention import flash_attention_bhsd
from repro.kernels.rglru import rglru_scan_kernel
from repro.kernels.rmsnorm import rmsnorm_kernel
from repro.kernels.ssd import ssd_scan_kernel


def _interpret() -> bool:
    return jax.default_backend() != "tpu"


def flash_attention(q, k, v, *, causal: bool = True,
                    block_q: int = 128, block_k: int = 128):
    """q: (B,S,H,D); k,v: (B,S,Hkv,D) -> (B,S,H,D)."""
    B, S, H, D = q.shape
    Hkv = k.shape[2]
    qk = q.transpose(0, 2, 1, 3).reshape(B * H, S, D)
    kk = k.transpose(0, 2, 1, 3).reshape(B * Hkv, S, D)
    vk = v.transpose(0, 2, 1, 3).reshape(B * Hkv, S, D)
    out = flash_attention_bhsd(qk, kk, vk, causal=causal, block_q=block_q,
                               block_k=block_k, interpret=_interpret())
    return out.reshape(B, H, S, D).transpose(0, 2, 1, 3)


def decode_attention(q, k_cache, v_cache, valid, *, block_k: int = 512):
    """q: (B,1,H,D); caches: (B,T,Hkv,D); valid: (B,T) -> (B,1,H,D)."""
    B, _, H, D = q.shape
    T, Hkv = k_cache.shape[1], k_cache.shape[2]
    G = H // Hkv
    qk = q.reshape(B, Hkv, G, D).reshape(B * Hkv, G, D)
    kk = k_cache.transpose(0, 2, 1, 3).reshape(B * Hkv, T, D)
    vk = v_cache.transpose(0, 2, 1, 3).reshape(B * Hkv, T, D)
    vmask = jnp.broadcast_to(valid[:, None, :], (B, Hkv, T)).reshape(
        B * Hkv, T)
    out = decode_attention_grouped(qk, kk, vk, vmask, block_k=block_k,
                                   interpret=_interpret())
    return out.reshape(B, Hkv, G, D).reshape(B, 1, H, D)


def rglru_scan(x, log_a, h0, *, block_w: int = 128, block_s: int = 256):
    """x, log_a (B,S,W) fp32; h0 (B,W) -> (ys, h_last) fp32."""
    B, S, W = x.shape
    bs = block_s
    while S % bs:
        bs //= 2
    bw = block_w if W % block_w == 0 else W
    return rglru_scan_kernel(x.astype(jnp.float32),
                             log_a.astype(jnp.float32),
                             h0.astype(jnp.float32),
                             block_w=bw, block_s=max(bs, 1),
                             interpret=_interpret())


def ssd_scan(x, dt, A, B, C, *, chunk: int = 128):
    """Chunked SSD. Shapes per repro.kernels.ref.ssd_scan."""
    s = x.shape[1]
    ck = chunk
    while s % ck:
        ck //= 2
    return ssd_scan_kernel(x.astype(jnp.float32), dt.astype(jnp.float32),
                           A.astype(jnp.float32), B.astype(jnp.float32),
                           C.astype(jnp.float32), chunk=max(ck, 1),
                           interpret=_interpret())


def rmsnorm(x, weight, *, eps: float = 1e-6):
    return rmsnorm_kernel(x, weight, eps=eps, interpret=_interpret())
