"""Flash-decoding Pallas kernel: one query token vs a long KV cache.

Grid = (batch*kv_heads, T/block_k): the innermost axis streams KV-cache
blocks; the ``group`` query heads that share a kv head ride along as the
sublane axis of a single (group, D) query tile, so decode GQA costs one pass
over the cache per kv head (the memory-bound roofline optimum). A boolean
validity mask handles ragged/ring-buffer caches.
"""
from __future__ import annotations

import functools

import jax
import jax.numpy as jnp
from jax.experimental import pallas as pl
from jax.experimental.pallas import tpu as pltpu

NEG_INF = -1e30


def _kernel(q_ref, k_ref, v_ref, valid_ref, o_ref, m_ref, l_ref, acc_ref, *,
            scale: float):
    ki = pl.program_id(1)
    nk = pl.num_programs(1)

    @pl.when(ki == 0)
    def _init():
        m_ref[...] = jnp.full_like(m_ref, NEG_INF)
        l_ref[...] = jnp.zeros_like(l_ref)
        acc_ref[...] = jnp.zeros_like(acc_ref)

    q = q_ref[0].astype(jnp.float32) * scale              # (G, D)
    k = k_ref[0].astype(jnp.float32)                      # (bk, D)
    v = v_ref[0].astype(jnp.float32)
    valid = valid_ref[0]                                  # (bk,) bool
    s = q @ k.T                                           # (G, bk)
    s = jnp.where(valid[None, :], s, NEG_INF)

    m_prev = m_ref[...]
    l_prev = l_ref[...]
    m_new = jnp.maximum(m_prev, jnp.max(s, axis=1))
    p = jnp.exp(s - m_new[:, None])
    p = jnp.where(valid[None, :], p, 0.0)
    alpha = jnp.exp(m_prev - m_new)
    l_ref[...] = alpha * l_prev + jnp.sum(p, axis=1)
    acc_ref[...] = acc_ref[...] * alpha[:, None] + p @ v
    m_ref[...] = m_new

    @pl.when(ki == nk - 1)
    def _finalize():
        l = l_ref[...]
        l = jnp.where(l == 0.0, 1.0, l)
        o_ref[0] = (acc_ref[...] / l[:, None]).astype(o_ref.dtype)


@functools.partial(jax.jit, static_argnames=("block_k", "interpret"))
def decode_attention_grouped(q, k, v, valid, *, block_k: int = 512,
                             interpret: bool = True):
    """q: (BHkv, G, D); k, v: (BHkv, T, D); valid: (BHkv, T) bool."""
    BHkv, G, D = q.shape
    T = k.shape[1]
    block_k = min(block_k, T)
    assert T % block_k == 0
    grid = (BHkv, T // block_k)
    kernel = functools.partial(_kernel, scale=D ** -0.5)
    return pl.pallas_call(
        kernel,
        grid=grid,
        in_specs=[
            pl.BlockSpec((1, G, D), lambda bh, ki: (bh, 0, 0)),
            pl.BlockSpec((1, block_k, D), lambda bh, ki: (bh, ki, 0)),
            pl.BlockSpec((1, block_k, D), lambda bh, ki: (bh, ki, 0)),
            pl.BlockSpec((1, block_k), lambda bh, ki: (bh, ki)),
        ],
        out_specs=pl.BlockSpec((1, G, D), lambda bh, ki: (bh, 0, 0)),
        out_shape=jax.ShapeDtypeStruct((BHkv, G, D), q.dtype),
        scratch_shapes=[
            pltpu.VMEM((G,), jnp.float32),
            pltpu.VMEM((G,), jnp.float32),
            pltpu.VMEM((G, D), jnp.float32),
        ],
        interpret=interpret,
    )(q, k, v, valid)
