"""RG-LRU linear-recurrence Pallas kernel (RecurrentGemma/Griffin).

The recurrence h_t = a_t * h_{t-1} + sqrt(1-a_t^2) * x_t is elementwise over
the width axis, so the kernel tiles width across the grid's first axis (fully
parallel, lane-aligned blocks of 128) and walks the innermost grid axis over
sequence chunks, carrying the running state in VMEM scratch. Inside a chunk
the time loop is a ``fori_loop`` over VREG rows — sequential in time but with
``block_w`` lanes of parallel ALU work per step, which is the right shape for
the VPU (there is no matmul here for the MXU).
"""
from __future__ import annotations

import functools

import jax
import jax.numpy as jnp
from jax.experimental import pallas as pl
from jax.experimental.pallas import tpu as pltpu


def _kernel(x_ref, a_ref, h0_ref, y_ref, hlast_ref, h_ref, *,
            block_s: int):
    si = pl.program_id(1)
    ns = pl.num_programs(1)

    @pl.when(si == 0)
    def _init():
        h_ref[...] = h0_ref[0].astype(jnp.float32)        # (bw,)

    x = x_ref[0].astype(jnp.float32)                      # (bs, bw)
    log_a = a_ref[0].astype(jnp.float32)
    a = jnp.exp(log_a)
    gated = jnp.sqrt(jnp.clip(1.0 - a * a, 1e-9, 1.0)) * x

    def step(t, carry):
        h = carry
        h = a[t] * h + gated[t]
        y_ref[0, t, :] = h.astype(y_ref.dtype)
        return h

    h = jax.lax.fori_loop(0, block_s, step, h_ref[...])
    h_ref[...] = h

    @pl.when(si == ns - 1)
    def _final():
        hlast_ref[0] = h_ref[...].astype(hlast_ref.dtype)


@functools.partial(jax.jit, static_argnames=("block_w", "block_s",
                                             "interpret"))
def rglru_scan_kernel(x, log_a, h0, *, block_w: int = 128,
                      block_s: int = 256, interpret: bool = True):
    """x, log_a: (B, S, W) fp32; h0: (B, W) fp32.

    Returns (ys (B,S,W) fp32, h_last (B,W) fp32)."""
    B, S, W = x.shape
    block_w = min(block_w, W)
    block_s = min(block_s, S)
    assert W % block_w == 0 and S % block_s == 0
    grid = (B * (W // block_w), S // block_s)
    nw = W // block_w

    kernel = functools.partial(_kernel, block_s=block_s)
    ys, h_last = pl.pallas_call(
        kernel,
        grid=grid,
        in_specs=[
            pl.BlockSpec((1, block_s, block_w),
                         lambda bw, si: (bw // nw, si, bw % nw)),
            pl.BlockSpec((1, block_s, block_w),
                         lambda bw, si: (bw // nw, si, bw % nw)),
            pl.BlockSpec((1, block_w), lambda bw, si: (bw // nw, bw % nw)),
        ],
        out_specs=[
            pl.BlockSpec((1, block_s, block_w),
                         lambda bw, si: (bw // nw, si, bw % nw)),
            pl.BlockSpec((1, block_w), lambda bw, si: (bw // nw, bw % nw)),
        ],
        out_shape=[
            jax.ShapeDtypeStruct((B, S, W), jnp.float32),
            jax.ShapeDtypeStruct((B, W), jnp.float32),
        ],
        scratch_shapes=[pltpu.VMEM((block_w,), jnp.float32)],
        interpret=interpret,
    )(x, log_a, h0)
    return ys, h_last
