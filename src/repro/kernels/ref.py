"""Pure-jnp oracles for every Pallas kernel. These are the ground truth the
kernel sweep tests assert against, and the execution path used on CPU
(dry-runs, benchmarks) where the TPU kernels would run in interpret mode.
"""
from __future__ import annotations

from typing import Tuple

import jax
import jax.numpy as jnp

NEG_INF = -1e30


def flash_attention(q: jnp.ndarray, k: jnp.ndarray, v: jnp.ndarray,
                    *, causal: bool = True) -> jnp.ndarray:
    """q: (B,S,H,D); k,v: (B,S,Hkv,D) -> (B,S,H,D). GQA by head grouping."""
    B, S, H, D = q.shape
    Hkv = k.shape[2]
    G = H // Hkv
    qg = q.reshape(B, S, Hkv, G, D).astype(jnp.float32)
    scores = jnp.einsum("bshgd,bthd->bhgst", qg, k.astype(jnp.float32))
    scores = scores * (D ** -0.5)
    if causal:
        mask = jnp.tril(jnp.ones((S, S), bool))
        scores = jnp.where(mask[None, None, None], scores, NEG_INF)
    probs = jax.nn.softmax(scores, axis=-1)
    out = jnp.einsum("bhgst,bthd->bshgd", probs, v.astype(jnp.float32))
    return out.reshape(B, S, H, D).astype(q.dtype)


def decode_attention(q: jnp.ndarray, k_cache: jnp.ndarray,
                     v_cache: jnp.ndarray,
                     valid: jnp.ndarray) -> jnp.ndarray:
    """q: (B,1,H,D); caches (B,T,Hkv,D); valid (B,T) bool -> (B,1,H,D)."""
    B, _, H, D = q.shape
    T, Hkv = k_cache.shape[1], k_cache.shape[2]
    G = H // Hkv
    qg = q.reshape(B, Hkv, G, D).astype(jnp.float32)
    scores = jnp.einsum("bhgd,bthd->bhgt", qg,
                        k_cache.astype(jnp.float32)) * (D ** -0.5)
    scores = jnp.where(valid[:, None, None, :], scores, NEG_INF)
    probs = jax.nn.softmax(scores, axis=-1)
    out = jnp.einsum("bhgt,bthd->bhgd", probs, v_cache.astype(jnp.float32))
    return out.reshape(B, 1, H, D).astype(q.dtype)


def rglru_scan(x: jnp.ndarray, log_a: jnp.ndarray,
               h0: jnp.ndarray) -> Tuple[jnp.ndarray, jnp.ndarray]:
    """h_t = a_t h_{t-1} + sqrt(1-a_t^2) x_t. x, log_a (B,S,W); h0 (B,W)."""
    a = jnp.exp(log_a.astype(jnp.float32))
    gated = jnp.sqrt(jnp.clip(1.0 - a * a, 1e-9, 1.0)) * x.astype(jnp.float32)

    def step(h, inp):
        at, gt = inp
        h = at * h + gt
        return h, h

    h_last, ys = jax.lax.scan(
        step, h0.astype(jnp.float32),
        (jnp.moveaxis(a, 1, 0), jnp.moveaxis(gated, 1, 0)))
    return jnp.moveaxis(ys, 0, 1), h_last


def ssd_scan(x: jnp.ndarray, dt: jnp.ndarray, A: jnp.ndarray,
             B: jnp.ndarray, C: jnp.ndarray, *, chunk: int
             ) -> Tuple[jnp.ndarray, jnp.ndarray]:
    """Sequential-state-space oracle for the SSD kernel (token-by-token).

    x (b,s,h,p); dt (b,s,h); A (h,); B,C (b,s,g,n).
    Returns (y (b,s,h,p), final_state (b,h,p,n)).
    """
    b, s, h, p = x.shape
    g, n = B.shape[2], B.shape[3]
    rep = h // g
    Br = jnp.repeat(B, rep, axis=2).astype(jnp.float32)
    Cr = jnp.repeat(C, rep, axis=2).astype(jnp.float32)
    xf = x.astype(jnp.float32)
    dtf = dt.astype(jnp.float32)

    def step(state, inp):
        xt, dtt, Bt, Ct = inp                               # (b,h,p),(b,h),(b,h,n)
        decay = jnp.exp(-dtt * A[None])[..., None, None]    # (b,h,1,1)
        upd = dtt[..., None, None] * jnp.einsum("bhn,bhp->bhpn", Bt, xt)
        state = decay * state + upd
        y = jnp.einsum("bhn,bhpn->bhp", Ct, state)
        return state, y

    init = jnp.zeros((b, h, p, n), jnp.float32)
    final, ys = jax.lax.scan(
        step, init,
        (jnp.moveaxis(xf, 1, 0), jnp.moveaxis(dtf, 1, 0),
         jnp.moveaxis(Br, 1, 0), jnp.moveaxis(Cr, 1, 0)))
    return jnp.moveaxis(ys, 0, 1), final


def rmsnorm(x: jnp.ndarray, weight: jnp.ndarray,
            *, eps: float = 1e-6) -> jnp.ndarray:
    xf = x.astype(jnp.float32)
    var = jnp.mean(jnp.square(xf), axis=-1, keepdims=True)
    return (xf * jax.lax.rsqrt(var + eps)
            * weight.astype(jnp.float32)).astype(x.dtype)
