"""Causal GQA flash-attention Pallas kernel (TPU target).

Tiling: grid = (batch*q_heads, Sq/block_q, Sk/block_k); the innermost grid
axis streams KV blocks while (m, l, acc) accumulate in VMEM scratch — the
standard streaming-softmax decomposition. Block shapes are MXU-aligned
(multiples of 128 on the seq axes; head_dim is the lane axis). GQA is
expressed in the BlockSpec index maps: the KV specs map q-head ``h`` to kv
head ``h // group`` so no materialised head-replication ever hits HBM.
"""
from __future__ import annotations

import functools

import jax
import jax.numpy as jnp
from jax.experimental import pallas as pl
from jax.experimental.pallas import tpu as pltpu

NEG_INF = -1e30


def _kernel(q_ref, k_ref, v_ref, o_ref, m_ref, l_ref, acc_ref, *,
            scale: float, block_q: int, block_k: int, causal: bool):
    qi = pl.program_id(1)
    ki = pl.program_id(2)
    nk = pl.num_programs(2)

    @pl.when(ki == 0)
    def _init():
        m_ref[...] = jnp.full_like(m_ref, NEG_INF)
        l_ref[...] = jnp.zeros_like(l_ref)
        acc_ref[...] = jnp.zeros_like(acc_ref)

    q = q_ref[0].astype(jnp.float32) * scale              # (bq, D)
    k = k_ref[0].astype(jnp.float32)                      # (bk, D)
    v = v_ref[0].astype(jnp.float32)                      # (bk, D)
    s = q @ k.T                                           # (bq, bk)
    if causal:
        rows = qi * block_q + jax.lax.broadcasted_iota(
            jnp.int32, (block_q, block_k), 0)
        cols = ki * block_k + jax.lax.broadcasted_iota(
            jnp.int32, (block_q, block_k), 1)
        s = jnp.where(rows >= cols, s, NEG_INF)

    m_prev = m_ref[...]
    l_prev = l_ref[...]
    m_cur = jnp.max(s, axis=1)
    m_new = jnp.maximum(m_prev, m_cur)
    p = jnp.exp(s - m_new[:, None])
    alpha = jnp.exp(m_prev - m_new)
    l_new = alpha * l_prev + jnp.sum(p, axis=1)
    acc_ref[...] = acc_ref[...] * alpha[:, None] + p @ v
    m_ref[...] = m_new
    l_ref[...] = l_new

    @pl.when(ki == nk - 1)
    def _finalize():
        l = l_ref[...]
        l = jnp.where(l == 0.0, 1.0, l)                   # fully-masked rows
        o_ref[0] = (acc_ref[...] / l[:, None]).astype(o_ref.dtype)


@functools.partial(jax.jit, static_argnames=("causal", "block_q", "block_k",
                                             "interpret"))
def flash_attention_bhsd(q, k, v, *, causal: bool = True,
                         block_q: int = 128, block_k: int = 128,
                         interpret: bool = True):
    """q: (BH, Sq, D); k, v: (BHkv, Sk, D) with BH = BHkv * group."""
    BH, Sq, D = q.shape
    BHkv, Sk, _ = k.shape
    group = BH // BHkv
    block_q = min(block_q, Sq)
    block_k = min(block_k, Sk)
    assert Sq % block_q == 0 and Sk % block_k == 0
    grid = (BH, Sq // block_q, Sk // block_k)
    scale = D ** -0.5

    kernel = functools.partial(_kernel, scale=scale, block_q=block_q,
                               block_k=block_k, causal=causal)
    return pl.pallas_call(
        kernel,
        grid=grid,
        in_specs=[
            pl.BlockSpec((1, block_q, D), lambda bh, qi, ki: (bh, qi, 0)),
            pl.BlockSpec((1, block_k, D),
                         lambda bh, qi, ki: (bh // group, ki, 0)),
            pl.BlockSpec((1, block_k, D),
                         lambda bh, qi, ki: (bh // group, ki, 0)),
        ],
        out_specs=pl.BlockSpec((1, block_q, D),
                               lambda bh, qi, ki: (bh, qi, 0)),
        out_shape=jax.ShapeDtypeStruct((BH, Sq, D), q.dtype),
        scratch_shapes=[
            pltpu.VMEM((block_q,), jnp.float32),          # running max m
            pltpu.VMEM((block_q,), jnp.float32),          # running sum l
            pltpu.VMEM((block_q, D), jnp.float32),        # output accumulator
        ],
        interpret=interpret,
    )(q, k, v)
