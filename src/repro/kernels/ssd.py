"""Mamba-2 SSD (state-space duality) chunked-scan Pallas kernel.

Grid = (batch*heads, S/chunk): the innermost axis walks chunks sequentially,
carrying the (P, N) inter-chunk state in VMEM scratch. Each chunk does the
dual quadratic form — (chunk x chunk) decay-masked C·Bᵀ "attention" plus the
incoming-state contribution — entirely in VMEM with MXU-shaped matmuls
(chunk and N are 128-multiples for the full-size configs; P=64 rides the
sublane axis). This is the TPU-native adaptation of the paper's CUDA
chunk-parallel SSD: instead of warp-level shuffles, the intra-chunk work is
expressed as dense matmuls and the sequential dependency is confined to the
innermost grid axis.
"""
from __future__ import annotations

import functools

import jax
import jax.numpy as jnp
from jax.experimental import pallas as pl
from jax.experimental.pallas import tpu as pltpu


def _kernel(x_ref, dt_ref, a_ref, b_ref, c_ref, y_ref, state_out_ref,
            state_ref, *, chunk: int):
    ci = pl.program_id(1)
    nc = pl.num_programs(1)

    @pl.when(ci == 0)
    def _init():
        state_ref[...] = jnp.zeros_like(state_ref)

    x = x_ref[0].astype(jnp.float32)                      # (c, P)
    dt = dt_ref[0].astype(jnp.float32)                    # (c, 1) -> (c,)
    dt = dt[:, 0]
    A = a_ref[0, 0]                                       # scalar for head
    Bm = b_ref[0].astype(jnp.float32)                     # (c, N)
    Cm = c_ref[0].astype(jnp.float32)                     # (c, N)

    dA = dt * A                                           # (c,)
    seg = jnp.cumsum(dA)                                  # (c,)
    # intra-chunk attention-like dual form
    li = seg[:, None]
    lj = seg[None, :]
    mask = (jax.lax.broadcasted_iota(jnp.int32, (chunk, chunk), 0)
            >= jax.lax.broadcasted_iota(jnp.int32, (chunk, chunk), 1))
    delta = jnp.where(mask, li - lj, 0.0)
    decay = jnp.where(mask, jnp.exp(-delta), 0.0)         # (c, c)
    att = (Cm @ Bm.T) * decay * dt[None, :]
    y = att @ x                                           # (c, P)
    # incoming-state contribution: y_i += exp(-seg_i) * C_i . S_prev
    state = state_ref[...]                                # (P, N)
    y = y + jnp.exp(-seg)[:, None] * (Cm @ state.T)
    y_ref[0] = y.astype(y_ref.dtype)
    # state update: S' = exp(-sum dA) S + sum_j exp(-(seg_last-seg_j)) dt_j x_j B_j^T
    w = jnp.exp(-(seg[-1] - seg)) * dt                    # (c,)
    state_new = (jnp.exp(-jnp.sum(dA)) * state
                 + (x * w[:, None]).T @ Bm)               # (P, N)
    state_ref[...] = state_new

    @pl.when(ci == nc - 1)
    def _final():
        state_out_ref[0] = state_new.astype(state_out_ref.dtype)


@functools.partial(jax.jit, static_argnames=("chunk", "interpret"))
def ssd_scan_kernel(x, dt, A, B, C, *, chunk: int = 128,
                    interpret: bool = True):
    """x (b,s,h,p); dt (b,s,h); A (h,); B,C (b,s,g,n).

    Returns (y (b,s,h,p) fp32, final_state (b,h,p,n) fp32)."""
    b, s, h, p = x.shape
    g, n = B.shape[2], B.shape[3]
    rep = h // g
    assert s % chunk == 0
    nc = s // chunk
    # layouts: head-major so each grid cell streams contiguous chunks
    xk = x.transpose(0, 2, 1, 3).reshape(b * h, s, p)
    dtk = dt.transpose(0, 2, 1).reshape(b * h, s, 1)
    Ak = jnp.broadcast_to(A[None], (b, h)).reshape(b * h, 1)
    Bk = B.transpose(0, 2, 1, 3).reshape(b * g, s, n)
    Ck = C.transpose(0, 2, 1, 3).reshape(b * g, s, n)

    kernel = functools.partial(_kernel, chunk=chunk)
    y, state = pl.pallas_call(
        kernel,
        grid=(b * h, nc),
        in_specs=[
            pl.BlockSpec((1, chunk, p), lambda bh, ci: (bh, ci, 0)),
            pl.BlockSpec((1, chunk, 1), lambda bh, ci: (bh, ci, 0)),
            pl.BlockSpec((1, 1), lambda bh, ci: (bh, 0)),
            pl.BlockSpec((1, chunk, n), lambda bh, ci: (bh // rep, ci, 0)),
            pl.BlockSpec((1, chunk, n), lambda bh, ci: (bh // rep, ci, 0)),
        ],
        out_specs=[
            pl.BlockSpec((1, chunk, p), lambda bh, ci: (bh, ci, 0)),
            pl.BlockSpec((1, p, n), lambda bh, ci: (bh, 0, 0)),
        ],
        out_shape=[
            jax.ShapeDtypeStruct((b * h, s, p), jnp.float32),
            jax.ShapeDtypeStruct((b * h, p, n), jnp.float32),
        ],
        scratch_shapes=[pltpu.VMEM((p, n), jnp.float32)],
        interpret=interpret,
    )(xk, dtk, Ak, Bk, Ck)
    y = y.reshape(b, h, s, p).transpose(0, 2, 1, 3)
    state = state.reshape(b, h, p, n)
    return y, state
