"""Fused RMSNorm Pallas kernel: one HBM read, fp32 reduction in VMEM, one
HBM write. Rows (flattened batch*seq) tile the grid; the feature axis stays
whole in the lane dimension.
"""
from __future__ import annotations

import functools

import jax
import jax.numpy as jnp
from jax.experimental import pallas as pl


def _kernel(x_ref, w_ref, o_ref, *, eps: float):
    x = x_ref[...].astype(jnp.float32)                    # (br, D)
    w = w_ref[...].astype(jnp.float32)                    # (D,)
    var = jnp.mean(jnp.square(x), axis=-1, keepdims=True)
    o_ref[...] = (x * jax.lax.rsqrt(var + eps) * w[None]).astype(o_ref.dtype)


@functools.partial(jax.jit, static_argnames=("eps", "block_rows",
                                             "interpret"))
def rmsnorm_kernel(x, weight, *, eps: float = 1e-6, block_rows: int = 256,
                   interpret: bool = True):
    """x: (..., D); weight: (D,)."""
    orig_shape = x.shape
    D = x.shape[-1]
    R = 1
    for d in x.shape[:-1]:
        R *= d
    x2 = x.reshape(R, D)
    block_rows = min(block_rows, R)
    pad = (-R) % block_rows
    if pad:
        x2 = jnp.pad(x2, ((0, pad), (0, 0)))
    Rp = x2.shape[0]
    kernel = functools.partial(_kernel, eps=eps)
    out = pl.pallas_call(
        kernel,
        grid=(Rp // block_rows,),
        in_specs=[
            pl.BlockSpec((block_rows, D), lambda r: (r, 0)),
            pl.BlockSpec((D,), lambda r: (0,)),
        ],
        out_specs=pl.BlockSpec((block_rows, D), lambda r: (r, 0)),
        out_shape=jax.ShapeDtypeStruct((Rp, D), x.dtype),
        interpret=interpret,
    )(x2, weight)
    if pad:
        out = out[:R]
    return out.reshape(orig_shape)
