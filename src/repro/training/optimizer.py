"""AdamW on parameter pytrees — pure JAX, no optax dependency.

State is a pytree matching params (m, v moments) plus a scalar step, so the
distributed layer can shard optimizer state with the same PartitionSpecs as
the parameters (ZeRO-0 tensor-parallel layout)."""
from __future__ import annotations

import dataclasses
from typing import Any, NamedTuple, Tuple

import jax
import jax.numpy as jnp


@dataclasses.dataclass(frozen=True)
class AdamWConfig:
    lr: float = 3e-4
    b1: float = 0.9
    b2: float = 0.95
    eps: float = 1e-8
    weight_decay: float = 0.1
    grad_clip: float = 1.0
    warmup_steps: int = 100
    # moments in fp32 even when params are bf16
    moment_dtype: str = "float32"


class AdamWState(NamedTuple):
    step: jnp.ndarray
    m: Any
    v: Any


def init_adamw(params: Any, cfg: AdamWConfig = AdamWConfig()) -> AdamWState:
    dt = jnp.dtype(cfg.moment_dtype)
    zeros = lambda p: jnp.zeros(p.shape, dt)        # noqa: E731
    return AdamWState(step=jnp.zeros((), jnp.int32),
                      m=jax.tree.map(zeros, params),
                      v=jax.tree.map(zeros, params))


def _schedule(cfg: AdamWConfig, step):
    warm = jnp.minimum(step.astype(jnp.float32) / max(cfg.warmup_steps, 1),
                       1.0)
    return cfg.lr * warm


def global_norm(tree: Any) -> jnp.ndarray:
    return jnp.sqrt(sum(jnp.sum(jnp.square(g.astype(jnp.float32)))
                        for g in jax.tree.leaves(tree)))


def adamw_update(cfg: AdamWConfig, params: Any, grads: Any,
                 state: AdamWState) -> Tuple[Any, AdamWState, jnp.ndarray]:
    step = state.step + 1
    gnorm = global_norm(grads)
    scale = jnp.minimum(1.0, cfg.grad_clip / (gnorm + 1e-9))
    lr = _schedule(cfg, step)
    b1c = 1.0 - cfg.b1 ** step.astype(jnp.float32)
    b2c = 1.0 - cfg.b2 ** step.astype(jnp.float32)

    def upd(p, g, m, v):
        g = g.astype(jnp.float32) * scale
        m2 = cfg.b1 * m + (1 - cfg.b1) * g
        v2 = cfg.b2 * v + (1 - cfg.b2) * g * g
        mh = m2 / b1c
        vh = v2 / b2c
        delta = lr * (mh / (jnp.sqrt(vh) + cfg.eps)
                      + cfg.weight_decay * p.astype(jnp.float32))
        return (p.astype(jnp.float32) - delta).astype(p.dtype), m2, v2

    flat_p, treedef = jax.tree.flatten(params)
    flat_g = jax.tree.leaves(grads)
    flat_m = jax.tree.leaves(state.m)
    flat_v = jax.tree.leaves(state.v)
    out = [upd(p, g, m, v) for p, g, m, v in
           zip(flat_p, flat_g, flat_m, flat_v)]
    new_p = jax.tree.unflatten(treedef, [o[0] for o in out])
    new_m = jax.tree.unflatten(treedef, [o[1] for o in out])
    new_v = jax.tree.unflatten(treedef, [o[2] for o in out])
    return new_p, AdamWState(step=step, m=new_m, v=new_v), gnorm
