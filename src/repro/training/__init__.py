from repro.training.checkpoint import load_checkpoint, save_checkpoint
from repro.training.optimizer import (AdamWConfig, AdamWState, adamw_update,
                                      init_adamw)
from repro.training.train_loop import make_train_step, train

__all__ = ["AdamWConfig", "AdamWState", "adamw_update", "init_adamw",
           "make_train_step", "train", "save_checkpoint", "load_checkpoint"]
