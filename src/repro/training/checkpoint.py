"""Minimal msgpack-free checkpointing: params/opt-state pytrees to .npz."""
from __future__ import annotations

import json
import os
from typing import Any, Tuple

import jax
import numpy as np


def _flatten(tree: Any):
    leaves, treedef = jax.tree.flatten(tree)
    return leaves, treedef


def save_checkpoint(path: str, params: Any, extra: Any = None) -> None:
    os.makedirs(os.path.dirname(path) or ".", exist_ok=True)
    leaves, treedef = jax.tree.flatten(params)
    arrays = {f"p{i}": np.asarray(x) for i, x in enumerate(leaves)}
    meta = {"treedef": str(treedef), "n": len(leaves)}
    if extra is not None:
        e_leaves, e_def = jax.tree.flatten(extra)
        for i, x in enumerate(e_leaves):
            arrays[f"e{i}"] = np.asarray(x)
        meta["extra_treedef"] = str(e_def)
        meta["extra_n"] = len(e_leaves)
    np.savez(path, __meta__=json.dumps(meta), **arrays)


def load_checkpoint(path: str, params_template: Any,
                    extra_template: Any = None) -> Tuple[Any, Any]:
    data = np.load(path if path.endswith(".npz") else path + ".npz",
                   allow_pickle=False)
    meta = json.loads(str(data["__meta__"]))
    _, treedef = jax.tree.flatten(params_template)
    leaves = [data[f"p{i}"] for i in range(meta["n"])]
    params = jax.tree.unflatten(treedef, leaves)
    extra = None
    if extra_template is not None and "extra_n" in meta:
        _, e_def = jax.tree.flatten(extra_template)
        extra = jax.tree.unflatten(
            e_def, [data[f"e{i}"] for i in range(meta["extra_n"])])
    return params, extra
