"""Training step factory + a simple host-driven loop.

``make_train_step(model, cfg)`` returns a pure function
    (params, opt_state, batch) -> (params, opt_state, metrics)
suitable for jit/pjit; the models already scan-over-layers and remat their
layer bodies, so this lowers compactly even for the 48-layer configs.
"""
from __future__ import annotations

import time
from typing import Any, Callable, Dict, Optional

import jax
import jax.numpy as jnp

from repro.training.optimizer import (AdamWConfig, AdamWState, adamw_update,
                                      init_adamw)


def make_train_step(model, opt_cfg: AdamWConfig = AdamWConfig()
                    ) -> Callable:
    def train_step(params, opt_state: AdamWState, batch: Dict[str, Any]):
        def loss_fn(p):
            if "frames" in batch:
                return model.loss(p, batch["tokens"], batch["labels"],
                                  batch["frames"])
            return model.loss(p, batch["tokens"], batch["labels"])

        loss, grads = jax.value_and_grad(loss_fn)(params)
        new_params, new_state, gnorm = adamw_update(opt_cfg, params, grads,
                                                    opt_state)
        metrics = {"loss": loss.astype(jnp.float32), "grad_norm": gnorm,
                   "step": new_state.step}
        return new_params, new_state, metrics

    return train_step


def train(model, params, data_iter, *, steps: int,
          opt_cfg: AdamWConfig = AdamWConfig(),
          log_every: int = 10,
          callback: Optional[Callable] = None):
    """Single-host training loop used by the examples."""
    opt_state = init_adamw(params, opt_cfg)
    step_fn = jax.jit(make_train_step(model, opt_cfg))
    history = []
    t0 = time.perf_counter()
    for i in range(steps):
        batch = next(data_iter)
        params, opt_state, metrics = step_fn(params, opt_state, batch)
        if i % log_every == 0 or i == steps - 1:
            m = {k: float(v) for k, v in metrics.items()}
            m["wall_s"] = time.perf_counter() - t0
            history.append(m)
            if callback:
                callback(i, m)
    return params, opt_state, history
