"""Mamba-2 (SSD) language model — attention-free, constant-size state.

[arXiv:2405.21060] State-space duality: training/prefill uses the chunked
block decomposition (quadratic intra-chunk, linear inter-chunk), decode uses
the O(1)-per-token recurrent form. The state (B, H, P, N) replaces the KV
cache, which is what makes the ``long_500k`` shape native for this family.
"""
from __future__ import annotations

from typing import Any

import jax
import jax.numpy as jnp

from repro.models import blocks
from repro.models.common import (ModelConfig, dense_init, rms_norm,
                                 scan_layers, softmax_cross_entropy,
                                 split_keys)


class MambaLM:
    def __init__(self, cfg: ModelConfig):
        assert cfg.ssm_state > 0, "ssm arch requires ssm_state"
        self.cfg = cfg

    def init(self, key) -> Any:
        cfg = self.cfg
        ks = split_keys(key, 3)
        layer_keys = jax.random.split(ks[2], cfg.num_layers)

        def one(k):
            kn, kb = jax.random.split(k)
            return {"norm": jnp.ones((cfg.d_model,), cfg.weight_dtype),
                    "mixer": blocks.init_ssd_block(kb, cfg)}

        params = {
            "embed": dense_init(ks[0], (cfg.vocab_size, cfg.d_model),
                                cfg.weight_dtype, scale=0.02),
            "final_norm": jnp.ones((cfg.d_model,), cfg.weight_dtype),
            "layers": jax.vmap(one)(layer_keys),
        }
        if not cfg.tie_embeddings:
            params["lm_head"] = dense_init(
                ks[1], (cfg.d_model, cfg.vocab_size), cfg.weight_dtype)
        return params

    def _embed(self, params, tokens):
        return jnp.take(params["embed"], tokens, axis=0).astype(
            self.cfg.activation_dtype)

    def _unembed(self, params, x):
        cfg = self.cfg
        x = rms_norm(x, params["final_norm"], cfg.norm_eps, cfg.use_pallas)
        head = (params["embed"].T if cfg.tie_embeddings
                else params["lm_head"])
        return x @ head.astype(x.dtype)

    def _run(self, params, x, *, collect_state: bool):
        cfg = self.cfg

        def body(h, lp):
            r = rms_norm(h, lp["norm"], cfg.norm_eps, cfg.use_pallas)
            y, state = blocks.ssd_block_forward(lp["mixer"], cfg, r)
            return h + y, (state if collect_state else 0)

        body_fn = jax.checkpoint(body) if cfg.remat else body
        return scan_layers(body_fn, x, params["layers"],
                           unroll=cfg.unroll_layers)

    def forward(self, params, tokens, positions=None):
        x = self._embed(params, tokens)
        x, _ = self._run(params, x, collect_state=False)
        return self._unembed(params, x), jnp.zeros((), jnp.float32)

    def loss(self, params, tokens, labels, mask=None):
        logits, _ = self.forward(params, tokens)
        return softmax_cross_entropy(logits, labels, mask)

    def prefill(self, params, tokens, max_len=None):
        x = self._embed(params, tokens)
        x, states = self._run(params, x, collect_state=True)
        return self._unembed(params, x[:, -1:]), states

    def init_cache(self, batch: int, max_len: int):
        one = blocks.init_ssd_state(self.cfg, batch)
        return jax.tree.map(lambda *xs: jnp.stack(xs),
                            *([one] * self.cfg.num_layers))

    def decode_step(self, params, token, cache, pos):
        cfg = self.cfg
        x = self._embed(params, token)

        def body(h, inp):
            lp, st = inp
            r = rms_norm(h, lp["norm"], cfg.norm_eps, cfg.use_pallas)
            y, new_st = blocks.ssd_block_forward(lp["mixer"], cfg, r,
                                                 state=st)
            return h + y, new_st

        x, new_cache = scan_layers(body, x, (params["layers"], cache),
                                   unroll=cfg.unroll_layers)
        return self._unembed(params, x), new_cache
