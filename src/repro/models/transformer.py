"""Decoder-only transformer assembly covering the dense, MoE and VLM
(early-fusion) families. Layers are stacked with a leading ``layer`` axis and
executed via ``jax.lax.scan``; heterogeneous prefixes (e.g. DeepSeek's
first-k-dense FFN layers) are unrolled separately.

Model contract (shared by every family in the zoo):
    init(key)                          -> params
    forward(params, tokens)            -> logits (B,S,V)      [training]
    prefill(params, tokens)            -> (logits, cache)
    init_cache(batch, max_len)         -> cache pytree (zeros)
    decode_step(params, token, cache, pos) -> (logits, cache)
"""
from __future__ import annotations

from typing import Any, Optional

import jax
import jax.numpy as jnp

from repro.models import attention as attn
from repro.models import blocks
from repro.models.common import (ModelConfig, dense_init, rms_norm,
                                 scan_layers, softmax_cross_entropy,
                                 split_keys)


class DecoderOnlyLM:
    """Dense / MoE / early-fusion-VLM decoder LM."""

    def __init__(self, cfg: ModelConfig):
        self.cfg = cfg
        self.n_prefix = cfg.first_k_dense if cfg.num_experts else 0
        self.n_scanned = cfg.num_layers - self.n_prefix

    # ------------------------------------------------------------------
    # init
    # ------------------------------------------------------------------
    def _init_layer(self, key, *, moe: bool):
        cfg = self.cfg
        ka, kf = jax.random.split(key)
        p = {"attn_norm": jnp.ones((cfg.d_model,), cfg.weight_dtype),
             "ffn_norm": jnp.ones((cfg.d_model,), cfg.weight_dtype)}
        if cfg.use_mla:
            p["attn"] = attn.init_mla(ka, cfg)
        else:
            p["attn"] = attn.init_attention(ka, cfg)
        if moe:
            p["moe"] = blocks.init_moe(kf, cfg)
        else:
            p["ffn"] = blocks.init_ffn(kf, cfg)
        return p

    def init(self, key) -> Any:
        cfg = self.cfg
        ks = split_keys(key, 4 + self.n_prefix)
        params = {
            "embed": dense_init(ks[0], (cfg.vocab_size, cfg.d_model),
                                cfg.weight_dtype, scale=0.02),
            "final_norm": jnp.ones((cfg.d_model,), cfg.weight_dtype),
        }
        if not cfg.tie_embeddings:
            params["lm_head"] = dense_init(
                ks[1], (cfg.d_model, cfg.vocab_size), cfg.weight_dtype)
        # unrolled prefix (dense-FFN) layers
        params["prefix"] = [
            self._init_layer(ks[3 + i], moe=False)
            for i in range(self.n_prefix)]
        # scanned homogeneous stack
        layer_keys = jax.random.split(ks[2], self.n_scanned)
        moe = bool(cfg.num_experts)
        params["layers"] = jax.vmap(
            lambda k: self._init_layer(k, moe=moe))(layer_keys)
        return params

    # ------------------------------------------------------------------
    # layer bodies
    # ------------------------------------------------------------------
    def _layer_full(self, lp, x, positions, *, moe: bool,
                    cache_len=None):
        """Full-sequence layer (train/prefill). Returns (x, cache, aux)."""
        cfg = self.cfg
        h = rms_norm(x, lp["attn_norm"], cfg.norm_eps, cfg.use_pallas)
        if cfg.use_mla:
            a, cache = attn.mla_forward(lp["attn"], cfg, h, positions,
                                        cache_len=cache_len)
        else:
            a, cache = attn.attention_forward(
                lp["attn"], cfg, h, positions, window=cfg.attention_window,
                cache_len=cache_len)
        x = x + a
        h = rms_norm(x, lp["ffn_norm"], cfg.norm_eps, cfg.use_pallas)
        if moe:
            f, aux = blocks.moe_forward(lp["moe"], cfg, h)
            aux = aux.load_balance_loss
        else:
            f = blocks.ffn_forward(lp["ffn"], cfg, h)
            aux = jnp.zeros((), jnp.float32)
        return x + f, cache, aux

    def _layer_decode(self, lp, x, cache, pos, *, moe: bool):
        cfg = self.cfg
        h = rms_norm(x, lp["attn_norm"], cfg.norm_eps, cfg.use_pallas)
        if cfg.use_mla:
            a, new_cache = attn.mla_decode(lp["attn"], cfg, h, cache, pos)
        else:
            a, new_cache = attn.attention_decode(
                lp["attn"], cfg, h, cache, pos, window=cfg.attention_window)
        x = x + a
        h = rms_norm(x, lp["ffn_norm"], cfg.norm_eps, cfg.use_pallas)
        if moe:
            f, _ = blocks.moe_forward(lp["moe"], cfg, h)
        else:
            f = blocks.ffn_forward(lp["ffn"], cfg, h)
        return x + f, new_cache

    # ------------------------------------------------------------------
    # public api
    # ------------------------------------------------------------------
    def _embed(self, params, tokens):
        cfg = self.cfg
        x = jnp.take(params["embed"], tokens, axis=0)
        return x.astype(cfg.activation_dtype)

    def _unembed(self, params, x):
        cfg = self.cfg
        x = rms_norm(x, params["final_norm"], cfg.norm_eps, cfg.use_pallas)
        head = (params["embed"].T if cfg.tie_embeddings
                else params["lm_head"])
        return x @ head.astype(x.dtype)

    def _run_stack(self, params, x, positions, *, collect_cache: bool,
                   cache_len=None):
        cfg = self.cfg
        moe = bool(cfg.num_experts)
        aux_total = jnp.zeros((), jnp.float32)
        prefix_caches = []
        for lp in params["prefix"]:
            x, c, aux = self._layer_full(lp, x, positions, moe=False,
                                         cache_len=cache_len)
            aux_total = aux_total + aux
            prefix_caches.append(c)

        def body(carry, lp):
            h, acc = carry
            h, cache, aux = self._layer_full(lp, h, positions, moe=moe,
                                             cache_len=cache_len)
            return (h, acc + aux), (cache if collect_cache else 0)

        body_fn = jax.checkpoint(body) if cfg.remat else body
        (x, aux_total), caches = scan_layers(
            body_fn, (x, aux_total), params["layers"],
            unroll=cfg.unroll_layers)
        return x, aux_total, prefix_caches, caches

    def forward(self, params, tokens, positions: Optional[jnp.ndarray] = None):
        B, S = tokens.shape
        if positions is None:
            positions = jnp.broadcast_to(jnp.arange(S)[None], (B, S))
        x = self._embed(params, tokens)
        x, aux, _, _ = self._run_stack(params, x, positions,
                                       collect_cache=False)
        return self._unembed(params, x), aux

    def loss(self, params, tokens, labels, mask=None):
        logits, aux = self.forward(params, tokens)
        return softmax_cross_entropy(logits, labels, mask) + 0.01 * aux

    def prefill(self, params, tokens, max_len=None):
        B, S = tokens.shape
        positions = jnp.broadcast_to(jnp.arange(S)[None], (B, S))
        x = self._embed(params, tokens)
        x, _, prefix_caches, caches = self._run_stack(
            params, x, positions, collect_cache=True, cache_len=max_len)
        logits = self._unembed(params, x[:, -1:])
        return logits, {"prefix": prefix_caches, "scanned": caches}

    def init_cache(self, batch: int, max_len: int):
        cfg = self.cfg
        if cfg.use_mla:
            one = lambda: attn.init_mla_cache(cfg, batch, max_len)  # noqa: E731
        else:
            one = lambda: attn.init_kv_cache(cfg, batch, max_len)  # noqa: E731
        stacked = jax.tree.map(
            lambda *xs: jnp.stack(xs),
            *([one()] * self.n_scanned)) if self.n_scanned else one()
        return {"prefix": [one() for _ in range(self.n_prefix)],
                "scanned": stacked}

    def decode_step(self, params, token, cache, pos):
        """token: (B,1) int32; pos: (B,) tokens already in cache."""
        cfg = self.cfg
        moe = bool(cfg.num_experts)
        x = self._embed(params, token)
        new_prefix = []
        for lp, c in zip(params["prefix"], cache["prefix"]):
            x, nc = self._layer_decode(lp, x, c, pos, moe=False)
            new_prefix.append(nc)

        def body(h, inp):
            lp, c = inp
            h, nc = self._layer_decode(lp, h, c, pos, moe=moe)
            return h, nc

        x, new_caches = scan_layers(
            body, x, (params["layers"], cache["scanned"]),
            unroll=cfg.unroll_layers)
        logits = self._unembed(params, x)
        return logits, {"prefix": new_prefix, "scanned": new_caches}
