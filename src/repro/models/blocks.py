"""Non-attention blocks: FFN (gated & ungated), MoE (routed + shared experts,
expert-parallel einsum dispatch), RG-LRU recurrent block (Griffin /
RecurrentGemma), Mamba-2 SSD mixer.
"""
from __future__ import annotations

from typing import NamedTuple, Optional, Tuple

import jax
import jax.numpy as jnp

from repro.models.common import (ModelConfig, dense_init, ffn_act, is_gated,
                                 rms_norm, split_keys)


# ---------------------------------------------------------------------------
# Dense FFN
# ---------------------------------------------------------------------------

def init_ffn(key, cfg: ModelConfig, d_ff: Optional[int] = None):
    d_ff = d_ff or cfg.d_ff
    dt = cfg.weight_dtype
    ks = split_keys(key, 3)
    p = {"w_in": dense_init(ks[0], (cfg.d_model, d_ff), dt),
         "w_out": dense_init(ks[1], (d_ff, cfg.d_model), dt)}
    if is_gated(cfg.ffn_activation):
        p["w_gate"] = dense_init(ks[2], (cfg.d_model, d_ff), dt)
    return p


def ffn_forward(p, cfg: ModelConfig, x: jnp.ndarray) -> jnp.ndarray:
    up = x @ p["w_in"].astype(x.dtype)
    if is_gated(cfg.ffn_activation):
        gate = x @ p["w_gate"].astype(x.dtype)
        h = ffn_act(gate, up, cfg.ffn_activation)
    else:
        h = ffn_act(up, up, cfg.ffn_activation)
    return h @ p["w_out"].astype(x.dtype)


# ---------------------------------------------------------------------------
# MoE — top-k routed experts (+ optional shared experts), einsum dispatch.
#
# Expert weights carry a leading expert axis sharded over the `model` mesh
# axis (expert parallelism); the one-hot dispatch/combine einsums lower to
# all-to-all / reduce-scatter collectives under GSPMD.
# ---------------------------------------------------------------------------

def init_moe(key, cfg: ModelConfig):
    dt = cfg.weight_dtype
    E = cfg.num_experts
    d_ff = cfg.moe_d_ff or cfg.d_ff
    ks = split_keys(key, 5)
    p = {
        "router": dense_init(ks[0], (cfg.d_model, E), dt, scale=0.02),
        "w_gate": dense_init(ks[1], (E, cfg.d_model, d_ff), dt),
        "w_in": dense_init(ks[2], (E, cfg.d_model, d_ff), dt),
        "w_out": dense_init(ks[3], (E, d_ff, cfg.d_model), dt),
    }
    if cfg.num_shared_experts:
        shared_ff = d_ff * cfg.num_shared_experts
        sub = cfg.replace(d_ff=shared_ff)
        p["shared"] = init_ffn(ks[4], sub, d_ff=shared_ff)
    return p


class MoEAux(NamedTuple):
    load_balance_loss: jnp.ndarray
    router_entropy: jnp.ndarray


def moe_forward(p, cfg: ModelConfig, x: jnp.ndarray,
                rng: Optional[jax.Array] = None) -> Tuple[jnp.ndarray, MoEAux]:
    if cfg.moe_dispatch == "capacity":
        return moe_forward_capacity(p, cfg, x, rng)
    return moe_forward_dense(p, cfg, x, rng)


def moe_forward_dense(p, cfg: ModelConfig, x: jnp.ndarray,
                      rng: Optional[jax.Array] = None
                      ) -> Tuple[jnp.ndarray, MoEAux]:
    """x: (B,S,d). Dense one-hot dispatch (Switch/Mesh-TF style): every token
    is multiplied into its top-k experts via einsum; GSPMD turns the expert
    axis contraction into expert-parallel collectives.

    BASELINE formulation: computes ALL experts for ALL tokens — FLOPs waste
    factor E/top_k (the §Perf compute-term target; see
    ``moe_forward_capacity``)."""
    B, S, D = x.shape
    E, K = cfg.num_experts, cfg.top_k
    logits = (x @ p["router"].astype(x.dtype)).astype(jnp.float32)  # (B,S,E)
    if cfg.router_jitter and rng is not None:
        logits = logits + cfg.router_jitter * jax.random.normal(
            rng, logits.shape)
    probs = jax.nn.softmax(logits, axis=-1)
    top_w, top_idx = jax.lax.top_k(probs, K)               # (B,S,K)
    top_w = top_w / jnp.clip(jnp.sum(top_w, -1, keepdims=True), 1e-9)
    # combine weights (B,S,E): sum over k of w_k * onehot(idx_k)
    onehot = jax.nn.one_hot(top_idx, E, dtype=jnp.float32)  # (B,S,K,E)
    combine = jnp.einsum("bske,bsk->bse", onehot, top_w)    # (B,S,E)
    xe = x.astype(jnp.float32)
    # dispatch: (B,S,E,D) implicit — contract directly to keep memory bounded:
    # h_e = act(x @ Wg_e) * (x @ Wi_e); y = sum_e combine_e * (h_e @ Wo_e)
    gate = jnp.einsum("bsd,edf->bsef", xe, p["w_gate"].astype(jnp.float32))
    up = jnp.einsum("bsd,edf->bsef", xe, p["w_in"].astype(jnp.float32))
    h = ffn_act(gate, up, "swiglu")
    h = h * combine[..., None]                              # mask non-selected
    y = jnp.einsum("bsef,efd->bsd", h, p["w_out"].astype(jnp.float32))
    y = y.astype(x.dtype)
    if "shared" in p:
        shared_ff = (cfg.moe_d_ff or cfg.d_ff) * cfg.num_shared_experts
        y = y + ffn_forward(p["shared"], cfg.replace(ffn_activation="swiglu"),
                            x)
    # Switch-style load-balance loss: E * sum_e f_e * P_e
    f = jnp.mean(combine > 0, axis=(0, 1))                  # fraction routed
    pmean = jnp.mean(probs, axis=(0, 1))
    lb = E * jnp.sum(f * pmean)
    ent = -jnp.mean(jnp.sum(probs * jnp.log(probs + 1e-9), -1))
    return y, MoEAux(load_balance_loss=lb, router_entropy=ent)


def moe_forward_capacity(p, cfg: ModelConfig, x: jnp.ndarray,
                         rng: Optional[jax.Array] = None
                         ) -> Tuple[jnp.ndarray, MoEAux]:
    """Capacity-based scatter/gather dispatch (§Perf optimization): tokens
    are routed into per-expert buffers of capacity
    C = ceil(tokens*top_k/E * capacity_factor); expert FFNs run on (E, C, d)
    so FFN FLOPs scale with routed tokens (~top_k*cap), not tokens*E —
    a ~E/(top_k*cap) compute-term reduction (llama4-scout: ~12.8x).
    Overflowing tokens are dropped (standard Switch semantics; the residual
    stream and shared experts still serve them)."""
    B, S, D = x.shape
    E, K = cfg.num_experts, cfg.top_k
    N = B * S
    logits = (x @ p["router"].astype(x.dtype)).astype(jnp.float32)
    if cfg.router_jitter and rng is not None:
        logits = logits + cfg.router_jitter * jax.random.normal(
            rng, logits.shape)
    probs = jax.nn.softmax(logits, axis=-1)                # (B,S,E)
    top_w, top_idx = jax.lax.top_k(probs, K)
    top_w = top_w / jnp.clip(jnp.sum(top_w, -1, keepdims=True), 1e-9)

    xf = x.reshape(N, D)
    e_flat = top_idx.reshape(N * K)                        # expert per slot
    w_flat = top_w.reshape(N * K)
    tok_ids = jnp.arange(N * K) // K
    C = max(int(-(-N * K // E) * cfg.capacity_factor), 1)
    # arrival-order rank of each assignment within its expert
    onehot = jax.nn.one_hot(e_flat, E, dtype=jnp.int32)    # (NK, E)
    rank = jnp.take_along_axis(jnp.cumsum(onehot, axis=0),
                               e_flat[:, None], axis=1)[:, 0] - 1
    keep = rank < C
    slot = jnp.where(keep, e_flat * C + rank, E * C)       # E*C = drop slot
    buf = jnp.zeros((E * C + 1, D), xf.dtype)
    buf = buf.at[slot].set(jnp.where(keep[:, None],
                                     xf[tok_ids], 0), mode="drop")
    xe = buf[:E * C].reshape(E, C, D).astype(jnp.float32)
    if cfg.moe_ep_constraint:
        # expert axis -> model (EP); capacity axis -> data. Without the
        # capacity sharding each data shard recomputes every expert's full
        # global buffer and the dispatch LOSES to dense (+25%, measured);
        # with it, per-device FFN work drops to routed-tokens/devices.
        from jax.sharding import PartitionSpec as _P
        xe = jax.lax.with_sharding_constraint(
            xe, _P("model", "data", None))
    gate = jnp.einsum("ecd,edf->ecf", xe,
                      p["w_gate"].astype(jnp.float32))
    up = jnp.einsum("ecd,edf->ecf", xe, p["w_in"].astype(jnp.float32))
    h = ffn_act(gate, up, "swiglu")
    ye = jnp.einsum("ecf,efd->ecd", h, p["w_out"].astype(jnp.float32))
    ye_flat = ye.reshape(E * C, D)
    contrib = jnp.where(keep[:, None], ye_flat[jnp.minimum(slot, E * C - 1)]
                        * w_flat[:, None], 0.0)
    y = jnp.zeros((N, D), jnp.float32).at[tok_ids].add(contrib)
    y = y.reshape(B, S, D).astype(x.dtype)
    if "shared" in p:
        y = y + ffn_forward(p["shared"], cfg.replace(ffn_activation="swiglu"),
                            x)
    # fraction of tokens routed to each expert (matches the dense path)
    f = jnp.mean(jax.nn.one_hot(top_idx, E, dtype=jnp.float32),
                 axis=(0, 1, 2)) * K
    pmean = jnp.mean(probs, axis=(0, 1))
    lb = E * jnp.sum(f * pmean)
    ent = -jnp.mean(jnp.sum(probs * jnp.log(probs + 1e-9), -1))
    return y, MoEAux(load_balance_loss=lb, router_entropy=ent)


# ---------------------------------------------------------------------------
# RG-LRU (Real-Gated Linear Recurrent Unit) — RecurrentGemma / Griffin
# ---------------------------------------------------------------------------

class RGLRUState(NamedTuple):
    h: jnp.ndarray          # (B, lru_width) recurrent state
    conv: jnp.ndarray       # (B, k-1, lru_width) conv tail


_LRU_C = 8.0  # Griffin's c constant


def init_rglru_block(key, cfg: ModelConfig):
    dt = cfg.weight_dtype
    W = cfg.lru_width
    ks = split_keys(key, 7)
    # linear-in (x branch + gate branch), temporal conv, rg-lru params, out
    return {
        "w_x": dense_init(ks[0], (cfg.d_model, W), dt),
        "w_y": dense_init(ks[1], (cfg.d_model, W), dt),    # multiplicative branch
        "conv_w": dense_init(ks[2], (cfg.conv_kernel, W), dt, scale=0.5),
        "lambda_param": jax.random.uniform(ks[3], (W,), jnp.float32,
                                           0.9, 0.999).astype(jnp.float32),
        "w_input_gate": dense_init(ks[4], (W, W), dt, scale=0.02),
        "w_rec_gate": dense_init(ks[5], (W, W), dt, scale=0.02),
        "w_out": dense_init(ks[6], (W, cfg.d_model), dt),
    }


def _lru_log_a(p, gate_r):
    """log recurrence coefficient: c * softplus(Lambda) * sigmoid(r)."""
    softp = jax.nn.softplus(p["lambda_param"])             # (W,)
    return -_LRU_C * softp * gate_r                        # (..., W)


def rglru_scan(x: jnp.ndarray, log_a: jnp.ndarray, h0: jnp.ndarray,
               use_pallas: bool = False):
    """Linear recurrence h_t = a_t h_{t-1} + sqrt(1-a_t^2) x_t over seq axis.

    x, log_a: (B,S,W) fp32; h0: (B,W). Returns (ys (B,S,W), h_last)."""
    if use_pallas:
        from repro.kernels import ops as kops
        return kops.rglru_scan(x, log_a, h0)
    a = jnp.exp(log_a)
    gated = jnp.sqrt(jnp.clip(1.0 - a * a, 1e-9, 1.0)) * x

    def assoc(c1, c2):
        a1, b1 = c1
        a2, b2 = c2
        return a1 * a2, b2 + a2 * b1

    a_s, b_s = jax.lax.associative_scan(
        assoc, (jnp.moveaxis(a, 1, 0), jnp.moveaxis(gated, 1, 0)), axis=0)
    ys = jnp.moveaxis(b_s + a_s * h0[None], 0, 1)
    return ys, ys[:, -1]


def _causal_conv(x: jnp.ndarray, w: jnp.ndarray,
                 tail: Optional[jnp.ndarray] = None):
    """Depthwise causal conv along seq. x (B,S,W), w (k,W), tail (B,k-1,W).
    Returns (out (B,S,W), new_tail (B,k-1,W))."""
    k = w.shape[0]
    if tail is None:
        tail = jnp.zeros((x.shape[0], k - 1, x.shape[-1]), x.dtype)
    xp = jnp.concatenate([tail, x], axis=1)                # (B,S+k-1,W)
    out = sum(xp[:, i:i + x.shape[1]] * w[i][None, None]
              for i in range(k))
    new_tail = xp[:, -(k - 1):] if k > 1 else tail
    return out, new_tail


def rglru_block_forward(p, cfg: ModelConfig, x: jnp.ndarray,
                        state: Optional[RGLRUState] = None
                        ) -> Tuple[jnp.ndarray, RGLRUState]:
    """Full Griffin recurrent block: in-proj -> conv -> RG-LRU -> gate -> out.
    x: (B,S,d_model). Works for S==1 (decode) given a state."""
    B, S, _ = x.shape
    W = cfg.lru_width
    xb = x @ p["w_x"].astype(x.dtype)                      # (B,S,W)
    yb = jax.nn.gelu((x @ p["w_y"].astype(x.dtype)).astype(jnp.float32))
    tail = state.conv if state is not None else None
    xc, new_tail = _causal_conv(xb, p["conv_w"].astype(xb.dtype), tail)
    xc32 = xc.astype(jnp.float32)
    gate_i = jax.nn.sigmoid(xc32 @ p["w_input_gate"].astype(jnp.float32))
    gate_r = jax.nn.sigmoid(xc32 @ p["w_rec_gate"].astype(jnp.float32))
    log_a = _lru_log_a(p, gate_r)                          # (B,S,W)
    gated_x = gate_i * xc32
    h0 = state.h if state is not None else jnp.zeros((B, W), jnp.float32)
    if S == 1:
        a = jnp.exp(log_a[:, 0])
        h = a * h0 + jnp.sqrt(jnp.clip(1 - a * a, 1e-9, 1)) * gated_x[:, 0]
        ys = h[:, None]
        h_last = h
    else:
        ys, h_last = rglru_scan(gated_x, log_a, h0,
                                use_pallas=cfg.use_pallas)
    out = (ys * yb).astype(x.dtype)                        # multiplicative gate
    y = out @ p["w_out"].astype(x.dtype)
    return y, RGLRUState(h=h_last, conv=new_tail)


def init_rglru_state(cfg: ModelConfig, batch: int) -> RGLRUState:
    return RGLRUState(
        h=jnp.zeros((batch, cfg.lru_width), jnp.float32),
        conv=jnp.zeros((batch, cfg.conv_kernel - 1, cfg.lru_width),
                       cfg.activation_dtype))


# ---------------------------------------------------------------------------
# Mamba-2 SSD (state-space duality) mixer
# ---------------------------------------------------------------------------

class SSDState(NamedTuple):
    ssm: jnp.ndarray        # (B, H, P, N) recurrent state
    conv: jnp.ndarray       # (B, k-1, conv_dim) conv tail


def init_ssd_block(key, cfg: ModelConfig):
    dt = cfg.weight_dtype
    d_in = cfg.d_inner
    H = cfg.ssm_nheads
    N = cfg.ssm_state
    G = cfg.ssm_ngroups
    conv_dim = d_in + 2 * G * N
    ks = split_keys(key, 5)
    return {
        # fused in-proj: [z (d_in), x (d_in), B (G*N), C (G*N), dt (H)]
        "w_in": dense_init(ks[0], (cfg.d_model,
                                   2 * d_in + 2 * G * N + H), dt),
        "conv_w": dense_init(ks[1], (cfg.conv_kernel, conv_dim), dt,
                             scale=0.5),
        "A_log": jnp.log(jnp.linspace(1.0, 16.0, H)).astype(jnp.float32),
        "D": jnp.ones((H,), jnp.float32),
        "dt_bias": jnp.zeros((H,), jnp.float32),
        "norm_w": jnp.ones((d_in,), dt),
        "w_out": dense_init(ks[2], (d_in, cfg.d_model), dt),
    }


def _ssd_split(p, cfg: ModelConfig, u: jnp.ndarray):
    d_in = cfg.d_inner
    G, N, H = cfg.ssm_ngroups, cfg.ssm_state, cfg.ssm_nheads
    zxbcdt = u @ p["w_in"].astype(u.dtype)
    z, xBC, dt = jnp.split(zxbcdt, [d_in, 2 * d_in + 2 * G * N], axis=-1)
    return z, xBC, dt


def ssd_chunked(x, dt, A, B, C, chunk: int, use_pallas: bool = False):
    """Chunked SSD algorithm (Mamba-2 §6): intra-chunk dual (attention-like)
    form + inter-chunk recurrence on states.

    x: (b, s, h, p); dt: (b, s, h); A: (h,); B, C: (b, s, g, n).
    Returns (y (b,s,h,p), final_state (b,h,p,n)). All fp32.
    """
    if use_pallas:
        from repro.kernels import ops as kops
        return kops.ssd_scan(x, dt, A, B, C, chunk=chunk)
    b, s, h, p = x.shape
    g, n = B.shape[2], B.shape[3]
    s_orig = s
    if s % chunk:
        # pad with dt=0 positions: decay exp(0)=1, zero state/output
        # contribution, so padding is an exact no-op.
        pad = chunk - s % chunk
        x = jnp.pad(x, ((0, 0), (0, pad), (0, 0), (0, 0)))
        dt = jnp.pad(dt, ((0, 0), (0, pad), (0, 0)))
        B = jnp.pad(B, ((0, 0), (0, pad), (0, 0), (0, 0)))
        C = jnp.pad(C, ((0, 0), (0, pad), (0, 0), (0, 0)))
        s = s + pad
    nc = s // chunk
    rep = h // g
    xr = x.reshape(b, nc, chunk, h, p)
    dtr = dt.reshape(b, nc, chunk, h)
    Br = jnp.repeat(B.reshape(b, nc, chunk, g, n), rep, axis=3)
    Cr = jnp.repeat(C.reshape(b, nc, chunk, g, n), rep, axis=3)
    dA = dtr * A[None, None, None]                          # decay rate > 0
    # cumulative log-decay within chunk
    seg = jnp.cumsum(dA, axis=2)                            # (b,nc,c,h)
    # intra-chunk: y_ij = C_i . B_j * exp(seg_i - seg_j) * dt_j  (j<=i)
    li = seg[:, :, :, None]                                 # i axis
    lj = seg[:, :, None, :]                                 # j axis
    mask = jnp.tril(jnp.ones((chunk, chunk), bool))[None, None, :, :, None]
    # mask the exponent BEFORE exp: exp(+big) on masked entries would give
    # inf whose cotangent is NaN even under where().
    delta = jnp.where(mask, li - lj, 0.0)
    decay = jnp.where(mask, jnp.exp(-delta), 0.0)           # (b,nc,c,c,h)
    cb = jnp.einsum("bkihn,bkjhn->bkijh", Cr, Br)
    att = cb * decay * dtr[:, :, None]                      # weight by dt_j
    y_intra = jnp.einsum("bkijh,bkjhp->bkihp", att, xr)
    # chunk states: S_k = sum_j exp(seg_last - seg_j) dt_j B_j x_j^T
    last = seg[:, :, -1:, :]                                # (b,nc,1,h)
    w = jnp.exp(-(last - seg)) * dtr                        # (b,nc,c,h)
    states = jnp.einsum("bkjh,bkjhn,bkjhp->bkhpn", w, Br, xr)
    # inter-chunk recurrence over k: S'_k = exp(-sum dA_k) S'_{k-1} + S_k
    chunk_decay = jnp.exp(-jnp.sum(dA, axis=2))             # (b,nc,h)

    def scan_fn(carry, inp):
        s_k, d_k = inp
        new = carry * d_k[:, :, None, None] + s_k
        return new, carry                                    # emit state *before* chunk

    init = jnp.zeros((b, h, p, n), jnp.float32)
    final, prev_states = jax.lax.scan(
        scan_fn, init,
        (jnp.moveaxis(states, 1, 0), jnp.moveaxis(chunk_decay, 1, 0)))
    prev_states = jnp.moveaxis(prev_states, 0, 1)           # (b,nc,h,p,n)
    # contribution of the incoming state to each position in the chunk:
    # y_i += exp(-seg_i) * C_i . S_prev
    y_inter = jnp.einsum("bkihn,bkhpn,bkih->bkihp", Cr, prev_states,
                         jnp.exp(-seg))
    y = (y_intra + y_inter).reshape(b, s, h, p)
    if s != s_orig:
        y = y[:, :s_orig]
    return y, final


def ssd_block_forward(p, cfg: ModelConfig, u: jnp.ndarray,
                      state: Optional[SSDState] = None
                      ) -> Tuple[jnp.ndarray, SSDState]:
    """Full Mamba-2 block. u: (B,S,d_model). S==1 -> recurrent decode."""
    Bsz, S, _ = u.shape
    d_in = cfg.d_inner
    G, N, H, P = cfg.ssm_ngroups, cfg.ssm_state, cfg.ssm_nheads, cfg.ssm_head_dim
    z, xBC, dt = _ssd_split(p, cfg, u)
    tail = state.conv if state is not None else None
    xBC, new_tail = _causal_conv(xBC, p["conv_w"].astype(xBC.dtype), tail)
    xBC = jax.nn.silu(xBC.astype(jnp.float32))
    x, Bmat, Cmat = jnp.split(xBC, [d_in, d_in + G * N], axis=-1)
    x = x.reshape(Bsz, S, H, P)
    Bmat = Bmat.reshape(Bsz, S, G, N)
    Cmat = Cmat.reshape(Bsz, S, G, N)
    dt = jax.nn.softplus(dt.astype(jnp.float32) + p["dt_bias"])  # (B,S,H)
    A = jnp.exp(p["A_log"])                                  # (H,) > 0
    if S == 1 and state is not None:
        # recurrent step: S' = exp(-dt*A) S + dt * B x^T ; y = C.S' + D x
        dA = jnp.exp(-dt[:, 0, :, None, None] * A[None, :, None, None])
        rep = H // G
        Bs = jnp.repeat(Bmat[:, 0], rep, axis=1)             # (B,H,N)
        Cs = jnp.repeat(Cmat[:, 0], rep, axis=1)
        upd = dt[:, 0, :, None, None] * jnp.einsum(
            "bhn,bhp->bhpn", Bs, x[:, 0])
        new_state = dA * state.ssm + upd
        y = jnp.einsum("bhn,bhpn->bhp", Cs, new_state)
        y = y + p["D"][None, :, None] * x[:, 0]
        y = y[:, None]                                       # (B,1,H,P)
        final = new_state
    else:
        y, final = ssd_chunked(x, dt, A, Bmat, Cmat, cfg.ssm_chunk,
                               use_pallas=cfg.use_pallas)
        y = y + p["D"][None, None, :, None] * x
        if state is not None:
            # fold initial state's contribution (prefill-with-state rare; keep
            # zero-state contract for prefill)
            pass
    y = y.reshape(Bsz, S, d_in)
    # gated RMSNorm (mamba2 style): norm(y * silu(z))
    y = y * jax.nn.silu(z.astype(jnp.float32))
    y = rms_norm(y.astype(u.dtype), p["norm_w"], cfg.norm_eps)
    out = y @ p["w_out"].astype(u.dtype)
    return out, SSDState(ssm=final, conv=new_tail)


def init_ssd_state(cfg: ModelConfig, batch: int) -> SSDState:
    conv_dim = cfg.d_inner + 2 * cfg.ssm_ngroups * cfg.ssm_state
    return SSDState(
        ssm=jnp.zeros((batch, cfg.ssm_nheads, cfg.ssm_head_dim,
                       cfg.ssm_state), jnp.float32),
        conv=jnp.zeros((batch, cfg.conv_kernel - 1, conv_dim),
                       cfg.activation_dtype))
