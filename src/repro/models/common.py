"""Shared model-definition machinery: config dataclass, norms, rope, inits.

All models are pure-JAX pytree-param modules (no flax): ``init_*`` functions
build nested dicts of arrays, ``apply``-style functions consume them. Layer
stacks are stored with a leading ``layer`` axis and executed with
``jax.lax.scan`` so the traced graph (and XLA compile time) stays small even
for 48-layer multi-billion-parameter configs.
"""
from __future__ import annotations

import dataclasses
from typing import Any, Optional, Tuple

import jax
import jax.numpy as jnp

Params = Any  # nested dict pytree of jnp arrays


# ---------------------------------------------------------------------------
# Config
# ---------------------------------------------------------------------------

@dataclasses.dataclass(frozen=True)
class ModelConfig:
    """Architecture hyper-parameters for every supported family."""

    name: str = "model"
    arch_type: str = "dense"  # dense | moe | ssm | hybrid | encdec | vlm
    num_layers: int = 2
    d_model: int = 256
    num_heads: int = 4
    num_kv_heads: int = 4
    head_dim: int = 64
    d_ff: int = 512
    vocab_size: int = 1024

    # ffn / norm flavour
    ffn_activation: str = "swiglu"  # swiglu | squared_relu | gelu
    use_qk_norm: bool = False       # chameleon-style qk layernorm
    norm_eps: float = 1e-6

    # positional encoding
    use_rope: bool = True
    rope_theta: float = 10000.0

    # attention variants
    attention_window: int = 0       # 0 = full attention; >0 = sliding window

    # MoE
    num_experts: int = 0
    num_shared_experts: int = 0
    top_k: int = 1
    moe_d_ff: int = 0               # per-expert hidden dim (deepseek style)
    first_k_dense: int = 0          # leading dense layers (deepseek)
    router_jitter: float = 0.0

    # MLA (deepseek)
    use_mla: bool = False
    kv_lora_rank: int = 0
    qk_nope_head_dim: int = 128
    qk_rope_head_dim: int = 64
    v_head_dim: int = 128

    # SSM (mamba2 / SSD)
    ssm_state: int = 0
    ssm_head_dim: int = 64
    ssm_expand: int = 2
    ssm_chunk: int = 64
    ssm_ngroups: int = 1
    conv_kernel: int = 4

    # hybrid (recurrentgemma / griffin)
    block_pattern: Tuple[str, ...] = ()   # cycled over layers, e.g. ("rec","rec","attn")
    lru_width: int = 0
    local_window: int = 0

    # encoder-decoder (whisper)
    is_encoder_decoder: bool = False
    encoder_layers: int = 0
    encoder_seq: int = 1500          # whisper frame count after conv frontend

    # modality frontend stub (vlm/audio): if set, inputs may be embeddings
    frontend_stub: str = ""          # "" | "audio_frames" | "vq_image_tokens"

    # numerics
    dtype: str = "bfloat16"
    param_dtype: str = "bfloat16"
    tie_embeddings: bool = False

    # execution
    use_pallas: bool = False         # True: Pallas kernels (TPU / interpret)
    remat: bool = True               # checkpoint layer bodies in training
    # KV-cache write mechanism for decode: "onehot" (paper-era baseline,
    # reads+writes the whole cache each step) or "scatter"
    # (dynamic_update_slice, O(1) traffic — the optimized default; see
    # EXPERIMENTS.md §Perf for the before/after).
    kv_update: str = "onehot"
    # Full-sequence attention reference path: "naive" materializes the SxS
    # score matrix (baseline; what the Pallas kernel replaces on TPU);
    # "chunked" streams KV blocks with a running softmax (flash-style jnp) —
    # §Perf iteration 1, bounded temps for 32k prefill.
    ref_attention: str = "naive"
    # MoE dispatch: "dense" (einsum over ALL experts — baseline, E/top_k
    # FLOPs waste) or "capacity" (scatter/gather per-expert buffers — §Perf
    # compute-term optimization).
    moe_dispatch: str = "dense"
    capacity_factor: float = 1.25
    # apply an explicit expert-parallel sharding constraint to the capacity
    # dispatch buffers (GSPMD cannot propagate sharding through the
    # data-dependent scatter; requires an active mesh context)
    moe_ep_constraint: bool = False
    # Unroll layer stacks instead of lax.scan. Used by the roofline cost
    # extrapolation: XLA cost_analysis counts a scan body ONCE regardless of
    # trip count, so exact per-layer FLOPs/bytes come from compiling small
    # unrolled variants (see launch/dryrun.py --cost-extrapolate).
    unroll_layers: bool = False

    # provenance
    source: str = ""                 # citation per assignment

    # ------------------------------------------------------------------
    @property
    def activation_dtype(self) -> jnp.dtype:
        return jnp.dtype(self.dtype)

    @property
    def weight_dtype(self) -> jnp.dtype:
        return jnp.dtype(self.param_dtype)

    @property
    def q_per_kv(self) -> int:
        return max(1, self.num_heads // max(1, self.num_kv_heads))

    @property
    def d_inner(self) -> int:
        return self.ssm_expand * self.d_model

    @property
    def ssm_nheads(self) -> int:
        return self.d_inner // self.ssm_head_dim

    def replace(self, **kw) -> "ModelConfig":
        return dataclasses.replace(self, **kw)

    def reduced(self) -> "ModelConfig":
        """Smoke-test variant: same family, tiny dims (spec: <=2 layers,
        d_model<=512, <=4 experts)."""
        kw = dict(
            num_layers=2,
            d_model=min(self.d_model, 256),
            num_heads=4,
            num_kv_heads=min(4, max(1, self.num_kv_heads)),
            head_dim=64,
            d_ff=min(self.d_ff, 512),
            vocab_size=min(self.vocab_size, 512),
            dtype="float32",
            param_dtype="float32",
        )
        if self.num_experts:
            kw.update(num_experts=4, top_k=min(self.top_k, 2),
                      num_shared_experts=min(self.num_shared_experts, 1),
                      moe_d_ff=min(self.moe_d_ff or self.d_ff, 256),
                      first_k_dense=min(self.first_k_dense, 1))
        if self.use_mla:
            kw.update(kv_lora_rank=64, qk_nope_head_dim=32,
                      qk_rope_head_dim=16, v_head_dim=32)
        if self.arch_type == "ssm":
            kw.update(ssm_state=32, ssm_head_dim=32, ssm_chunk=16)
        if self.arch_type == "hybrid":
            kw.update(lru_width=256, local_window=32, num_layers=3)
        if self.is_encoder_decoder:
            kw.update(encoder_layers=2, encoder_seq=16)
        if self.attention_window:
            kw.update(attention_window=32)
        return self.replace(**kw)


# ---------------------------------------------------------------------------
# Initializers
# ---------------------------------------------------------------------------

def dense_init(key, shape, dtype, scale: Optional[float] = None):
    """Truncated-normal fan-in init (LeCun-ish), matching llama-family."""
    fan_in = shape[-2] if len(shape) >= 2 else shape[-1]
    std = scale if scale is not None else fan_in ** -0.5
    return (jax.random.truncated_normal(key, -2.0, 2.0, shape, jnp.float32)
            * std).astype(dtype)


def split_keys(key, n):
    return list(jax.random.split(key, n))


# ---------------------------------------------------------------------------
# Norms / activations
# ---------------------------------------------------------------------------

def rms_norm(x: jnp.ndarray, weight: jnp.ndarray, eps: float = 1e-6,
             use_pallas: bool = False) -> jnp.ndarray:
    if use_pallas:
        from repro.kernels import ops as kops
        return kops.rmsnorm(x, weight, eps=eps)
    dtype = x.dtype
    xf = x.astype(jnp.float32)
    var = jnp.mean(jnp.square(xf), axis=-1, keepdims=True)
    out = xf * jax.lax.rsqrt(var + eps)
    return (out * weight.astype(jnp.float32)).astype(dtype)


def layer_norm(x, weight, bias, eps=1e-5):
    dtype = x.dtype
    xf = x.astype(jnp.float32)
    mu = jnp.mean(xf, axis=-1, keepdims=True)
    var = jnp.var(xf, axis=-1, keepdims=True)
    out = (xf - mu) * jax.lax.rsqrt(var + eps)
    out = out * weight.astype(jnp.float32) + bias.astype(jnp.float32)
    return out.astype(dtype)


def ffn_act(x_gate, x_up, kind: str):
    """Combine gate/up projections per the configured activation."""
    if kind == "swiglu":
        return jax.nn.silu(x_gate) * x_up
    if kind == "squared_relu":            # nemotron-4
        r = jax.nn.relu(x_gate)
        return r * r
    if kind == "gelu":                    # whisper / starcoder-style
        return jax.nn.gelu(x_gate, approximate=True)
    if kind == "geglu":                   # recurrentgemma MLP
        return jax.nn.gelu(x_gate, approximate=True) * x_up
    raise ValueError(f"unknown ffn activation {kind!r}")


def is_gated(kind: str) -> bool:
    return kind in ("swiglu", "geglu")


# ---------------------------------------------------------------------------
# RoPE
# ---------------------------------------------------------------------------

def rope_frequencies(head_dim: int, theta: float) -> jnp.ndarray:
    exponents = jnp.arange(0, head_dim, 2, dtype=jnp.float32) / head_dim
    return 1.0 / (theta ** exponents)                     # (head_dim//2,)


def apply_rope(x: jnp.ndarray, positions: jnp.ndarray,
               theta: float) -> jnp.ndarray:
    """x: (..., seq, heads, head_dim); positions: broadcastable to (..., seq)."""
    head_dim = x.shape[-1]
    freqs = rope_frequencies(head_dim, theta)             # (hd/2,)
    angles = positions[..., :, None].astype(jnp.float32) * freqs  # (..., seq, hd/2)
    sin = jnp.sin(angles)[..., :, None, :]                # (..., seq, 1, hd/2)
    cos = jnp.cos(angles)[..., :, None, :]
    x1, x2 = jnp.split(x.astype(jnp.float32), 2, axis=-1)
    out = jnp.concatenate([x1 * cos - x2 * sin, x1 * sin + x2 * cos], axis=-1)
    return out.astype(x.dtype)


def sinusoidal_positions(length: int, dim: int) -> jnp.ndarray:
    """Whisper-style fixed sinusoidal embeddings (length, dim)."""
    log_timescale = jnp.log(10000.0) / (dim // 2 - 1)
    inv = jnp.exp(-log_timescale * jnp.arange(dim // 2, dtype=jnp.float32))
    t = jnp.arange(length, dtype=jnp.float32)[:, None] * inv[None, :]
    return jnp.concatenate([jnp.sin(t), jnp.cos(t)], axis=-1)


# ---------------------------------------------------------------------------
# Losses
# ---------------------------------------------------------------------------

def softmax_cross_entropy(logits: jnp.ndarray, labels: jnp.ndarray,
                          mask: Optional[jnp.ndarray] = None) -> jnp.ndarray:
    """Mean next-token loss. logits (B,S,V) fp-any, labels (B,S) int32."""
    logits = logits.astype(jnp.float32)
    lse = jax.nn.logsumexp(logits, axis=-1)
    ll = jnp.take_along_axis(logits, labels[..., None], axis=-1)[..., 0]
    nll = lse - ll
    if mask is not None:
        nll = nll * mask
        return jnp.sum(nll) / jnp.maximum(jnp.sum(mask), 1.0)
    return jnp.mean(nll)


def remat_wrap(fn, enabled: bool):
    return jax.checkpoint(fn) if enabled else fn


def scan_layers(body, carry, stacked_xs, *, unroll: bool):
    """lax.scan over stacked layer params/caches, or a python unroll when
    ``unroll`` (exact XLA cost accounting — scan bodies are costed once).

    body(carry, x) -> (carry, y); ys are re-stacked on unroll so both paths
    return identical pytrees."""
    if not unroll:
        return jax.lax.scan(body, carry, stacked_xs)
    length = jax.tree.leaves(stacked_xs)[0].shape[0]
    ys = []
    for i in range(length):
        x = jax.tree.map(lambda a: a[i], stacked_xs)
        carry, y = body(carry, x)
        ys.append(y)
    if ys and jax.tree.leaves(ys[0]):
        ys_stacked = jax.tree.map(lambda *zs: jnp.stack(zs), *ys)
    else:
        ys_stacked = ys[0] if ys else None
    return carry, ys_stacked
