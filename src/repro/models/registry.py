"""Model factory: ModelConfig -> model object with the uniform contract."""
from __future__ import annotations

from repro.models.common import ModelConfig


def build_model(cfg: ModelConfig):
    if cfg.arch_type == "ssm":
        from repro.models.ssm import MambaLM
        return MambaLM(cfg)
    if cfg.arch_type == "hybrid":
        from repro.models.hybrid import HybridLM
        return HybridLM(cfg)
    if cfg.arch_type in ("encdec", "audio"):
        from repro.models.encdec import EncDecLM
        return EncDecLM(cfg)
    # dense / moe / vlm all share the decoder-only assembly
    from repro.models.transformer import DecoderOnlyLM
    return DecoderOnlyLM(cfg)
