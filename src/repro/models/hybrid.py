"""RecurrentGemma / Griffin hybrid: RG-LRU recurrent blocks + local (MQA,
windowed) attention at a 1:2 ratio. [arXiv:2402.19427]

Layer layout: units of (rec, rec, attn) are scanned; a trailing remainder
(38 = 12*3 + 2 -> two recurrent layers) is unrolled. Every layer is a
residual pair (temporal mixer, GeGLU MLP) with pre-RMSNorm.

Bounded state (LRU state + fixed attention window) => long_500k is native
sub-quadratic for this family.
"""
from __future__ import annotations

from typing import Any

import jax
import jax.numpy as jnp

from repro.models import attention as attn
from repro.models import blocks
from repro.models.common import (ModelConfig, dense_init, rms_norm,
                                 scan_layers, softmax_cross_entropy,
                                 split_keys)


class HybridLM:
    def __init__(self, cfg: ModelConfig):
        assert cfg.lru_width and cfg.local_window
        self.cfg = cfg
        pat = cfg.block_pattern or ("rec", "rec", "attn")
        self.pattern = pat
        self.n_units = cfg.num_layers // len(pat)
        self.n_tail = cfg.num_layers - self.n_units * len(pat)

    # ------------------------------------------------------------------
    def _init_mixer(self, key, kind: str):
        cfg = self.cfg
        if kind == "rec":
            return blocks.init_rglru_block(key, cfg)
        return attn.init_attention(key, cfg, num_kv=cfg.num_kv_heads)

    def _init_layer(self, key, kind: str):
        cfg = self.cfg
        km, kf = jax.random.split(key)
        return {"temporal_norm": jnp.ones((cfg.d_model,), cfg.weight_dtype),
                "mlp_norm": jnp.ones((cfg.d_model,), cfg.weight_dtype),
                "mixer": self._init_mixer(km, kind),
                "mlp": blocks.init_ffn(kf, cfg)}

    def _init_unit(self, key):
        ks = split_keys(key, len(self.pattern))
        return {f"l{i}": self._init_layer(ks[i], kind)
                for i, kind in enumerate(self.pattern)}

    def init(self, key) -> Any:
        cfg = self.cfg
        ks = split_keys(key, 4 + self.n_tail)
        unit_keys = jax.random.split(ks[2], self.n_units)
        params = {
            "embed": dense_init(ks[0], (cfg.vocab_size, cfg.d_model),
                                cfg.weight_dtype, scale=0.02),
            "final_norm": jnp.ones((cfg.d_model,), cfg.weight_dtype),
            "units": jax.vmap(self._init_unit)(unit_keys),
            "tail": [self._init_layer(ks[4 + i], "rec")
                     for i in range(self.n_tail)],
        }
        if not cfg.tie_embeddings:
            params["lm_head"] = dense_init(
                ks[1], (cfg.d_model, cfg.vocab_size), cfg.weight_dtype)
        return params

    # ------------------------------------------------------------------
    def _layer_full(self, lp, kind, x, positions, *, collect_cache):
        cfg = self.cfg
        h = rms_norm(x, lp["temporal_norm"], cfg.norm_eps, cfg.use_pallas)
        if kind == "attn":
            y, cache = attn.attention_forward(
                lp["mixer"], cfg, h, positions, window=cfg.local_window)
        else:
            y, cache = blocks.rglru_block_forward(lp["mixer"], cfg, h)
        x = x + y
        h = rms_norm(x, lp["mlp_norm"], cfg.norm_eps, cfg.use_pallas)
        x = x + blocks.ffn_forward(lp["mlp"], cfg, h)
        return x, (cache if collect_cache else 0)

    def _layer_decode(self, lp, kind, x, cache, pos):
        cfg = self.cfg
        h = rms_norm(x, lp["temporal_norm"], cfg.norm_eps, cfg.use_pallas)
        if kind == "attn":
            y, nc = attn.attention_decode(lp["mixer"], cfg, h, cache, pos,
                                          window=cfg.local_window)
        else:
            y, nc = blocks.rglru_block_forward(lp["mixer"], cfg, h,
                                               state=cache)
        x = x + y
        h = rms_norm(x, lp["mlp_norm"], cfg.norm_eps, cfg.use_pallas)
        x = x + blocks.ffn_forward(lp["mlp"], cfg, h)
        return x, nc

    def _unit_full(self, up, x, positions, *, collect_cache):
        caches = {}
        for i, kind in enumerate(self.pattern):
            x, c = self._layer_full(up[f"l{i}"], kind, x, positions,
                                    collect_cache=collect_cache)
            caches[f"l{i}"] = c
        return x, caches

    # ------------------------------------------------------------------
    def _embed(self, params, tokens):
        return jnp.take(params["embed"], tokens, axis=0).astype(
            self.cfg.activation_dtype)

    def _unembed(self, params, x):
        cfg = self.cfg
        x = rms_norm(x, params["final_norm"], cfg.norm_eps, cfg.use_pallas)
        head = (params["embed"].T if cfg.tie_embeddings
                else params["lm_head"])
        return x @ head.astype(x.dtype)

    def _run(self, params, x, positions, *, collect_cache):
        cfg = self.cfg

        def body(h, up):
            h, caches = self._unit_full(up, h, positions,
                                        collect_cache=collect_cache)
            return h, caches

        body_fn = jax.checkpoint(body) if cfg.remat else body
        x, unit_caches = scan_layers(body_fn, x, params["units"],
                                     unroll=cfg.unroll_layers)
        tail_caches = []
        for lp in params["tail"]:
            x, c = self._layer_full(lp, "rec", x, positions,
                                    collect_cache=collect_cache)
            tail_caches.append(c)
        return x, unit_caches, tail_caches

    def forward(self, params, tokens, positions=None):
        B, S = tokens.shape
        if positions is None:
            positions = jnp.broadcast_to(jnp.arange(S)[None], (B, S))
        x = self._embed(params, tokens)
        x, _, _ = self._run(params, x, positions, collect_cache=False)
        return self._unembed(params, x), jnp.zeros((), jnp.float32)

    def loss(self, params, tokens, labels, mask=None):
        logits, _ = self.forward(params, tokens)
        return softmax_cross_entropy(logits, labels, mask)

    def prefill(self, params, tokens, max_len=None):
        B, S = tokens.shape
        positions = jnp.broadcast_to(jnp.arange(S)[None], (B, S))
        x = self._embed(params, tokens)
        x, unit_caches, tail_caches = self._run(params, x, positions,
                                                collect_cache=True)
        logits = self._unembed(params, x[:, -1:])
        return logits, {"units": unit_caches, "tail": tail_caches}

    def init_cache(self, batch: int, max_len: int):
        cfg = self.cfg

        def one(kind):
            if kind == "attn":
                return attn.init_kv_cache(
                    cfg.replace(attention_window=cfg.local_window),
                    batch, max_len)
            return blocks.init_rglru_state(cfg, batch)

        unit = {f"l{i}": one(kind) for i, kind in enumerate(self.pattern)}
        units = jax.tree.map(lambda *xs: jnp.stack(xs),
                             *([unit] * self.n_units))
        return {"units": units,
                "tail": [one("rec") for _ in range(self.n_tail)]}

    def decode_step(self, params, token, cache, pos):
        cfg = self.cfg
        x = self._embed(params, token)

        def body(h, inp):
            up, uc = inp
            ncs = {}
            for i, kind in enumerate(self.pattern):
                h, nc = self._layer_decode(up[f"l{i}"], kind, h,
                                           uc[f"l{i}"], pos)
                ncs[f"l{i}"] = nc
            return h, ncs

        x, new_units = scan_layers(body, x,
                                   (params["units"], cache["units"]),
                                   unroll=cfg.unroll_layers)
        new_tail = []
        for lp, c in zip(params["tail"], cache["tail"]):
            x, nc = self._layer_decode(lp, "rec", x, c, pos)
            new_tail.append(nc)
        return self._unembed(params, x), {"units": new_units,
                                          "tail": new_tail}
