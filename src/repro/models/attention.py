"""Attention building blocks: GQA/MQA prefill + decode, sliding-window ring
buffer, MLA (deepseek), cross-attention (whisper). Reference paths are pure
jnp; the Pallas kernels in ``repro.kernels`` are dispatched when
``cfg.use_pallas`` is set (interpret mode on CPU, compiled on TPU).
"""
from __future__ import annotations

from typing import NamedTuple, Optional, Tuple

import jax
import jax.numpy as jnp

from repro.models.common import (ModelConfig, apply_rope, dense_init,
                                 rms_norm, split_keys)

NEG_INF = -1e30


# ---------------------------------------------------------------------------
# Core scaled-dot-product helpers (reference paths)
# ---------------------------------------------------------------------------

def gqa_attention(q: jnp.ndarray, k: jnp.ndarray, v: jnp.ndarray,
                  mask: Optional[jnp.ndarray], *,
                  causal: bool = False,
                  use_pallas: bool = False) -> jnp.ndarray:
    """q: (B,S,H,D); k,v: (B,T,Hkv,D); mask: broadcastable (B,1,S,T) bool.

    Grouped-query: H = G*Hkv query heads share each kv head.
    """
    if use_pallas and causal and mask is None and q.shape[1] == k.shape[1]:
        from repro.kernels import ops as kops
        return kops.flash_attention(q, k, v, causal=True)
    B, S, H, D = q.shape
    T = k.shape[1]
    Hkv = k.shape[2]
    G = H // Hkv
    qg = q.reshape(B, S, Hkv, G, D).astype(jnp.float32)
    kf = k.astype(jnp.float32)
    vf = v.astype(jnp.float32)
    scores = jnp.einsum("bshgd,bthd->bhgst", qg, kf) * (D ** -0.5)
    if causal:
        cm = jnp.tril(jnp.ones((S, T), dtype=bool), k=T - S)
        scores = jnp.where(cm[None, None, None], scores, NEG_INF)
    if mask is not None:
        scores = jnp.where(mask[:, :, None] if mask.ndim == 4 else mask,
                           scores, NEG_INF)
    probs = jax.nn.softmax(scores, axis=-1)
    out = jnp.einsum("bhgst,bthd->bshgd", probs, vf)
    return out.reshape(B, S, H, D).astype(q.dtype)


def decode_attention(q: jnp.ndarray, k_cache: jnp.ndarray,
                     v_cache: jnp.ndarray, valid: jnp.ndarray, *,
                     use_pallas: bool = False) -> jnp.ndarray:
    """Single-token attention. q: (B,1,H,D); caches: (B,T,Hkv,D);
    valid: (B,T) bool marking live cache slots."""
    if use_pallas:
        from repro.kernels import ops as kops
        return kops.decode_attention(q, k_cache, v_cache, valid)
    mask = valid[:, None, None, :]                        # (B,1,1,T)
    return gqa_attention(q, k_cache, v_cache, mask)


def flash_attention_jnp(q: jnp.ndarray, k: jnp.ndarray, v: jnp.ndarray, *,
                        causal: bool = True, window: int = 0,
                        block_k: int = 512,
                        unroll: bool = False) -> jnp.ndarray:
    """Memory-bounded reference attention: lax.scan over KV blocks with a
    running (m, l, acc) streaming softmax — the jnp analogue of the Pallas
    flash kernel. Peak temp is O(S*block_k) instead of O(S*T), which is what
    lets the 32k prefill shapes fit per-device HBM (§Perf iteration 1).

    q: (B,S,H,Dk); k: (B,T,Hkv,Dk); v: (B,T,Hkv,Dv). Query/key absolute
    positions are their indices (prefill convention)."""
    B, S, H, D = q.shape
    T, Hkv = k.shape[1], k.shape[2]
    Dv = v.shape[-1]
    G = H // Hkv
    scale = D ** -0.5
    qg = (q.reshape(B, S, Hkv, G, D).astype(jnp.float32)) * scale
    nb = -(-T // block_k)
    pad = nb * block_k - T
    if pad:
        k = jnp.pad(k, ((0, 0), (0, pad), (0, 0), (0, 0)))
        v = jnp.pad(v, ((0, 0), (0, pad), (0, 0), (0, 0)))
    kb = jnp.moveaxis(k.reshape(B, nb, block_k, Hkv, D), 1, 0)
    vb = jnp.moveaxis(v.reshape(B, nb, block_k, Hkv, Dv), 1, 0)
    rows = jnp.arange(S)

    def body(carry, blk):
        m, l, acc, j0 = carry
        kj, vj = blk
        s = jnp.einsum("bshgd,bthd->bshgt", qg, kj.astype(jnp.float32))
        cols = j0 + jnp.arange(block_k)
        mask = cols[None, :] < T
        if causal:
            mask = mask & (cols[None, :] <= rows[:, None])
        if window:
            mask = mask & (cols[None, :] > rows[:, None] - window)
        s = jnp.where(mask[None, :, None, None, :], s, NEG_INF)
        m_cur = jnp.max(s, axis=-1)
        m_new = jnp.maximum(m, m_cur)
        p = jnp.exp(s - m_new[..., None])
        p = jnp.where(mask[None, :, None, None, :], p, 0.0)
        alpha = jnp.exp(m - m_new)
        l_new = alpha * l + jnp.sum(p, axis=-1)
        acc_new = acc * alpha[..., None] + jnp.einsum(
            "bshgt,bthd->bshgd", p, vj.astype(jnp.float32))
        return (m_new, l_new, acc_new, j0 + block_k), None

    m0 = jnp.full((B, S, Hkv, G), NEG_INF, jnp.float32)
    l0 = jnp.zeros((B, S, Hkv, G), jnp.float32)
    acc0 = jnp.zeros((B, S, Hkv, G, Dv), jnp.float32)
    if unroll:
        # python loop: exact XLA cost accounting (scan bodies are costed
        # once); used by the roofline cost-extrapolation variants
        carry = (m0, l0, acc0, 0)
        for i in range(nb):
            carry, _ = body(carry, (kb[i], vb[i]))
        m, l, acc, _ = carry
    else:
        (m, l, acc, _), _ = jax.lax.scan(body, (m0, l0, acc0, 0), (kb, vb))
    l = jnp.where(l == 0.0, 1.0, l)
    out = acc / l[..., None]
    return out.reshape(B, S, H, Dv).astype(q.dtype)


# threshold above which full-sequence attention switches to the chunked
# (flash-style) reference path; small shapes keep the naive path, whose
# numerics the kernel tests pin down exactly.
CHUNKED_ATTENTION_MIN_SEQ = 1024


# ---------------------------------------------------------------------------
# Param init
# ---------------------------------------------------------------------------

def init_attention(key, cfg: ModelConfig, *, num_kv: Optional[int] = None):
    """Standard fused-proj GQA attention params."""
    num_kv = cfg.num_kv_heads if num_kv is None else num_kv
    dt = cfg.weight_dtype
    kq, kk, kv, ko = split_keys(key, 4)
    p = {
        "wq": dense_init(kq, (cfg.d_model, cfg.num_heads * cfg.head_dim), dt),
        "wk": dense_init(kk, (cfg.d_model, num_kv * cfg.head_dim), dt),
        "wv": dense_init(kv, (cfg.d_model, num_kv * cfg.head_dim), dt),
        "wo": dense_init(ko, (cfg.num_heads * cfg.head_dim, cfg.d_model), dt),
    }
    if cfg.use_qk_norm:
        p["q_norm"] = jnp.ones((cfg.head_dim,), dt)
        p["k_norm"] = jnp.ones((cfg.head_dim,), dt)
    return p


def _project_qkv(p, cfg: ModelConfig, x: jnp.ndarray, num_kv: int):
    B, S, _ = x.shape
    q = (x @ p["wq"].astype(x.dtype)).reshape(B, S, cfg.num_heads, cfg.head_dim)
    k = (x @ p["wk"].astype(x.dtype)).reshape(B, S, num_kv, cfg.head_dim)
    v = (x @ p["wv"].astype(x.dtype)).reshape(B, S, num_kv, cfg.head_dim)
    if cfg.use_qk_norm:
        q = rms_norm(q, p["q_norm"], cfg.norm_eps)
        k = rms_norm(k, p["k_norm"], cfg.norm_eps)
    return q, k, v


# ---------------------------------------------------------------------------
# KV cache containers
# ---------------------------------------------------------------------------

class KVCache(NamedTuple):
    """Per-layer decode cache. Full mode: length = max_len; window mode:
    ring buffer of length = window, indexed with pos % window."""
    k: jnp.ndarray        # (B, T, Hkv, D)
    v: jnp.ndarray        # (B, T, Hkv, D)


def init_kv_cache(cfg: ModelConfig, batch: int, max_len: int,
                  *, num_kv: Optional[int] = None,
                  head_dim: Optional[int] = None) -> KVCache:
    num_kv = cfg.num_kv_heads if num_kv is None else num_kv
    head_dim = cfg.head_dim if head_dim is None else head_dim
    length = cfg.attention_window or max_len
    shape = (batch, length, num_kv, head_dim)
    z = jnp.zeros(shape, cfg.activation_dtype)
    return KVCache(k=z, v=z)


def cache_positions(cfg: ModelConfig, cache_len: int, pos: jnp.ndarray):
    """valid-slot mask for a decode step at absolute position ``pos``
    (number of tokens already in cache). Handles ring-buffer windows."""
    idx = jnp.arange(cache_len)
    if cfg.attention_window:
        # slots hold absolute positions pos-1, pos-2, ... (wrapped); a slot i
        # is valid if it has been written: i < pos (before wrap) or always
        # after the buffer has wrapped once.
        return (idx[None, :] < jnp.minimum(pos, cache_len)[:, None])
    return idx[None, :] < pos[:, None]


# ---------------------------------------------------------------------------
# Attention forward: full-sequence (train / prefill) and decode step
# ---------------------------------------------------------------------------

def attention_forward(p, cfg: ModelConfig, x: jnp.ndarray,
                      positions: jnp.ndarray, *,
                      num_kv: Optional[int] = None,
                      window: int = 0,
                      cache_len: Optional[int] = None
                      ) -> Tuple[jnp.ndarray, KVCache]:
    """Causal self-attention over a whole sequence. Returns output and the
    cache that a subsequent decode would consume (prefill contract)."""
    num_kv = cfg.num_kv_heads if num_kv is None else num_kv
    B, S, _ = x.shape
    q, k, v = _project_qkv(p, cfg, x, num_kv)
    if cfg.use_rope:
        q = apply_rope(q, positions, cfg.rope_theta)
        k = apply_rope(k, positions, cfg.rope_theta)
    use_chunked = (cfg.ref_attention == "chunked"
                   and S >= CHUNKED_ATTENTION_MIN_SEQ
                   and not cfg.use_pallas)
    if use_chunked:
        out = flash_attention_jnp(q, k, v, causal=True, window=window,
                                  unroll=cfg.unroll_layers)
    elif window:
        # banded causal mask: j in (i-window, i]
        i = jnp.arange(S)[:, None]
        j = jnp.arange(S)[None, :]
        band = (j <= i) & (j > i - window)
        out = gqa_attention(q, k, v, band[None, None], use_pallas=False)
    else:
        out = gqa_attention(q, k, v, None, causal=True,
                            use_pallas=cfg.use_pallas)
    out = out.reshape(B, S, cfg.num_heads * cfg.head_dim)
    y = out @ p["wo"].astype(out.dtype)
    cache = _cache_from_prefill(cfg, k, v, window, cache_len)
    return y, cache


def _cache_from_prefill(cfg: ModelConfig, k, v, window: int,
                        cache_len: Optional[int] = None) -> KVCache:
    if window or cfg.attention_window:
        w = window or cfg.attention_window
        S = k.shape[1]
        if S >= w:
            k = jax.lax.dynamic_slice_in_dim(k, S - w, w, axis=1)
            v = jax.lax.dynamic_slice_in_dim(v, S - w, w, axis=1)
            # ring layout: slot (S - w + i) % w == written order; we re-roll so
            # that slot j holds absolute position with j == pos % w.
            shift = (S - w) % w
            k = jnp.roll(k, shift, axis=1)
            v = jnp.roll(v, shift, axis=1)
        else:
            pad = w - S
            k = jnp.pad(k, ((0, 0), (0, pad), (0, 0), (0, 0)))
            v = jnp.pad(v, ((0, 0), (0, pad), (0, 0), (0, 0)))
    elif cache_len is not None and cache_len > k.shape[1]:
        pad = cache_len - k.shape[1]
        k = jnp.pad(k, ((0, 0), (0, pad), (0, 0), (0, 0)))
        v = jnp.pad(v, ((0, 0), (0, pad), (0, 0), (0, 0)))
    return KVCache(k=k, v=v)


def scatter_cache_update(cache_arr: jnp.ndarray, new_vals: jnp.ndarray,
                         slot: jnp.ndarray) -> jnp.ndarray:
    """In-place-style cache write: O(B*H*D) traffic instead of the one-hot
    formulation's full O(B*T*H*D) read+write (a §Perf optimization — see
    EXPERIMENTS.md). cache (B,T,...), new (B,1,...), slot (B,)."""
    def upd(c, v, s):
        idx = (s,) + (0,) * (c.ndim - 1)
        return jax.lax.dynamic_update_slice(c, v.astype(c.dtype), idx)
    return jax.vmap(upd)(cache_arr, new_vals, slot)


def _write_cache(cfg: ModelConfig, cache_arr, new_vals, slot):
    if cfg.kv_update == "scatter":
        return scatter_cache_update(cache_arr, new_vals, slot)
    cache_len = cache_arr.shape[1]
    onehot = jax.nn.one_hot(slot, cache_len, dtype=new_vals.dtype)
    expand = onehot.reshape(onehot.shape + (1,) * (cache_arr.ndim - 2))
    return cache_arr * (1 - expand) + expand * new_vals


def attention_decode(p, cfg: ModelConfig, x: jnp.ndarray, cache: KVCache,
                     pos: jnp.ndarray, *,
                     num_kv: Optional[int] = None,
                     window: int = 0) -> Tuple[jnp.ndarray, KVCache]:
    """One-token decode. x: (B,1,d_model); pos: (B,) int32 tokens-so-far."""
    num_kv = cfg.num_kv_heads if num_kv is None else num_kv
    B = x.shape[0]
    q, k, v = _project_qkv(p, cfg, x, num_kv)
    if cfg.use_rope:
        q = apply_rope(q, pos[:, None], cfg.rope_theta)
        k = apply_rope(k, pos[:, None], cfg.rope_theta)
    w = window or cfg.attention_window
    cache_len = cache.k.shape[1]
    slot = jnp.mod(pos, cache_len) if w else jnp.minimum(pos, cache_len - 1)
    k_new = _write_cache(cfg, cache.k, k, slot)
    v_new = _write_cache(cfg, cache.v, v, slot)
    valid = cache_positions(cfg.replace(attention_window=w), cache_len,
                            pos + 1)
    out = decode_attention(q, k_new, v_new, valid,
                           use_pallas=cfg.use_pallas)
    out = out.reshape(B, 1, cfg.num_heads * cfg.head_dim)
    y = out @ p["wo"].astype(out.dtype)
    return y, KVCache(k=k_new, v=v_new)


# ---------------------------------------------------------------------------
# Cross attention (whisper decoder -> encoder states)
# ---------------------------------------------------------------------------

def init_cross_attention(key, cfg: ModelConfig):
    return init_attention(key, cfg, num_kv=cfg.num_kv_heads)


def cross_attention(p, cfg: ModelConfig, x: jnp.ndarray,
                    enc_k: jnp.ndarray, enc_v: jnp.ndarray) -> jnp.ndarray:
    """x: (B,S,d); enc_k/enc_v: (B,T,Hkv,D) precomputed from encoder."""
    B, S, _ = x.shape
    q = (x @ p["wq"].astype(x.dtype)).reshape(B, S, cfg.num_heads,
                                              cfg.head_dim)
    out = gqa_attention(q, enc_k, enc_v, None)
    out = out.reshape(B, S, cfg.num_heads * cfg.head_dim)
    return out @ p["wo"].astype(out.dtype)


def encoder_kv(p, cfg: ModelConfig, enc_out: jnp.ndarray):
    B, T, _ = enc_out.shape
    k = (enc_out @ p["wk"].astype(enc_out.dtype)).reshape(
        B, T, cfg.num_kv_heads, cfg.head_dim)
    v = (enc_out @ p["wv"].astype(enc_out.dtype)).reshape(
        B, T, cfg.num_kv_heads, cfg.head_dim)
    return k, v


# ---------------------------------------------------------------------------
# MLA — Multi-head Latent Attention (DeepSeek-V2) with compressed KV cache
# ---------------------------------------------------------------------------

class MLACache(NamedTuple):
    c_kv: jnp.ndarray     # (B, T, kv_lora_rank) compressed latents
    k_rope: jnp.ndarray   # (B, T, qk_rope_head_dim) shared rope key


def init_mla(key, cfg: ModelConfig):
    dt = cfg.weight_dtype
    H = cfg.num_heads
    qk_dim = cfg.qk_nope_head_dim + cfg.qk_rope_head_dim
    ks = split_keys(key, 5)
    return {
        "wq": dense_init(ks[0], (cfg.d_model, H * qk_dim), dt),
        "w_dkv": dense_init(ks[1], (cfg.d_model,
                                    cfg.kv_lora_rank + cfg.qk_rope_head_dim), dt),
        "kv_norm": jnp.ones((cfg.kv_lora_rank,), dt),
        "w_uk": dense_init(ks[2], (cfg.kv_lora_rank,
                                   H * cfg.qk_nope_head_dim), dt),
        "w_uv": dense_init(ks[3], (cfg.kv_lora_rank, H * cfg.v_head_dim), dt),
        "wo": dense_init(ks[4], (H * cfg.v_head_dim, cfg.d_model), dt),
    }


def init_mla_cache(cfg: ModelConfig, batch: int, max_len: int) -> MLACache:
    dt = cfg.activation_dtype
    return MLACache(
        c_kv=jnp.zeros((batch, max_len, cfg.kv_lora_rank), dt),
        k_rope=jnp.zeros((batch, max_len, cfg.qk_rope_head_dim), dt))


def _mla_qkv(p, cfg: ModelConfig, x, positions):
    """Project q (nope+rope split) and compressed kv latents."""
    B, S, _ = x.shape
    H = cfg.num_heads
    qk_dim = cfg.qk_nope_head_dim + cfg.qk_rope_head_dim
    q = (x @ p["wq"].astype(x.dtype)).reshape(B, S, H, qk_dim)
    q_nope, q_rope = jnp.split(q, [cfg.qk_nope_head_dim], axis=-1)
    q_rope = apply_rope(q_rope, positions, cfg.rope_theta)
    ckv = x @ p["w_dkv"].astype(x.dtype)                   # (B,S,rank+rope)
    c_kv, k_rope = jnp.split(ckv, [cfg.kv_lora_rank], axis=-1)
    c_kv = rms_norm(c_kv, p["kv_norm"], cfg.norm_eps)
    k_rope = apply_rope(k_rope[:, :, None, :], positions,
                        cfg.rope_theta)[:, :, 0, :]        # (B,S,rope)
    return q_nope, q_rope, c_kv, k_rope


def _mla_attend(p, cfg: ModelConfig, q_nope, q_rope, c_kv, k_rope, mask):
    """Attention over (possibly cached) latents; up-projects K/V lazily."""
    B, T = c_kv.shape[:2]
    H = cfg.num_heads
    k_nope = (c_kv @ p["w_uk"].astype(c_kv.dtype)).reshape(
        B, T, H, cfg.qk_nope_head_dim)
    v = (c_kv @ p["w_uv"].astype(c_kv.dtype)).reshape(B, T, H, cfg.v_head_dim)
    scale = (cfg.qk_nope_head_dim + cfg.qk_rope_head_dim) ** -0.5
    s_nope = jnp.einsum("bshd,bthd->bhst", q_nope.astype(jnp.float32),
                        k_nope.astype(jnp.float32))
    s_rope = jnp.einsum("bshd,btd->bhst", q_rope.astype(jnp.float32),
                        k_rope.astype(jnp.float32))
    scores = (s_nope + s_rope) * scale
    scores = jnp.where(mask, scores, NEG_INF)
    probs = jax.nn.softmax(scores, axis=-1)
    out = jnp.einsum("bhst,bthd->bshd", probs, v.astype(jnp.float32))
    out = out.reshape(B, -1, H * cfg.v_head_dim).astype(q_nope.dtype)
    return out @ p["wo"].astype(out.dtype)


def _mla_attend_chunked(p, cfg: ModelConfig, q_nope, q_rope, c_kv, k_rope):
    """Flash-style MLA attention: concat (nope, rope) into one key space so
    the chunked streaming-softmax path applies; O(S*block) temps instead of
    the O(S*T) score matrix (critical for the 32k prefill shapes)."""
    B, T = c_kv.shape[:2]
    H = cfg.num_heads
    k_nope = (c_kv @ p["w_uk"].astype(c_kv.dtype)).reshape(
        B, T, H, cfg.qk_nope_head_dim)
    v = (c_kv @ p["w_uv"].astype(c_kv.dtype)).reshape(B, T, H,
                                                      cfg.v_head_dim)
    q_cat = jnp.concatenate([q_nope, q_rope], axis=-1)
    k_cat = jnp.concatenate(
        [k_nope, jnp.broadcast_to(k_rope[:, :, None, :],
                                  (B, T, H, cfg.qk_rope_head_dim))],
        axis=-1)
    out = flash_attention_jnp(q_cat, k_cat, v, causal=True,
                              unroll=cfg.unroll_layers)
    out = out.reshape(B, -1, H * cfg.v_head_dim)
    return out @ p["wo"].astype(out.dtype)


def mla_forward(p, cfg: ModelConfig, x, positions,
                cache_len: Optional[int] = None
                ) -> Tuple[jnp.ndarray, MLACache]:
    B, S, _ = x.shape
    q_nope, q_rope, c_kv, k_rope = _mla_qkv(p, cfg, x, positions)
    if (cfg.ref_attention == "chunked"
            and S >= CHUNKED_ATTENTION_MIN_SEQ):
        y = _mla_attend_chunked(p, cfg, q_nope, q_rope, c_kv, k_rope)
    else:
        causal = jnp.tril(jnp.ones((S, S), bool))[None, None]
        y = _mla_attend(p, cfg, q_nope, q_rope, c_kv, k_rope, causal)
    if cache_len is not None and cache_len > S:
        pad = ((0, 0), (0, cache_len - S), (0, 0))
        c_kv = jnp.pad(c_kv, pad)
        k_rope = jnp.pad(k_rope, pad)
    return y, MLACache(c_kv=c_kv, k_rope=k_rope)


def mla_decode(p, cfg: ModelConfig, x, cache: MLACache,
               pos: jnp.ndarray) -> Tuple[jnp.ndarray, MLACache]:
    B = x.shape[0]
    T = cache.c_kv.shape[1]
    q_nope, q_rope, c_kv, k_rope = _mla_qkv(p, cfg, x, pos[:, None])
    slot = jnp.minimum(pos, T - 1)
    c_new = _write_cache(cfg, cache.c_kv, c_kv, slot)
    kr_new = _write_cache(cfg, cache.k_rope, k_rope, slot)
    valid = (jnp.arange(T)[None] < (pos + 1)[:, None])[:, None, None]
    y = _mla_attend(p, cfg, q_nope, q_rope, c_new, kr_new, valid)
    return y, MLACache(c_kv=c_new, k_rope=kr_new)
