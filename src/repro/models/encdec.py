"""Whisper-style encoder-decoder backbone. [arXiv:2212.04356]

The mel-spectrogram + conv feature extractor is a STUB per the assignment:
``forward``/``prefill`` consume precomputed frame embeddings
(B, encoder_seq, d_model) supplied by ``input_specs``. Everything downstream
(bidirectional encoder, causal decoder with self+cross attention) is real.

Whisper uses LayerNorm (with bias) and GELU MLPs; positions are fixed
sinusoids so arbitrary decode lengths lower without extra parameters.
"""
from __future__ import annotations

from typing import Any

import jax
import jax.numpy as jnp

from repro.models import attention as attn
from repro.models import blocks
from repro.models.common import (ModelConfig, dense_init, layer_norm,
                                 scan_layers, sinusoidal_positions,
                                 softmax_cross_entropy, split_keys)


def _init_ln(cfg):
    return {"w": jnp.ones((cfg.d_model,), cfg.weight_dtype),
            "b": jnp.zeros((cfg.d_model,), cfg.weight_dtype)}


class EncDecLM:
    def __init__(self, cfg: ModelConfig):
        assert cfg.is_encoder_decoder
        self.cfg = cfg

    # ------------------------------------------------------------------
    def _init_enc_layer(self, key):
        cfg = self.cfg
        ka, kf = jax.random.split(key)
        return {"attn_norm": _init_ln(cfg), "ffn_norm": _init_ln(cfg),
                "attn": attn.init_attention(ka, cfg),
                "ffn": blocks.init_ffn(kf, cfg)}

    def _init_dec_layer(self, key):
        cfg = self.cfg
        ka, kc, kf = split_keys(key, 3)
        return {"self_norm": _init_ln(cfg), "cross_norm": _init_ln(cfg),
                "ffn_norm": _init_ln(cfg),
                "self_attn": attn.init_attention(ka, cfg),
                "cross_attn": attn.init_cross_attention(kc, cfg),
                "ffn": blocks.init_ffn(kf, cfg)}

    def init(self, key) -> Any:
        cfg = self.cfg
        ks = split_keys(key, 5)
        enc_keys = jax.random.split(ks[2], cfg.encoder_layers)
        dec_keys = jax.random.split(ks[3], cfg.num_layers)
        return {
            "embed": dense_init(ks[0], (cfg.vocab_size, cfg.d_model),
                                cfg.weight_dtype, scale=0.02),
            "lm_head": dense_init(ks[1], (cfg.d_model, cfg.vocab_size),
                                  cfg.weight_dtype),
            "enc_final_norm": _init_ln(cfg),
            "dec_final_norm": _init_ln(cfg),
            "enc_layers": jax.vmap(self._init_enc_layer)(enc_keys),
            "dec_layers": jax.vmap(self._init_dec_layer)(dec_keys),
        }

    # ------------------------------------------------------------------
    def encode(self, params, frames: jnp.ndarray) -> jnp.ndarray:
        """frames: (B, T_enc, d_model) stubbed conv-frontend output."""
        cfg = self.cfg
        T = frames.shape[1]
        pos = sinusoidal_positions(T, cfg.d_model).astype(frames.dtype)
        x = frames + pos[None]

        def body(h, lp):
            a = layer_norm(h, lp["attn_norm"]["w"], lp["attn_norm"]["b"])
            q, k, v = attn._project_qkv(lp["attn"],
                                        cfg.replace(use_rope=False),
                                        a, cfg.num_kv_heads)
            y = attn.gqa_attention(q, k, v, None)  # bidirectional
            y = y.reshape(h.shape[0], h.shape[1], -1)
            h = h + y @ lp["attn"]["wo"].astype(y.dtype)
            f = layer_norm(h, lp["ffn_norm"]["w"], lp["ffn_norm"]["b"])
            h = h + blocks.ffn_forward(lp["ffn"], cfg, f)
            return h, 0

        body_fn = jax.checkpoint(body) if cfg.remat else body
        x, _ = scan_layers(body_fn, x, params["enc_layers"],
                           unroll=cfg.unroll_layers)
        return layer_norm(x, params["enc_final_norm"]["w"],
                          params["enc_final_norm"]["b"])

    # ------------------------------------------------------------------
    def _dec_layer_full(self, lp, x, positions, enc_k, enc_v,
                        *, collect_cache, cache_len=None):
        cfg = self.cfg
        h = layer_norm(x, lp["self_norm"]["w"], lp["self_norm"]["b"])
        y, cache = attn.attention_forward(
            lp["self_attn"], cfg.replace(use_rope=False), h, positions,
            window=cfg.attention_window, cache_len=cache_len)
        x = x + y
        h = layer_norm(x, lp["cross_norm"]["w"], lp["cross_norm"]["b"])
        x = x + attn.cross_attention(lp["cross_attn"], cfg, h, enc_k, enc_v)
        h = layer_norm(x, lp["ffn_norm"]["w"], lp["ffn_norm"]["b"])
        x = x + blocks.ffn_forward(lp["ffn"], cfg, h)
        return x, (cache if collect_cache else 0)

    def _dec_layer_decode(self, lp, x, self_cache, enc_k, enc_v, pos):
        cfg = self.cfg
        h = layer_norm(x, lp["self_norm"]["w"], lp["self_norm"]["b"])
        y, nc = attn.attention_decode(
            lp["self_attn"], cfg.replace(use_rope=False), h, self_cache,
            pos, window=cfg.attention_window)
        x = x + y
        h = layer_norm(x, lp["cross_norm"]["w"], lp["cross_norm"]["b"])
        x = x + attn.cross_attention(lp["cross_attn"], cfg, h, enc_k, enc_v)
        h = layer_norm(x, lp["ffn_norm"]["w"], lp["ffn_norm"]["b"])
        x = x + blocks.ffn_forward(lp["ffn"], cfg, h)
        return x, nc

    def _embed_tokens(self, params, tokens, start_pos=0):
        cfg = self.cfg
        x = jnp.take(params["embed"], tokens, axis=0).astype(
            cfg.activation_dtype)
        S = tokens.shape[1]
        pos = sinusoidal_positions(start_pos + S, cfg.d_model)[start_pos:]
        return x + pos[None].astype(x.dtype)

    def _unembed(self, params, x):
        x = layer_norm(x, params["dec_final_norm"]["w"],
                       params["dec_final_norm"]["b"])
        return x @ params["lm_head"].astype(x.dtype)

    def _cross_kv(self, params, enc_out):
        cfg = self.cfg

        def body(_, lp):
            k, v = attn.encoder_kv(lp["cross_attn"], cfg, enc_out)
            return 0, (k, v)

        _, (ks, vs) = scan_layers(body, 0, params["dec_layers"],
                                  unroll=cfg.unroll_layers)
        return ks, vs      # (L, B, T_enc, Hkv, D)

    # ------------------------------------------------------------------
    def forward(self, params, tokens, frames):
        """Teacher-forced training forward. tokens (B,S); frames (B,T,d)."""
        cfg = self.cfg
        B, S = tokens.shape
        enc_out = self.encode(params, frames)
        cross_k, cross_v = self._cross_kv(params, enc_out)
        positions = jnp.broadcast_to(jnp.arange(S)[None], (B, S))
        x = self._embed_tokens(params, tokens)

        def body(h, inp):
            lp, (ek, ev) = inp
            h, _ = self._dec_layer_full(lp, h, positions, ek, ev,
                                        collect_cache=False)
            return h, 0

        body_fn = jax.checkpoint(body) if cfg.remat else body
        x, _ = scan_layers(body_fn, x,
                           (params["dec_layers"], (cross_k, cross_v)),
                           unroll=cfg.unroll_layers)
        return self._unembed(params, x), jnp.zeros((), jnp.float32)

    def loss(self, params, tokens, labels, frames, mask=None):
        logits, _ = self.forward(params, tokens, frames)
        return softmax_cross_entropy(logits, labels, mask)

    def prefill(self, params, tokens, frames, max_len=None):
        cfg = self.cfg
        B, S = tokens.shape
        enc_out = self.encode(params, frames)
        cross_k, cross_v = self._cross_kv(params, enc_out)
        positions = jnp.broadcast_to(jnp.arange(S)[None], (B, S))
        x = self._embed_tokens(params, tokens)

        def body(h, inp):
            lp, (ek, ev) = inp
            h, cache = self._dec_layer_full(lp, h, positions, ek, ev,
                                            collect_cache=True,
                                            cache_len=max_len)
            return h, cache

        x, self_caches = scan_layers(
            body, x, (params["dec_layers"], (cross_k, cross_v)),
            unroll=cfg.unroll_layers)
        logits = self._unembed(params, x[:, -1:])
        return logits, {"self": self_caches,
                        "cross_k": cross_k, "cross_v": cross_v}

    def init_cache(self, batch: int, max_len: int):
        cfg = self.cfg
        one = attn.init_kv_cache(cfg, batch, max_len)
        self_c = jax.tree.map(lambda *xs: jnp.stack(xs),
                              *([one] * cfg.num_layers))
        z = jnp.zeros((cfg.num_layers, batch, cfg.encoder_seq,
                       cfg.num_kv_heads, cfg.head_dim),
                      cfg.activation_dtype)
        return {"self": self_c, "cross_k": z, "cross_v": z}

    def decode_step(self, params, token, cache, pos):
        cfg = self.cfg
        x = jnp.take(params["embed"], token, axis=0).astype(
            cfg.activation_dtype)
        # per-example sinusoidal position embedding computed from pos (B,)
        d = cfg.d_model
        log_timescale = jnp.log(10000.0) / (d // 2 - 1)
        inv = jnp.exp(-log_timescale * jnp.arange(d // 2, dtype=jnp.float32))
        t = pos[:, None].astype(jnp.float32) * inv[None, :]
        sinus = jnp.concatenate([jnp.sin(t), jnp.cos(t)], axis=-1)
        x = x + sinus[:, None, :].astype(x.dtype)

        def body(h, inp):
            lp, sc, ek, ev = inp
            h, nc = self._dec_layer_decode(lp, h, sc, ek, ev, pos)
            return h, nc

        x, new_self = scan_layers(
            body, x, (params["dec_layers"], cache["self"],
                      cache["cross_k"], cache["cross_v"]),
            unroll=cfg.unroll_layers)
        logits = self._unembed(params, x)
        return logits, {"self": new_self, "cross_k": cache["cross_k"],
                        "cross_v": cache["cross_v"]}
