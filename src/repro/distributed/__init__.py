from repro.distributed.sharding import (batch_pspec, cache_pspecs,
                                        data_axes, logits_pspec,
                                        param_pspecs, with_sharding)

__all__ = ["batch_pspec", "cache_pspecs", "data_axes", "logits_pspec",
           "param_pspecs", "with_sharding"]
