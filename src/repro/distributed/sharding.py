"""Sharding rules: map model/optimizer/cache pytrees to PartitionSpecs.

Strategy (Megatron-style TP x DP, MoE expert-parallel over the `model`
axis):
  * batch axes       -> data axes ("pod","data") when divisible, else None
  * attention fused-QKV / FFN-in hidden dim, vocab dim -> "model"
  * attention out / FFN-out contraction dim            -> "model"
  * expert axis of MoE expert weights                  -> "model" (EP)
  * KV cache heads / MLA latent rank / SSM heads / LRU width -> "model"
  * norms, scalars, small vectors -> replicated

Rules are NAME-BASED over pytree paths, so one table covers every family in
the zoo; stacked (scan) params get a leading unsharded layer axis
automatically (detected by rank bump).
"""
from __future__ import annotations

from typing import Any, Optional, Tuple

import jax
from jax.sharding import Mesh, NamedSharding, PartitionSpec as P

MODEL_AXIS = "model"


def data_axes(mesh: Mesh) -> Tuple[str, ...]:
    return tuple(a for a in mesh.axis_names if a in ("pod", "data"))


# ---------------------------------------------------------------------------
# rule table: (path substring match, rank) -> spec builder
# each entry maps the TRAILING dims of the unstacked parameter
# ---------------------------------------------------------------------------

def _param_rule(name: str, path: str) -> Optional[Tuple[Optional[str], ...]]:
    """Returns the trailing-dims partition (tuple of axis names/None) for a
    parameter leaf, or None for full replication."""
    m = MODEL_AXIS
    # embeddings / unembeddings
    if name == "embed":
        return (m, None)                      # (V, d) vocab-parallel
    if name == "lm_head":
        return (None, m)                      # (d, V)
    # attention projections
    if name in ("wq", "wk", "wv"):
        return (None, m)                      # (d, H*hd)
    if name == "wo":
        return (m, None)                      # (H*hd, d)
    # MLA
    if name == "w_dkv":
        return (None, None)                   # latent proj small; replicate
    if name in ("w_uk", "w_uv"):
        return (None, m)                      # (rank, H*hd)
    # FFN
    if name in ("w_in", "w_gate"):
        if "moe" in path and "shared" not in path:
            return (m, None, None)            # (E, d, f) expert-parallel
        if "mixer" in path and "moe" not in path:
            return (None, m)                  # ssm in_proj (d, X)
        return (None, m)                      # (d, f)
    if name == "w_out":
        if "moe" in path and "shared" not in path:
            return (m, None, None)            # (E, f, d)
        return (m, None)                      # (f, d)
    if name == "router":
        return None                           # replicate (tiny, all-to-all)
    # hybrid RG-LRU
    if name in ("w_x", "w_y"):
        return (None, m)                      # (d, W)
    if name in ("w_input_gate", "w_rec_gate"):
        return (None, m)                      # (W, W) shard output dim
    # convs / per-channel vectors: shard the channel (lane) dim
    if name == "conv_w":
        return (None, m)                      # (k, channels)
    if name in ("lambda_param", "norm_w"):
        return None                           # small; replicate
    return None


def _spec_for_leaf(path_str: str, ndim: int,
                   expected_extra: int) -> P:
    parts = [p for p in path_str.split("/") if p]
    name = parts[-1] if parts else ""
    rule = _param_rule(name, path_str)
    if rule is None:
        return P()
    lead = ndim - len(rule)
    if lead < 0:
        return P()
    return P(*([None] * lead + list(rule)))


def _path_to_str(path) -> str:
    out = []
    for k in path:
        if hasattr(k, "key"):
            out.append(str(k.key))
        elif hasattr(k, "idx"):
            out.append(str(k.idx))
        elif hasattr(k, "name"):
            out.append(str(k.name))
    return "/".join(out)


def sanitize_spec(spec: P, shape, mesh: Mesh) -> P:
    """Drop sharding on any dim the mesh axes do not divide (explicit
    in_shardings require exact divisibility, unlike GSPMD-internal
    propagation which pads)."""
    out = []
    for i, entry in enumerate(list(spec) + [None] * (len(shape) - len(spec))):
        if entry is None:
            out.append(None)
            continue
        axes = entry if isinstance(entry, tuple) else (entry,)
        size = 1
        for a in axes:
            size *= mesh.shape[a]
        out.append(entry if shape[i] % size == 0 and shape[i] >= size
                   else None)
    return P(*out)


def param_pspecs(params: Any, mesh: Optional[Mesh] = None) -> Any:
    """PartitionSpec tree matching ``params`` (works on SDS trees too)."""
    def one(path, leaf):
        spec = _spec_for_leaf(_path_to_str(path), len(leaf.shape), 0)
        return sanitize_spec(spec, leaf.shape, mesh) if mesh is not None \
            else spec
    return jax.tree_util.tree_map_with_path(one, params)


# ---------------------------------------------------------------------------
# caches & activations
# ---------------------------------------------------------------------------

def cache_pspecs(cache: Any, mesh: Mesh, global_batch: int) -> Any:
    """Decode-cache specs. Heads/latent/width dims go to `model`; the batch
    dim goes to the data axes when divisible (else replicated — e.g. the
    batch=1 long-context shape)."""
    da = data_axes(mesh)
    dp = int(jax.numpy.prod(jax.numpy.array(
        [mesh.shape[a] for a in da]))) if da else 1
    batch_spec = da if (da and global_batch % dp == 0
                        and global_batch >= dp) else None
    m = MODEL_AXIS

    def one(path, leaf):
        ps = _path_to_str(path)
        nd = len(leaf.shape)
        # identify the stacked-layer leading axis by convention: caches are
        # built stacked, so rank>=3 arrays start with (L, B, ...) except
        # prefix/tail lists whose leaves start with (B, ...).
        stacked = any(s in ps for s in ("scanned", "units", "self",
                                        "cross_k", "cross_v")) \
            and "prefix" not in ps and "tail" not in ps
        lead = [None] if stacked else []
        body = [batch_spec]
        rest = nd - len(lead) - 1
        mdl = mesh.shape[m]
        shape = leaf.shape
        off = len(lead) + 1                    # index of first body dim
        if "c_kv" in ps:                       # (.., T, rank)
            body += [None] * (rest - 1) + [m]
        elif "k_rope" in ps:                   # (.., T, rope_dim) small
            body += [None] * rest
        elif "ssm" in ps and rest == 3:        # (H, P, N)
            body += [m, None, None]
        elif ps.endswith("conv") or "conv" in ps.split("/")[-1]:
            body += [None] * (rest - 1) + [m]  # (k-1, channels)
        elif ps.endswith("h"):                 # LRU state (B, W)
            body += [None] * (rest - 1) + [m]
        elif rest == 3:                        # KV cache (T, Hkv, D)
            hkv = shape[off + 1]
            T = shape[off]
            if hkv % mdl == 0:
                body += [None, m, None]        # head-parallel
            elif T % mdl == 0:
                body += [m, None, None]        # context-parallel fallback
            else:
                body += [None, None, None]
        else:
            body += [None] * rest
        return sanitize_spec(P(*(lead + body)), shape, mesh)
    return jax.tree_util.tree_map_with_path(one, cache)


def batch_pspec(mesh: Mesh, global_batch: int, extra_dims: int = 1) -> P:
    da = data_axes(mesh)
    dp = 1
    for a in da:
        dp *= mesh.shape[a]
    if da and global_batch % dp == 0 and global_batch >= dp:
        return P(da, *([None] * extra_dims))
    return P(None, *([None] * extra_dims))


def logits_pspec(mesh: Mesh, global_batch: int,
                 vocab_size: Optional[int] = None) -> P:
    bs = batch_pspec(mesh, global_batch, extra_dims=0)
    vocab_axis = MODEL_AXIS
    if vocab_size is not None and vocab_size % mesh.shape[MODEL_AXIS]:
        vocab_axis = None                      # e.g. whisper's 51865
    return P(bs[0] if len(bs) else None, None, vocab_axis)


def with_sharding(tree: Any, specs: Any, mesh: Mesh) -> Any:
    """Attach NamedShardings to a ShapeDtypeStruct tree (for .lower())."""
    return jax.tree.map(
        lambda sds, spec: jax.ShapeDtypeStruct(
            sds.shape, sds.dtype, sharding=NamedSharding(mesh, spec)),
        tree, specs,
        is_leaf=lambda x: isinstance(x, jax.ShapeDtypeStruct))
