from repro.workloads.azure_trace import generate_azure_trace
from repro.workloads.prototypes import (PROTOTYPES, WorkloadSpec,
                                        generate_requests)

__all__ = ["PROTOTYPES", "WorkloadSpec", "generate_requests",
           "generate_azure_trace"]
