"""The paper's five workload prototypes (Table 1) and request generators.

Each prototype manipulates four knobs: context length, generation length,
concurrency (request-rate multiplier), and prompt-template pool size (the
prefix-cache locality control: 5 templates => High Cache Hit, 500 =>
cache-cold)."""
from __future__ import annotations

import dataclasses
from typing import Dict, List

import numpy as np

from repro.serving.request import Request


@dataclasses.dataclass(frozen=True)
class WorkloadSpec:
    name: str
    context_range: tuple          # (lo, hi) prompt tokens
    generation_range: tuple       # (lo, hi) output tokens
    concurrency: float            # request-rate multiplier
    template_pool: int            # prompt templates (prefix-cache locality)
    template_frac: float = 0.9    # shared-prefix fraction of the prompt


# paper Table 1
PROTOTYPES: Dict[str, WorkloadSpec] = {
    "normal": WorkloadSpec("normal", (256, 1024), (100, 350), 1.0, 500),
    "long_context": WorkloadSpec("long_context", (1024, 8192), (1, 100),
                                 1.0, 500),
    "long_generation": WorkloadSpec("long_generation", (1, 256), (350, 350),
                                    1.0, 500),
    "high_concurrency": WorkloadSpec("high_concurrency", (256, 1024),
                                     (100, 350), 5.0, 500),
    "high_cache_hit": WorkloadSpec("high_cache_hit", (256, 1024), (100, 350),
                                   1.0, 5),
}


def generate_requests(spec: WorkloadSpec, n: int, *, base_rate: float = 1.0,
                      start_time: float = 0.0, seed: int = 0
                      ) -> List[Request]:
    """Poisson arrivals at base_rate*concurrency req/s, uniform lengths."""
    rng = np.random.default_rng(seed)
    rate = base_rate * spec.concurrency
    gaps = rng.exponential(1.0 / rate, size=n)
    arrivals = start_time + np.cumsum(gaps)
    lo_c, hi_c = spec.context_range
    lo_g, hi_g = spec.generation_range
    out: List[Request] = []
    for i in range(n):
        out.append(Request(
            arrival_time=float(arrivals[i]),
            prompt_len=int(rng.integers(lo_c, hi_c + 1)),
            output_len=int(rng.integers(lo_g, hi_g + 1)),
            template_id=int(rng.integers(0, spec.template_pool)),
            template_frac=spec.template_frac,
        ))
    return out
