"""Synthetic regeneration of the Azure 2024 LLM-inference trace statistics
the paper evaluates against (§2.4, §5.1: "20% random sampling of the Azure
2024 conversational trace").

The real trace is not available offline, so we resample its *published*
statistics: 91.6% context-heavy / 8.3% balanced / 0.1% generation-heavy mix
(paper Fig. 3), hourly-mean input lengths oscillating in the 1200-2100 token
band with heavy right tails (std bound ~3500, Fig. 4), outputs stable at
100-200 tokens, plus Poisson arrivals whose rate drifts hour-by-hour —
the non-stationarity that breaks offline-profiled DVFS policies.
"""
from __future__ import annotations

from typing import List

import numpy as np

from repro.serving.request import Request

MIX_2024 = {"context_heavy": 0.916, "balanced": 0.083,
            "generation_heavy": 0.001}
MIX_2023 = {"context_heavy": 0.458, "balanced": 0.527,
            "generation_heavy": 0.015}


def _sample_lengths(rng, kind: str, hour_mean_ctx: float):
    if kind == "context_heavy":
        # lognormal with hourly drifting mean, clipped to the trace's band
        ctx = int(np.clip(rng.lognormal(np.log(hour_mean_ctx), 0.9),
                          64, 16384))
        gen = int(np.clip(rng.normal(150, 40), 1, 400))
    elif kind == "balanced":
        ctx = int(np.clip(rng.normal(600, 250), 32, 4096))
        gen = int(np.clip(rng.normal(250, 80), 16, 800))
    else:  # generation_heavy
        ctx = int(np.clip(rng.normal(120, 60), 1, 512))
        gen = int(np.clip(rng.normal(700, 150), 200, 2000))
    return ctx, gen


def generate_azure_trace(duration_s: float, *, base_rate: float = 1.0,
                         year: int = 2024, template_pool: int = 200,
                         seed: int = 0) -> List[Request]:
    """Non-stationary request stream over ``duration_s`` simulated seconds.

    Hourly segments re-draw the context-length mean (1200-2100 band) and the
    arrival-rate multiplier (0.5x-2.0x), reproducing the paper's intra-week
    volatility at a compressed timescale (1 "hour" = 600 sim-seconds so the
    12-hour experiment has ~72 regime shifts)."""
    rng = np.random.default_rng(seed)
    mix = MIX_2024 if year == 2024 else MIX_2023
    kinds = list(mix.keys())
    probs = np.array([mix[k] for k in kinds])
    probs = probs / probs.sum()

    hour_len = 600.0
    out: List[Request] = []
    t = 0.0
    while t < duration_s:
        hour_mean_ctx = rng.uniform(1200, 2100)
        rate = base_rate * rng.uniform(0.5, 2.0)
        hour_end = min(t + hour_len, duration_s)
        while t < hour_end:
            t += rng.exponential(1.0 / rate)
            if t >= hour_end:
                break
            kind = kinds[int(rng.choice(len(kinds), p=probs))]
            ctx, gen = _sample_lengths(rng, kind, hour_mean_ctx)
            out.append(Request(
                arrival_time=t, prompt_len=ctx, output_len=gen,
                template_id=int(rng.integers(0, template_pool)),
                template_frac=0.9))
    return out
