"""Name-keyed policy registry.

Factories take ``(hardware, **kwargs)`` and return a ``PowerPolicy``.
Registering a class works because classes are callable with that
signature; any callable does.

Entries carry a *scope* — ``"node"`` (default; one controller per
engine) or ``"fleet"`` (one controller per cluster, e.g. ``global`` and
``hierarchy``; see ``repro.policies.fleet`` / ``repro.policies.
hierarchy``). The scope is read off the registered factory (class
attribute) so CLIs can offer only the names valid for their attachment
point: ``available_policies(scope="node")``.
"""
from __future__ import annotations

from typing import Callable, Dict, List, Optional

from repro.energy.power_model import A6000, HardwareSpec

_REGISTRY: Dict[str, Callable] = {}


def register_policy(name: str) -> Callable:
    """Decorator: ``@register_policy("static")`` on a class or factory."""
    def deco(factory: Callable) -> Callable:
        key = name.lower()
        if key in _REGISTRY:
            raise ValueError(f"policy {name!r} already registered")
        _REGISTRY[key] = factory
        return factory
    return deco


def get_policy(name: str, hardware: HardwareSpec = A6000, **kwargs):
    """Construct a registered policy by name.

    >>> get_policy("agft")          # paper tuner, default config
    >>> get_policy("static", frequency_mhz=1200.0)
    >>> get_policy("hierarchy", power_cap_w=800.0)   # fleet scope
    """
    key = name.lower()
    if key not in _REGISTRY:
        raise KeyError(f"unknown policy {name!r}; available: "
                       f"{', '.join(available_policies())}")
    return _REGISTRY[key](hardware, **kwargs)


def policy_scope(name: str) -> str:
    """Declared scope of a registered entry ("node" unless the factory
    says otherwise) without constructing it."""
    return getattr(_REGISTRY[name.lower()], "scope", "node")


def available_policies(scope: Optional[str] = None) -> List[str]:
    """Sorted registry names, optionally filtered to one scope."""
    return sorted(n for n in _REGISTRY
                  if scope is None or policy_scope(n) == scope)
