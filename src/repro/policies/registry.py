"""Name-keyed policy registry.

Factories take ``(hardware, **kwargs)`` and return a ``PowerPolicy``.
Registering a class works because classes are callable with that
signature; any callable does.
"""
from __future__ import annotations

from typing import Callable, Dict, List

from repro.energy.power_model import A6000, HardwareSpec

_REGISTRY: Dict[str, Callable] = {}


def register_policy(name: str) -> Callable:
    """Decorator: ``@register_policy("static")`` on a class or factory."""
    def deco(factory: Callable) -> Callable:
        key = name.lower()
        if key in _REGISTRY:
            raise ValueError(f"policy {name!r} already registered")
        _REGISTRY[key] = factory
        return factory
    return deco


def get_policy(name: str, hardware: HardwareSpec = A6000, **kwargs):
    """Construct a registered policy by name.

    >>> get_policy("agft")          # paper tuner, default config
    >>> get_policy("static", frequency_mhz=1200.0)
    """
    key = name.lower()
    if key not in _REGISTRY:
        raise KeyError(f"unknown policy {name!r}; available: "
                       f"{', '.join(available_policies())}")
    return _REGISTRY[key](hardware, **kwargs)


def available_policies() -> List[str]:
    return sorted(_REGISTRY)
