"""Hierarchical power-cap fleet coordination: per-node frequency *bands*.

The two extremes of cluster frequency control already exist in this repo:
fully-local closed loops (the paper's AGFT per node, no coordination) and
the fully-global single-frequency controller (``repro.policies.fleet``).
This module is the hierarchy in between — the same two-level shape
GreenLLM (arXiv:2508.16449) uses for SLO-aware cluster DVFS, applied to
the datacenter power-cap scenario:

* **Fleet level** (:class:`BandCoordinator`, FLEET_TICK cadence): split a
  cluster-wide power budget ``power_cap_w`` into per-node frequency bands
  ``[f_lo, f_hi]`` by load-weighted water-filling over recent per-node
  power draw. The per-node power budget maps to ``f_hi`` through the
  hardware's full-busy power curve (conservative: a node pinned at or
  below ``f_hi`` cannot exceed its budget even fully loaded, so the fleet
  cannot exceed the cap), and ``f_lo = f_hi - band_width`` leaves the
  node room to fine-tune downward.
* **Node level** (every iteration window): the node's own policy — AGFT,
  SLO, ondemand, static — keeps optimizing *inside* its band via the
  optional ``set_band(f_lo, f_hi)`` hook (``repro.policies.base``). AGFT
  masks LinUCB arms outside the band (statistics survive band changes);
  windowed rule policies clamp their decisions.

Band protocol (driver contract, ``repro.serving.driver``)
---------------------------------------------------------
A fleet policy that sets ``coordinates_bands = True`` exposes ``bands``
— a list of per-node ``(f_lo, f_hi)`` tuples (or ``None``) refreshed by
each ``act(engines, now)`` call. After every FLEET_TICK the event loop
propagates each band to the node's policy (``set_band``, when the policy
has the hook) and clamps the engine's *current* frequency into the band;
a band that excludes the running frequency therefore forces an immediate
DVFS transition, billed like any other (``freq_transitions_total``, plus
transition energy/stall when the hardware prices them). The optional
``initial_bands(engines)`` hook lets the coordinator cap the fleet from
t=0, before any telemetry exists.

Any fleet policy may also declare ``power_cap_w``: the event loop then
meters fleet power draw between consecutive FLEET_TICKs and accumulates
``cap_violation_s`` (seconds of tick intervals whose mean draw exceeded
the cap) — :class:`FleetPowerMeter` is the no-actuation carrier of that
attribute for measuring *uncoordinated* baselines under the same meter.

With ``power_cap_w=None`` the coordinator never produces bands, and node
policies with no band set make bit-identical decisions to the
uncoordinated run (``tests/golden_agft_decisions.json`` holds).

Usage::

    ServingCluster(cfg, n_nodes=4, policies=["agft"] * 4,
                   fleet_policy=get_policy("hierarchy", power_cap_w=800.0))
    python -m repro.launch.serve --nodes 4 --fleet-policy hierarchy \
        --power-cap-w 800 --policy agft
"""
from __future__ import annotations

from typing import List, Optional, Sequence, Tuple, Union

import numpy as np

from repro.energy.power_model import HardwareSpec
from repro.policies.registry import register_policy

Band = Tuple[float, float]
HardwareArg = Union[HardwareSpec, Sequence[HardwareSpec]]


def _primary_spec(hardware: HardwareArg) -> HardwareSpec:
    """First spec of a per-node list, or the spec itself — the fleet-policy
    registry convention: ``hardware`` may carry per-node specs for mixed
    fleets, and policies that govern one value per fleet use the first."""
    if isinstance(hardware, HardwareSpec):
        return hardware
    return list(hardware)[0]


def full_busy_power_w(spec: HardwareSpec, f_mhz: float) -> float:
    """Worst-case (fully busy, compute and memory pipelines saturated)
    node power draw at ``f_mhz`` — the same CMOS decomposition the DVFS
    model prices iterations with, at u_busy = u_mem = 1. Monotone in f,
    so budget -> frequency inverts by table lookup."""
    fr = min(max(f_mhz / spec.f_max, 1e-3), 1.0)
    return (spec.p_idle + spec.p_static_active
            + spec.p_dyn_compute * fr ** spec.alpha + spec.p_dyn_memory)


def waterfill(budget: float, weights: Sequence[float],
              demands: Sequence[float]) -> List[float]:
    """Classic water-filling: split ``budget`` proportionally to
    ``weights``, capping each share at ``demands[i]`` and redistributing
    the surplus among the uncapped until the budget (or every demand) is
    exhausted. Returns per-item allocations; sums to
    ``min(budget, sum(demands))`` up to float error."""
    n = len(weights)
    alloc = [0.0] * n
    active = [i for i in range(n) if demands[i] > 0.0]
    budget = max(float(budget), 0.0)
    while active and budget > 1e-9:
        wsum = sum(weights[i] for i in active)
        if wsum > 0.0:
            share = {i: budget * weights[i] / wsum for i in active}
        else:
            share = {i: budget / len(active) for i in active}
        capped = [i for i in active
                  if alloc[i] + share[i] >= demands[i] - 1e-12]
        if not capped:
            for i in active:
                alloc[i] += share[i]
            budget = 0.0
            break
        for i in capped:
            budget -= demands[i] - alloc[i]
            alloc[i] = demands[i]
            active.remove(i)
    # demands PRIORITIZE scarce budget, they don't waste slack: whatever
    # every demand left on the table flows back proportional to weights
    # (harmless over-provisioning — the frequency map saturates at f_max)
    if budget > 1e-9:
        wsum = sum(weights)
        for i in range(n):
            alloc[i] += (budget * weights[i] / wsum if wsum > 0.0
                         else budget / n)
    return alloc


@register_policy("hierarchy")
class BandCoordinator:
    """Fleet-scope power-cap coordinator: budget -> per-node bands.

    On each FLEET_TICK it reads one telemetry snapshot per node and

    1. weighs nodes by instantaneous load (running + waiting requests;
       uniform on the first tick or an idle fleet),
    2. caps each node's *demand* at ``ramp_headroom`` x its recent power
       draw (a quiet node releases budget to hungry peers but can still
       ramp geometrically, one headroom factor per tick), never below the
       physics floor ``full_busy_power_w(f_min)`` nor above
       ``full_busy_power_w(f_max)``,
    3. water-fills the cap over ``(weights, demands)`` on top of a
       ``p_idle`` floor per node (slack the demands leave behind flows
       back, so demand capping only bites when the budget is scarce), and
    4. maps each node budget to ``f_hi`` = the highest grid frequency
       whose full-busy draw fits the budget (conservative: the node
       cannot violate its budget even fully loaded). The cap is a
       one-sided constraint, so ``f_lo`` defaults to ``f_min`` — the node
       policy remains free to clock *down* to its EDP optimum; pass
       ``band_width_mhz`` to floor the band at ``f_hi - band_width``
       (latency protection at the price of energy).

    ``uniform=True`` degenerates to the fair capped *single-frequency*
    comparator: one ``f`` for every node with ``n * full_busy_power_w(f)
    <= power_cap_w`` and zero band width — the thing the hierarchy must
    beat on EDP (``benchmarks/tab_powercap.py``).

    ``power_cap_w=None`` disables actuation entirely (no bands are ever
    produced) so attaching the coordinator is decision-neutral.
    """

    scope = "fleet"
    coordinates_bands = True

    def __init__(self, hardware: HardwareArg,
                 power_cap_w: Optional[float] = None,
                 sampling_period_s: float = 0.8,
                 band_width_mhz: Optional[float] = None,
                 ramp_headroom: float = 2.0,
                 uniform: bool = False):
        # ``hardware`` may be one spec (homogeneous fleet, the historical
        # form) or a per-node spec list for mixed fleets. The primary spec
        # keeps the legacy attributes; per-spec inversion tables are built
        # lazily. ``act``/``initial_bands`` refresh the node->spec mapping
        # from the engines they are handed, so the constructor list is
        # only the pre-telemetry default.
        if isinstance(hardware, HardwareSpec):
            specs = [hardware]
        else:
            specs = list(hardware)
            if not specs:
                raise ValueError("empty per-node hardware list")
        self.hw = specs[0]
        self._node_specs: Optional[List[HardwareSpec]] = (
            specs if any(sp != self.hw for sp in specs) else None)
        self.power_cap_w = power_cap_w
        self.sampling_period_s = sampling_period_s
        self.band_width_mhz = (float(band_width_mhz)
                               if band_width_mhz is not None else None)
        self.ramp_headroom = float(ramp_headroom)
        self.uniform = uniform
        # budget -> frequency inversion table (power is monotone in f)
        self._grid = self.hw.frequencies()
        self._grid_power = np.array([full_busy_power_w(self.hw, f)
                                     for f in self._grid])
        self._p_fmin = float(self._grid_power[0])
        self._p_fmax = float(self._grid_power[-1])
        #: spec -> (grid, grid_power, p_fmin, p_fmax) for non-primary specs
        self._tables: dict = {}
        self.bands: Optional[List[Band]] = None
        self.history: List[dict] = []
        self._prev_energy: Optional[List[float]] = None
        self._prev_t: float = 0.0

    # ------------------------------------------------------------------
    def _table(self, spec: HardwareSpec):
        """Per-spec budget->frequency inversion table (mixed fleets)."""
        if spec == self.hw:
            return self._grid, self._grid_power, self._p_fmin, self._p_fmax
        tab = self._tables.get(spec)
        if tab is None:
            grid = spec.frequencies()
            gp = np.array([full_busy_power_w(spec, f) for f in grid])
            tab = (grid, gp, float(gp[0]), float(gp[-1]))
            self._tables[spec] = tab
        return tab

    def _f_for_budget(self, budget_w: float,
                      spec: Optional[HardwareSpec] = None) -> float:
        """Highest grid frequency whose full-busy draw fits the budget
        (f_min when even the floor doesn't fit — can't clock lower)."""
        if spec is None:
            grid, gp = self._grid, self._grid_power
        else:
            grid, gp, _, _ = self._table(spec)
        i = int(np.searchsorted(gp, budget_w + 1e-9, side="right")) - 1
        return grid[max(i, 0)]

    def _compute_bands(self, weights: List[float],
                       draws: List[Optional[float]],
                       down: Optional[List[bool]] = None,
                       specs: Optional[List[HardwareSpec]] = None
                       ) -> List[Optional[Band]]:
        """``down`` (fault injection, ``repro.serving.faults``) excludes
        dead nodes from the water-fill: their weight, demand, and idle
        floor are zero, so the whole budget re-spreads over survivors
        within this tick, and their band is None (nothing to govern).
        With ``down=None`` (or no node down) the arithmetic is exactly
        the historical healthy-fleet path.

        ``specs`` (or the stored node->spec mapping) switches the mixed-
        fleet path on: per-node idle floors, per-spec demand envelopes,
        and per-spec budget->frequency inversion. A homogeneous fleet
        takes the historical single-table arithmetic unchanged (the
        ``n_up * floor`` budget expression is kept verbatim — summing n
        identical floors would round differently)."""
        n = len(weights)
        cap = float(self.power_cap_w)
        if down is not None and not any(down):
            down = None
        specs = specs if specs is not None else self._node_specs
        hetero = (specs is not None
                  and any(sp != self.hw for sp in specs))
        if self.uniform:
            n_up = n if down is None else n - sum(down)
            if hetero:
                # fair capped comparator on a mixed fleet: the same
                # per-node power budget, inverted through each node's own
                # full-busy curve
                fs = [self._f_for_budget(cap / max(n_up, 1), sp)
                      for sp in specs]
                if down is None:
                    return [(f, f) for f in fs]
                return [None if d else (f, f)
                        for f, d in zip(fs, down)]
            f = self._f_for_budget(cap / max(n_up, 1))
            if down is None:
                return [(f, f)] * n
            return [None if d else (f, f) for d in down]
        n_up = n if down is None else n - sum(down)
        if hetero:
            floors = []
            for i in range(n):
                if down is not None and down[i]:
                    floors.append(0.0)
                else:
                    floors.append(min(specs[i].p_idle,
                                      cap / max(n_up, 1)))
        else:
            floor = min(self.hw.p_idle, cap / max(n_up, 1))
            floors = None
        demands = []
        for i, d in enumerate(draws):
            if down is not None and down[i]:
                demands.append(0.0)
                continue
            if hetero:
                _, _, p_fmin_i, p_fmax_i = self._table(specs[i])
                floor_i = floors[i]
            else:
                p_fmin_i, p_fmax_i, floor_i = \
                    self._p_fmin, self._p_fmax, floor
            demand = p_fmax_i
            if d is not None:
                demand = min(demand,
                             max(d * self.ramp_headroom, p_fmin_i))
            demands.append(max(demand - floor_i, 0.0))
        if down is not None:
            weights = [0.0 if dn else w for w, dn in zip(weights, down)]
            if all(w <= 0 for w in weights):
                weights = [0.0 if dn else 1.0 for dn in down]
        elif all(w <= 0 for w in weights):
            weights = [1.0] * n
        budget = (cap - sum(floors) if hetero
                  else cap - n_up * floor)
        extra = waterfill(budget, weights, demands)
        bands: List[Optional[Band]] = []
        for i, a in enumerate(extra):
            if down is not None and down[i]:
                bands.append(None)
                continue
            if hetero:
                sp_i = specs[i]
                hi = self._f_for_budget(floors[i] + a, sp_i)
            else:
                sp_i = self.hw
                hi = self._f_for_budget(floor + a)
            lo = (sp_i.f_min if self.band_width_mhz is None
                  else max(sp_i.f_min, hi - self.band_width_mhz))
            bands.append((lo, hi))
        return bands

    # ------------------------------------------------------------------
    def _engine_specs(self, engines) -> Optional[List[HardwareSpec]]:
        """Refresh the node->spec mapping from live engines (authoritative
        over the constructor default — per-node placement is the loop's)."""
        specs = [getattr(e, "hardware", self.hw) for e in engines]
        self._node_specs = (specs if any(sp != self.hw for sp in specs)
                            else None)
        return self._node_specs

    def initial_bands(self, engines) -> Optional[List[Band]]:
        """Telemetry-free bands for t=0 (uniform weights, unconstrained
        demands) so the fleet is capped from the first event, not from
        the first tick."""
        if self.power_cap_w is None or not len(engines):
            return None
        n = len(engines)
        return self._compute_bands([1.0] * n, [None] * n,
                                   specs=self._engine_specs(engines))

    def act(self, engines, now: float) -> Optional[float]:
        """FLEET_TICK: refresh ``self.bands`` (the event loop propagates
        them to node policies and engines). Returns None — the
        coordinator never sets a single fleet frequency itself."""
        snaps = [e.metrics.snapshot() for e in engines]
        energy = [s["vllm:energy_joules_total"] for s in snaps]
        if self.power_cap_w is None:
            return None
        n = len(engines)
        draws: List[Optional[float]] = [None] * n
        if self._prev_energy is not None \
                and len(self._prev_energy) == n and now > self._prev_t:
            dt = now - self._prev_t
            draws = [(e1 - e0) / dt
                     for e0, e1 in zip(self._prev_energy, energy)]
        weights = [float(s["vllm:num_requests_running"]
                         + s["vllm:num_requests_waiting"]) for s in snaps]
        self._prev_energy, self._prev_t = energy, now
        # fault injection: dead nodes leave the water-fill — the power
        # budget re-spreads over survivors within this tick (their draw
        # history is also voided so recovery doesn't ramp off stale watts)
        down = [getattr(e, "fault_state", None) is not None
                and e.fault_state.down for e in engines]
        if any(down):
            draws = [None if dn else d for d, dn in zip(draws, down)]
        self.bands = self._compute_bands(
            weights, draws, down=down,
            specs=self._engine_specs(engines))
        self.history.append({
            "t": now,
            "bands": list(self.bands),
            "weights": weights,
            "node_power_w": draws,
            "fleet_power_w": (sum(d for d in draws if d is not None)
                              if any(d is not None for d in draws)
                              else None),
        })
        return None

    def maybe_act(self, engine) -> Optional[float]:
        raise TypeError(
            "BandCoordinator is fleet-scope: attach it with "
            "ServingCluster(..., fleet_policy=...), not as a per-node "
            "policy")


@register_policy("hierarchy-uniform")
def make_uniform_coordinator(hardware: HardwareArg,
                             **kwargs) -> BandCoordinator:
    """The capped single-frequency comparator: ``get_policy(
    "hierarchy-uniform", power_cap_w=...)`` == ``get_policy("hierarchy",
    uniform=True, ...)`` — one fleet-wide frequency meeting the cap, no
    per-node bands, no room for node-local fine-tuning."""
    if kwargs.pop("uniform", True) is not True:
        raise ValueError("hierarchy-uniform is fixed to uniform=True")
    return BandCoordinator(hardware, uniform=True, **kwargs)


make_uniform_coordinator.scope = "fleet"


@register_policy("fleet-meter")
class FleetPowerMeter:
    """Observe-only fleet policy: carries ``power_cap_w`` so the event
    loop meters fleet draw and cap-violation seconds on FLEET_TICKs, but
    never actuates — attach it to *uncoordinated* runs (per-node AGFT, no
    coordinator) to measure what they do to a power budget under exactly
    the same meter as the hierarchy (``benchmarks/tab_powercap.py``)."""

    scope = "fleet"
    coordinates_bands = False
    #: never actuates — per-node policies stay in charge of their engines
    observe_only = True

    def __init__(self, hardware: HardwareArg,
                 power_cap_w: Optional[float] = None,
                 sampling_period_s: float = 0.8):
        self.hw = _primary_spec(hardware)
        self.power_cap_w = power_cap_w
        self.sampling_period_s = sampling_period_s

    def act(self, engines, now: float) -> Optional[float]:
        return None

    def maybe_act(self, engine) -> Optional[float]:
        raise TypeError(
            "FleetPowerMeter is fleet-scope: attach it with "
            "ServingCluster(..., fleet_policy=...), not as a per-node "
            "policy")
