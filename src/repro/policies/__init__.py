"""Pluggable power policies: interchangeable GPU-frequency controllers.

Every controller implements the :class:`PowerPolicy` protocol — a single
``maybe_act(engine) -> Optional[float]`` hook the shared drive loop
(``repro.serving.driver``) calls after each engine step. Policies observe
the engine exclusively through the Prometheus-boundary telemetry window
(:class:`repro.core.monitor.TelemetryMonitor` -> ``WindowStats``) and
actuate exclusively through ``engine.set_frequency`` — the paper's
non-invasive contract, now enforced for *all* baselines so comparisons
(paper Tables 2/3, GreenLLM-style SLO control, OS governors) run on equal
footing over the same trace.

Built-in registry entries
-------------------------
``agft``            the paper's contextual-bandit tuner (LinUCB + pruning
                    + refinement + Page-Hinkley convergence)
``agft-switchcost`` AGFT with DVFS transitions priced into the reward
                    (switching-aware bandits, arXiv:2410.11855)
``agft-2d``         phase-disaggregated AGFT: learns a ``(f_prefill,
                    f_decode)`` pair over a pruned product action space
                    seeded around the analytic per-phase EDP optima
                    (GreenLLM, arXiv:2508.16449; see
                    ``repro.core.tuner2d`` / ``repro.policies.phased``)
``greenllm-rule``   static per-phase clocks from the same analytic sweep —
                    the rule comparator for the 2-D surface (event-loop
                    mode only; batched mode refuses phased policies)
``static``          one fixed frequency for the whole run (locked clocks)
``oracle``          best *fixed* frequency from an offline EDP sweep
``ondemand``        utilization-threshold rule DVFS (Linux ondemand style)
``slo``             latency-budget AIMD feedback controller
                    (GreenLLM-style); ``mode="ttft"`` budgets first-token
                    latency instead of TPOT
``slo-ttft``        shorthand for ``slo`` in TTFT-budget mode
``observer``        records telemetry windows, never actuates (exact
                    baseline time series for phase benchmarks)
``global``          FLEET scope: one frequency for all nodes, an inner
                    policy (default agft) driven by fleet-aggregated
                    telemetry — attach via ``ServingCluster(...,
                    fleet_policy="global")`` (see ``repro.policies.fleet``)
``hierarchy``       FLEET scope: power-cap coordinator — water-fills a
                    cluster power budget (``power_cap_w``) into per-node
                    frequency bands on FLEET_TICK while node-local
                    policies fine-tune inside them via the optional
                    ``set_band`` hook (see ``repro.policies.hierarchy``)
``hierarchy-uniform``  FLEET scope: the capped single-frequency
                    comparator (``hierarchy`` with ``uniform=True``)
``fleet-meter``     FLEET scope: observe-only carrier of ``power_cap_w``
                    so uncoordinated runs are metered for cap violations
                    under the same event-loop meter as the hierarchy

Registering a new policy
------------------------
Subclass :class:`WindowedPolicy` (or provide any object with
``maybe_act``) and register a factory taking ``(hardware, **kwargs)``::

    from repro.policies import WindowedPolicy, register_policy

    @register_policy("powersave")
    class PowersavePolicy(WindowedPolicy):
        phase_name = "powersave"
        def decide(self, window, engine):
            return self.hw.f_min

    get_policy("powersave")                    # constructs with defaults
    get_policy("powersave", sampling_period_s=0.4)

Classes register directly because they are callable with the factory
signature; plain functions work too (see ``agft.py``). Names are
case-insensitive and must be unique. Per-node heterogeneous mixes are
first-class: ``ServingCluster(..., policies=["agft", "slo", None])``
resolves names through this registry.
"""
from repro.policies.base import (PowerPolicy, TelemetryRecorder,
                                 WindowedPolicy)
from repro.policies.registry import (available_policies, get_policy,
                                     register_policy)
from repro.policies.fixed import (OracleFixedPolicy, StaticPolicy,
                                  snap_to_grid)
from repro.policies.rules import OndemandPolicy, SLOAwareLatencyPolicy
from repro.policies.agft import make_agft, make_agft_switchcost
from repro.policies.phased import GreenLLMRulePolicy, make_agft_2d
from repro.policies.fleet import (FleetPolicy, FleetTelemetryView,
                                  GlobalFrequencyPolicy)
from repro.policies.hierarchy import (BandCoordinator, FleetPowerMeter,
                                      full_busy_power_w, waterfill)

__all__ = ["PowerPolicy", "WindowedPolicy", "TelemetryRecorder",
           "available_policies", "get_policy", "register_policy",
           "StaticPolicy", "OracleFixedPolicy", "OndemandPolicy",
           "SLOAwareLatencyPolicy", "make_agft", "make_agft_switchcost",
           "make_agft_2d", "GreenLLMRulePolicy",
           "snap_to_grid", "FleetPolicy", "FleetTelemetryView",
           "GlobalFrequencyPolicy", "BandCoordinator", "FleetPowerMeter",
           "full_busy_power_w", "waterfill"]
