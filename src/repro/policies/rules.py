"""Reactive rule-based governors: utilization-threshold DVFS and an
SLO-aware latency feedback controller.

These are the competing controllers the paper's evaluation is implicitly
measured against: ``ondemand`` is the classic OS governor (scales with raw
utilization, blind to the serving phase mix), ``slo`` is a GreenLLM-style
(arXiv:2508.16449) TPOT-budget controller — minimize frequency subject to
a latency budget, with AIMD dynamics (additive down-steps while the budget
has headroom, multiplicative recovery on violation).
"""
from __future__ import annotations

from typing import Optional

from repro.energy.power_model import HardwareSpec
from repro.policies.base import WindowedPolicy
from repro.policies.fixed import snap_to_grid
from repro.policies.registry import register_policy


@register_policy("ondemand")
class OndemandPolicy(WindowedPolicy):
    """Linux-ondemand-style governor on the telemetry window.

    util = busy_s / window duration. Above ``up_threshold`` jump straight
    to f_max; below it scale the target proportionally (f_max * util /
    up_threshold). Phase-blind by construction: a fully-busy memory-bound
    decode window looks identical to a compute-bound prefill window, so it
    never finds the interior EDP optimum — exactly the failure mode that
    motivates AGFT.
    """

    phase_name = "ondemand"

    def __init__(self, hardware: HardwareSpec,
                 up_threshold: float = 0.8,
                 sampling_period_s: float = 0.8):
        super().__init__(hardware, sampling_period_s)
        self.up_threshold = up_threshold

    def decide(self, window, engine) -> Optional[float]:
        if window is None:
            return self.hw.f_max
        util = window.busy_s / max(window.duration_s, 1e-9)
        if util >= self.up_threshold:
            return self.hw.f_max
        return snap_to_grid(self.hw.f_max * util / self.up_threshold,
                            self.hw)


@register_policy("slo")
class SLOAwareLatencyPolicy(WindowedPolicy):
    """TPOT-budget feedback controller (GreenLLM-style).

    Tracks the window's effective TPOT against a budget and walks the
    frequency down while latency has headroom, recovering multiplicatively
    on violation (latency safety beats energy). The budget is either given
    explicitly (``tpot_slo_s``) or self-calibrated as ``(1 +
    overhead_budget)`` x the first productive window's TPOT at the initial
    (default f_max) frequency — i.e. "spend at most the paper's <10%
    latency overhead".
    """

    phase_name = "slo"

    def __init__(self, hardware: HardwareSpec,
                 tpot_slo_s: Optional[float] = None,
                 overhead_budget: float = 0.10,
                 headroom: float = 0.9,
                 down_step_mhz: Optional[float] = None,
                 boost: float = 1.25,
                 sampling_period_s: float = 0.8):
        super().__init__(hardware, sampling_period_s)
        self.tpot_slo_s = tpot_slo_s
        self.overhead_budget = overhead_budget
        self.headroom = headroom
        self.down_step_mhz = down_step_mhz or 2 * hardware.f_step
        self.boost = boost

    def decide(self, window, engine) -> Optional[float]:
        if window is None or window.generation_tokens <= 0:
            return None
        tpot = window.effective_tpot
        if self.tpot_slo_s is None:
            # calibrate the budget off the reference window and hold
            self.tpot_slo_s = tpot * (1.0 + self.overhead_budget)
            return None
        f = engine.frequency
        if tpot > self.tpot_slo_s:
            # violation: multiplicative recovery (at least two grid steps)
            return snap_to_grid(max(f * self.boost,
                                    f + 2 * self.hw.f_step), self.hw)
        if tpot < self.headroom * self.tpot_slo_s:
            # headroom: additive decrease toward the energy-optimal floor
            return snap_to_grid(f - self.down_step_mhz, self.hw)
        return None
