"""Reactive rule-based governors: utilization-threshold DVFS and an
SLO-aware latency feedback controller.

These are the competing controllers the paper's evaluation is implicitly
measured against: ``ondemand`` is the classic OS governor (scales with raw
utilization, blind to the serving phase mix), ``slo`` is a GreenLLM-style
(arXiv:2508.16449) TPOT-budget controller — minimize frequency subject to
a latency budget, with AIMD dynamics (additive down-steps while the budget
has headroom, multiplicative recovery on violation).

Both are band-governable (``WindowedPolicy.set_band``): under a
hierarchical power-cap coordinator their decisions — including ondemand's
jump-to-f_max and the SLO controller's multiplicative boost — are clamped
into the fleet-assigned ``[f_lo, f_hi]``; the band's upper edge wins over
latency recovery because the cap is a hard datacenter constraint.
"""
from __future__ import annotations

from typing import Optional

from repro.energy.power_model import HardwareSpec
from repro.policies.base import WindowedPolicy
from repro.policies.fixed import snap_to_grid
from repro.policies.registry import register_policy


@register_policy("ondemand")
class OndemandPolicy(WindowedPolicy):
    """Linux-ondemand-style governor on the telemetry window.

    util = busy_s / window duration. Above ``up_threshold`` jump straight
    to f_max; below it scale the target proportionally (f_max * util /
    up_threshold). Phase-blind by construction: a fully-busy memory-bound
    decode window looks identical to a compute-bound prefill window, so it
    never finds the interior EDP optimum — exactly the failure mode that
    motivates AGFT.
    """

    phase_name = "ondemand"

    def __init__(self, hardware: HardwareSpec,
                 up_threshold: float = 0.8,
                 sampling_period_s: float = 0.8):
        super().__init__(hardware, sampling_period_s)
        self.up_threshold = up_threshold

    def decide(self, window, engine) -> Optional[float]:
        if window is None:
            return self.hw.f_max
        util = window.busy_s / max(window.duration_s, 1e-9)
        if util >= self.up_threshold:
            return self.hw.f_max
        return snap_to_grid(self.hw.f_max * util / self.up_threshold,
                            self.hw)


@register_policy("slo")
class SLOAwareLatencyPolicy(WindowedPolicy):
    """Latency-budget feedback controller (GreenLLM-style), in one of two
    budget modes:

    ``mode="tpot"`` (default) tracks the window's effective TPOT;
    ``mode="ttft"`` tracks the window's mean first-token latency, measured
    from the scheduler's exact first-token counters (no float-equality
    replay) — the budget that matters for interactive front-ends whose
    SLO is on responsiveness rather than streaming rate.

    Either way the controller walks the frequency down while the budgeted
    latency has headroom and recovers multiplicatively on violation
    (latency safety beats energy). The budget is either given explicitly
    (``tpot_slo_s`` / ``ttft_slo_s``) or self-calibrated as ``(1 +
    overhead_budget)`` x the first productive window's value at the
    initial (default f_max) frequency — i.e. "spend at most the paper's
    <10% latency overhead".
    """

    phase_name = "slo"

    def __init__(self, hardware: HardwareSpec,
                 tpot_slo_s: Optional[float] = None,
                 overhead_budget: float = 0.10,
                 headroom: float = 0.9,
                 down_step_mhz: Optional[float] = None,
                 boost: float = 1.25,
                 sampling_period_s: float = 0.8,
                 mode: str = "tpot",
                 ttft_slo_s: Optional[float] = None):
        if mode not in ("tpot", "ttft"):
            raise ValueError(f"mode must be 'tpot' or 'ttft', got {mode!r}")
        super().__init__(hardware, sampling_period_s)
        self.mode = mode
        self.tpot_slo_s = tpot_slo_s
        self.ttft_slo_s = ttft_slo_s
        self.overhead_budget = overhead_budget
        self.headroom = headroom
        self.down_step_mhz = down_step_mhz or 2 * hardware.f_step
        self.boost = boost

    # ------------------------------------------------------------------
    def _budgeted_latency(self, window) -> Optional[float]:
        """The window's value of the budgeted metric, or None if the
        window produced no samples of it."""
        if self.mode == "ttft":
            # mean_ttft_s is 0 when no request produced its first token
            # in this window — no signal, no decision
            return window.mean_ttft_s if window.mean_ttft_s > 0 else None
        if window.generation_tokens <= 0:
            return None
        return window.effective_tpot

    def _budget(self) -> Optional[float]:
        return self.ttft_slo_s if self.mode == "ttft" else self.tpot_slo_s

    def _calibrate(self, value: float) -> None:
        budget = value * (1.0 + self.overhead_budget)
        if self.mode == "ttft":
            self.ttft_slo_s = budget
        else:
            self.tpot_slo_s = budget

    def decide(self, window, engine) -> Optional[float]:
        if window is None:
            return None
        lat = self._budgeted_latency(window)
        if lat is None:
            return None
        budget = self._budget()
        if budget is None:
            # calibrate the budget off the reference window and hold
            self._calibrate(lat)
            return None
        f = engine.frequency
        if lat > budget:
            # violation: multiplicative recovery (at least two grid steps)
            return snap_to_grid(max(f * self.boost,
                                    f + 2 * self.hw.f_step), self.hw)
        if lat < self.headroom * budget:
            # headroom: additive decrease toward the energy-optimal floor
            return snap_to_grid(f - self.down_step_mhz, self.hw)
        return None


@register_policy("slo-ttft")
def make_slo_ttft(hardware: HardwareSpec, **kwargs
                  ) -> SLOAwareLatencyPolicy:
    """TTFT-budget convenience entry: ``get_policy("slo-ttft")`` ==
    ``get_policy("slo", mode="ttft")``. A redundant ``mode="ttft"`` kwarg
    is tolerated; any other mode is rejected."""
    mode = kwargs.pop("mode", "ttft")
    if mode != "ttft":
        raise ValueError(f"slo-ttft is fixed to mode='ttft', got {mode!r}")
    return SLOAwareLatencyPolicy(hardware, mode="ttft", **kwargs)
