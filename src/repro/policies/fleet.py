"""Fleet-scope power policies: one controller, many engines.

The ROADMAP's cross-node coordination baseline: a cluster-global
controller that sets a SINGLE frequency for every node, driven by
fleet-aggregated telemetry — the thing to beat for per-node closed loops
(which can converge to different per-node optima under segregated
traffic). GreenLLM-style SLO budgeting and the paper's AGFT loop both
slot in unchanged as the *inner* decision rule, because the fleet is
exposed to them through :class:`FleetTelemetryView` — an aggregate-engine
facade satisfying the same ``clock``/``metrics.snapshot()``/
``set_frequency`` surface a single engine offers, with counters summed
across nodes (:func:`repro.core.monitor.aggregate_snapshots`).

Fleet policies declare ``scope = "fleet"`` and implement
``act(engines, now)``; the event loop (``repro.serving.driver``) calls
them on FLEET_TICK events every ``sampling_period_s`` sim-seconds, where
``now`` is the loop's coherent virtual time across all nodes. Attach via
``ServingCluster(..., fleet_policy="global")``.
"""
from __future__ import annotations

from typing import List, Optional, Protocol, runtime_checkable

import numpy as np

from repro.core.monitor import aggregate_snapshots
from repro.energy.power_model import HardwareSpec
from repro.policies.registry import get_policy, register_policy


@runtime_checkable
class FleetPolicy(Protocol):
    """Structural interface of a cluster-global frequency controller."""

    #: FLEET_TICK cadence in sim-seconds
    sampling_period_s: float

    def act(self, engines, now: float) -> Optional[float]:
        """Observe the fleet (aggregate telemetry only) and optionally set
        every engine's frequency; return the actuated frequency, else
        ``None``."""
        ...


class _AggregateMetrics:
    """``metrics.snapshot()`` shim summing every engine's exporter."""

    def __init__(self, engines):
        self._engines = engines

    def snapshot(self):
        return aggregate_snapshots([e.metrics.snapshot()
                                    for e in self._engines])


class FleetTelemetryView:
    """Aggregate-engine facade: looks like one engine, is the whole fleet.

    ``clock`` is the event loop's virtual time (set by the fleet policy at
    each tick), ``metrics.snapshot()`` sums the nodes' counters, and
    ``set_frequency`` broadcasts — so any per-node policy (AGFT, SLO,
    ondemand, static) governs the fleet unmodified. Unknown attributes
    delegate to the first engine (model/engine config for analytic
    sweeps), which is sound for the homogeneous fleets ``ServingCluster``
    builds.
    """

    def __init__(self, engines):
        self.engines = list(engines)
        self.clock = 0.0
        self.metrics = _AggregateMetrics(self.engines)

    @property
    def frequency(self) -> float:
        return float(np.mean([e.frequency for e in self.engines]))

    def set_frequency(self, f_mhz: float) -> None:
        for e in self.engines:
            e.set_frequency(f_mhz)

    def __getattr__(self, name):
        return getattr(self.engines[0], name)


@register_policy("global")
class GlobalFrequencyPolicy:
    """Fleet-wide single-frequency controller (cross-node baseline).

    Wraps an *inner* per-node-style policy (registry name or instance;
    default the paper's ``agft`` tuner) and runs it against the
    :class:`FleetTelemetryView`, so one closed loop learns one frequency
    for the whole cluster from summed telemetry::

        get_policy("global")                          # global AGFT
        get_policy("global", inner="slo")             # global SLO budget
        get_policy("global", inner="static", frequency_mhz=1200.0)

    Extra kwargs construct the inner policy. Compare against
    ``ServingCluster(policies=["agft", ...])`` on the same trace to
    quantify what per-node loops buy (``benchmarks.tab_fleet``).
    """

    scope = "fleet"

    def __init__(self, hardware: HardwareSpec, inner="agft",
                 sampling_period_s: float = 0.8, **inner_kwargs):
        # fleet-policy registry convention: ``hardware`` may be a per-node
        # spec list on mixed fleets; a single global frequency is governed
        # against the primary (first) spec
        if not isinstance(hardware, HardwareSpec):
            hardware = list(hardware)[0]
        if isinstance(inner, str):
            inner = get_policy(inner, hardware=hardware,
                               sampling_period_s=sampling_period_s,
                               **inner_kwargs)
        elif inner_kwargs:
            raise TypeError("inner_kwargs only apply when `inner` is a "
                            "registry name")
        self.hw = hardware
        self.inner = inner
        self.sampling_period_s = sampling_period_s
        self.view: Optional[FleetTelemetryView] = None

    # ------------------------------------------------------------------
    def act(self, engines, now: float) -> Optional[float]:
        if self.view is None or self.view.engines != list(engines):
            self.view = FleetTelemetryView(engines)
        self.view.clock = now
        return self.inner.maybe_act(self.view)

    def maybe_act(self, engine) -> Optional[float]:
        raise TypeError(
            "GlobalFrequencyPolicy is fleet-scope: attach it with "
            "ServingCluster(..., fleet_policy=...), not as a per-node "
            "policy")

    # ------------------------------------------------------------------
    @property
    def history(self) -> List[dict]:
        """Per-window decision history, recorded by the inner policy."""
        return self.inner.history
