"""Fixed-frequency policies: static pinning and the offline-oracle fix.

``StaticPolicy`` is the classic "locked clocks" baseline (nvidia-smi -lgc).
``OracleFixedPolicy`` is the paper's "theoretical optimum" comparator: the
best *fixed* frequency from an offline EDP sweep. Pass the swept value in
(e.g. from ``benchmarks.common.two_stage_optimal``); when none is given it
sweeps the hardware grid analytically with the engine's own DVFS cost
model over a representative mixed continuous-batching iteration.
"""
from __future__ import annotations

from typing import Optional

from repro.energy.costs import iteration_cost
from repro.energy.power_model import DVFSModel, HardwareSpec
from repro.policies.base import WindowedPolicy
from repro.policies.registry import register_policy


def snap_to_grid(f_mhz: float, hw: HardwareSpec) -> float:
    """Clamp to the envelope and round onto the native frequency grid."""
    f = min(max(f_mhz, hw.f_min), hw.f_max)
    steps = round((f - hw.f_min) / hw.f_step)
    return min(hw.f_min + steps * hw.f_step, hw.f_max)


@register_policy("static")
class StaticPolicy(WindowedPolicy):
    """Pin one frequency for the whole run.

    Default is 0.7 x f_max snapped to the grid — inside the band where the
    paper's offline optima land (Fig. 6: 1200-1410 of 1800 MHz).
    """

    phase_name = "static"

    def __init__(self, hardware: HardwareSpec,
                 frequency_mhz: Optional[float] = None,
                 sampling_period_s: float = 0.8):
        super().__init__(hardware, sampling_period_s)
        self.frequency_mhz = snap_to_grid(
            frequency_mhz if frequency_mhz is not None
            else 0.7 * hardware.f_max, hardware)

    def decide(self, window, engine):
        return self.frequency_mhz


@register_policy("oracle")
class OracleFixedPolicy(StaticPolicy):
    """Best fixed frequency from an offline sweep.

    With an explicit ``frequency_mhz`` (measured sweep optimum) this is a
    relabelled StaticPolicy. Without one it runs the sweep analytically on
    first contact with the engine: per-iteration EDP = P(f) * t(f)^2 over
    the full frequency grid, priced by the engine backend's DVFS model on a
    decode-dominant mixed iteration (``decode_frac`` of the seq budget
    decoding at ``avg_context``, one prefill chunk in flight).
    """

    phase_name = "oracle"

    def __init__(self, hardware: HardwareSpec,
                 frequency_mhz: Optional[float] = None,
                 sampling_period_s: float = 0.8,
                 decode_frac: float = 0.5, avg_context: float = 1024.0,
                 prefill_chunk: int = 256):
        WindowedPolicy.__init__(self, hardware, sampling_period_s)
        self.frequency_mhz = (snap_to_grid(frequency_mhz, hardware)
                              if frequency_mhz is not None else None)
        self.decode_frac = decode_frac
        self.avg_context = avg_context
        self.prefill_chunk = prefill_chunk

    def decide(self, window, engine):
        if self.frequency_mhz is None:
            self.frequency_mhz = self._sweep(engine)
        return self.frequency_mhz

    def _sweep(self, engine) -> float:
        cfg = engine.model_cfg
        dvfs = getattr(engine.backend, "dvfs", None) or DVFSModel(self.hw)
        decode_seqs = max(int(self.decode_frac * engine.cfg.max_num_seqs), 1)
        fd, md = iteration_cost(cfg, prefill_tokens=0,
                                decode_seqs=decode_seqs,
                                avg_context=self.avg_context)
        fp, mp = iteration_cost(cfg, prefill_tokens=self.prefill_chunk,
                                decode_seqs=0,
                                avg_context=self.prefill_chunk / 2)
        flops, mem = fd + fp, md + mp
        # under a fleet-assigned band, sweep inside it: the in-band EDP
        # optimum generally differs from the unconstrained optimum clamped
        # to the band edge (a grid-free band falls back to the base clamp)
        grid = self.hw.frequencies()
        if self.band is not None:
            in_band = [f for f in grid
                       if self.band[0] - 1e-9 <= f <= self.band[1] + 1e-9]
            grid = in_band or grid
        best_f, best_edp = self.hw.f_max, float("inf")
        for f in grid:
            t, p = dvfs.iteration_time_power(flops, mem, f)
            edp = p * t * t
            if edp < best_edp:
                best_f, best_edp = f, edp
        return float(best_f)
