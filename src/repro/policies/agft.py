"""Registry entry for the paper's tuner: AGFT *is* a PowerPolicy.

``AGFTTuner`` already conforms structurally (``maybe_act(engine) ->
Optional[float]``, telemetry via the shared ``TelemetryMonitor``); this
module only adapts its constructor signature to the registry's
``(hardware, **kwargs)`` convention.
"""
from __future__ import annotations

from typing import Optional

from repro.core.tuner import AGFTConfig, AGFTTuner
from repro.energy.power_model import HardwareSpec
from repro.policies.registry import register_policy


@register_policy("agft")
def make_agft(hardware: HardwareSpec, cfg: Optional[AGFTConfig] = None,
              **kwargs) -> AGFTTuner:
    """``get_policy("agft")`` | ``get_policy("agft", cfg=AGFTConfig(...))``
    | ``get_policy("agft", strategy="thompson", ...)`` — extra kwargs are
    AGFTConfig fields."""
    if cfg is not None and kwargs:
        raise TypeError("pass either cfg= or AGFTConfig field kwargs")
    return AGFTTuner(hardware, cfg or AGFTConfig(**kwargs))
