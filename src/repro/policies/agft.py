"""Registry entries for the paper's tuner: AGFT *is* a PowerPolicy.

``AGFTTuner`` already conforms structurally (``maybe_act(engine) ->
Optional[float]``, the ``tick`` hook for pure POLICY_TICK scheduling,
telemetry via the shared ``TelemetryMonitor``, and the optional band hook
``set_band(f_lo, f_hi)`` — implemented by masking LinUCB arms outside the
fleet-assigned band, see ``repro.policies.hierarchy``); this module only
adapts its constructor signature to the registry's ``(hardware,
**kwargs)`` convention, plus two ablation variants: the fault-naive
learner (``agft-naive``) and the switching-cost-aware reward
(``agft-switchcost``). The phase-disaggregated 2-D variant (``agft-2d``)
lives in ``repro.policies.phased`` with its rule comparator.
"""
from __future__ import annotations

import dataclasses
from typing import Optional

from repro.core.tuner import AGFTConfig, AGFTTuner
from repro.energy.power_model import HardwareSpec
from repro.policies.registry import register_policy

#: default DVFS transition price for ``agft-switchcost`` when the hardware
#: spec doesn't declare one: ~an A6000-class board stalling O(10 ms) at
#: near-busy power per PLL relock, plus the pipeline-refill glitch —
#: conservative but the right order (switching-aware bandits,
#: arXiv:2410.11855, price exactly this regularizer).
DEFAULT_SWITCH_COST_J = 15.0


@register_policy("agft")
def make_agft(hardware: HardwareSpec, cfg: Optional[AGFTConfig] = None,
              **kwargs) -> AGFTTuner:
    """``get_policy("agft")`` | ``get_policy("agft", cfg=AGFTConfig(...))``
    | ``get_policy("agft", strategy="thompson", ...)`` — extra kwargs are
    AGFTConfig fields."""
    if cfg is not None and kwargs:
        raise TypeError("pass either cfg= or AGFTConfig field kwargs")
    return AGFTTuner(hardware, cfg or AGFTConfig(**kwargs))


@register_policy("agft-naive")
def make_agft_naive(hardware: HardwareSpec,
                    cfg: Optional[AGFTConfig] = None,
                    **kwargs) -> AGFTTuner:
    """AGFT with graceful degradation disabled (``fault_aware=False``):
    under fault injection (``repro.serving.faults``) it credits faulted
    and stale telemetry windows into the LinUCB bank and never re-issues
    stuck actuations — the poisoned-feedback baseline the resilient
    tuner is measured against in ``benchmarks/tab_faults.py``. On a
    healthy engine it is exactly ``agft``."""
    if cfg is not None and kwargs:
        raise TypeError("pass either cfg= or AGFTConfig field kwargs")
    cfg = cfg or AGFTConfig(**kwargs)
    return AGFTTuner(hardware,
                     dataclasses.replace(cfg, fault_aware=False))


@register_policy("agft-switchcost")
def make_agft_switchcost(hardware: HardwareSpec,
                         switch_cost_j: Optional[float] = None,
                         cfg: Optional[AGFTConfig] = None,
                         **kwargs) -> AGFTTuner:
    """AGFT with a switching-cost-aware reward: frequency *changes* are
    billed ``switch_cost_j`` joules into the credited window's EDP, so the
    bandit learns to hold its operating point unless moving pays for the
    transition. The cost defaults to the hardware spec's
    ``dvfs_transition_cost_j`` when it prices transitions, else
    ``DEFAULT_SWITCH_COST_J``."""
    if cfg is not None and kwargs:
        raise TypeError("pass either cfg= or AGFTConfig field kwargs")
    cost = (switch_cost_j if switch_cost_j is not None
            else (hardware.dvfs_transition_cost_j or DEFAULT_SWITCH_COST_J))
    cfg = cfg or AGFTConfig(**kwargs)
    cfg = dataclasses.replace(
        cfg, reward=dataclasses.replace(cfg.reward, switch_cost_j=cost))
    return AGFTTuner(hardware, cfg)
