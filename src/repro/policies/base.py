"""PowerPolicy protocol and the windowed-policy base class.

A power policy is anything with ``maybe_act(engine) -> Optional[float]``:
called after every engine step, it may read the engine's aggregate metrics
and actuate ``engine.set_frequency``; it returns the chosen frequency when
it acts and ``None`` otherwise. The shared event loop
(``repro.serving.driver``) calls nothing else, so AGFT, rule-based
governors and SLO controllers are interchangeable behind this boundary.

Policies carry a ``scope`` class attribute: ``"node"`` (this module's
default — one controller per engine, invoked on iteration-complete
events) or ``"fleet"`` (one controller for a whole cluster, invoked on
FLEET_TICK events with aggregated telemetry; see
``repro.policies.fleet``).

Node policies may additionally implement the OPTIONAL band hook
``set_band(f_lo, f_hi)``: a fleet coordinator (``repro.policies.
hierarchy``) assigns each node a frequency band on FLEET_TICK and the
node-local loop fine-tunes inside it. ``WindowedPolicy`` implements it by
clamping every decision into the band; AGFT masks its LinUCB arms
instead. Policies without the hook simply aren't band-governed (the
event loop still clamps the engine's frequency into the band).

A second OPTIONAL hook, ``tick(engine, now)``, supports the event loop's
pure POLICY_TICK scheduling (``policy_tick_mode="tick"``): one decision
per wall-clock tick, telemetry window cut at the tick's virtual time.
``WindowedPolicy`` and ``AGFTTuner`` implement it; duck-typed minimal
policies without it fall back to ``maybe_act`` at tick times.
"""
from __future__ import annotations

from typing import List, Optional, Protocol, Tuple, runtime_checkable

from repro.core.monitor import TelemetryMonitor
from repro.energy.edp import WindowStats
from repro.energy.power_model import HardwareSpec
from repro.policies.registry import register_policy


@runtime_checkable
class PowerPolicy(Protocol):
    """Structural interface every frequency controller implements.

    ``set_band(f_lo, f_hi)`` is an OPTIONAL extra hook (deliberately not a
    protocol member — ``runtime_checkable`` would demand it of every
    implementation): policies that have it are band-governable by a fleet
    coordinator; the event loop feature-detects it with ``getattr``.
    """

    def maybe_act(self, engine) -> Optional[float]:
        """Observe the engine (aggregate metrics only) and optionally set
        its frequency; return the actuated frequency, else ``None``."""
        ...


class WindowedPolicy:
    """Base for policies that decide once per telemetry window.

    Owns a :class:`TelemetryMonitor` so every subclass observes the engine
    through the same Prometheus-boundary ``WindowStats`` the paper's monitor
    produces, and records an AGFT-compatible ``history`` of per-window
    decisions (``t``/``freq``/``energy_j``/``tpot``/``edp``/``phase``) so
    benchmarks can treat all policies uniformly.

    Subclasses implement ``decide(window, engine) -> Optional[float]``;
    the returned frequency is clamped to the hardware envelope — and into
    the fleet-assigned band, when a coordinator has set one — and actuated.
    A decision may instead be a ``(f_prefill, f_decode)`` pair (the
    optional 2-D surface, see ``repro.policies.phased``): both axes are
    clamped the same way and actuated via ``set_phase_frequencies``.
    Phased policies declare ``phased = True`` so the batched fleet loop
    can refuse them at construction.
    """

    #: label recorded in history rows; subclasses override
    phase_name = "rule"
    #: governs a single engine (fleet-scope policies declare "fleet")
    scope = "node"

    def __init__(self, hardware: HardwareSpec,
                 sampling_period_s: float = 0.8):
        self.hw = hardware
        self.monitor = TelemetryMonitor(sampling_period_s)
        self.band: Optional[Tuple[float, float]] = None
        self.history: List[dict] = []

    # ------------------------------------------------------------------
    def set_band(self, f_lo: float, f_hi: float) -> None:
        """Fleet-coordinator hook: clamp every subsequent decision into
        ``[f_lo, f_hi]`` (inverted bounds tolerated, clamped to the
        hardware envelope)."""
        lo, hi = (float(f_lo), float(f_hi))
        if lo > hi:
            lo, hi = hi, lo
        lo = min(max(lo, self.hw.f_min), self.hw.f_max)
        hi = min(max(hi, self.hw.f_min), self.hw.f_max)
        self.band = (lo, hi)

    def maybe_act(self, engine) -> Optional[float]:
        if not self.monitor.due(engine):
            return None
        # a due iteration-gated decision IS a tick cut at the engine
        # clock — one decision body, two gates
        return self.tick(engine, engine.clock)

    def tick(self, engine, now: float) -> Optional[float]:
        """POLICY_TICK entrypoint (``policy_tick_mode="tick"``): decide
        once per wall-clock tick, with the telemetry window cut at the
        tick's virtual time ``now`` instead of at an iteration boundary.
        One tick = one decision — the monitor's due-gating is the event
        loop's job in this mode (and ``maybe_act``'s in iteration mode).

        Under fault injection (``repro.serving.faults``) a failed
        telemetry scrape blanks the window: the monitor is re-armed
        without a snapshot, no decision is taken (the engine holds its
        frequency), and a ``blank`` history row records the dropout —
        the rule-policy half of graceful degradation (AGFT's richer
        freeze lives in ``repro.core.tuner``)."""
        fs = getattr(engine, "fault_state", None)
        if fs is not None and fs.scrape_dropped(now):
            self.monitor.skip(engine, now=now)
            self._record(engine, None, None, t=now)
            return None
        window = self.monitor.observe(engine, now=now)
        f = self.decide(window, engine)
        if isinstance(f, tuple):
            # phase-disaggregated decision (optional 2-D surface): clamp
            # each axis into the envelope/band and actuate both phase
            # clocks (see repro.serving.engine.set_phase_frequencies)
            f = tuple(self._clamp(x) for x in f)
            engine.set_phase_frequencies(*f)
        elif f is not None:
            f = self._clamp(f)
            engine.set_frequency(f)
        self._record(engine, f, window, t=now)
        return f

    def _clamp(self, f: float) -> float:
        f = float(min(max(f, self.hw.f_min), self.hw.f_max))
        if self.band is not None:
            f = float(min(max(f, self.band[0]), self.band[1]))
        return f

    def decide(self, window: Optional[WindowStats],
               engine) -> Optional[float]:
        """Per-window decision; ``window`` is ``None`` on the first sample."""
        raise NotImplementedError

    # ------------------------------------------------------------------
    def _record(self, engine, f: Optional[float],
                window: Optional[WindowStats],
                t: Optional[float] = None) -> None:
        self.history.append({
            "t": engine.clock if t is None else t,
            "freq": float(engine.frequency),
            "reward": None,
            "edp": window.edp if window else None,
            "energy_j": window.energy_j if window else None,
            "tpot": window.effective_tpot if window else None,
            "phase": self.phase_name if window else "warmup",
            "acted": f is not None,
        })


@register_policy("observer")
class TelemetryRecorder(WindowedPolicy):
    """Observe-only policy: records per-window telemetry, never actuates.

    Attach it to a baseline (fixed-frequency) engine so time-windowed
    energy/latency series are measured exactly — replacing the old
    average-power estimate in the phase benchmarks.
    """

    phase_name = "observe"

    def decide(self, window, engine):
        return None
