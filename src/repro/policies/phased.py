"""Phase-disaggregated policies: the optional 2-D decision surface.

GreenLLM (arXiv:2508.16449) observes that the two phases of LLM inference
want different clocks — prefill is compute-bound (fast clocks amortize),
decode is bandwidth-bound (fast clocks burn power waiting on HBM) — so a
single per-node frequency is always a compromise. Policies here emit
``(f_prefill, f_decode)`` pairs instead: ``WindowedPolicy.tick`` clamps
both axes and actuates them via ``engine.set_phase_frequencies``, the
engine prices each iteration phase at its own clock and bills every
phase switch through the DVFS-transition machinery.

Two registry entries:

``greenllm-rule``  static per-phase targets from the offline analytic EDP
                   sweep (``repro.energy.phase_optimal_frequencies``) —
                   the rule-based comparator: right clocks, no adaptation.
``agft-2d``        the learned counterpart (``repro.core.tuner2d``): AGFT
                   over a pruned product action space seeded around the
                   same analytic optima.

Both declare ``phased = True`` — the batched fleet loop
(``repro.serving.fleet_step``) refuses phased policies at construction
because its vectorized pricing is single-clock per node; use the event
loop (``step_mode="events"``).

``benchmarks/tab_phases_2d.py`` ablates 1-D AGFT vs both of these on the
Azure production trace.
"""
from __future__ import annotations

from typing import Optional, Tuple

from repro.core.tuner import AGFTConfig
from repro.core.tuner2d import AGFT2DTuner
from repro.energy.phases import phase_optimal_frequencies
from repro.energy.power_model import HardwareSpec
from repro.policies.base import WindowedPolicy
from repro.policies.registry import register_policy


@register_policy("agft-2d")
def make_agft_2d(hardware: HardwareSpec,
                 cfg: Optional[AGFTConfig] = None,
                 seed_span: int = 2, seed_step_mhz: float = 90.0,
                 batch_cap: Optional[int] = None,
                 **kwargs) -> AGFT2DTuner:
    """``get_policy("agft-2d")`` — phase-disaggregated AGFT. Extra kwargs
    are AGFTConfig fields; ``seed_span``/``seed_step_mhz`` shape the
    seeded product space (``2*span + 1`` points per axis), ``batch_cap``
    optionally clamps scheduler admission as a second knob."""
    if cfg is not None and kwargs:
        raise TypeError("pass either cfg= or AGFTConfig field kwargs")
    return AGFT2DTuner(hardware, cfg or AGFTConfig(**kwargs),
                       seed_span=seed_span, seed_step_mhz=seed_step_mhz,
                       batch_cap=batch_cap)


@register_policy("greenllm-rule")
class GreenLLMRulePolicy(WindowedPolicy):
    """Static per-phase clocks from the analytic EDP sweep.

    Decides the same ``(f_prefill, f_decode)`` pair every window: each
    phase's single-iteration EDP argmin over the hardware grid, computed
    lazily on first decision from the engine's own model/scheduler shape
    (and recomputed if a fleet coordinator moves the band, since the sweep
    is band-restricted). This is the oracle-flavored RULE comparator for
    the 2-D surface — the right clocks for each phase, but no adaptation
    to load, batch mix, or drift, which is exactly the gap ``agft-2d``
    is measured by.
    """

    phase_name = "greenllm"
    phased = True

    def __init__(self, hardware: HardwareSpec,
                 sampling_period_s: float = 0.8,
                 batch_cap: Optional[int] = None):
        super().__init__(hardware, sampling_period_s)
        self.batch_cap = batch_cap
        self._pair: Optional[Tuple[float, float]] = None
        self._pair_band = None

    def decide(self, window, engine):
        if self._pair is None or self._pair_band != self.band:
            self._pair = phase_optimal_frequencies(
                self.hw, engine.model_cfg,
                dvfs=getattr(engine.backend, "dvfs", None),
                prefill_chunk=getattr(engine.cfg, "prefill_chunk", 512),
                decode_seqs=max(
                    getattr(engine.cfg, "max_num_seqs", 64) // 2, 1),
                band=self.band)
            self._pair_band = self.band
            if self.batch_cap is not None and hasattr(engine, "sched"):
                engine.sched.set_admission_cap(self.batch_cap)
        return self._pair
