"""Per-phase analytic EDP optima: the seed of phase-disaggregated DVFS.

Prefill iterations are compute-bound (their EDP-vs-frequency minimum sits
near the perf knee, ~0.78 f_max), decode iterations are bandwidth-bound
(their minimum sits near the bandwidth knee, ~0.65 f_max) — so the best
*single* clock is a compromise between two optima that are hundreds of MHz
apart (GreenLLM, arXiv:2508.16449). This module sweeps the hardware grid
once per phase with the same :class:`repro.energy.DVFSModel` physics the
engine bills, producing the static per-phase pair ``(f_prefill, f_decode)``
that (a) the ``greenllm-rule`` comparator pins for a whole run and (b) the
2-D AGFT tuner uses to seed its pruned product action space.
"""
from __future__ import annotations

from functools import lru_cache
from typing import Optional, Sequence, Tuple

from repro.energy.costs import iteration_cost
from repro.energy.power_model import DVFSModel, HardwareSpec
from repro.models.common import ModelConfig


@lru_cache(maxsize=32)
def _dvfs_for(hw: HardwareSpec) -> DVFSModel:
    """One tabulated DVFSModel per spec — mixed fleets resolve per-phase
    optima for the same tier many times (one policy per node); rebuilding
    the full frequency-terms table each call is pure waste."""
    return DVFSModel(hw)


def _edp_argmin(dvfs: DVFSModel, flops: float, mem: float,
                grid: Sequence[float]) -> float:
    """Frequency minimizing per-iteration EDP = P(f) * t(f)^2 over ``grid``
    (the ``OracleFixedPolicy._sweep`` criterion, applied to one phase)."""
    best_f, best_edp = grid[-1], float("inf")
    for f in grid:
        t, p = dvfs.iteration_time_power(flops, mem, f)
        edp = p * t * t
        if edp < best_edp:
            best_f, best_edp = f, edp
    return float(best_f)


def phase_optimal_frequencies(
        hw: HardwareSpec, model_cfg: ModelConfig, *,
        dvfs: Optional[DVFSModel] = None,
        prefill_chunk: int = 512,
        decode_seqs: int = 32,
        avg_context: float = 1024.0,
        band: Optional[Tuple[float, float]] = None) -> Tuple[float, float]:
    """Analytic ``(f_prefill, f_decode)``: the EDP-optimal clock for a
    representative pure-prefill iteration (one ``prefill_chunk``-token
    chunk) and for a representative pure-decode iteration (``decode_seqs``
    sequences at ``avg_context`` mean context).

    With a fleet-assigned ``band`` the sweep is restricted to in-band grid
    points on BOTH axes (falling back to the full grid when the band holds
    no grid point), so hierarchy/thermal clamps compose the same way they
    do for the 1-D oracle sweep.

    The optima are per-spec by construction (the sweep runs over ``hw``'s
    own grid with ``hw``'s own knees); the cached result path below makes
    repeat lookups on mixed fleets O(1) per node.
    """
    if dvfs is None:
        if band is None:
            return _phase_optima_cached(hw, model_cfg, prefill_chunk,
                                        decode_seqs, avg_context)
        dvfs = _dvfs_for(hw)
    grid = hw.frequencies()
    if band is not None:
        in_band = [f for f in grid
                   if band[0] - 1e-9 <= f <= band[1] + 1e-9]
        grid = in_band or grid
    fp, mp = iteration_cost(model_cfg, prefill_tokens=prefill_chunk,
                            decode_seqs=0,
                            avg_context=prefill_chunk / 2)
    fd, md = iteration_cost(model_cfg, prefill_tokens=0,
                            decode_seqs=max(decode_seqs, 1),
                            avg_context=avg_context)
    return (_edp_argmin(dvfs, fp, mp, grid),
            _edp_argmin(dvfs, fd, md, grid))


@lru_cache(maxsize=256)
def _phase_optima_cached(hw: HardwareSpec, model_cfg: ModelConfig,
                         prefill_chunk: int, decode_seqs: int,
                         avg_context: float) -> Tuple[float, float]:
    return phase_optimal_frequencies(
        hw, model_cfg, dvfs=_dvfs_for(hw), prefill_chunk=prefill_chunk,
        decode_seqs=decode_seqs, avg_context=avg_context)
