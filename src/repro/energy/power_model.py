"""Analytical DVFS latency/power model — the physics layer of the simulated
serving environment (the paper evaluates in "an environment simulating
realistic, fluctuating inference requests"; this is ours — see
docs/ARCHITECTURE.md for how it slots under the engine/event core).

Latency: an iteration splits into a compute-bound part that scales ~1/f and
a memory-bound part that is frequency-insensitive (GDDR/HBM clocks are not
tied to the core clock). Power: classic CMOS decomposition
P = P_idle + P_static_active + P_dyn_compute·u_c·(f/f_max)^alpha
              + P_dyn_memory·u_m,
with alpha≈3 (V roughly tracks f). These two facts alone reproduce the
paper's phenomenology: U-shaped EDP-vs-frequency curves whose minimum sits
high for compute-bound workloads (prefill-heavy, high-concurrency) and low
for memory-bound ones (decode-heavy, cache-hit-heavy).

The knee constants also give prefill and decode *different* optima
(compute-bound prefill near the perf knee, bandwidth-bound decode near the
bw knee) — the separation phase-disaggregated DVFS exploits
(``repro.energy.phases``, ``set_phase_frequencies``).

Three calibrations ship: the A6000 set (used for the faithful reproduction
so learned optima land in the paper's 1200-1410 MHz band), A6000_MEASURED
(the same physics with nonzero DVFS transition billing), and a TPU-v5e set
(the deployment target; "frequency" is the virtualized power-state knob).
"""
from __future__ import annotations

import dataclasses
from typing import List, Tuple

import numpy as np


@dataclasses.dataclass(frozen=True)
class HardwareSpec:
    name: str
    f_min: float                 # MHz
    f_max: float                 # MHz
    f_step: float                # MHz (native grid granularity)
    peak_flops: float            # FLOP/s at f_max (half precision)
    mem_bw: float                # bytes/s
    p_idle: float                # W, device powered but idle
    p_static_active: float       # W, clock-tree/leakage when busy
    p_dyn_compute: float         # W, dynamic compute power at f_max, u=1
    p_dyn_memory: float          # W, memory-subsystem power at full bw
    alpha: float = 3.0           # dynamic power exponent
    iteration_overhead_s: float = 3.0e-4   # launch/scheduling per iteration
    # Achievable memory bandwidth vs core clock has a KNEE: flat at full
    # bandwidth above bw_knee*f_max (DMA/L2 keep up), dropping as a power
    # law below it (address-generation / issue-rate limited). This is what
    # pins decode-heavy (memory-bound) EDP optima at moderate frequencies
    # (paper Fig. 6: Long-Generation's optimum is 1260 MHz, not 210) while
    # costing almost no TPOT at the optimum (paper Table 3: +7.1%).
    #   bw_eff = bw * min(1, (fr/bw_knee)^bw_beta)
    bw_knee: float = 0.65
    bw_beta: float = 0.9
    # Compute throughput saturates near the top of the V/F curve (issue
    # limits, memory interleave): effective throughput = fr for fr<=knee,
    # then knee + slope*(fr-knee). This is why measured EDP optima for
    # compute-bound LLM serving sit at ~0.75-0.78 f_max (paper Fig. 6:
    # 1365-1410 of 1800 MHz), never at f_max.
    perf_knee: float = 0.78
    perf_slope_above_knee: float = 0.25
    # DVFS transition cost: switching the core clock is not free — the PLL
    # relock plus pipeline drain stalls execution for O(10 ms) at near-busy
    # power (switching-aware bandits, arXiv:2410.11855). When nonzero the
    # engine bills `dvfs_transition_cost_j` joules and advances the clock by
    # `dvfs_transition_s` on every *actual* frequency change. Both default
    # to 0 so the faithful-reproduction calibrations are unchanged; the
    # ``agft-switchcost`` policy variant prices transitions in the reward
    # even when the simulation itself does not bill them.
    dvfs_transition_cost_j: float = 0.0
    dvfs_transition_s: float = 0.0

    def frequencies(self) -> List[float]:
        out, f = [], self.f_min
        while f <= self.f_max + 1e-9:
            out.append(round(f, 3))
            f += self.f_step
        return out


# Calibrated so that (i) peak busy power ~ board TDP, (ii) the compute-bound
# EDP optimum lands near 0.75-0.78 f_max (paper Fig. 6: 1365-1410 MHz of
# 1800), (iii) baseline serving power for Llama-3-3B-class load sits in the
# paper's observed 180-240 W band.
A6000 = HardwareSpec(
    name="NVIDIA-A6000",
    f_min=210.0, f_max=1800.0, f_step=15.0,
    peak_flops=155e12,           # bf16/fp16 tensor-core peak
    mem_bw=768e9,                # GDDR6
    p_idle=25.0,
    p_static_active=38.0,
    p_dyn_compute=185.0,
    p_dyn_memory=52.0,
    alpha=3.0,
)

# The A6000 calibration with MEASURED (nonzero) DVFS transition costs, so
# the simulation itself bills clock changes — the switchcost ablation shows
# up in measured energy, not only in the reward (ROADMAP item). Calibration:
# nvidia-smi -lgc style application-clock changes stall execution for the
# PLL relock + pipeline drain, ~8 ms on Ampere-class parts (the O(10 ms)
# figure the switching-aware bandit literature assumes, arXiv:2410.11855);
# during the stall the SMs sit at active-idle — roughly P_idle +
# P_static_active + ~0.5*P_dyn_compute ≈ 155 W — so one transition costs
# ~155 W x 8 ms ≈ 1.25 J. Kept as a separate spec so the faithful
# reproduction (golden trajectories, paper tables) stays on the free-
# transition A6000 calibration.
A6000_MEASURED = dataclasses.replace(
    A6000,
    name="NVIDIA-A6000-measured-dvfs",
    dvfs_transition_s=8e-3,
    dvfs_transition_cost_j=1.25,
)

# TPU v5e: "frequency" = virtualized power-state multiplier; the grid
# mirrors the published v5e roofline constants.
TPU_V5E = HardwareSpec(
    name="TPU-v5e",
    f_min=0.25 * 1_000, f_max=1_000.0, f_step=25.0,   # normalized milli-units
    peak_flops=197e12,
    mem_bw=819e9,
    p_idle=60.0,
    p_static_active=40.0,
    p_dyn_compute=140.0,
    p_dyn_memory=60.0,
    alpha=3.0,
)


class DVFSModel:
    """Maps (work, frequency) -> (latency, energy) for one engine iteration.

    The frequency-response terms (effective compute throughput with
    top-of-curve saturation, bandwidth-knee factor, the f^alpha dynamic-power
    term) depend only on the frequency, so they are tabulated once over the
    hardware's native ``f_step`` grid at construction; off-grid frequencies
    (clamped values, custom policies) fall back to computing and memoising
    the same terms on first use. Cached values are produced by the exact
    expressions the scalar path used, so latency/power are bit-identical.

    Three consumers share this table: the scalar per-event path
    (:meth:`iteration_time_power`), the batched fleet path
    (:meth:`iteration_time_power_vec` over rows from
    :meth:`freq_terms_array`), and per-phase pricing
    (``SimBackend.execute_phased`` calls the scalar method once per phase
    at that phase's clock) — all billing the same physics.
    """

    def __init__(self, spec: HardwareSpec):
        self.spec = spec
        # f_mhz -> (comp_denominator, mem_denominator, fr**alpha)
        self._freq_terms_cache: dict = {}
        for f in spec.frequencies():
            self._freq_terms(f)

    def _freq_terms(self, f_mhz: float) -> Tuple[float, float, float]:
        terms = self._freq_terms_cache.get(f_mhz)
        if terms is None:
            sp = self.spec
            fr = min(max(f_mhz / sp.f_max, 1e-3), 1.0)
            # effective compute throughput with top-of-curve saturation
            if fr <= sp.perf_knee:
                thr = fr
            else:
                thr = sp.perf_knee \
                    + sp.perf_slope_above_knee * (fr - sp.perf_knee)
            bw_factor = min(1.0, (fr / sp.bw_knee) ** sp.bw_beta)
            terms = (sp.peak_flops * thr, sp.mem_bw * bw_factor,
                     fr ** sp.alpha)
            self._freq_terms_cache[f_mhz] = terms
        return terms

    def iteration_time_power(self, flops: float, mem_bytes: float,
                             f_mhz: float) -> Tuple[float, float]:
        """Returns (seconds, watts) for one iteration of the given work."""
        sp = self.spec
        terms = self._freq_terms_cache.get(f_mhz)     # inlined hot path
        if terms is None:
            terms = self._freq_terms(f_mhz)
        comp_denom, mem_denom, fr_alpha = terms
        t_comp = flops / comp_denom if flops > 0 else 0.0
        t_mem = mem_bytes / mem_denom if mem_bytes > 0 else 0.0
        # compute and memory pipelines overlap; overhead does not
        t_busy = max(t_comp, t_mem)
        t = t_busy + sp.iteration_overhead_s
        if t_busy <= 0.0:
            return t, sp.p_idle
        u_busy = t_busy / t
        u_mem = t_mem / t
        # SMs draw near-full dynamic power whenever busy (paper Fig. 1:
        # decode ~300 W vs prefill 280-325 W on A800) — power scales with
        # the clock cube, NOT with FLOP utilization.
        p = (sp.p_idle + sp.p_static_active * u_busy
             + sp.p_dyn_compute * u_busy * fr_alpha
             + sp.p_dyn_memory * u_mem)
        return t, p

    # -- vectorized fleet path ------------------------------------------
    def freq_terms_array(self, f_mhz: "np.ndarray") -> "np.ndarray":
        """Per-node frequency terms as an ``(n, 3)`` array with columns
        ``(comp_denominator, mem_denominator, fr**alpha)``.

        Rows are drawn from the same memoised scalar ``_freq_terms`` table
        the per-event path uses, so batched physics stays bit-identical."""
        f = np.asarray(f_mhz, dtype=np.float64)
        out = np.empty((f.shape[0], 3), dtype=np.float64)
        for i in range(f.shape[0]):
            out[i] = self._freq_terms(float(f[i]))
        return out

    def iteration_time_power_vec(self, flops: "np.ndarray",
                                 mem_bytes: "np.ndarray",
                                 terms: "np.ndarray"):
        """Vectorized :meth:`iteration_time_power` over per-node work arrays.

        ``terms`` is the ``(n, 3)`` array from :meth:`freq_terms_array`.
        The arithmetic is the identical IEEE expression sequence applied
        elementwise, so (seconds, watts) match the scalar path bit-for-bit.
        Both denominators are strictly positive (``fr`` is clamped at 1e-3),
        so zero work divides to exactly 0.0 — same value the scalar guard
        produces."""
        sp = self.spec
        t_comp = flops / terms[..., 0]
        t_mem = mem_bytes / terms[..., 1]
        t_busy = np.maximum(t_comp, t_mem)
        t = t_busy + sp.iteration_overhead_s
        u_busy = t_busy / t
        u_mem = t_mem / t
        p = (sp.p_idle + sp.p_static_active * u_busy
             + sp.p_dyn_compute * u_busy * terms[..., 2]
             + sp.p_dyn_memory * u_mem)
        p = np.where(t_busy <= 0.0, sp.p_idle, p)
        return t, p

    def iteration_time_energy(self, flops: float, mem_bytes: float,
                              f_mhz: float) -> Tuple[float, float]:
        t, p = self.iteration_time_power(flops, mem_bytes, f_mhz)
        return t, p * t

    def idle_energy(self, seconds: float) -> float:
        return self.spec.p_idle * seconds
