from repro.energy.power_model import (A6000, A6000_MEASURED, TPU_V5E,
                                      DVFSModel, HardwareSpec)
from repro.energy.costs import (CostModel, active_param_count,
                                get_cost_model, iteration_cost, param_count)
from repro.energy.phases import phase_optimal_frequencies

__all__ = ["A6000", "A6000_MEASURED", "TPU_V5E", "CostModel", "DVFSModel",
           "HardwareSpec", "active_param_count", "get_cost_model",
           "iteration_cost", "param_count", "phase_optimal_frequencies"]
