from repro.energy.power_model import (A6000, A6000_MEASURED, EDGE_ORIN,
                                      H100, HARDWARE, HW_CONST_COLS, L4,
                                      TPU_V5E, DVFSModel, HardwareSpec,
                                      hw_const_rows, parse_fleet_hardware,
                                      resolve_hardware)
from repro.energy.costs import (CostModel, active_param_count,
                                get_cost_model, iteration_cost, param_count)
from repro.energy.phases import phase_optimal_frequencies

__all__ = ["A6000", "A6000_MEASURED", "CostModel", "DVFSModel", "EDGE_ORIN",
           "H100", "HARDWARE", "HW_CONST_COLS", "HardwareSpec", "L4",
           "TPU_V5E", "active_param_count", "get_cost_model",
           "hw_const_rows", "iteration_cost", "param_count",
           "parse_fleet_hardware", "phase_optimal_frequencies",
           "resolve_hardware"]
