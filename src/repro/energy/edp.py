"""Energy-Delay-Product accounting (paper §2.3).

The paper's per-window EDP uses the decision window's energy and its
effective per-output-token delay (Tables 2/3: EDP ~= Energy_w x TPOT_w,
e.g. 231.6 J x 0.018 s ~= 4.07). We adopt exactly that:

    delay_w = busy_seconds_w / generation_tokens_w     (effective TPOT)
    EDP_w   = energy_w * delay_w

plus a MIXED variant whose delay adds a TTFT-pressure term
(delay = tpot_eff + ttft_weight * mean_ttft_w): the offline sweep and the
paper's SLO framing both weight first-token latency, and without it the
online optimum biases ~15-25% below the offline one (measured; see
EXPERIMENTS.md §Repro).
"""
from __future__ import annotations

import dataclasses
from typing import Dict


@dataclasses.dataclass(frozen=True)
class WindowStats:
    """Differenced counters over one sampling window."""
    duration_s: float
    energy_j: float
    busy_s: float
    prefill_tokens: int
    cached_prompt_tokens: int
    generation_tokens: int
    iterations: int
    requests_running: int
    requests_waiting: int
    gpu_cache_usage: float
    cache_hit_rate: float
    mean_ttft_s: float = 0.0

    @property
    def effective_tpot(self) -> float:
        if self.generation_tokens <= 0:
            return self.duration_s          # stalled window: worst-case delay
        return self.busy_s / self.generation_tokens

    @property
    def edp(self) -> float:
        return self.energy_j * self.effective_tpot

    def edp_mixed(self, ttft_weight: float = 0.1) -> float:
        return self.energy_j * (self.effective_tpot
                                + ttft_weight * self.mean_ttft_s)

    @property
    def power_w(self) -> float:
        return self.energy_j / self.duration_s if self.duration_s else 0.0


def diff_snapshots(prev: Dict[str, float], cur: Dict[str, float],
                   duration_s: float) -> WindowStats:
    d = lambda k: cur[k] - prev[k]   # noqa: E731
    hits = d("vllm:prefix_cache_hits_total")
    queries = d("vllm:prefix_cache_queries_total")
    return WindowStats(
        duration_s=duration_s,
        energy_j=d("vllm:energy_joules_total"),
        busy_s=d("vllm:busy_seconds_total"),
        prefill_tokens=int(d("vllm:prompt_tokens_total")),
        cached_prompt_tokens=int(d("vllm:cached_prompt_tokens_total")),
        generation_tokens=int(d("vllm:generation_tokens_total")),
        iterations=int(d("vllm:iterations_total")),
        requests_running=int(cur["vllm:num_requests_running"]),
        requests_waiting=int(cur["vllm:num_requests_waiting"]),
        gpu_cache_usage=float(cur["vllm:gpu_cache_usage_perc"]),
        cache_hit_rate=hits / queries if queries > 0 else 0.0,
        mean_ttft_s=(d("vllm:ttft_seconds_total")
                     / max(d("vllm:ttft_count_total"), 1)),
    )
