"""Analytical per-iteration FLOP/byte costs for a model config.

Used by the simulated execution backend (engine iterations) and cross-checked
against the XLA-compiled cost_analysis in the roofline benchmarks.

The config-dependent terms (parameter counts, attention-layer fraction,
KV bytes/token) are pure functions of the frozen ``ModelConfig``, so
:class:`CostModel` hoists them out of the per-iteration path once and the
remaining per-call work is a handful of fused multiply-adds. The module-level
``iteration_cost`` keeps the original functional API on top of a cached
``CostModel`` per config.
"""
from __future__ import annotations

from functools import lru_cache

import numpy as np

from repro.models.common import ModelConfig


def param_count(cfg: ModelConfig) -> float:
    """Total parameters (approximate, matmul weights dominate)."""
    d = cfg.d_model
    emb = cfg.vocab_size * d * (1 if cfg.tie_embeddings else 2)
    per_layer = 0.0
    if cfg.arch_type == "ssm":
        conv_dim = cfg.d_inner + 2 * cfg.ssm_ngroups * cfg.ssm_state
        per_layer = (d * (2 * cfg.d_inner + 2 * cfg.ssm_ngroups * cfg.ssm_state
                          + cfg.ssm_nheads)
                     + cfg.conv_kernel * conv_dim + cfg.d_inner * d)
        return emb + cfg.num_layers * per_layer
    # attention weights
    if cfg.use_mla:
        qk_dim = cfg.qk_nope_head_dim + cfg.qk_rope_head_dim
        attn_p = (d * cfg.num_heads * qk_dim
                  + d * (cfg.kv_lora_rank + cfg.qk_rope_head_dim)
                  + cfg.kv_lora_rank * cfg.num_heads
                  * (cfg.qk_nope_head_dim + cfg.v_head_dim)
                  + cfg.num_heads * cfg.v_head_dim * d)
    else:
        attn_p = (d * cfg.num_heads * cfg.head_dim
                  + 2 * d * cfg.num_kv_heads * cfg.head_dim
                  + cfg.num_heads * cfg.head_dim * d)
    # ffn weights
    gate_mult = 3 if cfg.ffn_activation in ("swiglu", "geglu") else 2
    if cfg.num_experts:
        e_ff = cfg.moe_d_ff or cfg.d_ff
        ffn_p = cfg.num_experts * 3 * d * e_ff \
            + cfg.num_shared_experts * 3 * d * e_ff
        dense_ffn_p = gate_mult * d * cfg.d_ff
        n_moe = cfg.num_layers - cfg.first_k_dense
        total_layers = n_moe * (attn_p + ffn_p) \
            + cfg.first_k_dense * (attn_p + dense_ffn_p)
        return emb + total_layers
    if cfg.arch_type == "hybrid":
        rec_p = (2 * d * cfg.lru_width + 2 * cfg.lru_width ** 2
                 + cfg.lru_width * d)
        attn_frac = (cfg.block_pattern or ("rec", "rec", "attn")).count(
            "attn") / len(cfg.block_pattern or ("rec", "rec", "attn"))
        mix_p = attn_frac * attn_p + (1 - attn_frac) * rec_p
        per_layer = mix_p + gate_mult * d * cfg.d_ff
        return emb + cfg.num_layers * per_layer
    per_layer = attn_p + gate_mult * d * cfg.d_ff
    n_dec = cfg.num_layers
    total = emb + n_dec * per_layer
    if cfg.is_encoder_decoder:
        enc_layer = attn_p + gate_mult * d * cfg.d_ff
        cross_p = attn_p
        total += cfg.encoder_layers * enc_layer + n_dec * cross_p
    return total


def active_param_count(cfg: ModelConfig) -> float:
    """Parameters touched per token (MoE: only routed top-k + shared)."""
    if not cfg.num_experts:
        return param_count(cfg)
    d = cfg.d_model
    e_ff = cfg.moe_d_ff or cfg.d_ff
    dense = param_count(cfg.replace(num_experts=0, num_shared_experts=0,
                                    first_k_dense=0, d_ff=1))
    n_moe = cfg.num_layers - cfg.first_k_dense
    active_ffn = (cfg.top_k + cfg.num_shared_experts) * 3 * d * e_ff
    gate_mult = 3
    return (dense + n_moe * active_ffn
            + cfg.first_k_dense * gate_mult * d * cfg.d_ff)


def kv_bytes_per_token_layer(cfg: ModelConfig, bytes_per_el: int = 2) -> float:
    """KV-cache bytes appended per token per attention layer."""
    if cfg.use_mla:
        return (cfg.kv_lora_rank + cfg.qk_rope_head_dim) * bytes_per_el
    return 2 * cfg.num_kv_heads * cfg.head_dim * bytes_per_el


def attention_layers(cfg: ModelConfig) -> float:
    if cfg.arch_type == "ssm":
        return 0.0
    if cfg.arch_type == "hybrid":
        pat = cfg.block_pattern or ("rec", "rec", "attn")
        return cfg.num_layers * pat.count("attn") / len(pat)
    return cfg.num_layers


class CostModel:
    """Per-``ModelConfig`` iteration-cost evaluator with every
    config-derived term precomputed once.

    ``iteration_cost`` here is arithmetic-identical (same expressions, same
    association order) to the historical module-level function, so simulated
    clocks/energies are bit-for-bit unchanged — it just stops re-deriving
    ``active_param_count``/``attention_layers``/``kv_bytes_per_token_layer``
    on every engine iteration.
    """

    def __init__(self, cfg: ModelConfig, bytes_per_el: int = 2):
        self.cfg = cfg
        self.bytes_per_el = bytes_per_el
        self.n_active = active_param_count(cfg)
        self.n_total = param_count(cfg)
        self.attn_layers = attention_layers(cfg)
        self.window = cfg.attention_window or 0
        # flops = _flops_per_token * tokens + _attn_coeff * ctx-terms
        self._flops_per_token = 2.0 * self.n_active
        self._attn_coeff = 4.0 * (cfg.num_heads * cfg.head_dim) \
            * self.attn_layers
        # memory: weights stream once per iteration, KV traffic per token
        self.kv_bytes_per_token = kv_bytes_per_token_layer(cfg, bytes_per_el) \
            * self.attn_layers
        self.weight_bytes = self.n_active * bytes_per_el
        if cfg.arch_type == "ssm":
            self._state_bytes_per_seq = (cfg.ssm_nheads * cfg.ssm_head_dim
                                         * cfg.ssm_state * 4) * cfg.num_layers
        elif cfg.arch_type == "hybrid":
            self._state_bytes_per_seq = (cfg.lru_width * 4) * cfg.num_layers
        else:
            self._state_bytes_per_seq = 0

    def iteration_cost(self, *, prefill_tokens: int, decode_seqs: int,
                       avg_context: float, cached_prefill_tokens: int = 0):
        """(flops, mem_bytes) for one continuous-batching iteration.

        prefill_tokens: NEW prompt tokens processed this iteration
        (prefix-cache hits excluded); decode_seqs: sequences generating one
        token each; avg_context: mean KV length the decode tokens attend to.
        """
        tokens = prefill_tokens + decode_seqs
        eff_ctx = min(avg_context, self.window) if self.window \
            else avg_context
        ctx = max(eff_ctx, 1.0)
        # attention score/value FLOPs: 4 * d_attn * context per token per
        # layer; prefill pays the causal triangle (factor 0.5)
        flops = self._flops_per_token * tokens + self._attn_coeff * (
            prefill_tokens * ctx * 0.5 + decode_seqs * ctx)
        kv = self.kv_bytes_per_token
        mem = self.weight_bytes                 # weight reads
        mem += tokens * kv                      # cache writes
        mem += decode_seqs * kv * ctx           # decode cache reads
        mem += prefill_tokens * kv * 0.1        # prefill reread (flash)
        if self._state_bytes_per_seq:           # ssm/recurrent state traffic
            mem += decode_seqs * self._state_bytes_per_seq
        return flops, mem

    def iteration_cost_vec(self, *, prefill_tokens: "np.ndarray",
                           decode_seqs: "np.ndarray",
                           avg_context: "np.ndarray"):
        """Vectorized :meth:`iteration_cost` over per-node arrays.

        Elementwise it is the identical expression sequence (same
        association order) as the scalar path, so the batched fleet backend
        gets bit-for-bit the scalar flops/bytes for every node at once."""
        tokens = prefill_tokens + decode_seqs
        if self.window:
            eff_ctx = np.minimum(avg_context, self.window)
        else:
            eff_ctx = avg_context
        ctx = np.maximum(eff_ctx, 1.0)
        flops = self._flops_per_token * tokens + self._attn_coeff * (
            prefill_tokens * ctx * 0.5 + decode_seqs * ctx)
        kv = self.kv_bytes_per_token
        mem = self.weight_bytes + tokens * kv
        mem = mem + decode_seqs * kv * ctx
        mem = mem + prefill_tokens * kv * 0.1
        if self._state_bytes_per_seq:
            mem = mem + decode_seqs * self._state_bytes_per_seq
        return flops, mem


@lru_cache(maxsize=256)
def get_cost_model(cfg: ModelConfig, bytes_per_el: int = 2) -> CostModel:
    """Shared ``CostModel`` per (config, dtype width) — configs are frozen
    dataclasses, so caching on identity-of-value is safe."""
    return CostModel(cfg, bytes_per_el)


def iteration_cost(cfg: ModelConfig, *, prefill_tokens: int,
                   decode_seqs: int, avg_context: float,
                   cached_prefill_tokens: int = 0,
                   bytes_per_el: int = 2):
    """Functional API over the cached :class:`CostModel` (see there)."""
    return get_cost_model(cfg, bytes_per_el).iteration_cost(
        prefill_tokens=prefill_tokens, decode_seqs=decode_seqs,
        avg_context=avg_context, cached_prefill_tokens=cached_prefill_tokens)
