"""Analytical per-iteration FLOP/byte costs for a model config.

Used by the simulated execution backend (engine iterations) and cross-checked
against the XLA-compiled cost_analysis in the roofline benchmarks.
"""
from __future__ import annotations

from repro.models.common import ModelConfig


def param_count(cfg: ModelConfig) -> float:
    """Total parameters (approximate, matmul weights dominate)."""
    d = cfg.d_model
    emb = cfg.vocab_size * d * (1 if cfg.tie_embeddings else 2)
    per_layer = 0.0
    if cfg.arch_type == "ssm":
        conv_dim = cfg.d_inner + 2 * cfg.ssm_ngroups * cfg.ssm_state
        per_layer = (d * (2 * cfg.d_inner + 2 * cfg.ssm_ngroups * cfg.ssm_state
                          + cfg.ssm_nheads)
                     + cfg.conv_kernel * conv_dim + cfg.d_inner * d)
        return emb + cfg.num_layers * per_layer
    # attention weights
    if cfg.use_mla:
        qk_dim = cfg.qk_nope_head_dim + cfg.qk_rope_head_dim
        attn_p = (d * cfg.num_heads * qk_dim
                  + d * (cfg.kv_lora_rank + cfg.qk_rope_head_dim)
                  + cfg.kv_lora_rank * cfg.num_heads
                  * (cfg.qk_nope_head_dim + cfg.v_head_dim)
                  + cfg.num_heads * cfg.v_head_dim * d)
    else:
        attn_p = (d * cfg.num_heads * cfg.head_dim
                  + 2 * d * cfg.num_kv_heads * cfg.head_dim
                  + cfg.num_heads * cfg.head_dim * d)
    # ffn weights
    gate_mult = 3 if cfg.ffn_activation in ("swiglu", "geglu") else 2
    if cfg.num_experts:
        e_ff = cfg.moe_d_ff or cfg.d_ff
        ffn_p = cfg.num_experts * 3 * d * e_ff \
            + cfg.num_shared_experts * 3 * d * e_ff
        dense_ffn_p = gate_mult * d * cfg.d_ff
        n_moe = cfg.num_layers - cfg.first_k_dense
        total_layers = n_moe * (attn_p + ffn_p) \
            + cfg.first_k_dense * (attn_p + dense_ffn_p)
        return emb + total_layers
    if cfg.arch_type == "hybrid":
        rec_p = (2 * d * cfg.lru_width + 2 * cfg.lru_width ** 2
                 + cfg.lru_width * d)
        attn_frac = (cfg.block_pattern or ("rec", "rec", "attn")).count(
            "attn") / len(cfg.block_pattern or ("rec", "rec", "attn"))
        mix_p = attn_frac * attn_p + (1 - attn_frac) * rec_p
        per_layer = mix_p + gate_mult * d * cfg.d_ff
        return emb + cfg.num_layers * per_layer
    per_layer = attn_p + gate_mult * d * cfg.d_ff
    n_dec = cfg.num_layers
    total = emb + n_dec * per_layer
    if cfg.is_encoder_decoder:
        enc_layer = attn_p + gate_mult * d * cfg.d_ff
        cross_p = attn_p
        total += cfg.encoder_layers * enc_layer + n_dec * cross_p
    return total


def active_param_count(cfg: ModelConfig) -> float:
    """Parameters touched per token (MoE: only routed top-k + shared)."""
    if not cfg.num_experts:
        return param_count(cfg)
    d = cfg.d_model
    e_ff = cfg.moe_d_ff or cfg.d_ff
    dense = param_count(cfg.replace(num_experts=0, num_shared_experts=0,
                                    first_k_dense=0, d_ff=1))
    n_moe = cfg.num_layers - cfg.first_k_dense
    active_ffn = (cfg.top_k + cfg.num_shared_experts) * 3 * d * e_ff
    gate_mult = 3
    return (dense + n_moe * active_ffn
            + cfg.first_k_dense * gate_mult * d * cfg.d_ff)


def kv_bytes_per_token_layer(cfg: ModelConfig, bytes_per_el: int = 2) -> float:
    """KV-cache bytes appended per token per attention layer."""
    if cfg.use_mla:
        return (cfg.kv_lora_rank + cfg.qk_rope_head_dim) * bytes_per_el
    return 2 * cfg.num_kv_heads * cfg.head_dim * bytes_per_el


def attention_layers(cfg: ModelConfig) -> float:
    if cfg.arch_type == "ssm":
        return 0.0
    if cfg.arch_type == "hybrid":
        pat = cfg.block_pattern or ("rec", "rec", "attn")
        return cfg.num_layers * pat.count("attn") / len(pat)
    return cfg.num_layers


def iteration_cost(cfg: ModelConfig, *, prefill_tokens: int,
                   decode_seqs: int, avg_context: float,
                   cached_prefill_tokens: int = 0,
                   bytes_per_el: int = 2):
    """(flops, mem_bytes) for one continuous-batching iteration.

    prefill_tokens: NEW prompt tokens processed this iteration (prefix-cache
    hits excluded); decode_seqs: sequences generating one token each;
    avg_context: mean KV length the decode tokens attend to.
    """
    n_active = active_param_count(cfg)
    n_total = param_count(cfg)
    attn_l = attention_layers(cfg)
    d_attn = cfg.num_heads * cfg.head_dim
    window = cfg.attention_window or 0

    tokens = prefill_tokens + decode_seqs
    flops = 2.0 * n_active * tokens
    # attention score/value FLOPs: 4 * d_attn * context per token per layer
    eff_ctx = min(avg_context, window) if window else avg_context
    flops += 4.0 * d_attn * attn_l * (
        prefill_tokens * max(eff_ctx, 1.0) * 0.5    # causal triangle
        + decode_seqs * max(eff_ctx, 1.0))

    # memory: weights stream once per iteration (batched reuse), KV traffic
    kv_l = kv_bytes_per_token_layer(cfg, bytes_per_el) * attn_l
    mem = n_active * bytes_per_el                      # weight reads
    mem += tokens * kv_l                               # cache writes
    mem += decode_seqs * kv_l * max(eff_ctx, 1.0)      # decode cache reads
    mem += prefill_tokens * kv_l * 0.1                 # prefill reread (flash)
    # ssm state traffic
    if cfg.arch_type in ("ssm", "hybrid"):
        state = cfg.ssm_nheads * cfg.ssm_head_dim * cfg.ssm_state * 4 \
            if cfg.arch_type == "ssm" else cfg.lru_width * 4
        mem += decode_seqs * state * cfg.num_layers
    del n_total
    return flops, mem
