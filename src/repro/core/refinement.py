"""Mixed maturity-based action-space refinement (paper §4.4, Fig. 10).

Periodically re-centers a fine-grained action space (anchor +/- 150 MHz at
15 MHz steps) around the current best estimate of the optimum:

* Statistical refinement (t < t_mature): anchor = lowest historical mean
  EDP among sufficiently-sampled arms — trust data, not the immature model.
* Predictive refinement (t >= t_mature): anchor = argmax LinUCB UCB score
  for the CURRENT context x_t — trust the mature model, focus exploration
  where it predicts the highest reward.

2-D ``(f_prefill, f_decode)`` action spaces (``repro.core.tuner2d``)
refine the same way with a product grid: per-axis windows centered on the
anchor pair (coarser range/step — ``half_range_2d_mhz``/``step_2d_mhz`` —
so the arm count stays learnable), filtered by the same permanent-prune
set and band rules.

Under a fleet-assigned frequency band (``repro.policies.hierarchy``) the
anchor is already band-restricted (both ``best_historical`` and
``argmax_ucb`` select among legal arms only) and the candidate grid is
clipped to the band before rebuilding — refinement concentrates arms
where the coordinator allows the node to act instead of spending them on
frequencies the mask would immediately veto. A band too narrow to hold 3
grid points skips refinement (the bank's nearest-arm guarantee keeps at
least one action legal).
"""
from __future__ import annotations

import dataclasses
from typing import List, Optional

import numpy as np

from repro.core.linucb import LinUCBBank
from repro.core.pruning import PruningFramework


@dataclasses.dataclass
class RefinementConfig:
    enabled: bool = True
    interval: int = 25               # rounds between refinements
    maturity_threshold: int = 100    # t_mature
    stat_min_samples: int = 4
    half_range_mhz: float = 150.0
    step_mhz: float = 15.0
    # 2-D (f_prefill, f_decode) action spaces refine on a coarser product
    # grid per axis so arm count stays learnable (default 5x5 = 25 arms
    # per refinement vs the 1-D grid's 21)
    half_range_2d_mhz: float = 90.0
    step_2d_mhz: float = 45.0


class MixedMaturityRefinement:
    def __init__(self, cfg: RefinementConfig, f_min: float, f_max: float,
                 ucb_alpha: float = 1.0):
        self.cfg = cfg
        self.f_min = f_min
        self.f_max = f_max
        self.ucb_alpha = ucb_alpha
        self.log: List[dict] = []
        # anchor -> grid memo: refinement re-anchors on the same few
        # frequencies for most of a long run, and the grid is a pure
        # function of the anchor (callers never mutate the list)
        self._grid_cache: dict = {}

    # ------------------------------------------------------------------
    def _axis_grid(self, anchor: float, half_range: float,
                   step: float) -> List[float]:
        lo = max(self.f_min, anchor - half_range)
        hi = min(self.f_max, anchor + half_range)
        # np.float64 subclasses float, so round() on the tolist() floats
        # is the same float.__round__ the array elements would use
        grid = np.arange(lo, hi + 1e-9, step)
        return [round(f, 3) for f in grid.tolist()]

    def _candidate_grid(self, anchor) -> List[float]:
        cached = self._grid_cache.get(anchor)
        if cached is not None:
            return cached
        cfg = self.cfg
        if isinstance(anchor, tuple):
            # 2-D anchor: product of per-axis grids centered on the pair
            # (coarser per-axis range/step — see RefinementConfig)
            pf = self._axis_grid(anchor[0], cfg.half_range_2d_mhz,
                                 cfg.step_2d_mhz)
            de = self._axis_grid(anchor[1], cfg.half_range_2d_mhz,
                                 cfg.step_2d_mhz)
            out = [(a, b) for a in pf for b in de]
        else:
            out = self._axis_grid(anchor, cfg.half_range_mhz, cfg.step_mhz)
        self._grid_cache[anchor] = out
        return out

    def maybe_refine(self, bank: LinUCBBank, pruner: PruningFramework,
                     x_t: np.ndarray, round_idx: int,
                     anchor: Optional[float] = None) -> Optional[float]:
        """Returns the anchor if a refinement happened. ``anchor`` may carry
        a precomputed predictive anchor (the stacked fleet path batches the
        UCB argmax across due nodes); it must equal what
        ``bank.argmax_ucb(x_t, self.ucb_alpha)`` would return and is only
        consulted in the mature phase."""
        cfg = self.cfg
        if not cfg.enabled or round_idx == 0 or round_idx % cfg.interval:
            return None
        if round_idx < cfg.maturity_threshold:
            anchor = bank.best_historical(cfg.stat_min_samples)
            mode = "statistical"
            if anchor is None:
                return None
        else:
            if anchor is None:
                anchor = bank.argmax_ucb(x_t, self.ucb_alpha)
            mode = "predictive"
        grid = pruner.filter_candidates(self._candidate_grid(anchor))
        band = getattr(bank, "band", None)
        if band is not None:
            lo, hi = band[0] - 1e-9, band[1] + 1e-9
            if isinstance(anchor, tuple):
                # the band clips BOTH axes of a 2-D product grid
                grid = [f for f in grid
                        if lo <= f[0] <= hi and lo <= f[1] <= hi]
            else:
                grid = [f for f in grid if lo <= f <= hi]
        if len(grid) < 3:
            return None
        bank.rebuild(grid, warm_from=anchor)
        self.log.append({"round": round_idx, "anchor": anchor, "mode": mode,
                         "n_arms": len(grid)})
        return anchor
