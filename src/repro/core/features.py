"""The 7-dimensional privacy-preserving workload fingerprint (paper §3.3,
§4.1).

Consumes ONLY aggregate window statistics differenced from the engine's
Prometheus-style exporter — never per-request prompt content or lengths.
Dimensions (order fixed, matches the paper):

    x1 has_queue        1[requests_waiting > 0]
    x2 prefill_tput     new prompt tokens / s
    x3 decode_tput      generated tokens / s
    x4 packing_eff      tokens per engine iteration
    x5 concurrency      requests currently running
    x6 cache_usage      KV-block pool occupancy [0,1]
    x7 cache_hit_rate   prefix-cache hit fraction [0,1]
"""
from __future__ import annotations

import dataclasses
from typing import Optional

import numpy as np

from repro.energy.edp import WindowStats

FEATURE_NAMES = ("has_queue", "prefill_tput", "decode_tput", "packing_eff",
                 "concurrency", "cache_usage", "cache_hit_rate")


@dataclasses.dataclass
class FeatureScales:
    """Fixed normalization scales so LinUCB sees O(1) features. Defaults fit
    a single-GPU vLLM-class server; they are scales, not clamps of meaning —
    values are clipped to [0, 1.5] to bound the bandit's design matrix."""
    prefill_tput: float = 20_000.0     # tokens/s
    decode_tput: float = 4_000.0       # tokens/s
    packing_eff: float = 1_024.0       # tokens/iteration
    concurrency: float = 64.0          # max_num_seqs


class FeatureExtractor:
    def __init__(self, scales: Optional[FeatureScales] = None):
        self.scales = scales or FeatureScales()

    @property
    def dim(self) -> int:
        return len(FEATURE_NAMES)

    def __call__(self, w: WindowStats) -> np.ndarray:
        s = self.scales
        dur = max(w.duration_s, 1e-9)
        raw = np.array([
            1.0 if w.requests_waiting > 0 else 0.0,
            (w.prefill_tokens / dur) / s.prefill_tput,
            (w.generation_tokens / dur) / s.decode_tput,
            ((w.prefill_tokens + w.generation_tokens)
             / max(w.iterations, 1)) / s.packing_eff,
            w.requests_running / s.concurrency,
            w.gpu_cache_usage,
            w.cache_hit_rate,
        ], dtype=np.float64)
        return np.clip(raw, 0.0, 1.5)
