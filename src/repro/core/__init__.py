from repro.core.features import FEATURE_NAMES, FeatureExtractor, FeatureScales
from repro.core.linucb import LinUCBArm, LinUCBBank
from repro.core.monitor import TelemetryMonitor, aggregate_snapshots
from repro.core.page_hinkley import (ConvergenceConfig, ConvergenceDetector,
                                     PageHinkley)
from repro.core.pruning import PruningConfig, PruningFramework
from repro.core.refinement import MixedMaturityRefinement, RefinementConfig
from repro.core.reward import RewardCalculator, RewardConfig
from repro.core.tuner import AGFTConfig, AGFTTuner

__all__ = ["FEATURE_NAMES", "FeatureExtractor", "FeatureScales", "LinUCBArm",
           "LinUCBBank", "ConvergenceConfig", "ConvergenceDetector",
           "PageHinkley", "PruningConfig", "PruningFramework",
           "MixedMaturityRefinement", "RefinementConfig", "RewardCalculator",
           "RewardConfig", "AGFTConfig", "AGFTTuner", "TelemetryMonitor",
           "aggregate_snapshots"]
