"""AGFT: the closed-loop adaptive frequency tuner (paper §4, Fig. 8).

Wires the pieces together on the monitor's sampling cadence:
  metric snapshot -> WindowStats -> (reward for the PREVIOUS action,
  7-dim context x_t) -> LinUCB update -> pruning -> refinement ->
  action selection (UCB exploration / greedy exploitation, gated by the
  Page-Hinkley convergence detector) -> frequency actuation.

The tuner touches the engine ONLY through (a) the metrics snapshot —
windowed by the shared :class:`repro.core.monitor.TelemetryMonitor` — and
(b) ``set_frequency``, the non-invasive boundary the paper requires. It
conforms to the ``repro.policies.PowerPolicy`` protocol and is registered
in the policy registry as ``"agft"``.
"""
from __future__ import annotations

import dataclasses
from typing import List, Optional

import numpy as np

from repro.core.features import FeatureExtractor, FeatureScales
from repro.core.linucb import LinUCBBank
from repro.core.monitor import TelemetryMonitor
from repro.core.page_hinkley import ConvergenceConfig, ConvergenceDetector
from repro.core.pruning import PruningConfig, PruningFramework
from repro.core.refinement import MixedMaturityRefinement, RefinementConfig
from repro.core.reward import RewardCalculator, RewardConfig
from repro.energy.power_model import HardwareSpec


@dataclasses.dataclass
class AGFTConfig:
    sampling_period_s: float = 0.8         # paper: sub-second window
    ucb_alpha: float = 0.8
    ridge: float = 1.0
    # exploration strategy: "linucb" (paper) | "thompson" (extension)
    strategy: str = "linucb"
    thompson_nu: float = 0.3
    # initial action space: coarse sweep of the full range
    initial_step_mhz: float = 90.0
    # ablations
    fine_grained: bool = True              # False => "No-grain"
    # graceful degradation under fault injection (repro.serving.faults):
    # freeze bandit updates on faulted/stale telemetry windows, hold the
    # previous frequency, and re-issue actuations that diverged from
    # telemetry. False = the naive baseline that learns from corrupted
    # windows (benchmarks/tab_faults.py quantifies the difference).
    fault_aware: bool = True
    pruning: PruningConfig = dataclasses.field(default_factory=PruningConfig)
    refinement: RefinementConfig = dataclasses.field(
        default_factory=RefinementConfig)
    convergence: ConvergenceConfig = dataclasses.field(
        default_factory=ConvergenceConfig)
    reward: RewardConfig = dataclasses.field(default_factory=RewardConfig)
    scales: FeatureScales = dataclasses.field(default_factory=FeatureScales)


class AGFTTuner:
    #: PowerPolicy scope: governs one engine (fleet-scope policies in
    #: ``repro.policies.fleet`` declare ``scope = "fleet"``)
    scope = "node"

    def __init__(self, hardware: HardwareSpec,
                 cfg: Optional[AGFTConfig] = None):
        self.hw = hardware
        self.cfg = cfg or AGFTConfig()
        if not self.cfg.fine_grained:
            # "No-grain" ablation: coarse actions, no refinement
            self.cfg.refinement = dataclasses.replace(
                self.cfg.refinement, enabled=False)
            self.cfg.initial_step_mhz = max(self.cfg.initial_step_mhz, 120.0)

        self.features = FeatureExtractor(self.cfg.scales)
        freqs = list(np.arange(hardware.f_min, hardware.f_max + 1e-9,
                               self.cfg.initial_step_mhz))
        if hardware.f_max not in freqs:
            freqs.append(hardware.f_max)
        self.bank = LinUCBBank([float(f) for f in freqs],
                               dim=self.features.dim, ridge=self.cfg.ridge)
        self.pruner = PruningFramework(self.cfg.pruning, hardware.f_max)
        self.refiner = MixedMaturityRefinement(
            self.cfg.refinement, hardware.f_min, hardware.f_max,
            ucb_alpha=self.cfg.ucb_alpha)
        self.convergence = ConvergenceDetector(self.cfg.convergence)
        self.reward_calc = RewardCalculator(self.cfg.reward)

        # closed-loop state
        self.round = 0
        self.monitor = TelemetryMonitor(self.cfg.sampling_period_s)
        self.prev_action: Optional[float] = None
        self.prev_context: Optional[np.ndarray] = None
        self.prev_switched = False    # did actuating prev_action change f?
        self.switch_count = 0         # actual DVFS transitions actuated
        self.band: Optional[tuple] = None   # fleet-assigned [f_lo, f_hi]
        self.history: List[dict] = []

    # ------------------------------------------------------------------
    def set_band(self, f_lo: float, f_hi: float) -> None:
        """Fleet-coordinator hook (hierarchical power capping): restrict
        the action space to ``[f_lo, f_hi]`` by masking LinUCB arms outside
        the band. Inverted bounds are tolerated (swapped), the band is
        clamped to the hardware envelope, and masking is reversible — a
        later, wider band re-legalizes the arms with their learned
        statistics intact. With no band set, decisions are bit-identical
        to the uncoordinated tuner."""
        lo, hi = (float(f_lo), float(f_hi))
        if lo > hi:
            lo, hi = hi, lo
        lo = min(max(lo, self.hw.f_min), self.hw.f_max)
        hi = min(max(hi, self.hw.f_min), self.hw.f_max)
        self.band = (lo, hi)
        self.bank.set_band(lo, hi)

    # ------------------------------------------------------------------
    @property
    def converged(self) -> bool:
        return self.convergence.converged

    @property
    def converged_round(self):
        return self.convergence.converged_round

    @property
    def first_converged_round(self):
        return self.convergence.first_converged_round

    # ------------------------------------------------------------------
    def maybe_act(self, engine) -> Optional[float]:
        """PowerPolicy entrypoint: called after every engine step; acts when
        the sampling window has elapsed. Returns the chosen frequency when
        it acts."""
        if not self.monitor.due(engine):
            return None
        return self.act(engine)

    def tick(self, engine, now: float) -> float:
        """POLICY_TICK entrypoint (``policy_tick_mode="tick"``): one
        decision per wall-clock tick, the telemetry window cut at the
        tick's virtual time ``now`` instead of at an iteration boundary
        (the event loop owns the cadence; no due-gating here)."""
        return self.act(engine, now=now)

    def act(self, engine, now: Optional[float] = None) -> float:
        # fault surface (None on healthy engines — the zero-fault path
        # pays one attribute read and stays decision-identical)
        fs = (getattr(engine, "fault_state", None)
              if self.cfg.fault_aware else None)
        if fs is not None and fs.scrape_dropped(
                engine.clock if now is None else now):
            # telemetry dropout: the scrape failed, the window is blank.
            # Re-arm the monitor without snapshotting (the next success
            # spans the gap) and hold the last safe frequency — no
            # context, no reward, nothing for the bandit to learn from.
            self.monitor.skip(engine, now=now)
            return self._fault_hold(engine, None, t=now)
        w_start = self.monitor.prev_time
        window = self.monitor.observe(engine, now=now)
        if window is None:
            # first observation: the monitor armed the window; take the floor
            f0 = self.bank.select_ucb(np.zeros(self.features.dim),
                                      self.cfg.ucb_alpha)
            self._actuate(engine, f0, None, None, None, t=now)
            return f0

        if fs is not None and (fs.disrupted_since(w_start)
                               or self._diverged(engine)):
            # faulted/stale window: a crash, recovery, throttle flip, or
            # dropout touched it — or the actuator silently stuck and the
            # engine diverged from the issued frequency. Its telemetry
            # would poison the LinUCB statistics, so freeze: no credit,
            # no convergence step, no refinement; hold the previous
            # frequency (re-issuing it, which is the stuck-DVFS recovery)
            # and withhold the corrupted context from the next credit.
            return self._fault_hold(engine, window, t=now)

        x_t = self.features(window)

        # 1. credit the previous action (billing its DVFS transition, if
        # the reward config prices switches)
        reward = None
        if self.prev_action is not None and self.prev_context is not None:
            reward = self.reward_calc(window, switched=self.prev_switched)
            arm = self.bank.arms.get(self.prev_action)
            if arm is not None:
                arm.update(self.prev_context, reward, edp=window.edp)
            self.convergence.update(reward)
            self.round += 1

        # 2. prune, refine (refinement only while learning: once converged
        # the system is in pure exploitation and the action space is frozen;
        # a Page-Hinkley drift alarm reopens both)
        self.pruner.apply(self.bank, self.round)
        if not self.convergence.converged:
            self.refiner.maybe_refine(self.bank, self.pruner, x_t,
                                      self.round)

        # 3. select
        if self.convergence.converged:
            f = self.bank.select_greedy(x_t)
            phase = "exploit"
        elif self.cfg.strategy == "thompson":
            f = self.bank.select_thompson(x_t, self.cfg.thompson_nu)
            phase = "explore"
        else:
            f = self.bank.select_ucb(x_t, self.cfg.ucb_alpha)
            phase = "explore"

        # 4. actuate + bookkeeping (the monitor already re-armed the window)
        self._actuate(engine, f, reward, window, phase, x_t, t=now)
        return f

    # ------------------------------------------------------------------
    def _diverged(self, engine) -> bool:
        """Did the engine's actuated state silently diverge from the last
        issued action (stuck/clamped DVFS under fault injection)? The 2-D
        tuner overrides this to compare phase-target pairs."""
        return (self.prev_action is not None
                and engine.frequency != self.prev_action)

    def _fault_hold(self, engine, window, t: Optional[float] = None
                    ) -> float:
        """Graceful degradation on a faulted window: re-issue the previous
        action (safe hold — also the stuck-actuator recovery path), record
        a ``fault-hold`` history row, and clear ``prev_context`` so the
        bandit credits nothing that touched corrupted telemetry."""
        f = (self.prev_action if self.prev_action is not None
             else float(engine.frequency))
        self._actuate(engine, f, None, window, "fault-hold", None, t=t)
        self.prev_context = None
        return f

    def _actuate(self, engine, f: float, reward, window, phase,
                 x_t: Optional[np.ndarray] = None,
                 t: Optional[float] = None) -> None:
        engine.set_frequency(f)
        self.prev_switched = (self.prev_action is not None
                              and float(f) != self.prev_action)
        self.switch_count += int(self.prev_switched)
        self.prev_action = float(f)
        self.prev_context = (x_t if x_t is not None
                             else np.zeros(self.features.dim))
        self.history.append({
            "t": engine.clock if t is None else t,
            "freq": float(f),
            "reward": reward,
            "edp": window.edp if window else None,
            "energy_j": window.energy_j if window else None,
            "tpot": window.effective_tpot if window else None,
            "phase": phase or "warmup",
            "n_arms": len(self.bank.arms),
            "converged": self.convergence.converged,
            "band": self.band,
        })
