"""LinUCB contextual bandit (paper §4.2, eqs. 1-5).

Each frequency is an arm with ridge-regression sufficient statistics
    A_f = I + sum x xᵀ,   b_f = sum r x,   theta_f = A_f⁻¹ b_f
selected by  argmax theta_fᵀx + alpha sqrt(xᵀ A_f⁻¹ x)  during exploration
and argmax theta_fᵀx during exploitation. A⁻¹ is maintained incrementally
(Sherman-Morrison), so a decision is O(|F| d²) — microseconds at d=7.

Storage is structure-of-arrays: the bank holds stacked ``(n_arms, d, d)``
``A``/``A_inv`` and ``(n_arms, d)`` ``b``/``theta`` plus per-arm counters,
kept in ascending-frequency order. Selection rules are einsum-vectorized
over the stack (one numpy dispatch per decision instead of one per arm),
and updates are in-place row operations. The historical dict-of-arms API —
``bank.arms[f].update(...)``, ``arm.n``, ``arm.ucb(x, alpha)`` — survives
as a zero-copy view (:class:`_ArmView`/:class:`_ArmMap`) so the pruning and
refinement frameworks work unchanged.

Arm order is deterministic: always ascending frequency, regardless of
``rebuild``/``remove`` history, so tie-breaks and Thompson's RNG-draw-to-arm
pairing never depend on action-space mutation order.

Actions are opaque sortable keys: 1-D banks key arms by ``float``
frequency; 2-D phase-disaggregated banks (``repro.core.tuner2d``) key them
by ``(f_prefill, f_decode)`` pairs, which sort lexicographically so the
deterministic-order guarantees carry over. The linear model per arm is
unchanged — only band legality branches on the key kind (a pair is legal
when BOTH clocks are in band).

Frequency bands (hierarchical fleet control): ``set_band(f_lo, f_hi)``
restricts *selection* to arms inside ``[f_lo, f_hi]`` via a reversible
boolean mask over the stack — statistics are never destroyed, so a band
that widens on a later FLEET_TICK instantly re-legalizes the arms it had
masked. At least one arm is always legal (the nearest to the band's
midpoint when the band contains none), and with no band set every
selection path is byte-for-byte the unmasked code.
"""
from __future__ import annotations

import bisect
from collections.abc import Mapping
from typing import Dict, Iterator, List, Optional, Sequence, Tuple

import numpy as np


def _key(f):
    """Canonical arm key: ``float`` for 1-D frequency actions,
    ``(float, float)`` for 2-D ``(f_prefill, f_decode)`` actions (see
    ``repro.core.tuner2d``). Pairs sort lexicographically, preserving the
    bank's deterministic ascending-action order; a bank holds one kind of
    key for its whole life (mixing is a caller bug)."""
    if isinstance(f, tuple):
        return (float(f[0]), float(f[1]))
    return float(f)


class LinUCBArm:
    """A standalone single arm (kept for direct use and as the reference
    implementation the vectorized bank is tested against)."""

    def __init__(self, dim: int, ridge: float = 1.0):
        self.dim = dim
        self.A = np.eye(dim) * ridge
        self.A_inv = np.eye(dim) / ridge
        self.b = np.zeros(dim)
        self.theta = np.zeros(dim)
        self.n = 0
        self.reward_sum = 0.0
        self.edp_sum = 0.0

    # ------------------------------------------------------------------
    def update(self, x: np.ndarray, reward: float,
               edp: Optional[float] = None) -> None:
        self.A += np.outer(x, x)
        # Sherman-Morrison rank-1 inverse update
        Ax = self.A_inv @ x
        self.A_inv -= np.outer(Ax, Ax) / (1.0 + x @ Ax)
        self.b += reward * x
        self.theta = self.A_inv @ self.b
        self.n += 1
        self.reward_sum += reward
        if edp is not None:
            self.edp_sum += edp

    # ------------------------------------------------------------------
    @property
    def mean_reward(self) -> float:
        return self.reward_sum / self.n if self.n else 0.0

    @property
    def mean_edp(self) -> float:
        return self.edp_sum / self.n if self.n else float("inf")

    def predict(self, x: np.ndarray) -> float:
        return float(self.theta @ x)

    def ucb(self, x: np.ndarray, alpha: float) -> float:
        bonus = alpha * float(np.sqrt(max(x @ self.A_inv @ x, 0.0)))
        return self.predict(x) + bonus


class _ArmView:
    """Live view of one bank row presenting the ``LinUCBArm`` interface.

    Attribute reads return (writable) slices of the bank's stacked arrays;
    ``update`` delegates to the bank's in-place row update. Views resolve
    their row index on every access, so they stay correct across
    ``remove``/``rebuild`` (and raise ``KeyError`` once the arm is gone).
    """

    __slots__ = ("_bank", "f")

    def __init__(self, bank: "LinUCBBank", f: float):
        self._bank = bank
        self.f = f

    @property
    def _i(self) -> int:
        return self._bank._index[self.f]

    @property
    def dim(self) -> int:
        return self._bank.dim

    @property
    def A(self) -> np.ndarray:
        return self._bank._A[self._i]

    @property
    def A_inv(self) -> np.ndarray:
        return self._bank._A_inv[self._i]

    @property
    def b(self) -> np.ndarray:
        return self._bank._b[self._i]

    @property
    def theta(self) -> np.ndarray:
        return self._bank._theta[self._i]

    @property
    def n(self) -> int:
        return int(self._bank._n[self._i])

    @property
    def reward_sum(self) -> float:
        return float(self._bank._reward_sum[self._i])

    @property
    def edp_sum(self) -> float:
        return float(self._bank._edp_sum[self._i])

    @property
    def mean_reward(self) -> float:
        n = self.n
        return self.reward_sum / n if n else 0.0

    @property
    def mean_edp(self) -> float:
        n = self.n
        return self.edp_sum / n if n else float("inf")

    def update(self, x: np.ndarray, reward: float,
               edp: Optional[float] = None) -> None:
        self._bank.update_arm(self.f, x, reward, edp)

    def predict(self, x: np.ndarray) -> float:
        return float(self.theta @ x)

    def ucb(self, x: np.ndarray, alpha: float) -> float:
        bonus = alpha * float(np.sqrt(max(x @ self.A_inv @ x, 0.0)))
        return self.predict(x) + bonus

    def __repr__(self) -> str:
        return f"_ArmView(f={self.f}, n={self.n})"


class _ArmMap(Mapping):
    """Read-only mapping ``frequency -> _ArmView`` over the bank, iterating
    in ascending-frequency order. Mutation goes through the bank
    (``remove``/``rebuild``), never through this map."""

    __slots__ = ("_bank",)

    def __init__(self, bank: "LinUCBBank"):
        self._bank = bank

    def __getitem__(self, f) -> _ArmView:
        f = _key(f)
        if f not in self._bank._index:
            raise KeyError(f)
        return _ArmView(self._bank, f)

    def __iter__(self) -> Iterator[float]:
        return iter(self._bank._f)

    def __len__(self) -> int:
        return len(self._bank._f)

    def __contains__(self, f) -> bool:           # avoid Mapping's try/except
        return _key(f) in self._bank._index


class LinUCBBank:
    """The arm set over the current (mutable) frequency action space.

    Selection strategies (beyond-paper extension):
      * "linucb"   — the paper's UCB rule (eq. 1/2)
      * "thompson" — linear Thompson sampling: per arm, sample
        theta ~ N(theta_f, nu^2 A_f^-1) and pick argmax x' theta_sample.
        Randomized exploration composes better with non-stationary reward
        drift (no deterministic untried-arm sweeps); compared empirically
        in benchmarks/ext_thompson.py.
    """

    def __init__(self, frequencies: Sequence[float], dim: int,
                 ridge: float = 1.0, seed: int = 0):
        self.dim = dim
        self.ridge = ridge
        self.rng = np.random.default_rng(seed)
        self.arms = _ArmMap(self)
        self._band: Optional[Tuple[float, float]] = None
        self._legal: Optional[np.ndarray] = None   # bool mask; None = all
        self._alloc(sorted({_key(f) for f in frequencies}))

    # -- storage -------------------------------------------------------
    def _alloc(self, freqs: List[float]) -> None:
        n, d = len(freqs), self.dim
        self._f: List[float] = freqs              # ascending, deduplicated
        #: pair-keyed (2-D action) banks branch only in band legality;
        #: every selection/update path is key-agnostic
        self._pair = bool(freqs) and isinstance(freqs[0], tuple)
        self._index: Dict[float, int] = {f: i for i, f in enumerate(freqs)}
        eye = np.eye(d)
        self._A = np.broadcast_to(eye * self.ridge, (n, d, d)).copy()
        self._A_inv = np.broadcast_to(eye / self.ridge, (n, d, d)).copy()
        self._b = np.zeros((n, d))
        self._theta = np.zeros((n, d))
        self._n = np.zeros(n, dtype=np.int64)
        self._reward_sum = np.zeros(n)
        self._edp_sum = np.zeros(n)
        self._apply_band()

    def _drop_rows(self, keep: np.ndarray) -> None:
        self._f = [f for f, k in zip(self._f, keep) if k]
        self._index = {f: i for i, f in enumerate(self._f)}
        self._A = self._A[keep]
        self._A_inv = self._A_inv[keep]
        self._b = self._b[keep]
        self._theta = self._theta[keep]
        self._n = self._n[keep]
        self._reward_sum = self._reward_sum[keep]
        self._edp_sum = self._edp_sum[keep]
        self._apply_band()

    # -- frequency band (hierarchical fleet control) -------------------
    @property
    def band(self) -> Optional[Tuple[float, float]]:
        return self._band

    def set_band(self, f_lo: float, f_hi: float) -> None:
        """Restrict selection to arms inside ``[f_lo, f_hi]`` (inclusive,
        inverted bounds tolerated). Reversible — statistics survive; the
        mask is recomputed on every action-space mutation."""
        lo, hi = (float(f_lo), float(f_hi))
        if lo > hi:
            lo, hi = hi, lo
        self._band = (lo, hi)
        self._apply_band()

    def clear_band(self) -> None:
        self._band = None
        self._legal = None

    def _apply_band(self) -> None:
        """Recompute the legal-arm mask; a band that contains no arm (e.g.
        narrower than the grid step) legalizes the single arm nearest to
        its midpoint so the bandit always has an action."""
        if self._band is None:
            self._legal = None
            return
        lo, hi = self._band
        f = np.asarray(self._f)
        if self._pair:
            # 2-D actions: the band intersects BOTH axes — a pair is legal
            # only when prefill AND decode clocks lie inside [lo, hi], so
            # hierarchy/thermal clamps compose with phase disaggregation.
            # Empty-band fallback: the pair nearest (Euclidean) to the
            # midpoint corner (mid, mid).
            legal = ((f >= lo - 1e-9) & (f <= hi + 1e-9)).all(axis=1)
            if not legal.any() and len(f):
                mid = (lo + hi) / 2.0
                d2 = ((f - mid) ** 2).sum(axis=1)
                legal[int(np.argmin(d2))] = True
            self._legal = legal
            return
        legal = (f >= lo - 1e-9) & (f <= hi + 1e-9)
        if not legal.any() and len(f):
            legal[int(np.argmin(np.abs(f - (lo + hi) / 2.0)))] = True
        self._legal = legal

    def is_legal(self, f: float) -> bool:
        return (self._legal is None
                or bool(self._legal[self._index[_key(f)]]))

    def n_legal(self) -> int:
        return (len(self._f) if self._legal is None
                else int(self._legal.sum()))

    def legal_frequencies(self) -> List[float]:
        if self._legal is None:
            return list(self._f)
        return [f for f, ok in zip(self._f, self._legal) if ok]

    def _argmax_legal(self, scores: np.ndarray) -> float:
        """Highest-scoring legal arm; ties break to the lowest frequency
        (subsetting preserves ascending order)."""
        if self._legal is None:
            return self._f[int(np.argmax(scores))]
        idx = np.flatnonzero(self._legal)
        return self._f[int(idx[int(np.argmax(scores[idx]))])]

    # ------------------------------------------------------------------
    @property
    def frequencies(self) -> List[float]:
        return list(self._f)

    def arm_stats(self) -> List[Tuple[float, int, float, float]]:
        """``(f, n, mean_reward, mean_edp)`` per arm in ascending-frequency
        order, computed in one vectorized pass — the bulk-read interface
        the pruning framework walks (identical values to reading each
        ``arms[f]`` view: same elementwise divisions, same zero/inf
        conventions for unsampled arms)."""
        n = self._n
        safe = np.where(n > 0, n, 1)
        mr = np.where(n > 0, self._reward_sum / safe, 0.0)
        me = np.where(n > 0, self._edp_sum / safe, np.inf)
        return list(zip(self._f, n.tolist(), mr.tolist(), me.tolist()))

    def remove(self, f: float) -> None:
        i = self._index.get(_key(f))
        if i is None:
            return
        keep = np.ones(len(self._f), dtype=bool)
        keep[i] = False
        self._drop_rows(keep)

    def rebuild(self, frequencies: Sequence[float],
                warm_from: Optional[float] = None) -> None:
        """Refinement: re-center the action space. Arms for surviving
        frequencies keep their statistics; NEW arms are warm-started from
        the anchor arm's sufficient statistics (nearby frequencies behave
        similarly — a sane prior that avoids re-exploring a fresh grid from
        scratch after every refinement)."""
        old_index, old = self._index, (self._A, self._A_inv, self._b,
                                       self._theta, self._n,
                                       self._reward_sum, self._edp_sum)
        proto = old_index.get(_key(warm_from)) if warm_from is not None \
            else None
        if proto is not None and old[4][proto] == 0:
            proto = None                          # untouched anchor: no prior
        self._alloc(sorted({_key(f) for f in frequencies}))
        for f, i in self._index.items():
            src = old_index.get(f, proto)
            if src is None:
                continue
            self._A[i] = old[0][src]
            self._A_inv[i] = old[1][src]
            self._b[i] = old[2][src]
            self._theta[i] = old[3][src]
            self._n[i] = old[4][src]
            self._reward_sum[i] = old[5][src]
            self._edp_sum[i] = old[6][src]

    # -- updates -------------------------------------------------------
    def update_arm(self, f: float, x: np.ndarray, reward: float,
                   edp: Optional[float] = None) -> None:
        """Sherman-Morrison rank-1 update of one arm, in place on the
        stacked arrays (arithmetic-identical to ``LinUCBArm.update``)."""
        i = self._index[_key(f)]
        self._A[i] += np.outer(x, x)
        A_inv = self._A_inv[i]
        Ax = A_inv @ x
        A_inv -= np.outer(Ax, Ax) / (1.0 + x @ Ax)
        b = self._b[i]
        b += reward * x
        self._theta[i] = A_inv @ b
        self._n[i] += 1
        self._reward_sum[i] += reward
        if edp is not None:
            self._edp_sum[i] += edp

    def update_arms(self, fs: Sequence[float], xs: np.ndarray,
                    rewards: Sequence[float],
                    edps: Optional[Sequence[float]] = None) -> None:
        """Batched Sherman-Morrison: credit one observation to each of
        several DISTINCT arms in a single einsum pass. No in-tree policy
        batches credits yet (the tuner settles one window at a time via
        ``update_arm``); this is the vectorized-bank API for controllers
        that do, kept numerically equivalent by the hot-path tests."""
        idx = np.array([self._index[_key(f)] for f in fs])
        if len(set(idx.tolist())) != len(idx):
            raise ValueError("update_arms requires distinct arms; "
                             "sequential rank-1 updates to one arm do not "
                             "commute with batching")
        X = np.asarray(xs, dtype=float).reshape(len(idx), self.dim)
        r = np.asarray(rewards, dtype=float)
        self._A[idx] += np.einsum("bi,bj->bij", X, X)
        Ax = np.einsum("bij,bj->bi", self._A_inv[idx], X)
        denom = 1.0 + np.einsum("bi,bi->b", X, Ax)
        self._A_inv[idx] -= np.einsum("bi,bj->bij", Ax, Ax) \
            / denom[:, None, None]
        self._b[idx] += r[:, None] * X
        self._theta[idx] = np.einsum("bij,bj->bi", self._A_inv[idx],
                                     self._b[idx])
        self._n[idx] += 1
        self._reward_sum[idx] += r
        if edps is not None:
            self._edp_sum[idx] += np.asarray(edps, dtype=float)

    # -- selection (vectorized over the stack) -------------------------
    def _scores_ucb(self, x: np.ndarray, alpha: float) -> np.ndarray:
        quad = np.einsum("i,aij,j->a", x, self._A_inv, x)
        return self._theta @ x + alpha * np.sqrt(np.maximum(quad, 0.0))

    def select_ucb(self, x: np.ndarray, alpha: float) -> float:
        # untried arms first (infinite-bonus convention), lowest-f first so
        # exploration sweeps upward through the cheap range
        untried = self._n == 0
        if self._legal is not None:
            untried = untried & self._legal
        if untried.any():
            return self._f[int(np.argmax(untried))]
        return self.argmax_ucb(x, alpha)

    def argmax_ucb(self, x: np.ndarray, alpha: float) -> float:
        """Highest-UCB arm, ignoring the untried-arm convention (used by
        predictive refinement to pick its anchor). Ties break to the lowest
        frequency."""
        return self._argmax_legal(self._scores_ucb(x, alpha))

    def select_thompson(self, x: np.ndarray, nu: float = 0.3) -> float:
        """Linear Thompson sampling over the arm set: one batched Cholesky
        of the (symmetrized) covariances, one (n_arms, d) normal draw."""
        n, d = len(self._f), self.dim
        sym = (self._A_inv + np.swapaxes(self._A_inv, 1, 2)) / 2.0 \
            + 1e-12 * np.eye(d)
        try:
            L = np.linalg.cholesky(sym)
        except np.linalg.LinAlgError:
            L = np.empty_like(sym)                # salvage the healthy arms
            for i in range(n):
                try:
                    L[i] = np.linalg.cholesky(sym[i])
                except np.linalg.LinAlgError:
                    L[i] = np.eye(d)
        z = self.rng.standard_normal((n, d))
        theta_s = self._theta + nu * np.einsum("aij,aj->ai", L, z)
        return self._argmax_legal(theta_s @ x)

    def select_greedy(self, x: np.ndarray) -> float:
        return self._argmax_legal(self._theta @ x)

    def best_historical(self, min_samples: int = 1) -> Optional[float]:
        mask = self._n >= min_samples
        if self._legal is not None:
            mask = mask & self._legal
        if not mask.any():
            return None
        mean_edp = np.full(len(self._f), np.inf)
        np.divide(self._edp_sum, self._n, out=mean_edp, where=mask)
        return self._f[int(np.argmin(mean_edp))]


# ---------------------------------------------------------------------------
# Stacked banks: one more SoA level — (n_nodes, n_slots, ...) — so a fleet of
# per-node LinUCB banks selects and updates in single numpy dispatches.
# ---------------------------------------------------------------------------

class _StackedArmView:
    """``LinUCBArm``-compatible view of one (node, frequency) row of a
    :class:`StackedBanks` — resolved live, like :class:`_ArmView`."""

    __slots__ = ("_banks", "_node", "f")

    def __init__(self, banks: "StackedBanks", node: int, f: float):
        self._banks = banks
        self._node = node
        self.f = f

    @property
    def _s(self) -> int:
        s = self._banks.slot_of(self._node, self.f)
        if s < 0:
            raise KeyError(self.f)
        return s

    @property
    def n(self) -> int:
        return int(self._banks.n_[self._node, self._s])

    @property
    def reward_sum(self) -> float:
        return float(self._banks.reward_sum[self._node, self._s])

    @property
    def edp_sum(self) -> float:
        return float(self._banks.edp_sum[self._node, self._s])

    @property
    def mean_reward(self) -> float:
        n = self.n
        return self.reward_sum / n if n else 0.0

    @property
    def mean_edp(self) -> float:
        n = self.n
        return self.edp_sum / n if n else float("inf")


class _StackedArmMap(Mapping):
    """Read-only ``frequency -> _StackedArmView`` mapping for one node of a
    :class:`StackedBanks` (ascending-frequency iteration order) — the
    interface :class:`repro.core.pruning.PruningFramework` walks."""

    __slots__ = ("_banks", "_node")

    def __init__(self, banks: "StackedBanks", node: int):
        self._banks = banks
        self._node = node

    def __getitem__(self, f) -> _StackedArmView:
        f = float(f)
        if self._banks.slot_of(self._node, f) < 0:
            raise KeyError(f)
        return _StackedArmView(self._banks, self._node, f)

    def __iter__(self) -> Iterator[float]:
        return iter(self._banks.node_frequencies(self._node))

    def __len__(self) -> int:
        return int(self._banks.m[self._node])

    def __contains__(self, f) -> bool:
        return self._banks.slot_of(self._node, float(f)) >= 0


class StackedBankView:
    """``LinUCBBank``-compatible facade over ONE node of a
    :class:`StackedBanks` — the adapter through which the unchanged
    per-node pruning/refinement frameworks mutate the stack. Every method
    reproduces the corresponding ``LinUCBBank`` arithmetic on this node's
    row slices (same expressions, same numpy calls on the same logical
    shapes), so a framework acting through the view is bit-identical to
    one acting on a standalone bank."""

    __slots__ = ("_banks", "_node", "arms", "band")

    def __init__(self, banks: "StackedBanks", node: int):
        self._banks = banks
        self._node = node
        self.arms = _StackedArmMap(banks, node)
        self.band = None                      # stacked path: no fleet bands

    @property
    def frequencies(self) -> List[float]:
        return self._banks.node_frequencies(self._node)

    def is_legal(self, f: float) -> bool:
        return True

    def n_legal(self) -> int:
        return int(self._banks.m[self._node])

    def remove(self, f: float) -> None:
        self._banks.remove(self._node, f)

    def rebuild(self, frequencies: Sequence[float],
                warm_from: Optional[float] = None) -> None:
        self._banks.rebuild(self._node, frequencies, warm_from)

    def best_historical(self, min_samples: int = 1) -> Optional[float]:
        return self._banks.best_historical(self._node, min_samples)

    def argmax_ucb(self, x: np.ndarray, alpha: float) -> float:
        return self._banks.argmax_ucb(self._node, x, alpha)

    def arm_stats(self) -> List[Tuple[float, int, float, float]]:
        """Bulk ``(f, n, mean_reward, mean_edp)`` read — see
        ``LinUCBBank.arm_stats``; row slices of this node's stack."""
        b, i = self._banks, self._node
        m = int(b.m[i])
        n = b.n_[i, :m]
        safe = np.where(n > 0, n, 1)
        mr = np.where(n > 0, b.reward_sum[i, :m] / safe, 0.0)
        me = np.where(n > 0, b.edp_sum[i, :m] / safe, np.inf)
        return list(zip(b._freq_list(i), n.tolist(), mr.tolist(),
                        me.tolist()))


class StackedBanks:
    """A fleet of per-node LinUCB banks stored as one more SoA level:
    ``(n_nodes, capacity, ...)`` stacks with per-node active-slot counts.

    Invariants per node ``i``: slots ``[0, m[i])`` hold the live arms in
    ascending-frequency order (matching ``LinUCBBank._f``); dead slots keep
    pristine ridge statistics (finite values, so batched selection over the
    full ``capacity`` axis stays NaN-free and is masked afterwards).

    Batched operations use only ops verified bit-identical to the scalar
    bank's: ``einsum('ki,kj->kij')`` for outers, batched ``matmul`` for
    gemv/dot (NOT ``einsum('ki,ki->k')``, whose reduction order differs
    from BLAS ddot), and the quad form ``einsum('ki,kaij,kj->ka')``.
    Per-node mutation (``remove``/``rebuild``, driven by the unchanged
    pruning/refinement frameworks through :class:`StackedBankView`) edits
    row slices in place.
    """

    def __init__(self, n_nodes: int, frequencies: Sequence[float], dim: int,
                 ridge: float = 1.0, capacity: Optional[int] = None):
        freqs = sorted({float(f) for f in frequencies})
        self.n_nodes = n_nodes
        self.dim = dim
        self.ridge = ridge
        K = capacity or max(len(freqs) + 4, 24)
        if K < len(freqs):
            raise ValueError(f"capacity {K} < initial arms {len(freqs)}")
        self.capacity = K
        d = dim
        self._eye_A = np.eye(d) * ridge
        self._eye_Ainv = np.eye(d) / ridge
        self.freqs = np.full((n_nodes, K), np.inf)
        self.freqs[:, :len(freqs)] = freqs
        self.m = np.full(n_nodes, len(freqs), dtype=np.int64)
        self.A = np.broadcast_to(self._eye_A, (n_nodes, K, d, d)).copy()
        self.A_inv = np.broadcast_to(self._eye_Ainv, (n_nodes, K, d, d)).copy()
        self.b = np.zeros((n_nodes, K, d))
        self.theta = np.zeros((n_nodes, K, d))
        self.n_ = np.zeros((n_nodes, K), dtype=np.int64)
        self.reward_sum = np.zeros((n_nodes, K))
        self.edp_sum = np.zeros((n_nodes, K))
        # per-node active-frequency lists, memoised for the scalar
        # adapters (pruning walks resolve slots thousands of times per
        # mutation); invalidated by _reset_slot/remove/rebuild
        self._flist: Dict[int, List[float]] = {}

    # -- per-node introspection ----------------------------------------
    def _freq_list(self, i: int) -> List[float]:
        fl = self._flist.get(i)
        if fl is None:
            fl = self.freqs[i, :self.m[i]].tolist()
            self._flist[i] = fl
        return fl

    def node_frequencies(self, i: int) -> List[float]:
        return list(self._freq_list(i))

    def slot_of(self, i: int, f: float) -> int:
        """Active slot holding frequency ``f`` on node ``i``; -1 if absent."""
        row = self._freq_list(i)
        s = bisect.bisect_left(row, f)
        if s < len(row) and row[s] == f:
            return s
        return -1

    def view(self, i: int) -> StackedBankView:
        return StackedBankView(self, i)

    # -- vectorized slot resolution ------------------------------------
    def slots_for(self, idx: np.ndarray, fs: np.ndarray) -> np.ndarray:
        """For each (node, frequency) pair: its active slot, or -1 when the
        frequency is no longer in that node's action space (pruned or
        dropped by a rebuild — the ``bank.arms.get(...) is None`` case)."""
        rows = self.freqs[idx]                              # (k, K)
        slots = np.sum(rows < fs[:, None], axis=1)
        k = len(idx)
        hit = np.zeros(k, dtype=bool)
        in_range = slots < self.capacity
        safe = np.where(in_range, slots, 0)
        hit = in_range & (rows[np.arange(k), safe] == fs) \
            & (safe < self.m[idx])
        return np.where(hit, safe, -1)

    # -- batched update (Sherman-Morrison) -----------------------------
    def update_rows(self, nodes: np.ndarray, slots: np.ndarray,
                    X: np.ndarray, rewards: np.ndarray,
                    edps: np.ndarray) -> None:
        """Credit one observation to one arm per node, all nodes at once.
        Arithmetic-identical to ``LinUCBBank.update_arm`` row by row."""
        sel = (nodes, slots)
        self.A[sel] += np.einsum("ki,kj->kij", X, X)
        Ainv = self.A_inv[sel]
        Ax = np.matmul(Ainv, X[:, :, None])[:, :, 0]
        denom = 1.0 + np.matmul(X[:, None, :], Ax[:, :, None])[:, 0, 0]
        Ainv -= np.einsum("ki,kj->kij", Ax, Ax) / denom[:, None, None]
        self.A_inv[sel] = Ainv
        bsel = self.b[sel]
        bsel += rewards[:, None] * X
        self.b[sel] = bsel
        self.theta[sel] = np.matmul(Ainv, bsel[:, :, None])[:, :, 0]
        self.n_[sel] += 1
        self.reward_sum[sel] += rewards
        self.edp_sum[sel] += edps

    # -- batched selection ---------------------------------------------
    def select_batch(self, idx: np.ndarray, X: np.ndarray, alpha: float,
                     greedy: np.ndarray):
        """Per-node arm choice: ``select_greedy`` where ``greedy`` is set,
        ``select_ucb`` (untried-first, then UCB argmax) elsewhere. Returns
        ``(slots, freqs)``. First-max argmax over ascending active slots
        reproduces the scalar banks' lowest-frequency tie-break."""
        K = self.capacity
        valid = np.arange(K)[None, :] < self.m[idx][:, None]
        theta = self.theta[idx]
        tx = np.matmul(theta, X[:, :, None])[:, :, 0]
        ng = ~greedy
        if ng.any():
            # the exploration bonus (the quad form — the dominant cost)
            # is only consulted on non-greedy rows; each row's einsum
            # contraction is independent of its batch neighbours, so the
            # subset dispatch is bit-identical to the full one
            sub = idx[ng]
            Xs = X[ng]
            quad = np.einsum("ki,kaij,kj->ka", Xs, self.A_inv[sub], Xs)
            scores = tx.copy()
            scores[ng] = tx[ng] + alpha * np.sqrt(np.maximum(quad, 0.0))
        else:
            scores = tx
        scores = np.where(valid, scores, -np.inf)
        slot = np.argmax(scores, axis=1)
        if ng.any():
            untried = valid[ng] & (self.n_[idx[ng]] == 0)
            has_u = untried.any(axis=1)
            if has_u.any():
                sl = slot[ng]
                slot[ng] = np.where(has_u, np.argmax(untried, axis=1), sl)
        return slot, self.freqs[idx, slot]

    # -- per-node mutation (pruning / refinement path) -----------------
    def _reset_slot(self, i: int, s: int) -> None:
        self._flist.pop(i, None)
        self.freqs[i, s] = np.inf
        self.A[i, s] = self._eye_A
        self.A_inv[i, s] = self._eye_Ainv
        self.b[i, s] = 0.0
        self.theta[i, s] = 0.0
        self.n_[i, s] = 0
        self.reward_sum[i, s] = 0.0
        self.edp_sum[i, s] = 0.0

    def _reset_node(self, i: int) -> None:
        """Broadcast reset of every slot of node ``i`` — one array write
        per stack instead of ``capacity`` scalar ``_reset_slot`` calls."""
        self._flist.pop(i, None)
        self.freqs[i] = np.inf
        self.A[i] = self._eye_A
        self.A_inv[i] = self._eye_Ainv
        self.b[i] = 0.0
        self.theta[i] = 0.0
        self.n_[i] = 0
        self.reward_sum[i] = 0.0
        self.edp_sum[i] = 0.0

    def remove(self, i: int, f: float) -> None:
        s = self.slot_of(i, float(f))
        if s < 0:
            return
        m = int(self.m[i])
        for arr in (self.freqs, self.n_, self.reward_sum, self.edp_sum,
                    self.A, self.A_inv, self.b, self.theta):
            arr[i, s:m - 1] = arr[i, s + 1:m]
        self.m[i] = m - 1
        self._reset_slot(i, m - 1)

    def rebuild(self, i: int, frequencies: Sequence[float],
                warm_from: Optional[float] = None) -> None:
        """Per-node ``LinUCBBank.rebuild``: surviving frequencies keep their
        rows, new frequencies warm-start from the anchor (skipped when the
        anchor was never sampled)."""
        new = sorted({float(f) for f in frequencies})
        if len(new) > self.capacity:
            raise ValueError(f"rebuild wants {len(new)} arms, "
                             f"capacity {self.capacity}")
        m = int(self.m[i])
        old_f = [float(f) for f in self.freqs[i, :m]]
        if new == old_f:
            # identity rebuild: every arm survives with its own row and
            # dead slots are already pristine (class invariant) — the
            # state after the full copy-out/reset/copy-back dance equals
            # the state before it, so skip the dance. A converged fleet
            # re-anchors on the same grid most refinement rounds, making
            # this the common case at day scale.
            return
        old_index = {f: s for s, f in enumerate(old_f)}
        old = (self.A[i, :m].copy(), self.A_inv[i, :m].copy(),
               self.b[i, :m].copy(), self.theta[i, :m].copy(),
               self.n_[i, :m].copy(), self.reward_sum[i, :m].copy(),
               self.edp_sum[i, :m].copy())
        proto = old_index.get(float(warm_from)) if warm_from is not None \
            else None
        if proto is not None and old[4][proto] == 0:
            proto = None                      # untouched anchor: no prior
        self._reset_node(i)
        self.freqs[i, :len(new)] = new
        self.m[i] = len(new)
        for s, f in enumerate(new):
            src = old_index.get(f, proto)
            if src is None:
                continue
            self.A[i, s] = old[0][src]
            self.A_inv[i, s] = old[1][src]
            self.b[i, s] = old[2][src]
            self.theta[i, s] = old[3][src]
            self.n_[i, s] = old[4][src]
            self.reward_sum[i, s] = old[5][src]
            self.edp_sum[i, s] = old[6][src]

    # -- per-node selection helpers (refinement anchors) ---------------
    def best_historical(self, i: int, min_samples: int = 1
                        ) -> Optional[float]:
        m = int(self.m[i])
        mask = self.n_[i, :m] >= min_samples
        if not mask.any():
            return None
        mean_edp = np.full(m, np.inf)
        np.divide(self.edp_sum[i, :m], self.n_[i, :m], out=mean_edp,
                  where=mask)
        return float(self.freqs[i, int(np.argmin(mean_edp))])

    def argmax_ucb(self, i: int, x: np.ndarray, alpha: float) -> float:
        m = int(self.m[i])
        quad = np.einsum("i,aij,j->a", x, self.A_inv[i, :m], x)
        scores = self.theta[i, :m] @ x \
            + alpha * np.sqrt(np.maximum(quad, 0.0))
        return float(self.freqs[i, int(np.argmax(scores))])

    def argmax_ucb_batch(self, idx: np.ndarray, X: np.ndarray,
                         alpha: float) -> np.ndarray:
        """One UCB-argmax anchor per node in ``idx`` — the batched form of
        :meth:`argmax_ucb`, using the same verified-identical batched gemv
        and quad-form dispatches as :meth:`select_batch` (dead slots carry
        pristine finite statistics and are masked to -inf, and first-max
        argmax over ascending slots keeps the lowest-frequency
        tie-break)."""
        K = self.capacity
        valid = np.arange(K)[None, :] < self.m[idx][:, None]
        tx = np.matmul(self.theta[idx], X[:, :, None])[:, :, 0]
        quad = np.einsum("ki,kaij,kj->ka", X, self.A_inv[idx], X)
        scores = tx + alpha * np.sqrt(np.maximum(quad, 0.0))
        scores = np.where(valid, scores, -np.inf)
        return self.freqs[idx, np.argmax(scores, axis=1)]
