"""LinUCB contextual bandit (paper §4.2, eqs. 1-5).

Each frequency is an arm with ridge-regression sufficient statistics
    A_f = I + sum x xᵀ,   b_f = sum r x,   theta_f = A_f⁻¹ b_f
selected by  argmax theta_fᵀx + alpha sqrt(xᵀ A_f⁻¹ x)  during exploration
and argmax theta_fᵀx during exploitation. A⁻¹ is maintained incrementally
(Sherman-Morrison), so a decision is O(|F| d²) — microseconds at d=7.

Storage is structure-of-arrays: the bank holds stacked ``(n_arms, d, d)``
``A``/``A_inv`` and ``(n_arms, d)`` ``b``/``theta`` plus per-arm counters,
kept in ascending-frequency order. Selection rules are einsum-vectorized
over the stack (one numpy dispatch per decision instead of one per arm),
and updates are in-place row operations. The historical dict-of-arms API —
``bank.arms[f].update(...)``, ``arm.n``, ``arm.ucb(x, alpha)`` — survives
as a zero-copy view (:class:`_ArmView`/:class:`_ArmMap`) so the pruning and
refinement frameworks work unchanged.

Arm order is deterministic: always ascending frequency, regardless of
``rebuild``/``remove`` history, so tie-breaks and Thompson's RNG-draw-to-arm
pairing never depend on action-space mutation order.

Frequency bands (hierarchical fleet control): ``set_band(f_lo, f_hi)``
restricts *selection* to arms inside ``[f_lo, f_hi]`` via a reversible
boolean mask over the stack — statistics are never destroyed, so a band
that widens on a later FLEET_TICK instantly re-legalizes the arms it had
masked. At least one arm is always legal (the nearest to the band's
midpoint when the band contains none), and with no band set every
selection path is byte-for-byte the unmasked code.
"""
from __future__ import annotations

from collections.abc import Mapping
from typing import Dict, Iterator, List, Optional, Sequence, Tuple

import numpy as np


class LinUCBArm:
    """A standalone single arm (kept for direct use and as the reference
    implementation the vectorized bank is tested against)."""

    def __init__(self, dim: int, ridge: float = 1.0):
        self.dim = dim
        self.A = np.eye(dim) * ridge
        self.A_inv = np.eye(dim) / ridge
        self.b = np.zeros(dim)
        self.theta = np.zeros(dim)
        self.n = 0
        self.reward_sum = 0.0
        self.edp_sum = 0.0

    # ------------------------------------------------------------------
    def update(self, x: np.ndarray, reward: float,
               edp: Optional[float] = None) -> None:
        self.A += np.outer(x, x)
        # Sherman-Morrison rank-1 inverse update
        Ax = self.A_inv @ x
        self.A_inv -= np.outer(Ax, Ax) / (1.0 + x @ Ax)
        self.b += reward * x
        self.theta = self.A_inv @ self.b
        self.n += 1
        self.reward_sum += reward
        if edp is not None:
            self.edp_sum += edp

    # ------------------------------------------------------------------
    @property
    def mean_reward(self) -> float:
        return self.reward_sum / self.n if self.n else 0.0

    @property
    def mean_edp(self) -> float:
        return self.edp_sum / self.n if self.n else float("inf")

    def predict(self, x: np.ndarray) -> float:
        return float(self.theta @ x)

    def ucb(self, x: np.ndarray, alpha: float) -> float:
        bonus = alpha * float(np.sqrt(max(x @ self.A_inv @ x, 0.0)))
        return self.predict(x) + bonus


class _ArmView:
    """Live view of one bank row presenting the ``LinUCBArm`` interface.

    Attribute reads return (writable) slices of the bank's stacked arrays;
    ``update`` delegates to the bank's in-place row update. Views resolve
    their row index on every access, so they stay correct across
    ``remove``/``rebuild`` (and raise ``KeyError`` once the arm is gone).
    """

    __slots__ = ("_bank", "f")

    def __init__(self, bank: "LinUCBBank", f: float):
        self._bank = bank
        self.f = f

    @property
    def _i(self) -> int:
        return self._bank._index[self.f]

    @property
    def dim(self) -> int:
        return self._bank.dim

    @property
    def A(self) -> np.ndarray:
        return self._bank._A[self._i]

    @property
    def A_inv(self) -> np.ndarray:
        return self._bank._A_inv[self._i]

    @property
    def b(self) -> np.ndarray:
        return self._bank._b[self._i]

    @property
    def theta(self) -> np.ndarray:
        return self._bank._theta[self._i]

    @property
    def n(self) -> int:
        return int(self._bank._n[self._i])

    @property
    def reward_sum(self) -> float:
        return float(self._bank._reward_sum[self._i])

    @property
    def edp_sum(self) -> float:
        return float(self._bank._edp_sum[self._i])

    @property
    def mean_reward(self) -> float:
        n = self.n
        return self.reward_sum / n if n else 0.0

    @property
    def mean_edp(self) -> float:
        n = self.n
        return self.edp_sum / n if n else float("inf")

    def update(self, x: np.ndarray, reward: float,
               edp: Optional[float] = None) -> None:
        self._bank.update_arm(self.f, x, reward, edp)

    def predict(self, x: np.ndarray) -> float:
        return float(self.theta @ x)

    def ucb(self, x: np.ndarray, alpha: float) -> float:
        bonus = alpha * float(np.sqrt(max(x @ self.A_inv @ x, 0.0)))
        return self.predict(x) + bonus

    def __repr__(self) -> str:
        return f"_ArmView(f={self.f}, n={self.n})"


class _ArmMap(Mapping):
    """Read-only mapping ``frequency -> _ArmView`` over the bank, iterating
    in ascending-frequency order. Mutation goes through the bank
    (``remove``/``rebuild``), never through this map."""

    __slots__ = ("_bank",)

    def __init__(self, bank: "LinUCBBank"):
        self._bank = bank

    def __getitem__(self, f) -> _ArmView:
        f = float(f)
        if f not in self._bank._index:
            raise KeyError(f)
        return _ArmView(self._bank, f)

    def __iter__(self) -> Iterator[float]:
        return iter(self._bank._f)

    def __len__(self) -> int:
        return len(self._bank._f)

    def __contains__(self, f) -> bool:           # avoid Mapping's try/except
        return float(f) in self._bank._index


class LinUCBBank:
    """The arm set over the current (mutable) frequency action space.

    Selection strategies (beyond-paper extension):
      * "linucb"   — the paper's UCB rule (eq. 1/2)
      * "thompson" — linear Thompson sampling: per arm, sample
        theta ~ N(theta_f, nu^2 A_f^-1) and pick argmax x' theta_sample.
        Randomized exploration composes better with non-stationary reward
        drift (no deterministic untried-arm sweeps); compared empirically
        in benchmarks/ext_thompson.py.
    """

    def __init__(self, frequencies: Sequence[float], dim: int,
                 ridge: float = 1.0, seed: int = 0):
        self.dim = dim
        self.ridge = ridge
        self.rng = np.random.default_rng(seed)
        self.arms = _ArmMap(self)
        self._band: Optional[Tuple[float, float]] = None
        self._legal: Optional[np.ndarray] = None   # bool mask; None = all
        self._alloc(sorted({float(f) for f in frequencies}))

    # -- storage -------------------------------------------------------
    def _alloc(self, freqs: List[float]) -> None:
        n, d = len(freqs), self.dim
        self._f: List[float] = freqs              # ascending, deduplicated
        self._index: Dict[float, int] = {f: i for i, f in enumerate(freqs)}
        eye = np.eye(d)
        self._A = np.broadcast_to(eye * self.ridge, (n, d, d)).copy()
        self._A_inv = np.broadcast_to(eye / self.ridge, (n, d, d)).copy()
        self._b = np.zeros((n, d))
        self._theta = np.zeros((n, d))
        self._n = np.zeros(n, dtype=np.int64)
        self._reward_sum = np.zeros(n)
        self._edp_sum = np.zeros(n)
        self._apply_band()

    def _drop_rows(self, keep: np.ndarray) -> None:
        self._f = [f for f, k in zip(self._f, keep) if k]
        self._index = {f: i for i, f in enumerate(self._f)}
        self._A = self._A[keep]
        self._A_inv = self._A_inv[keep]
        self._b = self._b[keep]
        self._theta = self._theta[keep]
        self._n = self._n[keep]
        self._reward_sum = self._reward_sum[keep]
        self._edp_sum = self._edp_sum[keep]
        self._apply_band()

    # -- frequency band (hierarchical fleet control) -------------------
    @property
    def band(self) -> Optional[Tuple[float, float]]:
        return self._band

    def set_band(self, f_lo: float, f_hi: float) -> None:
        """Restrict selection to arms inside ``[f_lo, f_hi]`` (inclusive,
        inverted bounds tolerated). Reversible — statistics survive; the
        mask is recomputed on every action-space mutation."""
        lo, hi = (float(f_lo), float(f_hi))
        if lo > hi:
            lo, hi = hi, lo
        self._band = (lo, hi)
        self._apply_band()

    def clear_band(self) -> None:
        self._band = None
        self._legal = None

    def _apply_band(self) -> None:
        """Recompute the legal-arm mask; a band that contains no arm (e.g.
        narrower than the grid step) legalizes the single arm nearest to
        its midpoint so the bandit always has an action."""
        if self._band is None:
            self._legal = None
            return
        lo, hi = self._band
        f = np.asarray(self._f)
        legal = (f >= lo - 1e-9) & (f <= hi + 1e-9)
        if not legal.any() and len(f):
            legal[int(np.argmin(np.abs(f - (lo + hi) / 2.0)))] = True
        self._legal = legal

    def is_legal(self, f: float) -> bool:
        return (self._legal is None
                or bool(self._legal[self._index[float(f)]]))

    def n_legal(self) -> int:
        return (len(self._f) if self._legal is None
                else int(self._legal.sum()))

    def legal_frequencies(self) -> List[float]:
        if self._legal is None:
            return list(self._f)
        return [f for f, ok in zip(self._f, self._legal) if ok]

    def _argmax_legal(self, scores: np.ndarray) -> float:
        """Highest-scoring legal arm; ties break to the lowest frequency
        (subsetting preserves ascending order)."""
        if self._legal is None:
            return self._f[int(np.argmax(scores))]
        idx = np.flatnonzero(self._legal)
        return self._f[int(idx[int(np.argmax(scores[idx]))])]

    # ------------------------------------------------------------------
    @property
    def frequencies(self) -> List[float]:
        return list(self._f)

    def remove(self, f: float) -> None:
        i = self._index.get(float(f))
        if i is None:
            return
        keep = np.ones(len(self._f), dtype=bool)
        keep[i] = False
        self._drop_rows(keep)

    def rebuild(self, frequencies: Sequence[float],
                warm_from: Optional[float] = None) -> None:
        """Refinement: re-center the action space. Arms for surviving
        frequencies keep their statistics; NEW arms are warm-started from
        the anchor arm's sufficient statistics (nearby frequencies behave
        similarly — a sane prior that avoids re-exploring a fresh grid from
        scratch after every refinement)."""
        old_index, old = self._index, (self._A, self._A_inv, self._b,
                                       self._theta, self._n,
                                       self._reward_sum, self._edp_sum)
        proto = old_index.get(float(warm_from)) if warm_from is not None \
            else None
        if proto is not None and old[4][proto] == 0:
            proto = None                          # untouched anchor: no prior
        self._alloc(sorted({float(f) for f in frequencies}))
        for f, i in self._index.items():
            src = old_index.get(f, proto)
            if src is None:
                continue
            self._A[i] = old[0][src]
            self._A_inv[i] = old[1][src]
            self._b[i] = old[2][src]
            self._theta[i] = old[3][src]
            self._n[i] = old[4][src]
            self._reward_sum[i] = old[5][src]
            self._edp_sum[i] = old[6][src]

    # -- updates -------------------------------------------------------
    def update_arm(self, f: float, x: np.ndarray, reward: float,
                   edp: Optional[float] = None) -> None:
        """Sherman-Morrison rank-1 update of one arm, in place on the
        stacked arrays (arithmetic-identical to ``LinUCBArm.update``)."""
        i = self._index[float(f)]
        self._A[i] += np.outer(x, x)
        A_inv = self._A_inv[i]
        Ax = A_inv @ x
        A_inv -= np.outer(Ax, Ax) / (1.0 + x @ Ax)
        b = self._b[i]
        b += reward * x
        self._theta[i] = A_inv @ b
        self._n[i] += 1
        self._reward_sum[i] += reward
        if edp is not None:
            self._edp_sum[i] += edp

    def update_arms(self, fs: Sequence[float], xs: np.ndarray,
                    rewards: Sequence[float],
                    edps: Optional[Sequence[float]] = None) -> None:
        """Batched Sherman-Morrison: credit one observation to each of
        several DISTINCT arms in a single einsum pass. No in-tree policy
        batches credits yet (the tuner settles one window at a time via
        ``update_arm``); this is the vectorized-bank API for controllers
        that do, kept numerically equivalent by the hot-path tests."""
        idx = np.array([self._index[float(f)] for f in fs])
        if len(set(idx.tolist())) != len(idx):
            raise ValueError("update_arms requires distinct arms; "
                             "sequential rank-1 updates to one arm do not "
                             "commute with batching")
        X = np.asarray(xs, dtype=float).reshape(len(idx), self.dim)
        r = np.asarray(rewards, dtype=float)
        self._A[idx] += np.einsum("bi,bj->bij", X, X)
        Ax = np.einsum("bij,bj->bi", self._A_inv[idx], X)
        denom = 1.0 + np.einsum("bi,bi->b", X, Ax)
        self._A_inv[idx] -= np.einsum("bi,bj->bij", Ax, Ax) \
            / denom[:, None, None]
        self._b[idx] += r[:, None] * X
        self._theta[idx] = np.einsum("bij,bj->bi", self._A_inv[idx],
                                     self._b[idx])
        self._n[idx] += 1
        self._reward_sum[idx] += r
        if edps is not None:
            self._edp_sum[idx] += np.asarray(edps, dtype=float)

    # -- selection (vectorized over the stack) -------------------------
    def _scores_ucb(self, x: np.ndarray, alpha: float) -> np.ndarray:
        quad = np.einsum("i,aij,j->a", x, self._A_inv, x)
        return self._theta @ x + alpha * np.sqrt(np.maximum(quad, 0.0))

    def select_ucb(self, x: np.ndarray, alpha: float) -> float:
        # untried arms first (infinite-bonus convention), lowest-f first so
        # exploration sweeps upward through the cheap range
        untried = self._n == 0
        if self._legal is not None:
            untried = untried & self._legal
        if untried.any():
            return self._f[int(np.argmax(untried))]
        return self.argmax_ucb(x, alpha)

    def argmax_ucb(self, x: np.ndarray, alpha: float) -> float:
        """Highest-UCB arm, ignoring the untried-arm convention (used by
        predictive refinement to pick its anchor). Ties break to the lowest
        frequency."""
        return self._argmax_legal(self._scores_ucb(x, alpha))

    def select_thompson(self, x: np.ndarray, nu: float = 0.3) -> float:
        """Linear Thompson sampling over the arm set: one batched Cholesky
        of the (symmetrized) covariances, one (n_arms, d) normal draw."""
        n, d = len(self._f), self.dim
        sym = (self._A_inv + np.swapaxes(self._A_inv, 1, 2)) / 2.0 \
            + 1e-12 * np.eye(d)
        try:
            L = np.linalg.cholesky(sym)
        except np.linalg.LinAlgError:
            L = np.empty_like(sym)                # salvage the healthy arms
            for i in range(n):
                try:
                    L[i] = np.linalg.cholesky(sym[i])
                except np.linalg.LinAlgError:
                    L[i] = np.eye(d)
        z = self.rng.standard_normal((n, d))
        theta_s = self._theta + nu * np.einsum("aij,aj->ai", L, z)
        return self._argmax_legal(theta_s @ x)

    def select_greedy(self, x: np.ndarray) -> float:
        return self._argmax_legal(self._theta @ x)

    def best_historical(self, min_samples: int = 1) -> Optional[float]:
        mask = self._n >= min_samples
        if self._legal is not None:
            mask = mask & self._legal
        if not mask.any():
            return None
        mean_edp = np.full(len(self._f), np.inf)
        np.divide(self._edp_sum, self._n, out=mean_edp, where=mask)
        return self._f[int(np.argmin(mean_edp))]
