"""LinUCB contextual bandit (paper §4.2, eqs. 1-5).

Each frequency is an arm with ridge-regression sufficient statistics
    A_f = I + sum x xᵀ,   b_f = sum r x,   theta_f = A_f⁻¹ b_f
selected by  argmax theta_fᵀx + alpha sqrt(xᵀ A_f⁻¹ x)  during exploration
and argmax theta_fᵀx during exploitation. A⁻¹ is maintained incrementally
(Sherman-Morrison), so a decision is O(|F| d²) — microseconds at d=7.
"""
from __future__ import annotations

import dataclasses
from typing import Dict, List, Optional

import numpy as np


class LinUCBArm:
    def __init__(self, dim: int, ridge: float = 1.0):
        self.dim = dim
        self.A = np.eye(dim) * ridge
        self.A_inv = np.eye(dim) / ridge
        self.b = np.zeros(dim)
        self.theta = np.zeros(dim)
        self.n = 0
        self.reward_sum = 0.0
        self.edp_sum = 0.0

    # ------------------------------------------------------------------
    def update(self, x: np.ndarray, reward: float,
               edp: Optional[float] = None) -> None:
        self.A += np.outer(x, x)
        # Sherman-Morrison rank-1 inverse update
        Ax = self.A_inv @ x
        self.A_inv -= np.outer(Ax, Ax) / (1.0 + x @ Ax)
        self.b += reward * x
        self.theta = self.A_inv @ self.b
        self.n += 1
        self.reward_sum += reward
        if edp is not None:
            self.edp_sum += edp

    # ------------------------------------------------------------------
    @property
    def mean_reward(self) -> float:
        return self.reward_sum / self.n if self.n else 0.0

    @property
    def mean_edp(self) -> float:
        return self.edp_sum / self.n if self.n else float("inf")

    def predict(self, x: np.ndarray) -> float:
        return float(self.theta @ x)

    def ucb(self, x: np.ndarray, alpha: float) -> float:
        bonus = alpha * float(np.sqrt(max(x @ self.A_inv @ x, 0.0)))
        return self.predict(x) + bonus


class LinUCBBank:
    """The arm set over the current (mutable) frequency action space.

    Selection strategies (beyond-paper extension):
      * "linucb"   — the paper's UCB rule (eq. 1/2)
      * "thompson" — linear Thompson sampling: per arm, sample
        theta ~ N(theta_f, nu^2 A_f^-1) and pick argmax x' theta_sample.
        Randomized exploration composes better with non-stationary reward
        drift (no deterministic untried-arm sweeps); compared empirically
        in benchmarks/ext_thompson.py.
    """

    def __init__(self, frequencies: List[float], dim: int,
                 ridge: float = 1.0, seed: int = 0):
        self.dim = dim
        self.ridge = ridge
        self.rng = np.random.default_rng(seed)
        self.arms: Dict[float, LinUCBArm] = {
            float(f): LinUCBArm(dim, ridge) for f in frequencies}

    # ------------------------------------------------------------------
    @property
    def frequencies(self) -> List[float]:
        return sorted(self.arms.keys())

    def remove(self, f: float) -> None:
        self.arms.pop(float(f), None)

    def rebuild(self, frequencies: List[float],
                warm_from: Optional[float] = None) -> None:
        """Refinement: re-center the action space. Arms for surviving
        frequencies keep their statistics; NEW arms are warm-started from
        the anchor arm's sufficient statistics (nearby frequencies behave
        similarly — a sane prior that avoids re-exploring a fresh grid from
        scratch after every refinement)."""
        proto = self.arms.get(float(warm_from)) if warm_from is not None \
            else None
        new: Dict[float, LinUCBArm] = {}
        for f in frequencies:
            f = float(f)
            arm = self.arms.get(f)
            if arm is None:
                arm = LinUCBArm(self.dim, self.ridge)
                if proto is not None and proto.n > 0:
                    arm.A = proto.A.copy()
                    arm.A_inv = proto.A_inv.copy()
                    arm.b = proto.b.copy()
                    arm.theta = proto.theta.copy()
                    arm.n = proto.n
                    arm.reward_sum = proto.reward_sum
                    arm.edp_sum = proto.edp_sum
            new[f] = arm
        self.arms = new

    # ------------------------------------------------------------------
    def select_ucb(self, x: np.ndarray, alpha: float) -> float:
        # untried arms first (infinite-bonus convention), lowest-f first so
        # exploration sweeps upward through the cheap range
        untried = [f for f, a in self.arms.items() if a.n == 0]
        if untried:
            return min(untried)
        return max(self.arms, key=lambda f: self.arms[f].ucb(x, alpha))

    def select_thompson(self, x: np.ndarray, nu: float = 0.3) -> float:
        """Linear Thompson sampling over the arm set."""
        best_f, best_v = None, -np.inf
        for f, arm in self.arms.items():
            # sample theta ~ N(theta, nu^2 A^-1) via Cholesky of A_inv
            try:
                L = np.linalg.cholesky(
                    (arm.A_inv + arm.A_inv.T) / 2.0 + 1e-12 * np.eye(self.dim))
            except np.linalg.LinAlgError:
                L = np.eye(self.dim)
            theta_s = arm.theta + nu * L @ self.rng.standard_normal(self.dim)
            v = float(theta_s @ x)
            if v > best_v:
                best_f, best_v = f, v
        return best_f

    def select_greedy(self, x: np.ndarray) -> float:
        return max(self.arms, key=lambda f: self.arms[f].predict(x))

    def best_historical(self, min_samples: int = 1) -> Optional[float]:
        cands = {f: a for f, a in self.arms.items() if a.n >= min_samples}
        if not cands:
            return None
        return min(cands, key=lambda f: cands[f].mean_edp)
