"""Telemetry monitor: the Prometheus-boundary observation window.

The paper's monitor (§4.1 "Periodic Metric Acquisition") polls the engine's
metrics endpoint on a fixed sampling period and differences consecutive
snapshots into per-window aggregates. That windowing used to live inside
``AGFTTuner.act``; it is policy-agnostic, so it lives here and every power
policy (AGFT, ondemand, SLO-aware, ...) observes the engine through the
same ``WindowStats`` boundary — aggregate counters only, never per-request
state (the privacy contract in ``serving.request``).

The monitor is duck-typed over its source: anything exposing ``clock`` and
``metrics.snapshot()`` works, which is how fleet-scope policies reuse it —
:class:`repro.policies.fleet.FleetTelemetryView` aggregates every node's
snapshot (via :func:`aggregate_snapshots`) behind the same interface, so a
cluster-global controller observes the fleet exactly the way a per-node
controller observes one engine.
"""
from __future__ import annotations

from typing import Dict, Optional, Sequence

import numpy as np

from repro.energy.edp import WindowStats, diff_snapshots

#: snapshot keys that are point-in-time *levels* shared across the fleet —
#: aggregated by averaging. Everything else (monotonic counters, additive
#: gauges like queue depths or power draw) sums across nodes.
_MEAN_KEYS = frozenset({"vllm:gpu_cache_usage_perc",
                        "vllm:current_frequency_mhz"})


def aggregate_snapshots(snaps: Sequence[Dict[str, float]]
                        ) -> Dict[str, float]:
    """Fold per-engine metric snapshots into one fleet-level snapshot.

    Counters and additive gauges (queue depths, watts) sum; fractional /
    frequency levels average. The result is shaped exactly like a single
    engine's ``snapshot()``, so ``diff_snapshots`` and every policy built
    on :class:`TelemetryMonitor` consume it unchanged.

    The fold is one numpy axis-0 reduction over an ``(n_nodes, n_keys)``
    matrix. Axis-0 reduction accumulates rows sequentially (numpy's
    pairwise summation applies along the contiguous inner axis only), so
    the totals are bit-identical to the historical per-key Python ``sum``
    at any fleet size.
    """
    if not snaps:
        return {}
    keys = list(snaps[0])
    mat = np.array([[s[k] for k in keys] for s in snaps], dtype=np.float64)
    tot = np.sum(mat, axis=0)
    n = len(snaps)
    return {k: (tot[i] / n if k in _MEAN_KEYS else tot[i])
            for i, k in enumerate(keys)}


class TelemetryMonitor:
    """Samples ``engine.metrics.snapshot()`` on a fixed cadence and diffs
    consecutive snapshots into :class:`WindowStats`.

    Usage::

        if monitor.due(engine):
            window = monitor.observe(engine)   # None on the first sample
    """

    def __init__(self, sampling_period_s: float = 0.8):
        self.sampling_period_s = sampling_period_s
        self.prev_snapshot: Optional[Dict[str, float]] = None
        self.prev_time = 0.0
        self.next_sample = 0.0

    def due(self, engine) -> bool:
        """True once the engine clock has crossed the next sample point."""
        return engine.clock >= self.next_sample

    def skip(self, engine, now: Optional[float] = None) -> None:
        """A scrape attempt failed (telemetry dropout, ``repro.serving.
        faults``): re-arm the sampling window WITHOUT taking a snapshot.
        ``prev_snapshot``/``prev_time`` are untouched, so the next
        successful ``observe`` spans the gap — one stale window covering
        both periods, which fault-aware policies refuse to learn from."""
        if now is None:
            now = engine.clock
        self.next_sample = now + self.sampling_period_s

    def observe(self, engine,
                now: Optional[float] = None) -> Optional[WindowStats]:
        """Snapshot now and return the window since the previous snapshot.

        Returns ``None`` on the first observation (no window exists yet);
        either way the sampling window is (re)armed from the current clock.

        ``now`` overrides the window's cut point (POLICY_TICK mode: the
        poller samples on its own wall-clock cadence, so windows span
        exact periods instead of ending wherever an iteration boundary
        happened to land). The snapshot itself is whatever the counters
        hold — an engine mid-long-iteration has already advanced past the
        tick, exactly like a real scrape racing the serving loop.
        """
        if now is None:
            now = engine.clock
        snap = engine.metrics.snapshot()
        window = None
        if self.prev_snapshot is not None:
            window = diff_snapshots(self.prev_snapshot, snap,
                                    max(now - self.prev_time, 1e-9))
        self.prev_snapshot = snap
        self.prev_time = now
        self.next_sample = now + self.sampling_period_s
        return window
