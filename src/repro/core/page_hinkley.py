"""Page-Hinkley test + convergence detection (paper §4.2 "Exploitation
Phase": the system transitions to greedy exploitation once the reward
sequence stabilizes, detected via a Page-Hinkley test).

PH tracks the cumulative deviation of the reward from its running mean; a
drift alarm means the reward distribution shifted (workload regime change).
Convergence = enough rounds with NO alarm and low recent reward variance.
A post-convergence alarm re-opens exploration — the mechanism that keeps
AGFT adaptive under the Azure trace's non-stationarity.
"""
from __future__ import annotations

import collections
import dataclasses
from typing import Deque


class PageHinkley:
    """Two-sided Page-Hinkley change detector."""

    def __init__(self, delta: float = 0.005, threshold: float = 0.5,
                 min_samples: int = 10):
        self.delta = delta
        self.threshold = threshold
        self.min_samples = min_samples
        self.reset()

    def reset(self) -> None:
        self.n = 0
        self.mean = 0.0
        self.m_up = 0.0      # cumulative positive deviation statistic
        self.m_dn = 0.0
        self.min_up = 0.0
        self.max_dn = 0.0

    def update(self, value: float) -> bool:
        """Feed one observation; True => drift alarm."""
        self.n += 1
        self.mean += (value - self.mean) / self.n
        dev = value - self.mean
        self.m_up += dev - self.delta
        self.m_dn += dev + self.delta
        self.min_up = min(self.min_up, self.m_up)
        self.max_dn = max(self.max_dn, self.m_dn)
        if self.n < self.min_samples:
            return False
        up_alarm = (self.m_up - self.min_up) > self.threshold
        dn_alarm = (self.max_dn - self.m_dn) > self.threshold
        if up_alarm or dn_alarm:
            self.reset()
            return True
        return False


@dataclasses.dataclass
class ConvergenceConfig:
    stable_rounds: int = 30          # PH-quiet rounds needed to declare
    std_window: int = 30             # rolling window for reward std
    std_threshold: float = 0.45      # max rolling std at convergence
    # PH sensitivity is matched to the observed window-reward noise
    # (std ~0.3 around -1): delta ~ noise/3, threshold ~ 6-7x delta.
    ph_delta: float = 0.1
    ph_threshold: float = 2.0
    # hysteresis: re-opening exploration after convergence requires a much
    # larger sustained drift than the stabilization test (otherwise ordinary
    # window noise keeps bouncing the system out of exploitation)
    drift_delta: float = 0.2
    drift_threshold: float = 6.0


class ConvergenceDetector:
    """Explore -> exploit transition + drift-triggered re-exploration."""

    def __init__(self, cfg: ConvergenceConfig = ConvergenceConfig()):
        self.cfg = cfg
        self.ph = PageHinkley(cfg.ph_delta, cfg.ph_threshold)
        self.ph_drift = PageHinkley(cfg.drift_delta, cfg.drift_threshold)
        self.recent: Deque[float] = collections.deque(maxlen=cfg.std_window)
        self.quiet_rounds = 0
        self.converged = False
        self.converged_round = None
        self.first_converged_round = None
        self.reopened = 0                # drift-triggered re-explorations
        self.round = 0

    def rolling_std(self) -> float:
        if len(self.recent) < 2:
            return float("inf")
        import numpy as np
        return float(np.std(self.recent))

    def update(self, reward: float) -> bool:
        """Feed a reward; returns current converged state."""
        self.round += 1
        self.recent.append(reward)
        if self.converged:
            if self.ph_drift.update(reward):
                # genuine regime change: reopen exploration
                self.converged = False
                self.converged_round = None
                self.quiet_rounds = 0
                self.reopened += 1
                self.ph.reset()
            return self.converged
        drift = self.ph.update(reward)
        self.quiet_rounds = 0 if drift else self.quiet_rounds + 1
        if (self.quiet_rounds >= self.cfg.stable_rounds
                and self.rolling_std() <= self.cfg.std_threshold):
            self.converged = True
            self.converged_round = self.round
            if self.first_converged_round is None:
                self.first_converged_round = self.round
            self.ph_drift.reset()
        return self.converged
