"""Intelligent action-space pruning (paper §4.3, Fig. 9): three cooperating
mechanisms that shrink the frequency action space so exploration
concentrates on viable regions.

1. Extreme-frequency instant pruning — early-rounds hard filter: an arm
   whose mean reward is catastrophically bad (below a hard negative
   threshold after a minimum number of samples) is removed permanently.
2. Historical performance pruning — mature-phase statistical filter: an arm
   sufficiently sampled whose mean EDP trails the best arm's by more than a
   dynamic tolerance (std of arm means) is removed.
3. Cascade pruning — physical heuristic: when a pruned frequency lies below
   half of f_max, every lower frequency is pruned with it (if a moderate
   clock already can't keep up, slower clocks certainly can't).

Under a fleet-assigned frequency band (``LinUCBBank.set_band``, see
``repro.policies.hierarchy``) pruning additionally never removes the last
band-legal arm: pruning is permanent, the band is not, so destroying the
only in-band action would leave the coordinator nothing to govern. With no
band set every arm is legal and the guard is inert.

All three mechanisms also apply to 2-D ``(f_prefill, f_decode)`` action
spaces (``repro.core.tuner2d``): extreme and historical pruning are
key-agnostic (they read per-arm reward/EDP statistics), and the cascade
generalizes axis-wise — a pair pruned with both clocks in the slow half
drags down every pair it dominates on both axes.
"""
from __future__ import annotations

import dataclasses
from typing import List, Set

import numpy as np

from repro.core.linucb import LinUCBBank


@dataclasses.dataclass
class PruningConfig:
    enabled: bool = True
    # extreme pruning
    early_rounds: int = 60
    extreme_min_samples: int = 3
    extreme_reward_threshold: float = -1.2
    # historical pruning
    mature_rounds: int = 30
    historical_min_samples: int = 6
    historical_tolerance_k: float = 1.0   # tolerance = k * std(mean EDPs)
    # cascade pruning
    cascade_fraction_of_fmax: float = 0.5
    # never shrink below this many arms
    min_arms: int = 3


class PruningFramework:
    def __init__(self, cfg: PruningConfig, f_max: float):
        self.cfg = cfg
        self.f_max = f_max
        self.permanently_pruned: Set[float] = set()
        self.log: List[dict] = []

    # ------------------------------------------------------------------
    def _prune(self, bank: LinUCBBank, f: float, mechanism: str,
               round_idx: int) -> None:
        if bank.is_legal(f) and bank.n_legal() <= 1:
            return                    # never orphan the band (see module doc)
        bank.remove(f)
        self.permanently_pruned.add(f)
        self.log.append({"round": round_idx, "freq": f,
                         "mechanism": mechanism})

    def _cascade(self, bank: LinUCBBank, f: float, round_idx: int) -> None:
        frac = self.cfg.cascade_fraction_of_fmax * self.f_max
        if isinstance(f, tuple):
            # 2-D actions: the physical argument generalizes axis-wise —
            # if a pair with BOTH clocks in the slow half can't keep up,
            # any pair it dominates (no faster on either axis) can't
            # either. Pairs with one fast axis never trigger a cascade.
            if f[0] >= frac or f[1] >= frac:
                return
            for g in list(bank.frequencies):
                if (g[0] <= f[0] and g[1] <= f[1]
                        and len(bank.arms) > self.cfg.min_arms):
                    self._prune(bank, g, "cascade", round_idx)
            return
        if f >= frac:
            return
        for g in list(bank.frequencies):
            if g < f and len(bank.arms) > self.cfg.min_arms:
                self._prune(bank, g, "cascade", round_idx)

    # ------------------------------------------------------------------
    def apply(self, bank: LinUCBBank, round_idx: int) -> None:
        """Arm statistics are read through ``bank.arm_stats()`` — one
        vectorized snapshot per phase instead of thousands of per-arm view
        resolutions. The snapshot is exact: removals never change the
        surviving arms' sufficient statistics, so values read up front
        equal the per-iteration live reads of the original walk."""
        if not self.cfg.enabled:
            return
        cfg = self.cfg
        arms = bank.arms
        # 1. extreme instant pruning (early phase only)
        if round_idx <= cfg.early_rounds:
            for f, n, mr, _ in bank.arm_stats():
                if len(arms) <= cfg.min_arms:
                    break
                if (n >= cfg.extreme_min_samples
                        and mr < cfg.extreme_reward_threshold):
                    self._prune(bank, f, "extreme", round_idx)
                    self._cascade(bank, f, round_idx)
        # 2. historical performance pruning (mature phase)
        if round_idx >= cfg.mature_rounds:
            sampled = [(f, me) for f, n, _, me in bank.arm_stats()
                       if n >= cfg.historical_min_samples]
            if len(sampled) >= 2:
                means = np.array([me for _, me in sampled])
                best = float(means.min())
                tol = cfg.historical_tolerance_k * float(means.std())
                for f, me in sampled:
                    if len(arms) <= cfg.min_arms:
                        break
                    if me > best + tol and me > best * 1.05:
                        self._prune(bank, f, "historical", round_idx)
                        self._cascade(bank, f, round_idx)

    def filter_candidates(self, freqs: List[float]) -> List[float]:
        """Refinement must not resurrect permanently-pruned frequencies."""
        return [f for f in freqs if f not in self.permanently_pruned]
