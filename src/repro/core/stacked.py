"""Stacked AGFT: one vectorized closed loop for a whole fleet of tuners.

The megafleet backend (``repro.serving.fleet_step``) steps thousands of
independent engines in lockstep; invoking a Python :class:`AGFTTuner.act`
per node per decision would dominate its runtime. This module runs the
SAME closed loop — window diff → features → reward → LinUCB credit →
convergence → pruning → refinement → selection — over ``(n_nodes, ...)``
arrays, one numpy dispatch per stage for every node due this round.

Bit-exactness contract: every stage is either (a) an elementwise port of
the scalar tuner arithmetic (same expression, same association order), or
(b) a batched linear-algebra form verified bit-identical to the scalar
bank's BLAS calls (see :class:`repro.core.linucb.StackedBanks`), or (c)
the *actual per-node object* (``PruningFramework``/
``MixedMaturityRefinement``) invoked through a bank view on exactly the
rounds the scalar tuner would invoke it with a mutating outcome —
vectorized prechecks prove the call would be a no-op otherwise. A fleet
driven by :class:`StackedAGFT` therefore produces per-node trajectories
bit-identical to per-node :class:`repro.core.tuner.AGFTTuner` instances.

Only the paper configuration is batchable: ``strategy="linucb"`` with no
fleet band. ``from_tuners`` validates this and refuses anything else (the
caller then falls back to per-node facades).
"""
from __future__ import annotations

import dataclasses
from typing import Optional, Sequence

import numpy as np

from repro.core.linucb import StackedBanks
from repro.core.tuner import AGFTTuner

#: metric-snapshot key order shared with ``repro.serving.fleet_step`` —
#: identical to ``MetricsExporter.snapshot()``; column indices below.
SNAP_KEYS = (
    "vllm:prompt_tokens_total",
    "vllm:cached_prompt_tokens_total",
    "vllm:generation_tokens_total",
    "vllm:iterations_total",
    "vllm:requests_finished_total",
    "vllm:prefix_cache_hits_total",
    "vllm:prefix_cache_queries_total",
    "vllm:energy_joules_total",
    "vllm:busy_seconds_total",
    "vllm:ttft_seconds_total",
    "vllm:ttft_count_total",
    "vllm:freq_transitions_total",
    "vllm:num_requests_running",
    "vllm:num_requests_waiting",
    "vllm:gpu_cache_usage_perc",
    "vllm:current_frequency_mhz",
    "vllm:current_power_watts",
)
_C = {k.split(":")[1]: i for i, k in enumerate(SNAP_KEYS)}


class _PHStack:
    """Vectorized two-sided Page-Hinkley detectors (one per node) —
    elementwise port of :class:`repro.core.page_hinkley.PageHinkley`."""

    def __init__(self, n: int, delta: float, threshold: float,
                 min_samples: int = 10):
        self.delta = delta
        self.threshold = threshold
        self.min_samples = min_samples
        self.n = np.zeros(n, dtype=np.int64)
        self.mean = np.zeros(n)
        self.m_up = np.zeros(n)
        self.m_dn = np.zeros(n)
        self.min_up = np.zeros(n)
        self.max_dn = np.zeros(n)

    def reset(self, k: np.ndarray) -> None:
        self.n[k] = 0
        self.mean[k] = 0.0
        self.m_up[k] = 0.0
        self.m_dn[k] = 0.0
        self.min_up[k] = 0.0
        self.max_dn[k] = 0.0

    def update(self, k: np.ndarray, v: np.ndarray) -> np.ndarray:
        """Feed one observation per node ``k``; True => drift alarm."""
        self.n[k] += 1
        self.mean[k] += (v - self.mean[k]) / self.n[k]
        dev = v - self.mean[k]
        self.m_up[k] += dev - self.delta
        self.m_dn[k] += dev + self.delta
        self.min_up[k] = np.minimum(self.min_up[k], self.m_up[k])
        self.max_dn[k] = np.maximum(self.max_dn[k], self.m_dn[k])
        alarm = (self.n[k] >= self.min_samples) \
            & (((self.m_up[k] - self.min_up[k]) > self.threshold)
               | ((self.max_dn[k] - self.m_dn[k]) > self.threshold))
        if alarm.any():
            self.reset(k[alarm])
        return alarm

class StackedAGFT:
    """The AGFT closed loop over ``(n_nodes,)`` state arrays.

    Constructed from a fleet of PRISTINE per-node tuners
    (:meth:`from_tuners`); per-node pruning/refinement framework objects
    are borrowed from the tuners (their logs and permanently-pruned sets
    accumulate in place), and :meth:`writeback` restores every tuner to
    the exact state its scalar twin would hold after the run.
    """

    def __init__(self, tuners: Sequence[AGFTTuner], *,
                 record_history: bool = True):
        t0 = tuners[0]
        cfg = t0.cfg
        n = len(tuners)
        self.tuners = list(tuners)
        self.cfg = cfg
        self.n_nodes = n
        self.dim = t0.features.dim
        self.scales = cfg.scales
        self.record_history = record_history
        self.period = cfg.sampling_period_s
        self.alpha = cfg.ucb_alpha

        freqs = t0.bank.frequencies
        self.banks = StackedBanks(n, freqs, self.dim, ridge=cfg.ridge)
        self.pruners = [t.pruner for t in tuners]
        self.refiners = [t.refiner for t in tuners]

        # monitor (TelemetryMonitor state, stacked)
        nk = len(SNAP_KEYS)
        self.prev_snap = np.zeros((n, nk))
        self.has_prev = np.zeros(n, dtype=bool)
        self.prev_time = np.zeros(n)
        self.next_sample = np.zeros(n)

        # reward reference (RewardCalculator state)
        self.ref_edp = np.full(n, np.nan)
        self.windows_seen = np.zeros(n, dtype=np.int64)

        # convergence (ConvergenceDetector state)
        ccfg = cfg.convergence
        self.ph = _PHStack(n, ccfg.ph_delta, ccfg.ph_threshold)
        self.ph_drift = _PHStack(n, ccfg.drift_delta, ccfg.drift_threshold)
        self.ring = np.zeros((n, ccfg.std_window))
        self.ring_pos = np.zeros(n, dtype=np.int64)
        self.ring_len = np.zeros(n, dtype=np.int64)
        self.quiet = np.zeros(n, dtype=np.int64)
        self.converged = np.zeros(n, dtype=bool)
        self.converged_round = np.full(n, -1, dtype=np.int64)
        self.first_converged_round = np.full(n, -1, dtype=np.int64)
        self.reopened = np.zeros(n, dtype=np.int64)
        self.conv_round = np.zeros(n, dtype=np.int64)

        # action bookkeeping (AGFTTuner state)
        self.round = np.zeros(n, dtype=np.int64)
        self.prev_action = np.full(n, np.nan)
        self.prev_context = np.zeros((n, self.dim))
        self.prev_switched = np.zeros(n, dtype=bool)
        self.switch_count = np.zeros(n, dtype=np.int64)

    # ------------------------------------------------------------------
    @classmethod
    def from_tuners(cls, policies: Sequence[object], *,
                    record_history: bool = True
                    ) -> Optional["StackedAGFT"]:
        """Build a stacked loop from per-node policies, or ``None`` when
        the fleet isn't batchable: every policy must be a pristine
        ``AGFTTuner`` (round 0, no telemetry seen, no band), using the
        paper's LinUCB strategy, with identical configs and identical
        initial action spaces."""
        if not policies:
            return None
        for p in policies:
            if type(p) is not AGFTTuner:
                return None
            if p.cfg.strategy != "linucb":
                return None
            if (p.round != 0 or p.monitor.prev_snapshot is not None
                    or p.prev_action is not None or p.band is not None
                    or p.history or p.bank._band is not None
                    or p.pruner.permanently_pruned or p.refiner.log):
                return None
        t0 = policies[0]
        ref_cfg = dataclasses.asdict(t0.cfg)
        ref_freqs = t0.bank.frequencies
        for p in policies[1:]:
            if dataclasses.asdict(p.cfg) != ref_cfg:
                return None
            if p.bank.frequencies != ref_freqs:
                return None
        return cls(policies, record_history=record_history)

    # ------------------------------------------------------------------
    def act(self, idx: np.ndarray, snap: np.ndarray, now: np.ndarray,
            actuate=None) -> np.ndarray:
        """One decision per node in ``idx``: ``snap`` rows are the nodes'
        current metric snapshots (``SNAP_KEYS`` order), ``now`` the window
        cut times (engine clocks in iteration mode, tick times in tick
        mode). Returns the chosen frequency per node.

        ``actuate`` (optional) is called with ``(idx, freqs)`` between
        selection and bookkeeping — exactly where the scalar tuner's
        ``_actuate`` calls ``engine.set_frequency`` — and may return the
        per-node history cut times (the scalar history records the
        POST-transition engine clock in iteration mode) or ``None`` to
        keep ``now`` (tick mode, where the cut is the tick time). Without
        the hook the caller actuates afterwards; histories then carry
        ``now``, correct whenever transitions don't advance the clock."""
        out = np.empty(len(idx))
        hp = self.has_prev[idx]
        aux = None
        if not hp.all():
            first = idx[~hp]
            _, f0 = self.banks.select_batch(
                first, np.zeros((len(first), self.dim)), self.alpha,
                np.zeros(len(first), dtype=bool))
            out[~hp] = f0
        if hp.any():
            reg = idx[hp]
            out[hp], aux = self._act_regular(reg, snap[hp], now[hp])
        hist_t = None
        if actuate is not None:
            hist_t = actuate(idx, out)
        if hist_t is None:
            hist_t = now
        if not hp.all():
            self._bookkeep(idx[~hp], out[~hp], None, None, None, None,
                           hist_t[~hp])
        if hp.any():
            reward, edp_plain, energy, tpot, x_t, greedy = aux
            self._bookkeep(idx[hp], out[hp], reward, edp_plain, energy,
                           tpot, hist_t[hp], x_t=x_t, greedy=greedy)
        # re-arm the window (monitor.observe does this on every path)
        self.prev_snap[idx] = snap
        self.has_prev[idx] = True
        self.prev_time[idx] = now
        self.next_sample[idx] = now + self.period
        return out

    # ------------------------------------------------------------------
    def _act_regular(self, reg: np.ndarray, snap: np.ndarray,
                     now: np.ndarray):
        prev = self.prev_snap[reg]
        d = snap - prev
        dur = np.maximum(now - self.prev_time[reg], 1e-9)
        energy = d[:, _C["energy_joules_total"]]
        busy = d[:, _C["busy_seconds_total"]]
        gen = d[:, _C["generation_tokens_total"]]
        pre = d[:, _C["prompt_tokens_total"]]
        iters = d[:, _C["iterations_total"]]
        running = snap[:, _C["num_requests_running"]]
        waiting = snap[:, _C["num_requests_waiting"]]
        usage = snap[:, _C["gpu_cache_usage_perc"]]
        hits = d[:, _C["prefix_cache_hits_total"]]
        queries = d[:, _C["prefix_cache_queries_total"]]
        hit_rate = np.where(queries > 0,
                            hits / np.where(queries > 0, queries, 1.0), 0.0)
        ttft = d[:, _C["ttft_seconds_total"]] \
            / np.maximum(d[:, _C["ttft_count_total"]], 1)
        # effective TPOT: busy/generated, stalled windows pay the duration
        tpot = np.where(gen > 0, busy / np.where(gen > 0, gen, 1.0), dur)

        # features (FeatureExtractor, elementwise)
        s = self.scales
        x_t = np.empty((len(reg), self.dim))
        x_t[:, 0] = np.where(waiting > 0, 1.0, 0.0)
        x_t[:, 1] = (pre / dur) / s.prefill_tput
        x_t[:, 2] = (gen / dur) / s.decode_tput
        x_t[:, 3] = ((pre + gen) / np.maximum(iters, 1)) / s.packing_eff
        x_t[:, 4] = running / s.concurrency
        x_t[:, 5] = usage
        x_t[:, 6] = hit_rate
        np.clip(x_t, 0.0, 1.5, out=x_t)

        # reward (RewardCalculator, elementwise) — prev_action is always
        # set after the first act, so every regular act credits. The switch
        # cost bills only the reward's mixed EDP; the arm credit and the
        # history record keep the window's raw ``edp`` / ``energy_j``.
        rcfg = self.cfg.reward
        self.windows_seen[reg] += 1
        energy_r = energy
        if rcfg.switch_cost_j:
            energy_r = np.where(self.prev_switched[reg],
                                energy + rcfg.switch_cost_j, energy)
        edp_plain = energy * tpot
        edp = np.maximum(energy_r * (tpot + rcfg.ttft_weight * ttft), 1e-12)
        ref = self.ref_edp[reg]
        ws = self.windows_seen[reg]
        ref = np.where(np.isnan(ref), edp,
                       np.where(ws <= rcfg.warmup_windows,
                                ref + (edp - ref) / ws,
                                ref + rcfg.ema * (edp - ref)))
        self.ref_edp[reg] = ref
        reward = -edp / np.maximum(ref, 1e-12)
        if rcfg.slo_tpot_s > 0:
            pen = rcfg.slo_penalty * (tpot / rcfg.slo_tpot_s - 1.0)
            reward = np.where(tpot > rcfg.slo_tpot_s, reward - pen, reward)
        qpen = rcfg.queue_penalty * np.minimum(
            waiting / np.maximum(running, 1), 2.0)
        reward = np.where((waiting > 0) & (running > 0),
                          reward - qpen, reward)

        # credit the previous action (arm may be gone: pruned or dropped
        # by a rebuild — then only convergence still sees the reward)
        slots = self.banks.slots_for(reg, self.prev_action[reg])
        hit = slots >= 0
        if hit.any():
            self.banks.update_rows(reg[hit], slots[hit],
                                   self.prev_context[reg[hit]],
                                   reward[hit], edp_plain[hit])
        self._converge_update(reg, reward)
        self.round[reg] += 1

        # pruning: vectorized precheck gates the per-node framework call
        need = self._pruning_precheck(reg)
        for i in np.flatnonzero(need):
            node = int(reg[i])
            self.pruners[node].apply(self.banks.view(node),
                                     int(self.round[node]))
        # refinement (only while learning) — predictive anchors (the UCB
        # argmax, the dominant per-node cost once the fleet is mature) are
        # batched into one stacked dispatch; the per-node framework call
        # then reuses the precomputed anchor
        rfcfg = self.cfg.refinement
        if rfcfg.enabled:
            rnd = self.round[reg]
            due = (~self.converged[reg]) & (rnd > 0) \
                & (rnd % rfcfg.interval == 0)
            if due.any():
                anchors = {}
                pred = due & (rnd >= rfcfg.maturity_threshold)
                if pred.any():
                    pi = np.flatnonzero(pred)
                    af = self.banks.argmax_ucb_batch(reg[pi], x_t[pi],
                                                     self.alpha)
                    anchors = dict(zip(pi.tolist(), af.tolist()))
                for i in np.flatnonzero(due):
                    node = int(reg[i])
                    self.refiners[node].maybe_refine(
                        self.banks.view(node), self.pruners[node],
                        x_t[i], int(self.round[node]),
                        anchor=anchors.get(i))

        # select: greedy exploitation once converged, UCB otherwise
        greedy = self.converged[reg]
        _, f = self.banks.select_batch(reg, x_t, self.alpha, greedy)
        return f, (reward, edp_plain, energy, tpot, x_t, greedy)

    # ------------------------------------------------------------------
    def _converge_update(self, k: np.ndarray, r: np.ndarray) -> None:
        """Elementwise port of ``ConvergenceDetector.update``."""
        ccfg = self.cfg.convergence
        self.conv_round[k] += 1
        W = ccfg.std_window
        self.ring[k, self.ring_pos[k]] = r
        self.ring_pos[k] = (self.ring_pos[k] + 1) % W
        self.ring_len[k] = np.minimum(self.ring_len[k] + 1, W)
        conv = self.converged[k]
        ck = k[conv]
        if len(ck):
            alarm = self.ph_drift.update(ck, r[conv])
            ak = ck[alarm]
            if len(ak):
                self.converged[ak] = False
                self.converged_round[ak] = -1
                self.quiet[ak] = 0
                self.reopened[ak] += 1
                self.ph.reset(ak)
        uk = k[~conv]
        if len(uk):
            drift = self.ph.update(uk, r[~conv])
            self.quiet[uk] = np.where(drift, 0, self.quiet[uk] + 1)
            cand = self.quiet[uk] >= ccfg.stable_rounds
            if cand.any():
                cku = uk[cand]
                # quiet >= stable_rounds implies a full ring; materialize
                # oldest->newest so np.std sums in deque order
                order = (self.ring_pos[cku][:, None]
                         + np.arange(W)[None, :]) % W
                vals = self.ring[cku[:, None], order]
                ok = np.std(vals, axis=1) <= ccfg.std_threshold
                ck2 = cku[ok]
                if len(ck2):
                    self.converged[ck2] = True
                    self.converged_round[ck2] = self.conv_round[ck2]
                    unset = self.first_converged_round[ck2] < 0
                    self.first_converged_round[ck2[unset]] = \
                        self.conv_round[ck2][unset]
                    self.ph_drift.reset(ck2)

    # ------------------------------------------------------------------
    def _pruning_precheck(self, reg: np.ndarray) -> np.ndarray:
        """True per node iff ``PruningFramework.apply`` COULD mutate the
        bank this round. The early-phase check is exact (same candidate
        predicate); the mature-phase check evaluates the full predicate —
        worst sampled mean EDP beyond BOTH the dynamic std tolerance and
        the 5% relative floor — with the tolerance shrunk by a 1e-9
        relative margin to absorb summation-order drift vs the scalar
        ``np.std`` (a framework call gated in is a no-op whenever the
        exact predicate fails, so erring toward calling is lossless;
        erring away would silently skip a prune and is forbidden)."""
        cfg = self.cfg.pruning
        k = len(reg)
        if not cfg.enabled:
            return np.zeros(k, dtype=bool)
        rnd = self.round[reg]
        banks = self.banks
        K = banks.capacity
        active = np.arange(K)[None, :] < banks.m[reg][:, None]
        n_act = banks.m[reg]
        nn = banks.n_[reg]
        need = np.zeros(k, dtype=bool)
        early = rnd <= cfg.early_rounds
        mature = rnd >= cfg.mature_rounds
        room = n_act > cfg.min_arms
        if early.any():
            with np.errstate(divide="ignore", invalid="ignore"):
                mr = banks.reward_sum[reg] / nn
            cand = active & (nn >= cfg.extreme_min_samples) \
                & (mr < cfg.extreme_reward_threshold)
            need |= early & room & cand.any(axis=1)
        if mature.any():
            sampled = active & (nn >= cfg.historical_min_samples)
            with np.errstate(divide="ignore", invalid="ignore"):
                me = banks.edp_sum[reg] / nn
            mes = np.where(sampled, me, 0.0)
            cnt = sampled.sum(axis=1)
            best = np.min(np.where(sampled, me, np.inf), axis=1)
            worst = np.max(np.where(sampled, me, -np.inf), axis=1)
            # masked two-pass variance of the sampled means — the same
            # arithmetic as the scalar np.std, modulo summation order
            denom = np.maximum(cnt, 1)
            mean = mes.sum(axis=1) / denom
            var = np.where(sampled, (mes - mean[:, None]) ** 2,
                           0.0).sum(axis=1) / denom
            tol = cfg.historical_tolerance_k * np.sqrt(var)
            need |= mature & room & (cnt >= 2) \
                & (worst > best * 1.05) \
                & (worst > best + tol * (1.0 - 1e-9))
        return need

    # ------------------------------------------------------------------
    def _bookkeep(self, idx: np.ndarray, f: np.ndarray, reward, edp,
                  energy, tpot, now, x_t: Optional[np.ndarray] = None,
                  greedy: Optional[np.ndarray] = None) -> None:
        """``AGFTTuner._actuate`` bookkeeping (sans engine actuation)."""
        prev = self.prev_action[idx]
        switched = ~np.isnan(prev) & (f != prev)
        self.prev_switched[idx] = switched
        self.switch_count[idx] += switched
        self.prev_action[idx] = f
        self.prev_context[idx] = x_t if x_t is not None else 0.0
        if not self.record_history:
            return
        m = self.banks.m[idx]
        conv = self.converged[idx]
        for j, node in enumerate(idx):
            if x_t is None:
                entry = {"t": float(now[j]), "freq": float(f[j]),
                         "reward": None, "edp": None, "energy_j": None,
                         "tpot": None, "phase": "warmup",
                         "n_arms": int(m[j]), "converged": bool(conv[j]),
                         "band": None}
            else:
                entry = {"t": float(now[j]), "freq": float(f[j]),
                         "reward": float(reward[j]), "edp": float(edp[j]),
                         "energy_j": float(energy[j]),
                         "tpot": float(tpot[j]),
                         "phase": "exploit" if greedy[j] else "explore",
                         "n_arms": int(m[j]), "converged": bool(conv[j]),
                         "band": None}
            self.tuners[int(node)].history.append(entry)

    # ------------------------------------------------------------------
    def writeback(self) -> None:
        """Restore each tuner to the exact state its scalar twin would
        hold: bank statistics, monitor window, reward reference,
        convergence detector, and action bookkeeping. History (when
        recorded) and pruner/refiner logs accumulated in place already."""
        for i, t in enumerate(self.tuners):
            b = self.banks
            m = int(b.m[i])
            t.bank._alloc([float(x) for x in b.freqs[i, :m]])
            t.bank._A[:] = b.A[i, :m]
            t.bank._A_inv[:] = b.A_inv[i, :m]
            t.bank._b[:] = b.b[i, :m]
            t.bank._theta[:] = b.theta[i, :m]
            t.bank._n[:] = b.n_[i, :m]
            t.bank._reward_sum[:] = b.reward_sum[i, :m]
            t.bank._edp_sum[:] = b.edp_sum[i, :m]
            if self.has_prev[i]:
                t.monitor.prev_snapshot = {
                    k: float(self.prev_snap[i, j])
                    for j, k in enumerate(SNAP_KEYS)}
            t.monitor.prev_time = float(self.prev_time[i])
            t.monitor.next_sample = float(self.next_sample[i])
            if not np.isnan(self.ref_edp[i]):
                t.reward_calc.ref_edp = float(self.ref_edp[i])
            t.reward_calc.windows_seen = int(self.windows_seen[i])
            c = t.convergence
            c.round = int(self.conv_round[i])
            c.quiet_rounds = int(self.quiet[i])
            c.converged = bool(self.converged[i])
            c.converged_round = (int(self.converged_round[i])
                                 if self.converged_round[i] >= 0 else None)
            c.first_converged_round = (
                int(self.first_converged_round[i])
                if self.first_converged_round[i] >= 0 else None)
            c.reopened = int(self.reopened[i])
            L = int(self.ring_len[i])
            order = (int(self.ring_pos[i]) + np.arange(L)) % self.ring.shape[1] \
                if L == self.ring.shape[1] else np.arange(L)
            c.recent.clear()
            c.recent.extend(float(v) for v in self.ring[i, order])
            for src, dst in ((self.ph, c.ph), (self.ph_drift, c.ph_drift)):
                dst.n = int(src.n[i])
                dst.mean = float(src.mean[i])
                dst.m_up = float(src.m_up[i])
                dst.m_dn = float(src.m_dn[i])
                dst.min_up = float(src.min_up[i])
                dst.max_dn = float(src.max_dn[i])
            t.round = int(self.round[i])
            t.switch_count = int(self.switch_count[i])
            t.prev_switched = bool(self.prev_switched[i])
            if not np.isnan(self.prev_action[i]):
                t.prev_action = float(self.prev_action[i])
                t.prev_context = self.prev_context[i].copy()


def stackable(policies: Sequence[object]) -> bool:
    """True when ``StackedAGFT.from_tuners`` would accept the fleet."""
    probe = StackedAGFT.from_tuners(policies, record_history=False)
    return probe is not None
