"""AGFT-2D: phase-disaggregated AGFT over a pruned product action space.

The 1-D tuner (``repro.core.tuner``) learns one clock per node; this
subclass learns a PAIR ``(f_prefill, f_decode)`` and actuates it through
``engine.set_phase_frequencies`` — prefill-chunk work runs at the first
clock, pure-decode work at the second, mixed iterations price each half at
its own clock with every phase switch billed through the engine's DVFS
transition machinery (GreenLLM, arXiv:2508.16449: prefill is compute-bound,
decode bandwidth-bound, so the two optima are hundreds of MHz apart).

The full product of two hardware grids (~107 x 107 actions on an A6000) is
unlearnable inside a sub-second-window run, so the initial space is a
PRUNED product: each axis is seeded around its analytic per-phase EDP
optimum (``repro.energy.phase_optimal_frequencies`` — the same sweep the
``greenllm-rule`` comparator pins statically) with ``2*seed_span + 1``
points at ``seed_step_mhz`` spacing, giving a 5x5 = 25-pair space by
default. From there the 1-D machinery generalizes: the LinUCB bank keys
arms by pair (lexicographic deterministic order), pruning's cascade drops
axis-dominated slow pairs, refinement rebuilds a product grid around the
anchor pair, and ``set_band`` masks pairs with EITHER clock out of band so
hierarchy/thermal clamps compose.

Everything else — features, reward, Page-Hinkley convergence, telemetry
windows, fault-aware freezes — is inherited unchanged. The seeding sweep
needs the engine's model/scheduler shape, so the bank is built lazily on
first contact; construction stays registry-compatible
(``get_policy("agft-2d")``).

Batched fleet mode (``step_mode="batched"``) refuses phased policies at
construction: its vectorized pricing paths are single-clock per node.
"""
from __future__ import annotations

from typing import Optional, Tuple

from repro.core.linucb import LinUCBBank
from repro.core.tuner import AGFTConfig, AGFTTuner
from repro.energy.phases import phase_optimal_frequencies
from repro.energy.power_model import HardwareSpec

import numpy as np


class AGFT2DTuner(AGFTTuner):
    #: feature-detected by the batched fleet loop's construction guard
    #: (phase-disaggregated actuation needs the per-event engine path)
    phased = True

    def __init__(self, hardware: HardwareSpec,
                 cfg: Optional[AGFTConfig] = None, *,
                 seed_span: int = 2, seed_step_mhz: float = 90.0,
                 batch_cap: Optional[int] = None):
        super().__init__(hardware, cfg)
        self.seed_span = int(seed_span)
        self.seed_step_mhz = float(seed_step_mhz)
        #: optional second knob: clamp the scheduler's concurrent-seq
        #: admission (``ContinuousBatchingScheduler.set_admission_cap``)
        self.batch_cap = batch_cap
        #: the product space is seeded from the engine's own model and
        #: scheduler shape, so it is built on first contact; until then
        #: the inherited 1-D bank is a placeholder that never selects
        self._space_built = False
        self.seed_pair: Optional[Tuple[float, float]] = None

    # ------------------------------------------------------------------
    def _snap(self, f: float) -> float:
        hw = self.hw
        f = min(max(f, hw.f_min), hw.f_max)
        return min(hw.f_min + round((f - hw.f_min) / hw.f_step) * hw.f_step,
                   hw.f_max)

    def _axis(self, center: float) -> list:
        return sorted({self._snap(center + k * self.seed_step_mhz)
                       for k in range(-self.seed_span, self.seed_span + 1)})

    def _build_space(self, engine) -> None:
        dvfs = getattr(engine.backend, "dvfs", None)
        sched = getattr(engine, "sched", None)
        self.seed_pair = phase_optimal_frequencies(
            self.hw, engine.model_cfg, dvfs=dvfs,
            prefill_chunk=getattr(engine.cfg, "prefill_chunk", 512),
            decode_seqs=max(getattr(engine.cfg, "max_num_seqs", 64) // 2,
                            1))
        pairs = [(a, b) for a in self._axis(self.seed_pair[0])
                 for b in self._axis(self.seed_pair[1])]
        self.bank = LinUCBBank(pairs, dim=self.features.dim,
                               ridge=self.cfg.ridge)
        if self.band is not None:
            self.bank.set_band(*self.band)
        if self.batch_cap is not None and sched is not None:
            sched.set_admission_cap(self.batch_cap)
        self._space_built = True

    # ------------------------------------------------------------------
    def act(self, engine, now: Optional[float] = None):
        if not self._space_built:
            self._build_space(engine)
        return super().act(engine, now=now)

    def _diverged(self, engine) -> bool:
        # stuck/clamped actuation surfaces as the engine's phase targets
        # (or a scalar override clearing them) differing from the issued
        # pair
        return (self.prev_action is not None
                and getattr(engine, "freq_targets", None)
                != self.prev_action)

    def _actuate(self, engine, f, reward, window, phase,
                 x_t: Optional[np.ndarray] = None,
                 t: Optional[float] = None) -> None:
        pair = (f if isinstance(f, tuple) else (float(f), float(f)))
        engine.set_phase_frequencies(*pair)
        self.prev_switched = (self.prev_action is not None
                              and pair != self.prev_action)
        self.switch_count += int(self.prev_switched)
        self.prev_action = pair
        self.prev_context = (x_t if x_t is not None
                             else np.zeros(self.features.dim))
        self.history.append({
            "t": engine.clock if t is None else t,
            "freq": pair,
            "reward": reward,
            "edp": window.edp if window else None,
            "energy_j": window.energy_j if window else None,
            "tpot": window.effective_tpot if window else None,
            "phase": phase or "warmup",
            "n_arms": len(self.bank.arms),
            "converged": self.convergence.converged,
            "band": self.band,
        })
