"""EDP-based reward (paper §4.2 "Reward Calculation"): r_t inversely
proportional to the window's measured EDP, with SLO pressure penalties.

Normalization: the first windows establish a reference EDP (EMA), so
r = -EDP/EDP_ref sits near -1 at baseline behaviour. That gives the
pruning thresholds their paper semantics (extreme pruning at mean reward
< -1.2 == ">=20% worse than reference").
"""
from __future__ import annotations

import dataclasses
from typing import Optional

from repro.energy.edp import WindowStats


@dataclasses.dataclass
class RewardConfig:
    warmup_windows: int = 5          # windows used to seed the reference
    ema: float = 0.02                # slow reference drift (non-stationarity)
    # TPOT SLO: ~1.33x the baseline TPOT of the reference serving setup
    # (llama3-3b @ A6000). 0 disables the penalty.
    slo_tpot_s: float = 0.016
    slo_penalty: float = 2.0
    # TTFT weight in the window-EDP delay (aligns the online objective with
    # the offline sweep's delay mix; 0 reverts to pure TPOT delay)
    # 0.1 balances offline-objective alignment (Tab 6) against stability
    # under non-stationary traces (0.25 aligns prototypes better but the
    # noisier TTFT signal destabilizes the Azure longrun — measured)
    ttft_weight: float = 0.1
    queue_penalty: float = 0.05      # per unit of waiting/running pressure
    # Switching-cost awareness (arXiv:2410.11855 switching-aware bandits):
    # a DVFS transition is priced as `switch_cost_j` extra joules folded
    # into the window's EDP whenever the credited action CHANGED the
    # frequency. 0 (default) reproduces the paper's switching-oblivious
    # reward exactly; the ``agft-switchcost`` registry variant enables it.
    switch_cost_j: float = 0.0


class RewardCalculator:
    def __init__(self, cfg: RewardConfig = RewardConfig()):
        self.cfg = cfg
        self.ref_edp: Optional[float] = None
        self.windows_seen = 0

    def __call__(self, w: WindowStats, switched: bool = False) -> float:
        """Reward for the window; ``switched`` marks that the credited
        action was a frequency *change* (a DVFS transition happened at the
        window's start), billing ``switch_cost_j`` into its energy."""
        self.windows_seen += 1
        if switched and self.cfg.switch_cost_j:
            w = dataclasses.replace(
                w, energy_j=w.energy_j + self.cfg.switch_cost_j)
        edp = max(w.edp_mixed(self.cfg.ttft_weight), 1e-12)
        if self.ref_edp is None:
            self.ref_edp = edp
        elif self.windows_seen <= self.cfg.warmup_windows:
            self.ref_edp += (edp - self.ref_edp) / self.windows_seen
        else:
            self.ref_edp += self.cfg.ema * (edp - self.ref_edp)
        r = -edp / max(self.ref_edp, 1e-12)
        if (self.cfg.slo_tpot_s > 0
                and w.effective_tpot > self.cfg.slo_tpot_s):
            r -= self.cfg.slo_penalty * (
                w.effective_tpot / self.cfg.slo_tpot_s - 1.0)
        if w.requests_waiting > 0 and w.requests_running > 0:
            r -= self.cfg.queue_penalty * min(
                w.requests_waiting / max(w.requests_running, 1), 2.0)
        return r
