"""recurrentgemma-9b — Griffin hybrid: RG-LRU + local attention, 1:2.
[arXiv:2402.19427] 38L d_model=4096 16H (MQA kv=1) d_ff=12288 vocab=256000,
lru_width=4096, local window 2048, pattern (rec, rec, attn).
38 = 12*(rec,rec,attn) + 2 trailing rec layers (38 % 3 != 0; see DESIGN.md).
Bounded state => long_500k native."""
from repro.models.common import ModelConfig

CONFIG = ModelConfig(
    name="recurrentgemma-9b",
    arch_type="hybrid",
    num_layers=38,
    d_model=4096,
    num_heads=16,
    num_kv_heads=1,
    head_dim=256,
    d_ff=12288,
    vocab_size=256000,
    block_pattern=("rec", "rec", "attn"),
    lru_width=4096,
    local_window=2048,
    ffn_activation="geglu",
    use_rope=True,
    source="arXiv:2402.19427",
)
