"""llama4-scout-17b-a16e — MoE, early fusion.
[hf:meta-llama/Llama-4-Scout-17B-16E] 48L d_model=5120 40H (GQA kv=8)
d_ff=8192 vocab=202048, MoE 16 experts top-1 (+1 shared, per model card)."""
from repro.models.common import ModelConfig

CONFIG = ModelConfig(
    name="llama4-scout-17b-a16e",
    arch_type="moe",
    num_layers=48,
    d_model=5120,
    num_heads=40,
    num_kv_heads=8,
    head_dim=128,
    d_ff=8192,
    moe_d_ff=8192,
    vocab_size=202048,
    num_experts=16,
    top_k=1,
    num_shared_experts=1,
    ffn_activation="swiglu",
    use_rope=True,
    rope_theta=500000.0,
    source="hf:meta-llama/Llama-4-Scout-17B-16E",
)
