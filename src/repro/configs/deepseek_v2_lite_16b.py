"""deepseek-v2-lite-16b — MoE with Multi-head Latent Attention.
[arXiv:2405.04434] 27L d_model=2048 16H d_ff(expert)=1408 vocab=102400,
MLA kv_lora=512, 2 shared + 64 routed experts top-6, first layer dense FFN.

Note: the assignment line reads "MoE 64e top-6 ... 2 shared+160 routed
top-6" — internally inconsistent; we follow the primary "64e top-6"
(the V2-Lite model card: 64 routed, 2 shared, moe_intermediate=1408,
dense first-layer intermediate=10944)."""
from repro.models.common import ModelConfig

CONFIG = ModelConfig(
    name="deepseek-v2-lite-16b",
    arch_type="moe",
    num_layers=27,
    d_model=2048,
    num_heads=16,
    num_kv_heads=16,
    head_dim=128,
    d_ff=10944,            # dense first-layer FFN (model card)
    moe_d_ff=1408,         # per-expert hidden (assignment)
    vocab_size=102400,
    num_experts=64,
    top_k=6,
    num_shared_experts=2,
    first_k_dense=1,
    use_mla=True,
    kv_lora_rank=512,
    qk_nope_head_dim=128,
    qk_rope_head_dim=64,
    v_head_dim=128,
    ffn_activation="swiglu",
    use_rope=True,
    rope_theta=10000.0,
    source="arXiv:2405.04434",
)
