"""nemotron-4-15b — dense, GQA, squared-ReLU (ungated) FFN.
[arXiv:2402.16819] 32L d_model=6144 48H (GQA kv=8) d_ff=24576 vocab=256000."""
from repro.models.common import ModelConfig

CONFIG = ModelConfig(
    name="nemotron-4-15b",
    arch_type="dense",
    num_layers=32,
    d_model=6144,
    num_heads=48,
    num_kv_heads=8,
    head_dim=128,
    d_ff=24576,
    vocab_size=256000,
    ffn_activation="squared_relu",
    use_rope=True,
    source="arXiv:2402.16819",
)
