"""mamba2-1.3b — attention-free SSM with SSD (state-space duality).
[arXiv:2405.21060] 48L d_model=2048 vocab=50280, d_state=128, expand=2,
head_dim=64 (=> 64 ssm heads), ngroups=1. Constant state => long_500k
native sub-quadratic."""
from repro.models.common import ModelConfig

CONFIG = ModelConfig(
    name="mamba2-1.3b",
    arch_type="ssm",
    num_layers=48,
    d_model=2048,
    num_heads=1,          # unused (attention-free)
    num_kv_heads=1,
    head_dim=64,
    d_ff=0,
    vocab_size=50280,
    ssm_state=128,
    ssm_head_dim=64,
    ssm_expand=2,
    ssm_ngroups=1,
    ssm_chunk=128,
    conv_kernel=4,
    tie_embeddings=True,
    use_rope=False,
    source="arXiv:2405.21060",
)
