"""Architecture registry: ``--arch <id>`` resolves here."""
from __future__ import annotations

import importlib
from typing import Dict, List

from repro.models.common import ModelConfig
from repro.configs.shapes import SHAPES, InputShape, get_shape  # noqa: F401

_MODULES = {
    "llama4-scout-17b-a16e": "llama4_scout_17b_a16e",
    "deepseek-v2-lite-16b": "deepseek_v2_lite_16b",
    "chameleon-34b": "chameleon_34b",
    "recurrentgemma-9b": "recurrentgemma_9b",
    "nemotron-4-15b": "nemotron_4_15b",
    "whisper-medium": "whisper_medium",
    "mamba2-1.3b": "mamba2_1p3b",
    "starcoder2-7b": "starcoder2_7b",
    "tinyllama-1.1b": "tinyllama_1p1b",
    "phi3-medium-14b": "phi3_medium_14b",
    "llama3-3b": "llama3_3b",   # the paper's own eval model
}

# default sliding window used when long_500k forces a sub-quadratic variant
DEFAULT_LONG_WINDOW = 8192

ASSIGNED_ARCHS: List[str] = [k for k in _MODULES if k != "llama3-3b"]


def get_config(arch: str) -> ModelConfig:
    if arch not in _MODULES:
        raise KeyError(
            f"unknown arch {arch!r}; available: {sorted(_MODULES)}")
    mod = importlib.import_module(f"repro.configs.{_MODULES[arch]}")
    return mod.CONFIG


def long_context_window(arch: str) -> int:
    mod = importlib.import_module(f"repro.configs.{_MODULES[arch]}")
    return getattr(mod, "LONG_CONTEXT_WINDOW", DEFAULT_LONG_WINDOW)


def config_for_shape(arch: str, shape_name: str) -> ModelConfig:
    """Resolve the config variant an input shape requires (e.g. long_500k
    switches full-attention archs to their sliding-window variant)."""
    cfg = get_config(arch)
    shape = get_shape(shape_name)
    if (shape.requires_subquadratic and cfg.arch_type
            not in ("ssm", "hybrid") and not cfg.attention_window):
        cfg = cfg.replace(attention_window=long_context_window(arch))
    return cfg


def all_configs() -> Dict[str, ModelConfig]:
    return {a: get_config(a) for a in _MODULES}
