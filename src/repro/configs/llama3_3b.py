"""llama3-3b — the paper's own evaluation model (AGFT §5.1 uses
"Llama-3-3B"; dims per Llama-3.2-3B model card).
28L d_model=3072 24H (GQA kv=8) d_ff=8192 vocab=128256."""
from repro.models.common import ModelConfig

CONFIG = ModelConfig(
    name="llama3-3b",
    arch_type="dense",
    num_layers=28,
    d_model=3072,
    num_heads=24,
    num_kv_heads=8,
    head_dim=128,
    d_ff=8192,
    vocab_size=128256,
    ffn_activation="swiglu",
    use_rope=True,
    rope_theta=500000.0,
    source="paper §5.1 / hf:meta-llama/Llama-3.2-3B",
)
