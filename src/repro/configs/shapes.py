"""Assigned input shapes. Decode shapes lower ``serve_step`` (one new token
against a KV cache of ``seq_len``); train/prefill lower full sequences."""
from __future__ import annotations

import dataclasses
from typing import Dict


@dataclasses.dataclass(frozen=True)
class InputShape:
    name: str
    seq_len: int
    global_batch: int
    kind: str  # "train" | "prefill" | "decode"
    # long-context decode must be sub-quadratic: attention archs switch to
    # their sliding-window variant when this flag is set.
    requires_subquadratic: bool = False


SHAPES: Dict[str, InputShape] = {
    "train_4k": InputShape("train_4k", 4_096, 256, "train"),
    "prefill_32k": InputShape("prefill_32k", 32_768, 32, "prefill"),
    "decode_32k": InputShape("decode_32k", 32_768, 128, "decode"),
    "long_500k": InputShape("long_500k", 524_288, 1, "decode",
                            requires_subquadratic=True),
}


def get_shape(name: str) -> InputShape:
    return SHAPES[name]
