"""chameleon-34b — early-fusion VLM: VQ image tokens share the text vocab,
so the backbone is a dense decoder with QK-norm. [arXiv:2405.09818]
48L d_model=8192 64H (GQA kv=8) d_ff=22016 vocab=65536 (incl. VQ codes).
The VQ-VAE image tokenizer is a STUB: input_specs provides token ids."""
from repro.models.common import ModelConfig

CONFIG = ModelConfig(
    name="chameleon-34b",
    arch_type="vlm",
    num_layers=48,
    d_model=8192,
    num_heads=64,
    num_kv_heads=8,
    head_dim=128,
    d_ff=22016,
    vocab_size=65536,
    use_qk_norm=True,
    ffn_activation="swiglu",
    use_rope=True,
    frontend_stub="vq_image_tokens",
    source="arXiv:2405.09818",
)
