"""starcoder2-7b — dense GQA + RoPE; model-card sliding window 4096 is the
sub-quadratic variant used for long_500k. [arXiv:2402.19173]
32L d_model=4608 36H (GQA kv=4) d_ff=18432 vocab=49152."""
from repro.models.common import ModelConfig

CONFIG = ModelConfig(
    name="starcoder2-7b",
    arch_type="dense",
    num_layers=32,
    d_model=4608,
    num_heads=36,
    num_kv_heads=4,
    head_dim=128,
    d_ff=18432,
    vocab_size=49152,
    ffn_activation="gelu",
    use_rope=True,
    rope_theta=100000.0,
    source="arXiv:2402.19173",
)
# sliding-window value used when long_500k requests the sub-quadratic variant
LONG_CONTEXT_WINDOW = 4096
