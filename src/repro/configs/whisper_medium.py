"""whisper-medium — encoder-decoder audio backbone; conv/mel frontend STUB
(input_specs provides (B, 1500, d_model) frame embeddings).
[arXiv:2212.04356] 24+24L d_model=1024 16H (kv=16) d_ff=4096 vocab=51865."""
from repro.models.common import ModelConfig

CONFIG = ModelConfig(
    name="whisper-medium",
    arch_type="encdec",
    is_encoder_decoder=True,
    num_layers=24,
    encoder_layers=24,
    encoder_seq=1500,
    d_model=1024,
    num_heads=16,
    num_kv_heads=16,
    head_dim=64,
    d_ff=4096,
    vocab_size=51865,
    ffn_activation="gelu",
    use_rope=False,
    frontend_stub="audio_frames",
    source="arXiv:2212.04356",
)
