from repro.serving.driver import (EngineNode, EventKind, EventLoop,
                                  POLICY_TICK_MODES, drive)
from repro.serving.engine import (EngineConfig, InferenceEngine, JaxBackend,
                                  SimBackend)
from repro.serving.faults import (FaultConfig, FaultModel,
                                  PRESETS as FAULT_PRESETS,
                                  parse_fault_spec)
from repro.serving.kv_cache import PagedKVCache
from repro.serving.metrics import MetricsExporter
from repro.serving.network import (DeliverySchedule, NetworkConfig,
                                   NetworkModel, PRESETS as NETWORK_PRESETS)
from repro.serving.request import Request, RequestState
from repro.serving.scheduler import BatchPlan, ContinuousBatchingScheduler

__all__ = ["EngineConfig", "EngineNode", "EventKind", "EventLoop",
           "InferenceEngine", "JaxBackend", "SimBackend", "PagedKVCache",
           "MetricsExporter", "NetworkConfig", "NetworkModel",
           "NETWORK_PRESETS", "DeliverySchedule", "POLICY_TICK_MODES",
           "FaultConfig", "FaultModel", "FAULT_PRESETS",
           "parse_fault_spec", "Request", "RequestState", "BatchPlan",
           "ContinuousBatchingScheduler", "drive"]
