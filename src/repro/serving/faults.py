"""Seeded fault injection for the discrete-event serving core.

The reproduction historically simulated a perfectly healthy fleet: every
``set_frequency`` landed, every telemetry window was complete, and no
node ever died. Real clusters are dominated by exactly those failures,
and online bandit DVFS is known to be fragile to corrupted feedback
(switching-aware bandits, arXiv:2410.11855) while SLO-aware controllers
must hold their guarantees precisely when capacity drops (GreenLLM,
arXiv:2508.16449). This module injects four fault classes into the
event loop (``repro.serving.driver``) as first-class ``NODE_FAULT`` /
``NODE_RECOVER`` events:

``crash``      node churn: a node goes dark for an MTTR-sampled outage;
               its in-flight and queued requests are evacuated and
               re-routed through the delivery schedule with exponential
               backoff under a bounded retry budget (budget exhausted ->
               the request is dropped and counted)
``dvfs``       flaky actuation: ``set_frequency`` silently sticks (the
               call is lost) or lags (applies after an extra stall) —
               policies must detect the divergence from telemetry and
               re-issue
``thermal``    throttling: the node's frequency envelope is clamped to a
               cap for a sampled window; the clamp composes with fleet-
               coordinator bands (the effective band is the
               intersection) and forces an immediate DVFS transition
               when the running frequency exceeds the cap
``telemetry``  dropouts: a metric scrape fails, blanking the monitor
               window; the *next* successful window spans the gap and is
               flagged stale so policies can refuse to learn from it

Determinism contract: every node draws from its own RNG streams derived
from ``(seed, node_id, fault_class)`` — adding or removing a node never
shifts another node's fault sequence, the same per-entity independence
the :class:`repro.serving.network.NetworkModel` submit-order stream
follows per cluster. A :class:`FaultModel` built from the same spec and
seed replays the identical fault schedule on the identical trace.

Graceful degradation lives with the consumers: ``AGFTTuner`` freezes
bandit updates on faulted/stale windows (no poisoning ``LinUCBBank``
statistics with corrupted rewards) and holds a safe frequency,
``WindowedPolicy`` skips decisions on blanked windows, the
``BandCoordinator`` re-water-fills the power budget over surviving nodes
on the next fleet tick, and the event loop stops delivering to dead
nodes and drains retries on recovery. With no fault model attached
(or the ``none`` preset) every code path is byte-identical to the
healthy simulation — both committed goldens hold.

Spec grammar (``FaultModel.from_spec``)::

    preset                       none | flaky-dvfs | node-churn |
                                 thermal | lossy-telemetry
    clause                       class:key=value[,key=value...]
    spec                         clause[;clause...]   (presets allowed
                                 as clauses; later clauses override)

    crash:mttf=60,mttr=5,retries=4,backoff=0.25
    dvfs:stick=0.35,lag=0.01
    thermal:mtbf=45,duration=8,cap=0.55
    telemetry:drop=0.3
    node-churn;telemetry:drop=0.5      # preset + override combine
"""
from __future__ import annotations

import dataclasses
import heapq
from typing import Dict, List, Optional, Sequence, Tuple

import numpy as np

#: fault-class indices salting the per-node RNG streams — one stream per
#: (seed, node, class) so classes never perturb each other's sequences
_STREAM_CRASH = 0
_STREAM_THERMAL = 1
_STREAM_DVFS = 2
_STREAM_TELEMETRY = 3

#: action kinds carried by the fault model's internal event heap
ONSET_ACTIONS = ("crash", "thermal-on")
RECOVER_ACTIONS = ("recover", "thermal-off")


@dataclasses.dataclass(frozen=True)
class FaultConfig:
    """Static description of the injected fault mix (times in sim
    seconds; a 0 rate/probability disables that class entirely)."""
    #: mean time to failure for node crashes (exponential); 0 = no churn
    crash_mttf_s: float = 0.0
    #: mean time to repair (exponential)
    crash_mttr_s: float = 5.0
    #: re-route attempts per request before it is dropped (0 = naive
    #: no-retry baseline: a crash loses every evacuated request)
    retry_budget: int = 3
    #: exponential-backoff base: attempt k is delayed ``backoff * 2**k``
    retry_backoff_s: float = 0.25
    #: probability an individual ``set_frequency`` call is silently lost
    dvfs_stick_prob: float = 0.0
    #: extra actuation stall billed to the clock when a flaky transition
    #: does land (the "lags" half of stick-or-lag)
    dvfs_lag_s: float = 0.0
    #: mean time between thermal-throttle onsets; 0 = no throttling
    thermal_mtbf_s: float = 0.0
    #: mean throttle-window duration (exponential)
    thermal_duration_s: float = 10.0
    #: frequency cap while throttled, as a fraction of f_max (clamped to
    #: the hardware envelope)
    thermal_cap_frac: float = 0.6
    #: probability an individual telemetry scrape fails (blank window)
    telemetry_drop_prob: float = 0.0

    @property
    def any_active(self) -> bool:
        return (self.crash_mttf_s > 0.0 or self.dvfs_stick_prob > 0.0
                or self.dvfs_lag_s > 0.0 or self.thermal_mtbf_s > 0.0
                or self.telemetry_drop_prob > 0.0)


#: named fault mixes for the CLI / benchmarks; rates are sized for the
#: benchmark traces (minutes of simulated serving), not datacenter MTTFs
PRESETS: Dict[str, FaultConfig] = {
    "none": FaultConfig(),
    "flaky-dvfs": FaultConfig(dvfs_stick_prob=0.35),
    "node-churn": FaultConfig(crash_mttf_s=60.0, crash_mttr_s=5.0,
                              retry_budget=4, retry_backoff_s=0.25),
    "thermal": FaultConfig(thermal_mtbf_s=45.0, thermal_duration_s=8.0,
                           thermal_cap_frac=0.55),
    "lossy-telemetry": FaultConfig(telemetry_drop_prob=0.3),
}

#: spec-clause field maps: ``class:key=value`` -> FaultConfig field
_CLAUSE_FIELDS: Dict[str, Dict[str, str]] = {
    "crash": {"mttf": "crash_mttf_s", "mttr": "crash_mttr_s",
              "retries": "retry_budget", "backoff": "retry_backoff_s"},
    "dvfs": {"stick": "dvfs_stick_prob", "lag": "dvfs_lag_s"},
    "thermal": {"mtbf": "thermal_mtbf_s", "duration": "thermal_duration_s",
                "cap": "thermal_cap_frac"},
    "telemetry": {"drop": "telemetry_drop_prob"},
}


def parse_fault_spec(spec: str) -> FaultConfig:
    """Parse the spec grammar (module docstring) into a
    :class:`FaultConfig`. Presets may appear as clauses; later clauses
    override earlier fields."""
    fields: Dict[str, object] = {}
    for clause in spec.split(";"):
        clause = clause.strip()
        if not clause:
            continue
        if clause in PRESETS:
            fields.update(dataclasses.asdict(PRESETS[clause]))
            continue
        name, sep, body = clause.partition(":")
        name = name.strip()
        if name not in _CLAUSE_FIELDS:
            raise ValueError(
                f"unknown fault clause {name!r}; presets: "
                f"{', '.join(sorted(PRESETS))}; classes: "
                f"{', '.join(sorted(_CLAUSE_FIELDS))}")
        if not sep:
            raise ValueError(f"fault clause {name!r} needs key=value "
                             f"settings (e.g. {name}:...)")
        fmap = _CLAUSE_FIELDS[name]
        for kv in body.split(","):
            key, sep2, val = kv.partition("=")
            key = key.strip()
            if not sep2 or key not in fmap:
                raise ValueError(
                    f"bad setting {kv!r} in fault clause {name!r}; "
                    f"keys: {', '.join(sorted(fmap))}")
            field = fmap[key]
            fields[field] = (int(val) if field == "retry_budget"
                             else float(val))
    cfg = FaultConfig(**fields)
    if cfg.retry_budget < 0:
        raise ValueError("retry budget must be >= 0")
    if not (0.0 <= cfg.dvfs_stick_prob <= 1.0
            and 0.0 <= cfg.telemetry_drop_prob <= 1.0):
        raise ValueError("fault probabilities must be in [0, 1]")
    return cfg


class NodeFaultState:
    """Per-node fault surface, attached to the engine as
    ``engine.fault_state`` — the feature-detection point for policies
    (``getattr(engine, "fault_state", None)``) and the actuation filter
    for the engine's ``set_frequency``.

    RNG streams are per ``(seed, node_id, class)`` so the node's fault
    sequence is a pure function of its own identity (the determinism
    satellite: membership changes never shift a peer's schedule).
    """

    __slots__ = ("node_id", "config", "down", "thermal_cap_mhz",
                 "last_disruption_t", "bypass", "sticks", "lags",
                 "scrape_drops", "crashes", "thermal_events",
                 "_rng_crash", "_rng_thermal", "_rng_dvfs",
                 "_rng_telemetry")

    def __init__(self, node_id: int, config: FaultConfig, seed: int):
        self.node_id = node_id
        self.config = config
        self.down = False
        self.thermal_cap_mhz: Optional[float] = None
        #: virtual time of the latest disruption touching this node —
        #: policies freeze windows that overlap it
        self.last_disruption_t: float = -np.inf
        #: loop-internal escape hatch: a forced clamp (thermal onset)
        #: must not itself stick
        self.bypass = False
        self.sticks = 0
        self.lags = 0
        self.scrape_drops = 0
        self.crashes = 0
        self.thermal_events = 0
        self._rng_crash = np.random.default_rng(
            (seed, node_id, _STREAM_CRASH))
        self._rng_thermal = np.random.default_rng(
            (seed, node_id, _STREAM_THERMAL))
        self._rng_dvfs = np.random.default_rng(
            (seed, node_id, _STREAM_DVFS))
        self._rng_telemetry = np.random.default_rng(
            (seed, node_id, _STREAM_TELEMETRY))

    # -- schedule sampling (consumed by FaultModel only) ---------------
    def sample_crash_gap(self) -> float:
        return float(self._rng_crash.exponential(self.config.crash_mttf_s))

    def sample_repair(self) -> float:
        return float(self._rng_crash.exponential(
            max(self.config.crash_mttr_s, 1e-6)))

    def sample_thermal_gap(self) -> float:
        return float(self._rng_thermal.exponential(
            self.config.thermal_mtbf_s))

    def sample_thermal_window(self) -> float:
        return float(self._rng_thermal.exponential(
            max(self.config.thermal_duration_s, 1e-6)))

    # -- engine-facing hooks -------------------------------------------
    def note_disruption(self, t: float) -> None:
        if t > self.last_disruption_t:
            self.last_disruption_t = t

    def disrupted_since(self, t: float) -> bool:
        """Did any fault touch this node at or after virtual time ``t``
        (telemetry-window staleness test for policies)?"""
        return self.last_disruption_t >= t

    def filter_set_frequency(self, f: float
                             ) -> Tuple[Optional[float], float]:
        """Actuation filter applied inside ``engine.set_frequency``:
        returns ``(effective_frequency_or_None, extra_stall_s)``. None
        means the call was silently lost (stuck actuator). A thermal
        throttle clamps whatever does land."""
        c = self.config
        extra = 0.0
        if not self.bypass and (c.dvfs_stick_prob > 0.0
                                or c.dvfs_lag_s > 0.0):
            u = float(self._rng_dvfs.random())
            if u < c.dvfs_stick_prob:
                self.sticks += 1
                return None, 0.0
            if c.dvfs_lag_s > 0.0:
                self.lags += 1
                extra = c.dvfs_lag_s
        if self.thermal_cap_mhz is not None:
            f = min(f, self.thermal_cap_mhz)
        return f, extra

    def scrape_dropped(self, now: float) -> bool:
        """One telemetry scrape attempt: True if it failed (blank
        window). Consumes the node's telemetry stream only when dropouts
        are configured, so the healthy path stays stream-silent."""
        c = self.config
        if c.telemetry_drop_prob <= 0.0 or self.down:
            return False
        if float(self._rng_telemetry.random()) < c.telemetry_drop_prob:
            self.scrape_drops += 1
            self.note_disruption(now)
            return True
        return False


@dataclasses.dataclass
class FaultAction:
    """One due fault transition popped by the event loop."""
    t: float
    node: int
    kind: str          # "crash" | "recover" | "thermal-on" | "thermal-off"
    cap_mhz: Optional[float] = None    # thermal-on payload


class FaultModel:
    """Seeded fault-event source for the event loop (router-pattern:
    ``next_time()`` / ``pop_due(t)``), plus the retry/re-route state the
    crash path needs.

    Bind it to a set of nodes once (``bind``); binding attaches a
    :class:`NodeFaultState` to every engine and seeds each node's first
    onset events. The model outlives a single ``EventLoop`` the same way
    the delivery schedule does, so repeated drains keep consuming one
    coherent fault timeline.
    """

    def __init__(self, config: Optional[FaultConfig] = None, *,
                 seed: int = 0, **overrides):
        if config is None:
            config = FaultConfig(**overrides)
        elif overrides:
            config = dataclasses.replace(config, **overrides)
        self.config = config
        self.seed = seed
        self.states: List[NodeFaultState] = []
        self._engines: Optional[List[object]] = None
        self._heap: List[Tuple[float, int, int, FaultAction]] = []
        self._seq = 0
        #: optional richer re-route target picker installed by
        #: ServingCluster: ``route(engines, request, up_mask) -> idx``
        self.route = None
        #: optional NetworkModel pricing re-route deliveries (hops +
        #: router queueing on top of the backoff delay)
        self.network = None
        # aggregate accounting (per-node detail lives on the states)
        self.crashes = 0
        self.recoveries = 0
        self.thermal_events = 0
        self.reroutes = 0
        self.retries = 0
        self.dropped: List[object] = []     # retry-budget-exhausted

    @classmethod
    def from_spec(cls, spec: str, *, seed: int = 0) -> "FaultModel":
        """Build from a preset name or the clause grammar (module
        docstring)."""
        return cls(parse_fault_spec(spec), seed=seed)

    # ------------------------------------------------------------------
    @property
    def active(self) -> bool:
        return self.config.any_active

    @property
    def drops(self) -> int:
        return len(self.dropped)

    def bind(self, engines: Sequence[object]) -> None:
        """Attach per-node fault state and seed first onset events.
        Idempotent for the same engine list (ServingCluster binds at
        construction; a direct EventLoop user may rebind harmlessly)."""
        engines = list(engines)
        if self._engines is not None:
            if [id(e) for e in engines] == [id(e) for e in self._engines]:
                return
            raise ValueError("FaultModel is already bound to a different "
                             "engine set; build one model per cluster")
        self._engines = engines
        c = self.config
        for i, eng in enumerate(engines):
            st = NodeFaultState(i, c, self.seed)
            self.states.append(st)
            eng.fault_state = st
            if c.crash_mttf_s > 0.0:
                self._push(st.sample_crash_gap(), FaultAction(
                    0.0, i, "crash"))
            if c.thermal_mtbf_s > 0.0:
                self._push(st.sample_thermal_gap(), FaultAction(
                    0.0, i, "thermal-on"))

    def _push(self, t: float, action: FaultAction) -> None:
        action.t = t
        heapq.heappush(self._heap, (t, self._seq, action.node, action))
        self._seq += 1

    # -- event-source surface (router pattern) -------------------------
    def next_time(self) -> Optional[float]:
        return self._heap[0][0] if self._heap else None

    def next_is_onset(self) -> bool:
        """Whether the head action starts a fault (NODE_FAULT) rather
        than ends one (NODE_RECOVER) — the loop labels its heap entry
        accordingly."""
        return bool(self._heap) and self._heap[0][3].kind in ONSET_ACTIONS

    def pop_due(self, t: float) -> List[FaultAction]:
        """All fault transitions due at or before ``t``, applying state
        flips and scheduling each consequence (repair after crash, next
        onset after repair) from the node's own streams."""
        out: List[FaultAction] = []
        while self._heap and self._heap[0][0] <= t:
            due, _, _, action = heapq.heappop(self._heap)
            st = self.states[action.node]
            kind = action.kind
            if kind == "crash":
                if st.down:          # already dark (overlap): reschedule
                    continue
                st.down = True
                st.crashes += 1
                self.crashes += 1
                st.note_disruption(due)
                self._push(due + st.sample_repair(),
                           FaultAction(0.0, action.node, "recover"))
            elif kind == "recover":
                st.down = False
                self.recoveries += 1
                st.note_disruption(due)
                self._push(due + st.sample_crash_gap(),
                           FaultAction(0.0, action.node, "crash"))
            elif kind == "thermal-on":
                cap = self._thermal_cap()
                st.thermal_cap_mhz = cap
                st.thermal_events += 1
                self.thermal_events += 1
                st.note_disruption(due)
                action.cap_mhz = cap
                self._push(due + st.sample_thermal_window(),
                           FaultAction(0.0, action.node, "thermal-off"))
            elif kind == "thermal-off":
                st.thermal_cap_mhz = None
                st.note_disruption(due)
                self._push(due + st.sample_thermal_gap(),
                           FaultAction(0.0, action.node, "thermal-on"))
            out.append(action)
        return out

    def _thermal_cap(self) -> float:
        """Thermal frequency cap in MHz (requires a bound engine for the
        hardware envelope)."""
        hw = self._engines[0].hardware
        cap = self.config.thermal_cap_frac * hw.f_max
        return float(min(max(cap, hw.f_min), hw.f_max))

    # -- crash re-route support ----------------------------------------
    def backoff_delay(self, attempt: int) -> float:
        """Exponential backoff for re-route attempt ``attempt`` (0-based)."""
        return self.config.retry_backoff_s * (2.0 ** attempt)

    def pick_node(self, engines: Sequence[object], request) -> int:
        """Re-route target: the installed cluster router over up nodes,
        else the least-loaded up node; falls back to the least-loaded
        node overall when the whole fleet is dark (the retry will bounce
        with backoff until a recovery or the budget runs out)."""
        up = [i for i, st in enumerate(self.states) if not st.down]
        pool = up if up else list(range(len(engines)))
        if self.route is not None and up:
            idx = self.route(engines, request, up)
            if idx in up:
                return idx
        return min(pool, key=lambda i: (
            engines[i].sched.num_running() + engines[i].sched.num_waiting()
            + engines[i].num_pending))

    def counters(self) -> Dict[str, int]:
        """Aggregate fault accounting for summaries/benchmarks."""
        return {
            "crashes": self.crashes,
            "recoveries": self.recoveries,
            "thermal_events": self.thermal_events,
            "reroutes": self.reroutes,
            "retries": self.retries,
            "dropped_retry": self.drops,
            "dvfs_sticks": sum(s.sticks for s in self.states),
            "dvfs_lags": sum(s.lags for s in self.states),
            "telemetry_drops": sum(s.scrape_drops for s in self.states),
        }
