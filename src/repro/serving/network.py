"""Routing-path network model: requests no longer teleport to engines.

The discrete-event core (``repro.serving.driver``) historically placed a
routed request into its engine's arrival heap at ``submit`` time — the
request materialized at the node the instant the client emitted it. Real
clusters interpose a routing path: a client→router hop, FIFO queueing at
the router (one dispatch pipeline, finite service rate), and a
router→node hop. SLO-aware DVFS work (GreenLLM, arXiv:2508.16449;
switching-aware bandits, arXiv:2410.11855) shows the telemetry a tuner
sees — queue depths, TTFT pressure — shifts materially once that delay
exists, so the event core must model it to evaluate policies honestly.

:class:`NetworkModel` prices the path per request, deterministically:

    t_router  = arrival + hop()                (client -> router)
    t_dispatch= max(t_router, router_free) + router_service_s
    delivery  = t_dispatch + hop()             (router -> node)

``hop()`` samples the configured per-hop latency distribution
(``constant`` / ``uniform`` / ``lognormal``) from a seeded
``numpy.random.default_rng`` stream consumed in submit order, so a given
(trace, seed) always prices identically. Router queueing is closed-form
FIFO (``router_free`` carries the dispatch pipe's busy horizon), so burst
arrivals see queue waits even when hops are constant.

A zero-configured model (all latencies 0) prices every request at its
arrival time exactly — ``delivery == arrival`` bit-for-bit — which is the
equivalence the property suite pins: routing through the network event
path with zero delay is byte-identical to direct submit.

:class:`DeliverySchedule` is the router's event-source half: the priced
``(delivery_time, node, request)`` entries live in ITS heap, and the
event loop pops them as ``ROUTE`` events — arrivals are *rescheduled*
onto engines at delivery time instead of placed at submit time. The
schedule outlives a single ``EventLoop`` (``run_until``-style repeated
drains keep consuming it).
"""
from __future__ import annotations

import dataclasses
import heapq
import itertools
import math
from typing import Dict, List, Optional, Tuple

import numpy as np

#: distribution names accepted by :class:`NetworkModel`
DISTRIBUTIONS = ("constant", "uniform", "lognormal")


@dataclasses.dataclass(frozen=True)
class NetworkConfig:
    """Static description of one routing path (all times in seconds)."""
    #: mean one-way per-hop latency; two hops per request (client->router,
    #: router->node). 0 disables hop delay entirely.
    hop_latency_s: float = 0.0
    #: per-request router service time — the FIFO dispatch pipe; bursts
    #: arriving faster than 1/service queue up. 0 disables queueing.
    router_service_s: float = 0.0
    #: per-hop latency distribution: "constant" | "uniform" | "lognormal"
    distribution: str = "constant"
    #: dispersion as a fraction of the mean: uniform half-width or
    #: lognormal coefficient of variation. Ignored by "constant".
    jitter: float = 0.0

    @property
    def mean_delay_s(self) -> float:
        """Expected unqueued routing delay (two hops + one service)."""
        return 2.0 * self.hop_latency_s + self.router_service_s


#: named calibrations for the CLI / benchmarks (mean end-to-end routing
#: delay in parentheses): "zero" is the equivalence configuration, the
#: others bracket same-rack to cross-region serving.
PRESETS: Dict[str, NetworkConfig] = {
    "zero": NetworkConfig(),
    "lan": NetworkConfig(hop_latency_s=150e-6, router_service_s=50e-6,
                         distribution="lognormal", jitter=0.3),   # ~350 us
    "datacenter": NetworkConfig(hop_latency_s=2.5e-3,
                                router_service_s=200e-6,
                                distribution="lognormal",
                                jitter=0.4),                      # ~5 ms
    "wan": NetworkConfig(hop_latency_s=24e-3, router_service_s=200e-6,
                         distribution="lognormal", jitter=0.25),  # ~50 ms
}


class NetworkModel:
    """Seeded, stateful pricer of the routing path (see module docstring).

    One instance prices one cluster's ingress in submit order; the hop
    RNG stream and the router-queue horizon are the only state, so two
    models constructed with identical config+seed price identical traces
    identically — the determinism every golden/property test leans on.
    """

    def __init__(self, config: Optional[NetworkConfig] = None, *,
                 seed: int = 0, **overrides):
        if config is None:
            config = NetworkConfig(**overrides)
        elif overrides:
            config = dataclasses.replace(config, **overrides)
        if config.distribution not in DISTRIBUTIONS:
            raise ValueError(
                f"unknown distribution {config.distribution!r}; choose "
                f"from {', '.join(DISTRIBUTIONS)}")
        if config.hop_latency_s < 0 or config.router_service_s < 0:
            raise ValueError("network latencies must be >= 0")
        self.config = config
        self.seed = seed
        self._rng = np.random.default_rng(seed)
        self._router_free = 0.0          # dispatch pipe busy horizon
        # lognormal(mu, sigma) parameterized to the configured mean/cv
        cv = max(config.jitter, 0.0)
        self._ln_sigma = math.sqrt(math.log1p(cv * cv))
        self._ln_mu = (math.log(config.hop_latency_s)
                       - 0.5 * self._ln_sigma ** 2
                       if config.hop_latency_s > 0 else 0.0)

    @classmethod
    def from_spec(cls, spec: str, *, seed: int = 0) -> "NetworkModel":
        """Build from a CLI spec: a preset name (``zero``/``lan``/
        ``datacenter``/``wan``) or ``fixed:<millis>`` for a constant
        total routing delay of ``<millis>`` ms."""
        if spec in PRESETS:
            return cls(PRESETS[spec], seed=seed)
        if spec.startswith("fixed:"):
            ms = float(spec.split(":", 1)[1])
            if ms < 0:
                raise ValueError("fixed network delay must be >= 0")
            return cls(NetworkConfig(hop_latency_s=ms * 1e-3 / 2.0),
                       seed=seed)
        raise ValueError(f"unknown network spec {spec!r}; presets: "
                         f"{', '.join(sorted(PRESETS))} or fixed:<ms>")

    # ------------------------------------------------------------------
    def _hop(self) -> float:
        c = self.config
        if c.hop_latency_s <= 0.0:
            return 0.0
        if c.distribution == "constant" or c.jitter <= 0.0:
            return c.hop_latency_s
        if c.distribution == "uniform":
            half = c.jitter * c.hop_latency_s
            return float(self._rng.uniform(
                max(c.hop_latency_s - half, 0.0), c.hop_latency_s + half))
        return float(self._rng.lognormal(self._ln_mu, self._ln_sigma))

    def delivery_time(self, arrival_time: float) -> float:
        """Price one request's routing path; advances the router-queue
        horizon. Call in submit (arrival) order. With a zero-configured
        model this returns ``arrival_time`` exactly."""
        c = self.config
        if c.hop_latency_s <= 0.0 and c.router_service_s <= 0.0:
            return arrival_time          # exact: no float detour
        t_router = arrival_time + self._hop()
        if c.router_service_s > 0.0:
            start = max(t_router, self._router_free)
            self._router_free = start + c.router_service_s
            t_router = self._router_free
        return t_router + self._hop()


class DeliverySchedule:
    """The router's event-source heap: priced deliveries awaiting their
    ROUTE event. ``repro.serving.driver.EventLoop`` pops due entries and
    hands each request to its engine at delivery time."""

    def __init__(self):
        self._heap: List[Tuple[float, int, int, object]] = []
        self._seq = itertools.count()    # FIFO among equal delivery times

    def __len__(self) -> int:
        return len(self._heap)

    def push(self, delivery_time: float, node_index: int,
             request) -> None:
        heapq.heappush(self._heap, (delivery_time, next(self._seq),
                                    node_index, request))

    def next_time(self) -> Optional[float]:
        return self._heap[0][0] if self._heap else None

    def first_time_per_node(self) -> Dict[int, float]:
        """Earliest scheduled delivery per node index — the event loop
        anchors a node's POLICY_TICK train where the node first gets
        work, matching the direct path's first-arrival anchor."""
        first: Dict[int, float] = {}
        for t, _, node, _ in self._heap:
            if node not in first or t < first[node]:
                first[node] = t
        return first

    def extract_node(self, node_index: int) -> List[Tuple[float, object]]:
        """Remove and return every in-flight delivery addressed to
        ``node_index``, in (delivery time, submit) order — the crash path
        (``repro.serving.faults``): requests still traversing the network
        toward a node that just died are pulled back and re-routed
        instead of delivered into the void. The surviving entries keep
        their order exactly."""
        if not self._heap:
            return []
        mine = [(t, s, req) for t, s, node, req in self._heap
                if node == node_index]
        if not mine:
            return []
        keep = [e for e in self._heap if e[2] != node_index]
        self._heap = keep
        heapq.heapify(keep)
        mine.sort()
        return [(t, req) for t, _, req in mine]

    def pop_due(self, t: float) -> List[Tuple[int, object]]:
        """All deliveries with ``delivery_time <= t``, in (time, submit)
        order — one ROUTE event delivers every request due at its
        instant, so a node's revival event is never scheduled between
        two same-time deliveries."""
        out = []
        while self._heap and self._heap[0][0] <= t:
            _, _, node, req = heapq.heappop(self._heap)
            out.append((node, req))
        return out
