"""The shared drive loop: one event-driven driver for every serving shape.

``InferenceEngine.run_until/drain`` and ``ServingCluster.drain`` used to
carry three copies of the same "step, then let the frequency authority
act" loop — with the cluster variant paying an O(n) ``engines.index``
lookup per step to find its tuner. This module unifies them: engines are
paired with their (optional) policy in an :class:`EngineNode`, and
:func:`drive` advances the laggard node (min simulated clock, via a heap —
O(log n) per step) until no work remains, invoking each node's attached
policy after its step. Nodes are independent simulations, so stepping the
laggard preserves causality; heterogeneous per-node policies are free.
"""
from __future__ import annotations

import dataclasses
import heapq
from typing import Optional, Sequence


@dataclasses.dataclass
class EngineNode:
    """An engine paired with the power policy that governs it (or None)."""
    engine: object                      # InferenceEngine
    policy: Optional[object] = None     # PowerPolicy


def drive(nodes: Sequence[EngineNode], *, t_end: Optional[float] = None,
          max_iters: int = 10_000_000) -> int:
    """Advance ``nodes`` in lock-step on the slowest clock.

    Each pop steps the laggard engine once and gives its policy a chance
    to act (``policy.maybe_act(engine)``). A node leaves the loop when it
    runs out of work or its clock reaches ``t_end``. Returns the number of
    engine steps executed.
    """
    heap = []
    for i, node in enumerate(nodes):
        if node.engine.has_work:
            heapq.heappush(heap, (node.engine.clock, i))
    it = 0
    while heap and it < max_iters:
        _, i = heapq.heappop(heap)
        node = nodes[i]
        eng = node.engine
        if not eng.has_work or (t_end is not None and eng.clock >= t_end):
            continue
        eng.step()
        if node.policy is not None:
            node.policy.maybe_act(eng)
        it += 1
        heapq.heappush(heap, (eng.clock, i))
    return it
