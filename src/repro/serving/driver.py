"""The discrete-event serving core: one event loop for every serving shape.

``InferenceEngine.run_until/drain`` and ``ServingCluster.drain`` used to
carry three copies of the same "step, then let the frequency authority
act" loop, later unified into a heap of engine *clocks*. This module grows
that into a proper discrete-event simulation: the heap holds typed, timed
events —

``ARRIVAL``          an idle engine's next request lands; the engine
                     idle-advances (billing idle energy) and iterates
``ITERATION``        an engine with schedulable work runs one
                     continuous-batching iteration, then its per-node
                     policy gets the iteration-complete callback
``FLEET_TICK``       a fleet-scope policy (:class:`repro.policies.fleet.
                     FleetPolicy`) samples aggregated telemetry on its own
                     cadence — the policy-tick event per-node controllers
                     don't need (their monitors gate on the engine clock
                     at iteration boundaries, which keeps decision
                     sequences bit-identical to the pre-event-loop driver)

Hierarchical power capping rides on FLEET_TICK (``repro.policies.
hierarchy``): when the fleet policy declares ``coordinates_bands``, the
loop propagates its per-node ``bands`` after every tick — calling each
node policy's optional ``set_band(f_lo, f_hi)`` hook and clamping the
engine's current frequency into the band, so a band that excludes the
running frequency forces an immediate DVFS transition, billed like any
other. When the fleet policy declares ``power_cap_w``, the loop also
meters fleet draw between consecutive ticks into ``cap_violation_s`` /
``metered_s`` / ``peak_fleet_power_w`` (budget accounting surfaced by
``ServingCluster.summary``).

Each node event is keyed by the engine's ``next_event_time()`` — the next
instant it actually does anything — so idle nodes cost nothing until their
next arrival, and the loop's virtual ``now`` (min over scheduled events)
is a coherent global timeline for fleet controllers. Nodes are independent
simulations, so per-node trajectories are identical to the old
laggard-clock loop; only the interleaving (and hence where fleet ticks can
see the fleet) changes. O(log n) per event; heterogeneous per-node
policies and a cluster-global controller are both free.
"""
from __future__ import annotations

import dataclasses
import enum
import heapq
import itertools
from typing import Dict, List, Optional, Sequence

#: FLEET_TICK cadence (sim-seconds) when the fleet policy doesn't declare
#: ``sampling_period_s`` — matches the paper's sub-second telemetry window.
DEFAULT_FLEET_TICK_PERIOD_S = 0.8


class EventKind(enum.IntEnum):
    """What a scheduled event will do when it fires."""
    ARRIVAL = 0        # idle engine: next request lands, then it iterates
    ITERATION = 1      # engine with schedulable work runs one iteration
    FLEET_TICK = 2     # fleet-scope policy samples aggregated telemetry


@dataclasses.dataclass
class EngineNode:
    """An engine paired with the power policy that governs it (or None)."""
    engine: object                      # InferenceEngine
    policy: Optional[object] = None     # PowerPolicy (node scope)


class EventLoop:
    """Event-scheduled driver over a set of :class:`EngineNode`.

    Exactly one event is outstanding per live node; firing it advances the
    engine one step (``engine.step()`` — idle-advance and/or iteration),
    invokes the node's policy, and reschedules at the engine's next event
    time. ``fleet_policy`` (optional) receives ``act(engines, now)`` ticks
    every ``fleet_policy.sampling_period_s`` sim-seconds while any node is
    live. A node leaves the loop when it drains or its clock reaches
    ``t_end``; ``run`` returns the number of engine steps executed.
    """

    def __init__(self, nodes: Sequence[EngineNode], *,
                 fleet_policy: Optional[object] = None,
                 t_end: Optional[float] = None,
                 max_iters: int = 10_000_000):
        self.nodes = list(nodes)
        self.fleet_policy = fleet_policy
        # resolved once; the loop never re-reads the policy attribute
        self._fleet_period = getattr(fleet_policy, "sampling_period_s",
                                     DEFAULT_FLEET_TICK_PERIOD_S)
        self.t_end = t_end
        self.max_iters = max_iters
        self.now = 0.0                       # virtual time, never decreases
        self.steps = 0
        self.counts: Dict[EventKind, int] = {k: 0 for k in EventKind}
        # power-budget accounting (active when the fleet policy declares a
        # cap; see repro.policies.hierarchy)
        self._power_cap = getattr(fleet_policy, "power_cap_w", None)
        self.cap_violation_s = 0.0
        self.metered_s = 0.0
        self.metered_energy_j = 0.0
        self.peak_fleet_power_w = 0.0
        self._seq = itertools.count()        # FIFO tie-break at equal times
        self._heap: List[tuple] = []
        self._live = 0
        for i in range(len(self.nodes)):
            if self._schedule_node(i):
                self._live += 1
        self._meter_t = 0.0
        self._meter_e = 0.0
        if fleet_policy is not None and self._live:
            start = min(t for t, _, _, _ in self._heap)
            self._meter_t = start
            self._meter_e = self._fleet_energy_j()
            # a band coordinator can cap the fleet from t=0, before any
            # telemetry exists — ask it for initial bands
            init = getattr(fleet_policy, "initial_bands", None)
            if init is not None:
                self._propagate_bands(init(self.engines))
            self._push(start + self._fleet_period, EventKind.FLEET_TICK, -1)

    # ------------------------------------------------------------------
    @property
    def engines(self) -> List[object]:
        return [n.engine for n in self.nodes]

    def _push(self, t: float, kind: EventKind, node: int) -> None:
        heapq.heappush(self._heap, (t, next(self._seq), kind, node))

    def _schedule_node(self, i: int) -> bool:
        """Schedule node ``i``'s next event; False if it has drained."""
        eng = self.nodes[i].engine
        t = eng.next_event_time()
        if t is None:
            return False
        kind = (EventKind.ITERATION if eng.sched.has_work
                else EventKind.ARRIVAL)
        self._push(t, kind, i)
        return True

    # -- hierarchical power capping (repro.policies.hierarchy) ---------
    def _propagate_bands(self, bands) -> None:
        """Deliver per-node frequency bands: hand each band to the node
        policy's optional ``set_band`` hook and clamp the engine's current
        frequency into it — a band excluding the running frequency forces
        a move, billed as a DVFS transition like any other."""
        if not bands:
            return
        for node, band in zip(self.nodes, bands):
            if band is None:
                continue
            lo, hi = band
            if lo > hi:
                lo, hi = hi, lo
            set_band = getattr(node.policy, "set_band", None)
            if set_band is not None:
                set_band(lo, hi)
            eng = node.engine
            f = min(max(eng.frequency, lo), hi)
            if f != eng.frequency:
                eng.set_frequency(f)

    def _fleet_energy_j(self) -> float:
        return sum(n.engine.metrics.c.energy_joules_total
                   for n in self.nodes)

    def _meter_power(self, t: float) -> None:
        """Budget accounting between consecutive FLEET_TICKs: mean fleet
        draw over the interval, peak tracking, and seconds spent above
        the declared cap."""
        if self._power_cap is None:
            return
        e = self._fleet_energy_j()
        if t > self._meter_t:
            dt = t - self._meter_t
            de = e - self._meter_e
            p = de / dt
            self.metered_s += dt
            self.metered_energy_j += de
            if p > self.peak_fleet_power_w:
                self.peak_fleet_power_w = p
            if p > self._power_cap:
                self.cap_violation_s += dt
        self._meter_t, self._meter_e = t, e

    @property
    def mean_fleet_power_w(self) -> float:
        return (self.metered_energy_j / self.metered_s
                if self.metered_s > 0 else 0.0)

    # ------------------------------------------------------------------
    def _run_single(self) -> int:
        """Single node, no fleet policy — the overwhelmingly common shape
        (every benchmark cell): exactly one event is ever outstanding, so
        the loop re-derives it inline instead of round-tripping the heap.
        Trajectories, step counts, ``now`` and event counts are identical
        to the general loop."""
        node = self.nodes[0]
        eng = node.engine
        policy = node.policy
        sched = eng.sched
        t_end = self.t_end
        counts = self.counts
        self._heap.clear()               # constructor's seed event, inlined
        while self.steps < self.max_iters:
            if sched.waiting or sched.running:
                kind = EventKind.ITERATION
                t = eng.clock
            elif eng._pending:
                kind = EventKind.ARRIVAL
                t = eng._pending[0][0]
            else:
                break                    # drained
            if t > self.now:
                self.now = t
            if t_end is not None and eng.clock >= t_end:
                break
            eng.step()
            if policy is not None:
                policy.maybe_act(eng)
            self.steps += 1
            counts[kind] += 1
        return self.steps

    def run(self) -> int:
        if len(self.nodes) == 1 and self.fleet_policy is None:
            return self._run_single()
        t_end = self.t_end
        while self._heap and self.steps < self.max_iters:
            t, _, kind, i = heapq.heappop(self._heap)
            if t > self.now:
                self.now = t

            if kind is EventKind.FLEET_TICK:
                if self._live == 0:
                    continue                       # fleet dies with nodes
                self.fleet_policy.act(self.engines, t)
                self._propagate_bands(getattr(self.fleet_policy, "bands",
                                              None))
                self._meter_power(t)
                self.counts[kind] += 1
                nxt = t + self._fleet_period
                if t_end is None or nxt < t_end:
                    self._push(nxt, EventKind.FLEET_TICK, -1)
                continue

            node = self.nodes[i]
            eng = node.engine
            if not eng.has_work or (t_end is not None
                                    and eng.clock >= t_end):
                self._live -= 1
                continue
            eng.step()
            if node.policy is not None:
                node.policy.maybe_act(eng)
            self.steps += 1
            self.counts[kind] += 1
            if not self._schedule_node(i):
                self._live -= 1
        if self.fleet_policy is not None:
            # final flush: the drain tail past the last FLEET_TICK must be
            # metered too, or cap violations there would go uncounted
            self._meter_power(max([self.now]
                                  + [n.engine.clock for n in self.nodes]))
        return self.steps


def drive(nodes: Sequence[EngineNode], *, t_end: Optional[float] = None,
          max_iters: int = 10_000_000,
          fleet_policy: Optional[object] = None) -> int:
    """Advance ``nodes`` through the shared event loop until no work
    remains (or ``t_end``/``max_iters``); returns engine steps executed.
    Thin facade over :class:`EventLoop` for the common one-shot case."""
    return EventLoop(nodes, fleet_policy=fleet_policy, t_end=t_end,
                     max_iters=max_iters).run()
