"""The discrete-event serving core: one event loop for every serving shape.

``InferenceEngine.run_until/drain`` and ``ServingCluster.drain`` used to
carry three copies of the same "step, then let the frequency authority
act" loop, later unified into a heap of engine *clocks*. This module grows
that into a proper discrete-event simulation: the heap holds typed, timed
events —

``ARRIVAL``          an idle engine's next request lands; the engine
                     idle-advances (billing idle energy) and iterates
``ITERATION``        an engine with schedulable work runs one
                     continuous-batching iteration, then its per-node
                     policy gets the iteration-complete callback
``FLEET_TICK``       a fleet-scope policy (:class:`repro.policies.fleet.
                     FleetPolicy`) samples aggregated telemetry on its own
                     cadence
``ROUTE``            the router's dispatch pipe (:class:`repro.serving.
                     network.DeliverySchedule`) delivers priced requests
                     to their engines: an arrival is *rescheduled* from
                     its submit-time placement to its network delivery
                     time. A delivery that lands earlier than a node's
                     outstanding event supersedes it (per-node event
                     versioning), and a drained node is revived — the
                     router is a first-class event source, not a
                     pre-drain bulk load
``POLICY_TICK``      per-node policy decision on a wall-clock cadence
                     (``policy_tick_mode="tick"``): telemetry windows are
                     cut at the tick's virtual time, decoupling decision
                     boundaries from iteration boundaries. The default
                     mode (``"iteration"``) keeps the historical
                     behavior — policies gate on the engine clock at
                     iteration boundaries — which stays bit-identical to
                     the pre-event-loop driver (the committed golden
                     trajectory); pure-tick trajectories are pinned by
                     their own golden (``tests/golden_agft_decisions_
                     tick.json``)
``NODE_FAULT`` /     a bound :class:`repro.serving.faults.FaultModel`'s
``NODE_RECOVER``     next transition fires: node crashes (in-flight and
                     queued work evacuated and re-routed with exponential
                     backoff under a bounded retry budget), recoveries
                     (the node rejoins the loop, clock advanced without
                     billing the outage), and thermal throttle flips
                     (the running frequency is force-clamped under the
                     cap and the governing band becomes the intersection
                     of the coordinator band with the thermal envelope).
                     With no fault model — or an all-zero config — none
                     of these paths execute and the loop is byte-
                     identical to the healthy simulation

Hierarchical power capping rides on FLEET_TICK (``repro.policies.
hierarchy``): when the fleet policy declares ``coordinates_bands``, the
loop propagates its per-node ``bands`` after every tick — calling each
node policy's optional ``set_band(f_lo, f_hi)`` hook and clamping the
engine's current frequency into the band, so a band that excludes the
running frequency forces an immediate DVFS transition, billed like any
other. When the fleet policy declares ``power_cap_w``, the loop also
meters fleet draw between consecutive ticks into ``cap_violation_s`` /
``metered_s`` / ``peak_fleet_power_w`` (budget accounting surfaced by
``ServingCluster.summary``).

Each node event is keyed by the engine's ``next_event_time()`` — the next
instant it actually does anything — so idle nodes cost nothing until their
next arrival, and the loop's virtual ``now`` (min over scheduled events)
is a coherent global timeline for fleet controllers and the router. At
equal times, ROUTE events outrank node events (a delivery due at *t* is
visible to an iteration at *t*, exactly as an already-placed arrival
would be); everything else stays FIFO. Nodes are independent simulations,
so per-node trajectories are identical to the old laggard-clock loop;
only the interleaving (and hence where fleet ticks can see the fleet)
changes. O(log n) per event; heterogeneous per-node policies, a
cluster-global controller, and a delayed routing path are all free.
"""
from __future__ import annotations

import dataclasses
import enum
import heapq
import itertools
from typing import Dict, List, Optional, Sequence, Tuple

from repro.serving.request import RequestState

#: FLEET_TICK cadence (sim-seconds) when the fleet policy doesn't declare
#: ``sampling_period_s`` — matches the paper's sub-second telemetry window.
DEFAULT_FLEET_TICK_PERIOD_S = 0.8

#: POLICY_TICK cadence when a node policy declares no sampling period of
#: its own (same sub-second window as the fleet default).
DEFAULT_POLICY_TICK_PERIOD_S = 0.8

#: valid ``policy_tick_mode`` values: ``"iteration"`` invokes node
#: policies after every engine step (monitors gate on the engine clock —
#: the golden-pinned historical behavior); ``"tick"`` schedules per-node
#: POLICY_TICK events on the policy's sampling period instead.
POLICY_TICK_MODES = ("iteration", "tick")


class EventKind(enum.IntEnum):
    """What a scheduled event will do when it fires."""
    ARRIVAL = 0        # idle engine: next request lands, then it iterates
    ITERATION = 1      # engine with schedulable work runs one iteration
    FLEET_TICK = 2     # fleet-scope policy samples aggregated telemetry
    ROUTE = 3          # router delivers in-flight requests to engines
    POLICY_TICK = 4    # node policy decides on a wall-clock cadence
    NODE_FAULT = 5     # fault model: crash / thermal-throttle onset
    NODE_RECOVER = 6   # fault model: repair / throttle release


@dataclasses.dataclass
class EngineNode:
    """An engine paired with the power policy that governs it (or None)."""
    engine: object                      # InferenceEngine
    policy: Optional[object] = None     # PowerPolicy (node scope)


def _policy_period(policy) -> float:
    """A node policy's decision cadence: its monitor's sampling period
    (WindowedPolicy, AGFTTuner), a bare ``sampling_period_s`` attribute,
    or the sub-second default."""
    monitor = getattr(policy, "monitor", None)
    period = getattr(monitor, "sampling_period_s", None)
    if period is None:
        period = getattr(policy, "sampling_period_s", None)
    return float(period) if period else DEFAULT_POLICY_TICK_PERIOD_S


class EventLoop:
    """Event-scheduled driver over a set of :class:`EngineNode`.

    At most one node event is outstanding per live node; firing it
    advances the engine one step (``engine.step()`` — idle-advance and/or
    iteration), invokes the node's policy (iteration mode), and
    reschedules at the engine's next event time. ``fleet_policy``
    (optional) receives ``act(engines, now)`` ticks every
    ``fleet_policy.sampling_period_s`` sim-seconds while any node is live
    or deliveries are in flight. ``router`` (optional, a
    :class:`repro.serving.network.DeliverySchedule`) feeds ROUTE events:
    deliveries land in engine arrival heaps at their priced network
    delivery times, superseding stale node events and reviving drained
    nodes. ``policy_tick_mode="tick"`` moves node-policy decisions onto
    per-node POLICY_TICK events (windows cut at tick time). A node leaves
    the loop when it drains or its clock reaches ``t_end``; ``run``
    returns the number of engine steps executed.
    """

    def __init__(self, nodes: Sequence[EngineNode], *,
                 fleet_policy: Optional[object] = None,
                 t_end: Optional[float] = None,
                 max_iters: int = 10_000_000,
                 router: Optional[object] = None,
                 policy_tick_mode: str = "iteration",
                 fault_model: Optional[object] = None):
        if policy_tick_mode not in POLICY_TICK_MODES:
            raise ValueError(
                f"policy_tick_mode must be one of {POLICY_TICK_MODES}, "
                f"got {policy_tick_mode!r}")
        self.nodes = list(nodes)
        self.fleet_policy = fleet_policy
        self.router = router
        self.policy_tick_mode = policy_tick_mode
        # resolved once; the loop never re-reads the policy attribute
        self._fleet_period = getattr(fleet_policy, "sampling_period_s",
                                     DEFAULT_FLEET_TICK_PERIOD_S)
        self.t_end = t_end
        self.max_iters = max_iters
        self.now = 0.0                       # virtual time, never decreases
        self.steps = 0
        self.counts: Dict[EventKind, int] = {k: 0 for k in EventKind}
        # power-budget accounting (active when the fleet policy declares a
        # cap; see repro.policies.hierarchy)
        self._power_cap = getattr(fleet_policy, "power_cap_w", None)
        self.cap_violation_s = 0.0
        self.metered_s = 0.0
        self.metered_energy_j = 0.0
        self.peak_fleet_power_w = 0.0
        self._seq = itertools.count()        # FIFO tie-break at equal times
        self._heap: List[tuple] = []
        # per-node scheduling state: time of the outstanding event (None
        # when the node holds no event) and its version — a delivery that
        # reschedules a node bumps the version, orphaning the heap entry
        self._sched_t: List[Optional[float]] = [None] * len(self.nodes)
        self._ver: List[int] = [0] * len(self.nodes)
        self._live = 0
        for i in range(len(self.nodes)):
            if self._schedule_node(i):
                self._live += 1
        # fault injection (repro.serving.faults): an ACTIVE model turns
        # the loop into a NODE_FAULT/NODE_RECOVER consumer; an absent or
        # all-zero model leaves every healthy path byte-identical
        self.faults = None
        #: the coordinator's last per-node bands, remembered so a thermal
        #: release can restore them after the throttle intersection
        self._coord_band: List[Optional[Tuple[float, float]]] = \
            [None] * len(self.nodes)
        #: observation hook called once per popped event, BEFORE it is
        #: applied (i.e. after the previous event fully settled):
        #: ``on_event(loop, kind, t)`` — the conservation property test
        #: audits request accounting at every step through it
        self.on_event = None
        self._route_t: Optional[float] = None    # earliest armed ROUTE
        self._route_ver = 0     # orphans superseded ROUTE events (faults)
        if fault_model is not None and fault_model.active:
            fault_model.bind(self.engines)
            self.faults = fault_model
            if self.router is None:
                # crash evacuation re-routes through a delivery schedule
                # even when no network model is configured
                from repro.serving.network import DeliverySchedule
                self.router = DeliverySchedule()
            self._arm_fault_event()
        if self.router is not None:
            nxt = self.router.next_time()
            if nxt is not None and (t_end is None or nxt < t_end):
                self._push(nxt, EventKind.ROUTE, -1)
                self._route_t = nxt
        self._meter_t = 0.0
        self._meter_e = 0.0
        if fleet_policy is not None and self._heap:
            start = min(t for t, *_ in self._heap)
            self._meter_t = start
            self._meter_e = self._fleet_energy_j()
            # a band coordinator can cap the fleet from t=0, before any
            # telemetry exists — ask it for initial bands
            init = getattr(fleet_policy, "initial_bands", None)
            if init is not None:
                self._propagate_bands(init(self.engines))
            self._push(start + self._fleet_period, EventKind.FLEET_TICK, -1)
        self._tick_period: List[float] = [0.0] * len(self.nodes)
        # whether a POLICY_TICK is outstanding for the node — a ROUTE
        # revival restarts a dead train, so tick liveness never depends
        # on the caller maintaining ``engine.inflight`` (ServingCluster
        # does; direct EventLoop/drive users need not)
        self._tick_alive: List[bool] = [False] * len(self.nodes)
        if policy_tick_mode == "tick" and self._heap:
            # a node's tick train anchors where the node first gets work:
            # its scheduled event, or — when requests are still in the
            # network — its earliest delivery (identical instants on the
            # zero-delay path, so routed and direct tick trajectories
            # coincide)
            deliveries = (router.first_time_per_node()
                          if router is not None else {})
            for i, node in enumerate(self.nodes):
                if node.policy is None:
                    continue
                self._tick_period[i] = _policy_period(node.policy)
                t0 = self._sched_t[i]
                if t0 is None:
                    t0 = deliveries.get(i)
                if t0 is None:
                    continue        # node never receives work: no ticks
                if t_end is None or t0 < t_end:
                    self._push(t0, EventKind.POLICY_TICK, i)
                    self._tick_alive[i] = True

    # ------------------------------------------------------------------
    @property
    def engines(self) -> List[object]:
        return [n.engine for n in self.nodes]

    def _push(self, t: float, kind: EventKind, node: int) -> None:
        # Same-time ordering: ROUTE outranks node events (a delivery due
        # at t must be visible to an iteration at t, exactly as an
        # already-placed arrival would be) and POLICY_TICK yields to them
        # (a tick coinciding with a node's event observes the engine
        # after it fired, whichever path seeded the event) — so routed
        # and direct configurations order identically at shared instants.
        # Everything else stays FIFO. Node events carry their node's
        # version so a reschedule can orphan them in place.
        if (kind is EventKind.ROUTE or kind is EventKind.NODE_FAULT
                or kind is EventKind.NODE_RECOVER):
            prio = 0
        elif kind is EventKind.POLICY_TICK:
            prio = 2
        else:
            prio = 1
        if node >= 0:
            ver = self._ver[node]
        elif kind is EventKind.ROUTE:
            ver = self._route_ver
        else:
            ver = 0
        heapq.heappush(self._heap,
                       (t, prio, next(self._seq), kind, node, ver))

    def _schedule_node(self, i: int) -> bool:
        """Schedule node ``i``'s next event; False if it has drained."""
        eng = self.nodes[i].engine
        t = eng.next_event_time()
        if t is None:
            self._sched_t[i] = None
            return False
        kind = (EventKind.ITERATION if eng.sched.has_work
                else EventKind.ARRIVAL)
        self._sched_t[i] = t
        self._push(t, kind, i)
        return True

    def _router_pending(self) -> bool:
        return self.router is not None and self.router.next_time() is not None

    # -- hierarchical power capping (repro.policies.hierarchy) ---------
    def _propagate_bands(self, bands) -> None:
        """Deliver per-node frequency bands: hand each band to the node
        policy's optional ``set_band`` hook and clamp the engine's current
        frequency into it — a band excluding the running frequency forces
        a move, billed as a DVFS transition like any other."""
        if not bands:
            return
        faults = self.faults
        for i, (node, band) in enumerate(zip(self.nodes, bands)):
            if band is None:
                continue
            lo, hi = band
            if lo > hi:
                lo, hi = hi, lo
            if faults is not None:
                # remember the coordinator's band and govern by its
                # intersection with any live thermal envelope
                self._coord_band[i] = (lo, hi)
                cap = faults.states[i].thermal_cap_mhz
                if cap is not None:
                    hi = min(hi, cap)
                    lo = min(lo, hi)
            set_band = getattr(node.policy, "set_band", None)
            if set_band is not None:
                set_band(lo, hi)
            eng = node.engine
            f = min(max(eng.frequency, lo), hi)
            if f != eng.frequency:
                eng.set_frequency(f)

    def _fleet_energy_j(self) -> float:
        return sum(n.engine.metrics.c.energy_joules_total
                   for n in self.nodes)

    def _meter_power(self, t: float) -> None:
        """Budget accounting between consecutive FLEET_TICKs: mean fleet
        draw over the interval, peak tracking, and seconds spent above
        the declared cap."""
        if self._power_cap is None:
            return
        e = self._fleet_energy_j()
        if t > self._meter_t:
            dt = t - self._meter_t
            de = e - self._meter_e
            p = de / dt
            self.metered_s += dt
            self.metered_energy_j += de
            if p > self.peak_fleet_power_w:
                self.peak_fleet_power_w = p
            if p > self._power_cap:
                self.cap_violation_s += dt
        self._meter_t, self._meter_e = t, e

    @property
    def mean_fleet_power_w(self) -> float:
        return (self.metered_energy_j / self.metered_s
                if self.metered_s > 0 else 0.0)

    # -- event handlers ------------------------------------------------
    def _fire_route(self, t: float) -> None:
        """Deliver every request due at ``t`` to its engine's arrival
        heap, then repair node scheduling: a delivery earlier than a
        node's outstanding event supersedes it (version bump); a drained
        node comes back to life."""
        t_end = self.t_end
        faults = self.faults
        touched = {}
        for idx, req in self.router.pop_due(t):
            if faults is not None and faults.states[idx].down:
                # the target died while this request was in flight:
                # bounce it back through the retry path instead of
                # delivering into the void
                eng = self.nodes[idx].engine
                if eng.inflight > 0:
                    eng.inflight -= 1
                self._reroute(req, t)
                continue
            self.nodes[idx].engine.deliver(req, t)
            touched[idx] = True
        self.counts[EventKind.ROUTE] += 1
        for idx in touched:
            eng = self.nodes[idx].engine
            if t_end is not None and eng.clock >= t_end:
                continue                     # past the horizon: stays down
            nt = eng.next_event_time()
            if nt is None:
                continue
            if self._sched_t[idx] is None:
                if self._schedule_node(idx):
                    self._live += 1          # revival
            elif nt < self._sched_t[idx]:
                self._ver[idx] += 1          # orphan the stale event
                self._schedule_node(idx)
            if (self.policy_tick_mode == "tick"
                    and not self._tick_alive[idx]
                    and self.nodes[idx].policy is not None
                    and (t_end is None or t < t_end)):
                # the node's tick train died while it was drained —
                # re-anchor it at the delivery that brought it back
                self._push(t, EventKind.POLICY_TICK, idx)
                self._tick_alive[idx] = True
        nxt = self.router.next_time()
        if nxt is not None and (t_end is None or nxt < t_end):
            self._push(nxt, EventKind.ROUTE, -1)
            self._route_t = nxt
        else:
            self._route_t = None

    def _fire_policy_tick(self, t: float, i: int) -> None:
        """One wall-clock policy decision for node ``i``: the policy's
        telemetry window is cut at the tick's virtual time ``t`` (not at
        an iteration boundary). The tick train dies only when the node is
        fully drained — idle gaps between arrivals still tick (a real
        poller doesn't stop polling an idle server)."""
        node = self.nodes[i]
        eng = node.engine
        fs = getattr(eng, "fault_state", None)
        if fs is not None and fs.down:
            self._tick_alive[i] = False      # dark: recovery restarts it
            return
        if (self._sched_t[i] is None and not eng.has_work
                and getattr(eng, "inflight", 0) == 0):
            self._tick_alive[i] = False      # drained: a ROUTE revives it
            return
        self.counts[EventKind.POLICY_TICK] += 1
        tick = getattr(node.policy, "tick", None)
        if tick is not None:
            tick(eng, t)
        else:                                # duck-typed minimal policies
            node.policy.maybe_act(eng)
        nxt = t + self._tick_period[i]
        if self.t_end is None or nxt < self.t_end:
            self._push(nxt, EventKind.POLICY_TICK, i)
        else:
            self._tick_alive[i] = False

    # -- fault injection (repro.serving.faults) ------------------------
    def _work_remains(self) -> bool:
        """Does the loop still owe anyone service? Under faults, a fully
        dark fleet holding unserved requests must keep its fault (and
        fleet) event trains alive until a recovery drains them; healthy
        loops keep the historical live-nodes-or-in-flight test."""
        if self._live > 0 or self._router_pending():
            return True
        if self.faults is not None:
            return any(n.engine.has_work for n in self.nodes)
        return False

    def _arm_fault_event(self) -> None:
        """Arm the loop's single outstanding fault event at the model's
        next transition (constructor seed and post-fire re-arm)."""
        fm = self.faults
        nxt = fm.next_time()
        if nxt is None or (self.t_end is not None and nxt >= self.t_end):
            return
        kind = (EventKind.NODE_FAULT if fm.next_is_onset()
                else EventKind.NODE_RECOVER)
        self._push(nxt, kind, -1)

    def _fire_faults(self, t: float, kind: EventKind) -> None:
        """Apply every fault transition due at ``t`` and re-arm the
        train while anything is left to serve."""
        for action in self.faults.pop_due(t):
            if action.kind == "crash":
                self._crash_node(action.node, t)
            elif action.kind == "recover":
                self._recover_node(action.node, t)
            elif action.kind == "thermal-on":
                self._thermal_flip(action.node, action.cap_mhz)
            else:                              # thermal-off
                self._thermal_flip(action.node, None)
        self.counts[kind] += 1
        if self._work_remains():
            self._arm_fault_event()

    def _arm_route(self, t: float) -> None:
        """Ensure a ROUTE event is armed no later than ``t``: re-routes
        can land ahead of the router's armed event — or revive a train
        that ended. At most one ROUTE event stays live (versioning)."""
        if self.t_end is not None and t >= self.t_end:
            return
        if self._route_t is not None and self._route_t <= t:
            return
        if self._route_t is not None:
            self._route_ver += 1          # orphan the later-armed event
        self._push(t, EventKind.ROUTE, -1)
        self._route_t = t

    def _crash_node(self, i: int, t: float) -> None:
        """Node ``i`` goes dark at ``t``: orphan its outstanding event,
        evacuate its running batch (KV state lost, recompute-style), its
        queue, its already-arrived undelivered heap entries, and its
        in-flight deliveries, re-routing every evacuee through the retry
        path. Arrivals the node owns that haven't happened yet stay
        owned — they re-enter service after recovery."""
        eng = self.nodes[i].engine
        if self._sched_t[i] is not None:
            self._ver[i] += 1                  # orphan the heap entry
            self._sched_t[i] = None
            self._live -= 1
        sched = eng.sched
        evac: List[object] = []
        for req in list(sched.running.values()):
            del sched.running[req.request_id]
            sched.kv.free(req, preempted=True)
            req.state = RequestState.WAITING
            req.prefilled = 0
            req.generated = 0
            req.cached_tokens = 0
            evac.append(req)
        while sched.waiting:
            evac.append(sched.waiting.popleft())
        while eng._pending and eng._pending[0][0] <= t:
            evac.append(heapq.heappop(eng._pending)[2])
        for _, req in self.router.extract_node(i):
            if eng.inflight > 0:
                eng.inflight -= 1
            evac.append(req)
        for req in evac:
            self._reroute(req, t)

    def _reroute(self, req, t: float) -> None:
        """Retry path for an evacuated/bounced request: re-deliver to a
        surviving node after exponential backoff (priced through the
        network model when one exists), or drop it once the retry budget
        is spent."""
        fm = self.faults
        if req.retries >= fm.config.retry_budget:
            req.state = RequestState.DROPPED
            fm.dropped.append(req)
            return
        attempt = req.retries
        req.retries += 1
        fm.retries += 1
        base = t + fm.backoff_delay(attempt)
        deliver = (fm.network.delivery_time(base)
                   if fm.network is not None else base)
        j = fm.pick_node(self.engines, req)
        self.nodes[j].engine.inflight += 1
        fm.reroutes += 1
        req.delivery_time = deliver
        self.router.push(deliver, j, req)
        self._arm_route(deliver)

    def _recover_node(self, i: int, t: float) -> None:
        """Node ``i`` comes back at ``t``: its clock jumps over the
        outage WITHOUT billing idle energy (the node was dark, not
        idling), it rejoins the event heap, and a dead POLICY_TICK train
        restarts."""
        node = self.nodes[i]
        eng = node.engine
        if t > eng.clock:
            eng.clock = t
        if self._sched_t[i] is None and (self.t_end is None
                                         or eng.clock < self.t_end):
            if self._schedule_node(i):
                self._live += 1
        if (self.policy_tick_mode == "tick" and not self._tick_alive[i]
                and node.policy is not None
                and (self.t_end is None or t < self.t_end)):
            self._push(t, EventKind.POLICY_TICK, i)
            self._tick_alive[i] = True

    def _thermal_flip(self, i: int, cap: Optional[float]) -> None:
        """Apply a thermal-throttle flip to node ``i`` (the model already
        flipped its state): onset force-clamps the running frequency
        under the cap — a DVFS transition billed like any other, exempt
        from stick/lag (hardware throttling bypasses the flaky driver
        interface) — and the governing band becomes coordinator band ∩
        thermal envelope; release restores the coordinator's band."""
        node = self.nodes[i]
        eng = node.engine
        if cap is not None and eng.frequency > cap:
            fs = self.faults.states[i]
            fs.bypass = True
            try:
                eng.set_frequency(cap)
            finally:
                fs.bypass = False
        set_band = getattr(node.policy, "set_band", None)
        if set_band is None:
            return
        hw = eng.hardware
        base = self._coord_band[i]
        if base is None:
            base = (hw.f_min, hw.f_max)
        if cap is None:
            set_band(*base)
        else:
            hi = min(base[1], cap)
            set_band(min(base[0], hi), hi)

    # ------------------------------------------------------------------
    def _run_single(self) -> int:
        """Single node, no fleet policy, no router, iteration-gated — the
        overwhelmingly common shape (every benchmark cell): exactly one
        event is ever outstanding, so the loop re-derives it inline
        instead of round-tripping the heap. Trajectories, step counts,
        ``now`` and event counts are identical to the general loop."""
        node = self.nodes[0]
        eng = node.engine
        policy = node.policy
        sched = eng.sched
        t_end = self.t_end
        counts = self.counts
        self._heap.clear()               # constructor's seed event, inlined
        while self.steps < self.max_iters:
            if sched.waiting or sched.running:
                kind = EventKind.ITERATION
                t = eng.clock
            elif eng._pending:
                kind = EventKind.ARRIVAL
                t = eng._pending[0][0]
            else:
                break                    # drained
            if t > self.now:
                self.now = t
            if t_end is not None and eng.clock >= t_end:
                break
            eng.step()
            if policy is not None:
                policy.maybe_act(eng)
            self.steps += 1
            counts[kind] += 1
        return self.steps

    def run(self) -> int:
        if (len(self.nodes) == 1 and self.fleet_policy is None
                and self.router is None and self.faults is None
                and self.policy_tick_mode == "iteration"):
            return self._run_single()
        t_end = self.t_end
        iteration_gated = self.policy_tick_mode == "iteration"
        while self._heap and self.steps < self.max_iters:
            t, _, _, kind, i, ver = heapq.heappop(self._heap)
            if self.on_event is not None:
                self.on_event(self, kind, t)
            if t > self.now:
                self.now = t

            if kind is EventKind.FLEET_TICK:
                if not self._work_remains():
                    continue                   # fleet dies with nodes
                self.fleet_policy.act(self.engines, t)
                self._propagate_bands(getattr(self.fleet_policy, "bands",
                                              None))
                self._meter_power(t)
                self.counts[kind] += 1
                nxt = t + self._fleet_period
                if t_end is None or nxt < t_end:
                    self._push(nxt, EventKind.FLEET_TICK, -1)
                continue

            if (kind is EventKind.NODE_FAULT
                    or kind is EventKind.NODE_RECOVER):
                self._fire_faults(t, kind)
                continue

            if kind is EventKind.ROUTE:
                if ver == self._route_ver:
                    self._fire_route(t)
                continue

            if kind is EventKind.POLICY_TICK:
                self._fire_policy_tick(t, i)
                continue

            if ver != self._ver[i]:
                continue                       # superseded by a delivery
            self._sched_t[i] = None
            node = self.nodes[i]
            eng = node.engine
            if not eng.has_work or (t_end is not None
                                    and eng.clock >= t_end):
                self._live -= 1
                continue
            eng.step()
            if iteration_gated and node.policy is not None:
                node.policy.maybe_act(eng)
            self.steps += 1
            self.counts[kind] += 1
            if not self._schedule_node(i):
                self._live -= 1
        if self.fleet_policy is not None:
            # final flush: the drain tail past the last FLEET_TICK must be
            # metered too, or cap violations there would go uncounted
            self._meter_power(max([self.now]
                                  + [n.engine.clock for n in self.nodes]))
        return self.steps


def drive(nodes: Sequence[EngineNode], *, t_end: Optional[float] = None,
          max_iters: int = 10_000_000,
          fleet_policy: Optional[object] = None,
          router: Optional[object] = None,
          policy_tick_mode: str = "iteration",
          fault_model: Optional[object] = None) -> int:
    """Advance ``nodes`` through the shared event loop until no work
    remains (or ``t_end``/``max_iters``); returns engine steps executed.
    Thin facade over :class:`EventLoop` for the common one-shot case."""
    return EventLoop(nodes, fleet_policy=fleet_policy, t_end=t_end,
                     max_iters=max_iters, router=router,
                     policy_tick_mode=policy_tick_mode,
                     fault_model=fault_model).run()
