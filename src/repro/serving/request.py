"""Request lifecycle for the continuous-batching engine.

Privacy contract (paper §2.2/§3.2): the AGFT tuner must never read
``prompt_len``/``output_len``/``template_id`` of an individual request —
those fields exist only so the *simulation* can execute the request. The
tuner consumes exclusively the aggregate metrics exported by
``serving.metrics``.
"""
from __future__ import annotations

import dataclasses
import enum
import itertools
from typing import Optional

_ids = itertools.count()


class RequestState(enum.Enum):
    WAITING = "waiting"
    RUNNING = "running"       # prefilling or decoding
    PREEMPTED = "preempted"
    FINISHED = "finished"
    DROPPED = "dropped"       # shed: deadline expired or retries exhausted


@dataclasses.dataclass(slots=True)
class Request:
    arrival_time: float
    prompt_len: int                  # hidden from the tuner
    output_len: int                  # hidden from the tuner
    template_id: int = 0             # prefix-cache identity (hidden)
    template_frac: float = 0.9       # fraction of prompt shared w/ template
    request_id: int = dataclasses.field(default_factory=lambda: next(_ids))
    #: load-shedding budget: a request still WAITING ``deadline_s`` after
    #: its arrival is dropped at admission instead of ballooning TTFT
    #: (None = never sheds, the historical behavior)
    deadline_s: Optional[float] = None
    #: crash re-route attempts consumed (fault injection; see
    #: ``repro.serving.faults`` — bounded by the model's retry budget)
    retries: int = 0

    # execution progress
    state: RequestState = RequestState.WAITING
    prefilled: int = 0               # prompt tokens processed (incl. cached)
    generated: int = 0
    cached_tokens: int = 0           # prompt tokens served from prefix cache

    # timing
    #: when the routing path handed the request to its engine (None on the
    #: direct-submit path); TTFT/E2E stay measured from ``arrival_time``,
    #: so routing delay shows up in latency instead of vanishing
    delivery_time: Optional[float] = None
    first_scheduled_time: Optional[float] = None
    first_token_time: Optional[float] = None
    finish_time: Optional[float] = None

    @property
    def net_delay(self) -> Optional[float]:
        """Routing-path delay (delivery - arrival); None if direct."""
        if self.delivery_time is None:
            return None
        return self.delivery_time - self.arrival_time

    # ------------------------------------------------------------------
    @property
    def prefill_remaining(self) -> int:
        return self.prompt_len - self.prefilled

    @property
    def is_prefilling(self) -> bool:
        return self.prefilled < self.prompt_len

    @property
    def done(self) -> bool:
        return self.generated >= self.output_len

    @property
    def context_len(self) -> int:
        return self.prefilled + self.generated

    # latency metrics -----------------------------------------------------
    @property
    def ttft(self) -> Optional[float]:
        if self.first_token_time is None:
            return None
        return self.first_token_time - self.arrival_time

    @property
    def tpot(self) -> Optional[float]:
        if self.finish_time is None or self.first_token_time is None:
            return None
        if self.output_len <= 1:
            return 0.0
        return ((self.finish_time - self.first_token_time)
                / (self.output_len - 1))

    @property
    def e2e(self) -> Optional[float]:
        if self.finish_time is None:
            return None
        return self.finish_time - self.arrival_time
