"""Fleet-level serving (beyond paper — its conclusion targets "LLM
inference clusters"): N engine replicas, each with its OWN AGFT tuner
(per-node closed loops, no cross-node coordination needed — the paper's
privacy/minimal-intrusion story holds per node), plus a load-aware router.

Because each node learns from its own fingerprint stream, heterogeneous
traffic splits (e.g. a router that segregates long-context from chat
traffic) let different nodes converge to DIFFERENT frequencies — fleet
energy beyond what one global setting achieves.
"""
from __future__ import annotations

import dataclasses
from typing import Callable, List, Optional

import numpy as np

from repro.core import AGFTConfig, AGFTTuner
from repro.energy import A6000, HardwareSpec
from repro.models.common import ModelConfig
from repro.serving.engine import EngineConfig, InferenceEngine
from repro.serving.request import Request


def route_least_loaded(engines: List[InferenceEngine],
                       req: Request) -> int:
    """Default router: fewest running+waiting requests."""
    loads = [e.sched.num_running() + e.sched.num_waiting() + len(e.pending)
             for e in engines]
    return int(np.argmin(loads))


def route_by_length(engines: List[InferenceEngine], req: Request) -> int:
    """Segregating router: long-context traffic to the first half of the
    fleet, short/chat traffic to the second half (workload-homogeneous
    nodes converge faster and to better-fitting frequencies)."""
    n = len(engines)
    half = max(n // 2, 1)
    if req.prompt_len >= 1024:
        pool = range(0, half)
    else:
        pool = range(half, n) if n > 1 else range(0, 1)
    loads = {i: engines[i].sched.num_running() + engines[i].sched.num_waiting()
             for i in pool}
    return min(loads, key=loads.get)


@dataclasses.dataclass
class ClusterSummary:
    energy_j: float
    finished: int
    mean_ttft_s: float
    mean_tpot_s: float
    edp: float
    node_frequencies: List[float]
    node_energy_j: List[float]


class ServingCluster:
    def __init__(self, model_cfg: ModelConfig, n_nodes: int = 2, *,
                 hardware: HardwareSpec = A6000,
                 engine_cfg: Optional[EngineConfig] = None,
                 tuner_cfg: Optional[AGFTConfig] = None,
                 with_tuners: bool = True,
                 router: Callable = route_least_loaded):
        self.engines = [InferenceEngine(model_cfg,
                                        engine_cfg or EngineConfig(),
                                        hardware=hardware,
                                        initial_frequency=hardware.f_max)
                        for _ in range(n_nodes)]
        self.tuners = [AGFTTuner(hardware, tuner_cfg or AGFTConfig())
                       if with_tuners else None for _ in range(n_nodes)]
        self.router = router

    # ------------------------------------------------------------------
    def submit(self, requests: List[Request]) -> None:
        """Route each request at its arrival time (arrival order)."""
        for req in sorted(requests, key=lambda r: r.arrival_time):
            idx = self.router(self.engines, req)
            self.engines[idx].submit([req])

    @property
    def has_work(self) -> bool:
        return any(e.has_work for e in self.engines)

    def drain(self, max_iters: int = 10_000_000) -> None:
        """Advance all nodes in lock-step on the slowest clock (nodes are
        independent; stepping the laggard preserves causality)."""
        it = 0
        while self.has_work and it < max_iters:
            active = [e for e in self.engines if e.has_work]
            eng = min(active, key=lambda e: e.clock)
            eng.step()
            tuner = self.tuners[self.engines.index(eng)]
            if tuner is not None:
                tuner.maybe_act(eng)
            it += 1

    # ------------------------------------------------------------------
    def summary(self) -> ClusterSummary:
        fin = [r for e in self.engines for r in e.finished]
        tpots = [r.tpot for r in fin if r.tpot is not None]
        energy = sum(e.metrics.c.energy_joules_total for e in self.engines)
        tpot = float(np.mean(tpots)) if tpots else 0.0
        return ClusterSummary(
            energy_j=energy,
            finished=len(fin),
            mean_ttft_s=float(np.mean([r.ttft for r in fin])) if fin else 0,
            mean_tpot_s=tpot,
            edp=energy * tpot,
            node_frequencies=[e.frequency for e in self.engines],
            node_energy_j=[e.metrics.c.energy_joules_total
                           for e in self.engines],
        )
