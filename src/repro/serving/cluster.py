"""Fleet-level serving (beyond paper — its conclusion targets "LLM
inference clusters"): N engine replicas, each governed by its OWN power
policy (per-node closed loops, no cross-node coordination needed — the
paper's privacy/minimal-intrusion story holds per node), plus a
load-aware router and an optional FLEET-scope controller.

Policies are per-node and heterogeneous: ``policies=["agft", "slo",
None]`` gives node 0 the paper tuner, node 1 a GreenLLM-style SLO
controller, and leaves node 2 at fixed clocks — all driven by the shared
event loop in ``repro.serving.driver``. Because each node learns from its
own fingerprint stream, heterogeneous traffic splits (e.g. a router that
segregates long-context from chat traffic) let different nodes converge
to DIFFERENT frequencies — fleet energy beyond what one global setting
achieves.

``fleet_policy=`` attaches the cross-node coordination baseline instead:
one controller (e.g. ``"global"``) sampling fleet-aggregated telemetry on
FLEET_TICK events and setting a single frequency for every node — the
comparison that quantifies what the per-node closed loops buy
(``benchmarks.tab_fleet``).

Hierarchical control passes BOTH: ``fleet_policy=get_policy("hierarchy",
power_cap_w=...)`` plus per-node ``policies=["agft", ...]`` — the
coordinator water-fills the power budget into per-node frequency bands on
FLEET_TICK and the node loops fine-tune inside them (``repro.policies.
hierarchy``). When the fleet policy declares ``power_cap_w``, the event
loop meters the fleet draw and ``summary()`` reports the budget
accounting (``cap_violation_s``, mean/peak fleet watts).

``network=`` routes requests through a :class:`repro.serving.network.
NetworkModel` (instance, preset name like ``"wan"``, or ``fixed:<ms>``
spec): each submit is priced with per-hop latency + router queueing and
becomes an ARRIVAL *rescheduling* event the event loop delivers at the
request's network delivery time — instead of instant placement at submit
time. Routing decisions still happen at submit in arrival order (the
in-flight count keeps the router's load view identical), so a zero-delay
network is bit-identical to no network at all.

``policy_tick_mode="tick"`` decouples per-node policy decisions from
iteration boundaries: the loop fires per-node POLICY_TICK events on each
policy's sampling period and telemetry windows are cut at tick time. The
default ``"iteration"`` keeps the golden-pinned historical behavior.
"""
from __future__ import annotations

import dataclasses
from typing import Callable, List, Optional, Sequence, Union

import numpy as np

from repro.core import AGFTConfig
from repro.energy import A6000, HardwareSpec, parse_fleet_hardware
from repro.models.common import ModelConfig
from repro.policies import get_policy
from repro.serving.driver import POLICY_TICK_MODES, EngineNode, EventLoop
from repro.serving.engine import EngineConfig, InferenceEngine
from repro.serving.faults import FaultModel
from repro.serving.network import DeliverySchedule, NetworkModel
from repro.serving.request import Request

PolicySpec = Union[str, None, object]   # registry name | None | instance


def route_least_loaded(engines: List[InferenceEngine],
                       req: Request) -> int:
    """Default router: fewest running+waiting requests, normalized by the
    node's peak-throughput scale so the comparison survives mixed fleets
    (an L4 at 3 requests is busier than an H100 at 5 — raw counts are the
    wrong signal across tiers). On a homogeneous fleet every count divides
    by the same positive constant, which preserves the argmin (and its
    ties) exactly — the historical placement is unchanged."""
    loads = [(e.sched.num_running() + e.sched.num_waiting()
              + e.num_pending) / e.hardware.peak_throughput()
             for e in engines]
    return int(np.argmin(loads))


class RoundRobinRouter:
    """Cyclic placement in submit order — the hardware- and load-blind
    baseline the energy-aware router is measured against
    (``benchmarks/tab_hetero.py``). Stateful: construct one per cluster."""

    def __init__(self):
        self._next = 0

    def __call__(self, engines: List[InferenceEngine],
                 req: Request) -> int:
        i = self._next % len(engines)
        self._next = i + 1
        return i


class EnergyAwareRouter:
    """Marginal joules-per-token placement subject to the SLO tier.

    For each candidate node, estimated from the node's own per-spec
    ``CostModel``/``DVFSModel`` at its *current* clock and queue depth:

    * ``est_ttft`` — queueing + prefill delay: the prompt's prefill time
      at the node's current clock, once for each request already queued
      ahead (waiting + pending) plus once for this request;
    * ``jpt`` — marginal joules per generated token: the increase in
      decode-iteration energy from growing the node's decode batch by one
      sequence (joining a busy efficient node rides its amortized weight
      reads; opening an idle node pays them in full), plus the prompt's
      prefill energy amortized over an assumed ``decode_tokens`` output.

    Placement: among nodes whose ``est_ttft`` fits the request's SLO tier
    (``req.deadline_s`` when the workload carries deadlines, else
    ``default_ttft_slo_s``), take the lowest ``jpt``; when no node fits
    the tier, take the lowest ``est_ttft`` (degrade toward least-loaded
    rather than blow the tier everywhere). Both scans break ties to the
    lowest node index, so placement is deterministic under equal costs.
    """

    def __init__(self, default_ttft_slo_s: float = 2.0,
                 decode_tokens: int = 128,
                 avg_context: float = 1024.0):
        self.default_ttft_slo_s = float(default_ttft_slo_s)
        self.decode_tokens = int(decode_tokens)
        self.avg_context = float(avg_context)

    def __call__(self, engines: List[InferenceEngine],
                 req: Request) -> int:
        slo = (req.deadline_s if req.deadline_s is not None
               else self.default_ttft_slo_s)
        d_tok = self.decode_tokens
        best_i, best_jpt = -1, float("inf")
        fb_i, fb_wait = 0, float("inf")
        for i, e in enumerate(engines):
            dvfs = e.backend.dvfs
            cost = e.backend.cost
            f = e.frequency
            q_ahead = (e.sched.num_waiting() + e.num_pending)
            d0 = e.sched.num_running()
            fp, mp = cost.iteration_cost(
                prefill_tokens=req.prompt_len, decode_seqs=0,
                avg_context=req.prompt_len / 2)
            t_pf, p_pf = dvfs.iteration_time_power(fp, mp, f)
            est_ttft = (q_ahead + 1) * t_pf
            fd1, md1 = cost.iteration_cost(
                prefill_tokens=0, decode_seqs=d0 + 1,
                avg_context=self.avg_context)
            t1, p1 = dvfs.iteration_time_power(fd1, md1, f)
            if d0 > 0:
                fd0, md0 = cost.iteration_cost(
                    prefill_tokens=0, decode_seqs=d0,
                    avg_context=self.avg_context)
                t0, p0 = dvfs.iteration_time_power(fd0, md0, f)
                de = p1 * t1 - p0 * t0
                if de <= 0.0:
                    # marginal degenerates (equal-cost plateau): fall back
                    # to the node's average joules per decoded token
                    de = p1 * t1 / (d0 + 1)
            else:
                de = p1 * t1
            jpt = (p_pf * t_pf + d_tok * de) / d_tok
            if est_ttft <= slo and jpt < best_jpt:
                best_i, best_jpt = i, jpt
            if est_ttft < fb_wait:
                fb_i, fb_wait = i, est_ttft
        return best_i if best_i >= 0 else fb_i


#: Router factory registry: names accepted by ``ServingCluster(router=)``
#: and ``launch.serve --router``. Factories, not instances — stateful
#: routers must not leak placement state across clusters.
ROUTERS = {
    "least-loaded": lambda: route_least_loaded,
    "length": lambda: route_by_length,
    "round-robin": RoundRobinRouter,
    "energy": EnergyAwareRouter,
}


def make_router(name: str) -> Callable:
    key = str(name).strip().lower()
    if key not in ROUTERS:
        raise ValueError(f"unknown router {name!r}; registry has "
                         f"{sorted(ROUTERS)}")
    return ROUTERS[key]()


def route_by_length(engines: List[InferenceEngine], req: Request) -> int:
    """Segregating router: long-context traffic to the first half of the
    fleet, short/chat traffic to the second half (workload-homogeneous
    nodes converge faster and to better-fitting frequencies)."""
    n = len(engines)
    half = max(n // 2, 1)
    if req.prompt_len >= 1024:
        pool = range(0, half)
    else:
        pool = range(half, n) if n > 1 else range(0, 1)
    loads = {i: engines[i].sched.num_running() + engines[i].sched.num_waiting()
             for i in pool}
    return min(loads, key=loads.get)


@dataclasses.dataclass
class ClusterSummary:
    energy_j: float
    finished: int
    mean_ttft_s: float
    mean_tpot_s: float
    edp: float
    node_frequencies: List[float]
    node_energy_j: List[float]
    # hardware-tier accounting (mixed fleets; single-tier fleets get one
    # entry). ``energy_by_tier`` maps spec name -> joules, and
    # ``finished_by_tier`` counts completions per tier so joules/request
    # per tier falls out directly.
    node_hardware: Optional[List[str]] = None
    energy_by_tier: Optional[dict] = None
    finished_by_tier: Optional[dict] = None
    # power-budget accounting (None unless the attached fleet policy
    # declares power_cap_w — see repro.policies.hierarchy)
    power_cap_w: Optional[float] = None
    cap_violation_s: Optional[float] = None
    metered_s: Optional[float] = None
    mean_fleet_power_w: Optional[float] = None
    peak_fleet_power_w: Optional[float] = None
    # routing-path accounting (None unless a network model is attached)
    mean_net_delay_s: Optional[float] = None
    max_net_delay_s: Optional[float] = None
    # robustness accounting (always present; non-trivial only under
    # fault injection / deadlines — see repro.serving.faults)
    submitted: int = 0
    dropped_total: int = 0
    completion_rate: float = 1.0
    fault_counters: Optional[dict] = None


class ServingCluster:
    def __init__(self, model_cfg: ModelConfig, n_nodes: int = 2, *,
                 hardware: Union[HardwareSpec, str,
                                 Sequence[HardwareSpec]] = A6000,
                 engine_cfg: Optional[EngineConfig] = None,
                 tuner_cfg: Optional[AGFTConfig] = None,
                 with_tuners: bool = True,
                 policies: Optional[Sequence[PolicySpec]] = None,
                 router: Union[Callable, str] = route_least_loaded,
                 fleet_policy: PolicySpec = None,
                 network: Union[NetworkModel, str, None] = None,
                 faults: Union[FaultModel, str, None] = None,
                 fault_seed: int = 0,
                 policy_tick_mode: str = "iteration",
                 step_mode: str = "event",
                 batched_record_history: bool = True,
                 batched_train_cap: Optional[int] = None,
                 batched_classb_path: str = "vector"):
        """``policies`` takes one entry per node — a registry name, a
        ready policy instance, or None (fixed clocks). When omitted,
        ``with_tuners`` keeps the legacy behaviour: an AGFT tuner per node
        (``tuner_cfg`` applies) or no policy at all. ``fleet_policy``
        attaches a FLEET-scope controller instead (registry name like
        ``"global"`` or instance); per-node policies then default to None
        so exactly one authority actuates each node (pass both explicitly
        for hierarchical experiments). ``network`` prices each submit's
        routing path (NetworkModel instance, preset name, or
        ``fixed:<ms>`` spec) and turns placement into delayed delivery;
        ``faults`` attaches a seeded fault-injection model
        (:class:`repro.serving.faults.FaultModel` instance, preset name
        like ``"node-churn"``, or the clause spec grammar — ``fault_seed``
        seeds a string spec); ``policy_tick_mode`` picks iteration-gated
        (default) or pure wall-clock POLICY_TICK policy scheduling.

        ``step_mode`` selects the drain backend: ``"event"`` (default)
        is the per-event heap loop; ``"batched"`` steps the fleet
        through :class:`repro.serving.fleet_step.BatchedFleetLoop` —
        structure-of-arrays state, vectorized decode physics, batched
        LinUCB decisions — with bit-identical per-node trajectories
        (see that module for the exact contract and the unsupported
        shapes, e.g. network models). ``batched_record_history`` can
        drop per-decision tuner history on the batched path, the main
        residual per-node Python cost at mega-fleet scale;
        ``batched_train_cap`` overrides the decode-train length cap
        (``BatchedFleetLoop.TRAIN_CAP``), and ``batched_classb_path``
        selects the admission path (``"vector"`` default, ``"engine"``
        for the real-step fallback).

        ``hardware`` describes the fleet's accelerators: one spec or
        registry name (homogeneous, the historical form), a per-node spec
        list (``hardware=[A6000, H100, L4]``), or a fleet spec string
        (``hardware="a6000,h100:2,l4"``). Per-node policies resolve
        against their own node's spec; mixed fleets hand fleet policies
        the full per-node list (the hierarchy coordinator water-fills
        through per-spec power curves), and ``router`` may be a registry
        name from :data:`ROUTERS` (``"energy"``, ``"least-loaded"``,
        ``"round-robin"``, ``"length"``) or any callable."""
        hw_list = parse_fleet_hardware(hardware, n_nodes)
        self.hardware = hw_list
        hetero = any(hw != hw_list[0] for hw in hw_list)
        engines = [InferenceEngine(model_cfg,
                                   engine_cfg or EngineConfig(),
                                   hardware=hw,
                                   initial_frequency=hw.f_max)
                   for hw in hw_list]
        if isinstance(fleet_policy, str):
            fleet_policy = get_policy(
                fleet_policy,
                hardware=hw_list if hetero else hw_list[0])
        if (fleet_policy is not None
                and getattr(fleet_policy, "scope", "node") != "fleet"):
            raise ValueError(
                f"fleet_policy must have scope 'fleet', got "
                f"{type(fleet_policy).__name__} (scope "
                f"{getattr(fleet_policy, 'scope', 'node')!r})")
        self.fleet_policy = fleet_policy
        if policies is None:
            policies = (["agft"] * n_nodes
                        if with_tuners and fleet_policy is None
                        else [None] * n_nodes)
        if len(policies) != n_nodes:
            raise ValueError(f"got {len(policies)} policies for "
                             f"{n_nodes} nodes")
        resolved = []
        for node_hw, spec in zip(hw_list, policies):
            if isinstance(spec, str):
                kw = ({"cfg": tuner_cfg}
                      if spec == "agft" and tuner_cfg is not None else {})
                spec = get_policy(spec, hardware=node_hw, **kw)
            if spec is not None and getattr(spec, "scope", "node") == "fleet":
                raise ValueError(
                    f"{type(spec).__name__} is fleet-scope; attach it via "
                    f"fleet_policy=, not per-node policies")
            resolved.append(spec)
        self.nodes = [EngineNode(e, p) for e, p in zip(engines, resolved)]
        self.router = make_router(router) if isinstance(router, str) \
            else router
        if isinstance(network, str):
            network = NetworkModel.from_spec(network)
        self.network = network
        if policy_tick_mode not in POLICY_TICK_MODES:
            raise ValueError(
                f"policy_tick_mode must be one of {POLICY_TICK_MODES}, "
                f"got {policy_tick_mode!r}")
        self.policy_tick_mode = policy_tick_mode
        if isinstance(faults, str):
            faults = FaultModel.from_spec(faults, seed=fault_seed)
        if faults is not None and not faults.active:
            faults = None                  # the "none" preset: healthy
        self.faults = faults
        if faults is not None:
            faults.bind(engines)
            faults.network = self.network
            # crash re-routes reuse the cluster router, restricted to the
            # surviving subset (the loop's least-loaded fallback applies
            # when the installed router is the default anyway)
            cluster_router = self.router

            def _route_up(engs, req, up):
                return up[cluster_router([engs[i] for i in up], req)]

            faults.route = _route_up
        if step_mode not in ("event", "batched"):
            raise ValueError(f"step_mode must be 'event' or 'batched', "
                             f"got {step_mode!r}")
        if step_mode == "batched" and network is not None:
            raise NotImplementedError(
                "step_mode='batched' does not support a network model "
                "(in-flight routed deliveries need the event heap)")
        if step_mode == "batched" and faults is not None:
            raise NotImplementedError(
                "step_mode='batched' does not support an active fault "
                "model (crash evacuation and re-routing need the event "
                "heap)")
        self.step_mode = step_mode
        self.batched_record_history = batched_record_history
        self.batched_train_cap = batched_train_cap
        self.batched_classb_path = batched_classb_path
        # priced deliveries awaiting their ROUTE event; persists across
        # drains so run_until-style repeated draining keeps consuming it
        # (crash re-routes need the pipe even without a network model)
        self._deliveries = (DeliverySchedule()
                            if network is not None or faults is not None
                            else None)
        self.submitted = 0
        self._loop: Optional[EventLoop] = None   # last drain's event loop

    # ------------------------------------------------------------------
    @property
    def engines(self) -> List[InferenceEngine]:
        return [n.engine for n in self.nodes]

    @property
    def policies(self) -> List[Optional[object]]:
        return [n.policy for n in self.nodes]

    #: legacy alias from the AGFT-only era
    tuners = policies

    # ------------------------------------------------------------------
    def submit(self, requests: List[Request]) -> None:
        """Route each request at its arrival time (arrival order). With a
        network model attached, placement is deferred: the request's
        routing path is priced (hops + router queueing) and the event
        loop delivers it to its engine at the network delivery time — the
        engine's in-flight counter keeps the router's load view identical
        to the direct path meanwhile."""
        engines = self.engines
        net = self.network
        fm = self.faults
        self.submitted += len(requests)
        for req in sorted(requests, key=lambda r: r.arrival_time):
            if fm is not None:
                # never place on a node currently known dark (mid-drain
                # submits; before the first drain every node is up)
                idx = fm.pick_node(engines, req)
            else:
                idx = self.router(engines, req)
            if net is None:
                engines[idx].submit([req])
            else:
                req.delivery_time = net.delivery_time(req.arrival_time)
                engines[idx].inflight += 1
                self._deliveries.push(req.delivery_time, idx, req)

    @property
    def has_work(self) -> bool:
        return (any(n.engine.has_work for n in self.nodes)
                or bool(self._deliveries))

    def drain(self, max_iters: int = 10_000_000) -> int:
        """Advance all nodes through the shared event loop (events fire in
        virtual-time order; nodes are independent, so per-node
        trajectories don't depend on interleaving). A fleet policy, if
        attached, ticks on its own cadence against the loop's global
        timeline; the loop is kept so ``summary()`` can surface its
        power-budget accounting. In-flight routed requests ride along as
        ROUTE events.

        With ``step_mode="batched"`` the fleet advances through the
        structure-of-arrays :class:`repro.serving.fleet_step.
        BatchedFleetLoop` instead — same trajectories, same ``summary()``
        accounting, minutes instead of hours at mega-fleet scale."""
        if self.step_mode == "batched":
            from repro.serving.fleet_step import BatchedFleetLoop
            self._loop = BatchedFleetLoop(
                self.nodes, fleet_policy=self.fleet_policy,
                max_iters=max_iters,
                policy_tick_mode=self.policy_tick_mode,
                record_history=self.batched_record_history,
                train_cap=self.batched_train_cap,
                classb_path=self.batched_classb_path)
        else:
            self._loop = EventLoop(self.nodes,
                                   fleet_policy=self.fleet_policy,
                                   max_iters=max_iters,
                                   router=self._deliveries,
                                   policy_tick_mode=self.policy_tick_mode,
                                   fault_model=self.faults)
        return self._loop.run()

    # ------------------------------------------------------------------
    def summary(self) -> ClusterSummary:
        engines = self.engines
        fin = [r for e in engines for r in e.finished]
        tpots = [r.tpot for r in fin if r.tpot is not None]
        energy = sum(e.metrics.c.energy_joules_total for e in engines)
        tpot = float(np.mean(tpots)) if tpots else 0.0
        out = ClusterSummary(
            energy_j=energy,
            finished=len(fin),
            mean_ttft_s=float(np.mean([r.ttft for r in fin])) if fin else 0,
            mean_tpot_s=tpot,
            edp=energy * tpot,
            node_frequencies=[e.frequency for e in engines],
            node_energy_j=[e.metrics.c.energy_joules_total
                           for e in engines],
        )
        # per-hardware-tier accounting: joules and completions grouped by
        # spec name (trivially one group on a homogeneous fleet)
        out.node_hardware = [e.hardware.name for e in engines]
        energy_by_tier: dict = {}
        finished_by_tier: dict = {}
        for e in engines:
            tier = e.hardware.name
            energy_by_tier[tier] = (energy_by_tier.get(tier, 0.0)
                                    + e.metrics.c.energy_joules_total)
            finished_by_tier[tier] = (finished_by_tier.get(tier, 0)
                                      + len(e.finished))
        out.energy_by_tier = energy_by_tier
        out.finished_by_tier = finished_by_tier
        loop = self._loop
        if loop is not None and loop._power_cap is not None:
            out.power_cap_w = loop._power_cap
            out.cap_violation_s = loop.cap_violation_s
            out.metered_s = loop.metered_s
            out.mean_fleet_power_w = loop.mean_fleet_power_w
            out.peak_fleet_power_w = loop.peak_fleet_power_w
        if self.network is not None:
            delays = [r.net_delay for r in fin if r.net_delay is not None]
            out.mean_net_delay_s = float(np.mean(delays)) if delays else 0.0
            out.max_net_delay_s = float(np.max(delays)) if delays else 0.0
        # robustness accounting: deadline sheds always count; retry-
        # budget drops and fault counters require an attached model
        out.submitted = self.submitted
        out.dropped_total = sum(len(e.sched.dropped) for e in engines)
        if self.faults is not None:
            out.dropped_total += self.faults.drops
            out.fault_counters = self.faults.counters()
        served = max(out.submitted - out.dropped_total, 1)
        out.completion_rate = (len(fin) / served
                               if out.submitted > 0 else 1.0)
        return out
