"""The inference engine: continuous-batching loop with pluggable execution
backends and a simulated clock.

``SimBackend`` prices each iteration with the analytical DVFS model (the
paper's evaluation environment); ``JaxBackend`` executes real JAX forwards
of a (reduced) model so the whole serving stack can be integration-tested
end-to-end on CPU. Both expose identical (latency, energy, power) effects,
so AGFT drives either transparently through ``set_frequency``.

The engine is a discrete-event process: future arrivals live in a heap
(O(log n) ``submit``, no re-sorts), and ``next_event_time`` tells the
event-scheduled driver (``repro.serving.driver``) when this engine next
does anything — now, if the scheduler holds work; at the next arrival, if
it is idle. ``step`` = (idle-advance to that arrival, billing idle energy)
+ ``run_iteration``; both halves are public so event loops can drive them
separately.

Requests reach the arrival heap by one of two paths: ``submit`` (direct
placement, keyed by the request's own arrival time — the historical
instant-materialization model) or ``deliver`` (the routed path: a
:class:`repro.serving.network.NetworkModel` priced the request's network
delivery time and the event loop hands it over on a ROUTE event). A
request routed to this engine but still traversing the network is counted
in ``inflight``; queue-depth telemetry (``requests_waiting``) and router
load (``num_pending``) include it, so a zero-delay network is
indistinguishable — bit-for-bit — from direct submit.
"""
from __future__ import annotations

import dataclasses
import heapq
import itertools
from typing import List, Optional, Tuple

import numpy as np

from repro.energy import A6000, CostModel, DVFSModel, HardwareSpec
from repro.models.common import ModelConfig
from repro.serving.driver import EngineNode, drive
from repro.serving.kv_cache import PagedKVCache
from repro.serving.metrics import MetricsExporter
from repro.serving.request import Request
from repro.serving.scheduler import BatchPlan, ContinuousBatchingScheduler


# ---------------------------------------------------------------------------
# Execution backends
# ---------------------------------------------------------------------------

class SimBackend:
    """Analytical backend: iteration cost -> DVFS model -> (dt, energy, W).

    The per-iteration path is a few dozen scalar flops: config-derived cost
    terms live in a precomputed :class:`repro.energy.CostModel`, frequency
    response in the DVFS model's tabulated grid, and batch context means are
    plain Python sums (numpy dispatch overhead dominates at batch size ~8).
    """

    def __init__(self, cfg: ModelConfig, hardware: HardwareSpec = A6000):
        self.cfg = cfg
        self.dvfs = DVFSModel(hardware)
        self.cost = CostModel(cfg)
        self._shared_weight_bytes = 2.0 * self.cost.n_active

    def execute(self, plan: BatchPlan, f_mhz: float
                ) -> Tuple[float, float, float]:
        cost = self.cost
        flops = 0.0
        mem = 0.0
        if plan.prefill:
            s = 0.0
            tok = 0
            for r, n in plan.prefill:
                s += r.prefilled + n / 2
                tok += n
            f1, m1 = cost.iteration_cost(prefill_tokens=tok,
                                         decode_seqs=0,
                                         avg_context=s / len(plan.prefill))
            flops += f1
            mem += m1
        if plan.decode:
            s = 0.0
            for r in plan.decode:
                s += r.prefilled + r.generated       # inlined context_len
            f2, m2 = cost.iteration_cost(prefill_tokens=0,
                                         decode_seqs=len(plan.decode),
                                         avg_context=s / len(plan.decode))
            flops += f2
            # weight reads are shared between the prefill and decode halves
            # of a mixed iteration — don't double count them.
            if plan.prefill:
                m2 -= self._shared_weight_bytes
            mem += max(m2, 0.0)
        t, p = self.dvfs.iteration_time_power(flops, mem, f_mhz)
        return t, p * t, p

    def execute_phased(self, plan: BatchPlan, f_prefill: float,
                       f_decode: float
                       ) -> Tuple[float, float, float, float]:
        """Per-phase pricing of one iteration: the prefill half at
        ``f_prefill``, the decode half at ``f_decode``. Returns
        ``(t_prefill, e_prefill, t_decode, e_decode)``.

        The work split is identical to :meth:`execute` — same two
        ``iteration_cost`` calls, same shared-weight-read subtraction on
        the decode half of a mixed iteration — but each half is priced by
        its own ``iteration_time_power`` call at its phase clock. Each
        half carries its own ``iteration_overhead_s`` (the mid-iteration
        clock switch splits the launch into two dispatches), so a mixed
        iteration at an equal pair is deliberately NOT the same number as
        the single-clock :meth:`execute` — 1-D engines never route through
        this method.
        """
        cost = self.cost
        t_pf = e_pf = t_de = e_de = 0.0
        if plan.prefill:
            s = 0.0
            tok = 0
            for r, n in plan.prefill:
                s += r.prefilled + n / 2
                tok += n
            f1, m1 = cost.iteration_cost(prefill_tokens=tok,
                                         decode_seqs=0,
                                         avg_context=s / len(plan.prefill))
            t, p = self.dvfs.iteration_time_power(f1, m1, f_prefill)
            t_pf, e_pf = t, p * t
        if plan.decode:
            s = 0.0
            for r in plan.decode:
                s += r.prefilled + r.generated       # inlined context_len
            f2, m2 = cost.iteration_cost(prefill_tokens=0,
                                         decode_seqs=len(plan.decode),
                                         avg_context=s / len(plan.decode))
            # weight reads are shared between the halves of a mixed
            # iteration — the decode half re-reads only what the prefill
            # half didn't already stream (same rule as ``execute``)
            if plan.prefill:
                m2 -= self._shared_weight_bytes
            t, p = self.dvfs.iteration_time_power(f2, max(m2, 0.0),
                                                  f_decode)
            t_de, e_de = t, p * t
        return t_pf, e_pf, t_de, e_de

    def execute_mixed_vec(self, prefill_tokens, prefill_count,
                          prefill_ctx_sum, decode_seqs, decode_ctx_sum,
                          terms, hw=None):
        """Batched :meth:`execute` over per-node plan aggregates — the
        mixed prefill+decode pricing of the batched fleet backend's
        admission fast path.

        Each row is one node's iteration: new prompt tokens and the
        context sum over its prefill half (``sum(r.prefilled + n/2)``),
        decode sequence count and context sum, and the node's tabulated
        frequency terms. Elementwise this is the identical float-op
        sequence as the scalar ``execute`` — the two ``iteration_cost``
        calls, the shared-weight-read subtraction on mixed iterations,
        and the same masking as the scalar branches — so per-node
        (dt, energy, power) is bit-for-bit the scalar result.

        ``hw`` optionally carries per-row hardware-constant columns
        (``repro.energy.hw_const_rows`` order) for mixed-hardware fleets;
        the model cost side is fleet-homogeneous either way.
        """
        cost = self.cost
        has_pf = prefill_tokens > 0
        has_de = decode_seqs > 0
        zeros = np.zeros_like(prefill_tokens)
        f1, m1 = cost.iteration_cost_vec(
            prefill_tokens=prefill_tokens, decode_seqs=zeros,
            avg_context=prefill_ctx_sum / np.maximum(prefill_count, 1))
        f2, m2 = cost.iteration_cost_vec(
            prefill_tokens=zeros, decode_seqs=decode_seqs,
            avg_context=decode_ctx_sum / np.maximum(decode_seqs, 1))
        # weight reads are shared between the prefill and decode halves
        # of a mixed iteration — don't double count them (scalar branch:
        # ``if plan.prefill: m2 -= shared``, then ``mem += max(m2, 0)``)
        m2 = np.where(has_pf, m2 - self._shared_weight_bytes, m2)
        m2 = np.maximum(m2, 0.0)
        flops = np.where(has_pf, f1, 0.0) + np.where(has_de, f2, 0.0)
        mem = np.where(has_pf, m1, 0.0) + np.where(has_de, m2, 0.0)
        t, p = self.dvfs.iteration_time_power_vec(flops, mem, terms, hw=hw)
        return t, p * t, p


class JaxBackend:
    """Real-execution backend for integration tests: runs the actual model
    (reduced config) per iteration and prices energy off measured wall time.
    """

    def __init__(self, cfg: ModelConfig, hardware: HardwareSpec = A6000,
                 max_batch: int = 8, cache_len: int = 256, seed: int = 0):
        import jax
        import jax.numpy as jnp
        from repro.models import build_model
        self.cfg = cfg
        self.dvfs = DVFSModel(hardware)
        self.model = build_model(cfg)
        self.params = self.model.init(jax.random.PRNGKey(seed))
        self.max_batch = max_batch
        self.cache_len = cache_len
        self.cache = self.model.init_cache(max_batch, cache_len)
        self._jax = jax
        self._jnp = jnp
        self._decode = jax.jit(self.model.decode_step)
        self._prefill = jax.jit(
            lambda p, t: self.model.forward(p, t)[0])

    def execute(self, plan: BatchPlan, f_mhz: float
                ) -> Tuple[float, float, float]:
        import time
        jnp = self._jnp
        t0 = time.perf_counter()
        if plan.prefill_tokens:
            # bucket prefill lengths to powers of two (zero-pad): the jitted
            # forward retraces per distinct shape, so without bucketing every
            # novel prompt length recompiles; with it there are at most
            # log2(64)+1 prefill traces per process.
            n = min(plan.prefill_tokens, 64)
            n = 1 << (max(n, 1) - 1).bit_length()
            toks = jnp.zeros((1, n), jnp.int32)
            self._prefill(self.params, toks).block_until_ready()
        if plan.decode:
            b = self.max_batch
            tok = jnp.zeros((b, 1), jnp.int32)
            pos = jnp.minimum(
                jnp.array([r.context_len for r in plan.decode[:b]]
                          + [1] * max(0, b - len(plan.decode)),
                          jnp.int32), self.cache_len - 1)
            logits, self.cache = self._decode(self.params, tok, self.cache,
                                              pos)
            logits.block_until_ready()
        wall = time.perf_counter() - t0
        # price energy with the DVFS power model at measured utilization
        fr = f_mhz / self.dvfs.spec.f_max
        sp = self.dvfs.spec
        p = sp.p_idle + sp.p_static_active + sp.p_dyn_compute * fr ** sp.alpha
        # frequency scales the compute-bound fraction of wall time
        t = wall * (1.0 / max(fr, 1e-3))
        return t, p * t, p


# ---------------------------------------------------------------------------
# Engine
# ---------------------------------------------------------------------------

@dataclasses.dataclass
class EngineConfig:
    num_kv_blocks: int = 4096
    kv_block_size: int = 16
    max_num_seqs: int = 64
    max_batched_tokens: int = 2048
    prefill_chunk: int = 512
    enable_prefix_cache: bool = True


class InferenceEngine:
    def __init__(self, model_cfg: ModelConfig,
                 engine_cfg: Optional[EngineConfig] = None,
                 hardware: HardwareSpec = A6000,
                 backend: Optional[object] = None,
                 initial_frequency: Optional[float] = None):
        self.model_cfg = model_cfg
        self.cfg = engine_cfg or EngineConfig()
        self.hardware = hardware
        self.kv = PagedKVCache(self.cfg.num_kv_blocks,
                               self.cfg.kv_block_size,
                               self.cfg.enable_prefix_cache)
        self.sched = ContinuousBatchingScheduler(
            self.kv, max_num_seqs=self.cfg.max_num_seqs,
            max_batched_tokens=self.cfg.max_batched_tokens,
            prefill_chunk=self.cfg.prefill_chunk)
        self.backend = backend or SimBackend(model_cfg, hardware)
        self.metrics = MetricsExporter()
        self.clock = 0.0
        self.frequency = initial_frequency or hardware.f_max
        #: phase-disaggregated DVFS targets ``(f_prefill, f_decode)`` set
        #: by ``set_phase_frequencies``; None (the default) = classic 1-D
        #: mode, whose iteration path is untouched by phased pricing
        self.freq_targets: Optional[Tuple[float, float]] = None
        # future arrivals: (arrival_time, submit order, request) heap —
        # O(log n) per submit, FIFO among equal arrival times
        self._pending: List[Tuple[float, int, Request]] = []
        self._submit_seq = itertools.count()
        #: requests routed to this engine but still in the network (the
        #: router will ``deliver`` them); counted as waiting load
        self.inflight = 0
        #: per-node fault surface (``repro.serving.faults.NodeFaultState``)
        #: attached by a bound FaultModel; None = healthy simulation, and
        #: every fault hook below is a single None check
        self.fault_state = None
        self.finished: List[Request] = []

    # ------------------------------------------------------------------
    def submit(self, requests: List[Request]) -> None:
        for r in requests:
            heapq.heappush(self._pending,
                           (r.arrival_time, next(self._submit_seq), r))

    def deliver(self, request: Request, t: float) -> None:
        """Routed-path arrival: the network delivered ``request`` at
        virtual time ``t`` — it becomes schedulable from ``t`` (never
        before its own arrival time), and leaves the in-flight count."""
        heapq.heappush(self._pending,
                       (max(t, request.arrival_time),
                        next(self._submit_seq), request))
        if self.inflight > 0:
            self.inflight -= 1

    def set_frequency(self, f_mhz: float) -> None:
        """Actuate one clock for every phase (the paper's non-invasive 1-D
        boundary). Clears any per-phase targets: a scalar actuation — a
        1-D policy, a band clamp, an operator override — always wins over
        a previously issued phase pair."""
        self.freq_targets = None
        self._apply_frequency(f_mhz)

    def set_phase_frequencies(self, f_prefill: float,
                              f_decode: float) -> None:
        """Phase-disaggregated actuation: run prefill-chunk work at
        ``f_prefill`` and pure-decode work at ``f_decode`` from the next
        iteration on (mixed iterations price each half at its own clock;
        every actual mid-iteration clock change is billed through the
        same ``dvfs_transition_cost`` machinery as a policy actuation).
        Targets are clamped to the hardware envelope and persist until
        ``set_frequency`` reverts the engine to 1-D mode."""
        sp = self.hardware
        self.freq_targets = (
            float(min(max(f_prefill, sp.f_min), sp.f_max)),
            float(min(max(f_decode, sp.f_min), sp.f_max)))

    def _apply_frequency(self, f_mhz: float) -> None:
        """The actual clock switch (fault filter -> clamp -> transition
        billing) — shared by the public 1-D ``set_frequency`` and the
        per-phase switches ``run_iteration`` performs in phased mode."""
        fs = self.fault_state
        if fs is not None:
            # flaky actuation: the call may silently stick (lost) or lag
            # (extra stall billed to the clock); a thermal throttle clamps
            # whatever does land
            eff, stall = fs.filter_set_frequency(f_mhz)
            if eff is None:
                return
            f_mhz = eff
            if stall > 0.0:
                self.clock += stall
        sp = self.hardware
        f = min(max(f_mhz, sp.f_min), sp.f_max)
        if f != self.frequency:
            c = self.metrics.c
            c.freq_transitions_total += 1
            # DVFS transitions are billed when the hardware prices them
            # (both default to 0 in the shipped calibrations)
            if sp.dvfs_transition_cost_j > 0.0:
                c.energy_joules_total += sp.dvfs_transition_cost_j
            if sp.dvfs_transition_s > 0.0:
                self.clock += sp.dvfs_transition_s
        self.frequency = f

    # ------------------------------------------------------------------
    @property
    def pending(self) -> List[Request]:
        """Future arrivals in heap (not time) order — introspection only;
        hot paths use the heap directly."""
        return [r for _, _, r in self._pending]

    @property
    def num_pending(self) -> int:
        """Future arrivals this engine already owns: heap entries plus
        requests still in flight through the network — so router load
        balancing sees the same totals whichever path requests take."""
        return len(self._pending) + self.inflight

    @property
    def next_arrival_time(self) -> Optional[float]:
        return self._pending[0][0] if self._pending else None

    def _ingest_arrivals(self) -> None:
        while self._pending and self._pending[0][0] <= self.clock:
            self.sched.add_request(heapq.heappop(self._pending)[2])

    @property
    def has_work(self) -> bool:
        return bool(self._pending) or self.sched.has_work

    def next_event_time(self) -> Optional[float]:
        """When this engine next does anything: now if the scheduler holds
        work, the next arrival if idle, ``None`` if fully drained."""
        if self.sched.has_work:
            return self.clock
        if self._pending:
            return self._pending[0][0]
        return None

    def advance_to(self, t: float) -> None:
        """Idle-advance the clock to ``t``, billing idle energy for the
        gap, then ingest every arrival now due."""
        dt = max(t - self.clock, 0.0)
        dvfs = getattr(self.backend, "dvfs", None)
        idle_e = dvfs.idle_energy(dt) if dvfs else 0.0
        self.clock = max(self.clock, t)
        self.metrics.c.energy_joules_total += idle_e
        self._ingest_arrivals()

    def step(self) -> List[Request]:
        """One engine iteration; returns requests finished in it. If the
        scheduler is idle, first skips to the next arrival (billing idle
        power for the gap)."""
        self._ingest_arrivals()
        if not self.sched.has_work:
            if not self._pending:
                return []
            self.advance_to(self._pending[0][0])
        return self.run_iteration()

    def _blocked_tick(self) -> List[Request]:
        """Blocked (e.g. out of KV blocks with nothing preemptible): burn a
        millisecond at idle power — time is never free."""
        dt = 1e-3
        dvfs = getattr(self.backend, "dvfs", None)
        if dvfs is not None:
            self.metrics.c.energy_joules_total += dvfs.idle_energy(dt)
        self.clock += dt
        return []

    def _execute_phased(self, plan: BatchPlan
                        ) -> Tuple[float, float, float]:
        """Phase-disaggregated iteration: switch to ``f_prefill`` for the
        prefill half and ``f_decode`` for the decode half (each switch
        runs through ``_apply_frequency``, so fault filtering, clamping
        and DVFS-transition billing apply exactly as for a policy
        actuation), then price each half at the clock that actually
        landed. A mixed iteration ends at the decode clock."""
        f_pf, f_de = self.freq_targets
        ex = getattr(self.backend, "execute_phased", None)
        if ex is None:
            # backend can't split an iteration (e.g. JaxBackend measures
            # one wall time): run the whole batch at the dominant phase's
            # target — decode when any decode work is present
            self._apply_frequency(f_de if plan.decode else f_pf)
            return self.backend.execute(plan, self.frequency)
        if plan.prefill:
            self._apply_frequency(f_pf)
            f_pf = self.frequency        # what the switch actually landed
        if plan.decode:
            self._apply_frequency(f_de)
            f_de = self.frequency
        t_pf, e_pf, t_de, e_de = ex(plan, f_pf, f_de)
        dt = t_pf + t_de
        energy = e_pf + e_de
        return dt, energy, (energy / dt if dt > 0.0 else 0.0)

    def run_iteration(self) -> List[Request]:
        """Execute one continuous-batching iteration at the current clock
        (the scheduler is expected to hold work; otherwise this is a
        blocked tick)."""
        sched = self.sched
        plan = sched.schedule(self.clock)
        if not plan.prefill and not plan.decode:     # inlined plan.empty
            # blocked (e.g. out of KV blocks): try preemption, else idle-tick
            if not sched._preempt_lowest_priority():
                return self._blocked_tick()
            plan = sched.schedule(self.clock)
            if plan.empty:
                return self._blocked_tick()

        # prefix-cache credit must be read BEFORE completion advances
        # ``prefilled`` (a request is on its first chunk exactly while
        # prefilled == cached_tokens; evaluating afterwards never matches)
        cached_tok = 0
        for r, _n in plan.prefill:
            if r.cached_tokens and r.prefilled == r.cached_tokens:
                cached_tok += r.cached_tokens

        if self.freq_targets is None:
            dt, energy, power = self.backend.execute(plan, self.frequency)
        else:
            dt, energy, power = self._execute_phased(plan)
        self.clock += dt
        finished = sched.complete_iteration(plan, self.clock)
        if finished:
            self.finished.extend(finished)

        # metrics (one pass over the prefill half; comparisons inline the
        # Request properties — hot path)
        prefill_tok = 0
        gen_from_prefill = 0
        for r, n in plan.prefill:
            prefill_tok += n
            if r.prefilled >= r.prompt_len:
                gen_from_prefill += 1
        c = self.metrics.c
        c.prompt_tokens_total += prefill_tok
        c.cached_prompt_tokens_total += cached_tok
        c.generation_tokens_total += len(plan.decode) + gen_from_prefill
        c.iterations_total += 1
        c.requests_finished_total += len(finished)
        c.requests_dropped_total = len(sched.dropped)
        # TTFT is accounted when the scheduler assigns first_token_time —
        # not by replaying a float-equality check against the clock, which
        # could silently drop samples. (Guarded: the event list is empty on
        # almost every iteration — skip the drain call + list churn.)
        if sched._first_token_events:
            for r in sched.pop_first_token_events():
                c.ttft_seconds_total += r.first_token_time - r.arrival_time
                c.ttft_count_total += 1
        stats = self.kv.stats
        c.prefix_cache_hits_total = stats.hits
        c.prefix_cache_queries_total = stats.queries
        c.energy_joules_total += energy
        c.busy_seconds_total += dt
        c.requests_running = len(sched.running)
        # waiting = queued at the scheduler + owned-but-not-yet-ingested,
        # wherever those live (this engine's heap or the network path) —
        # identical totals for direct submit and zero-delay delivery
        c.requests_waiting = (len(sched.waiting) + len(self._pending)
                              + self.inflight)
        c.gpu_cache_usage = self.kv.usage
        c.current_frequency_mhz = self.frequency
        c.current_power_watts = power
        return finished

    # ------------------------------------------------------------------
    def run_until(self, t_end: float, policy=None, *, tuner=None) -> None:
        """Advance simulated time to t_end through the shared drive loop,
        invoking the attached policy's ``maybe_act`` on its own cadence.
        (``tuner=`` is a deprecated alias for ``policy=``.)"""
        drive([EngineNode(self, policy if policy is not None else tuner)],
              t_end=t_end)

    def drain(self, policy=None, max_iters: int = 10_000_000, *,
              tuner=None) -> None:
        drive([EngineNode(self, policy if policy is not None else tuner)],
              max_iters=max_iters)
