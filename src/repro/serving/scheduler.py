"""Continuous-batching scheduler (vLLM-style, chunked prefill).

Every engine iteration builds a mixed batch: each RUNNING decode sequence
contributes one token; WAITING/prefilling sequences contribute prompt chunks
up to the per-iteration token budget. Finished sequences release their
blocks immediately to admit waiting work — the "come-and-go" behaviour
(Orca/vLLM) whose interleaving is exactly what makes phase identification
from raw power telemetry hard (paper Fig. 1) and motivates the fingerprint.

Hot-path structures are sized for fleet-scale traces: ``waiting`` is a
deque (O(1) FCFS admission pops and preemption re-queues, no per-iteration
list rebuild when the batch is full), and ``running`` is an
insertion-ordered dict keyed by ``request_id`` — O(1) removal on the
completion and preemption paths, with iteration order identical to the old
append-only list. ``complete_iteration`` touches only the iteration's
batch participants (the only requests whose ``generated`` advanced),
instead of scanning every running sequence.
"""
from __future__ import annotations

import dataclasses
from collections import deque
from typing import Deque, Dict, List, Tuple

from repro.serving.kv_cache import PagedKVCache
from repro.serving.request import Request, RequestState


@dataclasses.dataclass
class BatchPlan:
    """Work selected for one iteration."""
    prefill: List[Tuple[Request, int]]      # (request, new prompt tokens)
    decode: List[Request]                   # one token each

    @property
    def prefill_tokens(self) -> int:
        return sum(n for _, n in self.prefill)

    @property
    def decode_seqs(self) -> int:
        return len(self.decode)

    @property
    def total_tokens(self) -> int:
        return self.prefill_tokens + self.decode_seqs

    @property
    def empty(self) -> bool:
        return not self.prefill and not self.decode


class ContinuousBatchingScheduler:
    def __init__(self, kv: PagedKVCache, *,
                 max_num_seqs: int = 64,
                 max_batched_tokens: int = 2048,
                 prefill_chunk: int = 512):
        self.kv = kv
        self.max_num_seqs = max_num_seqs
        #: the configured ceiling ``set_admission_cap`` clamps against
        self._base_max_seqs = max_num_seqs
        self.max_batched_tokens = max_batched_tokens
        self.prefill_chunk = prefill_chunk
        self.waiting: Deque[Request] = deque()
        self.running: Dict[int, Request] = {}       # request_id -> Request
        # requests whose first output token was produced since the last
        # ``pop_first_token_events`` call — the engine drains this to
        # account TTFT at assignment time (no float-equality replay)
        self._first_token_events: List[Request] = []
        # deadline-expired requests shed at admission; the flag keeps the
        # no-deadline hot path free of per-request deadline checks
        self.dropped: List[Request] = []
        self._has_deadlines = False

    # ------------------------------------------------------------------
    def add_request(self, req: Request) -> None:
        req.state = RequestState.WAITING
        if req.deadline_s is not None:
            self._has_deadlines = True
        self.waiting.append(req)

    def set_admission_cap(self, cap) -> None:
        """Optional second control knob (dual-knob policies): clamp
        concurrent-sequence admission to ``min(cap, configured
        max_num_seqs)``; ``None`` restores the configured ceiling.
        Already-running sequences are never evicted — the cap throttles
        future admission only, so it takes effect as sequences finish.
        Admission always reads ``max_num_seqs`` live (both the event loop
        and the batched fleet path drive the real ``_admit``), so a
        policy may retune the cap every window."""
        base = self._base_max_seqs
        self.max_num_seqs = (base if cap is None
                             else max(1, min(base, int(cap))))

    @property
    def has_work(self) -> bool:
        return bool(self.waiting or self.running)

    def num_waiting(self) -> int:
        return len(self.waiting)

    def num_running(self) -> int:
        return len(self.running)

    # ------------------------------------------------------------------
    def _admit(self, now: float) -> List[Request]:
        """FCFS admission while seq and KV budgets allow; returns the
        requests admitted this call, in admission order (the batched
        fleet backend drives admission directly off this list).

        A request that does not fit the KV budget is skipped (not
        head-of-line blocking) and keeps its queue position relative to the
        other non-admitted requests.

        Requests carrying a ``deadline_s`` that has already expired are
        shed here (``self.dropped``) instead of admitted — graceful load
        shedding for overloaded or post-crash queues. Traces without
        deadlines never pay for the check.
        """
        admitted: List[Request] = []
        if not self.waiting or (len(self.running) >= self.max_num_seqs
                                and not self._has_deadlines):
            return admitted
        skipped: List[Request] = []
        for _ in range(len(self.waiting)):
            if (len(self.running) >= self.max_num_seqs
                    and not self._has_deadlines):
                break
            req = self.waiting.popleft()
            if (req.deadline_s is not None
                    and now - req.arrival_time > req.deadline_s):
                req.state = RequestState.DROPPED
                self.dropped.append(req)
                continue
            if len(self.running) >= self.max_num_seqs:
                skipped.append(req)
                continue
            total = req.prompt_len + req.output_len
            if self.kv.try_allocate(req, total):
                req.state = RequestState.RUNNING
                if req.first_scheduled_time is None:
                    req.first_scheduled_time = now
                # prefix-cache hits skip that prefill work
                req.prefilled = req.cached_tokens
                self.running[req.request_id] = req
                admitted.append(req)
            else:
                skipped.append(req)
        self.waiting.extendleft(reversed(skipped))
        return admitted

    def _preempt_lowest_priority(self) -> bool:
        """Free blocks by kicking the most recent running request back to
        the queue (vLLM recompute-style preemption)."""
        for req in reversed(self.running.values()):
            if req.is_prefilling:
                continue
            del self.running[req.request_id]
            self.kv.free(req, preempted=True)
            req.state = RequestState.WAITING
            req.prefilled = 0
            req.generated = 0
            req.cached_tokens = 0
            self.waiting.appendleft(req)
            return True
        return False

    # ------------------------------------------------------------------
    def schedule(self, now: float) -> BatchPlan:
        self._admit(now)
        budget = self.max_batched_tokens
        decode: List[Request] = []
        prefill: List[Tuple[Request, int]] = []
        prefilling: List[Request] = []
        # single pass over running: decodes admitted first (latency-critical,
        # one token each, in running order while budget lasts); prefill
        # candidates collected for the chunk pass below. The comparisons
        # inline ``is_prefilling`` — this is the hottest loop in the engine.
        for req in self.running.values():
            if req.prefilled < req.prompt_len:
                prefilling.append(req)
            elif budget > 0:
                decode.append(req)
                budget -= 1
        # then chunked prefill
        for req in prefilling:
            if budget <= 0:
                break
            chunk = min(req.prompt_len - req.prefilled, self.prefill_chunk,
                        budget)
            prefill.append((req, chunk))
            budget -= chunk
        return BatchPlan(prefill=prefill, decode=decode)

    # ------------------------------------------------------------------
    def pop_first_token_events(self) -> List[Request]:
        """Requests that produced their first token since the last call."""
        events, self._first_token_events = self._first_token_events, []
        return events

    def complete_iteration(self, plan: BatchPlan, now: float
                           ) -> List[Request]:
        """Apply the iteration's effects; returns newly finished requests.

        Only the plan's participants can newly finish (``generated`` only
        advances through a plan), so completion is O(batch), not
        O(running).
        """
        finished: List[Request] = []
        for req, chunk in plan.prefill:
            req.prefilled += chunk
            if req.prefilled >= req.prompt_len:
                # prompt done -> first output token is produced this iter
                req.generated += 1
                if req.first_token_time is None:
                    req.first_token_time = now
                    self._first_token_events.append(req)
                self.kv.register_prefix(req)
                if req.generated >= req.output_len:
                    finished.append(req)
        for req in plan.decode:
            req.generated += 1
            if req.generated >= req.output_len:
                finished.append(req)
        for req in finished:
            req.state = RequestState.FINISHED
            req.finish_time = now
            del self.running[req.request_id]
            self.kv.free(req)
        return finished
