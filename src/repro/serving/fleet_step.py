"""Batched mega-fleet stepping: structure-of-arrays state, event-horizon
sync, vectorized decode physics — ``ServingCluster(step_mode="batched")``.

The event loop (``repro.serving.driver``) pays a heap round-trip and a
Python engine iteration per node event; at fleet scale (1000+ nodes over
an Azure trace day, ~10^8-10^9 node steps) that is hours of pure
interpreter overhead. This backend keeps the *real* ``InferenceEngine``
objects as the source of truth for discrete state (scheduler queues, KV
cache, request objects) but mirrors every numeric scalar the hot path
touches — clock, frequency, energy, the 17 telemetry counters/gauges,
queue depths, decode context sums — into stacked numpy arrays, and steps
the whole fleet in rounds:

* **classA** (the overwhelming majority of steps in decode-heavy serving):
  nodes whose next iteration is a pure decode batch — running sequences
  only, nothing waiting, nothing prefilling, no arrival due. One numpy
  dispatch prices *all* such nodes' iterations at once through the same
  ``CostModel.iteration_cost_vec`` / ``DVFSModel.iteration_time_power_vec``
  expressions the scalar backend uses (verified bit-identical); request
  finishes are precomputed into per-node ``(finish_iteration, admission
  order)`` heaps so per-request Python runs only on the iterations where
  a request actually completes.
* **classB** (everything else — arrivals, admission, chunked prefill,
  KV-pressure blocked ticks): a three-phase vectorized admission path
  (``_step_classb``). Discrete pre-work runs against the real engine
  objects — arrival ingest, the scheduler's own ``_admit`` (so admission
  order and prefix-cache LRU/stats mutations, failed ``try_allocate``
  side effects included, are exactly the per-event loop's), plan
  selection over mirrored running-order prefill lists — then all nodes'
  mixed prefill+decode iterations are priced in one batched
  ``SimBackend.execute_mixed_vec`` dispatch, and completion (TTFT
  assignment, ``register_prefix``, finish-heap joins, blocked ticks) is
  replayed per node in the scalar engine's exact order. No real
  ``engine.step()`` runs on this path (the ``classb_engine_steps``
  counter stays 0; ``classb_path="engine"`` retains the old
  flush/step/refresh fallback for bisection). Preemption is provably
  unreachable under the ``max_num_seqs <= max_batched_tokens`` guard:
  every running request contributes to the plan, so an empty plan means
  an empty running set and only blocked idle-ticks remain structural.

Decisions run through :class:`repro.core.stacked.StackedAGFT` (one numpy
dispatch per stage for every node due this round) when the fleet is
batchable — otherwise each policy sees a per-node facade whose
reads/actuations are backed by the arrays, so arbitrary policies work
unchanged (slower). Fleet-scope policies fire at event horizons: nodes
step while their next event is strictly before the horizon ``T``, then
the fleet tick fires at ``T`` against fully flushed engines.

Equivalence contract (gated by ``tests/test_fleet_step.py``): per-node
trajectories — clocks, energies, all exported counters, finished-request
timestamps, tuner decisions and bank state — are **bit-identical** to
``EventLoop`` in both ``policy_tick_mode`` settings. Documented
measure-zero exceptions, all requiring exact float coincidences that
generated workloads do not produce:

* a FLEET_TICK and a node event at the *exact same* float instant may
  order differently (the loop steps nodes strictly before the horizon);
* a POLICY_TICK coinciding exactly with a node's event time fires after
  that step in both backends, but an arrival landing exactly on a tick
  boundary of an idle node may order differently.

``max_iters`` is honored exactly: when the remaining budget no longer
covers one step per eligible node, the loop falls back to strict
event-time single-stepping and stops on the exact step count, like
``EventLoop.run`` (under truncation the *allocation* of the final steps
across nodes follows event order, which for multi-node fleets matches
the event loop's heap order up to same-instant ties).

Heterogeneous *hardware* fleets are supported: each node carries its own
``HardwareSpec`` (frequency-terms table per node; power/overhead scalars
as per-node constant columns through the vectorized physics), and the
result is bit-identical to the per-event loop on mixed fleets just as on
homogeneous ones. Mixed AGFT fleets automatically take the facade path
(``StackedAGFT.from_tuners`` refuses differing frequency grids).

Unsupported shapes raise ``NotImplementedError`` at construction: network
routing (in-flight deliveries), fleet policy + tick mode, non-Sim
backends, heterogeneous *model* configs, an engine whose backend DVFS
spec disagrees with its hardware, ``max_num_seqs > max_batched_tokens``
(the decode-every-iteration invariant the finish heaps rely on), an
active fault model (crash evacuation and re-routing need the event
heap), and phase-disaggregated engines or policies (``freq_targets`` /
``phased = True`` — per-phase clocks need the per-event pricing path;
see ``repro.policies.phased``).
"""
from __future__ import annotations

import heapq
from typing import List, Optional, Sequence

import numpy as np

from repro.core.stacked import StackedAGFT
from repro.serving.driver import (DEFAULT_FLEET_TICK_PERIOD_S,
                                  POLICY_TICK_MODES, EngineNode,
                                  _policy_period)
from repro.energy.power_model import hw_const_rows
from repro.serving.engine import SimBackend
from repro.serving.request import RequestState

#: sentinel "no finish pending" iteration index (far beyond any run)
_BIG = 1 << 62


class _NodeFacade:
    """Engine stand-in handed to per-node policies on the facade path:
    reads come from the batched arrays, ``set_frequency`` goes through
    the loop's batched transition billing. Exposes exactly the policy-
    visible surface (the privacy boundary): clock, frequency, hardware,
    and ``metrics.snapshot()``."""

    __slots__ = ("_loop", "_i")

    def __init__(self, loop: "BatchedFleetLoop", i: int):
        self._loop = loop
        self._i = i

    @property
    def clock(self) -> float:
        return float(self._loop.clock[self._i])

    @property
    def frequency(self) -> float:
        return float(self._loop.freq[self._i])

    @property
    def hardware(self):
        return self._loop.specs[self._i]

    @property
    def metrics(self) -> "_NodeFacade":
        return self

    def snapshot(self) -> dict:
        return self._loop._snapshot_dict(self._i)

    def set_frequency(self, f_mhz: float) -> None:
        self._loop._set_frequency_one(self._i, f_mhz)


class BatchedFleetLoop:
    """Drop-in for :class:`repro.serving.driver.EventLoop` over fleets of
    simulated engines sharing one model config — per-node hardware may
    differ (see module docstring). ``run()`` returns the number of engine
    steps, like ``EventLoop.run``."""

    def __init__(self, nodes: Sequence[EngineNode], *,
                 fleet_policy: Optional[object] = None,
                 max_iters: int = 10_000_000,
                 policy_tick_mode: str = "iteration",
                 decisions: str = "auto",
                 record_history: bool = True,
                 train_cap: Optional[int] = None,
                 classb_path: str = "vector"):
        if policy_tick_mode not in POLICY_TICK_MODES:
            raise ValueError(
                f"policy_tick_mode must be one of {POLICY_TICK_MODES}, "
                f"got {policy_tick_mode!r}")
        if decisions not in ("auto", "stacked", "facade"):
            raise ValueError("decisions must be 'auto', 'stacked' or "
                             f"'facade', got {decisions!r}")
        if classb_path not in ("vector", "engine"):
            raise ValueError("classb_path must be 'vector' or 'engine', "
                             f"got {classb_path!r}")
        self.train_cap = int(train_cap) if train_cap is not None \
            else self.TRAIN_CAP
        if self.train_cap < 1:
            raise ValueError(f"train_cap must be >= 1, got {train_cap}")
        self.classb_path = classb_path
        self.nodes = list(nodes)
        self.engines = [nd.engine for nd in self.nodes]
        self.policies = [nd.policy for nd in self.nodes]
        n = len(self.engines)
        if n == 0:
            raise ValueError("BatchedFleetLoop needs at least one node")
        e0 = self.engines[0]
        if not isinstance(e0.backend, SimBackend):
            raise NotImplementedError(
                "step_mode='batched' requires SimBackend engines")
        self.hw = e0.hardware
        self.dvfs = e0.backend.dvfs
        self.cost = e0.backend.cost
        for eng in self.engines:
            if not isinstance(eng.backend, SimBackend):
                raise NotImplementedError(
                    "step_mode='batched' requires SimBackend engines")
            if eng.backend.dvfs.spec != eng.hardware:
                raise NotImplementedError(
                    "step_mode='batched' requires each engine's backend "
                    "DVFS spec to match its hardware (mixed specs are "
                    "fine; a mismatched engine is not)")
            if (eng.backend.cost.cfg != self.cost.cfg
                    or eng.backend.cost.bytes_per_el
                    != self.cost.bytes_per_el):
                raise NotImplementedError(
                    "step_mode='batched' requires a homogeneous fleet "
                    "(identical ModelConfig on every node)")
            if eng.cfg.max_num_seqs > eng.cfg.max_batched_tokens:
                raise NotImplementedError(
                    "step_mode='batched' requires max_num_seqs <= "
                    "max_batched_tokens (every running decode must fit "
                    "each iteration's token budget)")
            if getattr(eng, "inflight", 0):
                raise NotImplementedError(
                    "step_mode='batched' does not support in-flight "
                    "routed requests (network models)")
            if getattr(eng, "fault_state", None) is not None:
                raise NotImplementedError(
                    "step_mode='batched' does not support an active "
                    "fault model (crash evacuation and re-routing need "
                    "the event heap)")
            if getattr(eng, "freq_targets", None) is not None:
                raise NotImplementedError(
                    "step_mode='batched' does not support phase-"
                    "disaggregated engines (per-phase clocks need the "
                    "per-event pricing path; use step_mode='events')")
        for pol in self.policies:
            if getattr(pol, "phased", False):
                raise NotImplementedError(
                    "step_mode='batched' does not support phased "
                    "policies (agft-2d / greenllm-rule actuate "
                    "set_phase_frequencies; use step_mode='events')")
        self.fleet_policy = fleet_policy
        self.max_iters = max_iters
        self.policy_tick_mode = policy_tick_mode
        self._tick_mode = policy_tick_mode == "tick"
        if fleet_policy is not None and self._tick_mode:
            raise NotImplementedError(
                "step_mode='batched' does not support a fleet policy "
                "together with policy_tick_mode='tick'")
        self.n = n
        self.steps = 0
        self.now = 0.0
        self._round_hook = None          # test instrumentation: f(loop)
        self.backend = e0.backend        # model-homogeneity-checked above
        # --- per-node hardware (mixed fleets) -------------------------
        # The frequency-response terms table is per-node (each node's own
        # DVFSModel memo), and the power/overhead scalars become per-node
        # constant columns threaded through the vectorized physics. On a
        # homogeneous fleet every row holds the same values the scalar
        # constants held, so the arithmetic is bit-identical.
        self.specs = [eng.hardware for eng in self.engines]
        self.dvfs_by_node = [eng.backend.dvfs for eng in self.engines]
        self.hetero = any(sp != self.hw for sp in self.specs)
        self.hw_consts = hw_const_rows(self.specs)
        self.f_min_col = np.array([sp.f_min for sp in self.specs])
        self.f_max_col = np.array([sp.f_max for sp in self.specs])
        self.trans_j_col = np.array(
            [sp.dvfs_transition_cost_j for sp in self.specs])
        self.trans_s_col = np.array(
            [sp.dvfs_transition_s for sp in self.specs])
        self.p_idle_col = self.hw_consts[:, 0]
        #: real ``engine.step()`` calls (the retired classB fallback —
        #: stays 0 on the default vectorized path) and total admissions,
        #: so benchmarks can report real-steps-per-admitted-request
        self.classb_engine_steps = 0
        self.classb_fast_steps = 0
        self.admitted_requests = 0

        # --- stacked numeric state (mirrors of engine scalars) --------
        f8, i8 = np.float64, np.int64
        self.clock = np.zeros(n, f8)
        self.freq = np.zeros(n, f8)
        self.terms = np.zeros((n, 3), f8)
        self.energy = np.zeros(n, f8)
        self.busy = np.zeros(n, f8)
        self.prompt_tok = np.zeros(n, i8)
        self.cached_tok = np.zeros(n, i8)
        self.gen_tok = np.zeros(n, i8)
        self.iters = np.zeros(n, i8)
        self.fin_cnt = np.zeros(n, i8)
        self.hits = np.zeros(n, i8)
        self.queries = np.zeros(n, i8)
        self.ttft_sum = np.zeros(n, f8)
        self.ttft_cnt = np.zeros(n, i8)
        self.trans = np.zeros(n, i8)
        self.g_run = np.zeros(n, i8)
        self.g_wait = np.zeros(n, i8)
        self.g_usage = np.zeros(n, f8)
        self.g_freq = np.zeros(n, f8)
        self.g_pow = np.zeros(n, f8)
        self.usage = np.zeros(n, f8)
        # scheduler mirrors
        self.R = np.zeros(n, i8)         # len(running)
        self.W = np.zeros(n, i8)         # len(waiting)
        self.P = np.zeros(n, i8)         # prefilling rows among running
        self.D = np.zeros(n, i8)         # decode rows (R - P)
        self.S_ctx = np.zeros(n, i8)     # sum(prefilled+generated) decodes
        self.pend = np.zeros(n, i8)      # len(engine._pending)
        self.next_arrival = np.full(n, np.inf)
        # finish bookkeeping: per-node heap of (finish_iter, adm_seq, req)
        self.next_fin = np.full(n, _BIG, i8)
        self._heaps: List[list] = [[] for _ in range(n)]
        self._fin_map: List[dict] = [{} for _ in range(n)]
        self._adm_seq: List[dict] = [{} for _ in range(n)]
        self._adm_ctr = [0] * n
        # running-order prefilling requests per node — the scheduler's
        # chunk-pass order, maintained so admission plans never rescan
        # the running dict
        self._prefilling: List[list] = [[] for _ in range(n)]
        # engine-side staleness: dirty => arrays lead the engine object
        self.dirty = np.zeros(n, bool)
        self.gen_dirty = np.zeros(n, bool)

        for i in range(n):
            self._refresh(i)

        # --- decisions ------------------------------------------------
        self.stacked: Optional[StackedAGFT] = None
        if decisions in ("auto", "stacked") and fleet_policy is None \
                and all(p is not None for p in self.policies):
            self.stacked = StackedAGFT.from_tuners(
                self.policies, record_history=record_history)
        if decisions == "stacked" and self.stacked is None:
            raise ValueError(
                "decisions='stacked' but the fleet is not batchable "
                "(see StackedAGFT.from_tuners) or a fleet policy is "
                "attached")
        self._facades = (None if self.stacked is not None else
                         [_NodeFacade(self, i) for i in range(n)])

        # --- policy ticks (tick mode) ---------------------------------
        nev0 = np.where((self.R > 0) | (self.W > 0), self.clock,
                        self.next_arrival)
        if self._tick_mode:
            self.tick_period = np.zeros(n, f8)
            self.next_tick = np.full(n, np.inf)
            self.tick_alive = np.zeros(n, bool)
            for i in range(n):
                if self.policies[i] is None:
                    continue
                self.tick_period[i] = _policy_period(self.policies[i])
                if np.isfinite(nev0[i]):
                    # first tick anchors at the node's first event time
                    self.next_tick[i] = nev0[i]
                    self.tick_alive[i] = True

        # --- fleet ticks + power metering -----------------------------
        self._T: Optional[float] = None
        self._power_cap = getattr(fleet_policy, "power_cap_w", None)
        self.cap_violation_s = 0.0
        self.metered_s = 0.0
        self.metered_energy_j = 0.0
        self.peak_fleet_power_w = 0.0
        self._meter_t = 0.0
        self._meter_e = 0.0
        if fleet_policy is not None:
            self._fleet_period = getattr(fleet_policy, "sampling_period_s",
                                         DEFAULT_FLEET_TICK_PERIOD_S)
            if np.isfinite(nev0).any():
                start = float(nev0[np.isfinite(nev0)].min())
                self._meter_t = start
                self._meter_e = self._fleet_energy_j()
                init = getattr(fleet_policy, "initial_bands", None)
                if init is not None:
                    self._propagate_bands(init(self.engines))
                    for i in range(n):
                        self._refresh_actuation(i)
                self._T = start + self._fleet_period

    # ------------------------------------------------------------------
    # engine <-> array synchronization
    # ------------------------------------------------------------------
    def _refresh(self, i: int) -> None:
        """Re-mirror node ``i``'s engine into its array row (after a real
        ``engine.step()``, or at construction)."""
        eng = self.engines[i]
        c = eng.metrics.c
        self.clock[i] = eng.clock
        f = eng.frequency
        if f != self.freq[i] or not self.terms[i].any():
            self.freq[i] = f
            self.terms[i] = self.dvfs_by_node[i]._freq_terms(float(f))
        self.prompt_tok[i] = c.prompt_tokens_total
        self.cached_tok[i] = c.cached_prompt_tokens_total
        self.gen_tok[i] = c.generation_tokens_total
        self.iters[i] = c.iterations_total
        self.fin_cnt[i] = c.requests_finished_total
        self.hits[i] = c.prefix_cache_hits_total
        self.queries[i] = c.prefix_cache_queries_total
        self.energy[i] = c.energy_joules_total
        self.busy[i] = c.busy_seconds_total
        self.ttft_sum[i] = c.ttft_seconds_total
        self.ttft_cnt[i] = c.ttft_count_total
        self.trans[i] = c.freq_transitions_total
        self.g_run[i] = c.requests_running
        self.g_wait[i] = c.requests_waiting
        self.g_usage[i] = c.gpu_cache_usage
        self.g_freq[i] = c.current_frequency_mhz
        self.g_pow[i] = c.current_power_watts
        self.usage[i] = eng.kv.usage
        sched = eng.sched
        self.W[i] = len(sched.waiting)
        self.pend[i] = len(eng._pending)
        self.next_arrival[i] = (eng._pending[0][0] if eng._pending
                                else np.inf)
        heap = self._heaps[i]
        fmap = self._fin_map[i]
        aseq = self._adm_seq[i]
        ctr = self._adm_ctr[i]
        it = c.iterations_total
        pl = []
        S = 0
        for req in sched.running.values():
            rid = req.request_id
            sq = aseq.get(rid)
            if sq is None:
                # admission sequence: first-seen order over the running
                # dict == insertion order == the scheduler's decode plan
                # order, so same-iteration finishers pop in plan order
                aseq[rid] = sq = ctr
                ctr += 1
                self.admitted_requests += 1
            if req.prefilled < req.prompt_len:
                pl.append(req)
            else:
                S += req.prefilled + req.generated
                if rid not in fmap:
                    # decodes one token per iteration from here on (the
                    # max_num_seqs <= max_batched_tokens guard), so the
                    # finish iteration is fixed at join time
                    fin = it + req.output_len - req.generated
                    fmap[rid] = fin
                    heapq.heappush(heap, (fin, sq, req))
        self._adm_ctr[i] = ctr
        self._prefilling[i] = pl
        self.R[i] = len(sched.running)
        self.P[i] = len(pl)
        self.D[i] = self.R[i] - len(pl)
        self.S_ctx[i] = S
        # lazily drop entries whose request finished through a real step
        while heap and heap[0][2].state is RequestState.FINISHED:
            _, _, req = heapq.heappop(heap)
            fmap.pop(req.request_id, None)
            aseq.pop(req.request_id, None)
        self.next_fin[i] = heap[0][0] if heap else _BIG
        self.dirty[i] = False
        self.gen_dirty[i] = False

    def _flush(self, i: int) -> None:
        """Write node ``i``'s array row back into its engine (before a
        real step, a fleet tick, or at run end). No-op when the engine
        already matches (no vectorized activity since last sync)."""
        if not self.dirty[i]:
            return
        eng = self.engines[i]
        eng.clock = float(self.clock[i])
        eng.frequency = float(self.freq[i])
        c = eng.metrics.c
        c.prompt_tokens_total = int(self.prompt_tok[i])
        c.cached_prompt_tokens_total = int(self.cached_tok[i])
        c.generation_tokens_total = int(self.gen_tok[i])
        c.iterations_total = int(self.iters[i])
        c.requests_finished_total = int(self.fin_cnt[i])
        c.prefix_cache_hits_total = int(self.hits[i])
        c.prefix_cache_queries_total = int(self.queries[i])
        c.energy_joules_total = float(self.energy[i])
        c.busy_seconds_total = float(self.busy[i])
        c.ttft_seconds_total = float(self.ttft_sum[i])
        c.ttft_count_total = int(self.ttft_cnt[i])
        c.freq_transitions_total = int(self.trans[i])
        c.requests_running = int(self.g_run[i])
        c.requests_waiting = int(self.g_wait[i])
        c.gpu_cache_usage = float(self.g_usage[i])
        c.current_frequency_mhz = float(self.g_freq[i])
        c.current_power_watts = float(self.g_pow[i])
        if self.gen_dirty[i]:
            run_d = eng.sched.running
            it = int(self.iters[i])
            for rid, fin in self._fin_map[i].items():
                req = run_d.get(rid)
                if req is not None:
                    req.generated = req.output_len - (fin - it)
            self.gen_dirty[i] = False
        self.dirty[i] = False

    def _refresh_actuation(self, i: int) -> None:
        """Light re-mirror after real-engine actuation (fleet ticks call
        ``set_frequency`` on real engines): only clock / frequency /
        energy / transition count can have moved."""
        eng = self.engines[i]
        c = eng.metrics.c
        self.clock[i] = eng.clock
        self.energy[i] = c.energy_joules_total
        self.trans[i] = c.freq_transitions_total
        f = eng.frequency
        if f != self.freq[i]:
            self.freq[i] = f
            self.terms[i] = self.dvfs_by_node[i]._freq_terms(float(f))

    # ------------------------------------------------------------------
    # telemetry views
    # ------------------------------------------------------------------
    def _snap_matrix(self, idx: np.ndarray) -> np.ndarray:
        """Rows of ``MetricsExporter.snapshot()`` values in ``SNAP_KEYS``
        order for the nodes in ``idx`` — the StackedAGFT input."""
        m = np.empty((len(idx), 17))
        m[:, 0] = self.prompt_tok[idx]
        m[:, 1] = self.cached_tok[idx]
        m[:, 2] = self.gen_tok[idx]
        m[:, 3] = self.iters[idx]
        m[:, 4] = self.fin_cnt[idx]
        m[:, 5] = self.hits[idx]
        m[:, 6] = self.queries[idx]
        m[:, 7] = self.energy[idx]
        m[:, 8] = self.busy[idx]
        m[:, 9] = self.ttft_sum[idx]
        m[:, 10] = self.ttft_cnt[idx]
        m[:, 11] = self.trans[idx]
        m[:, 12] = self.g_run[idx]
        m[:, 13] = self.g_wait[idx]
        m[:, 14] = self.g_usage[idx]
        m[:, 15] = self.g_freq[idx]
        m[:, 16] = self.g_pow[idx]
        return m

    def _snapshot_dict(self, i: int) -> dict:
        """A single node's snapshot as the exporter dict (facade path)."""
        return {
            "vllm:prompt_tokens_total": int(self.prompt_tok[i]),
            "vllm:cached_prompt_tokens_total": int(self.cached_tok[i]),
            "vllm:generation_tokens_total": int(self.gen_tok[i]),
            "vllm:iterations_total": int(self.iters[i]),
            "vllm:requests_finished_total": int(self.fin_cnt[i]),
            "vllm:prefix_cache_hits_total": int(self.hits[i]),
            "vllm:prefix_cache_queries_total": int(self.queries[i]),
            "vllm:energy_joules_total": float(self.energy[i]),
            "vllm:busy_seconds_total": float(self.busy[i]),
            "vllm:ttft_seconds_total": float(self.ttft_sum[i]),
            "vllm:ttft_count_total": int(self.ttft_cnt[i]),
            "vllm:freq_transitions_total": int(self.trans[i]),
            "vllm:num_requests_running": int(self.g_run[i]),
            "vllm:num_requests_waiting": int(self.g_wait[i]),
            "vllm:gpu_cache_usage_perc": float(self.g_usage[i]),
            "vllm:current_frequency_mhz": float(self.g_freq[i]),
            "vllm:current_power_watts": float(self.g_pow[i]),
        }

    # ------------------------------------------------------------------
    # actuation (engine.set_frequency semantics over arrays)
    # ------------------------------------------------------------------
    def _actuate(self, idx: np.ndarray, f: np.ndarray) -> None:
        # Per-node clamp and transition billing: on a homogeneous fleet
        # every column holds the scalar spec's value, and adding a 0.0
        # transition cost is a bitwise no-op for the non-negative energy
        # and clock accumulators, so this is the identical arithmetic the
        # scalar-spec version performed.
        f = np.minimum(np.maximum(f, self.f_min_col[idx]),
                       self.f_max_col[idx])
        ch = f != self.freq[idx]
        if ch.any():
            chi = idx[ch]
            self.trans[chi] += 1
            self.energy[chi] += self.trans_j_col[chi]
            self.clock[chi] += self.trans_s_col[chi]
            fch = f[ch]
            for j, i in enumerate(chi.tolist()):
                self.terms[i] = self.dvfs_by_node[i]._freq_terms(
                    float(fch[j]))
            self.dirty[chi] = True
        self.freq[idx] = f

    def _set_frequency_one(self, i: int, f_mhz: float) -> None:
        sp = self.specs[i]
        f = min(max(f_mhz, sp.f_min), sp.f_max)
        if f != self.freq[i]:
            self.trans[i] += 1
            if sp.dvfs_transition_cost_j > 0.0:
                self.energy[i] += sp.dvfs_transition_cost_j
            if sp.dvfs_transition_s > 0.0:
                self.clock[i] += sp.dvfs_transition_s
            self.terms[i] = self.dvfs_by_node[i]._freq_terms(float(f))
            self.dirty[i] = True
        self.freq[i] = f

    def _iter_hook(self, idx: np.ndarray, f: np.ndarray) -> np.ndarray:
        """StackedAGFT actuation hook, iteration mode: apply the batched
        ``set_frequency`` and hand back the POST-transition clocks — the
        scalar tuner's history records ``engine.clock`` after actuation."""
        self._actuate(idx, f)
        return self.clock[idx].copy()

    def _tick_hook(self, idx: np.ndarray, f: np.ndarray) -> None:
        """Tick-mode hook: actuate, but history keeps the tick times."""
        self._actuate(idx, f)
        return None

    # ------------------------------------------------------------------
    # stepping
    # ------------------------------------------------------------------
    #: max decode iterations advanced per node per round. Horizon cuts
    #: (arrival / policy due / finish / fleet tick) bound trains anyway;
    #: the cap bounds wasted speculative physics past a cut. Measured on
    #: the 1000-node Azure day replay (``benchmarks/tab_megafleet.py
    #: --train-cap sweep``, 1h slice): 64 beats 8 by ~20% and 256 by
    #: ~16% node-iterations/sec — small caps pay per-round dispatch
    #: overhead more often, large caps price physics past the typical
    #: ~2s policy horizon that then gets thrown away.
    TRAIN_CAP = 64

    def _policy_horizon(self, idx: np.ndarray) -> np.ndarray:
        """Per-node next policy-decision time for the nodes in ``idx`` —
        the iteration-mode train cut. ``inf`` = no policy; ``-inf`` =
        opaque policy (can't see its sampler), forcing 1-step trains so
        ``maybe_act`` still runs after every iteration."""
        if self.stacked is not None:
            return self.stacked.next_sample[idx]
        ns = np.empty(len(idx))
        for j, i in enumerate(idx.tolist()):
            pol = self.policies[i]
            if pol is None:
                ns[j] = np.inf
            else:
                ns[j] = getattr(getattr(pol, "monitor", None),
                                "next_sample", -np.inf)
        return ns

    def _step_trains(self, idx: np.ndarray, cap: int) -> int:
        """Advance every pure-decode node in ``idx`` by a *train* of up
        to ``cap`` consecutive iterations, cut at its next event horizon:
        request finish, pending arrival, policy decision (sample due /
        tick), or fleet tick. Within a train nothing discrete happens, so
        the whole trajectory is computable up front — the vectorized
        mirror of repeated ``run_iteration`` + ``SimBackend.execute``
        all-decode steps. Clock/energy/busy accumulate through a
        leading-element ``cumsum`` (numpy's axis-1 cumsum is the
        sequential left fold), so every intermediate value is
        bit-identical to the scalar ``+=`` chain. Returns the number of
        engine steps taken."""
        k_n = len(idx)
        m = np.minimum(self.next_fin[idx] - self.iters[idx], cap)
        Mm = int(m.max())
        D = self.D[idx]
        S = (self.S_ctx[idx][:, None]
             + D[:, None] * np.arange(Mm, dtype=np.int64)[None, :])
        avg = S / D[:, None]
        flops, mem = self.cost.iteration_cost_vec(
            prefill_tokens=np.zeros((k_n, 1), np.int64),
            decode_seqs=D[:, None], avg_context=avg)
        mem = np.maximum(mem, 0.0)
        t, p = self.dvfs.iteration_time_power_vec(
            flops, mem, self.terms[idx][:, None, :],
            hw=self.hw_consts[idx][:, None, :])
        cat = np.empty((k_n, Mm + 1))
        cat[:, 0] = self.clock[idx]
        cat[:, 1:] = t
        c = np.cumsum(cat, axis=1)
        # arrival + fleet horizon: iteration j+1 runs iff its start clock
        # c[:, j] is still before the horizon (the event loop pops the
        # earlier event otherwise)
        k_cut = np.sum(c[:, :Mm] < self.next_arrival[idx][:, None],
                       axis=1)
        if self._T is not None:
            k_cut = np.minimum(k_cut, np.sum(c[:, :Mm] < self._T, axis=1))
        if self._tick_mode:
            # a POLICY_TICK at tau fires only strictly before the next
            # node event: iteration j+1 (at c[:, j]) still runs when
            # tau >= c[:, j]
            if Mm > 1:
                tau = self.next_tick[idx]
                k_cut = np.minimum(
                    k_cut, 1 + np.sum(tau[:, None] >= c[:, 1:Mm], axis=1))
            else:
                k_cut = np.minimum(k_cut, 1)
        else:
            # iteration mode checks maybe_act after EVERY step: stop at
            # the first iteration whose end clock crosses next_sample
            ns = self._policy_horizon(idx)
            if Mm > 1:
                k_cut = np.minimum(
                    k_cut, 1 + np.sum(c[:, 1:Mm] < ns[:, None], axis=1))
            else:
                k_cut = np.minimum(k_cut, 1)
        k = np.minimum(m, k_cut)
        rows = np.arange(k_n)
        self.clock[idx] = c[rows, k]
        cat[:, 1:] = p * t
        cat[:, 0] = self.energy[idx]
        self.energy[idx] = np.cumsum(cat, axis=1)[rows, k]
        cat[:, 1:] = t
        cat[:, 0] = self.busy[idx]
        self.busy[idx] = np.cumsum(cat, axis=1)[rows, k]
        self.gen_tok[idx] += D * k
        self.iters[idx] += k
        self.S_ctx[idx] += D * k
        fin_due = self.next_fin[idx] == self.iters[idx]
        if fin_due.any():
            for i in idx[fin_due].tolist():
                self._process_finishers(i)
        self.g_run[idx] = self.R[idx]
        self.g_wait[idx] = self.pend[idx]
        self.g_usage[idx] = self.usage[idx]
        self.g_freq[idx] = self.freq[idx]
        self.g_pow[idx] = p[rows, k - 1]
        self.dirty[idx] = True
        self.gen_dirty[idx] = True
        return int(k.sum())

    def _process_finishers(self, i: int) -> None:
        """Complete every request whose precomputed finish iteration is
        due on node ``i`` — the per-request tail of the scheduler's
        ``complete_iteration`` (state, finish_time, running-dict removal,
        KV free) in decode-plan order."""
        eng = self.engines[i]
        run_d = eng.sched.running
        kv = eng.kv
        heap = self._heaps[i]
        fmap = self._fin_map[i]
        aseq = self._adm_seq[i]
        it = int(self.iters[i])
        clk = float(self.clock[i])
        n_f = 0
        while heap and heap[0][0] <= it:
            fin, _, req = heapq.heappop(heap)
            rid = req.request_id
            if req.state is RequestState.FINISHED or fmap.get(rid) != fin:
                fmap.pop(rid, None)
                aseq.pop(rid, None)
                continue
            req.generated = req.output_len
            req.state = RequestState.FINISHED
            req.finish_time = clk
            del run_d[rid]
            del fmap[rid]
            aseq.pop(rid, None)
            kv.free(req)
            eng.finished.append(req)
            self.S_ctx[i] -= req.prefilled + req.output_len
            n_f += 1
        self.R[i] -= n_f
        self.D[i] -= n_f
        self.fin_cnt[i] += n_f
        self.usage[i] = kv.usage
        self.next_fin[i] = heap[0][0] if heap else _BIG

    def _step_py(self, i: int) -> None:
        """One real engine step for node ``i`` — the retired classB
        fallback, kept behind ``classb_path='engine'`` for bisection and
        the equivalence suite's cross-check of the vectorized path."""
        self.classb_engine_steps += 1
        self._flush(i)
        self.engines[i].step()
        self._refresh(i)

    def _step_classb(self, b_idx: np.ndarray) -> int:
        """One engine iteration for every structural node in ``b_idx`` —
        arrivals, admission, chunked prefill, blocked ticks — with **no**
        real ``engine.step()`` calls. Three phases:

        1. per-node discrete pre-work against the real engine objects:
           arrival ingest, idle-advance billing, the scheduler's own
           ``_admit`` (so prefix-cache ``try_allocate`` side effects —
           stats and LRU motion on failure included — are the event
           loop's by construction), and plan selection over the mirrored
           running-order prefill lists;
        2. one batched ``SimBackend.execute_mixed_vec`` dispatch pricing
           every node's mixed prefill+decode iteration;
        3. per-node completion replay in the scalar engine's exact order:
           chunk advancement, first-token assignment + TTFT accounting,
           ``register_prefix``, instant finishers, then decode-finish
           heap joins and ``_process_finishers``.

        Preemption is unreachable here: with ``max_num_seqs <=
        max_batched_tokens`` every running request contributes to the
        plan, so an empty plan means an empty running set and the scalar
        engine's preemption scan is a guaranteed no-op before its blocked
        tick. Returns the number of engine steps taken (== len(b_idx);
        blocked ticks are steps too)."""
        p_idle_l = self.p_idle_col
        r_node: List[int] = []
        r_clk: List[float] = []
        r_pf: List[list] = []
        r_pf_tok: List[int] = []
        r_pf_cnt: List[int] = []
        r_pf_ctx: List[float] = []
        r_ctok: List[int] = []
        r_dec: List[int] = []
        r_dctx: List[int] = []
        r_newdec: List[list] = []
        inf = np.inf
        clk_a = self.clock[b_idx].tolist()
        D_a = self.D[b_idx].tolist()
        S_a = self.S_ctx[b_idx].tolist()
        for k, i in enumerate(b_idx.tolist()):
            eng = self.engines[i]
            sched = eng.sched
            pend = eng._pending
            add = sched.add_request
            clk = clk_a[k]
            while pend and pend[0][0] <= clk:
                add(heapq.heappop(pend)[2])
            if not (sched.running or sched.waiting):
                # idle engine: ``step`` advances to the next arrival,
                # billing idle energy for the gap (advance_to semantics)
                t_arr = pend[0][0]
                dt = t_arr - clk
                if dt < 0.0:
                    dt = 0.0
                self.energy[i] += p_idle_l[i] * dt
                if t_arr > clk:
                    clk = t_arr
                while pend and pend[0][0] <= clk:
                    add(heapq.heappop(pend)[2])
            # _admit's own first move is this same emptiness check; doing
            # it here skips the call entirely on no-queue steps
            admitted = sched._admit(clk) if sched.waiting else ()
            newdec: list = ()
            if admitted:
                aseq = self._adm_seq[i]
                ctr = self._adm_ctr[i]
                pl = self._prefilling[i]
                newdec = []
                for req in admitted:
                    aseq[req.request_id] = ctr
                    ctr += 1
                    if req.prefilled < req.prompt_len:
                        pl.append(req)
                    else:
                        newdec.append(req)     # fully prefix-cached
                self._adm_ctr[i] = ctr
                self.admitted_requests += len(admitted)
            # the scheduler's batch pass: every running decode fits (the
            # max_num_seqs <= max_batched_tokens guard), then chunked
            # prefill over the running-order prefilling mirror
            dec_n = D_a[k] + len(newdec)
            dctx = S_a[k]
            for req in newdec:
                dctx += req.prefilled          # generated == 0 here
            budget = sched.max_batched_tokens - dec_n
            chunk_cap = sched.prefill_chunk
            pf: list = []
            pf_tok = 0
            pf_ctx = 0.0
            ctok = 0
            for req in self._prefilling[i]:
                if budget <= 0:
                    break
                chunk = req.prompt_len - req.prefilled
                if chunk > chunk_cap:
                    chunk = chunk_cap
                if chunk > budget:
                    chunk = budget
                pf.append((req, chunk))
                pf_tok += chunk
                # prefix-cache credit is read while the request sits on
                # its first chunk, exactly as run_iteration's pre-execute
                # pass does
                if req.cached_tokens and req.prefilled == req.cached_tokens:
                    ctok += req.cached_tokens
                pf_ctx += req.prefilled + chunk / 2
                budget -= chunk
            if not pf and not dec_n:
                # empty plan <=> empty running set (see docstring): the
                # engine burns a blocked millisecond at idle power — no
                # metric writes, only the classification mirrors move
                self.energy[i] += p_idle_l[i] * 1e-3
                self.clock[i] = clk + 1e-3
                self.W[i] = len(sched.waiting)
                self.pend[i] = len(pend)
                self.next_arrival[i] = pend[0][0] if pend else inf
                self.dirty[i] = True
                continue
            r_node.append(i)
            r_clk.append(clk)
            r_pf.append(pf)
            r_pf_tok.append(pf_tok)
            r_pf_cnt.append(len(pf))
            r_pf_ctx.append(pf_ctx)
            r_ctok.append(ctok)
            r_dec.append(dec_n)
            r_dctx.append(dctx)
            r_newdec.append(newdec)

        steps = len(b_idx)
        self.classb_fast_steps += steps
        if not r_node:
            return steps
        rows = np.asarray(r_node, np.int64)
        pf_tok_v = np.asarray(r_pf_tok, np.int64)
        dec_v = np.asarray(r_dec, np.int64)
        t_v, e_v, p_v = self.backend.execute_mixed_vec(
            pf_tok_v, np.asarray(r_pf_cnt, np.int64),
            np.asarray(r_pf_ctx), dec_v,
            np.asarray(r_dctx, np.int64), self.terms[rows],
            hw=self.hw_consts[rows])

        # completion replay accumulates its per-row counter outcomes in
        # plain lists and commits them as one scatter per array below —
        # the per-row loop touches only real objects (requests, heaps,
        # the scheduler) plus the rare TTFT accumulators. The elementwise
        # arithmetic (int sums, one f8 add per element on unique rows)
        # is the scalar writes' exactly.
        finished_state = RequestState.FINISHED
        clk_v = np.asarray(r_clk) + t_v
        clk_l = clk_v.tolist()
        it_v = self.iters[rows] + 1
        it_l = it_v.tolist()
        gen_pf_l: List[int] = []
        n_fin_l: List[int] = []
        n_join_l: List[int] = []
        join_ctx_l: List[int] = []
        nf_l: List[int] = []
        R_l: List[int] = []
        P_l: List[int] = []
        W_l: List[int] = []
        npend_l: List[int] = []
        narr_l: List[float] = []
        hits_l: List[int] = []
        q_l: List[int] = []
        usage_l: List[float] = []
        for j, i in enumerate(r_node):
            eng = self.engines[i]
            sched = eng.sched
            kv = eng.kv
            pend = eng._pending
            clk = clk_l[j]
            it = it_l[j]
            heap = self._heaps[i]
            fmap = self._fin_map[i]
            aseq = self._adm_seq[i]
            gen_pf = 0
            n_join = 0
            join_ctx = 0
            fin_pf: list = ()
            pf = r_pf[j]
            if pf:
                completed = False
                for req, chunk in pf:
                    req.prefilled += chunk
                    if req.prefilled >= req.prompt_len:
                        # prompt done -> first output token this iter
                        completed = True
                        gen_pf += 1
                        req.generated += 1
                        if req.first_token_time is None:
                            req.first_token_time = clk
                            self.ttft_sum[i] += clk - req.arrival_time
                            self.ttft_cnt[i] += 1
                        kv.register_prefix(req)
                        if req.generated >= req.output_len:
                            if fin_pf == ():
                                fin_pf = []
                            fin_pf.append(req)
                        else:
                            rid = req.request_id
                            fin = it + req.output_len - req.generated
                            fmap[rid] = fin
                            heapq.heappush(heap, (fin, aseq[rid], req))
                            n_join += 1
                            join_ctx += req.prefilled + req.generated
                if completed:
                    self._prefilling[i] = [
                        r for r in self._prefilling[i]
                        if r.prefilled < r.prompt_len]
                if fin_pf:
                    # the scalar finished loop runs after both plan
                    # halves; prefill finishers free their KV before the
                    # decode finishers (matched by _process_finishers
                    # running below)
                    run_d = sched.running
                    done = eng.finished
                    for req in fin_pf:
                        rid = req.request_id
                        req.state = finished_state
                        req.finish_time = clk
                        del run_d[rid]
                        aseq.pop(rid, None)
                        kv.free(req)
                        done.append(req)
            for req in r_newdec[j]:
                # admitted fully-cached: decodes from this very
                # iteration, so the finish iteration is fixed now;
                # ``generated`` stays implicit (reconstructed by _flush
                # from the finish map, like train decodes)
                rid = req.request_id
                fin = it + req.output_len - 1
                fmap[rid] = fin
                heapq.heappush(heap, (fin, aseq[rid], req))
            gen_pf_l.append(gen_pf)
            n_fin_l.append(len(fin_pf))
            n_join_l.append(n_join)
            join_ctx_l.append(join_ctx)
            nf_l.append(heap[0][0] if heap else _BIG)
            R_l.append(len(sched.running))
            P_l.append(len(self._prefilling[i]))
            W_l.append(len(sched.waiting))
            n_p = len(pend)
            npend_l.append(n_p)
            narr_l.append(pend[0][0] if n_p else inf)
            st = kv.stats
            hits_l.append(st.hits)
            q_l.append(st.queries)
            usage_l.append(kv.usage)
        nf_v = np.asarray(nf_l, np.int64)
        n_fin_v = np.asarray(n_fin_l, np.int64)
        w_v = np.asarray(W_l, np.int64)
        npend_v = np.asarray(npend_l, np.int64)
        self.clock[rows] = clk_v
        self.energy[rows] += e_v
        self.busy[rows] += t_v
        self.prompt_tok[rows] += pf_tok_v
        self.cached_tok[rows] += np.asarray(r_ctok, np.int64)
        self.gen_tok[rows] += dec_v + np.asarray(gen_pf_l, np.int64)
        self.iters[rows] = it_v
        self.fin_cnt[rows] += n_fin_v
        self.hits[rows] = hits_l
        self.queries[rows] = q_l
        # decode contexts grew by one token each; prefill completers
        # join the decode pool at their post-iteration context
        self.S_ctx[rows] = np.asarray(r_dctx, np.int64) + dec_v \
            + np.asarray(join_ctx_l, np.int64)
        self.D[rows] = dec_v + np.asarray(n_join_l, np.int64)
        self.R[rows] = R_l
        self.P[rows] = P_l
        self.W[rows] = w_v
        self.pend[rows] = npend_v
        self.next_arrival[rows] = narr_l
        self.g_wait[rows] = w_v + npend_v
        self.next_fin[rows] = nf_v
        self.usage[rows] = usage_l
        self.gen_dirty[rows[dec_v > 0]] = True
        self.dirty[rows] = True
        self.g_freq[rows] = self.freq[rows]
        self.g_pow[rows] = p_v
        due = nf_v <= it_v
        if due.any():
            # decode finishers whose precomputed iteration just came due;
            # runs after the scatters (it reads iters/clock and rewrites
            # S_ctx/R/D/fin_cnt/usage/next_fin for the nodes it touches)
            for i in rows[due].tolist():
                self._process_finishers(i)
        # gauge tail of run_iteration's metric block (post-finisher state)
        self.g_run[rows] = self.R[rows]
        self.g_usage[rows] = self.usage[rows]
        return steps

    # ------------------------------------------------------------------
    # policies
    # ------------------------------------------------------------------
    def _policy_phase(self, stepped: np.ndarray) -> None:
        """Iteration-mode decisions for every node stepped this round —
        the batched mirror of ``policy.maybe_act(engine)`` after
        ``engine.step()``."""
        if self.stacked is not None:
            due = stepped[self.clock[stepped]
                          >= self.stacked.next_sample[stepped]]
            if len(due):
                self.stacked.act(due, self._snap_matrix(due),
                                 self.clock[due].copy(),
                                 actuate=self._iter_hook)
        else:
            for i in stepped.tolist():
                pol = self.policies[i]
                if pol is not None:
                    pol.maybe_act(self._facades[i])

    def _fire_ticks(self, nev: np.ndarray) -> None:
        """Tick-mode decisions: fire every POLICY_TICK scheduled strictly
        before its node's next event (ticks at exactly the event time
        fire after the step — POLICY_TICK yields to node events in the
        event loop's same-time ordering)."""
        while True:
            due = self.tick_alive & (self.next_tick < nev)
            if not due.any():
                break
            idx = np.flatnonzero(due)
            t = self.next_tick[idx].copy()
            if self.stacked is not None:
                self.stacked.act(idx, self._snap_matrix(idx), t,
                                 actuate=self._tick_hook)
            else:
                for j, i in enumerate(idx.tolist()):
                    pol = self.policies[i]
                    tick = getattr(pol, "tick", None)
                    if tick is not None:
                        tick(self._facades[i], float(t[j]))
                    else:
                        pol.maybe_act(self._facades[i])
            self.next_tick[idx] = t + self.tick_period[idx]

    # ------------------------------------------------------------------
    # fleet ticks + power metering (EventLoop semantics)
    # ------------------------------------------------------------------
    def _fleet_energy_j(self) -> float:
        # ordered Python sum over flushed engines — same accumulation
        # order (and hence bits) as EventLoop._fleet_energy_j
        return sum(nd.engine.metrics.c.energy_joules_total
                   for nd in self.nodes)

    def _meter_power(self, t: float) -> None:
        if self._power_cap is None:
            return
        e = self._fleet_energy_j()
        if t > self._meter_t:
            dt = t - self._meter_t
            de = e - self._meter_e
            p = de / dt
            self.metered_s += dt
            self.metered_energy_j += de
            if p > self.peak_fleet_power_w:
                self.peak_fleet_power_w = p
            if p > self._power_cap:
                self.cap_violation_s += dt
        self._meter_t, self._meter_e = t, e

    @property
    def mean_fleet_power_w(self) -> float:
        return (self.metered_energy_j / self.metered_s
                if self.metered_s > 0 else 0.0)

    def _propagate_bands(self, bands) -> None:
        """EventLoop._propagate_bands against the real nodes (engines are
        flushed whenever this runs)."""
        if not bands:
            return
        for i, band in enumerate(bands):
            if band is None:
                continue
            lo, hi = band
            if lo > hi:
                lo, hi = hi, lo
            set_band = getattr(self.policies[i], "set_band", None)
            if set_band is not None:
                set_band(lo, hi)
            eng = self.engines[i]
            f = min(max(eng.frequency, lo), hi)
            if f != eng.frequency:
                eng.set_frequency(f)

    def _fire_fleet_tick(self) -> None:
        T = self._T
        for i in range(self.n):
            self._flush(i)
        self.fleet_policy.act(self.engines, T)
        self._propagate_bands(getattr(self.fleet_policy, "bands", None))
        self._meter_power(T)
        for i in range(self.n):
            self._refresh_actuation(i)
        if T > self.now:
            self.now = T
        self._T = T + self._fleet_period

    # ------------------------------------------------------------------
    def run(self) -> int:
        while self.steps < self.max_iters:
            sched_work = (self.R > 0) | (self.W > 0)
            nev = np.where(sched_work, self.clock, self.next_arrival)
            active = np.isfinite(nev)
            if self._tick_mode:
                # drained nodes' tick trains die silently, as the event
                # loop's dying POLICY_TICK pop does
                dead = self.tick_alive & ~active
                if dead.any():
                    self.tick_alive[dead] = False
            if not active.any():
                break
            if self._T is not None:
                eligible = active & (nev < self._T)
                if not eligible.any():
                    self._fire_fleet_tick()
                    continue
            else:
                eligible = active
            if self._tick_mode:
                self._fire_ticks(nev)
                # tick actuation can advance clocks (transition stalls):
                # a pending arrival may now be due — reclassify below
            classB = eligible & (~sched_work | (self.W > 0) | (self.P > 0)
                                 | (self.next_arrival <= self.clock))
            a_idx = np.flatnonzero(eligible & ~classB)
            b_idx = np.flatnonzero(classB)
            fast = self.classb_path == "vector"
            remaining = self.max_iters - self.steps
            if remaining < len(a_idx) + len(b_idx):
                # the budget can't cover one step per eligible node this
                # round: finish in strict event-time order, one step at
                # a time, so the loop lands exactly on max_iters like
                # EventLoop.run
                elig = np.flatnonzero(eligible)
                j = int(elig[int(np.argmin(nev[elig]))])
                jj = np.asarray([j])
                if classB[j]:
                    if fast:
                        self.steps += self._step_classb(jj)
                    else:
                        self._step_py(j)
                        self.steps += 1
                else:
                    self.steps += self._step_trains(jj, 1)
                t_j = float(nev[j])
                if t_j > self.now:
                    self.now = t_j
                if not self._tick_mode:
                    self._policy_phase(jj)
                if self._round_hook is not None:
                    self._round_hook(self)
                continue
            if len(a_idx):
                cap = self.train_cap
                budget_a = remaining - len(b_idx)
                if budget_a < len(a_idx) * cap:
                    cap = budget_a // len(a_idx)   # >= 1 by the branch above
                self.steps += self._step_trains(a_idx, cap)
            if len(b_idx):
                if fast:
                    self.steps += self._step_classb(b_idx)
                else:
                    for i in b_idx.tolist():
                        self._step_py(i)
                    self.steps += len(b_idx)
            t_max = float(np.max(nev[eligible]))
            if t_max > self.now:
                self.now = t_max
            if not self._tick_mode:
                self._policy_phase(np.flatnonzero(eligible))
            if self._round_hook is not None:
                self._round_hook(self)

        drained = not np.isfinite(
            np.where((self.R > 0) | (self.W > 0), self.clock,
                     self.next_arrival)).any()
        for i in range(self.n):
            self._flush(i)
        if self.stacked is not None:
            self.stacked.writeback()
        if self.fleet_policy is not None:
            if drained and self._T is not None and self._T > self.now:
                # the pending FLEET_TICK pops once more (and dies); its
                # pop still advances the loop's virtual now
                self.now = self._T
            self._meter_power(max([self.now]
                                  + [float(x) for x in self.clock]))
        return self.steps
