"""Paged KV-cache manager with prefix caching (vLLM-style).

Block-granular allocation; prompt prefixes deriving from a shared template
are content-addressed so repeated templates hit cached blocks instead of
recomputing prefill (the mechanism behind the paper's "High Cache Hit"
prototype and the ``cache_hit_rate`` fingerprint dimension).

Accounting invariant (property-tested):
    num_blocks == free_blocks + sum(seq_blocks.values()) + len(prefix_blocks)
Every resident block is exactly one of: free, owned by a sequence, or a
cache-resident prefix block (shared read-only; refcount counts borrowers).

The hot paths (``lookup_prefix``/``try_allocate``/``register_prefix``) run
once per admission at fleet scale — millions of times per mega-fleet
replay — so they are written dict-local-and-branch-lean: attribute loads
hoisted out of per-block loops, cache statistics accumulated per call
instead of per block, and registration evicting its shortfall in one bulk
LRU sweep (exactly equivalent to per-block eviction: newly registered
blocks always enter at the LRU tail, so the victims of n sequential
single evictions are the same n oldest unreferenced blocks a single bulk
sweep selects).
"""
from __future__ import annotations

import collections
import dataclasses
from typing import Dict, List

from repro.serving.request import Request

_NO_KEYS: List[int] = []


@dataclasses.dataclass
class CacheStats:
    hits: int = 0          # block-granular prefix-cache hits
    queries: int = 0       # block-granular lookups
    preemptions: int = 0

    @property
    def hit_rate(self) -> float:
        return self.hits / self.queries if self.queries else 0.0


class PagedKVCache:
    """Block placement + prefix cache. Simulation-grade: tracks occupancy,
    not tensors — the tensors live in the model cache pytree; this layer
    produces the usage/hit-rate metrics the AGFT fingerprint consumes."""

    def __init__(self, num_blocks: int, block_size: int = 16,
                 enable_prefix_cache: bool = True):
        self.num_blocks = num_blocks
        self.block_size = block_size
        self.enable_prefix_cache = enable_prefix_cache
        self.free_blocks = num_blocks
        self.seq_blocks: Dict[int, int] = {}             # request_id -> count
        self.seq_borrowed: Dict[int, List[int]] = {}
        self.prefix_blocks: Dict[int, int] = {}          # key -> refcount
        self.prefix_lru: collections.OrderedDict = collections.OrderedDict()
        # cached blocks with refcount 0 — lets the LRU eviction sweep
        # short-circuit when the whole cache is borrowed (the steady state
        # of a saturated long run, where scanning would find nothing)
        self._evictable = 0
        # per-template prefix-key chains, memoised and grown in place: key
        # i of a template's chain is always (template_id << 32) | i — a
        # packed int, so chains build at C speed from range() and hash as
        # small ints — and a shorter request's chain is a prefix slice of
        # the longest one built so far
        self._keys_memo: Dict[int, List[int]] = {}
        self.stats = CacheStats()

    # ------------------------------------------------------------------
    def blocks_needed(self, tokens: int) -> int:
        return -(-tokens // self.block_size)

    @property
    def used_blocks(self) -> int:
        return self.num_blocks - self.free_blocks

    @property
    def usage(self) -> float:
        return self.used_blocks / self.num_blocks if self.num_blocks else 0.0

    def check_invariant(self) -> bool:
        return (self.free_blocks + sum(self.seq_blocks.values())
                + len(self.prefix_blocks)) == self.num_blocks

    # ------------------------------------------------------------------
    def _prefix_keys(self, req: Request) -> List[int]:
        """The request's chain, as a shared memo list (callers iterate or
        copy-slice; they never mutate the returned list)."""
        n = int(req.prompt_len * req.template_frac) // self.block_size
        if n <= 0:
            return _NO_KEYS
        tid = req.template_id
        memo = self._keys_memo.get(tid)
        if memo is None:
            base = tid << 32
            memo = list(range(base, base + n))
            self._keys_memo[tid] = memo
            return memo
        ln = len(memo)
        if ln < n:
            base = tid << 32
            memo.extend(range(base + ln, base + n))
            return memo
        if ln == n:
            return memo
        return memo[:n]

    def lookup_prefix(self, req: Request) -> int:
        """Longest cached prefix (tokens); records hit/miss stats."""
        if not self.enable_prefix_cache:
            return 0
        keys = self._prefix_keys(req)
        hits = 0
        pb = self.prefix_blocks
        move = self.prefix_lru.move_to_end
        for key in keys:
            if key in pb:
                hits += 1
                move(key)
            else:
                break                                    # prefixes are chains
        st = self.stats
        st.queries += hits + 1 if hits < len(keys) else hits
        st.hits += hits
        return hits * self.block_size

    def _evict_prefix(self, n: int) -> int:
        """Evict up to n unreferenced cached blocks (LRU order)."""
        want = min(n, self._evictable)
        if want <= 0:
            return 0
        # collect victims with an early-exit scan (no full-LRU snapshot:
        # the head of the order is where unreferenced blocks live, so this
        # stops after O(victims) entries in the common case)
        pb = self.prefix_blocks
        victims: List[int] = []
        for key in self.prefix_lru:
            if pb[key] == 0:
                victims.append(key)
                if len(victims) >= want:
                    break
        lru = self.prefix_lru
        for key in victims:
            del pb[key]
            del lru[key]
        self.free_blocks += len(victims)
        self._evictable -= len(victims)
        return len(victims)

    def try_allocate(self, req: Request, total_tokens: int) -> bool:
        """Reserve capacity for prompt+generation. Cached prefix blocks are
        borrowed (shared); the remainder comes from the free pool, evicting
        idle cached blocks if required. All-or-nothing."""
        cached_tokens = self.lookup_prefix(req)
        shared_blocks = cached_tokens // self.block_size
        need = max(0, self.blocks_needed(total_tokens) - shared_blocks)
        # take references on the matched prefix BEFORE any eviction, so the
        # LRU sweep cannot free the very blocks this request matched on
        borrowed = self._prefix_keys(req)[:shared_blocks]
        pb = self.prefix_blocks
        evictable = self._evictable
        for key in borrowed:
            refs = pb[key]
            if refs == 0:
                evictable -= 1
            pb[key] = refs + 1
        self._evictable = evictable
        if need > self.free_blocks:
            self._evict_prefix(need - self.free_blocks)
        if need > self.free_blocks:
            evictable = self._evictable        # re-read: eviction moved it
            for key in borrowed:                       # rollback
                refs = pb[key] - 1
                pb[key] = refs
                if refs == 0:
                    evictable += 1
            self._evictable = evictable
            return False
        self.free_blocks -= need
        self.seq_blocks[req.request_id] = need
        self.seq_borrowed[req.request_id] = borrowed
        req.cached_tokens = cached_tokens
        return True

    def register_prefix(self, req: Request) -> None:
        """After prefill completes, publish the request's template prefix
        into the cache (copy-on-publish: new cached blocks come from the
        free pool; skipped under pressure). The expected shortfall is
        evicted in one bulk sweep up front; the per-block fallback only
        fires when eviction victims were themselves later links of this
        chain (which the live membership re-check then re-registers)."""
        if not self.enable_prefix_cache:
            return
        pb = self.prefix_blocks
        keys = self._prefix_keys(req)
        n_missing = len(keys) - sum(map(pb.__contains__, keys))
        if not n_missing:
            return
        if n_missing > self.free_blocks:
            self._evict_prefix(n_missing - self.free_blocks)
        lru = self.prefix_lru
        free = self.free_blocks
        for key in keys:
            if key in pb:
                continue
            if free <= 0:
                self.free_blocks = free
                if not self._evict_prefix(1):
                    return                               # no room; skip rest
                free = self.free_blocks
            free -= 1
            pb[key] = 0
            lru[key] = True
            self._evictable += 1
        self.free_blocks = free

    def free(self, req: Request, *, preempted: bool = False) -> None:
        self.free_blocks += self.seq_blocks.pop(req.request_id, 0)
        pb = self.prefix_blocks
        for key in self.seq_borrowed.pop(req.request_id, []):
            refs = pb.get(key)
            if refs is not None and refs > 0:
                pb[key] = refs - 1
                if refs == 1:
                    self._evictable += 1
        if preempted:
            self.stats.preemptions += 1
