"""Prometheus-style metrics exporter for the serving engine.

Mirrors the vLLM exporter the paper scrapes (§4.1 "Periodic Metric
Acquisition"): monotonically-increasing counters plus point-in-time gauges.
The AGFT monitor polls ``snapshot()`` on its sampling period and differences
consecutive snapshots — exactly the REST/Prometheus pattern, and the ONLY
interface the tuner is allowed to read (privacy boundary)."""
from __future__ import annotations

import dataclasses
from typing import Dict


@dataclasses.dataclass
class EngineCounters:
    # counters (monotonic)
    prompt_tokens_total: int = 0         # new prefill tokens computed
    cached_prompt_tokens_total: int = 0  # prompt tokens served by prefix cache
    generation_tokens_total: int = 0
    iterations_total: int = 0
    requests_finished_total: int = 0
    # deadline-expired requests shed at admission (load shedding; fault
    # retry-budget drops are accounted at the fault model, not per engine)
    requests_dropped_total: int = 0
    prefix_cache_hits_total: int = 0
    prefix_cache_queries_total: int = 0
    energy_joules_total: float = 0.0
    busy_seconds_total: float = 0.0
    # aggregate first-token latency (vLLM exports TTFT histograms; an
    # aggregate sum/count is privacy-preserving — no per-request identity)
    ttft_seconds_total: float = 0.0
    ttft_count_total: int = 0
    # actual frequency changes actuated (DVFS transitions are not free;
    # the switching-cost reward and fleet telemetry both consume this)
    freq_transitions_total: int = 0

    # gauges (point-in-time)
    requests_running: int = 0
    requests_waiting: int = 0
    gpu_cache_usage: float = 0.0
    current_frequency_mhz: float = 0.0
    current_power_watts: float = 0.0


class MetricsExporter:
    def __init__(self):
        self.c = EngineCounters()

    def snapshot(self) -> Dict[str, float]:
        """Flat dict, prometheus-naming; this is the tuner-visible surface."""
        c = self.c
        return {
            "vllm:prompt_tokens_total": c.prompt_tokens_total,
            "vllm:cached_prompt_tokens_total": c.cached_prompt_tokens_total,
            "vllm:generation_tokens_total": c.generation_tokens_total,
            "vllm:iterations_total": c.iterations_total,
            "vllm:requests_finished_total": c.requests_finished_total,
            "vllm:requests_dropped_total": c.requests_dropped_total,
            "vllm:prefix_cache_hits_total": c.prefix_cache_hits_total,
            "vllm:prefix_cache_queries_total": c.prefix_cache_queries_total,
            "vllm:energy_joules_total": c.energy_joules_total,
            "vllm:busy_seconds_total": c.busy_seconds_total,
            "vllm:ttft_seconds_total": c.ttft_seconds_total,
            "vllm:ttft_count_total": c.ttft_count_total,
            "vllm:freq_transitions_total": c.freq_transitions_total,
            "vllm:num_requests_running": c.requests_running,
            "vllm:num_requests_waiting": c.requests_waiting,
            "vllm:gpu_cache_usage_perc": c.gpu_cache_usage,
            "vllm:current_frequency_mhz": c.current_frequency_mhz,
            "vllm:current_power_watts": c.current_power_watts,
        }
