"""Sharding-rule unit tests (no multi-device runtime needed: specs are pure
metadata; the compile-level proof lives in test_dryrun.py)."""
import jax
import jax.numpy as jnp
import numpy as np
from jax.sharding import Mesh, PartitionSpec as P

from repro.distributed.sharding import (batch_pspec, cache_pspecs,
                                        logits_pspec, param_pspecs,
                                        sanitize_spec)


def fake_mesh(shape=(2, 4), names=("data", "model")):
    devs = np.array(jax.devices() * int(np.prod(shape)))[:int(np.prod(shape))]
    return Mesh(devs.reshape(shape), names)


MESH = fake_mesh()


class TestSanitize:
    def test_drops_nondivisible(self):
        spec = sanitize_spec(P(None, "model"), (10, 51865), MESH)
        assert spec == P(None, None)

    def test_keeps_divisible(self):
        spec = sanitize_spec(P(None, "model"), (10, 512), MESH)
        assert spec == P(None, "model")

    def test_tuple_axes(self):
        spec = sanitize_spec(P(("data", "model"), None), (8, 3), MESH)
        assert spec == P(("data", "model"), None)
        spec = sanitize_spec(P(("data", "model"), None), (6, 3), MESH)
        assert spec == P(None, None)


class TestParamSpecs:
    def test_dense_rules(self):
        params = {
            "embed": jax.ShapeDtypeStruct((32000, 2048), jnp.bfloat16),
            "lm_head": jax.ShapeDtypeStruct((2048, 32000), jnp.bfloat16),
            "layers": {"attn": {
                "wq": jax.ShapeDtypeStruct((22, 2048, 2048), jnp.bfloat16),
                "wo": jax.ShapeDtypeStruct((22, 2048, 2048), jnp.bfloat16),
            }},
        }
        specs = param_pspecs(params, MESH)
        assert specs["embed"] == P("model", None)
        assert specs["lm_head"] == P(None, "model")
        # stacked params get a leading unsharded layer axis
        assert specs["layers"]["attn"]["wq"] == P(None, None, "model")
        assert specs["layers"]["attn"]["wo"] == P(None, "model", None)

    def test_moe_expert_parallel(self):
        params = {"layers": {"moe": {
            "w_in": jax.ShapeDtypeStruct((26, 64, 2048, 1408), jnp.bfloat16),
            "w_out": jax.ShapeDtypeStruct((26, 64, 1408, 2048), jnp.bfloat16),
            "router": jax.ShapeDtypeStruct((26, 2048, 64), jnp.bfloat16),
        }}}
        specs = param_pspecs(params, MESH)
        assert specs["layers"]["moe"]["w_in"] == P(None, "model", None, None)
        assert specs["layers"]["moe"]["w_out"] == P(None, "model", None, None)
        assert specs["layers"]["moe"]["router"] == P(None, None, None)

    def test_nondivisible_vocab_replicates(self):
        params = {"embed": jax.ShapeDtypeStruct((51865, 1024), jnp.float32)}
        specs = param_pspecs(params, MESH)
        assert specs["embed"] == P(None, None)


class TestCacheSpecs:
    def test_kv_head_parallel_when_divisible(self):
        cache = {"scanned": {
            "k": jax.ShapeDtypeStruct((22, 8, 128, 4, 64), jnp.bfloat16)}}
        specs = cache_pspecs(cache, MESH, global_batch=8)
        assert specs["scanned"]["k"] == P(None, ("data",), None, "model",
                                          None)

    def test_context_parallel_fallback(self):
        # Hkv=1 cannot shard over model=4 -> shard cache length instead
        cache = {"scanned": {
            "k": jax.ShapeDtypeStruct((22, 8, 128, 1, 64), jnp.bfloat16)}}
        specs = cache_pspecs(cache, MESH, global_batch=8)
        assert specs["scanned"]["k"] == P(None, ("data",), "model", None,
                                          None)

    def test_batch_one_replicates_batch_axis(self):
        cache = {"scanned": {
            "k": jax.ShapeDtypeStruct((22, 1, 128, 4, 64), jnp.bfloat16)}}
        specs = cache_pspecs(cache, MESH, global_batch=1)
        assert specs["scanned"]["k"][1] is None


class TestBatchAndLogits:
    def test_batch_sharded_when_divisible(self):
        assert batch_pspec(MESH, 8)[0] in ("data", ("data",))
        assert batch_pspec(MESH, 3)[0] is None

    def test_logits_vocab_guard(self):
        assert logits_pspec(MESH, 8, 32000)[-1] == "model"
        assert logits_pspec(MESH, 8, 51865)[-1] is None
