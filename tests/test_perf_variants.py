"""Equivalence tests for the §Perf optimization variants: every optimized
path must match its baseline formulation bit-for-bit (up to float tolerance)
— 'keep the speedup, prove the semantics'."""
import jax
import jax.numpy as jnp
import numpy as np
import pytest

import repro.models.attention as attention_mod
from repro.configs import get_config
from repro.kernels import ref
from repro.models import blocks, build_model
from repro.models.attention import flash_attention_jnp, gqa_attention


class TestChunkedAttention:
    @pytest.mark.parametrize("B,S,H,Hkv,D,block", [
        (2, 256, 4, 2, 64, 64),
        (1, 200, 4, 1, 32, 64),       # non-multiple of block
        (2, 128, 8, 8, 64, 32),
    ])
    def test_matches_naive_causal(self, B, S, H, Hkv, D, block):
        ks = jax.random.split(jax.random.PRNGKey(0), 3)
        q = jax.random.normal(ks[0], (B, S, H, D))
        k = jax.random.normal(ks[1], (B, S, Hkv, D))
        v = jax.random.normal(ks[2], (B, S, Hkv, D))
        a = flash_attention_jnp(q, k, v, causal=True, block_k=block)
        b = ref.flash_attention(q, k, v, causal=True)
        np.testing.assert_allclose(np.asarray(a), np.asarray(b),
                                   rtol=1e-5, atol=1e-5)

    def test_matches_naive_banded(self):
        ks = jax.random.split(jax.random.PRNGKey(1), 3)
        q = jax.random.normal(ks[0], (2, 256, 4, 64))
        k = jax.random.normal(ks[1], (2, 256, 2, 64))
        v = jax.random.normal(ks[2], (2, 256, 2, 64))
        i = jnp.arange(256)[:, None]
        j = jnp.arange(256)[None, :]
        band = (j <= i) & (j > i - 64)
        a = flash_attention_jnp(q, k, v, causal=True, window=64, block_k=64)
        b = gqa_attention(q, k, v, band[None, None])
        np.testing.assert_allclose(np.asarray(a), np.asarray(b),
                                   rtol=1e-5, atol=1e-5)

    def test_unrolled_matches_scan(self):
        ks = jax.random.split(jax.random.PRNGKey(2), 3)
        q = jax.random.normal(ks[0], (1, 128, 2, 32))
        k = jax.random.normal(ks[1], (1, 128, 2, 32))
        v = jax.random.normal(ks[2], (1, 128, 2, 32))
        a = flash_attention_jnp(q, k, v, causal=True, block_k=32)
        b = flash_attention_jnp(q, k, v, causal=True, block_k=32,
                                unroll=True)
        np.testing.assert_allclose(np.asarray(a), np.asarray(b),
                                   rtol=1e-6, atol=1e-6)

    def test_mixed_value_head_dim(self):
        """Dv != Dk (the MLA folding case)."""
        ks = jax.random.split(jax.random.PRNGKey(3), 3)
        q = jax.random.normal(ks[0], (1, 64, 4, 48))
        k = jax.random.normal(ks[1], (1, 64, 4, 48))
        v = jax.random.normal(ks[2], (1, 64, 4, 32))
        a = flash_attention_jnp(q, k, v, causal=True, block_k=16)
        # naive reference with distinct Dv
        s = jnp.einsum("bshd,bthd->bhst", q, k) * (48 ** -0.5)
        mask = jnp.tril(jnp.ones((64, 64), bool))
        s = jnp.where(mask[None, None], s, -1e30)
        p = jax.nn.softmax(s, -1)
        b = jnp.einsum("bhst,bthd->bshd", p, v)
        np.testing.assert_allclose(np.asarray(a), np.asarray(b),
                                   rtol=1e-5, atol=1e-5)

    @pytest.mark.parametrize("arch", ["deepseek-v2-lite-16b",
                                      "chameleon-34b"])
    def test_model_level_chunked_matches_naive(self, arch, monkeypatch):
        monkeypatch.setattr(attention_mod, "CHUNKED_ATTENTION_MIN_SEQ", 8)
        cfg = get_config(arch).reduced()
        m1 = build_model(cfg)
        m2 = build_model(cfg.replace(ref_attention="chunked"))
        params = m1.init(jax.random.PRNGKey(0))
        tokens = jax.random.randint(jax.random.PRNGKey(1), (2, 32), 0,
                                    cfg.vocab_size)
        l1, _ = m1.forward(params, tokens)
        l2, _ = m2.forward(params, tokens)
        np.testing.assert_allclose(np.asarray(l1), np.asarray(l2),
                                   rtol=3e-4, atol=3e-4)


class TestCapacityMoE:
    @pytest.mark.parametrize("arch", ["llama4-scout-17b-a16e",
                                      "deepseek-v2-lite-16b"])
    def test_no_drop_capacity_matches_dense(self, arch):
        cfg = get_config(arch).reduced().replace(
            capacity_factor=float(get_config(arch).reduced().num_experts))
        p = blocks.init_moe(jax.random.PRNGKey(0), cfg)
        x = jax.random.normal(jax.random.PRNGKey(1), (2, 16, cfg.d_model))
        y1, a1 = blocks.moe_forward_dense(p, cfg, x)
        y2, a2 = blocks.moe_forward_capacity(p, cfg, x)
        np.testing.assert_allclose(np.asarray(y1), np.asarray(y2),
                                   rtol=2e-4, atol=2e-4)
        np.testing.assert_allclose(float(a1.load_balance_loss),
                                   float(a2.load_balance_loss), rtol=1e-4)

    def test_tight_capacity_drops_but_finite(self):
        cfg = get_config("deepseek-v2-lite-16b").reduced().replace(
            capacity_factor=0.5)
        p = blocks.init_moe(jax.random.PRNGKey(2), cfg)
        x = jax.random.normal(jax.random.PRNGKey(3), (2, 32, cfg.d_model))
        y, _ = blocks.moe_forward_capacity(p, cfg, x)
        assert bool(jnp.all(jnp.isfinite(y)))

    def test_capacity_grad_finite(self):
        cfg = get_config("llama4-scout-17b-a16e").reduced().replace(
            moe_dispatch="capacity")
        model = build_model(cfg)
        params = model.init(jax.random.PRNGKey(0))
        tokens = jax.random.randint(jax.random.PRNGKey(1), (2, 17), 0,
                                    cfg.vocab_size)
        loss, grads = jax.value_and_grad(
            lambda p: model.loss(p, tokens[:, :-1], tokens[:, 1:]))(params)
        assert jnp.isfinite(loss)
        assert all(bool(jnp.all(jnp.isfinite(g)))
                   for g in jax.tree.leaves(grads))


class TestScatterKV:
    @pytest.mark.parametrize("arch", ["tinyllama-1.1b",
                                      "deepseek-v2-lite-16b",
                                      "recurrentgemma-9b"])
    def test_scatter_matches_onehot_decode(self, arch):
        cfg = get_config(arch).reduced()
        m1 = build_model(cfg.replace(kv_update="onehot"))
        m2 = build_model(cfg.replace(kv_update="scatter"))
        params = m1.init(jax.random.PRNGKey(0))
        B, S, CAP = 2, 8, 16
        tokens = jax.random.randint(jax.random.PRNGKey(1), (B, S), 0,
                                    cfg.vocab_size)
        _, cache = m1.prefill(params, tokens, max_len=CAP)
        pos = jnp.full((B,), S, jnp.int32)
        tok = tokens[:, :1]
        c1 = c2 = cache
        for i in range(4):
            d1, c1 = m1.decode_step(params, tok, c1, pos + i)
            d2, c2 = m2.decode_step(params, tok, c2, pos + i)
            np.testing.assert_allclose(np.asarray(d1), np.asarray(d2),
                                       rtol=1e-5, atol=1e-5)
