"""Training substrate tests: AdamW math, loss decrease, checkpoint
round-trip, data pipeline determinism."""
import os
import tempfile

import jax
import jax.numpy as jnp
import numpy as np

from repro.configs import get_config
from repro.data import synthetic_token_batches
from repro.models import build_model
from repro.training import (AdamWConfig, adamw_update, init_adamw,
                            load_checkpoint, make_train_step,
                            save_checkpoint, train)


def test_adamw_matches_reference_on_quadratic():
    """AdamW must descend f(w) = ||w||^2 quickly."""
    cfg = AdamWConfig(lr=0.1, weight_decay=0.0, warmup_steps=1,
                      grad_clip=1e9)
    params = {"w": jnp.array([3.0, -2.0, 1.0])}
    state = init_adamw(params, cfg)
    for _ in range(200):
        grads = {"w": 2 * params["w"]}
        params, state, _ = adamw_update(cfg, params, grads, state)
    assert float(jnp.max(jnp.abs(params["w"]))) < 1e-2


def test_grad_clip_bounds_update():
    cfg = AdamWConfig(lr=1.0, grad_clip=1.0, weight_decay=0.0,
                      warmup_steps=1)
    params = {"w": jnp.zeros(4)}
    state = init_adamw(params, cfg)
    _, _, gnorm = adamw_update(cfg, params, {"w": jnp.full(4, 100.0)},
                               state)
    np.testing.assert_allclose(float(gnorm), 200.0, rtol=1e-5)


def test_train_loss_decreases_tiny_model():
    cfg = get_config("tinyllama-1.1b").reduced().replace(num_layers=2)
    model = build_model(cfg)
    params = model.init(jax.random.PRNGKey(0))
    data = synthetic_token_batches(cfg.vocab_size, 4, 32, seed=0)
    _, _, hist = train(model, params, data, steps=30,
                       opt_cfg=AdamWConfig(lr=1e-3, warmup_steps=5),
                       log_every=29)
    assert hist[-1]["loss"] < hist[0]["loss"]


def test_checkpoint_roundtrip():
    cfg = get_config("tinyllama-1.1b").reduced().replace(num_layers=2)
    model = build_model(cfg)
    params = model.init(jax.random.PRNGKey(0))
    with tempfile.TemporaryDirectory() as d:
        p = os.path.join(d, "ckpt.npz")
        save_checkpoint(p, params)
        loaded, _ = load_checkpoint(p, params)
        for a, b in zip(jax.tree.leaves(params), jax.tree.leaves(loaded)):
            np.testing.assert_array_equal(np.asarray(a), np.asarray(b))


def test_data_pipeline_deterministic_and_learnable():
    it1 = synthetic_token_batches(100, 2, 16, seed=3)
    it2 = synthetic_token_batches(100, 2, 16, seed=3)
    b1, b2 = next(it1), next(it2)
    np.testing.assert_array_equal(b1["tokens"], b2["tokens"])
    # labels are next-token-shifted views of the same stream
    assert b1["tokens"].shape == b1["labels"].shape == (2, 16)
    assert b1["tokens"].max() < 100


def test_train_step_jits_once():
    cfg = get_config("tinyllama-1.1b").reduced().replace(num_layers=2)
    model = build_model(cfg)
    params = model.init(jax.random.PRNGKey(0))
    opt = init_adamw(params)
    step = jax.jit(make_train_step(model))
    data = synthetic_token_batches(cfg.vocab_size, 2, 16, seed=1)
    b = next(data)
    batch = {k: jnp.asarray(v) for k, v in b.items()}
    p1, o1, m1 = step(params, opt, batch)
    p2, o2, m2 = step(p1, o1, batch)
    assert int(m2["step"]) == 2
    assert jnp.isfinite(m2["loss"])
