"""Batched fleet-step backend vs the per-event loop: bit-equality gates.

``ServingCluster(step_mode="batched")`` promises trajectories
bit-identical to the default event loop for every supported fleet shape
(see the ``repro.serving.fleet_step`` module docstring for the
equivalence contract and its measure-zero exceptions). These tests drain
the SAME submitted workload through both backends and require exact
equality of: step counts, per-node clocks/frequencies, every metric
counter, every finished request's timeline fields, AGFT policy histories
and LinUCB bank matrices, fleet power-cap accounting, and the public
``summary()`` artifact.

A hypothesis property (skipped without the package, like
``tests/test_property.py``) checks the structural invariant the batched
core's correctness rests on: per-node clocks never move backwards across
event-horizon rounds.
"""
import dataclasses
import math

import numpy as np
import pytest

from repro.configs import get_config
from repro.energy.power_model import A6000_MEASURED
from repro.serving.cluster import ServingCluster
from repro.serving.engine import EngineConfig
from repro.serving.fleet_step import BatchedFleetLoop
from repro.workloads import generate_azure_trace

CFG = get_config("llama3-3b")

REQ_FIELDS = ("arrival_time", "prompt_len", "output_len", "prefilled",
              "generated", "finish_time", "first_token_time",
              "first_scheduled_time")
BANK_ARRS = ("_A", "_A_inv", "_b", "_theta", "_n",
             "_reward_sum", "_edp_sum")


def make(n, seed, dur=30.0, rate=0.5, **kw):
    cl = ServingCluster(CFG, n_nodes=n, **kw)
    reqs = generate_azure_trace(dur, base_rate=rate * n, seed=seed)
    cl.submit(reqs)
    return cl


def _counters(eng):
    c = eng.metrics.c
    return dataclasses.asdict(c) if dataclasses.is_dataclass(c) \
        else dict(vars(c))


def _eq(a, b):
    """Exact equality, except NaN == NaN (empty-summary statistics)."""
    if isinstance(a, float) and isinstance(b, float) \
            and math.isnan(a) and math.isnan(b):
        return True
    return a == b


def assert_fleets_identical(a: ServingCluster, b: ServingCluster,
                            sa: int, sb: int) -> None:
    assert sa == sb, f"step counts differ: {sa} vs {sb}"
    for i, (na, nb) in enumerate(zip(a.nodes, b.nodes)):
        ea, eb = na.engine, nb.engine
        assert ea.clock == eb.clock, (i, "clock", ea.clock, eb.clock)
        assert ea.frequency == eb.frequency, (i, "frequency")
        ca, cb = _counters(ea), _counters(eb)
        for k in ca:
            assert ca[k] == cb[k], (i, k, ca[k], cb[k])
        assert len(ea.finished) == len(eb.finished), (i, "finished count")
        # request_ids differ across the two generated traces (global
        # counter), so requests are matched by finish order
        for ra, rb in zip(ea.finished, eb.finished):
            for f in REQ_FIELDS:
                assert getattr(ra, f) == getattr(rb, f), (i, f)
        pa, pb = na.policy, nb.policy
        if pa is None:
            continue
        if hasattr(pa, "history"):
            assert pa.history == pb.history, (i, "history")
        if hasattr(pa, "bank"):
            for name in BANK_ARRS:
                assert np.array_equal(getattr(pa.bank, name),
                                      getattr(pb.bank, name)), (i, name)
            assert pa.round == pb.round
            assert pa.switch_count == pb.switch_count
            assert pa.prev_action == pb.prev_action
    suma = dataclasses.asdict(a.summary())
    sumb = dataclasses.asdict(b.summary())
    for k in suma:
        assert _eq(suma[k], sumb[k]), ("summary", k, suma[k], sumb[k])


def drain_both(n, seed, tick="iteration", dur=30.0, rate=0.5, **kw):
    a = make(n, seed, dur=dur, rate=rate, policy_tick_mode=tick,
             step_mode="event", **kw)
    b = make(n, seed, dur=dur, rate=rate, policy_tick_mode=tick,
             step_mode="batched", **kw)
    sa = a.drain()
    sb = b.drain()
    assert_fleets_identical(a, b, sa, sb)
    return a, b


# ---------------------------------------------------------------------------
# the required grid: 1 / 3 / 10 nodes x both policy-tick modes
# ---------------------------------------------------------------------------

@pytest.mark.parametrize("tick", ["iteration", "tick"])
@pytest.mark.parametrize("n,seed", [(1, 0), (3, 1), (10, 2)])
def test_batched_equals_event_grid(n, seed, tick):
    drain_both(n, seed, tick=tick)


# ---------------------------------------------------------------------------
# fleet shapes that exercise the non-default code paths
# ---------------------------------------------------------------------------

def test_measured_hardware():
    """Nonzero DVFS transition latency/cost (clock-advancing switches)."""
    drain_both(3, 6, hardware=A6000_MEASURED)
    drain_both(2, 7, tick="tick", hardware=A6000_MEASURED)


def test_no_tuners():
    drain_both(3, 8, with_tuners=False)


def test_mixed_policy_fleet_uses_facades():
    """Heterogeneous policies fall off the stacked-AGFT fast path onto
    per-node facades; trajectories must not change."""
    a, b = drain_both(4, 9, policies=["agft", "slo", "ondemand", None])
    assert b._loop.stacked is None
    a, b = drain_both(4, 10, tick="tick",
                      policies=["agft", "slo", "ondemand", None])
    assert b._loop.stacked is None


def test_fleet_policies():
    drain_both(3, 11, fleet_policy="global")
    drain_both(3, 12, fleet_policy="hierarchy",
               policies=["agft", "agft", "agft"])


def test_kv_admission_pressure():
    """High arrival rate: waiting queues, failed admissions, prefix-cache
    eviction churn — the per-node Python fallback path."""
    drain_both(2, 13, rate=4.0)


def test_throughput_engine_config():
    """The mega-fleet benchmark's coarse-block single-chunk config."""
    drain_both(3, 14, engine_cfg=EngineConfig(num_kv_blocks=512,
                                              kv_block_size=128,
                                              prefill_chunk=2048))


# ---------------------------------------------------------------------------
# vectorized classB path: counters + the retained engine fallback
# ---------------------------------------------------------------------------

def test_classb_vectorized_no_engine_steps():
    """The admission fast path runs zero real engine.step() calls, even
    under KV pressure (waiting queues, failed admissions, blocked ticks)."""
    _, b = drain_both(2, 15, rate=4.0)
    loop = b._loop
    assert loop.classb_engine_steps == 0
    assert loop.admitted_requests > 0
    assert loop.classb_fast_steps > 0


def test_classb_engine_fallback_path():
    """classb_path='engine' retains the flush/step/refresh fallback and
    stays bit-identical too (bisection escape hatch)."""
    _, b = drain_both(2, 16, rate=4.0, batched_classb_path="engine")
    loop = b._loop
    assert loop.classb_engine_steps > 0
    assert loop.classb_fast_steps == 0


def test_train_cap_parameter():
    """Any train cap produces the same trajectories (caps only bound
    speculative physics past a horizon cut)."""
    for cap in (1, 8, 256):
        drain_both(3, 17, batched_train_cap=cap)
    with pytest.raises(ValueError, match="train_cap"):
        ServingCluster(CFG, n_nodes=1, step_mode="batched",
                       batched_train_cap=0).drain()


# ---------------------------------------------------------------------------
# max_iters is honored exactly (EventLoop.run parity)
# ---------------------------------------------------------------------------

def test_max_iters_exact_single_node():
    """Truncated single-node runs are bit-identical at every cut — the
    batched loop lands on the exact step count instead of overshooting by
    a round."""
    for cut in (1, 7, 50, 413):
        a = make(1, 20, step_mode="event")
        b = make(1, 20, step_mode="batched")
        sa = a.drain(max_iters=cut)
        sb = b.drain(max_iters=cut)
        assert sa == sb == cut
        assert_fleets_identical(a, b, sa, sb)


def test_max_iters_exact_multi_node():
    """Multi-node: both backends consume exactly min(max_iters, drain)
    steps; a budget covering the drain reproduces the full trajectory."""
    full = make(3, 21, step_mode="batched").drain()
    assert full > 100
    for cut in (1, 5, full // 3, full - 1):
        a = make(3, 21, step_mode="event")
        b = make(3, 21, step_mode="batched")
        sa = a.drain(max_iters=cut)
        sb = b.drain(max_iters=cut)
        assert sa == sb == min(cut, full), cut
    a = make(3, 21, step_mode="event")
    b = make(3, 21, step_mode="batched")
    sa = a.drain(max_iters=full)
    sb = b.drain(max_iters=full)
    assert sa == sb == full
    assert_fleets_identical(a, b, sa, sb)


def test_max_iters_exact_tick_mode():
    for cut in (3, 29):
        a = make(2, 22, step_mode="event", policy_tick_mode="tick")
        b = make(2, 22, step_mode="batched", policy_tick_mode="tick")
        assert a.drain(max_iters=cut) == b.drain(max_iters=cut) == cut


# ---------------------------------------------------------------------------
# unsupported shapes fail loudly, never silently diverge
# ---------------------------------------------------------------------------

def test_bad_step_mode_rejected():
    with pytest.raises(ValueError, match="step_mode"):
        ServingCluster(CFG, n_nodes=1, step_mode="vectorized")


def test_network_model_rejected():
    with pytest.raises(NotImplementedError, match="network"):
        ServingCluster(CFG, n_nodes=2, step_mode="batched",
                       network="datacenter")


def test_mismatched_backend_hardware_rejected():
    """Mixed specs are supported; what stays rejected is an engine whose
    backend DVFS model disagrees with its own ``hardware`` attribute
    (the batched physics would bill the wrong power curve)."""
    cl = ServingCluster(CFG, n_nodes=2, step_mode="batched")
    cl.nodes[1].engine.hardware = A6000_MEASURED
    with pytest.raises(NotImplementedError, match="DVFS spec"):
        cl.drain()


# ---------------------------------------------------------------------------
# heterogeneous hardware: mixed specs drive the same SoA physics
# ---------------------------------------------------------------------------

@pytest.mark.parametrize("tick", ["iteration", "tick"])
def test_mixed_fleet_bit_identical(tick):
    """3-node mixed fleet (A6000 + H100 + edge) with per-node AGFT loops:
    batched and event backends must agree bit-for-bit, including the
    nonzero-DVFS-transition-cost specs."""
    a, b = drain_both(3, 30, tick=tick,
                      hardware="a6000,h100,edge-orin",
                      policies=["agft", "agft", "agft"])
    assert b._loop.hetero
    assert [sp.name for sp in b._loop.specs] == \
        ["NVIDIA-A6000", "NVIDIA-H100", "EDGE-ORIN"]


def test_mixed_fleet_routers_bit_identical():
    """Routing policy composes with the batched backend on mixed fleets."""
    for router in ("energy", "round-robin"):
        drain_both(3, 31, hardware="h100,l4,a6000", router=router,
                   with_tuners=False)


def test_mixed_fleet_hierarchy_bit_identical():
    """Per-spec waterfill tables + per-node band propagation through the
    coordinator survive the batched fast path."""
    drain_both(3, 32, hardware="a6000,a6000,l4",
               fleet_policy="hierarchy",
               policies=["agft", "agft", "agft"])


def test_fleet_policy_with_tick_mode_rejected():
    cl = ServingCluster(CFG, n_nodes=2, step_mode="batched",
                        fleet_policy="global", policy_tick_mode="tick")
    with pytest.raises(NotImplementedError, match="fleet policy"):
        cl.drain()


def test_oversubscribed_seq_budget_rejected():
    cl = ServingCluster(CFG, n_nodes=1, step_mode="batched",
                        engine_cfg=EngineConfig(max_num_seqs=64,
                                                max_batched_tokens=32))
    with pytest.raises(NotImplementedError, match="max_num_seqs"):
        cl.drain()


# ---------------------------------------------------------------------------
# structural invariant: clocks are monotone across event-horizon rounds
# ---------------------------------------------------------------------------

try:
    from hypothesis import given, settings
    from hypothesis import strategies as st
    _HAVE_HYPOTHESIS = True
except ImportError:                                   # pragma: no cover
    _HAVE_HYPOTHESIS = False


def _run_monotone_check(n, seed, tick):
    cl = make(n, seed, dur=20.0, rate=0.8, policy_tick_mode=tick,
              step_mode="batched")
    loop = BatchedFleetLoop(cl.nodes, fleet_policy=None,
                            policy_tick_mode=tick)
    state = {"prev": loop.clock.copy(), "rounds": 0}

    def hook(lp):
        assert np.all(lp.clock >= state["prev"]), \
            "a node clock moved backwards across an event-horizon round"
        state["prev"] = lp.clock.copy()
        state["rounds"] += 1

    loop._round_hook = hook
    loop.run()
    assert state["rounds"] > 0
    assert np.all(loop.clock >= state["prev"] - 0.0)


def _run_classa_soundness(n, seed, tick, cap):
    """classA dispatch is only sound for nodes with NO admission-side
    work: an empty waiting queue, no chunked prefill in progress, and no
    arrival due at or before the node's current clock (every train
    iteration starts strictly before the next arrival horizon)."""
    cl = make(n, seed, dur=20.0, rate=0.8, policy_tick_mode=tick,
              step_mode="batched")
    loop = BatchedFleetLoop(cl.nodes, fleet_policy=None,
                            policy_tick_mode=tick, train_cap=cap)
    orig = loop._step_trains
    seen = {"nodes": 0}

    def checked(idx, cap_):
        assert np.all(loop.W[idx] == 0), "classA node with waiting work"
        assert np.all(loop.P[idx] == 0), "classA node mid-prefill"
        assert np.all(loop.D[idx] > 0), "classA node with no decodes"
        assert np.all(loop.next_arrival[idx] > loop.clock[idx]), \
            "classA node with an arrival already due"
        seen["nodes"] += len(idx)
        return orig(idx, cap_)

    loop._step_trains = checked
    loop.run()
    assert seen["nodes"] > 0


if _HAVE_HYPOTHESIS:
    @settings(max_examples=10, deadline=None)
    @given(n=st.integers(min_value=1, max_value=5),
           seed=st.integers(min_value=0, max_value=2 ** 20),
           tick=st.sampled_from(["iteration", "tick"]))
    def test_clocks_monotone_across_horizons(n, seed, tick):
        _run_monotone_check(n, seed, tick)

    @settings(max_examples=10, deadline=None)
    @given(n=st.integers(min_value=1, max_value=5),
           seed=st.integers(min_value=0, max_value=2 ** 20),
           tick=st.sampled_from(["iteration", "tick"]),
           cap=st.sampled_from([1, 8, 64]))
    def test_classa_nodes_have_no_admission_work(n, seed, tick, cap):
        _run_classa_soundness(n, seed, tick, cap)
else:                                                 # pragma: no cover
    @pytest.mark.skip(reason="hypothesis not installed")
    def test_clocks_monotone_across_horizons():
        pass

    def test_classa_nodes_have_no_admission_work():
        """Deterministic fallback when hypothesis is unavailable: run the
        same invariant check over a fixed sample grid."""
        for n, seed, tick, cap in [(1, 3, "iteration", 64),
                                   (3, 5, "iteration", 8),
                                   (4, 7, "tick", 1),
                                   (5, 11, "tick", 64)]:
            _run_classa_soundness(n, seed, tick, cap)
