"""Regenerate the committed golden AGFT decision trajectories from scratch.

Two goldens pin two scheduling semantics on the same fixed-seed trace:

``golden_agft_decisions.json``        the iteration-gated trajectory
    (policies invoked after every engine step, telemetry windows gated on
    the engine clock) — the paper-faithful mode every hot-path refactor
    must reproduce bit-for-bit (``tests/test_vectorized_hotpath.py``,
    ``tests/test_hierarchy.py``, ``tests/test_network.py``)
``golden_agft_decisions_tick.json``   the pure POLICY_TICK trajectory
    (``policy_tick_mode="tick"``: per-node wall-clock ticks, windows cut
    at tick time) — pinning the event-core's second scheduling mode so
    its decision sequence can't drift silently either

CI's ``golden-drift`` job runs this script in a fresh process and fails
on any byte difference between the regenerated files and the committed
ones, so a "refactor" can't silently shift decisions while the committed
goldens keep vouching for the old trajectories.

    PYTHONPATH=src python tests/generate_golden.py            # rewrite
    PYTHONPATH=src python tests/generate_golden.py --check    # verify
"""
from __future__ import annotations

import argparse
import json
import os
import sys

from repro.configs import get_config
from repro.core import AGFTTuner
from repro.energy import A6000
from repro.serving import EngineConfig, EngineNode, EventLoop, InferenceEngine
# imported for effect in CI's golden-drift job: loading the fault-injection
# module (and its numpy RNG machinery) must never perturb golden
# regeneration — the healthy path is fault-model-free by construction
import repro.serving.faults  # noqa: F401
from repro.workloads import PROTOTYPES, generate_requests

HERE = os.path.dirname(os.path.abspath(__file__))
GOLDEN = os.path.join(HERE, "golden_agft_decisions.json")
GOLDEN_TICK = os.path.join(HERE, "golden_agft_decisions_tick.json")

#: the pinned regression trace (do not change without regenerating AND
#: reviewing the diff — this redefines what "decision drift" means)
TRACE = {"workload": "normal", "n": 150, "rate": 3.0, "seed": 7}


def _engine_and_tuner():
    eng = InferenceEngine(get_config("llama3-3b"), EngineConfig(),
                          initial_frequency=A6000.f_max)
    eng.submit(generate_requests(PROTOTYPES[TRACE["workload"]], TRACE["n"],
                                 base_rate=TRACE["rate"],
                                 seed=TRACE["seed"]))
    return eng, AGFTTuner(A6000)


def _payload(eng, tuner) -> dict:
    return {
        "trace": dict(TRACE),
        "freqs": [h["freq"] for h in tuner.history],
        "phases": [h["phase"] for h in tuner.history],
        "rounds": tuner.round,
        "energy_j": eng.metrics.c.energy_joules_total,
        "clock": eng.clock,
    }


def generate() -> dict:
    """The iteration-gated trajectory (the historical golden)."""
    eng, tuner = _engine_and_tuner()
    eng.drain(policy=tuner)
    return _payload(eng, tuner)


def generate_tick() -> dict:
    """The pure POLICY_TICK trajectory: same trace, decisions on
    wall-clock ticks with windows cut at tick time."""
    eng, tuner = _engine_and_tuner()
    EventLoop([EngineNode(eng, tuner)], policy_tick_mode="tick").run()
    out = _payload(eng, tuner)
    out["mode"] = "tick"
    return out


def render(payload: dict) -> str:
    """The exact byte encoding of the committed files (json indent=1, no
    trailing newline) so ``--check`` / CI can compare bytes, not
    semantics."""
    return json.dumps(payload, indent=1)


GOLDENS = (
    (GOLDEN, generate),
    (GOLDEN_TICK, generate_tick),
)


def main() -> None:
    ap = argparse.ArgumentParser()
    ap.add_argument("--check", action="store_true",
                    help="exit 1 if a regenerated golden differs from "
                         "its committed file (byte comparison)")
    args = ap.parse_args()
    drifted = False
    for path, gen in GOLDENS:
        fresh = render(gen())
        if args.check:
            with open(path) as f:
                committed = f.read()
            if fresh != committed:
                print(f"GOLDEN DRIFT: regenerated trajectory differs "
                      f"from {path}", file=sys.stderr)
                drifted = True
            else:
                print(f"golden OK: {path} reproduces byte-for-byte")
            continue
        with open(path, "w") as f:
            f.write(fresh)
        print(f"wrote {path}")
    if drifted:
        sys.exit(1)


if __name__ == "__main__":
    main()
