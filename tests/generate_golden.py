"""Regenerate ``tests/golden_agft_decisions.json`` from scratch.

The golden file pins the exact AGFT decision trajectory (frequencies,
phases, rounds, total energy, final clock) on a fixed-seed trace; the
hot-path equivalence suite (``tests/test_vectorized_hotpath.py``) and the
band/no-cap tests (``tests/test_hierarchy.py``) assert against it. CI's
``golden-drift`` job runs this script in a fresh process and fails on any
byte difference between the regenerated file and the committed one, so a
hot-path "refactor" can't silently shift decisions while the committed
golden keeps vouching for the old trajectory.

    PYTHONPATH=src python tests/generate_golden.py            # rewrite
    PYTHONPATH=src python tests/generate_golden.py --check    # verify
"""
from __future__ import annotations

import argparse
import json
import os
import sys

from repro.configs import get_config
from repro.core import AGFTTuner
from repro.energy import A6000
from repro.serving import EngineConfig, InferenceEngine
from repro.workloads import PROTOTYPES, generate_requests

GOLDEN = os.path.join(os.path.dirname(os.path.abspath(__file__)),
                      "golden_agft_decisions.json")

#: the pinned regression trace (do not change without regenerating AND
#: reviewing the diff — this redefines what "decision drift" means)
TRACE = {"workload": "normal", "n": 150, "rate": 3.0, "seed": 7}


def generate() -> dict:
    eng = InferenceEngine(get_config("llama3-3b"), EngineConfig(),
                          initial_frequency=A6000.f_max)
    eng.submit(generate_requests(PROTOTYPES[TRACE["workload"]], TRACE["n"],
                                 base_rate=TRACE["rate"],
                                 seed=TRACE["seed"]))
    tuner = AGFTTuner(A6000)
    eng.drain(policy=tuner)
    return {
        "trace": dict(TRACE),
        "freqs": [h["freq"] for h in tuner.history],
        "phases": [h["phase"] for h in tuner.history],
        "rounds": tuner.round,
        "energy_j": eng.metrics.c.energy_joules_total,
        "clock": eng.clock,
    }


def render(payload: dict) -> str:
    """The exact byte encoding of the committed file (json indent=1, no
    trailing newline) so ``--check`` / CI can compare bytes, not
    semantics."""
    return json.dumps(payload, indent=1)


def main() -> None:
    ap = argparse.ArgumentParser()
    ap.add_argument("--check", action="store_true",
                    help="exit 1 if the regenerated golden differs from "
                         "the committed file (byte comparison)")
    args = ap.parse_args()
    fresh = render(generate())
    if args.check:
        with open(GOLDEN) as f:
            committed = f.read()
        if fresh != committed:
            print("GOLDEN DRIFT: regenerated trajectory differs from "
                  f"{GOLDEN}", file=sys.stderr)
            sys.exit(1)
        print(f"golden OK: {GOLDEN} reproduces byte-for-byte")
        return
    with open(GOLDEN, "w") as f:
        f.write(fresh)
    print(f"wrote {GOLDEN}")


if __name__ == "__main__":
    main()
