"""Integration: the serving engine driving REAL JAX forward passes (reduced
tinyllama) through the JaxBackend, with AGFT attached — proves the tuner is
backend-agnostic (it only sees metrics + set_frequency)."""

from repro.configs import get_config
from repro.core import AGFTConfig, AGFTTuner
from repro.energy import A6000
from repro.serving import EngineConfig, InferenceEngine, JaxBackend
from repro.workloads import PROTOTYPES, generate_requests


def test_engine_with_real_jax_execution():
    cfg = get_config("tinyllama-1.1b").reduced()
    backend = JaxBackend(cfg, A6000, max_batch=4, cache_len=64)
    eng = InferenceEngine(cfg, EngineConfig(max_num_seqs=4,
                                            max_batched_tokens=256,
                                            prefill_chunk=64),
                          hardware=A6000, backend=backend,
                          initial_frequency=A6000.f_max)
    reqs = generate_requests(PROTOTYPES["normal"], 6, base_rate=50.0, seed=0)
    for r in reqs:
        r.prompt_len = min(r.prompt_len, 48)
        r.output_len = min(r.output_len, 8)
    eng.submit(reqs)
    tuner = AGFTTuner(A6000, AGFTConfig(sampling_period_s=0.2))
    eng.drain(policy=tuner, max_iters=2000)
    assert len(eng.finished) == 6
    assert eng.metrics.c.energy_joules_total > 0
    assert all(r.generated == r.output_len for r in eng.finished)
    # the tuner must have acted through the same interface as in sim mode
    assert tuner.round >= 0
    assert eng.frequency >= A6000.f_min
