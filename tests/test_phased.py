"""Phase-disaggregated DVFS: per-phase pricing, transition billing, the
2-D tuner stack (pair-keyed banks, cascade dominance, product refinement),
the greenllm-rule comparator, the scheduler's admission-cap knob, and the
guards that keep 1-D paths byte-identical and batched mode honest."""
import os
import sys

import numpy as np
import pytest

sys.path.insert(0, os.path.dirname(os.path.abspath(__file__)))
import generate_golden  # noqa: E402  (tests/generate_golden.py)

from repro.configs import get_config
from repro.core import AGFTConfig, LinUCBBank, PruningConfig, \
    PruningFramework
from repro.core.refinement import MixedMaturityRefinement, RefinementConfig
from repro.core.tuner2d import AGFT2DTuner
from repro.energy import A6000, A6000_MEASURED
from repro.energy.phases import phase_optimal_frequencies
from repro.policies import get_policy
from repro.serving import EngineConfig, InferenceEngine
from repro.serving.cluster import ServingCluster
from repro.serving.engine import SimBackend
from repro.serving.request import Request
from repro.serving.scheduler import BatchPlan
from repro.workloads import PROTOTYPES, generate_requests

CFG = get_config("llama3-3b")


def _mixed_plan():
    pf = Request(arrival_time=0.0, prompt_len=600, output_len=50)
    pf.prefilled = 128
    d1 = Request(arrival_time=0.0, prompt_len=300, output_len=50)
    d1.prefilled, d1.generated = 300, 10
    d2 = Request(arrival_time=0.0, prompt_len=200, output_len=80)
    d2.prefilled, d2.generated = 200, 40
    return BatchPlan(prefill=[(pf, 256)], decode=[d1, d2])


class TestPhasedPricing:
    def test_mixed_iteration_is_sum_of_per_phase_costs(self):
        """execute_phased prices the same work split as execute, each half
        at its own clock (incl. the shared-weight-read subtraction)."""
        be = SimBackend(CFG, A6000)
        plan = _mixed_plan()
        f_pf, f_de = 1395.0, 1170.0
        t_pf, e_pf, t_de, e_de = be.execute_phased(plan, f_pf, f_de)

        cost = be.cost
        (r, n), = plan.prefill
        fl1, m1 = cost.iteration_cost(prefill_tokens=n, decode_seqs=0,
                                      avg_context=r.prefilled + n / 2)
        t1, p1 = be.dvfs.iteration_time_power(fl1, m1, f_pf)
        ctx = sum(q.prefilled + q.generated for q in plan.decode)
        fl2, m2 = cost.iteration_cost(prefill_tokens=0, decode_seqs=2,
                                      avg_context=ctx / 2)
        m2 = max(m2 - be._shared_weight_bytes, 0.0)
        t2, p2 = be.dvfs.iteration_time_power(fl2, m2, f_de)

        assert (t_pf, e_pf) == (t1, p1 * t1)
        assert (t_de, e_de) == (t2, p2 * t2)

    def test_single_phase_half_matches_1d_execute(self):
        """A decode-only plan priced phased at (anything, f) is exactly
        the 1-D execute at f — no phantom prefill half."""
        be = SimBackend(CFG, A6000)
        plan = BatchPlan(prefill=[], decode=_mixed_plan().decode)
        t, e, p = be.execute(plan, 1200.0)
        t_pf, e_pf, t_de, e_de = be.execute_phased(plan, 1800.0, 1200.0)
        assert (t_pf, e_pf) == (0.0, 0.0)
        assert (t_de, e_de) == (t, e)

    def test_phase_optima_split_compute_vs_bandwidth(self):
        """Prefill (compute-bound) wants a faster clock than decode
        (bandwidth-bound) — the headroom the 2-D surface exploits."""
        f_pf, f_de = phase_optimal_frequencies(A6000, CFG)
        assert f_pf > f_de
        lo, hi = 1300.0, 1500.0
        b_pf, b_de = phase_optimal_frequencies(A6000, CFG, band=(lo, hi))
        assert lo <= b_pf <= hi and lo <= b_de <= hi


class TestPhasedEngine:
    def _engine(self, hw=A6000):
        eng = InferenceEngine(CFG, EngineConfig(), hardware=hw,
                              initial_frequency=hw.f_max)
        eng.submit(generate_requests(PROTOTYPES["normal"], 30,
                                     base_rate=8.0, seed=3))
        return eng

    def test_phase_switches_billed_once_each(self):
        """A mixed phased iteration actuates pf then de: exactly 2
        transitions per iteration in steady state, each billed the
        hardware's transition energy and latency."""
        hw = A6000_MEASURED
        assert hw.dvfs_transition_cost_j > 0.0
        eng = InferenceEngine(CFG, EngineConfig(), hardware=hw,
                              initial_frequency=1170.0)
        eng.set_phase_frequencies(1395.0, 1170.0)
        plan = _mixed_plan()
        c = eng.metrics.c
        for _ in range(2):            # steady state: de clock live at entry
            n0, e0, t0 = (c.freq_transitions_total, c.energy_joules_total,
                          eng.clock)
            eng._execute_phased(plan)
            assert c.freq_transitions_total - n0 == 2   # ->pf, then ->de
            assert c.energy_joules_total - e0 == \
                pytest.approx(2 * hw.dvfs_transition_cost_j)
            assert eng.clock - t0 == \
                pytest.approx(2 * hw.dvfs_transition_s)
        # equal pair at the live clock: no transition, nothing billed
        eng.set_phase_frequencies(1170.0, 1170.0)
        n0, e0 = c.freq_transitions_total, c.energy_joules_total
        eng._execute_phased(plan)
        assert c.freq_transitions_total == n0
        assert c.energy_joules_total == e0

    def test_scalar_set_frequency_reverts_to_1d(self):
        eng = self._engine()
        eng.set_phase_frequencies(1395.0, 1170.0)
        assert eng.freq_targets == (1395.0, 1170.0)
        eng.set_frequency(1200.0)
        assert eng.freq_targets is None
        assert eng.frequency == 1200.0

    def test_targets_clamped_to_envelope(self):
        eng = self._engine()
        eng.set_phase_frequencies(99.0, 1e6)
        assert eng.freq_targets == (A6000.f_min, A6000.f_max)

    def test_phased_drain_finishes_everything(self):
        eng = self._engine()
        eng.set_phase_frequencies(1395.0, 1170.0)
        eng.drain()
        assert len(eng.finished) == 30
        assert all(r.generated == r.output_len for r in eng.finished)


class TestPairBank:
    PAIRS = [(1200.0, 1000.0), (1200.0, 1200.0), (1400.0, 1000.0),
             (1400.0, 1200.0), (1600.0, 1400.0)]

    def test_set_band_intersects_both_axes(self):
        bank = LinUCBBank(self.PAIRS, dim=3)
        bank.set_band(1100.0, 1450.0)
        legal = {f for f in bank.frequencies if bank.is_legal(f)}
        assert legal == {(1200.0, 1200.0), (1400.0, 1200.0)}
        bank.set_band(500.0, 2000.0)           # reversible
        assert all(bank.is_legal(f) for f in bank.frequencies)

    def test_empty_band_falls_back_to_nearest_pair(self):
        bank = LinUCBBank(self.PAIRS, dim=3)
        bank.set_band(1290.0, 1330.0)          # no pair fully inside
        legal = [f for f in bank.frequencies if bank.is_legal(f)]
        assert legal == [(1400.0, 1200.0)]     # nearest to (1310, 1310)

    def test_cascade_prunes_axis_dominated_pairs_only(self):
        f_max = 2100.0
        bank = LinUCBBank(self.PAIRS + [(900.0, 800.0), (800.0, 900.0),
                                        (700.0, 700.0)], dim=3)
        pr = PruningFramework(PruningConfig(min_arms=3), f_max)
        pr._cascade(bank, (900.0, 800.0), round_idx=1)
        left = set(bank.frequencies)
        # (700, 700) is dominated on both axes; (800, 900) is not
        assert (700.0, 700.0) not in left
        assert (800.0, 900.0) in left
        # a pair with one fast axis never triggers a cascade
        pr._cascade(bank, (1600.0, 800.0), round_idx=2)
        assert set(bank.frequencies) == left

    def test_refinement_builds_product_grid_in_band(self):
        cfg = RefinementConfig(interval=1, maturity_threshold=0,
                               half_range_2d_mhz=90.0, step_2d_mhz=45.0)
        ref = MixedMaturityRefinement(cfg, 500.0, 2100.0, ucb_alpha=0.5)
        bank = LinUCBBank(self.PAIRS, dim=3)
        bank.set_band(1150.0, 1460.0)
        pr = PruningFramework(PruningConfig(), 2100.0)
        anchor = ref.maybe_refine(bank, pr, np.zeros(3), 100)
        assert isinstance(anchor, tuple)
        for a, b in bank.frequencies:
            assert 1150.0 <= a <= 1460.0 and 1150.0 <= b <= 1460.0
            assert abs(a - anchor[0]) <= 90.0 + 1e-9
            assert abs(b - anchor[1]) <= 90.0 + 1e-9


class TestPhasedPolicies:
    def _served(self, policy, n=120, **kw):
        eng = InferenceEngine(CFG, EngineConfig(),
                              initial_frequency=A6000.f_max)
        eng.submit(generate_requests(PROTOTYPES["normal"], n,
                                     base_rate=4.0, seed=9))
        pol = get_policy(policy, hardware=A6000, **kw)
        eng.drain(policy=pol)
        return eng, pol

    def test_agft_2d_learns_pairs_end_to_end(self):
        eng, pol = self._served("agft-2d")
        assert isinstance(pol, AGFT2DTuner)
        assert len(eng.finished) == 120
        assert pol.seed_pair == phase_optimal_frequencies(
            A6000, CFG, dvfs=eng.backend.dvfs,
            prefill_chunk=eng.cfg.prefill_chunk,
            decode_seqs=eng.cfg.max_num_seqs // 2)
        acts = [h["freq"] for h in pol.history]
        assert acts and all(isinstance(f, tuple) and len(f) == 2
                            for f in acts)
        assert eng.freq_targets == pol.prev_action

    def test_greenllm_rule_pins_the_analytic_pair(self):
        eng, pol = self._served("greenllm-rule")
        assert len(eng.finished) == 120
        assert eng.freq_targets == pol._pair
        assert pol._pair[0] > pol._pair[1]

    def test_agft_2d_respects_band(self):
        eng, pol = self._served("agft-2d", n=60)
        pol.set_band(1200.0, 1400.0)
        f = pol.act(eng)
        assert 1200.0 <= f[0] <= 1400.0 and 1200.0 <= f[1] <= 1400.0

    def test_agft_2d_factory_rejects_cfg_plus_kwargs(self):
        with pytest.raises(TypeError):
            get_policy("agft-2d", hardware=A6000, cfg=AGFTConfig(),
                       strategy="thompson")

    def test_batched_mode_refuses_phased_policies(self):
        cl = ServingCluster(CFG, n_nodes=2, with_tuners=False,
                            policies=["greenllm-rule", None],
                            step_mode="batched")
        cl.submit(generate_requests(PROTOTYPES["normal"], 20,
                                    base_rate=4.0, seed=1))
        with pytest.raises(NotImplementedError, match="phased"):
            cl.drain()

    def test_batched_mode_refuses_phased_engines(self):
        cl = ServingCluster(CFG, n_nodes=2, with_tuners=False,
                            step_mode="batched")
        cl.engines[0].set_phase_frequencies(1395.0, 1170.0)
        cl.submit(generate_requests(PROTOTYPES["normal"], 20,
                                    base_rate=4.0, seed=1))
        with pytest.raises(NotImplementedError, match="phase"):
            cl.drain()


class TestOneDBitIdentity:
    """The 2-D generalization must not move a single byte of the 1-D
    contract: scalar banks, pruning, refinement and the engine's 1-D
    pricing path are arithmetically untouched (CI's golden-drift job
    runs the same comparison in a fresh process)."""

    @pytest.mark.parametrize("path,gen", generate_golden.GOLDENS,
                             ids=["iteration", "tick"])
    def test_1d_trajectory_reproduces_committed_golden_bytes(self, path,
                                                             gen):
        with open(path) as f:
            committed = f.read()
        assert generate_golden.render(gen()) == committed


class TestAdmissionCap:
    def test_cap_clamps_and_restores(self):
        eng = InferenceEngine(CFG, EngineConfig(max_num_seqs=32))
        sched = eng.sched
        sched.set_admission_cap(8)
        assert sched.max_num_seqs == 8
        sched.set_admission_cap(1000)     # never above the configured base
        assert sched.max_num_seqs == 32
        sched.set_admission_cap(0)        # floor of one sequence
        assert sched.max_num_seqs == 1
        sched.set_admission_cap(None)
        assert sched.max_num_seqs == 32

    def test_capped_engine_still_drains(self):
        eng = InferenceEngine(CFG, EngineConfig(max_num_seqs=32))
        eng.sched.set_admission_cap(2)
        eng.submit(generate_requests(PROTOTYPES["normal"], 25,
                                     base_rate=6.0, seed=5))
        eng.drain()
        assert len(eng.finished) == 25
