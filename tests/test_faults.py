"""Fault-injection subsystem tests (repro.serving.faults + event core):
spec grammar, per-node RNG stream independence, zero-fault byte-identity
against BOTH committed goldens, the request-conservation property
(submitted == finished + dropped + in-system at every event-loop step,
with a hypothesis variant when the library is installed), node-churn
retry/re-route vs the naive no-retry baseline, AGFT graceful degradation
(bank freeze on dropped telemetry, stuck-DVFS divergence hold), thermal
throttle clamping, deadline load shedding, and the batched-path guard."""
import json
import os

import pytest

from repro.configs import get_config
from repro.core import AGFTTuner
from repro.energy import A6000
from repro.serving import (EngineConfig, EngineNode, EventLoop,
                           InferenceEngine)
from repro.serving.cluster import ServingCluster
from repro.serving.faults import (PRESETS, FaultConfig, FaultModel,
                                  NodeFaultState, parse_fault_spec)
from repro.serving.request import RequestState
from repro.workloads import PROTOTYPES, generate_requests

CFG = get_config("llama3-3b")
HERE = os.path.dirname(__file__)
GOLDEN = os.path.join(HERE, "golden_agft_decisions.json")
GOLDEN_TICK = os.path.join(HERE, "golden_agft_decisions_tick.json")


def trace(n=80, rate=3.0, seed=21, workload="normal"):
    return generate_requests(PROTOTYPES[workload], n, base_rate=rate,
                             seed=seed)


# ---------------------------------------------------------------------------
# spec grammar
# ---------------------------------------------------------------------------

class TestSpecParsing:
    def test_presets_resolve_to_their_configs(self):
        for name, cfg in PRESETS.items():
            assert parse_fault_spec(name) == cfg
        assert not parse_fault_spec("none").any_active

    def test_clause_grammar(self):
        cfg = parse_fault_spec(
            "crash:mttf=60,mttr=5,retries=2,backoff=0.5;"
            "dvfs:stick=0.1,lag=0.01;thermal:mtbf=30,duration=4,cap=0.5;"
            "telemetry:drop=0.2")
        assert cfg == FaultConfig(
            crash_mttf_s=60.0, crash_mttr_s=5.0, retry_budget=2,
            retry_backoff_s=0.5, dvfs_stick_prob=0.1, dvfs_lag_s=0.01,
            thermal_mtbf_s=30.0, thermal_duration_s=4.0,
            thermal_cap_frac=0.5, telemetry_drop_prob=0.2)

    def test_preset_plus_override(self):
        cfg = parse_fault_spec("node-churn;crash:retries=0")
        assert cfg.crash_mttf_s == PRESETS["node-churn"].crash_mttf_s
        assert cfg.retry_budget == 0

    @pytest.mark.parametrize("bad", [
        "bogus", "crash", "crash:mttf", "crash:nope=1",
        "dvfs:stick=2.0", "telemetry:drop=-0.5",
        "crash:mttf=60,retries=-1",
    ])
    def test_rejects_bad_specs(self, bad):
        with pytest.raises(ValueError):
            parse_fault_spec(bad)


# ---------------------------------------------------------------------------
# per-node RNG streams: membership changes never shift a peer's schedule
# ---------------------------------------------------------------------------

class TestStreamIndependence:
    @staticmethod
    def _first_onsets(n_nodes, spec="node-churn;thermal:mtbf=45", seed=9):
        engines = [InferenceEngine(CFG, EngineConfig(),
                                   initial_frequency=A6000.f_max)
                   for _ in range(n_nodes)]
        fm = FaultModel.from_spec(spec, seed=seed)
        fm.bind(engines)
        first = {}
        for t, _, node, action in sorted(fm._heap):
            first.setdefault((node, action.kind), t)
        return first

    def test_bound_schedules_are_per_node_pure(self):
        two, three = self._first_onsets(2), self._first_onsets(3)
        for key, t in two.items():
            assert three[key] == t      # nodes 0/1 unchanged by node 2

    def test_telemetry_stream_replays_per_node(self):
        # a fresh state for the SAME (seed, node) replays identically,
        # whatever other nodes exist around it
        cfg = parse_fault_spec("lossy-telemetry")
        a, b = (NodeFaultState(1, cfg, seed=5) for _ in range(2))
        assert ([a.scrape_dropped(float(i)) for i in range(20)]
                == [b.scrape_dropped(float(i)) for i in range(20)])
        assert a.scrape_drops > 0          # the stream actually drops

    def test_seed_changes_the_schedule(self):
        cfg = parse_fault_spec("node-churn")
        a = NodeFaultState(0, cfg, seed=1).sample_crash_gap()
        b = NodeFaultState(0, cfg, seed=2).sample_crash_gap()
        assert a != b


# ---------------------------------------------------------------------------
# zero-fault byte-identity: both committed goldens
# ---------------------------------------------------------------------------

def _golden_run(policy_tick_mode):
    """The goldens' pinned trace (normal/150/3.0/seed 7) driven through
    an EventLoop with an attached-but-inactive FaultModel."""
    eng = InferenceEngine(CFG, EngineConfig(),
                          initial_frequency=A6000.f_max)
    eng.submit(generate_requests(PROTOTYPES["normal"], 150, base_rate=3.0,
                                 seed=7))
    tuner = AGFTTuner(A6000)
    fm = FaultModel(PRESETS["none"])
    assert not fm.active
    EventLoop([EngineNode(eng, tuner)], policy_tick_mode=policy_tick_mode,
              fault_model=fm).run()
    return eng, tuner


@pytest.mark.parametrize("mode,path", [("iteration", GOLDEN),
                                       ("tick", GOLDEN_TICK)])
def test_zero_fault_matches_committed_golden(mode, path):
    eng, tuner = _golden_run(mode)
    with open(path) as f:
        golden = json.load(f)
    assert [h["freq"] for h in tuner.history] == golden["freqs"]
    assert [h["phase"] for h in tuner.history] == golden["phases"]
    assert tuner.round == golden["rounds"]
    assert eng.metrics.c.energy_joules_total == golden["energy_j"]
    assert eng.clock == golden["clock"]


# ---------------------------------------------------------------------------
# conservation: submitted == finished + dropped + in-system, every step
# ---------------------------------------------------------------------------

def _total_accounted(cl):
    fin = sum(len(e.finished) for e in cl.engines)
    dropped = sum(len(e.sched.dropped) for e in cl.engines)
    if cl.faults is not None:
        dropped += cl.faults.drops
    in_system = sum(len(e.sched.waiting) + len(e.sched.running)
                    + len(e._pending) for e in cl.engines)
    in_flight = (len(cl._deliveries) if cl._deliveries is not None else 0)
    return fin + dropped + in_system + in_flight


def _assert_conserved(spec, fault_seed, n=60, nodes=2):
    cl = ServingCluster(CFG, n_nodes=nodes, policies=[None] * nodes,
                        faults=spec, fault_seed=fault_seed)
    cl.submit(trace(n, rate=4.0, seed=3))
    loop = EventLoop(cl.nodes, router=cl._deliveries,
                     fault_model=cl.faults)
    cl._loop = loop
    audited = [0]

    def audit(lp, kind, t):
        audited[0] += 1
        assert _total_accounted(cl) == cl.submitted

    loop.on_event = audit
    loop.run()
    assert audited[0] > 0
    assert _total_accounted(cl) == cl.submitted
    s = cl.summary()
    # fully drained: every request either finished or was dropped
    assert s.finished + s.dropped_total == s.submitted


CONSERVATION_CASES = [
    ("node-churn", 0),
    ("node-churn;crash:retries=0", 0),
    ("node-churn;crash:mttf=15,mttr=3", 2),
    ("node-churn;telemetry:drop=0.3;dvfs:stick=0.2", 1),
]


@pytest.mark.parametrize("spec,seed", CONSERVATION_CASES)
def test_conservation_at_every_event(spec, seed):
    _assert_conserved(spec, seed)


def test_conservation_property_hypothesis():
    pytest.importorskip("hypothesis")
    from hypothesis import given, settings
    from hypothesis import strategies as st

    @settings(max_examples=6, deadline=None)
    @given(st.integers(min_value=0, max_value=50),
           st.sampled_from(["node-churn",
                            "node-churn;crash:retries=0",
                            "node-churn;crash:mttf=20,mttr=4"]))
    def inner(fault_seed, spec):
        _assert_conserved(spec, fault_seed, n=40)

    inner()


# ---------------------------------------------------------------------------
# node churn: resilient retries vs the naive no-retry baseline
# ---------------------------------------------------------------------------

def _churn_summary(retries, n=250, nodes=3, seed=0):
    cl = ServingCluster(CFG, n_nodes=nodes, policies=[None] * nodes,
                        faults=f"node-churn;crash:retries={retries}",
                        fault_seed=seed)
    cl.submit(trace(n, rate=3.0, seed=11))
    cl.drain()
    return cl.summary()


def test_churn_resilient_completes_all_non_dropped():
    s = _churn_summary(retries=4)
    assert s.fault_counters["crashes"] > 0
    assert s.fault_counters["reroutes"] > 0
    assert s.finished + s.dropped_total == s.submitted
    assert s.completion_rate == 1.0

def test_churn_naive_no_retry_loses_requests():
    s = _churn_summary(retries=0)
    assert s.fault_counters["crashes"] > 0
    assert s.dropped_total > 0                  # provably lossy
    assert s.finished < s.submitted
    assert s.finished + s.dropped_total == s.submitted
    # dropped requests are terminally marked
    assert s.fault_counters["dropped_retry"] == s.dropped_total


def test_rerouted_requests_carry_retry_counts():
    cl = ServingCluster(CFG, n_nodes=3, policies=[None] * 3,
                        faults="node-churn")
    reqs = trace(250, rate=3.0, seed=11)
    cl.submit(reqs)
    cl.drain()
    assert cl.faults.reroutes > 0
    assert any(r.retries > 0 for r in reqs)
    assert all(r.state is RequestState.FINISHED for r in reqs
               if r.retries > 0)


# ---------------------------------------------------------------------------
# AGFT graceful degradation: frozen bank, stuck-DVFS hold
# ---------------------------------------------------------------------------

def test_bank_frozen_on_full_telemetry_dropout():
    """drop=1.0: every scrape fails, so the resilient tuner must never
    credit a window — zero LinUCB updates, zero rounds, fault-hold rows."""
    cl = ServingCluster(CFG, n_nodes=2, policies=["agft"] * 2,
                        faults="telemetry:drop=1.0")
    cl.submit(trace(80))
    cl.drain()
    for p in cl.policies:
        assert p.round == 0
        assert all(arm.n == 0 for arm in p.bank.arms.values())
        assert any(h["phase"] == "fault-hold" for h in p.history)
    s = cl.summary()
    assert s.fault_counters["telemetry_drops"] > 0
    assert s.finished == s.submitted


def test_naive_tuner_learns_from_corrupted_windows():
    """The agft-naive baseline (fault_aware=False) keeps updating its
    bank under total telemetry loss — the poisoning the resilient path
    refuses."""
    cl = ServingCluster(CFG, n_nodes=2, policies=["agft-naive"] * 2,
                        faults="telemetry:drop=1.0")
    cl.submit(trace(80))
    cl.drain()
    assert any(p.round > 0 for p in cl.policies)


def test_stuck_dvfs_holds_and_never_poisons():
    """stick=1.0: no actuation ever lands. The tuner must detect the
    divergence (telemetry frequency != chosen action), keep re-issuing,
    and never credit a window executed at the wrong frequency."""
    cl = ServingCluster(CFG, n_nodes=1, policies=["agft"],
                        faults="dvfs:stick=1.0")
    cl.submit(trace(60))
    cl.drain()
    eng, p = cl.engines[0], cl.policies[0]
    assert eng.frequency == A6000.f_max     # nothing ever landed
    for f, arm in p.bank.arms.items():
        if f != A6000.f_max:
            assert arm.n == 0               # no phantom-frequency credit
    assert cl.summary().fault_counters["dvfs_sticks"] > 0


# ---------------------------------------------------------------------------
# thermal throttling
# ---------------------------------------------------------------------------

def test_thermal_cap_clamps_frequency_for_the_window():
    cl = ServingCluster(CFG, n_nodes=2, policies=["agft"] * 2,
                        faults="thermal:mtbf=10,duration=5,cap=0.5")
    cl.submit(trace(150))
    loop = EventLoop(cl.nodes, router=cl._deliveries,
                     fault_model=cl.faults)
    cl._loop = loop
    throttled_seen = [0]

    def audit(lp, kind, t):
        for eng, st in zip(cl.engines, cl.faults.states):
            if st.thermal_cap_mhz is not None:
                throttled_seen[0] += 1
                assert eng.frequency <= st.thermal_cap_mhz

    loop.on_event = audit
    loop.run()
    assert cl.faults.thermal_events > 0
    assert throttled_seen[0] > 0
    s = cl.summary()
    assert s.finished == s.submitted


# ---------------------------------------------------------------------------
# deadline load shedding
# ---------------------------------------------------------------------------

def test_deadline_sheds_are_counted_everywhere():
    reqs = trace(120, rate=30.0, seed=5)     # hard overload burst
    for r in reqs:
        r.deadline_s = 0.5
    eng = InferenceEngine(CFG, EngineConfig(max_num_seqs=4),
                          initial_frequency=A6000.f_min)
    eng.submit(reqs)
    eng.drain()
    dropped = len(eng.sched.dropped)
    assert dropped > 0
    assert len(eng.finished) + dropped == len(reqs)
    assert all(r.state is RequestState.DROPPED for r in eng.sched.dropped)
    assert eng.metrics.c.requests_dropped_total == dropped
    snap = eng.metrics.snapshot()
    assert snap["vllm:requests_dropped_total"] == dropped


def test_deadlines_without_faults_count_in_cluster_summary():
    reqs = trace(120, rate=30.0, seed=5)
    for r in reqs:
        r.deadline_s = 0.5
    cl = ServingCluster(CFG, n_nodes=1, policies=[None],
                        engine_cfg=EngineConfig(max_num_seqs=4))
    cl.engines[0].set_frequency(A6000.f_min)
    cl.submit(reqs)
    cl.drain()
    s = cl.summary()
    assert s.dropped_total > 0
    assert s.finished + s.dropped_total == s.submitted
    assert s.completion_rate == 1.0        # of the non-shed requests


def test_no_deadline_trace_never_sheds():
    eng = InferenceEngine(CFG, EngineConfig(),
                          initial_frequency=A6000.f_max)
    eng.submit(trace(60))
    eng.drain()
    assert not eng.sched.dropped
    assert eng.metrics.c.requests_dropped_total == 0


# ---------------------------------------------------------------------------
# batched path: active fault models are rejected, inactive ones ignored
# ---------------------------------------------------------------------------

def test_batched_mode_rejects_active_fault_model():
    with pytest.raises(NotImplementedError):
        ServingCluster(CFG, n_nodes=2, policies=[None] * 2,
                       step_mode="batched", faults="node-churn")


def test_batched_mode_accepts_none_preset():
    cl = ServingCluster(CFG, n_nodes=2, policies=[None] * 2,
                        step_mode="batched", faults="none")
    assert cl.faults is None


def test_batched_loop_rejects_bound_engines():
    from repro.serving.fleet_step import BatchedFleetLoop
    engines = [InferenceEngine(CFG, EngineConfig(),
                               initial_frequency=A6000.f_max)
               for _ in range(2)]
    FaultModel.from_spec("node-churn").bind(engines)
    with pytest.raises(NotImplementedError):
        BatchedFleetLoop([EngineNode(e, None) for e in engines])
