"""Policy-subsystem tests: registry construction, the shared event loop on
engines and clusters (heterogeneous per-node mixes), the AGFT
decision-history regression against the pre-refactor drive loop,
energy/behaviour smoke checks for every registered baseline, the
switching-cost-aware reward, the SLO TTFT-budget mode, and the
fleet-scope global controller."""
import dataclasses

import numpy as np
import pytest

from repro.configs import get_config
from repro.core import AGFTTuner, TelemetryMonitor, aggregate_snapshots
from repro.core.reward import RewardCalculator, RewardConfig
from repro.energy import A6000, A6000_MEASURED
from repro.energy.edp import WindowStats
from repro.policies import (GlobalFrequencyPolicy, OndemandPolicy,
                            PowerPolicy, StaticPolicy, available_policies,
                            get_policy, register_policy, snap_to_grid)
from repro.serving import EngineConfig, EngineNode, InferenceEngine, drive
from repro.serving.cluster import ServingCluster
from repro.workloads import PROTOTYPES, generate_requests

CFG = get_config("llama3-3b")
CORE_POLICIES = ("agft", "static", "ondemand", "slo", "oracle")


def make_engine(frequency=None):
    return InferenceEngine(CFG, EngineConfig(),
                           initial_frequency=frequency or A6000.f_max)


def trace(n=80, rate=3.0, seed=21, workload="normal"):
    return generate_requests(PROTOTYPES[workload], n, base_rate=rate,
                             seed=seed)


# ---------------------------------------------------------------------------
# Registry
# ---------------------------------------------------------------------------

class TestRegistry:
    def test_all_core_policies_construct(self):
        for name in CORE_POLICIES:
            p = get_policy(name, hardware=A6000)
            assert isinstance(p, PowerPolicy)      # structural protocol

    def test_available_lists_core_policies(self):
        avail = available_policies()
        for name in CORE_POLICIES + ("observer",):
            assert name in avail

    def test_unknown_name_raises_with_choices(self):
        with pytest.raises(KeyError, match="agft"):
            get_policy("does-not-exist")

    def test_duplicate_registration_rejected(self):
        with pytest.raises(ValueError):
            register_policy("static")(StaticPolicy)

    def test_kwargs_reach_constructor(self):
        p = get_policy("static", frequency_mhz=1200.0)
        assert p.frequency_mhz == 1200.0
        t = get_policy("agft", strategy="thompson")
        assert t.cfg.strategy == "thompson"


# ---------------------------------------------------------------------------
# Shared drive loop
# ---------------------------------------------------------------------------

class TestDriver:
    @pytest.mark.parametrize("name", CORE_POLICIES + ("observer",))
    def test_every_policy_drains_engine(self, name):
        eng = make_engine()
        eng.submit(trace(60))
        eng.drain(policy=get_policy(name, hardware=A6000))
        assert len(eng.finished) == 60
        assert A6000.f_min <= eng.frequency <= A6000.f_max

    def test_tuner_kwarg_still_accepted(self):
        eng = make_engine()
        eng.submit(trace(30))
        eng.drain(tuner=get_policy("static"))
        assert len(eng.finished) == 30

    def test_drive_multi_engine_steps_laggard(self):
        nodes = []
        for seed in (1, 2):
            eng = make_engine()
            eng.submit(trace(40, seed=seed))
            nodes.append(EngineNode(eng, None))
        steps = drive(nodes)
        assert steps > 0
        assert all(len(n.engine.finished) == 40 for n in nodes)
        # lock-step on the slowest clock: final clocks stay comparable
        clocks = [n.engine.clock for n in nodes]
        assert max(clocks) < 3 * min(clocks)

    def test_run_until_respects_t_end(self):
        eng = make_engine()
        eng.submit(trace(200, rate=1.0))
        eng.run_until(5.0)
        assert eng.clock >= 5.0
        assert eng.has_work                    # plenty of trace left


# ---------------------------------------------------------------------------
# AGFT regression: the refactor must not change decisions
# ---------------------------------------------------------------------------

class TestAGFTRegression:
    def _trace_engine(self):
        eng = make_engine()
        eng.submit(trace(150, seed=7))
        return eng

    def test_decision_history_matches_prerefactor_loop(self):
        """The shared driver must reproduce the pre-refactor drive loop
        ('step, then tuner.maybe_act') decision-for-decision."""
        e1, t1 = self._trace_engine(), AGFTTuner(A6000)
        while e1.has_work:                     # pre-refactor loop, verbatim
            e1.step()
            t1.maybe_act(e1)

        e2, t2 = self._trace_engine(), AGFTTuner(A6000)
        e2.drain(policy=t2)

        assert t1.round == t2.round
        h1 = [(h["t"], h["freq"], h["phase"]) for h in t1.history]
        h2 = [(h["t"], h["freq"], h["phase"]) for h in t2.history]
        assert h1 == h2
        assert (e1.metrics.c.energy_joules_total
                == e2.metrics.c.energy_joules_total)

    def test_registry_agft_matches_direct_construction(self):
        e1, t1 = self._trace_engine(), AGFTTuner(A6000)
        e1.drain(policy=t1)
        e2, t2 = self._trace_engine(), get_policy("agft")
        e2.drain(policy=t2)
        assert [h["freq"] for h in t1.history] \
            == [h["freq"] for h in t2.history]

    def test_monitor_windows_match_manual_diff(self):
        from repro.energy.edp import diff_snapshots
        eng = make_engine()
        eng.submit(trace(30))
        mon = TelemetryMonitor(0.5)
        assert mon.observe(eng) is None        # first sample arms only
        s0, t0 = eng.metrics.snapshot(), eng.clock
        for _ in range(40):
            eng.step()
        w = mon.observe(eng)
        ref = diff_snapshots(s0, eng.metrics.snapshot(),
                             max(eng.clock - t0, 1e-9))
        assert w == ref                        # WindowStats is frozen/eq


# ---------------------------------------------------------------------------
# Baseline policy behaviour
# ---------------------------------------------------------------------------

class TestBaselines:
    def _energy(self, policy, n=120, rate=3.0, seed=5):
        eng = make_engine()
        eng.submit(trace(n, rate=rate, seed=seed))
        eng.drain(policy=policy)
        assert len(eng.finished) == n
        return eng.metrics.c.energy_joules_total, eng

    def test_static_below_fmax_saves_energy_when_slack_exists(self):
        e_max, _ = self._energy(None)
        e_static, eng = self._energy(StaticPolicy(A6000,
                                                  frequency_mhz=1200.0))
        assert eng.frequency == 1200.0
        assert e_static < e_max

    def test_oracle_picks_interior_frequency_and_saves(self):
        e_max, _ = self._energy(None)
        oracle = get_policy("oracle")
        e_oracle, _ = self._energy(oracle)
        assert A6000.f_min < oracle.frequency_mhz < A6000.f_max
        assert e_oracle < e_max

    def test_ondemand_downclocks_under_slack(self):
        policy = OndemandPolicy(A6000)
        eng = make_engine()
        eng.submit(trace(60, rate=0.5, seed=9))   # sparse arrivals
        eng.drain(policy=policy)
        freqs = [h["freq"] for h in policy.history]
        assert len(eng.finished) == 60
        assert min(freqs) < A6000.f_max           # it did scale down

    def test_slo_policy_walks_down_but_recovers(self):
        policy = get_policy("slo")
        eng = make_engine()
        eng.submit(trace(200, seed=3))
        eng.drain(policy=policy)
        freqs = [h["freq"] for h in policy.history]
        assert min(freqs) < A6000.f_max           # saved energy somewhere
        assert policy.tpot_slo_s is not None      # calibrated its budget

    def test_snap_to_grid(self):
        assert snap_to_grid(1203.0, A6000) == 1200.0
        assert snap_to_grid(1e9, A6000) == A6000.f_max
        assert snap_to_grid(-5.0, A6000) == A6000.f_min

    def test_observer_never_actuates(self):
        policy = get_policy("observer")
        _, eng = self._energy(policy, n=40)
        assert eng.frequency == A6000.f_max
        assert all(not h["acted"] for h in policy.history)
        assert any(h["energy_j"] for h in policy.history)


# ---------------------------------------------------------------------------
# Cluster with per-node policy mixes
# ---------------------------------------------------------------------------

class TestClusterPolicies:
    def test_heterogeneous_mix_drains(self):
        cl = ServingCluster(CFG, n_nodes=3,
                            policies=["agft", "slo", None])
        cl.submit(trace(90, seed=13))
        cl.drain()
        assert cl.summary().finished == 90
        names = [type(p).__name__ if p else None for p in cl.policies]
        assert names == ["AGFTTuner", "SLOAwareLatencyPolicy", None]

    def test_policy_instances_pass_through(self):
        static = StaticPolicy(A6000, frequency_mhz=900.0)
        cl = ServingCluster(CFG, n_nodes=2, policies=["ondemand", static])
        cl.submit(trace(60, seed=14))
        cl.drain()
        assert cl.policies[1] is static
        assert cl.summary().finished == 60
        assert cl.engines[1].frequency == 900.0

    def test_policy_count_mismatch_raises(self):
        with pytest.raises(ValueError):
            ServingCluster(CFG, n_nodes=2, policies=["agft"])

    def test_legacy_tuners_alias(self):
        cl = ServingCluster(CFG, n_nodes=2, with_tuners=True)
        assert all(isinstance(t, AGFTTuner) for t in cl.tuners)


# ---------------------------------------------------------------------------
# Fleet-scope global controller (cross-node coordination baseline)
# ---------------------------------------------------------------------------

class TestFleetGlobal:
    def test_registry_constructs_fleet_scope(self):
        p = get_policy("global")
        assert isinstance(p, GlobalFrequencyPolicy)
        assert p.scope == "fleet"
        assert "global" in available_policies()

    def test_global_sets_single_frequency_on_all_nodes(self):
        cl = ServingCluster(
            CFG, n_nodes=3,
            fleet_policy=get_policy("global", inner="static",
                                    frequency_mhz=1200.0))
        cl.submit(trace(90, seed=23))
        cl.drain()
        s = cl.summary()
        assert s.finished == 90
        assert s.node_frequencies == [1200.0, 1200.0, 1200.0]

    def test_global_agft_saves_energy_vs_fmax(self):
        base = ServingCluster(CFG, n_nodes=2, with_tuners=False)
        base.submit(trace(200, seed=25))
        base.drain()
        glob = ServingCluster(CFG, n_nodes=2, fleet_policy="global")
        glob.submit(trace(200, seed=25))
        glob.drain()
        b, g = base.summary(), glob.summary()
        assert g.finished == b.finished == 200
        assert g.energy_j < 0.9 * b.energy_j
        # one frequency for the whole fleet, always
        assert len(set(g.node_frequencies)) == 1
        assert len(glob.fleet_policy.history) > 0

    def test_global_comparable_to_per_node_on_same_trace(self):
        """The acceptance comparison: fleet-global vs per-node AGFT on an
        identical trace completes the same work; both save vs f_max."""
        def served(**kw):
            cl = ServingCluster(CFG, n_nodes=2, **kw)
            cl.submit(trace(200, seed=26))
            cl.drain()
            return cl.summary()
        base = served(with_tuners=False)
        glob = served(fleet_policy="global", with_tuners=False)
        pern = served(policies=["agft", "agft"])
        assert glob.finished == pern.finished == base.finished
        assert glob.energy_j < base.energy_j
        assert pern.energy_j < base.energy_j

    def test_fleet_policy_rejected_per_node(self):
        with pytest.raises(ValueError, match="fleet"):
            ServingCluster(CFG, n_nodes=2, policies=["global", "agft"])

    def test_node_policy_rejected_as_fleet(self):
        with pytest.raises(ValueError, match="scope"):
            ServingCluster(CFG, n_nodes=2, fleet_policy="agft")

    def test_global_maybe_act_raises(self):
        with pytest.raises(TypeError, match="fleet-scope"):
            get_policy("global").maybe_act(make_engine())

    def test_aggregate_snapshots_sums_counters_averages_levels(self):
        e1, e2 = make_engine(), make_engine()
        e1.submit(trace(20, seed=27))
        e2.submit(trace(20, seed=28))
        for e in (e1, e2):
            for _ in range(30):
                e.step()
        agg = aggregate_snapshots([e1.metrics.snapshot(),
                                   e2.metrics.snapshot()])
        assert agg["vllm:energy_joules_total"] == pytest.approx(
            e1.metrics.c.energy_joules_total
            + e2.metrics.c.energy_joules_total)
        assert agg["vllm:current_frequency_mhz"] == pytest.approx(
            (e1.frequency + e2.frequency) / 2)


# ---------------------------------------------------------------------------
# Switching-cost-aware reward (satellite; arXiv:2410.11855)
# ---------------------------------------------------------------------------

class TestSwitchingCost:
    def _window(self):
        return WindowStats(duration_s=0.8, energy_j=200.0, busy_s=0.7,
                           prefill_tokens=100, cached_prompt_tokens=0,
                           generation_tokens=500, iterations=40,
                           requests_running=8, requests_waiting=0,
                           gpu_cache_usage=0.5, cache_hit_rate=0.5,
                           mean_ttft_s=0.05)

    def test_switch_penalizes_reward(self):
        # identical reference window first (the calculator self-normalizes
        # its first sample to -1), then compare a switched vs held window
        cfg = RewardConfig(switch_cost_j=50.0)
        w = self._window()
        calc_hold, calc_move = RewardCalculator(cfg), RewardCalculator(cfg)
        calc_hold(w, switched=False)
        calc_move(w, switched=False)
        held = calc_hold(w, switched=False)
        moved = calc_move(w, switched=True)
        assert moved < held

    def test_zero_cost_reproduces_paper_reward(self):
        w = self._window()
        base = RewardCalculator(RewardConfig())(w)
        flagged = RewardCalculator(RewardConfig())(w, switched=True)
        assert base == flagged                  # cost 0 -> no-op flag

    def test_registry_variant_prices_switches(self):
        t = get_policy("agft-switchcost")
        assert t.cfg.reward.switch_cost_j > 0
        t2 = get_policy("agft-switchcost", switch_cost_j=99.0)
        assert t2.cfg.reward.switch_cost_j == 99.0

    def test_switchcost_variant_drains_and_counts_switches(self):
        eng = make_engine()
        eng.submit(trace(150, seed=29))
        t = get_policy("agft-switchcost")
        eng.drain(policy=t)
        assert len(eng.finished) == 150
        # the tuner counts changes between ITS consecutive actions; the
        # engine additionally counts the first actuation away from f_max
        assert 0 <= eng.metrics.c.freq_transitions_total \
            - t.switch_count <= 1
        assert t.switch_count > 0

    def test_engine_bills_transition_energy_when_priced(self):
        hw = dataclasses.replace(A6000, dvfs_transition_cost_j=5.0)
        eng = InferenceEngine(CFG, EngineConfig(), hardware=hw,
                              initial_frequency=hw.f_max)
        e0 = eng.metrics.c.energy_joules_total
        eng.set_frequency(1200.0)               # change: billed
        assert eng.metrics.c.energy_joules_total == e0 + 5.0
        assert eng.metrics.c.freq_transitions_total == 1
        eng.set_frequency(1200.0)               # no change: free
        assert eng.metrics.c.energy_joules_total == e0 + 5.0
        assert eng.metrics.c.freq_transitions_total == 1


# ---------------------------------------------------------------------------
# Calibrated A6000 transition costs (satellite; ROADMAP measured-billing)
# ---------------------------------------------------------------------------

class TestMeasuredTransitionSpec:
    def test_calibration_prices_transitions_without_touching_physics(self):
        assert A6000_MEASURED.dvfs_transition_cost_j > 0.0
        assert A6000_MEASURED.dvfs_transition_s > 0.0
        # same silicon otherwise: the envelope and power model match A6000
        for field in ("f_min", "f_max", "f_step", "peak_flops", "mem_bw",
                      "p_idle", "p_static_active", "p_dyn_compute",
                      "p_dyn_memory", "alpha"):
            assert getattr(A6000_MEASURED, field) == getattr(A6000, field)

    def test_one_transition_bills_energy_and_stall_time(self):
        eng = InferenceEngine(CFG, EngineConfig(),
                              hardware=A6000_MEASURED,
                              initial_frequency=A6000_MEASURED.f_max)
        e0, t0 = eng.metrics.c.energy_joules_total, eng.clock
        eng.set_frequency(1200.0)
        c = eng.metrics.c
        assert c.energy_joules_total == pytest.approx(
            e0 + A6000_MEASURED.dvfs_transition_cost_j)
        assert eng.clock == pytest.approx(
            t0 + A6000_MEASURED.dvfs_transition_s)
        assert c.freq_transitions_total == 1

    def test_transitions_show_up_in_measured_energy_not_just_reward(self):
        """Same trace, same single-actuation policy, transition cost as
        the only difference: the cost-priced run's measured energy is
        exactly one billed transition higher."""
        hw_cost = dataclasses.replace(
            A6000,
            dvfs_transition_cost_j=A6000_MEASURED.dvfs_transition_cost_j)

        def served(hw):
            eng = InferenceEngine(CFG, EngineConfig(), hardware=hw,
                                  initial_frequency=hw.f_max)
            eng.submit(trace(60, seed=35))
            eng.drain(policy=StaticPolicy(hw, frequency_mhz=1200.0))
            assert eng.metrics.c.freq_transitions_total == 1
            return eng.metrics.c.energy_joules_total
        free, priced = served(A6000), served(hw_cost)
        assert priced == pytest.approx(
            free + A6000_MEASURED.dvfs_transition_cost_j)

    def test_agft_on_measured_spec_pays_for_its_switching(self):
        eng = InferenceEngine(CFG, EngineConfig(),
                              hardware=A6000_MEASURED,
                              initial_frequency=A6000_MEASURED.f_max)
        eng.submit(trace(120, seed=36))
        tuner = get_policy("agft", hardware=A6000_MEASURED)
        eng.drain(policy=tuner)
        c = eng.metrics.c
        assert len(eng.finished) == 120
        assert c.freq_transitions_total > 0
        # every actuated change was billed into the measured counter
        assert c.energy_joules_total \
            > c.freq_transitions_total * A6000_MEASURED.dvfs_transition_cost_j


# ---------------------------------------------------------------------------
# SLO TTFT-budget mode (satellite)
# ---------------------------------------------------------------------------

class TestSLOTTFTMode:
    def test_registry_selects_mode(self):
        p = get_policy("slo", mode="ttft")
        assert p.mode == "ttft"
        alias = get_policy("slo-ttft")
        assert alias.mode == "ttft"
        with pytest.raises(ValueError, match="mode"):
            get_policy("slo", mode="e2e")

    def test_ttft_mode_calibrates_and_drains(self):
        eng = make_engine()
        eng.submit(trace(200, seed=31))
        p = get_policy("slo-ttft")
        eng.drain(policy=p)
        assert len(eng.finished) == 200
        assert p.ttft_slo_s is not None           # calibrated its budget
        assert p.tpot_slo_s is None               # never touched TPOT
        freqs = [h["freq"] for h in p.history]
        assert min(freqs) < A6000.f_max           # saved energy somewhere

    def test_explicit_ttft_budget_respected(self):
        p = get_policy("slo", mode="ttft", ttft_slo_s=0.5)
        assert p.ttft_slo_s == 0.5
        eng = make_engine()
        eng.submit(trace(80, seed=32))
        eng.drain(policy=p)
        assert p.ttft_slo_s == 0.5                # explicit budget held


# ---------------------------------------------------------------------------
# TTFT accounting (satellite fix)
# ---------------------------------------------------------------------------

class TestTTFTAccounting:
    def test_every_finished_request_counted_once(self):
        eng = make_engine()
        eng.submit(trace(80, rate=5.0, seed=17))
        eng.drain()
        c = eng.metrics.c
        assert c.ttft_count_total == len(eng.finished) == 80
        mean_ttft = np.mean([r.ttft for r in eng.finished])
        assert c.ttft_seconds_total / c.ttft_count_total \
            == pytest.approx(mean_ttft)

    def test_counted_once_under_preemption(self):
        eng = InferenceEngine(CFG, EngineConfig(num_kv_blocks=96,
                                                max_num_seqs=32),
                              initial_frequency=A6000.f_max)
        eng.submit(trace(60, rate=50.0, seed=5,
                         workload="high_concurrency"))
        eng.drain()
        assert eng.metrics.c.ttft_count_total == len(eng.finished) == 60
