"""Routed-arrival + POLICY_TICK event-core tests: NetworkModel pricing
(hop distributions, router FIFO queueing, determinism), the
DeliverySchedule event source, ARRIVAL rescheduling through the event
loop (stale-event supersession, drained-node revival, t_end-cut
resumption), zero-delay byte-identity with direct submit in BOTH policy
scheduling modes, the golden equivalences (iteration-gated == committed
golden through the routed path; pure-tick == the committed tick golden),
and tick-mode semantics on windowed policies."""
import json
import os

import pytest

from repro.configs import get_config
from repro.core import AGFTTuner
from repro.energy import A6000
from repro.policies import StaticPolicy, get_policy
from repro.serving import (EngineConfig, EngineNode, EventKind, EventLoop,
                           InferenceEngine, NetworkConfig, NetworkModel,
                           Request)
from repro.serving.cluster import ServingCluster
from repro.serving.network import PRESETS, DeliverySchedule
from repro.workloads import PROTOTYPES, generate_requests

CFG = get_config("llama3-3b")
HERE = os.path.dirname(__file__)
GOLDEN = os.path.join(HERE, "golden_agft_decisions.json")
GOLDEN_TICK = os.path.join(HERE, "golden_agft_decisions_tick.json")


def make_engine(**kw):
    return InferenceEngine(CFG, EngineConfig(**kw),
                           initial_frequency=A6000.f_max)


def trace(n=80, rate=3.0, seed=21, workload="normal"):
    return generate_requests(PROTOTYPES[workload], n, base_rate=rate,
                             seed=seed)


# ---------------------------------------------------------------------------
# NetworkModel pricing
# ---------------------------------------------------------------------------

class TestNetworkModel:
    def test_zero_model_prices_arrival_exactly(self):
        net = NetworkModel()
        for t in (0.0, 0.1, 3.7, 1234.5678901234):
            assert net.delivery_time(t) == t       # bit-exact, no detour

    def test_constant_hops_add_up(self):
        net = NetworkModel(NetworkConfig(hop_latency_s=5e-3,
                                         router_service_s=1e-3))
        # sparse arrivals: no queueing, so delay = 2 hops + 1 service
        assert net.delivery_time(10.0) == pytest.approx(10.0 + 11e-3)
        assert net.delivery_time(20.0) == pytest.approx(20.0 + 11e-3)

    def test_router_fifo_queues_bursts(self):
        net = NetworkModel(NetworkConfig(router_service_s=2e-3))
        # 4 simultaneous arrivals drain through one dispatch pipe
        ts = [net.delivery_time(1.0) for _ in range(4)]
        assert ts == pytest.approx([1.002, 1.004, 1.006, 1.008])
        # pipe goes idle before a later arrival: no residual queueing
        assert net.delivery_time(5.0) == pytest.approx(5.002)

    def test_seeded_streams_reproduce(self):
        cfg = NetworkConfig(hop_latency_s=10e-3, distribution="lognormal",
                            jitter=0.5)
        a = [NetworkModel(cfg, seed=3).delivery_time(t)
             for t in (0.0, 1.0, 2.0)]
        b = [NetworkModel(cfg, seed=3).delivery_time(t)
             for t in (0.0, 1.0, 2.0)]
        c = [NetworkModel(cfg, seed=4).delivery_time(t)
             for t in (0.0, 1.0, 2.0)]
        assert a == b
        assert a != c

    def test_uniform_jitter_bounded(self):
        net = NetworkModel(NetworkConfig(hop_latency_s=10e-3,
                                         distribution="uniform",
                                         jitter=0.5))
        for _ in range(50):
            d = net.delivery_time(0.0)
            assert 2 * 5e-3 <= d <= 2 * 15e-3

    def test_lognormal_mean_calibrated(self):
        net = NetworkModel(NetworkConfig(hop_latency_s=10e-3,
                                         distribution="lognormal",
                                         jitter=0.3), seed=1)
        delays = [net.delivery_time(0.0) for _ in range(400)]
        assert all(d > 0 for d in delays)
        mean = sum(delays) / len(delays)
        assert mean == pytest.approx(20e-3, rel=0.15)

    def test_delays_never_negative(self):
        for name in PRESETS:
            net = NetworkModel(PRESETS[name], seed=9)
            for t in (0.0, 0.5, 1.0):
                assert net.delivery_time(t) >= t

    def test_from_spec_presets_and_fixed(self):
        assert NetworkModel.from_spec("wan").config is PRESETS["wan"]
        fixed = NetworkModel.from_spec("fixed:30")
        assert fixed.delivery_time(2.0) == pytest.approx(2.030)
        with pytest.raises(ValueError, match="unknown network spec"):
            NetworkModel.from_spec("interplanetary")
        with pytest.raises(ValueError):
            NetworkModel.from_spec("fixed:-1")

    def test_invalid_config_rejected(self):
        with pytest.raises(ValueError, match="distribution"):
            NetworkModel(NetworkConfig(distribution="cauchy"))
        with pytest.raises(ValueError, match=">= 0"):
            NetworkModel(NetworkConfig(hop_latency_s=-1.0))

    def test_override_kwargs(self):
        net = NetworkModel(PRESETS["wan"], hop_latency_s=1e-3, jitter=0.0)
        assert net.config.hop_latency_s == 1e-3
        assert net.config.router_service_s == PRESETS["wan"].router_service_s


class TestDeliverySchedule:
    def test_pop_due_time_then_fifo_order(self):
        sched = DeliverySchedule()
        sched.push(2.0, 1, "b")
        sched.push(1.0, 0, "a")
        sched.push(2.0, 0, "c")        # same time as "b": FIFO after it
        assert sched.next_time() == 1.0
        assert sched.pop_due(1.5) == [(0, "a")]
        assert sched.pop_due(1.6) == []
        assert sched.pop_due(2.0) == [(1, "b"), (0, "c")]
        assert len(sched) == 0
        assert sched.next_time() is None

    def test_first_time_per_node(self):
        sched = DeliverySchedule()
        sched.push(3.0, 0, "x")
        sched.push(1.0, 1, "y")
        sched.push(2.0, 0, "z")
        assert sched.first_time_per_node() == {0: 2.0, 1: 1.0}


# ---------------------------------------------------------------------------
# Zero-delay network == direct submit, byte for byte (both tick modes)
# ---------------------------------------------------------------------------

def _cluster_state(cl):
    return {
        "finished": [len(e.finished) for e in cl.engines],
        "clocks": [e.clock for e in cl.engines],
        "energies": [e.metrics.c.energy_joules_total for e in cl.engines],
        "iterations": [e.metrics.c.iterations_total for e in cl.engines],
        "frequencies": [e.frequency for e in cl.engines],
        "histories": [[(h["t"], h["freq"], h["phase"]) for h in p.history]
                      for p in cl.policies if p is not None],
    }


class TestZeroDelayEquivalence:
    @pytest.mark.parametrize("mode", ["iteration", "tick"])
    @pytest.mark.parametrize("n_nodes", [1, 3])
    def test_zero_delay_byte_identical_to_direct(self, mode, n_nodes):
        def serve(net):
            cl = ServingCluster(CFG, n_nodes=n_nodes,
                                policies=["agft"] * n_nodes,
                                network=net, policy_tick_mode=mode)
            cl.submit(trace(90, seed=33))
            steps = cl.drain()
            return steps, _cluster_state(cl)
        s_direct, direct = serve(None)
        s_net, routed = serve(NetworkModel())
        assert direct == routed
        assert s_direct == s_net

    def test_zero_delay_requests_carry_delivery_times(self):
        cl = ServingCluster(CFG, n_nodes=2, with_tuners=False,
                            network=NetworkModel())
        cl.submit(trace(30, seed=8))
        cl.drain()
        fin = [r for e in cl.engines for r in e.finished]
        assert len(fin) == 30
        assert all(r.delivery_time == r.arrival_time for r in fin)
        assert all(r.net_delay == 0.0 for r in fin)
        s = cl.summary()
        assert s.mean_net_delay_s == 0.0
        assert s.max_net_delay_s == 0.0


# ---------------------------------------------------------------------------
# Delayed arrivals through the event loop
# ---------------------------------------------------------------------------

class TestDelayedArrivals:
    def _serve(self, net, n=60, seed=12, **kw):
        cl = ServingCluster(CFG, n_nodes=2, with_tuners=False,
                            network=net, **kw)
        cl.submit(trace(n, seed=seed))
        cl.drain()
        return cl

    def test_delay_completes_and_never_time_travels(self):
        cl = self._serve(NetworkModel.from_spec("wan", seed=5))
        fin = [r for e in cl.engines for r in e.finished]
        assert len(fin) == 60
        for r in fin:
            assert r.delivery_time > r.arrival_time
            # a request is never scheduled before the network delivered it
            assert r.first_scheduled_time >= r.delivery_time - 1e-12

    def test_delay_inflates_ttft_not_finish_count(self):
        direct = self._serve(None)
        routed = self._serve(NetworkModel.from_spec("fixed:40"))
        sd, sr = direct.summary(), routed.summary()
        assert sr.finished == sd.finished == 60
        assert sr.mean_net_delay_s == pytest.approx(0.040)
        # the 40 ms spent in the network lands in first-token latency
        assert sr.mean_ttft_s > sd.mean_ttft_s + 0.030

    def test_inflight_counts_drain_to_zero(self):
        cl = self._serve(NetworkModel.from_spec("wan"))
        assert all(e.inflight == 0 for e in cl.engines)
        assert len(cl._deliveries) == 0
        assert not cl.has_work

    def test_route_events_counted(self):
        cl = self._serve(NetworkModel.from_spec("wan"))
        counts = cl._loop.counts
        assert counts[EventKind.ROUTE] > 0
        assert counts[EventKind.ARRIVAL] + counts[EventKind.ITERATION] \
            == cl._loop.steps

    def test_waiting_telemetry_includes_inflight(self):
        eng = make_engine()
        eng.inflight = 7
        eng.submit(trace(5, seed=2))
        for _ in range(3):
            eng.step()
        assert eng.metrics.c.requests_waiting >= 7
        assert eng.num_pending >= 7


class TestArrivalRescheduling:
    def _delivery(self, t, node, prompt=64, out=16, arrival=0.0):
        sched = DeliverySchedule()
        sched.push(t, node, Request(arrival_time=arrival, prompt_len=prompt,
                                    output_len=out))
        return sched

    def test_delivery_revives_drained_node(self):
        eng = make_engine()                      # no initial work at all
        sched = self._delivery(1.5, 0)
        loop = EventLoop([EngineNode(eng, None)], router=sched)
        steps = loop.run()
        assert steps > 0
        assert len(eng.finished) == 1
        assert eng.finished[0].first_scheduled_time >= 1.5
        assert loop.counts[EventKind.ROUTE] == 1

    def test_early_delivery_supersedes_scheduled_arrival(self):
        eng = make_engine()
        late = Request(arrival_time=10.0, prompt_len=64, output_len=8)
        eng.submit([late])                       # ARRIVAL event lands at 10
        sched = self._delivery(2.0, 0)           # ...but this lands at 2
        loop = EventLoop([EngineNode(eng, None)], router=sched)
        loop.run()
        assert len(eng.finished) == 2
        delivered = next(r for r in eng.finished if r is not late)
        assert delivered.first_scheduled_time < 10.0
        assert delivered.finish_time < late.first_scheduled_time
        # the stale ARRIVAL@10 was orphaned, not double-fired
        assert loop.counts[EventKind.ARRIVAL] >= 1

    def test_t_end_cut_resumes_consistently(self):
        net = NetworkModel.from_spec("fixed:20")
        cl = ServingCluster(CFG, n_nodes=2, with_tuners=False, network=net)
        cl.submit(trace(40, rate=1.0, seed=4))
        loop = EventLoop(cl.nodes, router=cl._deliveries, t_end=5.0)
        loop.run()
        fin_early = sum(len(e.finished) for e in cl.engines)
        assert fin_early < 40                    # the horizon cut the run
        assert cl.has_work                       # deliveries/work remain
        cl.drain()                               # fresh loop resumes
        assert sum(len(e.finished) for e in cl.engines) == 40
        assert all(e.inflight == 0 for e in cl.engines)

    def test_fleet_tick_survives_all_nodes_idle_with_inflight(self):
        """The fleet policy must keep ticking while every node is
        momentarily drained but deliveries are still in flight."""
        eng = make_engine()
        sched = DeliverySchedule()
        for k in range(3):
            sched.push(2.0 + 2.0 * k, 0,
                       Request(arrival_time=0.0, prompt_len=32,
                               output_len=8))
        meter = get_policy("fleet-meter", power_cap_w=1.0)
        loop = EventLoop([EngineNode(eng, None)], fleet_policy=meter,
                         router=sched)
        loop.run()
        assert len(eng.finished) == 3
        assert loop.counts[EventKind.FLEET_TICK] > 3
        assert loop.metered_s > 0.0


# ---------------------------------------------------------------------------
# Golden equivalence: the acceptance configuration
# ---------------------------------------------------------------------------

class TestGoldenEquivalence:
    def _golden_trace(self, gold):
        tr = gold["trace"]
        return generate_requests(PROTOTYPES[tr["workload"]], tr["n"],
                                 base_rate=tr["rate"], seed=tr["seed"])

    def _assert_matches(self, gold, tuner, eng):
        assert [h["freq"] for h in tuner.history] == gold["freqs"]
        assert [h["phase"] for h in tuner.history] == gold["phases"]
        assert tuner.round == gold["rounds"]
        assert eng.metrics.c.energy_joules_total == pytest.approx(
            gold["energy_j"], rel=1e-12)
        assert eng.clock == pytest.approx(gold["clock"], rel=1e-12)

    def test_zero_delay_iteration_gated_reproduces_golden(self):
        """The PR's acceptance bit: routing through a zero-delay network
        with iteration-gated policies must not move one AGFT decision vs
        the committed golden trajectory."""
        with open(GOLDEN) as f:
            gold = json.load(f)
        tuner = AGFTTuner(A6000)
        cl = ServingCluster(CFG, n_nodes=1, policies=[tuner],
                            network=NetworkModel(),
                            policy_tick_mode="iteration")
        cl.submit(self._golden_trace(gold))
        cl.drain()
        self._assert_matches(gold, tuner, cl.engines[0])

    def test_pure_tick_reproduces_tick_golden(self):
        with open(GOLDEN_TICK) as f:
            gold = json.load(f)
        eng = make_engine()
        eng.submit(self._golden_trace(gold))
        tuner = AGFTTuner(A6000)
        EventLoop([EngineNode(eng, tuner)], policy_tick_mode="tick").run()
        self._assert_matches(gold, tuner, eng)

    def test_tick_golden_through_zero_delay_cluster(self):
        """Pure-tick + zero-delay network lands on the same committed
        tick trajectory — the two event sources compose without moving
        decisions."""
        with open(GOLDEN_TICK) as f:
            gold = json.load(f)
        tuner = AGFTTuner(A6000)
        cl = ServingCluster(CFG, n_nodes=1, policies=[tuner],
                            network=NetworkModel(),
                            policy_tick_mode="tick")
        cl.submit(self._golden_trace(gold))
        cl.drain()
        self._assert_matches(gold, tuner, cl.engines[0])

    def test_the_two_goldens_differ(self):
        """Decoupling decision boundaries from iteration boundaries must
        actually change the trajectory — otherwise the second golden
        pins nothing."""
        with open(GOLDEN) as f:
            gold = json.load(f)
        with open(GOLDEN_TICK) as f:
            tick = json.load(f)
        assert gold["freqs"] != tick["freqs"]


# ---------------------------------------------------------------------------
# POLICY_TICK semantics
# ---------------------------------------------------------------------------

class TestPolicyTickMode:
    def test_invalid_mode_rejected(self):
        with pytest.raises(ValueError, match="policy_tick_mode"):
            EventLoop([EngineNode(make_engine(), None)],
                      policy_tick_mode="hourly")
        with pytest.raises(ValueError, match="policy_tick_mode"):
            ServingCluster(CFG, n_nodes=2, policy_tick_mode="hourly")

    def test_tick_mode_windows_cut_on_wallclock_cadence(self):
        policy = get_policy("observer")          # records, never actuates
        eng = make_engine()
        eng.submit(trace(60, seed=6))
        EventLoop([EngineNode(eng, policy)], policy_tick_mode="tick").run()
        ts = [h["t"] for h in policy.history]
        assert len(ts) > 3
        gaps = [b - a for a, b in zip(ts, ts[1:])]
        # exact wall-clock periods, not iteration-boundary overshoots
        assert all(g == pytest.approx(0.8) for g in gaps)

    def test_iteration_mode_windows_land_on_iteration_boundaries(self):
        policy = get_policy("observer")
        eng = make_engine()
        eng.submit(trace(60, seed=6))
        eng.drain(policy=policy)
        gaps = [b - a for a, b in zip(
            (h["t"] for h in policy.history),
            [h["t"] for h in policy.history][1:])]
        # the engine clock gates: windows stretch past the period
        assert any(g > 0.8 + 1e-6 for g in gaps)

    def test_windowed_policy_tick_respects_band_and_envelope(self):
        policy = StaticPolicy(A6000, frequency_mhz=1200.0)
        policy.set_band(600.0, 900.0)
        eng = make_engine()
        eng.submit(trace(40, seed=14))
        EventLoop([EngineNode(eng, policy)], policy_tick_mode="tick").run()
        assert eng.frequency == 900.0

    def test_duck_typed_policy_falls_back_to_maybe_act(self):
        calls = []

        class Minimal:
            def maybe_act(self, engine):
                calls.append(engine.clock)
                return None

        eng = make_engine()
        eng.submit(trace(30, seed=7))
        EventLoop([EngineNode(eng, Minimal())],
                  policy_tick_mode="tick").run()
        assert calls                             # ticked via the fallback
        assert len(eng.finished) == 30

    def test_tick_counts_exposed(self):
        eng = make_engine()
        eng.submit(trace(40, seed=9))
        loop = EventLoop([EngineNode(eng, get_policy("observer"))],
                         policy_tick_mode="tick")
        loop.run()
        assert loop.counts[EventKind.POLICY_TICK] > 0
        assert loop.counts[EventKind.POLICY_TICK] \
            >= len(loop.nodes[0].policy.history)

    def test_tick_mode_with_heterogeneous_periods(self):
        nodes = []
        for period in (0.4, 1.6):
            eng = make_engine()
            eng.submit(trace(40, seed=10))
            nodes.append(EngineNode(
                eng, get_policy("observer", sampling_period_s=period)))
        EventLoop(nodes, policy_tick_mode="tick").run()
        h_fast = nodes[0].policy.history
        h_slow = nodes[1].policy.history
        assert len(h_fast) > len(h_slow)

    def test_tick_train_restarts_with_node_revival(self):
        """A bare DeliverySchedule user (no ServingCluster inflight
        bookkeeping): the node drains between widely-spaced deliveries,
        killing its tick train — the reviving ROUTE must restart it, or
        later requests would be served with zero policy decisions."""
        eng = make_engine()
        sched = DeliverySchedule()
        sched.push(0.0, 0, Request(arrival_time=0.0, prompt_len=64,
                                   output_len=8))
        sched.push(30.0, 0, Request(arrival_time=30.0, prompt_len=64,
                                    output_len=8))
        policy = get_policy("observer")
        loop = EventLoop([EngineNode(eng, policy)], router=sched,
                         policy_tick_mode="tick")
        loop.run()
        assert len(eng.finished) == 2
        ts = [h["t"] for h in policy.history]
        # decisions exist on BOTH sides of the drained 30 s gap
        assert any(t < 10.0 for t in ts)
        assert any(t >= 30.0 for t in ts)
        # ...but the train did die in between instead of ticking idly
        assert not any(10.0 < t < 30.0 for t in ts)

    def test_cluster_threads_tick_mode_and_network(self):
        cl = ServingCluster(CFG, n_nodes=2, policies=["agft", "slo"],
                            network="wan", policy_tick_mode="tick")
        cl.submit(trace(60, seed=11))
        cl.drain()
        s = cl.summary()
        assert s.finished == 60
        assert s.mean_net_delay_s > 0.0
        assert all(len(p.history) > 0 for p in cl.policies)
