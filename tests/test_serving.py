"""Serving-substrate tests: scheduler/KV-cache invariants, engine
accounting, energy model monotonicities, and the AGFT closed loop
end-to-end on the simulated engine. (The hypothesis-based KV property
test lives in test_property.py so this module runs without hypothesis.)"""
import numpy as np
import pytest

from repro.configs import get_config
from repro.core import AGFTTuner
from repro.energy import A6000, DVFSModel, active_param_count, param_count
from repro.energy.edp import diff_snapshots
from repro.serving import (EngineConfig, InferenceEngine, PagedKVCache,
                           Request)
from repro.workloads import PROTOTYPES, generate_azure_trace, \
    generate_requests

CFG = get_config("llama3-3b")


# ---------------------------------------------------------------------------
# KV cache properties
# ---------------------------------------------------------------------------

class TestPagedKVCache:
    def test_prefix_cache_hits_on_repeat_template(self):
        kv = PagedKVCache(num_blocks=512, block_size=16)
        r1 = Request(arrival_time=0, prompt_len=320, output_len=10,
                     template_id=7)
        assert kv.try_allocate(r1, 330)
        assert r1.cached_tokens == 0
        kv.register_prefix(r1)
        kv.free(r1)
        r2 = Request(arrival_time=1, prompt_len=320, output_len=10,
                     template_id=7)
        assert kv.try_allocate(r2, 330)
        assert r2.cached_tokens > 0                   # prefix reused
        assert kv.stats.hit_rate > 0

    def test_no_hits_across_templates(self):
        kv = PagedKVCache(num_blocks=512, block_size=16)
        r1 = Request(arrival_time=0, prompt_len=320, output_len=10,
                     template_id=1)
        kv.try_allocate(r1, 330)
        kv.register_prefix(r1)
        kv.free(r1)
        r2 = Request(arrival_time=1, prompt_len=320, output_len=10,
                     template_id=2)
        kv.try_allocate(r2, 330)
        assert r2.cached_tokens == 0

    def test_allocation_fails_when_full_then_recovers(self):
        kv = PagedKVCache(num_blocks=8, block_size=16,
                          enable_prefix_cache=False)
        r1 = Request(arrival_time=0, prompt_len=100, output_len=28)
        assert kv.try_allocate(r1, 128)               # all 8 blocks
        r2 = Request(arrival_time=0, prompt_len=100, output_len=28)
        assert not kv.try_allocate(r2, 128)
        kv.free(r1)
        assert kv.try_allocate(r2, 128)


# ---------------------------------------------------------------------------
# Scheduler / engine behaviour
# ---------------------------------------------------------------------------

class TestEngine:
    def _engine(self, **kw):
        return InferenceEngine(CFG, EngineConfig(**kw),
                               initial_frequency=A6000.f_max)

    def test_all_requests_finish_with_correct_tokens(self):
        eng = self._engine()
        reqs = generate_requests(PROTOTYPES["normal"], 50, base_rate=5.0,
                                 seed=0)
        eng.submit(reqs)
        eng.drain()
        assert len(eng.finished) == 50
        for r in eng.finished:
            assert r.generated == r.output_len
            assert r.prefilled == r.prompt_len
            assert r.finish_time >= r.arrival_time
            assert r.ttft is not None and r.ttft > 0

    def test_continuous_batching_interleaves_prefill_and_decode(self):
        eng = self._engine(prefill_chunk=128, max_batched_tokens=512)
        reqs = generate_requests(PROTOTYPES["normal"], 40, base_rate=20.0,
                                 seed=1)
        eng.submit(reqs)
        mixed = 0
        while eng.has_work:
            eng._ingest_arrivals()
            plan = eng.sched.schedule(eng.clock)
            if plan.prefill and plan.decode:
                mixed += 1
            if plan.empty:
                eng.step()
                continue
            dt, energy, power = eng.backend.execute(plan, eng.frequency)
            eng.clock += dt
            fin = eng.sched.complete_iteration(plan, eng.clock)
            eng.finished.extend(fin)
            eng.metrics.c.energy_joules_total += energy
            eng.metrics.c.busy_seconds_total += dt
            eng.metrics.c.generation_tokens_total += plan.decode_seqs
            eng.metrics.c.iterations_total += 1
        assert mixed > 0                     # prefill+decode share iterations

    def test_token_budget_respected(self):
        eng = self._engine(max_batched_tokens=256, prefill_chunk=128)
        eng.submit(generate_requests(PROTOTYPES["long_context"], 20,
                                     base_rate=50.0, seed=2))
        while eng.has_work:
            eng._ingest_arrivals()
            plan = eng.sched.schedule(eng.clock)
            assert plan.total_tokens <= 256
            if plan.empty:
                eng.step()
                continue
            dt, e, p = eng.backend.execute(plan, eng.frequency)
            eng.clock += dt
            eng.finished.extend(eng.sched.complete_iteration(plan, eng.clock))

    def test_energy_monotone_in_frequency_at_fixed_work(self):
        energies = []
        for f in (600.0, 1200.0, 1800.0):
            eng = self._engine()
            eng.set_frequency(f)
            eng.submit(generate_requests(PROTOTYPES["normal"], 30,
                                         base_rate=100.0, seed=3))
            eng.drain()
            energies.append(eng.metrics.c.busy_seconds_total and
                            eng.metrics.c.energy_joules_total)
        assert energies[0] < energies[2]      # downclocking saves energy

    def test_latency_monotone_decreasing_in_frequency(self):
        tpots = []
        for f in (400.0, 1800.0):
            eng = self._engine()
            eng.set_frequency(f)
            eng.submit(generate_requests(PROTOTYPES["normal"], 30,
                                         base_rate=100.0, seed=3))
            eng.drain()
            tpots.append(np.mean([r.tpot for r in eng.finished
                                  if r.tpot is not None]))
        assert tpots[0] > tpots[1]

    def test_metrics_snapshot_diff(self):
        eng = self._engine()
        eng.submit(generate_requests(PROTOTYPES["normal"], 20,
                                     base_rate=10.0, seed=4))
        s0 = eng.metrics.snapshot()
        t0 = eng.clock
        for _ in range(50):
            if not eng.has_work:
                break
            eng.step()
        w = diff_snapshots(s0, eng.metrics.snapshot(), eng.clock - t0)
        assert w.energy_j > 0
        assert w.generation_tokens >= 0
        assert 0 <= w.cache_hit_rate <= 1
        assert w.edp >= 0

    def test_preemption_under_kv_pressure(self):
        eng = self._engine(num_kv_blocks=96, max_num_seqs=32)
        eng.submit(generate_requests(PROTOTYPES["high_concurrency"], 60,
                                     base_rate=50.0, seed=5))
        eng.drain()
        assert len(eng.finished) == 60        # everything still completes


# ---------------------------------------------------------------------------
# Energy / power model
# ---------------------------------------------------------------------------

class TestPowerModel:
    def test_power_increases_with_frequency(self):
        m = DVFSModel(A6000)
        _, p_low = m.iteration_time_power(1e12, 1e9, 600.0)
        _, p_high = m.iteration_time_power(1e12, 1e9, 1800.0)
        assert p_high > p_low

    def test_compute_bound_latency_scales_inverse_freq(self):
        m = DVFSModel(A6000)
        t1, _ = m.iteration_time_power(1e13, 1e6, 700.0)
        t2, _ = m.iteration_time_power(1e13, 1e6, 1400.0)
        assert t1 / t2 == pytest.approx(2.0, rel=0.05)

    def test_memory_bound_latency_flat_above_knee(self):
        m = DVFSModel(A6000)
        f_knee = A6000.bw_knee * A6000.f_max
        t1, _ = m.iteration_time_power(1e6, 1e10, f_knee + 100)
        t2, _ = m.iteration_time_power(1e6, 1e10, A6000.f_max)
        assert t1 == pytest.approx(t2, rel=0.02)

    def test_edp_u_shape_for_memory_bound_work(self):
        """EDP(f) = P t^2 must have an interior minimum for decode-like
        (memory-bound) work — the core phenomenon behind the paper."""
        m = DVFSModel(A6000)
        freqs = np.arange(210, 1801, 15)
        edp = []
        for f in freqs:
            t, p = m.iteration_time_power(5e10, 1.2e10, float(f))
            edp.append(p * t * t)
        i = int(np.argmin(edp))
        assert 0 < i < len(freqs) - 1, "optimum must be interior"
        assert 900 <= freqs[i] <= 1500

    def test_param_counts_scale(self):
        n = param_count(CFG)
        assert 2.5e9 < n < 4.5e9              # llama-3-3b class
        moe = get_config("llama4-scout-17b-a16e")
        assert active_param_count(moe) < 0.35 * param_count(moe)


# ---------------------------------------------------------------------------
# AGFT end-to-end on the simulated engine
# ---------------------------------------------------------------------------

class TestAGFTEndToEnd:
    def _run(self, tuner, n=400, rate=3.0, seed=7, workload="normal"):
        eng = InferenceEngine(CFG, EngineConfig(),
                              initial_frequency=A6000.f_max)
        eng.submit(generate_requests(PROTOTYPES[workload], n,
                                     base_rate=rate, seed=seed))
        eng.drain(policy=tuner)
        return eng

    def test_agft_saves_energy_and_improves_edp(self):
        base = self._run(None)
        tuner = AGFTTuner(A6000)
        agft = self._run(tuner)
        eb = base.metrics.c.energy_joules_total
        ea = agft.metrics.c.energy_joules_total
        tpb = np.mean([r.tpot for r in base.finished if r.tpot is not None])
        tpa = np.mean([r.tpot for r in agft.finished if r.tpot is not None])
        assert ea < 0.8 * eb                          # >=20% energy saving
        assert ea * tpa < eb * tpb                    # EDP strictly better
        assert len(agft.finished) == len(base.finished)

    def test_agft_converges_and_exploits(self):
        tuner = AGFTTuner(A6000)
        self._run(tuner, n=800)
        post = [h for h in tuner.history if h["converged"]]
        assert len(post) > 0.3 * len(tuner.history)
        assert any(h["phase"] == "exploit" for h in tuner.history)

    def test_pruning_shrinks_action_space(self):
        tuner = AGFTTuner(A6000)
        self._run(tuner, n=600)
        assert len(tuner.pruner.permanently_pruned) > 0
        # pruned frequencies never re-enter the action space
        assert not (set(tuner.bank.arms)
                    & tuner.pruner.permanently_pruned)

    def test_privacy_boundary_features_only(self):
        """The tuner's contexts must be derivable from aggregate metrics
        alone: 7 dims, no per-request fields."""
        tuner = AGFTTuner(A6000)
        self._run(tuner, n=200)
        assert tuner.prev_context.shape == (7,)

    def test_adapts_to_azure_nonstationary_trace(self):
        eng = InferenceEngine(CFG, EngineConfig(),
                              initial_frequency=A6000.f_max)
        eng.submit(generate_azure_trace(600.0, base_rate=2.0, seed=8))
        tuner = AGFTTuner(A6000)
        eng.drain(policy=tuner)
        base = InferenceEngine(CFG, EngineConfig(),
                               initial_frequency=A6000.f_max)
        base.submit(generate_azure_trace(600.0, base_rate=2.0, seed=8))
        base.drain()
        assert (eng.metrics.c.energy_joules_total
                < 0.9 * base.metrics.c.energy_joules_total)
