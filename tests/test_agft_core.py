"""Unit tests for the AGFT decision stack: LinUCB math, Page-Hinkley,
pruning mechanisms, refinement, reward normalization, feature extraction."""
import numpy as np

from repro.core import (ConvergenceConfig, ConvergenceDetector,
                        FeatureExtractor, LinUCBArm, LinUCBBank, PageHinkley,
                        PruningConfig, PruningFramework, RefinementConfig,
                        MixedMaturityRefinement, RewardCalculator,
                        RewardConfig)
from repro.energy.edp import WindowStats


def make_window(**kw):
    base = dict(duration_s=0.8, energy_j=100.0, busy_s=0.6,
                prefill_tokens=500, cached_prompt_tokens=0,
                generation_tokens=300, iterations=40, requests_running=8,
                requests_waiting=0, gpu_cache_usage=0.4, cache_hit_rate=0.1)
    base.update(kw)
    return WindowStats(**base)


# ---------------------------------------------------------------------------
# LinUCB
# ---------------------------------------------------------------------------

class TestLinUCB:
    def test_sherman_morrison_matches_direct_inverse(self):
        rng = np.random.default_rng(0)
        arm = LinUCBArm(dim=7)
        for _ in range(50):
            arm.update(rng.uniform(0, 1, 7), rng.normal())
        np.testing.assert_allclose(arm.A_inv, np.linalg.inv(arm.A),
                                   rtol=1e-8, atol=1e-10)

    def test_theta_is_ridge_solution(self):
        rng = np.random.default_rng(1)
        arm = LinUCBArm(dim=4)
        X, r = [], []
        for _ in range(30):
            x = rng.uniform(0, 1, 4)
            rew = float(x @ np.array([1.0, -2.0, 0.5, 0.0]) + 0.01)
            arm.update(x, rew)
            X.append(x)
            r.append(rew)
        X = np.array(X)
        r = np.array(r)
        theta_direct = np.linalg.solve(np.eye(4) + X.T @ X, X.T @ r)
        np.testing.assert_allclose(arm.theta, theta_direct, rtol=1e-8)

    def test_learns_linear_reward_and_selects_best_arm(self):
        rng = np.random.default_rng(2)
        bank = LinUCBBank([600.0, 1200.0, 1800.0], dim=3)
        true = {600.0: np.array([-2.0, 0.0, 0.1]),
                1200.0: np.array([-0.5, 0.2, 0.0]),
                1800.0: np.array([-1.0, -0.1, 0.3])}
        for _ in range(400):
            x = rng.uniform(0, 1, 3)
            f = bank.select_ucb(x, alpha=0.5)
            r = float(true[f] @ x + 0.05 * rng.normal())
            bank.arms[f].update(x, r)
        x = np.array([1.0, 0.5, 0.5])
        assert bank.select_greedy(x) == 1200.0

    def test_ucb_bonus_shrinks_with_samples(self):
        arm = LinUCBArm(dim=3)
        x = np.array([1.0, 0.5, 0.2])
        b0 = arm.ucb(x, 1.0) - arm.predict(x)
        for _ in range(20):
            arm.update(x, -1.0)
        b1 = arm.ucb(x, 1.0) - arm.predict(x)
        assert b1 < b0

    def test_rebuild_warm_start(self):
        bank = LinUCBBank([900.0, 1200.0], dim=2)
        x = np.array([1.0, 0.5])
        for _ in range(10):
            bank.arms[1200.0].update(x, -0.8)
        bank.rebuild([1185.0, 1200.0, 1215.0], warm_from=1200.0)
        assert bank.arms[1215.0].n == 10                 # inherited prior
        assert bank.arms[1200.0].n == 10                 # survived intact
        assert 900.0 not in bank.arms


# ---------------------------------------------------------------------------
# Page-Hinkley / convergence
# ---------------------------------------------------------------------------

class TestPageHinkley:
    def test_no_alarm_on_stationary(self):
        rng = np.random.default_rng(3)
        ph = PageHinkley(delta=0.1, threshold=2.0)
        alarms = sum(ph.update(-1 + 0.05 * rng.normal()) for _ in range(500))
        assert alarms == 0

    def test_alarm_on_mean_shift(self):
        rng = np.random.default_rng(4)
        ph = PageHinkley(delta=0.1, threshold=2.0)
        for _ in range(100):
            ph.update(-1 + 0.05 * rng.normal())
        fired = any(ph.update(-3 + 0.05 * rng.normal()) for _ in range(60))
        assert fired

    def test_convergence_then_drift_reopens(self):
        rng = np.random.default_rng(5)
        det = ConvergenceDetector(ConvergenceConfig(
            stable_rounds=20, std_threshold=0.3))
        for _ in range(80):
            det.update(-1 + 0.1 * rng.normal())
        assert det.converged
        assert det.converged_round is not None
        for _ in range(80):
            det.update(-4 + 0.1 * rng.normal())
        assert det.reopened >= 1


# ---------------------------------------------------------------------------
# Pruning
# ---------------------------------------------------------------------------

class TestPruning:
    def _bank(self, freqs, dim=3):
        return LinUCBBank([float(f) for f in freqs], dim=dim)

    def test_extreme_pruning_removes_pathological_arm(self):
        bank = self._bank([300, 900, 1500])
        pruner = PruningFramework(PruningConfig(min_arms=2), f_max=1800)
        x = np.ones(3)
        for _ in range(4):
            bank.arms[300.0].update(x, -2.0, edp=10)   # far below -1.2
            bank.arms[900.0].update(x, -1.0, edp=5)
            bank.arms[1500.0].update(x, -1.0, edp=5)
        pruner.apply(bank, round_idx=10)
        assert 300.0 not in bank.arms
        assert any(e["mechanism"] == "extreme" for e in pruner.log)

    def test_extreme_pruning_only_in_early_phase(self):
        bank = self._bank([300, 900, 1500])
        pruner = PruningFramework(
            PruningConfig(early_rounds=60, min_arms=2,
                          historical_min_samples=100), f_max=1800)
        x = np.ones(3)
        for _ in range(4):
            bank.arms[300.0].update(x, -2.0, edp=10)
        pruner.apply(bank, round_idx=100)              # past early phase
        assert 300.0 in bank.arms

    def test_historical_pruning(self):
        bank = self._bank([600, 1200, 1800])
        pruner = PruningFramework(PruningConfig(min_arms=1), f_max=1800)
        x = np.ones(3)
        for _ in range(8):
            bank.arms[600.0].update(x, -1.0, edp=30.0)   # much worse EDP
            bank.arms[1200.0].update(x, -1.0, edp=5.0)
            bank.arms[1800.0].update(x, -1.0, edp=7.0)
        pruner.apply(bank, round_idx=50)
        assert 600.0 not in bank.arms
        assert 1200.0 in bank.arms

    def test_cascade_prunes_everything_below(self):
        bank = self._bank([210, 400, 700, 1200, 1800])
        pruner = PruningFramework(PruningConfig(min_arms=2), f_max=1800)
        x = np.ones(3)
        for _ in range(4):
            bank.arms[700.0].update(x, -2.0, edp=10)     # extreme at 700 MHz
            bank.arms[1200.0].update(x, -0.9, edp=3)
            bank.arms[1800.0].update(x, -1.0, edp=4)
        pruner.apply(bank, round_idx=10)
        # 700 < 0.5*1800 -> cascade removes 210 and 400 too
        assert all(f not in bank.arms for f in (210.0, 400.0, 700.0))

    def test_min_arms_floor(self):
        bank = self._bank([600, 1200])
        pruner = PruningFramework(PruningConfig(min_arms=2), f_max=1800)
        x = np.ones(3)
        for _ in range(4):
            bank.arms[600.0].update(x, -3.0, edp=99)
        pruner.apply(bank, round_idx=5)
        assert len(bank.arms) == 2                     # floor respected

    def test_refinement_never_resurrects_pruned(self):
        bank = self._bank([600, 1200, 1800])
        pruner = PruningFramework(PruningConfig(min_arms=1), f_max=1800)
        pruner.permanently_pruned.add(1215.0)
        ref = MixedMaturityRefinement(RefinementConfig(interval=1),
                                      210, 1800)
        x = np.ones(3)
        for _ in range(6):
            bank.arms[1200.0].update(x, -0.9, edp=2)
        ref.maybe_refine(bank, pruner, x, round_idx=50)
        assert 1215.0 not in bank.arms
        assert 1200.0 in bank.arms


# ---------------------------------------------------------------------------
# Refinement
# ---------------------------------------------------------------------------

class TestRefinement:
    def test_statistical_anchor_before_maturity(self):
        bank = LinUCBBank([600.0, 1200.0, 1800.0], dim=3)
        pruner = PruningFramework(PruningConfig(), f_max=1800)
        ref = MixedMaturityRefinement(
            RefinementConfig(interval=10, maturity_threshold=100), 210, 1800)
        x = np.ones(3)
        for _ in range(5):
            bank.arms[1200.0].update(x, -0.9, edp=2.0)
            bank.arms[600.0].update(x, -1.2, edp=9.0)
            bank.arms[1800.0].update(x, -1.0, edp=4.0)
        anchor = ref.maybe_refine(bank, pruner, x, round_idx=50)
        assert anchor == 1200.0
        assert ref.log[-1]["mode"] == "statistical"
        freqs = bank.frequencies
        assert min(freqs) >= 1050.0 and max(freqs) <= 1350.0
        assert all(abs((f - 1050.0) % 15.0) < 1e-6 for f in freqs)

    def test_predictive_anchor_after_maturity(self):
        bank = LinUCBBank([600.0, 1200.0], dim=3)
        pruner = PruningFramework(PruningConfig(), f_max=1800)
        ref = MixedMaturityRefinement(
            RefinementConfig(interval=10, maturity_threshold=100), 210, 1800)
        x = np.ones(3)
        for _ in range(5):
            bank.arms[600.0].update(x, -0.5, edp=1.0)   # best predicted
            bank.arms[1200.0].update(x, -1.5, edp=5.0)
        anchor = ref.maybe_refine(bank, pruner, x, round_idx=200)
        assert anchor == 600.0
        assert ref.log[-1]["mode"] == "predictive"

    def test_no_refinement_off_interval(self):
        bank = LinUCBBank([600.0], dim=3)
        pruner = PruningFramework(PruningConfig(), f_max=1800)
        ref = MixedMaturityRefinement(RefinementConfig(interval=25), 210, 1800)
        assert ref.maybe_refine(bank, pruner, np.ones(3), 13) is None


# ---------------------------------------------------------------------------
# Reward + features
# ---------------------------------------------------------------------------

class TestRewardAndFeatures:
    def test_reward_near_minus_one_at_reference(self):
        rc = RewardCalculator(RewardConfig(slo_tpot_s=0.0, queue_penalty=0.0))
        w = make_window()
        rs = [rc(w) for _ in range(20)]
        assert abs(rs[-1] + 1.0) < 1e-6

    def test_reward_worse_for_higher_edp(self):
        rc = RewardCalculator(RewardConfig(slo_tpot_s=0.0, queue_penalty=0.0))
        for _ in range(10):
            rc(make_window())
        r_bad = rc(make_window(energy_j=300.0))
        assert r_bad < -1.5

    def test_slo_penalty_applies(self):
        rc = RewardCalculator(RewardConfig(slo_tpot_s=0.001, slo_penalty=2.0,
                                           queue_penalty=0.0))
        for _ in range(10):
            rc(make_window())
        base = rc(make_window())
        rc2 = RewardCalculator(RewardConfig(slo_tpot_s=0.0,
                                            queue_penalty=0.0))
        for _ in range(10):
            rc2(make_window())
        no_slo = rc2(make_window())
        assert base < no_slo

    def test_feature_vector_dimensions_and_bounds(self):
        fx = FeatureExtractor()
        x = fx(make_window(requests_waiting=3))
        assert x.shape == (7,)
        assert x[0] == 1.0                      # has_queue
        assert np.all(x >= 0) and np.all(x <= 1.5)

    def test_features_distinguish_prototype_directions(self):
        fx = FeatureExtractor()
        x_ctx = fx(make_window(prefill_tokens=16000, generation_tokens=50))
        x_gen = fx(make_window(prefill_tokens=50, generation_tokens=3000))
        x_hit = fx(make_window(cache_hit_rate=0.95))
        assert x_ctx[1] > x_gen[1]              # prefill tput separates
        assert x_gen[2] > x_ctx[2]              # decode tput separates
        assert x_hit[6] > 0.9                   # hit rate separates


class TestThompsonExtension:
    def test_thompson_selects_within_action_space(self):
        rng = np.random.default_rng(0)
        bank = LinUCBBank([600.0, 1200.0, 1800.0], dim=3, seed=1)
        for _ in range(30):
            x = rng.uniform(0, 1, 3)
            f = bank.select_thompson(x, nu=0.3)
            assert f in bank.arms
            bank.arms[f].update(x, -1.0 + 0.1 * rng.normal())

    def test_thompson_concentrates_on_best_arm(self):
        rng = np.random.default_rng(1)
        bank = LinUCBBank([600.0, 1200.0], dim=2, seed=2)
        x = np.array([1.0, 0.5])
        for _ in range(300):
            f = bank.select_thompson(x, nu=0.3)
            r = -0.5 if f == 1200.0 else -1.5
            bank.arms[f].update(x, r + 0.05 * rng.normal())
        picks = [bank.select_thompson(x, nu=0.3) for _ in range(100)]
        assert picks.count(1200.0) > 80

    def test_tuner_with_thompson_strategy_runs(self):
        from repro.core import AGFTConfig, AGFTTuner
        from repro.energy import A6000
        from repro.serving import EngineConfig, InferenceEngine
        from repro.workloads import PROTOTYPES, generate_requests
        from repro.configs import get_config
        eng = InferenceEngine(get_config("llama3-3b"), EngineConfig(),
                              initial_frequency=A6000.f_max)
        eng.submit(generate_requests(PROTOTYPES["normal"], 150,
                                     base_rate=3.0, seed=9))
        tuner = AGFTTuner(A6000, AGFTConfig(strategy="thompson"))
        eng.drain(policy=tuner)
        assert len(eng.finished) == 150
        assert tuner.round > 0
