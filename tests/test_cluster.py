"""Fleet-level serving tests (beyond-paper extension)."""
import numpy as np

from repro.configs import get_config
from repro.serving.cluster import (ServingCluster, route_by_length,
                                   route_least_loaded)
from repro.workloads import PROTOTYPES, generate_requests

CFG = get_config("llama3-3b")


def _mixed_trace(n=400, seed=11):
    a = generate_requests(PROTOTYPES["long_context"], n // 2,
                          base_rate=1.5, seed=seed)
    b = generate_requests(PROTOTYPES["normal"], n // 2,
                          base_rate=1.5, seed=seed + 1)
    return a + b


def test_cluster_completes_all_requests():
    cl = ServingCluster(CFG, n_nodes=2, with_tuners=False)
    reqs = _mixed_trace(200)
    cl.submit(reqs)
    cl.drain()
    s = cl.summary()
    assert s.finished == 200
    assert s.energy_j > 0


def test_per_node_tuners_save_fleet_energy():
    base = ServingCluster(CFG, n_nodes=2, with_tuners=False)
    base.submit(_mixed_trace(300))
    base.drain()
    tuned = ServingCluster(CFG, n_nodes=2, with_tuners=True)
    tuned.submit(_mixed_trace(300))
    tuned.drain()
    assert tuned.summary().finished == base.summary().finished
    assert tuned.summary().energy_j < 0.85 * base.summary().energy_j


def test_length_router_specializes_nodes():
    """Segregated traffic -> the long-context node and the chat node learn
    different operating points."""
    cl = ServingCluster(CFG, n_nodes=2, with_tuners=True,
                        router=route_by_length)
    cl.submit(_mixed_trace(500))
    cl.drain()
    s = cl.summary()
    assert s.finished == 500
    # node 0 took long-context traffic, node 1 chat traffic: converged
    # frequencies should differ (long-context optimum is higher)
    post0 = [h["freq"] for h in cl.tuners[0].history if h["converged"]]
    post1 = [h["freq"] for h in cl.tuners[1].history if h["converged"]]
    if post0 and post1:   # both converged
        assert abs(np.mean(post0) - np.mean(post1)) > 30.0


def test_least_loaded_router_balances():
    cl = ServingCluster(CFG, n_nodes=3, with_tuners=False,
                        router=route_least_loaded)
    cl.submit(generate_requests(PROTOTYPES["normal"], 300,
                                base_rate=6.0, seed=3))
    cl.drain()
    per_node = [len(e.finished) for e in cl.engines]
    assert sum(per_node) == 300
    assert min(per_node) > 30          # nobody starved
