"""Dry-run regression tests.

jax locks the host device count at first init, so the dry-run (which forces
512 placeholder devices) must run in a SUBPROCESS; these tests exercise the
real entry point on a small debug mesh for a representative arch slice.
The full 10x4x2 production matrix is executed by
``python -m repro.launch.dryrun --all --mesh both`` (results recorded in
EXPERIMENTS.md §Dry-run)."""
import json
import os
import subprocess
import sys

import pytest

REPO = os.path.dirname(os.path.dirname(os.path.abspath(__file__)))


def run_dryrun(*args):
    env = dict(os.environ)
    env["PYTHONPATH"] = os.path.join(REPO, "src")
    env.pop("XLA_FLAGS", None)
    return subprocess.run(
        [sys.executable, "-m", "repro.launch.dryrun", *args],
        capture_output=True, text=True, cwd=REPO, env=env, timeout=900)


@pytest.mark.parametrize("arch,shape", [
    ("tinyllama-1.1b", "train_4k"),        # dense train
    ("deepseek-v2-lite-16b", "decode_32k"),  # MoE + MLA decode
    ("mamba2-1.3b", "long_500k"),          # SSM long-context decode
    ("recurrentgemma-9b", "decode_32k"),   # hybrid decode
    ("whisper-medium", "prefill_32k"),     # enc-dec prefill
])
def test_debug_mesh_lowers(arch, shape):
    r = run_dryrun("--arch", arch, "--shape", shape, "--debug-mesh",
                   "--mesh", "both")
    assert r.returncode == 0, r.stdout[-2000:] + r.stderr[-2000:]
    assert "2 ok, 0 failed" in r.stdout


def test_cost_extrapolation_exceeds_scan_counted(tmp_path):
    out = str(tmp_path / "extrap.json")
    r = run_dryrun("--arch", "tinyllama-1.1b", "--shape", "train_4k",
                   "--debug-mesh", "--cost-extrapolate", "--out", out)
    assert r.returncode == 0, r.stdout[-2000:] + r.stderr[-2000:]
    with open(out) as f:
        res = json.load(f)["results"][0]
    # scan bodies are costed once by XLA; the depth-extrapolated figure must
    # be several times larger for a 22-layer model
    assert res["extrapolated"]["flops"] > 3 * res["flops"]
    assert res["extrapolated"]["scan_length"] == 22


def test_collective_bytes_parser():
    sys.path.insert(0, os.path.join(REPO, "src"))
    os.environ.setdefault("XLA_FLAGS", "")
    from repro.launch.dryrun import collective_bytes
    hlo = """
  %ar = f32[128,256] all-reduce(%x), replica_groups={}
  %ag.1 = bf16[4,1024] all-gather(%y), dimensions={0}
  %cp = f32[16] collective-permute(%z), source_target_pairs={{0,1}}
  %dot = f32[128,256] dot(%a, %b)
"""
    out = collective_bytes(hlo)
    assert out["all-reduce"] == 128 * 256 * 4 * 2          # 2x convention
    assert out["all-gather"] == 4 * 1024 * 2
    assert out["collective-permute"] == 16 * 4
    assert out["total"] == (out["all-reduce"] + out["all-gather"]
                            + out["collective-permute"])
