"""Per-architecture smoke tests (assignment requirement): a REDUCED variant
of every assigned family (<=2-3 layers, d_model<=512, <=4 experts) runs one
forward/train step and one prefill+decode step on CPU; output shapes and
NaN-freeness are asserted. Decode from the prefill cache must match the full
teacher-forced forward — this exercises the KV/MLA/SSD/LRU cache contracts.
"""
import jax
import jax.numpy as jnp
import numpy as np
import pytest

from repro.configs import ASSIGNED_ARCHS, get_config
from repro.models import build_model

ALL_ARCHS = ASSIGNED_ARCHS + ["llama3-3b"]


def _inputs(cfg, key, B, S):
    tokens = jax.random.randint(key, (B, S), 0, cfg.vocab_size)
    if cfg.is_encoder_decoder:
        frames = jax.random.normal(
            key, (B, cfg.encoder_seq, cfg.d_model), cfg.activation_dtype)
        return (tokens, frames)
    return (tokens,)


@pytest.fixture(scope="module")
def key():
    return jax.random.PRNGKey(0)


@pytest.mark.parametrize("arch", ALL_ARCHS)
def test_forward_shapes_no_nan(arch, key):
    cfg = get_config(arch).reduced()
    model = build_model(cfg)
    params = model.init(key)
    B, S = 2, 16
    args = _inputs(cfg, key, B, S)
    logits, aux = model.forward(params, *args)
    assert logits.shape == (B, S, cfg.vocab_size)
    assert not bool(jnp.any(jnp.isnan(logits)))
    assert not bool(jnp.any(jnp.isinf(logits)))


@pytest.mark.parametrize("arch", ALL_ARCHS)
def test_train_step(arch, key):
    """One gradient step on the reduced config: loss finite, grads finite,
    params actually move."""
    cfg = get_config(arch).reduced()
    model = build_model(cfg)
    params = model.init(key)
    B, S = 2, 16
    args = _inputs(cfg, key, B, S + 1)
    tokens = args[0]
    inp, labels = tokens[:, :-1], tokens[:, 1:]
    extra = args[1:]

    def loss_fn(p):
        if extra:
            return model.loss(p, inp, labels, extra[0][:, : cfg.encoder_seq])
        return model.loss(p, inp, labels)

    loss, grads = jax.value_and_grad(loss_fn)(params)
    assert jnp.isfinite(loss)
    leaves = jax.tree.leaves(grads)
    assert all(bool(jnp.all(jnp.isfinite(g))) for g in leaves)
    gnorm = jnp.sqrt(sum(jnp.sum(jnp.square(g.astype(jnp.float32)))
                         for g in leaves))
    assert float(gnorm) > 0.0


@pytest.mark.parametrize("arch", ALL_ARCHS)
def test_prefill_decode_matches_forward(arch, key):
    cfg = get_config(arch).reduced()
    model = build_model(cfg)
    params = model.init(key)
    B, S, CAP = 2, 12, 32
    args = _inputs(cfg, key, B, S + 1)
    tokens = args[0]
    extra = args[1:]
    full, _ = model.forward(params, tokens, *extra)
    pl, cache = model.prefill(params, tokens[:, :S], *extra, max_len=CAP)
    np.testing.assert_allclose(np.asarray(pl[:, 0]), np.asarray(full[:, S - 1]),
                               rtol=2e-4, atol=2e-4)
    pos = jnp.full((B,), S, jnp.int32)
    dl, new_cache = model.decode_step(params, tokens[:, S:S + 1], cache, pos)
    np.testing.assert_allclose(np.asarray(dl[:, 0]), np.asarray(full[:, S]),
                               rtol=1e-3, atol=1e-3)
    # cache pytree structure must be stable across steps (scan/jit contract)
    assert (jax.tree.structure(new_cache) == jax.tree.structure(cache))


@pytest.mark.parametrize("arch", ALL_ARCHS)
def test_decode_from_zero_cache(arch, key):
    """Greedy decode 4 tokens from an empty cache — shapes stable, no NaN."""
    cfg = get_config(arch).reduced()
    model = build_model(cfg)
    params = model.init(key)
    B, CAP = 2, 16
    cache = model.init_cache(B, CAP)
    tok = jnp.zeros((B, 1), jnp.int32)
    pos = jnp.zeros((B,), jnp.int32)
    if cfg.is_encoder_decoder:
        # populate cross caches via prefill of a single BOS token
        frames = jax.random.normal(
            key, (B, cfg.encoder_seq, cfg.d_model), cfg.activation_dtype)
        _, cache = model.prefill(params, tok, frames, max_len=CAP)
        pos = jnp.ones((B,), jnp.int32)
    step = jax.jit(model.decode_step)
    for _ in range(4):
        logits, cache = step(params, tok, cache, pos)
        assert logits.shape == (B, 1, cfg.vocab_size)
        assert not bool(jnp.any(jnp.isnan(logits)))
        tok = jnp.argmax(logits, -1).astype(jnp.int32)
        pos = pos + 1


def test_sliding_window_variant_matches_full_within_window(key):
    """With S <= window the sliding-window variant must equal full attention."""
    cfg = get_config("tinyllama-1.1b").reduced()
    cfgw = cfg.replace(attention_window=64)
    m_full, m_win = build_model(cfg), build_model(cfgw)
    params = m_full.init(key)
    tokens = jax.random.randint(key, (2, 16), 0, cfg.vocab_size)
    lf, _ = m_full.forward(params, tokens)
    lw, _ = m_win.forward(params, tokens)
    np.testing.assert_allclose(np.asarray(lf), np.asarray(lw),
                               rtol=1e-5, atol=1e-5)


def test_long_context_configs_are_subquadratic():
    from repro.configs import config_for_shape
    for arch in ASSIGNED_ARCHS:
        cfg = config_for_shape(arch, "long_500k")
        ok = (cfg.arch_type in ("ssm", "hybrid")) or cfg.attention_window > 0
        assert ok, f"{arch} long_500k config is not sub-quadratic"
