#!/usr/bin/env python
"""Docs consistency gate (CI ``docs`` job): ``python tests/check_docs.py``.

Two checks, both over committed Markdown only (no network):

1. **Link check** — every relative ``[text](target)`` link in README.md,
   ROADMAP.md, and ``docs/*.md`` must resolve to an existing file or
   directory, and a ``#fragment`` must match a heading (GitHub slug
   rules) or an explicit ``<a name="...">`` anchor in the target file.
2. **Module-map completeness** — every package directory under
   ``src/repro/`` must be named in ``docs/ARCHITECTURE.md``'s module
   map, so the architecture page can't silently rot as packages land.

Deliberately not named ``test_*``: this is a repo-consistency gate, not
a tier-1 unit test, and it should not run (or fail) inside ``pytest -x``
while docs are mid-edit. Exit 0 on success, 1 with a findings list
otherwise.
"""
from __future__ import annotations

import re
import sys
from pathlib import Path

ROOT = Path(__file__).resolve().parent.parent
DOC_FILES = [ROOT / "README.md", ROOT / "ROADMAP.md",
             *sorted((ROOT / "docs").glob("*.md"))]
ARCHITECTURE = ROOT / "docs" / "ARCHITECTURE.md"
SRC_PKG_ROOT = ROOT / "src" / "repro"

LINK_RE = re.compile(r"\[[^\]]*\]\(([^)\s]+)\)")
HEADING_RE = re.compile(r"^#{1,6}\s+(.*)$", re.MULTILINE)
ANCHOR_RE = re.compile(r"<a\s+name=\"([^\"]+)\"")
FENCE_RE = re.compile(r"```.*?```", re.DOTALL)


def github_slug(heading: str) -> str:
    """GitHub's heading-to-anchor slug: strip markup, lowercase, drop
    punctuation, spaces to hyphens."""
    text = re.sub(r"[`*_]", "", heading.strip())
    text = re.sub(r"\[([^\]]*)\]\([^)]*\)", r"\1", text)  # inline links
    text = re.sub(r"[^\w\- ]", "", text.lower())
    return text.replace(" ", "-")


def anchors_in(path: Path) -> set:
    text = path.read_text(encoding="utf-8")
    slugs = {github_slug(h) for h in HEADING_RE.findall(text)}
    slugs.update(ANCHOR_RE.findall(text))
    return slugs


def check_links() -> list:
    problems = []
    for doc in DOC_FILES:
        if not doc.exists():
            problems.append(f"{doc.relative_to(ROOT)}: file missing")
            continue
        text = FENCE_RE.sub("", doc.read_text(encoding="utf-8"))
        for target in LINK_RE.findall(text):
            if target.startswith(("http://", "https://", "mailto:")):
                continue  # external links are not checked (no network)
            path_part, _, fragment = target.partition("#")
            dest = doc if not path_part else (
                doc.parent / path_part).resolve()
            rel = f"{doc.relative_to(ROOT)}: link '{target}'"
            if not dest.exists():
                problems.append(f"{rel} -> missing path {path_part}")
                continue
            if fragment:
                if dest.is_dir() or dest.suffix.lower() != ".md":
                    problems.append(
                        f"{rel} -> fragment on non-markdown target")
                elif fragment not in anchors_in(dest):
                    problems.append(
                        f"{rel} -> no heading/anchor '#{fragment}' "
                        f"in {dest.relative_to(ROOT)}")
    return problems


def check_module_map() -> list:
    if not ARCHITECTURE.exists():
        return ["docs/ARCHITECTURE.md: file missing"]
    text = ARCHITECTURE.read_text(encoding="utf-8")
    packages = sorted(p.name for p in SRC_PKG_ROOT.iterdir()
                      if p.is_dir() and (p / "__init__.py").exists())
    problems = []
    for pkg in packages:
        if f"repro/{pkg}/" not in text:
            problems.append(
                f"docs/ARCHITECTURE.md: package src/repro/{pkg}/ missing "
                f"from the module map")
    if not packages:
        problems.append("src/repro/: no packages found (wrong checkout?)")
    return problems


def main() -> int:
    problems = check_links() + check_module_map()
    if problems:
        print(f"check_docs: {len(problems)} problem(s)")
        for p in problems:
            print(f"  - {p}")
        return 1
    n_links = sum(
        len(LINK_RE.findall(FENCE_RE.sub("", d.read_text(encoding="utf-8"))))
        for d in DOC_FILES if d.exists())
    print(f"check_docs: OK ({len(DOC_FILES)} files, {n_links} links, "
          f"module map complete)")
    return 0


if __name__ == "__main__":
    sys.exit(main())
