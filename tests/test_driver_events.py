"""Discrete-event driver tests: equivalence with the PR-1 heap-of-clocks
loop (same finished set, clocks, and energy on fixed-seed traces), event
bookkeeping, idle/blocked energy accounting, and the never-backwards
time-monotonicity property (hypothesis-based, skipped without it)."""
import heapq

import pytest

from repro.configs import get_config
from repro.core import AGFTTuner
from repro.energy import A6000
from repro.policies import get_policy
from repro.serving import (EngineConfig, EngineNode, EventKind, EventLoop,
                           InferenceEngine, Request, drive)
from repro.workloads import PROTOTYPES, generate_requests

CFG = get_config("llama3-3b")


def make_engine(**kw):
    return InferenceEngine(CFG, EngineConfig(**kw),
                           initial_frequency=A6000.f_max)


def trace(n=80, rate=3.0, seed=21, workload="normal"):
    return generate_requests(PROTOTYPES[workload], n, base_rate=rate,
                             seed=seed)


def pr1_drive(nodes, *, t_end=None, max_iters=10_000_000):
    """The PR-1 drive loop, verbatim: heap keyed by engine CLOCK, step the
    laggard, then its policy — the reference the event loop must match
    decision-for-decision."""
    heap = []
    for i, node in enumerate(nodes):
        if node.engine.has_work:
            heapq.heappush(heap, (node.engine.clock, i))
    it = 0
    while heap and it < max_iters:
        _, i = heapq.heappop(heap)
        node = nodes[i]
        eng = node.engine
        if not eng.has_work or (t_end is not None and eng.clock >= t_end):
            continue
        eng.step()
        if node.policy is not None:
            node.policy.maybe_act(eng)
        it += 1
        heapq.heappush(heap, (eng.clock, i))
    return it


def engine_state(eng):
    # request_ids come from a process-global counter, so two identical
    # traces get different absolute ids — normalize to the trace-relative
    # id before comparing finished SETS across runs
    ids = [r.request_id for r in eng.finished]
    base = min(ids) if ids else 0
    return {
        "finished_ids": sorted(i - base for i in ids),
        "finish_times": sorted(r.finish_time for r in eng.finished),
        "clock": eng.clock,
        "energy": eng.metrics.c.energy_joules_total,
        "iterations": eng.metrics.c.iterations_total,
    }


# ---------------------------------------------------------------------------
# Equivalence vs the PR-1 loop
# ---------------------------------------------------------------------------

class TestPR1Equivalence:
    def test_single_node_no_policy(self):
        e1, e2 = make_engine(), make_engine()
        e1.submit(trace(120, seed=5))
        e2.submit(trace(120, seed=5))
        s1 = pr1_drive([EngineNode(e1, None)])
        s2 = drive([EngineNode(e2, None)])
        assert s1 == s2
        assert engine_state(e1) == engine_state(e2)

    def test_single_node_agft_decisions(self):
        e1, t1 = make_engine(), AGFTTuner(A6000)
        e1.submit(trace(150, seed=7))
        pr1_drive([EngineNode(e1, t1)])
        e2, t2 = make_engine(), AGFTTuner(A6000)
        e2.submit(trace(150, seed=7))
        drive([EngineNode(e2, t2)])
        assert engine_state(e1) == engine_state(e2)
        h1 = [(h["t"], h["freq"], h["phase"]) for h in t1.history]
        h2 = [(h["t"], h["freq"], h["phase"]) for h in t2.history]
        assert h1 == h2

    def test_multi_node_heterogeneous_policies(self):
        def fleet():
            nodes = []
            for i, pol in enumerate(("agft", "slo", None)):
                eng = make_engine()
                eng.submit(trace(60, seed=30 + i))
                p = get_policy(pol, hardware=A6000) if pol else None
                nodes.append(EngineNode(eng, p))
            return nodes
        n1, n2 = fleet(), fleet()
        pr1_drive(n1)
        drive(n2)
        for a, b in zip(n1, n2):
            assert engine_state(a.engine) == engine_state(b.engine)

    def test_run_until_series(self):
        """The fig11 pattern: repeated run_until on a 30 s cadence must
        land on the same clocks/energies as the PR-1 loop."""
        def series(loop):
            eng = make_engine()
            eng.submit(trace(150, rate=1.0, seed=9))
            t1 = AGFTTuner(A6000)
            out = []
            next_t = 30.0
            while eng.has_work:
                loop([EngineNode(eng, t1)], t_end=next_t)
                out.append((eng.clock,
                            eng.metrics.c.energy_joules_total))
                next_t = eng.clock + 30.0
            return out
        assert series(pr1_drive) == series(drive)


# ---------------------------------------------------------------------------
# Event-loop bookkeeping
# ---------------------------------------------------------------------------

class TestEventLoop:
    def test_event_kinds_counted(self):
        eng = make_engine()
        eng.submit(trace(40, rate=0.5, seed=3))   # sparse -> idle gaps
        loop = EventLoop([EngineNode(eng, None)])
        steps = loop.run()
        assert steps == loop.counts[EventKind.ARRIVAL] \
            + loop.counts[EventKind.ITERATION]
        assert loop.counts[EventKind.ARRIVAL] > 0      # idle-skips happened
        assert loop.counts[EventKind.ITERATION] > 0
        assert loop.counts[EventKind.FLEET_TICK] == 0  # no fleet policy

    def test_virtual_time_monotone_and_final(self):
        eng = make_engine()
        eng.submit(trace(50, seed=4))
        loop = EventLoop([EngineNode(eng, None)])
        loop.run()
        assert loop.now > 0.0
        assert not eng.has_work

    def test_max_iters_respected(self):
        eng = make_engine()
        eng.submit(trace(100, seed=6))
        steps = drive([EngineNode(eng, None)], max_iters=10)
        assert steps == 10
        assert eng.has_work

    def test_blocked_tick_bills_idle_energy(self):
        """A KV-starved engine burns idle power while blocked — time is
        never free (satellite fix: the old blocked tick advanced the clock
        without billing)."""
        eng = make_engine(num_kv_blocks=4, kv_block_size=16,
                          enable_prefix_cache=False)
        # needs 8 blocks; can never allocate, nothing to preempt
        eng.submit([Request(arrival_time=0.0, prompt_len=100,
                            output_len=28)])
        e0 = eng.metrics.c.energy_joules_total
        for _ in range(5):
            eng.step()
        billed = eng.metrics.c.energy_joules_total - e0
        assert billed == pytest.approx(5 * 1e-3 * A6000.p_idle)
        assert eng.clock == pytest.approx(5e-3)

    def test_submit_is_heap_ordered_not_sorted(self):
        """Out-of-order and incremental submits ingest in arrival order."""
        eng = make_engine()
        reqs = trace(30, seed=11)
        for r in reversed(reqs):          # worst-case submit order
            eng.submit([r])
        eng.drain()
        assert len(eng.finished) == 30
        order = [r.arrival_time for r in
                 sorted(eng.finished, key=lambda r: r.first_scheduled_time)]
        assert order == sorted(order)
