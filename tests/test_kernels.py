"""Per-kernel validation: shape/dtype sweeps asserting allclose against the
pure-jnp oracles in repro.kernels.ref (Pallas interpret mode on CPU)."""
import jax
import jax.numpy as jnp
import numpy as np
import pytest

from repro.kernels import ops, ref


def rand(key, shape, dtype):
    return jax.random.normal(key, shape, jnp.float32).astype(dtype)


TOL = {jnp.float32: dict(rtol=2e-5, atol=2e-5),
       jnp.bfloat16: dict(rtol=2e-2, atol=2e-2)}


# ---------------------------------------------------------------------------
# flash attention
# ---------------------------------------------------------------------------

@pytest.mark.parametrize("B,S,H,Hkv,D", [
    (1, 128, 4, 4, 64),     # MHA
    (2, 256, 8, 2, 64),     # GQA group=4
    (1, 256, 4, 1, 128),    # MQA, wide head
    (2, 384, 6, 2, 64),     # non-pow2 heads (starcoder-like ratios)
])
@pytest.mark.parametrize("dtype", [jnp.float32, jnp.bfloat16])
def test_flash_attention_sweep(B, S, H, Hkv, D, dtype):
    ks = jax.random.split(jax.random.PRNGKey(0), 3)
    q = rand(ks[0], (B, S, H, D), dtype)
    k = rand(ks[1], (B, S, Hkv, D), dtype)
    v = rand(ks[2], (B, S, Hkv, D), dtype)
    got = ops.flash_attention(q, k, v, causal=True)
    want = ref.flash_attention(q, k, v, causal=True)
    np.testing.assert_allclose(np.asarray(got, np.float32),
                               np.asarray(want, np.float32), **TOL[dtype])


def test_flash_attention_noncausal():
    ks = jax.random.split(jax.random.PRNGKey(1), 3)
    q = rand(ks[0], (2, 128, 4, 64), jnp.float32)
    k = rand(ks[1], (2, 128, 2, 64), jnp.float32)
    v = rand(ks[2], (2, 128, 2, 64), jnp.float32)
    got = ops.flash_attention(q, k, v, causal=False)
    want = ref.flash_attention(q, k, v, causal=False)
    np.testing.assert_allclose(np.asarray(got), np.asarray(want),
                               rtol=2e-5, atol=2e-5)


@pytest.mark.parametrize("block_q,block_k", [(64, 64), (128, 64), (64, 128)])
def test_flash_attention_block_shapes(block_q, block_k):
    ks = jax.random.split(jax.random.PRNGKey(2), 3)
    q = rand(ks[0], (1, 256, 2, 64), jnp.float32)
    k = rand(ks[1], (1, 256, 2, 64), jnp.float32)
    v = rand(ks[2], (1, 256, 2, 64), jnp.float32)
    got = ops.flash_attention(q, k, v, causal=True,
                              block_q=block_q, block_k=block_k)
    want = ref.flash_attention(q, k, v, causal=True)
    np.testing.assert_allclose(np.asarray(got), np.asarray(want),
                               rtol=2e-5, atol=2e-5)


# ---------------------------------------------------------------------------
# decode attention
# ---------------------------------------------------------------------------

@pytest.mark.parametrize("B,T,H,Hkv,D", [
    (1, 512, 4, 4, 64),
    (2, 1024, 8, 2, 64),
    (4, 512, 4, 1, 128),
])
@pytest.mark.parametrize("dtype", [jnp.float32, jnp.bfloat16])
def test_decode_attention_sweep(B, T, H, Hkv, D, dtype):
    ks = jax.random.split(jax.random.PRNGKey(3), 4)
    q = rand(ks[0], (B, 1, H, D), dtype)
    kc = rand(ks[1], (B, T, Hkv, D), dtype)
    vc = rand(ks[2], (B, T, Hkv, D), dtype)
    lengths = jax.random.randint(ks[3], (B,), 1, T + 1)
    valid = jnp.arange(T)[None] < lengths[:, None]
    got = ops.decode_attention(q, kc, vc, valid)
    want = ref.decode_attention(q, kc, vc, valid)
    np.testing.assert_allclose(np.asarray(got, np.float32),
                               np.asarray(want, np.float32), **TOL[dtype])


def test_decode_attention_ring_buffer_validity():
    """Scattered validity (ring-buffer decode) — not just a prefix mask."""
    ks = jax.random.split(jax.random.PRNGKey(4), 4)
    B, T, H, Hkv, D = 2, 512, 4, 2, 64
    q = rand(ks[0], (B, 1, H, D), jnp.float32)
    kc = rand(ks[1], (B, T, Hkv, D), jnp.float32)
    vc = rand(ks[2], (B, T, Hkv, D), jnp.float32)
    valid = jax.random.bernoulli(ks[3], 0.7, (B, T))
    got = ops.decode_attention(q, kc, vc, valid)
    want = ref.decode_attention(q, kc, vc, valid)
    np.testing.assert_allclose(np.asarray(got), np.asarray(want),
                               rtol=2e-5, atol=2e-5)


# ---------------------------------------------------------------------------
# RG-LRU scan
# ---------------------------------------------------------------------------

@pytest.mark.parametrize("B,S,W", [(1, 64, 128), (2, 256, 256),
                                   (3, 128, 384)])
def test_rglru_sweep(B, S, W):
    ks = jax.random.split(jax.random.PRNGKey(5), 3)
    x = jax.random.normal(ks[0], (B, S, W))
    log_a = -jax.nn.softplus(jax.random.normal(ks[1], (B, S, W)))
    h0 = jax.random.normal(ks[2], (B, W))
    ys, hl = ops.rglru_scan(x, log_a, h0)
    ys_r, hl_r = ref.rglru_scan(x, log_a, h0)
    np.testing.assert_allclose(np.asarray(ys), np.asarray(ys_r),
                               rtol=1e-5, atol=1e-5)
    np.testing.assert_allclose(np.asarray(hl), np.asarray(hl_r),
                               rtol=1e-5, atol=1e-5)


# ---------------------------------------------------------------------------
# SSD chunked scan
# ---------------------------------------------------------------------------

@pytest.mark.parametrize("b,s,h,p,g,n,chunk", [
    (1, 128, 4, 64, 1, 64, 32),
    (2, 256, 8, 32, 2, 32, 64),
    (1, 64, 2, 64, 1, 128, 16),
])
def test_ssd_sweep(b, s, h, p, g, n, chunk):
    ks = jax.random.split(jax.random.PRNGKey(6), 5)
    x = jax.random.normal(ks[0], (b, s, h, p))
    dt = jax.nn.softplus(jax.random.normal(ks[1], (b, s, h)))
    A = jnp.exp(jax.random.normal(ks[2], (h,)) * 0.3)
    B = jax.random.normal(ks[3], (b, s, g, n)) * 0.5
    C = jax.random.normal(ks[4], (b, s, g, n)) * 0.5
    y, st = ops.ssd_scan(x, dt, A, B, C, chunk=chunk)
    y_r, st_r = ref.ssd_scan(x, dt, A, B, C, chunk=chunk)
    np.testing.assert_allclose(np.asarray(y), np.asarray(y_r),
                               rtol=2e-4, atol=2e-4)
    np.testing.assert_allclose(np.asarray(st), np.asarray(st_r),
                               rtol=2e-4, atol=2e-4)


def test_ssd_kernel_matches_model_chunked_path():
    """The model's jnp chunked implementation and the kernel agree."""
    from repro.models.blocks import ssd_chunked
    ks = jax.random.split(jax.random.PRNGKey(7), 5)
    b, s, h, p, g, n = 1, 128, 4, 32, 1, 64
    x = jax.random.normal(ks[0], (b, s, h, p))
    dt = jax.nn.softplus(jax.random.normal(ks[1], (b, s, h)))
    A = jnp.exp(jax.random.normal(ks[2], (h,)) * 0.3)
    B = jax.random.normal(ks[3], (b, s, g, n)) * 0.5
    C = jax.random.normal(ks[4], (b, s, g, n)) * 0.5
    y1, st1 = ssd_chunked(x, dt, A, B, C, chunk=32)
    y2, st2 = ops.ssd_scan(x, dt, A, B, C, chunk=32)
    np.testing.assert_allclose(np.asarray(y1), np.asarray(y2),
                               rtol=2e-4, atol=2e-4)
    np.testing.assert_allclose(np.asarray(st1), np.asarray(st2),
                               rtol=2e-4, atol=2e-4)


# ---------------------------------------------------------------------------
# RMSNorm
# ---------------------------------------------------------------------------

@pytest.mark.parametrize("shape", [(4, 128), (2, 17, 256), (3, 5, 7, 512)])
@pytest.mark.parametrize("dtype", [jnp.float32, jnp.bfloat16])
def test_rmsnorm_sweep(shape, dtype):
    ks = jax.random.split(jax.random.PRNGKey(8), 2)
    x = rand(ks[0], shape, dtype)
    w = 1.0 + 0.1 * jax.random.normal(ks[1], (shape[-1],))
    got = ops.rmsnorm(x, w.astype(dtype))
    want = ref.rmsnorm(x, w.astype(dtype))
    np.testing.assert_allclose(np.asarray(got, np.float32),
                               np.asarray(want, np.float32), **TOL[dtype])


# ---------------------------------------------------------------------------
# Kernel-path model equivalence (use_pallas=True == reference model)
# ---------------------------------------------------------------------------

def test_model_with_pallas_kernels_matches_reference():
    from repro.configs import get_config
    from repro.models import build_model
    key = jax.random.PRNGKey(9)
    for arch in ["tinyllama-1.1b", "recurrentgemma-9b", "mamba2-1.3b"]:
        cfg = get_config(arch).reduced()
        m_ref = build_model(cfg)
        m_ker = build_model(cfg.replace(use_pallas=True))
        params = m_ref.init(key)
        tokens = jax.random.randint(key, (2, 64), 0, cfg.vocab_size)
        l_ref, _ = m_ref.forward(params, tokens)
        l_ker, _ = m_ker.forward(params, tokens)
        np.testing.assert_allclose(np.asarray(l_ker), np.asarray(l_ref),
                                   rtol=5e-4, atol=5e-4)
