"""Hypothesis property tests on system invariants.

The whole module is skipped (not errored) when hypothesis is absent —
install the pinned dev set from requirements-dev.txt to run it."""
import numpy as np
import pytest

pytest.importorskip("hypothesis")

from hypothesis import given, settings                       # noqa: E402
from hypothesis import strategies as st                      # noqa: E402

from repro.core.linucb import LinUCBArm, LinUCBBank          # noqa: E402
from repro.core.page_hinkley import PageHinkley              # noqa: E402
from repro.energy import A6000, DVFSModel                    # noqa: E402
from repro.energy.edp import WindowStats                     # noqa: E402
from repro.configs import get_config                         # noqa: E402
from repro.core.features import FeatureExtractor             # noqa: E402
from repro.serving import (EngineConfig, EngineNode, EventLoop,  # noqa: E402
                           InferenceEngine, NetworkConfig, NetworkModel,
                           PagedKVCache)
from repro.serving.cluster import ServingCluster             # noqa: E402
from repro.serving.request import Request                    # noqa: E402
from repro.workloads import PROTOTYPES, generate_requests    # noqa: E402
from repro.workloads.azure_trace import generate_azure_trace  # noqa: E402

floats01 = st.floats(0.0, 1.0, allow_nan=False)


class TestLinUCBProperties:
    @given(st.lists(st.tuples(
        st.lists(st.floats(-1, 1, allow_nan=False, allow_infinity=False),
                 min_size=3, max_size=3),
        st.floats(-5, 5, allow_nan=False)), min_size=1, max_size=60))
    @settings(max_examples=40, deadline=None)
    def test_a_inv_stays_inverse_and_spd(self, updates):
        arm = LinUCBArm(dim=3)
        for x, r in updates:
            arm.update(np.array(x), r)
        np.testing.assert_allclose(arm.A @ arm.A_inv, np.eye(3), atol=1e-6)
        eig = np.linalg.eigvalsh(arm.A)
        assert np.all(eig >= 1.0 - 1e-9)           # ridge floor preserved

    @given(st.lists(st.floats(-3, 0, allow_nan=False), min_size=2,
                    max_size=50))
    @settings(max_examples=40, deadline=None)
    def test_mean_reward_matches_numpy(self, rewards):
        arm = LinUCBArm(dim=2)
        x = np.array([1.0, 0.5])
        for r in rewards:
            arm.update(x, r)
        np.testing.assert_allclose(arm.mean_reward, np.mean(rewards),
                                   rtol=1e-9)

    @given(st.integers(2, 8), st.integers(0, 200))
    @settings(max_examples=20, deadline=None)
    def test_selection_always_within_action_space(self, n_arms, n_updates):
        rng = np.random.default_rng(0)
        freqs = [300.0 * (i + 1) for i in range(n_arms)]
        bank = LinUCBBank(freqs, dim=3)
        for _ in range(n_updates):
            x = rng.uniform(0, 1, 3)
            f = bank.select_ucb(x, 0.5)
            assert f in bank.arms
            bank.arms[f].update(x, -1.0 + 0.1 * rng.normal())
        assert bank.select_greedy(rng.uniform(0, 1, 3)) in bank.arms


class TestKVCacheProperties:
    @given(st.lists(st.tuples(st.integers(1, 2000), st.integers(1, 400),
                              st.integers(0, 20)), min_size=1, max_size=40))
    @settings(max_examples=50, deadline=None)
    def test_block_accounting_invariant(self, reqs):
        kv = PagedKVCache(num_blocks=256, block_size=16)
        live = []
        for prompt, out, tmpl in reqs:
            r = Request(arrival_time=0.0, prompt_len=prompt, output_len=out,
                        template_id=tmpl)
            if kv.try_allocate(r, prompt + out):
                live.append(r)
                kv.register_prefix(r)
            assert kv.check_invariant()
            assert 0 <= kv.free_blocks <= kv.num_blocks
        for r in live:
            kv.free(r)
            assert kv.check_invariant()
        assert kv.free_blocks + len(kv.prefix_blocks) == kv.num_blocks


class TestDetectorProperties:
    @given(st.floats(0.01, 0.2), st.floats(0.5, 5.0))
    @settings(max_examples=20, deadline=None)
    def test_ph_never_alarms_on_constant(self, delta, threshold):
        ph = PageHinkley(delta=delta, threshold=threshold)
        assert not any(ph.update(-1.0) for _ in range(300))


class TestPowerModelProperties:
    @given(st.floats(1e9, 1e15), st.floats(1e6, 1e12),
           st.floats(210.0, 1800.0))
    @settings(max_examples=60, deadline=None)
    def test_time_positive_power_within_envelope(self, flops, mem, f):
        m = DVFSModel(A6000)
        t, p = m.iteration_time_power(flops, mem, f)
        assert t > 0
        assert A6000.p_idle <= p <= (A6000.p_idle + A6000.p_static_active
                                     + A6000.p_dyn_compute
                                     + A6000.p_dyn_memory + 1e-9)

    @given(st.floats(1e9, 1e14), st.floats(1e6, 1e11))
    @settings(max_examples=30, deadline=None)
    def test_latency_monotone_nonincreasing_in_frequency(self, flops, mem):
        m = DVFSModel(A6000)
        ts = [m.iteration_time_power(flops, mem, f)[0]
              for f in (300.0, 900.0, 1500.0, 1800.0)]
        assert all(a >= b - 1e-12 for a, b in zip(ts, ts[1:]))


class TestWorkloadProperties:
    @given(st.sampled_from(sorted(PROTOTYPES)), st.integers(1, 200),
           st.integers(0, 10))
    @settings(max_examples=20, deadline=None)
    def test_generated_requests_within_spec(self, name, n, seed):
        spec = PROTOTYPES[name]
        reqs = generate_requests(spec, n, seed=seed)
        assert len(reqs) == n
        last = 0.0
        for r in reqs:
            assert spec.context_range[0] <= r.prompt_len \
                <= spec.context_range[1]
            assert spec.generation_range[0] <= r.output_len \
                <= spec.generation_range[1]
            assert 0 <= r.template_id < spec.template_pool
            assert r.arrival_time >= last
            last = r.arrival_time

    @given(st.integers(0, 5))
    @settings(max_examples=5, deadline=None)
    def test_azure_trace_context_heavy_dominates(self, seed):
        reqs = generate_azure_trace(1200.0, base_rate=2.0, seed=seed)
        assert len(reqs) > 100
        ctx_heavy = sum(1 for r in reqs if r.prompt_len > 2 * r.output_len)
        assert ctx_heavy / len(reqs) > 0.6       # 2024 mix: context-heavy


class TestEventOrderingProperties:
    """The discrete-event driver must never run an engine backwards in
    time, whatever the trace shape or node count."""

    @given(n_nodes=st.integers(1, 4),
           seed=st.integers(0, 1000),
           rate=st.floats(0.3, 8.0),
           workload=st.sampled_from(["normal", "high_concurrency",
                                     "long_generation"]))
    @settings(max_examples=15, deadline=None)
    def test_clocks_never_decrease(self, n_nodes, seed, rate, workload):
        nodes = []
        clocks = {}

        class Probe:
            """Records the engine clock at every iteration-complete."""
            def __init__(self, idx):
                self.idx = idx

            def maybe_act(self, engine):
                clocks.setdefault(self.idx, []).append(engine.clock)
                return None

        cfg = get_config("llama3-3b")
        for i in range(n_nodes):
            eng = InferenceEngine(cfg, EngineConfig())
            eng.submit(generate_requests(PROTOTYPES[workload], 15,
                                         base_rate=rate, seed=seed + i))
            nodes.append(EngineNode(eng, Probe(i)))
        loop = EventLoop(nodes)
        nows = []
        orig_push = loop._push

        def push_probe(t, kind, node):
            nows.append(loop.now)
            orig_push(t, kind, node)
        loop._push = push_probe
        loop.run()

        assert nows == sorted(nows)                 # virtual time monotone
        for series in clocks.values():              # per-engine monotone
            assert all(a <= b for a, b in zip(series, series[1:]))
        for node in nodes:
            assert not node.engine.has_work         # everything drained


class TestNetworkRoutingProperties:
    """ARRIVAL rescheduling through the router event source must keep
    every clock monotone (no same-node reordering, no time travel),
    deliver every request, and — at zero delay — be byte-identical to
    direct submit."""

    CFG = get_config("llama3-3b")

    def _routed_cluster(self, n_nodes, seed, net, policies=None,
                        n_requests=25, rate=3.0):
        cl = ServingCluster(self.CFG, n_nodes=n_nodes, with_tuners=False,
                            policies=policies, network=net)
        cl.submit(generate_requests(PROTOTYPES["normal"], n_requests,
                                    base_rate=rate, seed=seed))
        return cl

    @given(n_nodes=st.integers(1, 3), seed=st.integers(0, 500),
           delay_ms=st.floats(0.0, 60.0), rate=st.floats(0.5, 6.0))
    @settings(max_examples=12, deadline=None)
    def test_rescheduled_arrivals_never_time_travel(self, n_nodes, seed,
                                                    delay_ms, rate):
        clocks = {}

        class Probe:
            def __init__(self, idx):
                self.idx = idx

            def maybe_act(self, engine):
                clocks.setdefault(self.idx, []).append(engine.clock)
                return None

        net = NetworkModel(NetworkConfig(hop_latency_s=delay_ms * 1e-3 / 2,
                                         router_service_s=1e-4,
                                         distribution="lognormal",
                                         jitter=0.3), seed=seed)
        cl = self._routed_cluster(n_nodes, seed, net,
                                  policies=[Probe(i)
                                            for i in range(n_nodes)],
                                  rate=rate)
        loop = EventLoop(cl.nodes, router=cl._deliveries)
        nows = []
        orig_push = loop._push

        def push_probe(t, kind, node):
            nows.append(loop.now)
            orig_push(t, kind, node)
        loop._push = push_probe
        loop.run()

        assert nows == sorted(nows)              # virtual time monotone
        for series in clocks.values():           # per-node event monotone
            assert all(a <= b for a, b in zip(series, series[1:]))
        fin = [r for e in cl.engines for r in e.finished]
        assert len(fin) == 25                    # every delivery landed
        for r in fin:
            assert r.delivery_time >= r.arrival_time
            # never scheduled before the network handed it over
            assert r.first_scheduled_time >= r.delivery_time - 1e-12
        assert all(e.inflight == 0 for e in cl.engines)
        assert not cl.has_work

    @given(n_nodes=st.integers(1, 3), seed=st.integers(0, 500))
    @settings(max_examples=8, deadline=None)
    def test_zero_delay_network_byte_identical_to_direct(self, n_nodes,
                                                         seed):
        def state(net):
            cl = self._routed_cluster(n_nodes, seed, net,
                                      policies=["agft"] * n_nodes)
            steps = cl.drain()
            return {
                "steps": steps,
                "clocks": [e.clock for e in cl.engines],
                "energies": [e.metrics.c.energy_joules_total
                             for e in cl.engines],
                "finished": [len(e.finished) for e in cl.engines],
                "histories": [[(h["t"], h["freq"], h["phase"])
                               for h in p.history]
                              for p in cl.policies],
            }
        assert state(None) == state(NetworkModel())


class TestFeatureProperties:
    @given(st.floats(0.1, 10), st.floats(0, 1e5), st.floats(0, 1e5),
           st.integers(0, 1000), st.integers(0, 64), st.integers(0, 64),
           floats01, floats01)
    @settings(max_examples=60, deadline=None)
    def test_features_bounded_and_finite(self, dur, e, busy, toks, run,
                                         wait, usage, hit):
        w = WindowStats(duration_s=dur, energy_j=e, busy_s=busy,
                        prefill_tokens=toks, cached_prompt_tokens=0,
                        generation_tokens=toks, iterations=max(toks, 1),
                        requests_running=run, requests_waiting=wait,
                        gpu_cache_usage=usage, cache_hit_rate=hit)
        x = FeatureExtractor()(w)
        assert x.shape == (7,)
        assert np.all(np.isfinite(x))
        assert np.all(x >= 0) and np.all(x <= 1.5)
