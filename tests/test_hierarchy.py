"""Hierarchical power-cap coordination tests: band semantics on the
bandit (inverted bands, bands narrower than the grid step, pruning /
refinement interaction), band clamping on windowed policies, forced
moves billed as DVFS transitions through the event loop, water-filling
allocation properties, the coordinator meeting a cap that uncoordinated
per-node AGFT violates, and the no-cap bit-identity guarantee against
``tests/golden_agft_decisions.json``."""
import dataclasses
import json
import os

import numpy as np
import pytest

from repro.configs import get_config
from repro.core import AGFTTuner, LinUCBBank
from repro.core.pruning import PruningConfig, PruningFramework
from repro.energy import A6000
from repro.policies import (BandCoordinator, FleetPowerMeter, StaticPolicy,
                            available_policies, full_busy_power_w,
                            get_policy, waterfill)
from repro.serving import (EngineConfig, EngineNode, EventLoop,
                           InferenceEngine, NetworkModel)
from repro.serving.cluster import ServingCluster, route_by_length
from repro.workloads import PROTOTYPES, generate_requests

CFG = get_config("llama3-3b")
GOLDEN = os.path.join(os.path.dirname(__file__),
                      "golden_agft_decisions.json")


def make_engine(hardware=A6000, **kw):
    return InferenceEngine(CFG, EngineConfig(**kw), hardware=hardware,
                           initial_frequency=hardware.f_max)


def trace(n=80, rate=3.0, seed=21, workload="normal"):
    return generate_requests(PROTOTYPES[workload], n, base_rate=rate,
                             seed=seed)


def mixed_trace(n, seed=11, rate=4.0):
    return (generate_requests(PROTOTYPES["long_context"], n // 2,
                              base_rate=rate, seed=seed)
            + generate_requests(PROTOTYPES["normal"], n - n // 2,
                                base_rate=rate, seed=seed + 1))


# ---------------------------------------------------------------------------
# Band semantics on the LinUCB bank
# ---------------------------------------------------------------------------

class TestBankBand:
    FREQS = [210.0 + 90.0 * k for k in range(18)] + [1800.0]

    def test_band_masks_selection_but_keeps_statistics(self):
        bank = LinUCBBank(self.FREQS, dim=3)
        x = np.array([1.0, 0.5, 0.2])
        for f in bank.frequencies:
            bank.arms[f].update(x, -1.0, edp=5.0)
        bank.set_band(900.0, 1200.0)
        assert bank.legal_frequencies() == [930.0, 1020.0, 1110.0, 1200.0]
        assert 900.0 <= bank.select_ucb(x, 0.5) <= 1200.0
        assert 900.0 <= bank.select_greedy(x) <= 1200.0
        assert 900.0 <= bank.select_thompson(x) <= 1200.0
        # arms outside the band keep their stats and come back on widen
        assert bank.arms[210.0].n == 1
        bank.set_band(A6000.f_min, A6000.f_max)
        assert bank.legal_frequencies() == bank.frequencies
        bank.clear_band()
        assert bank.band is None

    def test_untried_sweep_restricted_to_band(self):
        bank = LinUCBBank(self.FREQS, dim=3)
        bank.set_band(600.0, 900.0)
        x = np.zeros(3)
        # lowest LEGAL untried arm first, not the global lowest
        assert bank.select_ucb(x, 0.8) == 660.0

    def test_inverted_band_is_normalized(self):
        tuner = AGFTTuner(A6000)
        tuner.set_band(1500.0, 1200.0)               # f_lo > f_hi
        assert tuner.band == (1200.0, 1500.0)
        legal = tuner.bank.legal_frequencies()
        assert legal and all(1200.0 <= f <= 1500.0 for f in legal)

    def test_band_narrower_than_step_leaves_one_legal_arm(self):
        tuner = AGFTTuner(A6000)                     # 90 MHz initial grid
        tuner.set_band(1000.0, 1001.0)               # contains no arm
        legal = tuner.bank.legal_frequencies()
        assert len(legal) == 1
        assert legal[0] == 1020.0                    # nearest to midpoint
        # and the bandit still selects it
        x = np.zeros(tuner.features.dim)
        assert tuner.bank.select_ucb(x, 0.8) == 1020.0

    def test_band_outside_envelope_clamps(self):
        tuner = AGFTTuner(A6000)
        tuner.set_band(2000.0, 3000.0)
        assert tuner.band == (A6000.f_max, A6000.f_max)
        assert tuner.bank.legal_frequencies() == [A6000.f_max]

    def test_rebuild_reapplies_band(self):
        bank = LinUCBBank(self.FREQS, dim=3)
        bank.set_band(1100.0, 1400.0)
        bank.rebuild([1100.0 + 15.0 * k for k in range(30)],
                     warm_from=1200.0)
        legal = bank.legal_frequencies()
        assert legal and all(1100.0 <= f <= 1400.0 for f in legal)
        assert any(f > 1400.0 for f in bank.frequencies)  # arms exist...
        assert all(f <= 1400.0 for f in legal)            # ...but masked

    def test_pruning_never_orphans_the_band(self):
        bank = LinUCBBank([210.0, 900.0, 1800.0], dim=3)
        bank.set_band(850.0, 950.0)                  # only 900 is legal
        pruner = PruningFramework(PruningConfig(min_arms=1), A6000.f_max)
        pruner._prune(bank, 900.0, "extreme", 1)
        assert 900.0 in bank.arms                    # refused
        pruner._prune(bank, 210.0, "extreme", 1)
        assert 210.0 not in bank.arms                # out-of-band: fine

    def test_refinement_grid_clipped_to_band(self):
        tuner = AGFTTuner(A6000)
        tuner.set_band(1200.0, 1320.0)
        x = np.zeros(tuner.features.dim)
        for f in tuner.bank.frequencies:
            for _ in range(tuner.cfg.refinement.stat_min_samples):
                tuner.bank.arms[f].update(x, -1.0, edp=5.0)
        anchor = tuner.refiner.maybe_refine(tuner.bank, tuner.pruner, x,
                                            tuner.cfg.refinement.interval)
        assert anchor is not None
        assert all(1200.0 <= f <= 1320.0 for f in tuner.bank.frequencies)


# ---------------------------------------------------------------------------
# Band hook on windowed policies
# ---------------------------------------------------------------------------

class TestWindowedPolicyBand:
    def test_static_decision_clamped_into_band(self):
        policy = StaticPolicy(A6000, frequency_mhz=1200.0)
        policy.set_band(600.0, 900.0)
        eng = make_engine()
        eng.submit(trace(40, seed=14))
        eng.drain(policy=policy)
        assert eng.frequency == 900.0

    def test_inverted_band_tolerated(self):
        policy = StaticPolicy(A6000, frequency_mhz=1200.0)
        policy.set_band(900.0, 600.0)
        assert policy.band == (600.0, 900.0)

    def test_ondemand_fmax_jump_respects_band(self):
        policy = get_policy("ondemand")
        policy.set_band(A6000.f_min, 1110.0)
        eng = make_engine()
        eng.submit(trace(60, rate=8.0, seed=9))      # busy -> wants f_max
        eng.drain(policy=policy)
        freqs = [h["freq"] for h in policy.history if h["acted"]]
        assert freqs and max(freqs) <= 1110.0

    def test_oracle_resweeps_inside_band(self):
        policy = get_policy("oracle")
        policy.set_band(A6000.f_min, 900.0)
        eng = make_engine()
        eng.submit(trace(40, seed=15))
        eng.drain(policy=policy)
        assert policy.frequency_mhz <= 900.0
        assert eng.frequency <= 900.0


# ---------------------------------------------------------------------------
# Driver propagation: forced moves are real DVFS transitions
# ---------------------------------------------------------------------------

class _StubCoordinator:
    """Minimal band coordinator: fixed per-node bands every tick."""
    scope = "fleet"
    coordinates_bands = True
    sampling_period_s = 0.8

    def __init__(self, bands, power_cap_w=None):
        self.bands = bands
        self.power_cap_w = power_cap_w

    def initial_bands(self, engines):
        return self.bands

    def act(self, engines, now):
        return None


class TestDriverPropagation:
    def test_band_excluding_current_freq_forces_billed_move(self):
        hw = dataclasses.replace(A6000, dvfs_transition_cost_j=5.0)
        eng = make_engine(hardware=hw)               # starts at f_max
        eng.submit(trace(40, seed=16))
        loop = EventLoop([EngineNode(eng, None)],
                         fleet_policy=_StubCoordinator([(210.0, 1200.0)]))
        loop.run()
        # the very first propagation moved 1800 -> 1200 and billed it
        assert eng.metrics.c.freq_transitions_total >= 1
        assert eng.metrics.c.energy_joules_total >= 5.0
        assert eng.frequency <= 1200.0

    def test_band_reaches_node_policy_set_band(self):
        eng = make_engine()
        eng.submit(trace(40, seed=17))
        tuner = AGFTTuner(A6000)
        loop = EventLoop([EngineNode(eng, tuner)],
                         fleet_policy=_StubCoordinator([(600.0, 1200.0)]))
        loop.run()
        assert tuner.band == (600.0, 1200.0)
        acted = [h["freq"] for h in tuner.history]
        assert acted and all(600.0 <= f <= 1200.0 for f in acted)

    def test_inverted_band_from_coordinator_normalized(self):
        eng = make_engine()
        eng.submit(trace(30, seed=18))
        loop = EventLoop([EngineNode(eng, None)],
                         fleet_policy=_StubCoordinator([(1200.0, 600.0)]))
        loop.run()
        assert eng.frequency <= 1200.0

    def test_cap_metering_accumulates(self):
        eng = make_engine()
        eng.submit(trace(80, rate=8.0, seed=19))
        meter = FleetPowerMeter(A6000, power_cap_w=1.0)   # absurdly low
        loop = EventLoop([EngineNode(eng, None)], fleet_policy=meter)
        loop.run()
        assert loop.metered_s > 0.0
        assert loop.cap_violation_s == pytest.approx(loop.metered_s)
        assert loop.peak_fleet_power_w > 1.0
        assert loop.mean_fleet_power_w > 1.0


# ---------------------------------------------------------------------------
# Band propagation under delayed (routed) arrivals
# ---------------------------------------------------------------------------

class TestBandsUnderDelayedArrivals:
    """PR-4 band propagation composed with the routed-ARRIVAL event path:
    a coordinator's bands reach engines and node policies while every
    request is still traversing the network, and forced moves are billed
    exactly as on the instant-placement path."""

    def test_initial_band_billed_while_requests_in_flight(self):
        hw = dataclasses.replace(A6000, dvfs_transition_cost_j=5.0)
        cl = ServingCluster(CFG, n_nodes=2, hardware=hw,
                            with_tuners=False,
                            fleet_policy=_StubCoordinator(
                                [(210.0, 1200.0)] * 2),
                            network=NetworkModel.from_spec("fixed:30"))
        cl.submit(trace(40, seed=16))
        # bands propagate at loop construction (t=0) — before the first
        # ROUTE event, so every routed request is still in the network
        loop = EventLoop(cl.nodes, fleet_policy=cl.fleet_policy,
                         router=cl._deliveries)
        for eng in cl.engines:
            assert eng.inflight > 0                  # still in flight...
            assert eng.metrics.c.freq_transitions_total == 1   # ...billed
            assert eng.metrics.c.energy_joules_total >= 5.0
            assert eng.frequency == 1200.0
        loop.run()
        assert sum(len(e.finished) for e in cl.engines) == 40
        assert all(e.frequency <= 1200.0 for e in cl.engines)
        assert all(e.inflight == 0 for e in cl.engines)

    def test_band_reaches_tuner_with_arrivals_in_flight(self):
        tuners = [AGFTTuner(A6000), AGFTTuner(A6000)]
        cl = ServingCluster(CFG, n_nodes=2, policies=tuners,
                            fleet_policy=_StubCoordinator(
                                [(600.0, 1200.0)] * 2),
                            network=NetworkModel.from_spec("fixed:25"))
        cl.submit(trace(60, seed=17))
        cl.drain()
        assert sum(len(e.finished) for e in cl.engines) == 60
        for t in tuners:
            assert t.band == (600.0, 1200.0)
            acted = [h["freq"] for h in t.history]
            assert acted and all(600.0 <= f <= 1200.0 for f in acted)

    def test_cap_metering_spans_inflight_gaps(self):
        """The fleet meter must keep metering across windows where every
        node is idle but deliveries are still in flight (the FLEET_TICK
        train may not die before the network drains)."""
        cl = ServingCluster(CFG, n_nodes=2, with_tuners=False,
                            fleet_policy=get_policy("fleet-meter",
                                                    power_cap_w=1.0),
                            network=NetworkModel.from_spec("fixed:500"))
        cl.submit(trace(30, rate=1.0, seed=18))
        cl.drain()
        loop = cl._loop
        assert sum(len(e.finished) for e in cl.engines) == 30
        assert loop.metered_s > 0.0
        assert loop.cap_violation_s <= loop.metered_s
        assert loop.peak_fleet_power_w > 1.0


# ---------------------------------------------------------------------------
# Water-filling
# ---------------------------------------------------------------------------

class TestWaterfill:
    def test_proportional_when_unconstrained(self):
        alloc = waterfill(100.0, [1.0, 3.0], [1e9, 1e9])
        assert alloc == pytest.approx([25.0, 75.0])

    def test_demand_cap_redistributes(self):
        alloc = waterfill(100.0, [1.0, 1.0], [10.0, 1e9])
        assert alloc[0] == pytest.approx(10.0)
        assert alloc[1] == pytest.approx(90.0)

    def test_slack_flows_back_past_demands(self):
        # demands prioritize scarce budget but must not waste slack
        alloc = waterfill(100.0, [1.0, 1.0], [10.0, 20.0])
        assert sum(alloc) == pytest.approx(100.0)
        assert alloc[1] > alloc[0]

    def test_zero_weights_split_evenly(self):
        alloc = waterfill(60.0, [0.0, 0.0, 0.0], [1e9] * 3)
        assert alloc == pytest.approx([20.0, 20.0, 20.0])

    def test_full_busy_power_monotone(self):
        grid = A6000.frequencies()
        powers = [full_busy_power_w(A6000, f) for f in grid]
        assert powers == sorted(powers)
        assert powers[-1] == pytest.approx(
            A6000.p_idle + A6000.p_static_active
            + A6000.p_dyn_compute + A6000.p_dyn_memory)


# ---------------------------------------------------------------------------
# The coordinator end-to-end
# ---------------------------------------------------------------------------

class TestBandCoordinator:
    def test_registry_scopes(self):
        for name in ("hierarchy", "hierarchy-uniform", "fleet-meter"):
            assert name in available_policies(scope="fleet")
            assert name not in available_policies(scope="node")
        p = get_policy("hierarchy", power_cap_w=500.0)
        assert isinstance(p, BandCoordinator)
        assert p.scope == "fleet"
        with pytest.raises(TypeError, match="fleet-scope"):
            p.maybe_act(make_engine())

    def test_uniform_mode_single_frequency_bands(self):
        coord = get_policy("hierarchy-uniform", power_cap_w=800.0)
        bands = coord._compute_bands([1.0] * 4, [None] * 4)
        assert len(set(bands)) == 1
        lo, hi = bands[0]
        assert lo == hi
        assert 4 * full_busy_power_w(A6000, hi) <= 800.0 + 1e-9

    def test_budget_below_floor_maps_to_fmin(self):
        coord = BandCoordinator(A6000, power_cap_w=10.0)
        assert coord._f_for_budget(1.0) == A6000.f_min

    def test_no_cap_produces_no_bands(self):
        coord = BandCoordinator(A6000)               # power_cap_w=None
        eng = make_engine()
        assert coord.initial_bands([eng]) is None
        assert coord.act([eng], 0.8) is None
        assert coord.bands is None

    def test_hierarchy_meets_cap_pernode_violates(self):
        """The acceptance shape at one budget: uncoordinated per-node
        AGFT violates the cap; the hierarchy holds it."""
        def served(fleet_name, cap):
            cl = ServingCluster(
                CFG, n_nodes=4, with_tuners=False,
                policies=["agft"] * 4,
                fleet_policy=get_policy(fleet_name, power_cap_w=cap),
                router=route_by_length)
            cl.submit(mixed_trace(200))
            cl.drain()
            return cl.summary()
        cap = 300.0
        pern = served("fleet-meter", cap)
        hier = served("hierarchy", cap)
        assert pern.cap_violation_s > 0.0
        assert hier.cap_violation_s == 0.0
        assert hier.peak_fleet_power_w <= cap
        assert hier.finished == pern.finished == 200

    def test_load_weighted_bands_differentiate_nodes(self):
        coord = BandCoordinator(A6000, power_cap_w=500.0)
        # hot node (weight 30) vs idle nodes: hotter -> wider budget
        bands = coord._compute_bands([30.0, 0.0, 0.0, 0.0],
                                     [250.0, 26.0, 26.0, 26.0])
        assert bands[0][1] > bands[1][1]


# ---------------------------------------------------------------------------
# No cap => bit-identical decisions (the golden guarantee)
# ---------------------------------------------------------------------------

class TestNoCapGoldenIdentity:
    def test_uncapped_coordinator_keeps_golden_trajectory(self):
        """Attaching an unconfigured hierarchy coordinator (no cap, so no
        bands) must not move a single AGFT decision vs the committed
        golden trajectory."""
        with open(GOLDEN) as f:
            gold = json.load(f)
        tr = gold["trace"]
        tuner = AGFTTuner(A6000)
        cl = ServingCluster(CFG, n_nodes=1, policies=[tuner],
                            fleet_policy=get_policy("hierarchy"))
        cl.submit(generate_requests(PROTOTYPES[tr["workload"]], tr["n"],
                                    base_rate=tr["rate"], seed=tr["seed"]))
        cl.drain()
        assert [h["freq"] for h in tuner.history] == gold["freqs"]
        assert [h["phase"] for h in tuner.history] == gold["phases"]
        assert tuner.round == gold["rounds"]
        eng = cl.engines[0]
        assert eng.metrics.c.energy_joules_total == pytest.approx(
            gold["energy_j"], rel=1e-9)
        assert eng.clock == pytest.approx(gold["clock"], rel=1e-9)
