"""PR-3 hot-path regression net: the vectorized structure-of-arrays
LinUCB bank vs a per-arm reference implementation, deterministic arm
ordering, the precomputed CostModel/DVFS table vs the explicit formulas,
golden AGFT decision-trajectory regression, the parallel benchmark map,
and the empty-run metric guards."""
import json
import os
import warnings

import numpy as np
import pytest

from repro.configs import get_config
from repro.core import AGFTTuner, LinUCBArm, LinUCBBank
from repro.energy import A6000, CostModel, DVFSModel, iteration_cost
from repro.energy.costs import (active_param_count, attention_layers,
                                kv_bytes_per_token_layer)
from repro.serving import EngineConfig, InferenceEngine
from repro.workloads import PROTOTYPES, generate_requests

GOLDEN = os.path.join(os.path.dirname(__file__),
                      "golden_agft_decisions.json")


# ---------------------------------------------------------------------------
# Reference (pre-vectorization) bank: dict of per-arm objects
# ---------------------------------------------------------------------------

class RefBank:
    """The historical dict-of-arms implementation, kept verbatim as the
    numerical reference the vectorized bank must agree with."""

    def __init__(self, frequencies, dim, ridge=1.0, seed=0):
        self.dim = dim
        self.ridge = ridge
        self.rng = np.random.default_rng(seed)
        self.arms = {float(f): LinUCBArm(dim, ridge) for f in frequencies}

    @property
    def frequencies(self):
        return sorted(self.arms.keys())

    def remove(self, f):
        self.arms.pop(float(f), None)

    def rebuild(self, frequencies, warm_from=None):
        proto = self.arms.get(float(warm_from)) if warm_from is not None \
            else None
        new = {}
        for f in sorted({float(g) for g in frequencies}):
            arm = self.arms.get(f)
            if arm is None:
                arm = LinUCBArm(self.dim, self.ridge)
                if proto is not None and proto.n > 0:
                    arm.A = proto.A.copy()
                    arm.A_inv = proto.A_inv.copy()
                    arm.b = proto.b.copy()
                    arm.theta = proto.theta.copy()
                    arm.n = proto.n
                    arm.reward_sum = proto.reward_sum
                    arm.edp_sum = proto.edp_sum
            new[f] = arm
        self.arms = new

    def select_ucb(self, x, alpha):
        untried = [f for f, a in self.arms.items() if a.n == 0]
        if untried:
            return min(untried)
        return max(self.arms, key=lambda f: self.arms[f].ucb(x, alpha))

    def select_thompson(self, x, nu=0.3):
        best_f, best_v = None, -np.inf
        for f, arm in self.arms.items():
            try:
                L = np.linalg.cholesky(
                    (arm.A_inv + arm.A_inv.T) / 2.0
                    + 1e-12 * np.eye(self.dim))
            except np.linalg.LinAlgError:
                L = np.eye(self.dim)
            theta_s = arm.theta + nu * L @ self.rng.standard_normal(self.dim)
            v = float(theta_s @ x)
            if v > best_v:
                best_f, best_v = f, v
        return best_f

    def select_greedy(self, x):
        return max(self.arms, key=lambda f: self.arms[f].predict(x))

    def best_historical(self, min_samples=1):
        cands = {f: a for f, a in self.arms.items() if a.n >= min_samples}
        if not cands:
            return None
        return min(cands, key=lambda f: cands[f].mean_edp)


class TestVectorizedBankEquivalence:
    FREQS = [210.0 + 90.0 * k for k in range(18)]

    def _assert_stats_match(self, bank, ref):
        assert bank.frequencies == ref.frequencies
        for f in ref.frequencies:
            v, a = bank.arms[f], ref.arms[f]
            assert v.n == a.n
            np.testing.assert_allclose(v.A_inv, a.A_inv,
                                       rtol=1e-10, atol=1e-12)
            np.testing.assert_allclose(v.theta, a.theta,
                                       rtol=1e-10, atol=1e-12)
            np.testing.assert_allclose(v.b, a.b, rtol=1e-10, atol=1e-12)

    def test_random_update_rebuild_remove_script(self):
        """Same selections and same sufficient statistics (to 1e-10) as the
        per-arm reference over a randomized update/rebuild/remove script."""
        dim = 7
        bank = LinUCBBank(self.FREQS, dim=dim)
        ref = RefBank(self.FREQS, dim=dim)
        rng = np.random.default_rng(42)
        for step in range(300):
            x = rng.uniform(0, 1.5, dim)
            op = rng.random()
            if op < 0.6:                                   # credit an arm
                f = ref.frequencies[rng.integers(len(ref.frequencies))]
                r = float(rng.normal(-1.0, 0.3))
                edp = float(rng.uniform(1, 30))
                bank.arms[f].update(x, r, edp=edp)
                ref.arms[f].update(x, r, edp=edp)
            elif op < 0.75:                                # selections agree
                alpha = float(rng.uniform(0.2, 1.5))
                assert bank.select_ucb(x, alpha) == ref.select_ucb(x, alpha)
                assert bank.select_greedy(x) == ref.select_greedy(x)
                ms = int(rng.integers(1, 5))
                assert bank.best_historical(ms) == ref.best_historical(ms)
            elif op < 0.85 and len(ref.arms) > 4:          # remove
                f = ref.frequencies[rng.integers(len(ref.frequencies))]
                bank.remove(f)
                ref.remove(f)
            else:                                          # refine/rebuild
                anchor = ref.frequencies[
                    rng.integers(len(ref.frequencies))]
                grid = [max(210.0, min(1800.0, anchor + 15.0 * k))
                        for k in range(-5, 6)]
                bank.rebuild(grid, warm_from=anchor)
                ref.rebuild(grid, warm_from=anchor)
            if step % 25 == 0:
                self._assert_stats_match(bank, ref)
        self._assert_stats_match(bank, ref)

    def test_thompson_matches_reference_stream(self):
        """Same seed, same arm order -> identical RNG-draw-to-arm pairing
        and identical Thompson selections."""
        dim = 4
        bank = LinUCBBank(self.FREQS, dim=dim, seed=9)
        ref = RefBank(sorted(self.FREQS), dim=dim, seed=9)
        rng = np.random.default_rng(3)
        for _ in range(60):
            x = rng.uniform(0, 1, dim)
            f = ref.frequencies[rng.integers(len(ref.frequencies))]
            r = float(rng.normal(-1.0, 0.2))
            bank.arms[f].update(x, r)
            ref.arms[f].update(x, r)
        for _ in range(20):
            x = rng.uniform(0, 1, dim)
            assert bank.select_thompson(x, 0.3) == ref.select_thompson(x, 0.3)

    def test_batched_update_matches_sequential(self):
        dim = 5
        b1 = LinUCBBank(self.FREQS[:6], dim=dim)
        b2 = LinUCBBank(self.FREQS[:6], dim=dim)
        rng = np.random.default_rng(11)
        fs = self.FREQS[:4]
        X = rng.uniform(0, 1, (4, dim))
        r = rng.normal(-1, 0.2, 4)
        edp = rng.uniform(1, 10, 4)
        for i, f in enumerate(fs):
            b1.arms[f].update(X[i], float(r[i]), edp=float(edp[i]))
        b2.update_arms(fs, X, r, edps=edp)
        for f in fs:
            np.testing.assert_allclose(b1.arms[f].A_inv, b2.arms[f].A_inv,
                                       rtol=1e-10, atol=1e-12)
            np.testing.assert_allclose(b1.arms[f].theta, b2.arms[f].theta,
                                       rtol=1e-10, atol=1e-12)
            assert b1.arms[f].n == b2.arms[f].n

    def test_batched_update_rejects_duplicate_arms(self):
        bank = LinUCBBank(self.FREQS[:4], dim=3)
        with pytest.raises(ValueError, match="distinct"):
            bank.update_arms([self.FREQS[0], self.FREQS[0]],
                             np.ones((2, 3)), [0.1, 0.2])


class TestDeterministicArmOrder:
    def test_iteration_order_is_ascending_regardless_of_history(self):
        bank = LinUCBBank([1200.0, 300.0, 900.0], dim=3)
        assert list(bank.arms) == [300.0, 900.0, 1200.0]
        # rebuild handing frequencies in descending order
        bank.rebuild([1500.0, 600.0, 900.0], warm_from=900.0)
        assert list(bank.arms) == [600.0, 900.0, 1500.0]
        assert bank.frequencies == [600.0, 900.0, 1500.0]
        bank.remove(900.0)
        assert list(bank.arms) == [600.0, 1500.0]

    def test_selection_tiebreak_and_rng_pairing_order_invariant(self):
        """Two banks whose action spaces were assembled in opposite orders
        make identical selections — tie-breaks and Thompson draws no longer
        depend on rebuild() history."""
        dim = 3
        up = LinUCBBank([600.0, 900.0, 1200.0], dim=dim, seed=5)
        down = LinUCBBank([1200.0, 900.0, 600.0], dim=dim, seed=5)
        x = np.array([1.0, 0.5, 0.2])
        # untried sweep: both start from the lowest frequency
        assert up.select_ucb(x, 0.5) == down.select_ucb(x, 0.5) == 600.0
        for bank in (up, down):
            for f in bank.frequencies:
                bank.arms[f].update(x, -1.0, edp=5.0)
        assert up.select_ucb(x, 0.5) == down.select_ucb(x, 0.5)
        assert up.select_greedy(x) == down.select_greedy(x)
        assert up.select_thompson(x) == down.select_thompson(x)


# ---------------------------------------------------------------------------
# Physics layer: precomputed CostModel / DVFS table vs explicit formulas
# ---------------------------------------------------------------------------

ARCHS = ["llama3-3b", "tinyllama-1.1b"]


class TestCostModel:
    @pytest.mark.parametrize("arch", ARCHS)
    def test_matches_explicit_formula(self, arch):
        cfg = get_config(arch)
        cm = CostModel(cfg)
        rng = np.random.default_rng(0)
        for _ in range(50):
            pf = int(rng.integers(0, 512))
            dec = int(rng.integers(0, 64))
            ctx = float(rng.uniform(0, 4096))
            flops, mem = cm.iteration_cost(prefill_tokens=pf,
                                           decode_seqs=dec, avg_context=ctx)
            # explicit (pre-hoisting) formula, recomputed from primitives
            n_active = active_param_count(cfg)
            attn_l = attention_layers(cfg)
            d_attn = cfg.num_heads * cfg.head_dim
            window = cfg.attention_window or 0
            tokens = pf + dec
            eff = min(ctx, window) if window else ctx
            ref_flops = 2.0 * n_active * tokens
            ref_flops += 4.0 * d_attn * attn_l * (
                pf * max(eff, 1.0) * 0.5 + dec * max(eff, 1.0))
            kv_l = kv_bytes_per_token_layer(cfg, 2) * attn_l
            ref_mem = n_active * 2
            ref_mem += tokens * kv_l
            ref_mem += dec * kv_l * max(eff, 1.0)
            ref_mem += pf * kv_l * 0.1
            assert flops == ref_flops
            assert mem == ref_mem

    def test_functional_api_uses_cached_model(self):
        cfg = get_config("llama3-3b")
        a = iteration_cost(cfg, prefill_tokens=32, decode_seqs=8,
                           avg_context=500.0)
        b = CostModel(cfg).iteration_cost(prefill_tokens=32, decode_seqs=8,
                                          avg_context=500.0)
        assert a == b


class TestDVFSTable:
    def test_table_matches_scalar_formula_on_and_off_grid(self):
        sp = A6000
        model = DVFSModel(sp)
        rng = np.random.default_rng(1)
        freqs = sp.frequencies() + [707.0, 1033.3]        # off-grid too
        for f in freqs:
            flops = float(rng.uniform(1e9, 1e13))
            mem = float(rng.uniform(1e6, 1e11))
            t, p = model.iteration_time_power(flops, mem, f)
            fr = min(max(f / sp.f_max, 1e-3), 1.0)
            thr = fr if fr <= sp.perf_knee else sp.perf_knee \
                + sp.perf_slope_above_knee * (fr - sp.perf_knee)
            t_comp = flops / (sp.peak_flops * thr)
            bw = min(1.0, (fr / sp.bw_knee) ** sp.bw_beta)
            t_mem = mem / (sp.mem_bw * bw)
            t_busy = max(t_comp, t_mem)
            t_ref = t_busy + sp.iteration_overhead_s
            u_busy, u_mem = t_busy / t_ref, t_mem / t_ref
            p_ref = (sp.p_idle + sp.p_static_active * u_busy
                     + sp.p_dyn_compute * u_busy * fr ** sp.alpha
                     + sp.p_dyn_memory * u_mem)
            assert t == t_ref
            assert p == p_ref

    def test_zero_work_is_idle(self):
        model = DVFSModel(A6000)
        t, p = model.iteration_time_power(0.0, 0.0, 1200.0)
        assert p == A6000.p_idle
        assert t == A6000.iteration_overhead_s


# ---------------------------------------------------------------------------
# Golden AGFT decision-history regression (CostModel + vectorized bank)
# ---------------------------------------------------------------------------

class TestGoldenDecisionTrajectory:
    def test_regression_trace_reproduces_golden(self):
        """The exact decision sequence captured on the pre-vectorization
        code (PR 2) must survive the CostModel + SoA-bank hot path."""
        with open(GOLDEN) as f:
            gold = json.load(f)
        tr = gold["trace"]
        eng = InferenceEngine(get_config("llama3-3b"), EngineConfig(),
                              initial_frequency=A6000.f_max)
        eng.submit(generate_requests(PROTOTYPES[tr["workload"]], tr["n"],
                                     base_rate=tr["rate"], seed=tr["seed"]))
        tuner = AGFTTuner(A6000)
        eng.drain(policy=tuner)
        assert [h["freq"] for h in tuner.history] == gold["freqs"]
        assert [h["phase"] for h in tuner.history] == gold["phases"]
        assert tuner.round == gold["rounds"]
        assert eng.metrics.c.energy_joules_total == pytest.approx(
            gold["energy_j"], rel=1e-9)
        assert eng.clock == pytest.approx(gold["clock"], rel=1e-9)


# ---------------------------------------------------------------------------
# Parallel benchmark harness + empty-run guards
# ---------------------------------------------------------------------------

def _square(v):
    return v * v


class TestParallelMap:
    def test_order_preserving_and_parallel(self):
        from benchmarks.parallel import pmap
        items = list(range(12))
        assert pmap(_square, items, jobs=2) == [v * v for v in items]

    def test_serial_fallbacks(self):
        from benchmarks.parallel import pmap
        assert pmap(_square, [3], jobs=8) == [9]
        assert pmap(_square, [1, 2, 3], jobs=1) == [1, 4, 9]

    def test_nested_call_degrades_to_serial(self, monkeypatch):
        import benchmarks.parallel as par
        monkeypatch.setenv("REPRO_BENCH_WORKER", "1")
        assert par.in_worker()
        assert par.pmap(_square, [2, 4], jobs=4) == [4, 16]


class TestPerfBaselineGate:
    def _row(self, us, kind="per_iteration", derived="ok", wall=1.0):
        return {"wall_s": wall, "us_per_call": us, "us_kind": kind,
                "derived": derived}

    def test_gate_fails_on_error_and_big_iteration_regression(self):
        from benchmarks.run import check_against_baseline
        base = {"benchmarks": {"fig5": self._row(40.0),
                               "tab6": self._row(1e6, kind="wall")}}
        cur = {"benchmarks": {"fig5": self._row(90.0),
                              "tab6": self._row(9e6, kind="wall"),
                              "fig7": self._row(0.0, derived="ERROR(x)")}}
        fails = check_against_baseline(cur, base)
        assert any("fig5" in f for f in fails)       # >2x per-iteration
        assert any("ERROR" in f for f in fails)      # errored cell
        assert not any("tab6" in f for f in fails)   # wall rows not gated

    def test_gate_passes_within_threshold(self):
        from benchmarks.run import check_against_baseline
        base = {"benchmarks": {"fig5": self._row(40.0)}}
        cur = {"benchmarks": {"fig5": self._row(75.0)}}
        assert check_against_baseline(cur, base) == []


class TestEmptyRunGuards:
    def test_zero_finished_requests_yield_nan_not_warning(self):
        from benchmarks.common import run_workload
        with warnings.catch_warnings():
            warnings.simplefilter("error", RuntimeWarning)
            row = run_workload("normal", n_requests=0)
        assert row["finished"] == 0
        assert np.isnan(row["ttft_s"])
        assert np.isnan(row["tpot_s"])

    def test_mean_helper(self):
        from benchmarks.common import _mean
        assert np.isnan(_mean([]))
        assert _mean([1.0, 3.0]) == 2.0
